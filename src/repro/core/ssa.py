"""Stochastic Spiking Attention (SSA) — the paper's core algorithm.

Implements paper Eq. (6) / Algorithm 1:

    SSA(Q^t, K^t, V^t) = BNL( BNL(Q^t K^t^T) V^t )

with Q^t, K^t, V^t binary ``[T, ..., N, d_k]`` spike trains per head. All
matrix products reduce to AND + count because the operands are binary; the
BNL normalisers are the hardware integer comparators with I_max = d_k
(scores) and I_max = N (output).

Three interchangeable implementations are provided:

* ``ssa_attention``           — differentiable reference used in training
                                (float ops + straight-through Bernoulli).
* ``ssa_attention_integer``   — bit-faithful integer simulation of the SSA
                                tile (uint8 counters, integer comparators);
                                used by tests as the hardware oracle.
* ``kernels/ssa_attention.py``— the Pallas TPU kernel (bit-packed uint32
                                lanes + popcount); validated against the
                                integer simulation.

Shapes follow the JAX convention ``[T, B, H, N, d]`` (time-major so that
lax.scan pipelines timesteps exactly like the hardware streams them).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.spikes import bernoulli_st, bnl_integer

Array = jax.Array


def _causal_mask(n: int, dtype=jnp.float32) -> Array:
    return jnp.tril(jnp.ones((n, n), dtype=dtype))


def ssa_attention(
    key: Array,
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = False,
) -> Array:
    """Differentiable SSA over spike trains ``[T, B, H, N, d]`` in {0,1}.

    Returns binary attention output of the same shape. Per timestep t:

        S^t[n,n'] ~ Bern( (1/d) sum_d Q^t[n,d] AND K^t[n',d] )   (Alg.1 l.5)
        A^t[n,d]  ~ Bern( (1/N) sum_n' S^t[n,n'] AND V^t[n',d] ) (Alg.1 l.9)

    For binary operands AND == multiply, so einsum is the exact rate math;
    the Bernoulli sampling path matches the integer comparator because both
    compare against a uniform grid of the same resolution.
    """
    T, B, H, N, d = q.shape
    keys = jax.random.split(key, 2 * T).reshape(T, 2, 2)

    mask = _causal_mask(N, q.dtype) if causal else None

    def per_t(args):
        kk, qt, kt, vt = args
        # scores: [B, H, N, N] counts / d
        counts_s = jnp.einsum("bhnd,bhmd->bhnm", qt, kt)
        p_s = counts_s / d
        if mask is not None:
            p_s = p_s * mask
        u_s = jax.random.uniform(kk[0], p_s.shape, dtype=p_s.dtype)
        s_t = bernoulli_st(p_s, u_s)
        # output: [B, H, N, d] counts / N
        counts_a = jnp.einsum("bhnm,bhmd->bhnd", s_t, vt)
        # The output BNL comparator has a fixed range I_max = N (§IV-B-2): the
        # hardware draws r ~ U{0..N-1} regardless of how many keys a causal
        # row can see, so the reference divides by N in causal mode too —
        # keeping it distribution-identical to ``ssa_attention_integer``.
        p_a = counts_a / float(N)
        p_a = jnp.clip(p_a, 0.0, 1.0)
        u_a = jax.random.uniform(kk[1], p_a.shape, dtype=p_a.dtype)
        return bernoulli_st(p_a, u_a)

    # vmap over time: SSA is stateless across t (BNL has no membrane), which
    # is exactly why the hardware tile can pipeline timesteps back-to-back.
    return jax.vmap(lambda kk, qt, kt, vt: per_t((kk, qt, kt, vt)))(keys, q, k, v)


def ssa_attention_integer(
    key: Array,
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = False,
) -> Array:
    """Bit-faithful integer SSA tile simulation (the test oracle).

    Operands must be integer {0,1} arrays ``[T, B, H, N, d]``. Uses uint8
    counters (d_k <= 256, §IV-B-2) and the unnormalised integer comparator
    (count > r, r ~ U{0..I_max-1}). Deterministic given ``key``. Returns
    uint8 spikes.
    """
    T, B, H, N, d = q.shape
    assert d <= 256, "SSA counter is UINT8: d_K up to 2^8 = 256 (paper)"
    qi = q.astype(jnp.int32)
    ki = k.astype(jnp.int32)
    vi = v.astype(jnp.int32)
    keys = jax.random.split(key, 2 * T).reshape(T, 2, 2)

    imask = jnp.tril(jnp.ones((N, N), jnp.int32)) if causal else None

    def per_t(kk, qt, kt, vt):
        counts_s = jnp.einsum("bhnd,bhmd->bhnm", qt, kt)  # AND + count
        if imask is not None:
            counts_s = counts_s * imask
        r_s = jax.random.randint(kk[0], counts_s.shape, 0, d, dtype=jnp.int32)
        s_t = (counts_s > r_s).astype(jnp.int32)
        counts_a = jnp.einsum("bhnm,bhmd->bhnd", s_t, vt)
        r_a = jax.random.randint(kk[1], counts_a.shape, 0, N, dtype=jnp.int32)
        a_t = (counts_a > r_a).astype(jnp.uint8)
        return a_t

    return jax.vmap(per_t)(keys, q, k, v)


def ssa_attention_rate(q_rate: Array, k_rate: Array, v_rate: Array, *, causal: bool = False) -> Array:
    """Expected value of SSA output rates given input rates (analysis tool).

    E[SSA] = clip((S_rate V_rate)/N) with S_rate = (Q_rate K_rate^T)/d — the
    deterministic limit as T -> inf. Used by convergence tests/benchmarks.
    Shapes ``[B, H, N, d]``.
    """
    d = q_rate.shape[-1]
    n = q_rate.shape[-2]
    s = jnp.einsum("bhnd,bhmd->bhnm", q_rate, k_rate) / d
    if causal:
        s = s * _causal_mask(n, s.dtype)
    a = jnp.einsum("bhnm,bhmd->bhnd", jnp.clip(s, 0.0, 1.0), v_rate) / n
    return jnp.clip(a, 0.0, 1.0)


def lif_spiking_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    v_thresh_s: float = 0.5,
    v_thresh_a: float = 0.5,
    causal: bool = False,
) -> Array:
    """Spikformer-style baseline attention  LIF(LIF(Q^t K^t^T) V^t)  (Table I, SNN col).

    Stateful across timesteps: the LIF membranes integrate the (scaled)
    integer products over t. This is the SOTA-spiking-transformer baseline
    the paper compares SSA against (SNN-Digi-Opt energy model uses it).
    """
    T, B, H, N, d = q.shape
    mask = _causal_mask(N, q.dtype) if causal else None

    def step(carry, qkv_t):
        v_s, v_a = carry
        qt, kt, vt = qkv_t
        scores = jnp.einsum("bhnd,bhmd->bhnm", qt, kt) / d
        if mask is not None:
            scores = scores * mask
        v_s = 0.5 * v_s + scores
        s_t = (v_s >= v_thresh_s).astype(q.dtype)
        s_t_grad = s_t  # heaviside handled by caller's surrogate if training
        v_s = v_s * (1.0 - s_t)
        out = jnp.einsum("bhnm,bhmd->bhnd", s_t_grad, vt) / N
        v_a = 0.5 * v_a + out
        a_t = (v_a >= v_thresh_a).astype(q.dtype)
        v_a = v_a * (1.0 - a_t)
        return (v_s, v_a), a_t

    v_s0 = jnp.zeros((B, H, N, N), q.dtype)
    v_a0 = jnp.zeros((B, H, N, d), q.dtype)
    _, out = lax.scan(step, (v_s0, v_a0), (q, k, v))
    return out


def ann_attention(q: Array, k: Array, v: Array, *, causal: bool = False) -> Array:
    """Vanilla softmax attention (Table I ANN column) — the ANN baseline."""
    d = q.shape[-1]
    scores = jnp.einsum("bhnd,bhmd->bhnm", q, k) / jnp.sqrt(jnp.asarray(d, q.dtype))
    if causal:
        n = q.shape[-2]
        neg = jnp.finfo(q.dtype).min
        scores = jnp.where(_causal_mask(n, jnp.bool_)[None, None], scores, neg)
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhnm,bhmd->bhnd", w, v)
