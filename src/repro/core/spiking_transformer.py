"""Xpikeformer paper models: spiking ViT (encoder) and spiking GPT (decoder).

These are the models of §VI (Tables III & IV) at paper scale, built from
the paper's three ingredients:

* Bernoulli rate coding + LIF neurons           (core/spikes.py)
* stochastic spiking attention (SSA)            (core/ssa.py)
* AIMC-executed linear layers with PCM non-idealities, HWAT and GDC
                                                (core/aimc.py)

Each model runs in one of three attention/activation modes, matching the
paper's comparison rows:

  mode="ann"  — vanilla transformer (softmax attention, GELU MLP, LayerNorm)
  mode="lif"  — Spikformer-style SNN: LIF(LIF(QK^T)V) attention  [13]
  mode="ssa"  — Xpikeformer: BNL(BNL(QK^T)V) stochastic spiking attention

and one of three weight-execution modes (AIMCSim):

  wmode="ideal" — float weights (conventional training, stage 1)
  wmode="hwat"  — quantisation + programming noise in the forward pass,
                  ideal backward (hardware-aware training, stage 2)
  wmode="hw"    — programmed PCM state with drift at time t + optional GDC
                  (long-term inference, Fig. 7 / Table V)

The spiking primitives (SSA attention, LIF, spiking linear) are taken from
a pluggable compute backend (``repro.engine``): the differentiable float
reference, the bit-faithful integer hardware oracle, or the bit-packed
Pallas kernels.  ``vit_forward``/``gpt_forward`` default to the reference
backend for backward compatibility; prefer driving these models through
``repro.engine.XpikeformerEngine``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import aimc as AM
from repro.core import spikes as SP
from repro.core import ssa as SSA

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AIMCSim:
    wmode: str = "ideal"  # ideal | hwat | hw
    cfg: AM.AIMCConfig = AM.AIMCConfig()
    t_seconds: float = 0.0
    gdc: bool = True


@dataclasses.dataclass(frozen=True)
class SpikingConfig:
    depth: int
    dim: int
    num_heads: int
    T: int = 4
    mode: str = "ssa"  # ann | lif | ssa
    mlp_ratio: int = 4
    # ViT task
    image_size: int = 32
    patch_size: int = 4
    num_classes: int = 10
    in_channels: int = 3
    # GPT task
    input_dim: int = 0  # continuous token features (ICL symbol detection)
    vocab: int = 0  # output classes

    @property
    def head_dim(self) -> int:
        return self.dim // self.num_heads

    @property
    def num_patches(self) -> int:
        return (self.image_size // self.patch_size) ** 2


# ---------------------------------------------------------------------------
# Linear layer under the three weight-execution modes
# ---------------------------------------------------------------------------


def _linear_def(key, d_in, d_out, scale=1.0):
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * (scale / jnp.sqrt(d_in))
    return {"w": w, "b": jnp.zeros((d_out,), jnp.float32)}


def linear(p, x: Array, sim: AIMCSim, key: Optional[Array]) -> Array:
    if "hw" in p:  # programmed PCM state (inference)
        from repro import aimc_device as AD

        hw = p["hw"]
        if isinstance(hw, AD.AIMCDeviceState):
            # device-state lifecycle: drift at the state's own clock,
            # stored (stale) GDC gain — see repro.aimc_device
            y = AD.analog_matmul(key, x, hw, sim.cfg)
        else:  # legacy dict state
            y = AM.aimc_matmul(key, x, hw, sim.cfg, t_seconds=sim.t_seconds,
                               gdc=sim.gdc)
        return y + p["b"]
    w = p["w"]
    if sim.wmode == "hwat":
        assert key is not None
        w = AM.hwat_weights(key, w, sim.cfg)
    return x @ w + p["b"]


def program_model(key: Array, params: Any, cfg: AM.AIMCConfig) -> Any:
    """Replace every {"w","b"} linear leaf by its programmed PCM state.

    Delegates to :func:`repro.aimc_device.program_tree` — each leaf becomes
    ``{"hw": AIMCDeviceState, "b": b}`` with the device clock at t = 0.
    Raises if the tree is already programmed (one-shot physical act)."""
    from repro import aimc_device as AD

    return AD.program_tree(key, params, cfg)


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _block_def(key, cfg: SpikingConfig):
    ks = jax.random.split(key, 6)
    d, f = cfg.dim, cfg.mlp_ratio * cfg.dim
    return {
        "ln1": {"scale": jnp.ones((d,)), "bias": jnp.zeros((d,))},
        "ln2": {"scale": jnp.ones((d,)), "bias": jnp.zeros((d,))},
        "wq": _linear_def(ks[0], d, d),
        "wk": _linear_def(ks[1], d, d),
        "wv": _linear_def(ks[2], d, d),
        "wo": _linear_def(ks[3], d, d),
        "w1": _linear_def(ks[4], d, f),
        "w2": _linear_def(ks[5], f, d),
    }


def _ln(p, x):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, -1, keepdims=True)
    var = jnp.var(xf, -1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + 1e-6)) * p["scale"] + p["bias"]


def _heads(x: Array, h: int) -> Array:
    *lead, n, d = x.shape
    return jnp.moveaxis(x.reshape(*lead, n, h, d // h), -2, -3)


def _unheads(x: Array) -> Array:
    *lead, h, n, hd = x.shape
    return jnp.moveaxis(x, -3, -2).reshape(*lead, n, h * hd)


def _ann_block(p, x, cfg: SpikingConfig, sim, keys, *, causal):
    h = _ln(p["ln1"], x)
    q = _heads(linear(p["wq"], h, sim, keys[0]), cfg.num_heads)
    k = _heads(linear(p["wk"], h, sim, keys[1]), cfg.num_heads)
    v = _heads(linear(p["wv"], h, sim, keys[2]), cfg.num_heads)
    a = SSA.ann_attention(q, k, v, causal=causal)
    x = x + linear(p["wo"], _unheads(a), sim, keys[3])
    h = _ln(p["ln2"], x)
    h = jax.nn.gelu(linear(p["w1"], h, sim, keys[4]))
    return x + linear(p["w2"], h, sim, keys[5])


def _default_backend():
    from repro.engine import ReferenceBackend  # deferred: engine imports us

    return ReferenceBackend()


def _spiking_block(p, s, cfg: SpikingConfig, sim, keys, rng, *, causal, backend):
    """s [T,B,N,D] binary. Table I SNN rows; no inter-layer normalisation.

    Every spiking primitive is taken from ``backend`` (reference float ops,
    bit-faithful integer simulation, or the bit-packed Pallas kernels), so
    one block definition serves every substrate."""

    def sp_lin(pp, z, kk):  # LIF(W z^t): per-timestep crossbar MVM + LIF
        return backend.spiking_linear(kk, pp, z, sim)

    q = _heads(sp_lin(p["wq"], s, keys[0]), cfg.num_heads)  # [T,B,H,N,hd]
    k = _heads(sp_lin(p["wk"], s, keys[1]), cfg.num_heads)
    v = _heads(sp_lin(p["wv"], s, keys[2]), cfg.num_heads)
    if cfg.mode == "ssa":
        a = backend.ssa_attention(rng, q, k, v, causal=causal)
    else:  # "lif" — Spikformer baseline
        a = SSA.lif_spiking_attention(
            q.astype(s.dtype), k.astype(s.dtype), v.astype(s.dtype), causal=causal
        )
    a = _unheads(a)
    s = s + sp_lin(p["wo"], a, keys[3])
    h = sp_lin(p["w1"], s, keys[4])
    return s + sp_lin(p["w2"], h, keys[5])


def _run_blocks(params, x_or_s, cfg: SpikingConfig, sim, rng, *, causal, backend=None):
    backend = backend or _default_backend()
    n_keys = 6
    for i, bp in enumerate(params["blocks"]):
        kk = jax.random.split(jax.random.fold_in(rng, i), n_keys + 1)
        if cfg.mode == "ann":
            x_or_s = _ann_block(bp, x_or_s, cfg, sim, kk[:n_keys], causal=causal)
        else:
            x_or_s = _spiking_block(
                bp, x_or_s, cfg, sim, kk[:n_keys], kk[n_keys], causal=causal,
                backend=backend,
            )
    return x_or_s


# ---------------------------------------------------------------------------
# Spiking ViT (Table III)
# ---------------------------------------------------------------------------


def init_vit(key: Array, cfg: SpikingConfig):
    ks = jax.random.split(key, cfg.depth + 3)
    pdim = cfg.patch_size * cfg.patch_size * cfg.in_channels
    return {
        "patch": _linear_def(ks[0], pdim, cfg.dim),
        "pos": jax.random.normal(ks[1], (cfg.num_patches, cfg.dim)) * 0.02,
        "blocks": [_block_def(ks[2 + i], cfg) for i in range(cfg.depth)],
        "head": _linear_def(ks[-1], cfg.dim, cfg.num_classes),
    }


def patchify(images: Array, patch: int) -> Array:
    b, hh, ww, c = images.shape
    ph, pw = hh // patch, ww // patch
    x = images.reshape(b, ph, patch, pw, patch, c)
    return jnp.moveaxis(x, 3, 2).reshape(b, ph * pw, patch * patch * c)


def vit_forward(params, images: Array, cfg: SpikingConfig, sim: AIMCSim, rng: Array,
                *, backend=None) -> Array:
    """images [B,H,W,C] -> logits [B, classes].

    ``backend`` selects the compute substrate for the spiking blocks (see
    ``repro.engine``); None means the differentiable reference backend.
    The patch embed and classifier head stay on the shared float path —
    they consume/produce real values, not spike trains — so spike-level
    backends remain bit-comparable."""
    k_embed, k_enc, k_blocks, k_head = jax.random.split(rng, 4)
    x = patchify(images, cfg.patch_size)
    x = linear(params["patch"], x, sim, k_embed) + params["pos"]
    if cfg.mode == "ann":
        h = _run_blocks(params, x, cfg, sim, k_blocks, causal=False)
        pooled = jnp.mean(h, axis=1)
    else:
        s = SP.rate_encode(k_enc, jax.nn.sigmoid(x), cfg.T)
        s = _run_blocks(params, s, cfg, sim, k_blocks, causal=False, backend=backend)
        pooled = jnp.mean(SP.rate_decode(s.astype(jnp.float32)), axis=1)
    return linear(params["head"], pooled, sim, k_head)


# ---------------------------------------------------------------------------
# Spiking GPT (Table IV — ICL wireless symbol detection)
# ---------------------------------------------------------------------------


def init_gpt(key: Array, cfg: SpikingConfig):
    ks = jax.random.split(key, cfg.depth + 3)
    return {
        "embed": _linear_def(ks[0], cfg.input_dim, cfg.dim),
        "pos": jax.random.normal(ks[1], (512, cfg.dim)) * 0.02,
        "blocks": [_block_def(ks[2 + i], cfg) for i in range(cfg.depth)],
        "head": _linear_def(ks[-1], cfg.dim, cfg.vocab),
    }


def gpt_forward(params, feats: Array, cfg: SpikingConfig, sim: AIMCSim, rng: Array,
                *, backend=None) -> Array:
    """feats [B,L,input_dim] -> logits [B,L,vocab] (causal).

    ``backend`` selects the compute substrate for the spiking blocks (see
    ``repro.engine``); None means the differentiable reference backend."""
    k_embed, k_enc, k_blocks, k_head = jax.random.split(rng, 4)
    L = feats.shape[1]
    x = linear(params["embed"], feats, sim, k_embed) + params["pos"][:L]
    if cfg.mode == "ann":
        h = _run_blocks(params, x, cfg, sim, k_blocks, causal=True)
    else:
        s = SP.rate_encode(k_enc, jax.nn.sigmoid(x), cfg.T)
        s = _run_blocks(params, s, cfg, sim, k_blocks, causal=True, backend=backend)
        h = SP.rate_decode(s.astype(jnp.float32))
    return linear(params["head"], h, sim, k_head)
