"""Analog in-memory computing (AIMC) simulation — PCM crossbars, Table II.

A from-scratch JAX equivalent of the AIHWKit pieces the paper uses:

* 5-bit effective weights from differential pairs of 4-bit-conductance PCM
  devices (Table II), per-column scaling;
* 128x128 crossbar tiles with the *row-block-wise mapping* of §IV-A-2:
  the input dim is cut into 128-row blocks, each block's column partial
  sums pass through a (shared, 5-bit) ADC, and the digitized partial sums
  are accumulated *digitally* in the LIF unit's carry-save adder — the
  non-binary pre-activation never goes to memory;
* programming noise, read noise, and conductance drift
  ``G(t) = G0 * (t/t0)^-nu`` with per-device drift exponents
  (Joshi et al., Nat. Comm. 2020);
* global drift compensation (GDC, §V-B): a calibration input read through
  the crossbar at time t rescales outputs by sum G(t0) / sum G(t);
* hardware-aware training (HWAT, §V-A): the forward pass applies
  quantisation + programming noise with a straight-through gradient, the
  backward pass stays ideal.

Everything operates on *float weights + simulation config*; the hardware
state (programmed conductance offsets, drift exponents) is sampled from a
key so experiments are reproducible.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AIMCConfig:
    # Table II
    conductance_bits: int = 4  # per PCM device
    weight_bits: int = 5  # differential pair => ~5-bit effective weight
    crossbar_rows: int = 128
    crossbar_cols: int = 128
    adc_bits: int = 5
    adc_sharing: int = 8
    # non-idealities (relative to per-column g_max)
    prog_noise_sigma: float = 0.03
    read_noise_sigma: float = 0.015
    # PCM drift (Joshi et al. 2020): nu ~ N(0.06, 0.02), t0 = 20 s
    drift_nu_mean: float = 0.06
    drift_nu_sigma: float = 0.02
    drift_t0_s: float = 20.0
    # ADC full-scale: multiple of g_max (expected partial-sum amplitude)
    adc_fullscale_rows: float = 8.0

    @property
    def levels(self) -> int:
        return 2 ** (self.weight_bits - 1) - 1  # +/-15 for 5-bit differential


# ---------------------------------------------------------------------------
# Weight quantisation (per-column scale)
# ---------------------------------------------------------------------------


def column_scale(w: Array, cfg: AIMCConfig) -> Array:
    """Per-output-column scale: g_max maps to max |w| in the column.

    Rank-generic over ``[..., d_in, d_out]`` — leading axes (e.g. a
    stacked layer-period axis) scale independently."""
    amax = jnp.max(jnp.abs(w), axis=-2)
    return jnp.where(amax > 0, amax / cfg.levels, 1.0)


def quantize_levels(w: Array, scale: Array, cfg: AIMCConfig) -> Array:
    """Signed integer conductance-pair levels in [-levels, levels]."""
    return jnp.clip(jnp.round(w / scale[..., None, :]), -cfg.levels, cfg.levels)


@jax.custom_vjp
def _ste(w: Array, w_eff: Array) -> Array:
    return w_eff


def _ste_fwd(w, w_eff):
    return w_eff, None


def _ste_bwd(_, g):
    return (g, None)  # gradient flows to the ideal float weight


_ste.defvjp(_ste_fwd, _ste_bwd)


# ---------------------------------------------------------------------------
# Hardware state: programming + drift
# ---------------------------------------------------------------------------


def program_weights(key: Array, w: Array, cfg: AIMCConfig) -> Dict[str, Array]:
    """Program float weights onto PCM: quantise + programming noise.

    Returns the persistent "hardware state" for inference:
      levels  — ideal integer levels
      eps     — programming error (in level units), frozen at program time
      nu      — per-device drift exponent
      scale   — per-column float scale
    """
    k1, k2 = jax.random.split(key)
    scale = column_scale(w, cfg)
    levels = quantize_levels(w, scale, cfg)
    eps = cfg.prog_noise_sigma * cfg.levels * jax.random.normal(k1, w.shape, jnp.float32)
    nu = cfg.drift_nu_mean + cfg.drift_nu_sigma * jax.random.normal(k2, w.shape, jnp.float32)
    nu = jnp.maximum(nu, 0.0)
    return {"levels": levels, "eps": eps, "nu": nu, "scale": scale}


def drift_factor(nu: Array, t_seconds: float, cfg: AIMCConfig) -> Array:
    t = max(float(t_seconds), cfg.drift_t0_s)
    return jnp.power(t / cfg.drift_t0_s, -nu)


def effective_weights(hw: Dict[str, Array], t_seconds: float, cfg: AIMCConfig) -> Array:
    """Conductance levels at inference time t (drifted, programming error)."""
    g = (hw["levels"] + hw["eps"]) * drift_factor(hw["nu"], t_seconds, cfg)
    return g  # in level units; multiply by scale to get weight units


def gdc_factor(hw: Dict[str, Array], t_seconds: float, cfg: AIMCConfig) -> Array:
    """Global drift compensation (§V-B): ratio of calibration column sums.

    Hardware reads |G| column sums with a known input; we reproduce that
    with the summed absolute conductance at t0 vs t (a per-tensor scalar —
    'global' compensation, not per-device)."""
    g0 = jnp.sum(jnp.abs(hw["levels"] + hw["eps"]))
    gt = jnp.sum(jnp.abs(effective_weights(hw, t_seconds, cfg)))
    return g0 / jnp.maximum(gt, 1e-9)


# ---------------------------------------------------------------------------
# Crossbar MVM with row-block-wise mapping + ADC
# ---------------------------------------------------------------------------


def _adc(x: Array, cfg: AIMCConfig) -> Array:
    """Shared 5-bit ADC on column partial sums (per 128-row block).

    Full scale is +/- adc_fullscale_rows (in g_max units); quantises to
    2^adc_bits uniform levels with straight-through gradient."""
    fs = cfg.adc_fullscale_rows * cfg.levels
    step = 2.0 * fs / (2 ** cfg.adc_bits - 1)
    q = jnp.clip(jnp.round(x / step), -(2 ** (cfg.adc_bits - 1)), 2 ** (cfg.adc_bits - 1) - 1)
    return _ste(x, q * step)


def aimc_matmul(
    key: Optional[Array],
    x: Array,
    hw: Dict[str, Array],
    cfg: AIMCConfig,
    *,
    t_seconds: float = 0.0,
    gdc: bool = True,
) -> Array:
    """x [..., d_in] @ W [d_in, d_out] through the simulated crossbars.

    Row-block-wise mapping: d_in is cut into 128-row blocks; each block's
    column currents get read noise + ADC quantisation, then the digitized
    partial sums accumulate exactly (CSA in the LIF unit)."""
    d_in, d_out = hw["levels"].shape
    g = effective_weights(hw, t_seconds, cfg)  # level units
    rows = cfg.crossbar_rows
    pad = (-d_in) % rows
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
        g = jnp.pad(g, [(0, pad), (0, 0)])
    nb = g.shape[0] // rows
    xb = x.reshape(*x.shape[:-1], nb, rows)
    gb = g.reshape(nb, rows, d_out)
    partial = jnp.einsum("...br,brd->...bd", xb.astype(jnp.float32), gb)
    if key is not None and cfg.read_noise_sigma > 0:
        noise = cfg.read_noise_sigma * cfg.levels * jax.random.normal(
            key, partial.shape, jnp.float32
        )
        partial = partial + noise
    partial = _adc(partial, cfg)
    out = jnp.sum(partial, axis=-2)  # exact digital accumulation (CSA)
    out = out * hw["scale"]
    if gdc and t_seconds > 0:
        out = out * gdc_factor(hw, t_seconds, cfg)
    return out


# ---------------------------------------------------------------------------
# HWAT: noisy forward with ideal backward (training-time simulation)
# ---------------------------------------------------------------------------


def hwat_weights(key: Array, w: Array, cfg: AIMCConfig) -> Array:
    """Quantise + inject programming noise, straight-through gradient."""
    scale = column_scale(w, cfg)
    levels = quantize_levels(w, scale, cfg)
    noise = cfg.prog_noise_sigma * cfg.levels * jax.random.normal(key, w.shape, jnp.float32)
    w_eff = (levels + noise) * scale
    return _ste(w, w_eff.astype(w.dtype))


def ideal_matmul(x: Array, w: Array) -> Array:
    return x @ w
