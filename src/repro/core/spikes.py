"""Spike encoding, spiking neurons, and surrogate gradients.

This module is the numerical foundation of the Xpikeformer reproduction:

* Bernoulli rate coding (paper Eq. (1)) — maps real activations in [0, 1]
  onto binary spike trains of length T.
* The LIF neuron (paper Eqs. (2)-(3)) — leaky integrate-and-fire with a
  hardware-faithful leak of beta = 0.5 (a right shift of the membrane
  register) and reset-to-zero on fire.
* The Bernoulli neuron layer (BNL, paper §IV-B) — the *stateless* neuron
  that replaces LIF inside stochastic spiking attention.  Its hardware form
  compares an **unnormalised integer** against a uniform random integer in
  (0, I_max]; we reproduce that comparison bit-faithfully rather than
  sampling from a float probability.
* Surrogate gradients — fast-sigmoid for the Heaviside spike function and
  a straight-through estimator for the Bernoulli samplers, so the whole
  spiking transformer trains with ordinary reverse-mode AD (the paper's
  SpikingJelly setup does the same).

Everything is pure-functional JAX: spike trains carry a leading time axis
``[T, ...]`` and the LIF state is threaded through ``jax.lax.scan``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array

# ---------------------------------------------------------------------------
# Surrogate gradients
# ---------------------------------------------------------------------------


@jax.custom_vjp
def heaviside_st(v: Array, alpha: float = 2.0) -> Array:
    """Heaviside step with fast-sigmoid surrogate gradient.

    Forward: 1.0 where v >= 0 else 0.0.
    Backward: grad * 1 / (1 + alpha*|v|)^2  (SpikingJelly's ATan-like fast
    sigmoid; alpha controls surrogate sharpness).
    """
    del alpha
    return (v >= 0.0).astype(v.dtype)


def _heaviside_fwd(v, alpha):
    return heaviside_st(v, alpha), (v, alpha)


def _heaviside_bwd(res, g):
    v, alpha = res
    surr = 1.0 / (1.0 + alpha * jnp.abs(v)) ** 2
    return (g * surr, None)


heaviside_st.defvjp(_heaviside_fwd, _heaviside_bwd)


@jax.custom_vjp
def bernoulli_st(p: Array, u: Array) -> Array:
    """Straight-through Bernoulli: forward samples (p > u), backward is id.

    ``u`` is uniform in [0, 1) and treated as a constant.  The straight-
    through estimator passes the gradient to the probability, which matches
    the training recipe for Bernoulli neurons (the expectation of the sample
    is exactly p, so d E[s]/d p = 1).
    """
    return (u < p).astype(p.dtype)


def _bern_fwd(p, u):
    return bernoulli_st(p, u), None


def _bern_bwd(_, g):
    return (g, None)


bernoulli_st.defvjp(_bern_fwd, _bern_bwd)


# ---------------------------------------------------------------------------
# Bernoulli rate coding (Eq. 1)
# ---------------------------------------------------------------------------


def rate_encode(key: Array, x: Array, T: int, *, straight_through: bool = True) -> Array:
    """Encode real values ``x`` in [0, 1] into spike trains ``s[t]``.

    Returns an array of shape ``(T,) + x.shape`` with values in {0, 1}
    (same dtype as x so gradients flow via the ST estimator).
    """
    x = jnp.clip(x, 0.0, 1.0)
    u = jax.random.uniform(key, (T,) + x.shape, dtype=x.dtype)
    if straight_through:
        return bernoulli_st(jnp.broadcast_to(x, u.shape), u)
    return (u < x).astype(x.dtype)


def rate_decode(spikes: Array) -> Array:
    """Decode a spike train by its firing rate (mean over leading T axis)."""
    return jnp.mean(spikes, axis=0)


# ---------------------------------------------------------------------------
# LIF neuron (Eqs. 2-3)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LIFParams:
    """LIF neuron hyper-parameters.

    beta = 0.5 corresponds to the hardware shift-register leak (a one-bit
    right shift of the membrane potential per timestep, §IV-A-2).
    """

    beta: float = 0.5
    v_thresh: float = 1.0
    surrogate_alpha: float = 2.0


def lif_step(v: Array, i_t: Array, p: LIFParams) -> Tuple[Array, Array]:
    """One LIF update. Returns (new membrane, output spikes)."""
    v = p.beta * v + i_t
    s = heaviside_st(v - p.v_thresh, p.surrogate_alpha)
    v = v * (1.0 - s)  # reset-to-zero on fire
    return v, s


def lif(currents: Array, p: LIFParams = LIFParams(), v0: Optional[Array] = None) -> Array:
    """Run an LIF neuron over a ``[T, ...]`` current sequence via lax.scan.

    Returns the ``[T, ...]`` binary spike outputs.
    """
    if v0 is None:
        v0 = jnp.zeros(currents.shape[1:], currents.dtype)

    def step(v, i_t):
        v, s = lif_step(v, i_t, p)
        return v, s

    _, spikes = lax.scan(step, v0, currents)
    return spikes


# ---------------------------------------------------------------------------
# Bernoulli neuron layer (BNL) — hardware-faithful integer comparison
# ---------------------------------------------------------------------------


def split_prn_bytes(word32: Array) -> Array:
    """Tap all four bytes of a 32-bit PRN word (paper §IV-B-3, [48][49]).

    The SSA engine maximises LFSR utilisation by using every byte of each
    32-bit LFSR word as an independent 8-bit PRN.  Given uint32 ``word32``
    of shape S this returns a uint8 array of shape S + (4,).
    """
    w = word32.astype(jnp.uint32)
    return jnp.stack(
        [
            (w & 0xFF).astype(jnp.uint8),
            ((w >> 8) & 0xFF).astype(jnp.uint8),
            ((w >> 16) & 0xFF).astype(jnp.uint8),
            ((w >> 24) & 0xFF).astype(jnp.uint8),
        ],
        axis=-1,
    )


def bnl_integer(key: Array, counts: Array, i_max: int) -> Array:
    """Hardware Bernoulli encoder: spike iff ``count > r`` with r ~ U{0..i_max-1}.

    ``counts`` are unnormalised integer accumulator values in [0, i_max]
    (e.g. the popcount of d_K AND results).  The hardware comparator fires
    when the input integer exceeds a uniform random integer drawn from
    (0, I_max]; with I_max a power of two the PRN is simply the low
    log2(I_max) bits of an LFSR word.  P(spike) = count / i_max exactly.
    Returns float spikes in {0,1} with gradient d s / d count = 1/i_max
    (straight-through through the comparison).
    """
    r = jax.random.randint(key, counts.shape, 0, i_max, dtype=jnp.int32)
    p = counts.astype(jnp.float32) / float(i_max)
    u = (r.astype(jnp.float32) + 0.0) / float(i_max)
    # (u < p) == (r < count) == (count > r): identical sample path to the
    # hardware comparator, while bernoulli_st provides the ST gradient.
    return bernoulli_st(p, u)


def bnl(key: Array, x: Array, scale: float) -> Array:
    """Float-input Bernoulli neuron layer: normalise by ``scale`` then sample."""
    p = jnp.clip(x / scale, 0.0, 1.0)
    u = jax.random.uniform(key, x.shape, dtype=p.dtype)
    return bernoulli_st(p, u)


# ---------------------------------------------------------------------------
# Spiking linear layer (AIMC-executed in hardware)
# ---------------------------------------------------------------------------


def spiking_linear(
    spikes: Array,
    w: Array,
    b: Optional[Array],
    p: LIFParams = LIFParams(),
) -> Array:
    """``LIF(W s^t + b)`` over a ``[T, ..., d_in]`` spike train.

    This is the reference (ideal, noise-free) semantics of one AIMC
    spiking-neuron tile: the crossbar computes the MVM per timestep and the
    LIF unit integrates it, with membrane state carried across timesteps —
    never materialising the T non-binary pre-activations in memory (the scan
    carry is the membrane register).
    """

    def step(v, s_t):
        i_t = s_t @ w if b is None else s_t @ w + b
        v, out = lif_step(v, i_t, p)
        return v, out

    v0 = jnp.zeros(spikes.shape[1:-1] + (w.shape[-1],), spikes.dtype)
    _, out = lax.scan(step, v0, spikes)
    return out
