"""Mesh-sharded distributed execution for the Xpikeformer engine.

The subsystem every multi-device scaling path builds on (see README
"Distributed serving"):

    Executor            — params / AIMC device state / DecodeState placed
        |                 on a (data, model) mesh; mesh-wide forward;
        |                 data-parallel continuous-batching scheduler
    ShardedBackend      — tensor-parallel spiking primitives via shard_map:
        |                 column/row-parallel crossbar linears (integer
        |                 spike-count psum), head-parallel SSA decode with
        |                 f(seed, pos, head) PRN streams
    TPPlan / TP_PARTS   — which leaves the `model` axis shards (shared by
                          placement and execution, so they always agree)

Sharded execution on the `integer` / `pallas` backends is bit-exact vs the
single-device oracle — through full forwards and whole scheduler runs with
mid-flight admission, eviction, PCM drift and GDC recalibration.
"""

from repro.distributed.backend import TP_PARTS, ShardedBackend, TPPlan
from repro.distributed.executor import Executor, param_pspecs_for_tree

__all__ = [
    "Executor",
    "ShardedBackend",
    "TPPlan",
    "TP_PARTS",
    "param_pspecs_for_tree",
]
