"""ShardedBackend: tensor-parallel spiking primitives under ``shard_map``.

The hardware analogue (paper §IV): Xpikeformer's throughput comes from
*spatial* parallelism — per-head SSA engine cores running concurrently and
AIMC crossbars tiled over output columns.  This module maps that onto the
``model`` axis of a ``(data, model)`` jax mesh:

* **column-parallel spiking linear** (``part="col"`` — Q/K/V projections,
  MLP in): crossbar *output columns* are sharded; the LIF membrane is
  per-column, so each shard quantises, accumulates and fires its own
  columns with zero communication.
* **row-parallel spiking linear** (``part="row"`` — attention out, MLP
  out): crossbar *input rows* are sharded; each shard accumulates its
  partial spike counts (shard-local programmed-AIMC matmul —
  ``kernels.ops.aimc_matmul_counts`` / ``kernels.ref.aimc_counts_ref``),
  the counts **psum** across ``model``, and scale/bias/LIF fire once on the
  reduced currents.  Counts are integer-valued f32, so the cross-shard
  reduction is *exact* and sharded == single-device bit-for-bit.
* **head-parallel SSA decode**: each shard runs the packed popcount tile
  over its own heads, drawing comparator integers from the per-``(seed,
  pos, head)`` streams (``draw_slot_decode_prns`` with the shard's global
  head offset ``lax.axis_index("model") * h_local``) — exactly the
  integers the single-device oracle draws for those heads.

Everything else (rate coding, embed/unembed, residual adds, cache
scatters) stays outside ``shard_map`` and is partitioned by GSPMD from the
parameter/state placements (``repro.distributed.executor``); batch/slot
dimensions ride the ``data`` axis.

Bit-exactness holds because every sharded reduction is over integer-valued
operands and every PRN stream is keyed by *logical* (slot, position, head)
coordinates, never by mesh coordinates.  Tensor parallelism engages for
the bit-exact digital substrates (``integer`` / ``pallas``); the
``reference`` backend's analog simulation (row-block ADC clipping, read
noise) is not decomposable across row shards, so it passes through and is
partitioned by GSPMD only.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.aimc_device import AIMCDeviceState
from repro.core import aimc as AM
from repro.kernels import ops as KOPS
from repro.kernels import ref as KREF
from repro.engine import _DecodeShims
from repro.kernels.plan import KVView
# single source of the jax.shard_map / jax.experimental shim
from repro.models.moe import _shard_map

Array = jax.Array

# which spiking-linear leaves are column- vs row-parallel (Megatron-style:
# the paper's per-head SSA cores and column-tiled crossbars)
TP_PARTS = {"wq": "col", "wk": "col", "wv": "col", "wi": "col", "wo": "row"}


@dataclasses.dataclass(frozen=True)
class TPPlan:
    """What the ``model`` axis can shard for a given config.

    Derived once from (cfg, mesh) and shared by parameter placement and
    the :class:`ShardedBackend`, so the two always agree on which leaves
    are sharded."""

    tp: int = 1  # model-axis size
    heads: bool = False  # h % tp == 0 and kv % tp == 0: SSA cores shardable

    @classmethod
    def from_config(cls, cfg, tp: int) -> "TPPlan":
        if tp <= 1:
            return cls()
        nh = getattr(cfg, "num_heads", 0) or 0
        kv = getattr(cfg, "num_kv_heads", 0) or 0
        return cls(tp=tp, heads=(nh > 0 and nh % tp == 0 and kv % tp == 0))

    def col_ok(self, d_out: int) -> bool:
        return self.tp > 1 and d_out % self.tp == 0

    def row_ok(self, d_in: int) -> bool:
        return self.tp > 1 and d_in % self.tp == 0


def _mat_dims(p: Any) -> Tuple[int, int]:
    """(d_in, d_out) of a normalised linear-param leaf."""
    if "hw" in p:
        hw = p["hw"]
        shape = hw.shape if isinstance(hw, AIMCDeviceState) else hw["levels"].shape
    else:
        shape = p["w"].shape
    return int(shape[-2]), int(shape[-1])


def _state_specs(col: bool, axis: str, lead: int = 0) -> AIMCDeviceState:
    """Per-field PartitionSpecs for a device state's crossbar matrix view.

    ``lead`` counts leading stack axes (0 for scan-sliced 2-D states inside
    shard_map, 1 for period-stacked leaves at placement time).  The single
    source of the AIMCDeviceState field -> spec mapping: parameter
    placement (``executor.param_pspecs_for_tree``) and the shard_map
    in_specs both derive from here, so they cannot disagree."""
    nl = (None,) * lead
    mat = P(*nl, None, axis) if col else P(*nl, axis, None)
    vec = P(*nl, axis) if col else P()
    sc = P()
    return AIMCDeviceState(levels=mat, eps=mat, nu=mat, scale=vec,
                           t_seconds=sc, gdc_gain=sc, levels_t=mat, img_inv=sc)


class ShardedBackend(_DecodeShims):
    """Tensor-parallel wrapper over a bit-exact engine backend.

    Implements the :class:`repro.engine.Backend` protocol; the mesh-aware
    entry points (``part=`` on ``spiking_linear``, ``spec.h0`` on
    ``decode_attention``) select the shard_map decomposition.  Two
    instances serve a mesh scheduler: the *decode* instance additionally
    shards the slot/batch dimension over ``data`` (``batch_axis="data"``);
    the *prefill* instance replicates it (prefill is batch-1).
    """

    differentiable = False

    def __init__(self, inner, mesh, cfg, *, batch_axis: Optional[str] = "data",
                 model_axis: str = "model"):
        from repro.parallel import sharding as SH

        sizes = SH.axis_sizes(mesh)
        self.inner = inner
        self.mesh = mesh
        self.cfg = cfg
        self.model_axis = model_axis if sizes.get(model_axis, 1) > 1 else None
        self.batch_axis = batch_axis if sizes.get(batch_axis or "", 1) > 1 else None
        self.data = sizes.get(batch_axis, 1) if self.batch_axis else 1
        # the analog reference path is not row-decomposable (per-row-block
        # ADC + read noise); TP engages for the digital substrates only
        if inner.name not in ("integer", "pallas"):
            self.model_axis = None
        self.plan = TPPlan.from_config(
            cfg, sizes.get(model_axis, 1) if self.model_axis else 1)
        self.name = f"sharded[{inner.name}]"
        self.bit_exact = inner.bit_exact
        # only offer the fused megakernel when the inner backend has it —
        # build_decode_plan keys "auto" off this being callable
        if not callable(getattr(inner, "decode_layer_fused", None)):
            self.decode_layer_fused = None

    # -- spec helpers ---------------------------------------------------

    def _batch(self, dim: int) -> Optional[str]:
        if self.batch_axis and dim % self.data == 0:
            return self.batch_axis
        return None

    def _x_spec(self, ndim: int, batch_dim: int, feat: Optional[str]) -> P:
        spec: list = [None] * ndim
        if ndim >= 3:  # [T, batch, ..., features]
            spec[1] = self._batch(batch_dim)
        if feat is not None:
            spec[-1] = feat
        return P(*spec)

    # -- passthrough primitives ----------------------------------------

    def ssa_attention(self, key, q, k, v, *, causal=False):
        return self.inner.ssa_attention(key, q, k, v, causal=causal)

    def lif(self, currents, *, beta=0.5, v_thresh=1.0):
        return self.inner.lif(currents, beta=beta, v_thresh=v_thresh)

    # -- head-parallel SSA decode --------------------------------------

    def decode_attention(self, view, q, spec, *, slot_keys):
        """Head-parallel SSA decode over a :class:`~repro.kernels.plan.
        KVView`: each shard runs the inner backend's decode over its own
        heads, drawing comparator integers from the per-``(seed, pos,
        global head)`` streams (``spec.h0`` plus the shard's
        ``lax.axis_index`` offset) — exactly the integers the single-device
        oracle draws for those heads.  A paged view's page axis is never
        sharded (pages are global); only the KV-head axis rides ``model``;
        slots ride ``data``."""
        h = q.shape[2]
        if self.model_axis is None or not self.plan.heads or h % self.plan.tp:
            return self.inner.decode_attention(view, q, spec,
                                               slot_keys=slot_keys)
        axis = self.model_axis
        h_local = h // self.plan.tp
        b = self._batch(q.shape[1])
        q_spec = P(None, b, axis, None, None)

        def off():
            return jnp.asarray(spec.h0) + lax.axis_index(axis) * h_local

        if view.paged:
            pool_spec = P(None, None, axis, None, None)  # [P,T,KV,page_len,d]

            def body(sk, qb, kb, vb, tb):
                sub = dataclasses.replace(spec, h0=off())
                return self.inner.decode_attention(
                    KVView.from_pool(kb, vb, tb), qb, sub, slot_keys=sk)

            return _shard_map(
                body, mesh=self.mesh,
                in_specs=(P(b), q_spec, pool_spec, pool_spec, P(b, None)),
                out_specs=q_spec,
            )(slot_keys, q, view.k, view.v, view.page_table)

        def body(sk, qb, kb, vb):  # dense k/v [T,B,H,L,d]: head axis shards
            sub = dataclasses.replace(spec, h0=off())
            return self.inner.decode_attention(
                KVView.dense(kb, vb), qb, sub, slot_keys=sk)

        return _shard_map(
            body, mesh=self.mesh,
            in_specs=(P(b), q_spec, q_spec, q_spec),
            out_specs=q_spec,
        )(slot_keys, q, view.k, view.v)

    # -- head-parallel fused decode layer ------------------------------

    def decode_layer_fused(self, slot_keys, s, view, pos, wq, wk, wv,
                           wo=None, wi=None, wo2=None, *, hd, h0=0,
                           write_pids=None, with_tail=True, with_mlp=True,
                           sim=None):
        """Head-parallel shard of the fused decode megakernel.

        The attention stage (projections + packed SSA) runs inside
        ``shard_map`` with ``with_tail=False``: each shard launches the
        inner backend's megakernel over its own ``h_local`` query heads
        (column-sliced ``wq``/``wk``/``wv``; per-column quantisation is
        shard-local-exact) at global head offset ``h0 + axis_index *
        h_local``, producing its slice of the attention spikes and its own
        KV heads' new trains.  The FFN tail then rides the existing
        row/col-parallel spiking linears *outside* the shard_map — the
        row path psums integer spike counts and fires LIF once, which is
        bit-identical to the fused kernel's tail (same committed
        roundings), so sharded-fused == single-device-fused exactly."""
        from repro import engine as E

        kvh = view.k.shape[2] if view.paged else view.k.shape[3]
        tp_ok = (self.model_axis is not None and self.plan.heads
                 and kvh % self.plan.tp == 0)
        if not tp_ok:
            return self.inner.decode_layer_fused(
                slot_keys, s, view, pos, wq, wk, wv, wo, wi, wo2, hd=hd,
                h0=h0, write_pids=write_pids, with_tail=with_tail,
                with_mlp=with_mlp, sim=sim)
        axis = self.model_axis
        # normalise the projection leaves so operands and specs agree on
        # the pytree shape (shard_map in_specs must mirror the operands)
        pq, pk, pv = (E._linear_parts(w) for w in (wq, wk, wv))
        h = _mat_dims(pq)[1] // hd
        h_local = h // self.plan.tp
        b = self._batch(s.shape[1])
        if view.paged:
            kv_spec = P(None, None, axis, None, None)  # [P,T,KV,page_len,hd]
            view_spec = KVView.from_pool(kv_spec, kv_spec, P(b, None))
        else:
            kv_spec = P(b, None, None, axis, None)  # [B,T,L,KV,hd]
            view_spec = KVView.dense(kv_spec, kv_spec)
        wp_specs = (P(b),) if write_pids is not None else ()
        wp_args = (write_pids,) if write_pids is not None else ()

        def body(sk, sb, vw, ps, wq_, wk_, wv_, *rest):
            off = jnp.asarray(h0) + lax.axis_index(axis) * h_local
            return self.inner.decode_layer_fused(
                sk, sb, vw, ps, wq_, wk_, wv_, hd=hd, h0=off,
                write_pids=rest[0] if rest else None,
                with_tail=False, sim=sim)

        a, k_new, v_new = _shard_map(
            body, mesh=self.mesh,
            in_specs=(P(b), P(None, b, None), view_spec, P(b),
                      self._p_specs(pq, col=True), self._p_specs(pk, col=True),
                      self._p_specs(pv, col=True)) + wp_specs,
            out_specs=(P(None, b, axis), P(None, b, axis, None),
                       P(None, b, axis, None)),
        )(slot_keys, s, view, pos, pq, pk, pv, *wp_args)
        if not with_tail:
            return a, k_new, v_new
        s1 = s + self.spiking_linear(None, wo, a, sim, part="row")
        if with_mlp:
            h1 = self.spiking_linear(None, wi, s1, sim, part="col")
            s1 = s1 + self.spiking_linear(None, wo2, h1, sim, part="row")
        return s1, k_new, v_new

    # -- tensor-parallel spiking linear --------------------------------

    def spiking_linear(self, key, p, spikes, sim=None, *, part=None):
        from repro import engine as E

        pn = E._linear_parts(p)
        d_in, d_out = _mat_dims(pn)
        active = (self.model_axis is not None and (
            (part == "col" and self.plan.col_ok(d_out))
            or (part == "row" and self.plan.row_ok(d_in))))
        if not active:
            return self.inner.spiking_linear(key, p, spikes, sim, part=part)
        if part == "col":
            return self._col_linear(key, pn, spikes, sim)
        return self._row_linear(key, pn, spikes, sim)

    def _p_specs(self, p, col: bool):
        axis = self.model_axis
        mat = P(None, axis) if col else P(axis, None)
        vec = P(axis) if col else P()
        specs = {}
        if "w" in p:
            specs["w"] = mat
        if "hw" in p:
            hw = p["hw"]
            specs["hw"] = (_state_specs(col, axis)
                           if isinstance(hw, AIMCDeviceState)
                           else {"levels": mat, "scale": vec})
        specs["b"] = vec if p.get("b") is not None else None
        return specs

    def _col_linear(self, key, p, spikes, sim):
        """Output columns sharded: each shard fires its own LIF columns."""
        x_in = self._x_spec(spikes.ndim, spikes.shape[1], None)
        x_out = self._x_spec(spikes.ndim, spikes.shape[1], self.model_axis)
        inner, p_specs = self.inner, self._p_specs(p, col=True)

        if key is None:
            def body(pl_, sp_):
                return inner.spiking_linear(None, pl_, sp_, sim)

            return _shard_map(body, mesh=self.mesh,
                              in_specs=(p_specs, x_in),
                              out_specs=x_out)(p, spikes)

        def body(k_, pl_, sp_):
            return inner.spiking_linear(k_, pl_, sp_, sim)

        return _shard_map(body, mesh=self.mesh,
                          in_specs=(P(), p_specs, x_in),
                          out_specs=x_out)(key, p, spikes)

    def _row_linear(self, key, p, spikes, sim):
        """Input rows sharded: psum integer spike counts, fire LIF once.

        The cross-shard reduction runs on integer-valued f32 partial counts
        (exact), then scale/bias/LIF replay the fused kernel's op sequence
        on the reduced currents — bit-identical to the single-device fused
        ``aimc_spiking_linear``."""
        from repro import engine as E

        axis = self.model_axis
        acfg = (sim or E._IDEAL_SIM).cfg
        inner = self.inner
        use_kernel = getattr(inner, "interpret", None) is not None  # pallas
        x_in = self._x_spec(spikes.ndim, spikes.shape[1], axis)
        x_out = self._x_spec(spikes.ndim, spikes.shape[1], None)

        def body(pl_, sp_):
            flat, unflatten = E._flatten_time(sp_)
            flat = flat.astype(jnp.float32)
            if "hw" in pl_:
                hw = pl_["hw"]
                if isinstance(hw, AIMCDeviceState):
                    levels, scale = hw.levels_t, hw.eff_scale
                else:
                    levels, scale = hw["levels"].astype(jnp.int8), hw["scale"]
            else:
                # per-column scale needs the *global* column max: pmax is
                # order-invariant, so shard-local quantisation with the
                # pmax'd scale reproduces the single-device levels exactly
                amax = lax.pmax(jnp.max(jnp.abs(pl_["w"]), axis=-2), axis)
                scale = jnp.where(amax > 0, amax / acfg.levels, 1.0
                                  ).astype(jnp.float32)
                levels = AM.quantize_levels(pl_["w"], scale, acfg
                                            ).astype(jnp.int8)
            if use_kernel:
                counts = KOPS.aimc_matmul_counts(flat, levels,
                                                 interpret=inner.interpret)
            else:
                counts = KREF.aimc_counts_ref(flat, levels)
            counts = lax.psum(counts, axis)  # exact: integer-valued f32
            pre = counts * scale[None, None, :]
            if pl_.get("b") is not None:
                pre = pre + pl_["b"].astype(jnp.float32)[None, None, :]
            return unflatten(inner.lif(pre))

        return _shard_map(body, mesh=self.mesh,
                          in_specs=(self._p_specs(p, col=False), x_in),
                          out_specs=x_out)(p, spikes)
