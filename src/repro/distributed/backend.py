"""ShardedBackend: tensor-parallel spiking primitives under ``shard_map``.

The hardware analogue (paper §IV): Xpikeformer's throughput comes from
*spatial* parallelism — per-head SSA engine cores running concurrently and
AIMC crossbars tiled over output columns.  This module maps that onto the
``model`` axis of a ``(data, model)`` jax mesh:

* **column-parallel spiking linear** (``part="col"`` — Q/K/V projections,
  MLP in): crossbar *output columns* are sharded; the LIF membrane is
  per-column, so each shard quantises, accumulates and fires its own
  columns with zero communication.
* **row-parallel spiking linear** (``part="row"`` — attention out, MLP
  out): crossbar *input rows* are sharded; each shard accumulates its
  partial spike counts (shard-local programmed-AIMC matmul —
  ``kernels.ops.aimc_matmul_counts`` / ``kernels.ref.aimc_counts_ref``),
  the counts **psum** across ``model``, and scale/bias/LIF fire once on the
  reduced currents.  Counts are integer-valued f32, so the cross-shard
  reduction is *exact* and sharded == single-device bit-for-bit.
* **head-parallel SSA decode**: each shard runs the packed popcount tile
  over its own heads, drawing comparator integers from the per-``(seed,
  pos, head)`` streams (``draw_slot_decode_prns`` with the shard's global
  head offset ``lax.axis_index("model") * h_local``) — exactly the
  integers the single-device oracle draws for those heads.

Everything else (rate coding, embed/unembed, residual adds, cache
scatters) stays outside ``shard_map`` and is partitioned by GSPMD from the
parameter/state placements (``repro.distributed.executor``); batch/slot
dimensions ride the ``data`` axis.

Bit-exactness holds because every sharded reduction is over integer-valued
operands and every PRN stream is keyed by *logical* (slot, position, head)
coordinates, never by mesh coordinates.  Tensor parallelism engages for
the bit-exact digital substrates (``integer`` / ``pallas``); the
``reference`` backend's analog simulation (row-block ADC clipping, read
noise) is not decomposable across row shards, so it passes through and is
partitioned by GSPMD only.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.aimc_device import AIMCDeviceState
from repro.core import aimc as AM
from repro.kernels import ops as KOPS
from repro.kernels import ref as KREF
# single source of the jax.shard_map / jax.experimental shim
from repro.models.moe import _shard_map

Array = jax.Array

# which spiking-linear leaves are column- vs row-parallel (Megatron-style:
# the paper's per-head SSA cores and column-tiled crossbars)
TP_PARTS = {"wq": "col", "wk": "col", "wv": "col", "wi": "col", "wo": "row"}


@dataclasses.dataclass(frozen=True)
class TPPlan:
    """What the ``model`` axis can shard for a given config.

    Derived once from (cfg, mesh) and shared by parameter placement and
    the :class:`ShardedBackend`, so the two always agree on which leaves
    are sharded."""

    tp: int = 1  # model-axis size
    heads: bool = False  # h % tp == 0 and kv % tp == 0: SSA cores shardable

    @classmethod
    def from_config(cls, cfg, tp: int) -> "TPPlan":
        if tp <= 1:
            return cls()
        nh = getattr(cfg, "num_heads", 0) or 0
        kv = getattr(cfg, "num_kv_heads", 0) or 0
        return cls(tp=tp, heads=(nh > 0 and nh % tp == 0 and kv % tp == 0))

    def col_ok(self, d_out: int) -> bool:
        return self.tp > 1 and d_out % self.tp == 0

    def row_ok(self, d_in: int) -> bool:
        return self.tp > 1 and d_in % self.tp == 0


def _mat_dims(p: Any) -> Tuple[int, int]:
    """(d_in, d_out) of a normalised linear-param leaf."""
    if "hw" in p:
        hw = p["hw"]
        shape = hw.shape if isinstance(hw, AIMCDeviceState) else hw["levels"].shape
    else:
        shape = p["w"].shape
    return int(shape[-2]), int(shape[-1])


def _state_specs(col: bool, axis: str, lead: int = 0) -> AIMCDeviceState:
    """Per-field PartitionSpecs for a device state's crossbar matrix view.

    ``lead`` counts leading stack axes (0 for scan-sliced 2-D states inside
    shard_map, 1 for period-stacked leaves at placement time).  The single
    source of the AIMCDeviceState field -> spec mapping: parameter
    placement (``executor.param_pspecs_for_tree``) and the shard_map
    in_specs both derive from here, so they cannot disagree."""
    nl = (None,) * lead
    mat = P(*nl, None, axis) if col else P(*nl, axis, None)
    vec = P(*nl, axis) if col else P()
    sc = P()
    return AIMCDeviceState(levels=mat, eps=mat, nu=mat, scale=vec,
                           t_seconds=sc, gdc_gain=sc, levels_t=mat, img_inv=sc)


class ShardedBackend:
    """Tensor-parallel wrapper over a bit-exact engine backend.

    Implements the :class:`repro.engine.Backend` protocol; the mesh-aware
    entry points (``part=`` on ``spiking_linear``, ``h0=`` on
    ``ssa_attention_decode``) select the shard_map decomposition.  Two
    instances serve a mesh scheduler: the *decode* instance additionally
    shards the slot/batch dimension over ``data`` (``batch_axis="data"``);
    the *prefill* instance replicates it (prefill is batch-1).
    """

    differentiable = False

    def __init__(self, inner, mesh, cfg, *, batch_axis: Optional[str] = "data",
                 model_axis: str = "model"):
        from repro.parallel import sharding as SH

        sizes = SH.axis_sizes(mesh)
        self.inner = inner
        self.mesh = mesh
        self.cfg = cfg
        self.model_axis = model_axis if sizes.get(model_axis, 1) > 1 else None
        self.batch_axis = batch_axis if sizes.get(batch_axis or "", 1) > 1 else None
        self.data = sizes.get(batch_axis, 1) if self.batch_axis else 1
        # the analog reference path is not row-decomposable (per-row-block
        # ADC + read noise); TP engages for the digital substrates only
        if inner.name not in ("integer", "pallas"):
            self.model_axis = None
        self.plan = TPPlan.from_config(
            cfg, sizes.get(model_axis, 1) if self.model_axis else 1)
        self.name = f"sharded[{inner.name}]"
        self.bit_exact = inner.bit_exact

    # -- spec helpers ---------------------------------------------------

    def _batch(self, dim: int) -> Optional[str]:
        if self.batch_axis and dim % self.data == 0:
            return self.batch_axis
        return None

    def _x_spec(self, ndim: int, batch_dim: int, feat: Optional[str]) -> P:
        spec: list = [None] * ndim
        if ndim >= 3:  # [T, batch, ..., features]
            spec[1] = self._batch(batch_dim)
        if feat is not None:
            spec[-1] = feat
        return P(*spec)

    # -- passthrough primitives ----------------------------------------

    def ssa_attention(self, key, q, k, v, *, causal=False):
        return self.inner.ssa_attention(key, q, k, v, causal=causal)

    def lif(self, currents, *, beta=0.5, v_thresh=1.0):
        return self.inner.lif(currents, beta=beta, v_thresh=v_thresh)

    # -- head-parallel SSA decode --------------------------------------

    def ssa_attention_decode(self, slot_keys, q, k, v, *, i_max,
                             h0: Union[int, Array] = 0):
        h = q.shape[2]
        if self.model_axis is None or not self.plan.heads or h % self.plan.tp:
            return self.inner.ssa_attention_decode(slot_keys, q, k, v,
                                                   i_max=i_max, h0=h0)
        axis = self.model_axis
        h_local = h // self.plan.tp
        b = self._batch(q.shape[1])
        kv_spec = P(None, b, axis, None, None)

        def body(sk, qb, kb, vb):
            off = jnp.asarray(h0) + lax.axis_index(axis) * h_local
            return self.inner.ssa_attention_decode(sk, qb, kb, vb,
                                                   i_max=i_max, h0=off)

        return _shard_map(
            body, mesh=self.mesh,
            in_specs=(P(b), kv_spec, kv_spec, kv_spec),
            out_specs=kv_spec,
        )(slot_keys, q, k, v)

    def ssa_attention_decode_paged(self, slot_keys, q, kpool, vpool,
                                   page_table, *, i_max,
                                   h0: Union[int, Array] = 0):
        """Head-parallel paged SSA decode: each shard gathers its own KV
        heads' pages through the (replicated) page table and draws the
        single-device oracle's comparator integers for its global heads —
        the paged mirror of :meth:`ssa_attention_decode`.  The page axis of
        the pool is never sharded (pages are global), only the KV-head axis
        rides ``model``; slots ride ``data``."""
        h = q.shape[2]
        if self.model_axis is None or not self.plan.heads or h % self.plan.tp:
            return self.inner.ssa_attention_decode_paged(
                slot_keys, q, kpool, vpool, page_table, i_max=i_max, h0=h0)
        axis = self.model_axis
        h_local = h // self.plan.tp
        b = self._batch(q.shape[1])
        q_spec = P(None, b, axis, None, None)
        pool_spec = P(None, None, axis, None, None)  # [P, T, KV, page_len, d]

        def body(sk, qb, kb, vb, tb):
            off = jnp.asarray(h0) + lax.axis_index(axis) * h_local
            return self.inner.ssa_attention_decode_paged(
                sk, qb, kb, vb, tb, i_max=i_max, h0=off)

        return _shard_map(
            body, mesh=self.mesh,
            in_specs=(P(b), q_spec, pool_spec, pool_spec, P(b, None)),
            out_specs=q_spec,
        )(slot_keys, q, kpool, vpool, page_table)

    # -- tensor-parallel spiking linear --------------------------------

    def spiking_linear(self, key, p, spikes, sim=None, *, part=None):
        from repro import engine as E

        pn = E._linear_parts(p)
        d_in, d_out = _mat_dims(pn)
        active = (self.model_axis is not None and (
            (part == "col" and self.plan.col_ok(d_out))
            or (part == "row" and self.plan.row_ok(d_in))))
        if not active:
            return self.inner.spiking_linear(key, p, spikes, sim, part=part)
        if part == "col":
            return self._col_linear(key, pn, spikes, sim)
        return self._row_linear(key, pn, spikes, sim)

    def _p_specs(self, p, col: bool):
        axis = self.model_axis
        mat = P(None, axis) if col else P(axis, None)
        vec = P(axis) if col else P()
        specs = {}
        if "w" in p:
            specs["w"] = mat
        if "hw" in p:
            hw = p["hw"]
            specs["hw"] = (_state_specs(col, axis)
                           if isinstance(hw, AIMCDeviceState)
                           else {"levels": mat, "scale": vec})
        specs["b"] = vec if p.get("b") is not None else None
        return specs

    def _col_linear(self, key, p, spikes, sim):
        """Output columns sharded: each shard fires its own LIF columns."""
        x_in = self._x_spec(spikes.ndim, spikes.shape[1], None)
        x_out = self._x_spec(spikes.ndim, spikes.shape[1], self.model_axis)
        inner, p_specs = self.inner, self._p_specs(p, col=True)

        if key is None:
            def body(pl_, sp_):
                return inner.spiking_linear(None, pl_, sp_, sim)

            return _shard_map(body, mesh=self.mesh,
                              in_specs=(p_specs, x_in),
                              out_specs=x_out)(p, spikes)

        def body(k_, pl_, sp_):
            return inner.spiking_linear(k_, pl_, sp_, sim)

        return _shard_map(body, mesh=self.mesh,
                          in_specs=(P(), p_specs, x_in),
                          out_specs=x_out)(key, p, spikes)

    def _row_linear(self, key, p, spikes, sim):
        """Input rows sharded: psum integer spike counts, fire LIF once.

        The cross-shard reduction runs on integer-valued f32 partial counts
        (exact), then scale/bias/LIF replay the fused kernel's op sequence
        on the reduced currents — bit-identical to the single-device fused
        ``aimc_spiking_linear``."""
        from repro import engine as E

        axis = self.model_axis
        acfg = (sim or E._IDEAL_SIM).cfg
        inner = self.inner
        use_kernel = getattr(inner, "interpret", None) is not None  # pallas
        x_in = self._x_spec(spikes.ndim, spikes.shape[1], axis)
        x_out = self._x_spec(spikes.ndim, spikes.shape[1], None)

        def body(pl_, sp_):
            flat, unflatten = E._flatten_time(sp_)
            flat = flat.astype(jnp.float32)
            if "hw" in pl_:
                hw = pl_["hw"]
                if isinstance(hw, AIMCDeviceState):
                    levels, scale = hw.levels_t, hw.eff_scale
                else:
                    levels, scale = hw["levels"].astype(jnp.int8), hw["scale"]
            else:
                # per-column scale needs the *global* column max: pmax is
                # order-invariant, so shard-local quantisation with the
                # pmax'd scale reproduces the single-device levels exactly
                amax = lax.pmax(jnp.max(jnp.abs(pl_["w"]), axis=-2), axis)
                scale = jnp.where(amax > 0, amax / acfg.levels, 1.0
                                  ).astype(jnp.float32)
                levels = AM.quantize_levels(pl_["w"], scale, acfg
                                            ).astype(jnp.int8)
            if use_kernel:
                counts = KOPS.aimc_matmul_counts(flat, levels,
                                                 interpret=inner.interpret)
            else:
                counts = KREF.aimc_counts_ref(flat, levels)
            counts = lax.psum(counts, axis)  # exact: integer-valued f32
            pre = counts * scale[None, None, :]
            if pl_.get("b") is not None:
                pre = pre + pl_["b"].astype(jnp.float32)[None, None, :]
            return unflatten(inner.lif(pre))

        return _shard_map(body, mesh=self.mesh,
                          in_specs=(self._p_specs(p, col=False), x_in),
                          out_specs=x_out)(p, spikes)
