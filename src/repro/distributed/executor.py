"""Executor: the whole inference stack placed on a ``(data, model)`` mesh.

One object owns the mesh placement of everything serving needs:

* **params / AIMC device state** — spiking-linear leaves (float weights or
  programmed :class:`~repro.aimc_device.AIMCDeviceState`) are tensor-
  parallel over ``model`` per :data:`~repro.distributed.backend.TP_PARTS`
  (Q/K/V/MLP-in column-sharded, attention-out/MLP-out row-sharded);
  everything else is replicated.
* **DecodeState** — decode slots are data-parallel: the slot axis of every
  cache leaf, token/seed/occupancy vector rides the ``data`` axis (via
  ``parallel.sharding.cache_pspecs``, which also shards the spiking KV
  head axis over ``model``); mid-flight admission splices a replicated
  batch-1 prefill into the sharded batch.
* **backends** — a decode :class:`~repro.distributed.backend.ShardedBackend`
  (slots over ``data``, TP over ``model``) and a batch-1 prefill instance
  (TP only).

The scheduler keeps its host-side bookkeeping (queues, energy accounting,
drift clocks) unchanged — `BatchScheduler(..., placement=executor)` pins
the jitted decode/prefill/splice out-shardings so the compiled step is
reused for the server's whole lifetime (drift/GDC updates stay leaf-value-
only), and per-slot activity/energy gathers transparently from the mesh.

Bit-exactness: with the ``integer`` or ``pallas`` backend, sharded forward
and a full ``BatchScheduler.run()`` (admissions, evictions, drift + GDC)
produce bit-identical tokens to the single-device oracle — reductions are
integer-valued, PRN streams are keyed by logical (seed, pos, head)
coordinates, and the GDC calibration read is an integer sum
(``aimc_device.recalibrate``).
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.aimc_device import AIMCDeviceState
from repro.distributed.backend import (TP_PARTS, ShardedBackend, TPPlan,
                                       _state_specs)
from repro.models import transformer as T
from repro.models.moe import ParallelCtx
from repro.parallel import sharding as SH

Array = jax.Array


# ---------------------------------------------------------------------------
# Parameter placement (actual trees, including programmed device state)
# ---------------------------------------------------------------------------


def _lead(n: int):
    return (None,) * n


def _leaf_pspec(name: str, leaf: Any, plan: TPPlan, axis: str):
    """Spec for one spiking-linear leaf (float original shape or programmed
    matrix-view device state), by its Megatron part."""
    part = TP_PARTS.get(name)
    if part is None or plan.tp <= 1:
        return jax.tree.map(lambda _: P(), leaf)
    if isinstance(leaf, AIMCDeviceState):
        d_in, d_out = leaf.shape[-2:]
        ok = plan.col_ok(d_out) if part == "col" else plan.row_ok(d_in)
        if not ok:
            return jax.tree.map(lambda _: P(), leaf)
        # same field -> spec mapping as the shard_map in_specs (one source)
        return _state_specs(part == "col", axis, lead=leaf.levels.ndim - 2)
    # float leaves keep their original (per-head) shapes; shard on whole
    # heads / ffn columns so the layout matches the shard_map decomposition
    if name in ("wq", "wk", "wv"):  # [*, d, nh, hd]
        if leaf.shape[-2] % plan.tp == 0:
            return P(*_lead(leaf.ndim - 3), None, axis, None)
    elif name == "wo" and leaf.ndim >= 3:  # attention wo [*, nh, hd, d]
        if leaf.shape[-3] % plan.tp == 0:
            return P(*_lead(leaf.ndim - 3), axis, None, None)
    elif name == "wi":  # [*, d, f]
        if leaf.shape[-1] % plan.tp == 0:
            return P(*_lead(leaf.ndim - 2), None, axis)
    elif name == "wo":  # mlp wo [*, f, d]
        if leaf.shape[-2] % plan.tp == 0:
            return P(*_lead(leaf.ndim - 2), axis, None)
    return P()


def param_pspecs_for_tree(cfg, params: Any, mesh, *, model_axis: str = "model"):
    """PartitionSpec tree parallel to an *actual* LM param tree.

    Unlike :func:`repro.parallel.sharding.param_pspecs` (which maps the
    abstract schema), this walks the real tree, so programmed
    :class:`AIMCDeviceState` leaves get per-field specs on their crossbar
    matrix view.  Spiking-linear leaves are tensor-parallel per
    :data:`TP_PARTS`; everything else replicates (the serving layout —
    a <1B spiking stack is latency-bound, not memory-bound)."""
    sizes = SH.axis_sizes(mesh)
    plan = TPPlan.from_config(cfg, sizes.get(model_axis, 1))
    specs = jax.tree.map(lambda _: P(), params)
    if not T._spiking_decode_enabled(cfg) or plan.tp <= 1:
        return specs

    def do_block(bspec: Dict[str, Any], bparams: Dict[str, Any]):
        mix, mixs = bparams.get("mixer"), bspec.get("mixer")
        if isinstance(mix, dict) and {"wq", "wk", "wv", "wo"} <= set(mix):
            for n in ("wq", "wk", "wv", "wo"):
                mixs[n] = _leaf_pspec(n, mix[n], plan, model_axis)
        mlp, mlps = bparams.get("mlp"), bspec.get("mlp")
        if isinstance(mlp, dict) and {"wi", "wo"} <= set(mlp):
            for n in ("wi", "wo"):
                mlps[n] = _leaf_pspec(n, mlp[n], plan, model_axis)

    for group in ("periods", "remainder"):
        if group in specs:
            for bk in specs[group]:
                do_block(specs[group][bk], params[group][bk])
    return specs


# ---------------------------------------------------------------------------
# Executor
# ---------------------------------------------------------------------------


class Executor:
    """Mesh-sharded execution of one engine (params + backends + placement).

    ::

        mesh = make_serving_mesh((2, 4))          # (data, model)
        ex = Executor(params, cfg, "pallas", mesh)
        logits = ex.forward(tokens, rng)          # TP+DP forward
        outs, stats = ex.serve(prompts, max_new=16, slots=4)

    or through the engine facade: ``engine.executor(mesh)``.
    """

    def __init__(self, params, cfg, backend, mesh, *, moe_impl: Optional[str] = None):
        from repro.engine import get_backend

        self.mesh = mesh
        self.cfg = cfg
        self.inner = get_backend(backend)
        sizes = SH.axis_sizes(mesh)
        self.data = sizes.get("data", 1)
        self.model = sizes.get("model", 1)
        self.moe_impl = moe_impl or ("ep_a2a" if cfg.is_moe else "dense")
        self.plan = TPPlan.from_config(cfg, self.model)
        self.param_specs = param_pspecs_for_tree(cfg, params, mesh)
        self.params = self.place_params(params)
        spiking = T._spiking_decode_enabled(cfg)
        if spiking and self.model > 1:
            self.decode_backend: Any = ShardedBackend(
                self.inner, mesh, cfg, batch_axis="data")
            self.prefill_backend: Any = ShardedBackend(
                self.inner, mesh, cfg, batch_axis=None)
        else:
            self.decode_backend = self.prefill_backend = (
                self.inner if spiking else None)
        self.pctx = ParallelCtx(
            mesh=mesh,
            dp_axes=("data",) if self.data > 1 else (),
            fsdp_axis=None,
            tp_axis="model" if self.model > 1 else None,
            seq_shard=False,
        )
        self._fwd = None
        self._schedulers: Dict[Any, Any] = {}

    # -- placement ------------------------------------------------------

    def _ns(self, spec) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    @property
    def replicated(self) -> NamedSharding:
        return self._ns(P())

    def place_params(self, params):
        """Commit a param tree to its mesh placement (idempotent; used at
        construction and after drift/GDC leaf-value updates so the pinned
        decode executable always sees identical shardings)."""
        return jax.device_put(params, SH.to_shardings(self.param_specs, self.mesh))

    def state_specs(self, slots: int, cache_len: int):
        """DecodeState PartitionSpecs: slot axis over ``data``, spiking KV
        heads over ``model`` (via ``sharding.cache_pspecs``)."""
        from repro.serving.state import DecodeState

        b = SH.batch_pspec(self.mesh, slots)
        return DecodeState(
            cache=SH.cache_pspecs(self.cfg, self.mesh, slots, cache_len),
            tokens=P(b), seeds=P(b), active=P(b),
        )

    def state_shardings(self, slots: int, cache_len: int):
        return SH.to_shardings(self.state_specs(slots, cache_len), self.mesh)

    def paged_state_specs(self, slots: int, cache_len: int, n_pages: int,
                          page_len: int):
        """PagedDecodeState PartitionSpecs: pool pages are *global* (the
        page axis never shards — any slot on any data shard may reference
        any page), the pool's KV-head axis rides ``model`` (each SSA core
        caches its own heads' spike pages, exactly like the dense cache),
        and the per-slot vectors/table ride ``data``."""
        from repro.serving.state import PagedDecodeState

        sizes = SH.axis_sizes(self.mesh)
        kv = "model" if ("model" in sizes
                         and self.cfg.num_kv_heads % sizes["model"] == 0) else None
        b = SH.batch_pspec(self.mesh, slots)
        leaf = P(None, None, kv, None, None)  # [P, T, KV, page_len, hd]
        pool = jax.tree.map(
            lambda s: P(None, *leaf) if len(s.shape) == 6 else leaf,
            T.paged_pool_schema(self.cfg, n_pages, page_len))
        return PagedDecodeState(pool=pool, page_table=P(b, None), pos=P(b),
                                tokens=P(b), seeds=P(b), active=P(b))

    def paged_state_shardings(self, slots: int, cache_len: int, n_pages: int,
                              page_len: int):
        return SH.to_shardings(
            self.paged_state_specs(slots, cache_len, n_pages, page_len),
            self.mesh)

    def place_state(self, state):
        from repro.serving.state import PagedDecodeState

        slots = state.tokens.shape[0]
        if isinstance(state, PagedDecodeState):
            mp = state.page_table.shape[1]
            return jax.device_put(state, self.paged_state_shardings(
                slots, mp * state.page_len, state.n_pages, state.page_len))
        cache_len = _cache_len(state.cache)
        return jax.device_put(state, self.state_shardings(slots, cache_len))

    def decode_out_shardings(self, slots: int, cache_len: int):
        """(logits, state, activity) shardings pinned onto the jitted
        decode step — output placement never drifts, so the step compiles
        exactly once for the server's lifetime."""
        b = SH.batch_pspec(self.mesh, slots)
        return (self._ns(P(b, None, None)),
                self.state_shardings(slots, cache_len),
                self._ns(P(b)))

    def paged_decode_out_shardings(self, slots: int, cache_len: int,
                                   n_pages: int, page_len: int):
        """(logits, paged state, activity) shardings for the paged step."""
        b = SH.batch_pspec(self.mesh, slots)
        return (self._ns(P(b, None, None)),
                self.paged_state_shardings(slots, cache_len, n_pages, page_len),
                self._ns(P(b)))

    # -- mesh-wide forward ---------------------------------------------

    def forward(self, tokens: Array, rng: Array) -> Array:
        """Full (spiking) forward on the mesh: tokens [B, S] -> logits.

        Batch rides ``data`` (when divisible); the spiking linears run
        through the :class:`ShardedBackend`'s explicit shard_map
        decomposition (column/row-parallel crossbars with integer-count
        psum); full-sequence SSA attention draws its comparator PRNs at
        logical shapes and is partitioned by GSPMD.  Bit-exact vs the
        single-device backend."""
        if self._fwd is None:
            cfg, moe_impl = self.cfg, self.moe_impl
            backend = self.decode_backend or self.inner

            def fn(params, x, rng):
                return T.forward(params, {"tokens": x}, cfg, rng=rng,
                                 backend=backend, moe_impl=moe_impl,
                                 remat="none")[0]

            self._fwd = jax.jit(fn)
        b = SH.batch_pspec(self.mesh, int(tokens.shape[0]))
        tokens = jax.device_put(jnp.asarray(tokens, jnp.int32),
                                self._ns(P(b, None)))
        return self._fwd(self.params, tokens, rng)

    # -- data-parallel continuous batching ------------------------------

    def scheduler(self, *, slots: int = 4, cache_len: int = 64, drift=None,
                  paged: bool = False, page_len: int = 8,
                  n_pages: Optional[int] = None,
                  decode_kernel: str = "auto"):
        """A mesh-sharded :class:`repro.serving.BatchScheduler`: slots are
        data-parallel, the decode math is tensor-parallel, admission /
        eviction / energy metering work exactly as on one device
        (``paged=True`` serves off the block-paged pool, KV heads sharded
        over ``model``, pages global).  Schedulers are cached per (slots,
        cache_len, paged geometry) to keep the compiled decode/prefill
        warm across :meth:`serve` calls."""
        from repro.serving import BatchScheduler

        key = (slots, cache_len, paged, decode_kernel) + (
            (page_len, n_pages) if paged else ())
        sch = self._schedulers.get(key)
        if sch is not None:
            sch.reset()
            sch.set_params(self.params)
            sch.drift = drift
            return sch
        sch = BatchScheduler(
            self.params, self.cfg, self.decode_backend, slots=slots,
            cache_len=cache_len, pctx=self.pctx, moe_impl=self.moe_impl,
            drift=drift, placement=self, paged=paged, page_len=page_len,
            n_pages=n_pages, decode_kernel=decode_kernel,
        )
        self._schedulers[key] = sch
        return sch

    def serve(self, prompts, max_new: int = 16, *, slots: int = 4,
              cache_len: int = 64, seed: int = 0, drift=None,
              paged: bool = False, page_len: int = 8,
              n_pages: Optional[int] = None, decode_kernel: str = "auto"):
        """Continuous-batching serve on the mesh -> (outputs, ServeStats)."""
        sch = self.scheduler(slots=slots, cache_len=cache_len, drift=drift,
                             paged=paged, page_len=page_len, n_pages=n_pages,
                             decode_kernel=decode_kernel)
        rids = [sch.submit(p, max_new, seed=seed + i)
                for i, p in enumerate(prompts)]
        outs = sch.run()
        if sch._programmed:
            # drift is physical: adopt the aged/recalibrated device state
            self.params = sch.params
        return [outs[r] for r in rids], sch.stats


def _cache_len(cache) -> int:
    """Recover cache_len from a cache pytree (spiking sk [.., B, T, L, ..]
    or ANN k [.., B, L, ..] leaves are not needed — any 'pos'-bearing block
    works because init_state built the tree from cache_schema)."""
    def find(tree):
        for k, v in tree.items():
            if isinstance(v, dict):
                if "sk" in v:
                    return v["sk"].shape[-3]
                if "k" in v:
                    return v["k"].shape[-3]
                got = find(v)
                if got is not None:
                    return got
        return None

    n = find(cache)
    return int(n) if n is not None else 0
