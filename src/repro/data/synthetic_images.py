"""Procedural image-classification dataset (offline ImageNet/CIFAR stand-in).

10 classes of oriented sinusoidal gratings (Gabor-like) with per-sample
random phase, amplitude jitter, colour cast and additive noise — enough
structure that a small ViT separates classes only by learning spatial
frequency/orientation, i.e. genuine feature extraction, while remaining
fully reproducible offline.  Used by Table III / Fig. 7 / Table V
reproductions at reduced scale (DESIGN.md §1).
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ImageConfig:
    size: int = 32
    channels: int = 3
    num_classes: int = 10
    noise: float = 0.35


def _class_params(num_classes: int):
    angles = jnp.linspace(0.0, jnp.pi * 0.9, num_classes)
    freqs = 2.0 + 3.0 * (jnp.arange(num_classes) % 3)
    return angles, freqs


def sample_batch(key: Array, cfg: ImageConfig, batch: int) -> Dict[str, Array]:
    k_cls, k_phase, k_amp, k_noise, k_col = jax.random.split(key, 5)
    labels = jax.random.randint(k_cls, (batch,), 0, cfg.num_classes)
    angles, freqs = _class_params(cfg.num_classes)
    a = angles[labels]
    f = freqs[labels]
    phase = jax.random.uniform(k_phase, (batch,)) * 2 * jnp.pi
    amp = 0.7 + 0.3 * jax.random.uniform(k_amp, (batch,))

    xs = jnp.linspace(0, 1, cfg.size)
    gx, gy = jnp.meshgrid(xs, xs, indexing="ij")
    arg = (
        2 * jnp.pi * f[:, None, None]
        * (gx[None] * jnp.cos(a)[:, None, None] + gy[None] * jnp.sin(a)[:, None, None])
        + phase[:, None, None]
    )
    base = amp[:, None, None] * jnp.sin(arg)  # [B,H,W]
    col = 0.5 + 0.5 * jax.random.uniform(k_col, (batch, 1, 1, cfg.channels))
    img = base[..., None] * col
    img = img + cfg.noise * jax.random.normal(k_noise, img.shape)
    img = jnp.clip(0.5 * (img + 1.0), 0.0, 1.0)
    return {"images": img.astype(jnp.float32), "labels": labels}
