"""In-context-learning MIMO symbol detection task (paper §VI Task 2).

Follows [30] / [3]: a GPT-style decoder sees 18 query–answer pairs
(received signal y, transmitted symbol x) drawn from ONE random unknown
channel H, then must detect the symbol for a 19th query.  QPSK per transmit
antenna: the class set is 4^N_t (16 for 2x2, 256 for 4x4 — "the number of
classes grows exponentially", §VI-A).

Token stream (length 2*pairs+1): alternating
  query token:  features = [Re(y), Im(y), 0-vector]
  answer token: features = [0, 0, one-hot(symbol)]
The model predicts the symbol class at every *query* position; BER counts
bit errors in the 2*N_t-bit Gray labelling of the class index.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

_QPSK = jnp.array([1 + 1j, 1 - 1j, -1 + 1j, -1 - 1j], jnp.complex64) / jnp.sqrt(2.0)


@dataclasses.dataclass(frozen=True)
class MIMOConfig:
    n_tx: int = 2
    n_rx: int = 2
    pairs: int = 18
    snr_db: float = 20.0

    @property
    def n_classes(self) -> int:
        return 4 ** self.n_tx

    @property
    def feat_dim(self) -> int:
        return 2 * self.n_rx + self.n_classes

    @property
    def seq_len(self) -> int:
        return 2 * self.pairs + 1

    @property
    def bits_per_symbol(self) -> int:
        return 2 * self.n_tx


def _symbols_of_class(cls: Array, n_tx: int) -> Array:
    """Class index -> per-antenna QPSK symbols [.., n_tx] complex."""
    idx = jnp.stack([(cls // (4 ** i)) % 4 for i in range(n_tx)], axis=-1)
    return _QPSK[idx]


def class_bits(cls: Array, n_tx: int) -> Array:
    """2*n_tx bit labelling of a class index."""
    nb = 2 * n_tx
    return jnp.stack([(cls // (2 ** i)) % 2 for i in range(nb)], axis=-1)


def sample_batch(key: Array, cfg: MIMOConfig, batch: int) -> Dict[str, Array]:
    """Returns {features [B,L,F], labels [B,L], mask [B,L]}."""
    kh, kx, kn = jax.random.split(key, 3)
    n_tok = cfg.pairs + 1
    h = (
        jax.random.normal(kh, (batch, cfg.n_rx, cfg.n_tx), jnp.float32)
        + 1j * jax.random.normal(jax.random.fold_in(kh, 1), (batch, cfg.n_rx, cfg.n_tx), jnp.float32)
    ) / jnp.sqrt(2.0 * cfg.n_tx)
    cls = jax.random.randint(kx, (batch, n_tok), 0, cfg.n_classes)
    x = _symbols_of_class(cls, cfg.n_tx)  # [B,n_tok,n_tx]
    noise_std = jnp.sqrt(10.0 ** (-cfg.snr_db / 10.0) / 2.0)
    w = noise_std * (
        jax.random.normal(kn, (batch, n_tok, cfg.n_rx))
        + 1j * jax.random.normal(jax.random.fold_in(kn, 1), (batch, n_tok, cfg.n_rx))
    )
    y = jnp.einsum("brt,bnt->bnr", h, x) + w  # [B,n_tok,n_rx]

    yfeat = jnp.concatenate([y.real, y.imag], axis=-1)  # [B,n_tok,2n_rx]
    onehot = jax.nn.one_hot(cls, cfg.n_classes)

    L, F = cfg.seq_len, cfg.feat_dim
    feats = jnp.zeros((batch, L, F), jnp.float32)
    feats = feats.at[:, 0::2, : 2 * cfg.n_rx].set(yfeat)  # queries at even pos
    feats = feats.at[:, 1::2, 2 * cfg.n_rx :].set(onehot[:, :-1])  # answers
    labels = jnp.zeros((batch, L), jnp.int32)
    labels = labels.at[:, 0::2].set(cls)
    mask = jnp.zeros((batch, L), jnp.float32).at[:, 0::2].set(1.0)
    return {"features": feats, "labels": labels, "mask": mask}


def ber(logits: Array, labels: Array, mask: Array, cfg: MIMOConfig) -> Array:
    """Bit error rate over masked (query) positions."""
    pred = jnp.argmax(logits, axis=-1)
    pb = class_bits(pred, cfg.n_tx)
    tb = class_bits(labels, cfg.n_tx)
    errs = jnp.sum(jnp.abs(pb - tb), axis=-1).astype(jnp.float32)
    return jnp.sum(errs * mask) / (jnp.sum(mask) * cfg.bits_per_symbol)
