"""Deterministic, seekable, sharded synthetic LM data pipeline.

Fault-tolerance requirement: after a restart at step k the pipeline must
replay *exactly* the batches k, k+1, ... regardless of how many hosts died
— so batches are a pure function of (seed, step) via counter-based RNG.
No state files, no iterators to snapshot: ``batch_at(step)``.

The synthetic stream is not uniform noise: it is a learnable order-2
Markov chain (per-seed random transition table), so smoke trainings show a
real falling loss.  ``host_slice`` gives each data-parallel host its
disjoint rows for per-host feeding at scale.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    order: int = 2  # markov order


def _transition_logits(cfg: DataConfig) -> Array:
    key = jax.random.PRNGKey(cfg.seed)
    v = min(cfg.vocab_size, 512)  # active vocabulary of the chain
    return jax.random.gumbel(key, (v, v, v)) * 2.0


class MarkovStream:
    """Pure-function batch source: batch_at(step) is deterministic."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._logits = _transition_logits(cfg)
        self._v = self._logits.shape[0]
        self._sample = jax.jit(self._sample_impl, static_argnums=())

    def _sample_impl(self, key: Array) -> Array:
        cfg = self.cfg
        b, s = cfg.global_batch, cfg.seq_len + 1
        k0, k1 = jax.random.split(key)
        init = jax.random.randint(k0, (b, 2), 0, self._v)

        def step(carry, kk):
            t1, t2 = carry
            logit = self._logits[t1, t2]
            nxt = jax.random.categorical(kk, logit)
            return (t2, nxt), nxt

        keys = jax.random.split(k1, s)
        _, toks = jax.lax.scan(step, (init[:, 0], init[:, 1]), keys)
        return jnp.moveaxis(toks, 0, 1).astype(jnp.int32)  # [B, S+1]

    def batch_at(self, step: int) -> Dict[str, Array]:
        key = jax.random.fold_in(jax.random.PRNGKey(self.cfg.seed + 1), step)
        return {"tokens": self._sample(key)}

    def host_slice(self, batch: Dict[str, Array], host_id: int, num_hosts: int):
        per = self.cfg.global_batch // num_hosts
        return jax.tree.map(lambda x: x[host_id * per : (host_id + 1) * per], batch)


def abstract_batch(vocab: int, batch: int, seq_len: int, *, frontend_dim: int = 0):
    """ShapeDtypeStruct stand-ins for a *training* batch (loss shifts by 1)."""
    if frontend_dim:
        return {
            "embeddings": jax.ShapeDtypeStruct((batch, seq_len, frontend_dim), jnp.float32),
            "targets": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
        }
    return {"tokens": jax.ShapeDtypeStruct((batch, seq_len + 1), jnp.int32)}


def abstract_inputs(batch: int, seq_len: int, *, frontend_dim: int = 0):
    """ShapeDtypeStruct stand-ins for raw forward inputs (prefill)."""
    if frontend_dim:
        return {
            "embeddings": jax.ShapeDtypeStruct((batch, seq_len, frontend_dim), jnp.float32)
        }
    return {"tokens": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32)}
