"""Unified Xpikeformer engine: one model API, pluggable compute backends.

The paper's central claim is that a *single* spiking transformer runs on
interchangeable substrates — GPU float math for training, a bit-faithful
integer simulation of the SSA/AIMC hardware, and the accelerated engine
itself.  This module makes that a first-class API instead of three
disconnected code paths:

* :class:`Backend` — the protocol every substrate implements: the three
  spiking primitive ops the models are built from (``ssa_attention``,
  ``lif``, ``spiking_linear``).
* ``"reference"``  — differentiable float ops + straight-through Bernoulli
  samplers; the only backend that models the AIMC *analog* non-idealities
  (HWAT noise, PCM drift, read noise, GDC).  Use for training and for the
  paper's drift/BER studies.
* ``"integer"``    — bit-faithful integer simulation of the digital SSA
  engine + 5-bit quantised crossbars (the hardware oracle).  Deterministic
  given a key; not differentiable.
* ``"pallas"``     — the bit-packed Pallas TPU kernels (popcount SSA,
  fused-membrane LIF, fused crossbar-MVM + LIF), with packing and padding
  absorbed inside the backend.  Bit-exact against ``"integer"`` given the
  same key.
* :class:`XpikeformerEngine` — facade over the paper models (spiking ViT
  and spiking GPT): ``from_config`` / ``init`` / ``forward`` / ``program``
  (PCM programming) / ``classify`` / ``detect_symbols``.

Quick start::

    from repro.engine import XpikeformerEngine
    eng = XpikeformerEngine.from_config("xpikeformer-vit-smoke", backend="pallas")
    params = eng.init(jax.random.PRNGKey(0))
    logits = eng.forward(images, jax.random.PRNGKey(1))

Serving (generic LM-stack archs, ``task="lm"``)::

    eng = XpikeformerEngine.from_config("xpikeformer-gpt-4-256", task="lm",
                                        backend="pallas")
    eng.init(jax.random.PRNGKey(0))
    outs = eng.generate([[5, 7, 9], [3, 1]], max_new=16)       # batch API
    outs, stats = eng.serve(prompts, max_new=16, slots=8)      # continuous batching
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, Optional, Protocol, Union, runtime_checkable

import jax
import jax.numpy as jnp

from repro import aimc_device as AD
from repro.aimc_device import AIMCDeviceState
from repro.core import aimc as AM
from repro.core import spikes as SP
from repro.core import ssa as SSA
from repro.core import spiking_transformer as ST
from repro.core.spiking_transformer import AIMCSim, SpikingConfig
from repro.kernels import decode_fused as KFD
from repro.kernels import ops as KOPS
from repro.kernels import ref as KREF
from repro.kernels.plan import AttnSpec, KVView

Array = jax.Array

_IDEAL_SIM = AIMCSim()  # wmode="ideal": plain float weights


# ---------------------------------------------------------------------------
# Backend protocol
# ---------------------------------------------------------------------------


@runtime_checkable
class Backend(Protocol):
    """A compute substrate for the three spiking primitives.

    ``p`` in :meth:`spiking_linear` is a linear-layer param leaf in any of
    the model formats: ``{"w", "b"}`` (float weights), ``{"hw", "b"}``
    (programmed PCM state from :func:`repro.core.spiking_transformer.
    program_model`), or a bare weight array.
    """

    name: str
    differentiable: bool
    bit_exact: bool

    def ssa_attention(self, key: Array, q: Array, k: Array, v: Array, *,
                      causal: bool = False) -> Array:
        """Stochastic spiking attention over ``[T,B,H,N,d]`` spike trains."""
        ...

    def decode_attention(self, view: KVView, q: Array, spec: AttnSpec, *,
                         slot_keys: Array) -> Array:
        """One-query SSA decode against cached KV spike trains (serving).

        The single decode surface: ``view`` is the K/V storage union —
        dense slot caches (``k``/``v [T,B,H,L,d]``, zero beyond each
        slot's position; zero spikes never beat a comparator draw, so
        validity masking is implicit) or a block-paged pool
        (``k``/``v [P,T,KV,page_len,d]`` plus ``page_table [B,MP]``;
        entry 0 is the permanently-zero null page, and GQA repeat happens
        inside the backend).  ``q [T,B,H,1,d]`` is the token being
        decoded.  ``spec`` carries the static geometry: ``i_max`` is the
        output comparator range — the *logical* cache capacity (the
        hardware tile dimension), fixed regardless of fill level and
        layout, so dense and paged decode draw identical streams;
        ``spec.h0`` is the mesh-aware entry point — a tensor-parallel
        shard that owns heads ``[h0, h0+H)`` passes its global head
        offset (possibly traced) and draws exactly the single-device
        oracle's integers for those heads (see
        :class:`repro.distributed.ShardedBackend`).

        ``slot_keys [B,2]`` are per-slot uint32 PRNG keys: every slot
        draws its own comparator integers so continuous-batching
        admission cannot perturb running slots; within a slot every head
        draws from ``f(seed, pos, global head index)``.

        The pre-PR-7 ``ssa_attention_decode`` / ``ssa_attention_decode_
        paged`` methods survive as deprecation shims (bit-exact
        forwarders) on every bundled backend."""
        ...

    def lif(self, currents: Array, *, beta: float = 0.5,
            v_thresh: float = 1.0) -> Array:
        """LIF neuron over a ``[T, ...]`` current sequence."""
        ...

    def spiking_linear(self, key: Optional[Array], p: Any, spikes: Array,
                       sim: Optional[AIMCSim] = None, *,
                       part: Optional[str] = None) -> Array:
        """``LIF(W s^t + b)`` over a ``[T, ..., d_in]`` spike train.

        ``part`` is a mesh-aware tensor-parallel hint from the model code:
        ``"col"`` for output-column-sharded layers (Q/K/V projections, MLP
        in) and ``"row"`` for input-sharded layers whose partial spike
        counts must psum before the LIF fires (attention out, MLP out).
        Single-device backends ignore it; ``repro.distributed.
        ShardedBackend`` uses it to pick the shard_map decomposition."""
        ...


def _linear_parts(p: Any) -> Dict[str, Any]:
    """Normalise a linear param leaf to ``{"w"|"hw", "b"}`` form."""
    if isinstance(p, AIMCDeviceState):
        return {"hw": p, "b": None}
    if isinstance(p, dict):
        return p
    return {"w": p, "b": None}


def _levels_scale(p: Dict[str, Any], sim: AIMCSim):
    """Integer conductance levels + per-column scale for a linear leaf.

    Programmed PCM state (:class:`repro.aimc_device.AIMCDeviceState`)
    executes its *digital image* — the drifted, GDC-compensated int8
    ``levels_t`` and per-column ``eff_scale`` that ``drift_to`` /
    ``recalibrate`` folded at calibration time, so the hot loop stays an
    int8 MXU matmul on every backend.  Float weights are quantised on the
    fly via the single source of truth
    (:func:`repro.aimc_device.quantize_weights`); legacy ``{"hw": {...}}``
    dicts keep their ideal-levels behaviour.  Continuous analog
    non-idealities (read noise, per-device drift residuals, shared-ADC
    clipping) remain reference-backend-only.
    """
    if "hw" in p:
        hw = p["hw"]
        if isinstance(hw, AIMCDeviceState):
            return hw.levels_t, hw.eff_scale
        return hw["levels"].astype(jnp.int8), hw["scale"]
    levels, scale = AD.quantize_weights(p["w"], sim.cfg)
    return levels.astype(jnp.int8), scale


def _gather_paged_kv(q: Array, kpool: Array, vpool: Array, page_table: Array):
    """Dense [T,B,H,L,d] K/V views of a paged pool (GQA-repeated to match q).

    The non-kernel backends' paged-decode path: gather each slot's pages
    through its table (null pages read as zeros) and hand the dense view to
    the slot-dense decode — bit-identical content, identical PRN streams."""
    h, kv = q.shape[2], kpool.shape[2]
    k = KOPS.gather_kv_pages(kpool, page_table)  # [T, B, KV, L, d]
    v = KOPS.gather_kv_pages(vpool, page_table)
    if kv != h:
        rep = h // kv
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    return k, v


def _flatten_time(spikes: Array):
    """[T, *lead, d_in] -> ([T, M, d_in], unflatten)"""
    t = spikes.shape[0]
    lead = spikes.shape[1:-1]
    d_in = spikes.shape[-1]
    flat = spikes.reshape(t, -1, d_in)

    def unflatten(out: Array) -> Array:
        return out.reshape((t,) + lead + (out.shape[-1],))

    return flat, unflatten


def _w_triple(p: Any, sim: AIMCSim):
    """Linear param leaf -> the fused kernels' (levels, scale, bias) triple."""
    parts = _linear_parts(p)
    levels, scale = _levels_scale(parts, sim)
    return (levels, scale, parts.get("b"))


class _DecodeShims:
    """The pre-PR-7 decode surface, forwarding to :meth:`decode_attention`.

    ``ssa_attention_decode`` / ``ssa_attention_decode_paged`` and their
    ``i_max``/``h0``/pool-vs-dense positional soup are deprecated: the one
    decode surface is ``decode_attention(view, q, spec)``.  These shims
    forward bit-exactly (asserted by the test suite) and warn once per
    trace site."""

    def ssa_attention_decode(self, slot_keys, q, k, v, *, i_max, h0=0):
        warnings.warn(
            "Backend.ssa_attention_decode is deprecated; use "
            "decode_attention(KVView.dense(k, v), q, AttnSpec(i_max, h0))",
            DeprecationWarning, stacklevel=2)
        return self.decode_attention(
            KVView.dense(k, v), q, AttnSpec(i_max=i_max, h0=h0),
            slot_keys=slot_keys)

    def ssa_attention_decode_paged(self, slot_keys, q, kpool, vpool,
                                   page_table, *, i_max, h0=0):
        warnings.warn(
            "Backend.ssa_attention_decode_paged is deprecated; use "
            "decode_attention(KVView.from_pool(kpool, vpool, page_table), "
            "q, AttnSpec(i_max, h0, groups))",
            DeprecationWarning, stacklevel=2)
        return self.decode_attention(
            KVView.from_pool(kpool, vpool, page_table), q,
            AttnSpec(i_max=i_max, h0=h0,
                     groups=q.shape[2] // kpool.shape[2]),
            slot_keys=slot_keys)


# ---------------------------------------------------------------------------
# Reference backend — differentiable float path (training)
# ---------------------------------------------------------------------------


class ReferenceBackend(_DecodeShims):
    """Float ops + straight-through Bernoulli/Heaviside surrogates.

    The only backend usable under ``jax.grad``; also the only one that
    applies the AIMC *analog* simulation (wmode="hwat"/"hw": programming
    noise, read noise, drift, GDC)."""

    name = "reference"
    differentiable = True
    bit_exact = False

    def ssa_attention(self, key, q, k, v, *, causal=False):
        return SSA.ssa_attention(key, q, k, v, causal=causal)

    def decode_attention(self, view, q, spec, *, slot_keys):
        if view.paged:
            k, v = _gather_paged_kv(q, view.k, view.v, view.page_table)
        else:
            k, v = view.k, view.v
        i_max, h0 = spec.i_max, spec.h0
        d = q.shape[-1]
        heads = jnp.asarray(h0) + jnp.arange(q.shape[2])

        def per_slot(key, qb, kb, vb):  # [T,H,1,d] x [T,H,L,d]
            def per_head(hi, qh, kh, vh):  # [T,1,d] x [T,L,d]
                k1, k2 = jax.random.split(jax.random.fold_in(key, hi))
                qf, kf, vf = (t.astype(jnp.float32) for t in (qh, kh, vh))
                counts_s = jnp.einsum("tnd,tld->tnl", qf, kf)
                p_s = counts_s / d
                s = SP.bernoulli_st(p_s, jax.random.uniform(k1, p_s.shape))
                counts_a = jnp.einsum("tnl,tld->tnd", s, vf)
                p_a = jnp.clip(counts_a / float(i_max), 0.0, 1.0)
                return SP.bernoulli_st(p_a, jax.random.uniform(k2, p_a.shape))

            # per-(slot, head) streams: f(seed, pos, global head) — the same
            # convention as the integer/pallas backends, so head-sharded
            # decode draws shard-locally without perturbing any stream
            return jax.vmap(per_head, in_axes=(0, 1, 1, 1), out_axes=1)(
                heads, qb, kb, vb)

        return jax.vmap(per_slot, in_axes=(0, 1, 1, 1), out_axes=1)(
            slot_keys, q, k, v
        )

    def lif(self, currents, *, beta=0.5, v_thresh=1.0):
        return SP.lif(currents, SP.LIFParams(beta=beta, v_thresh=v_thresh))

    def spiking_linear(self, key, p, spikes, sim=None, *, part=None):
        sim = sim or _IDEAL_SIM
        p = _linear_parts(p)
        if isinstance(p.get("hw"), AIMCDeviceState):
            # device-state lifecycle: per-device drift at the state's own
            # t_seconds, read noise, shared ADC, *stored* (stale) GDC gain
            pre = jax.vmap(
                lambda zt: AD.analog_matmul(key, zt, p["hw"], sim.cfg)
            )(spikes)
        elif "hw" in p:  # legacy dict state: full analog crossbar sim
            pre = jax.vmap(
                lambda zt: AM.aimc_matmul(
                    key, zt, p["hw"], sim.cfg, t_seconds=sim.t_seconds, gdc=sim.gdc
                )
            )(spikes)
        else:
            w = p["w"]
            if sim.wmode == "hwat":
                assert key is not None
                w = AM.hwat_weights(key, w, sim.cfg)
            pre = jax.vmap(lambda zt: zt @ w)(spikes)
        if p.get("b") is not None:
            pre = pre + p["b"]
        return SP.lif(pre)


# ---------------------------------------------------------------------------
# Integer backend — bit-faithful hardware oracle
# ---------------------------------------------------------------------------


class IntegerBackend(_DecodeShims):
    """Bit-faithful integer simulation of the SSA engine's digital datapath.

    Draws the comparator PRNs with the exact convention the pallas backend
    uses (:func:`repro.kernels.ops.draw_comparator_prns`), so the two are
    bit-identical given the same key — this backend is the correctness
    contract the kernels are validated against (including the fused
    decode-layer oracle, :meth:`decode_layer_fused`)."""

    name = "integer"
    differentiable = False
    bit_exact = True

    def ssa_attention(self, key, q, k, v, *, causal=False):
        t, b, h, n, d = q.shape
        g = t * b * h
        rs, ra = KOPS.draw_comparator_prns(key, (g, n, n), (g, n, d), d, n)
        out = KREF.ssa_attention_ref(
            q.reshape(g, n, d), k.reshape(g, n, d), v.reshape(g, n, d),
            rs, ra, causal=causal,
        )
        return out.reshape(t, b, h, n, d)

    def decode_attention(self, view, q, spec, *, slot_keys):
        t, b, h, n1, d = q.shape
        if view.paged:
            l = view.page_table.shape[1] * view.k.shape[3]
            # identical streams to the dense layout (bit-exact across modes)
            rs, ra = KOPS.draw_slot_decode_prns(slot_keys, t, h, l, d,
                                                spec.i_max, spec.h0)
            out = KREF.ssa_decode_paged_ref(
                jnp.moveaxis(q, 1, 0), view.k, view.v, view.page_table,
                rs.reshape(b, t, h, 1, l), ra.reshape(b, t, h, 1, d),
            )
            return jnp.moveaxis(out, 0, 1)
        k, v = view.k, view.v
        l = k.shape[3]
        # same per-(slot, head) PRN convention as the pallas wrapper
        # (bit-exactness); spec.h0 offsets the head streams for TP shards
        rs, ra = KOPS.draw_slot_decode_prns(slot_keys, t, h, l, d,
                                            spec.i_max, spec.h0)
        g = b * t * h
        out = KREF.ssa_decode_ref(
            jnp.moveaxis(q, 1, 0).reshape(g, 1, d),
            jnp.moveaxis(k, 1, 0).reshape(g, l, d),
            jnp.moveaxis(v, 1, 0).reshape(g, l, d),
            rs.reshape(g, 1, l), ra.reshape(g, 1, d),
        )
        return jnp.moveaxis(out.reshape(b, t, h, 1, d), 0, 1)

    def decode_layer_fused(self, slot_keys, s, view, pos, wq, wk, wv,
                           wo=None, wi=None, wo2=None, *, hd, h0=0,
                           write_pids=None, with_tail=True, with_mlp=True,
                           sim=None):
        """Fused-layer oracle: one decoder layer step, composed from the
        per-primitive reference oracles (see
        :func:`repro.kernels.ref.decode_layer_ref`).  The contract the
        pallas megakernel is fuzzed against; integer-fused ==
        integer-unfused by construction."""
        sim = sim or _IDEAL_SIM

        def tri(w):
            return None if w is None else _w_triple(w, sim)

        t, b, _ = s.shape
        wq = tri(wq)
        h = wq[0].shape[1] // hd
        if view.paged:
            l = view.page_table.shape[1] * view.k.shape[3]
            rs4, ra4 = KFD.draw_layer_prns(slot_keys, t, h, l, hd, h0)
            return KREF.decode_layer_paged_ref(
                s, view.k, view.v, view.page_table, pos, write_pids,
                wq, tri(wk), tri(wv), tri(wo), tri(wi), tri(wo2), rs4, ra4,
                hd=hd, with_tail=with_tail, with_mlp=with_mlp)
        l = view.k.shape[2]
        rs4, ra4 = KFD.draw_layer_prns(slot_keys, t, h, l, hd, h0)
        return KREF.decode_layer_ref(
            s, view.k, view.v, pos, wq, tri(wk), tri(wv), tri(wo), tri(wi),
            tri(wo2), rs4, ra4, hd=hd, with_tail=with_tail,
            with_mlp=with_mlp)

    def lif(self, currents, *, beta=0.5, v_thresh=1.0):
        t = currents.shape[0]
        flat = currents.astype(jnp.float32).reshape(t, -1)
        out = KREF.lif_ref(flat, beta=beta, v_thresh=v_thresh)
        return out.reshape(currents.shape)

    def spiking_linear(self, key, p, spikes, sim=None, *, part=None):
        sim = sim or _IDEAL_SIM
        p = _linear_parts(p)
        levels, scale = _levels_scale(p, sim)
        flat, unflatten = _flatten_time(spikes)
        out = KREF.aimc_spiking_linear_ref(
            flat.astype(jnp.float32), levels, scale, p.get("b")
        )
        return unflatten(out)


# ---------------------------------------------------------------------------
# Pallas backend — bit-packed TPU kernels on the model hot path
# ---------------------------------------------------------------------------


class PallasBackend(_DecodeShims):
    """The accelerated engine: popcount SSA + fused LIF/crossbar kernels.

    ``interpret=True`` executes the kernel bodies through the Pallas
    interpreter (exact semantics, runs on CPU); on TPU pass
    ``interpret=False`` for the compiled kernels.  Packing to uint32 lanes
    and padding to kernel block multiples happen inside the backend —
    callers hand over plain ``[T,B,H,N,d]`` / ``[T,...,d_in]`` spike
    trains."""

    name = "pallas"
    differentiable = False
    bit_exact = True

    def __init__(self, interpret: bool = True):
        self.interpret = interpret

    def ssa_attention(self, key, q, k, v, *, causal=False):
        return KOPS.ssa_attention_packed(
            q, k, v, key, causal=causal, interpret=self.interpret
        )

    def decode_attention(self, view, q, spec, *, slot_keys):
        if view.paged:
            return KOPS.ssa_attention_decode_paged_packed(
                q, view.k, view.v, view.page_table, slot_keys, spec.h0,
                i_max=spec.i_max, interpret=self.interpret,
            )
        return KOPS.ssa_attention_decode_packed(
            q, view.k, view.v, slot_keys, spec.h0, i_max=spec.i_max,
            interpret=self.interpret,
        )

    def decode_layer_fused(self, slot_keys, s, view, pos, wq, wk, wv,
                           wo=None, wi=None, wo2=None, *, hd, h0=0,
                           write_pids=None, with_tail=True, with_mlp=True,
                           sim=None):
        """One megakernel launch per decoder layer step (the PR-7 tentpole):
        packed-VMEM SSA + fused projections/FFN, dense or paged per the
        view; bit-exact vs :meth:`IntegerBackend.decode_layer_fused`."""
        sim = sim or _IDEAL_SIM

        def tri(w):
            return None if w is None else _w_triple(w, sim)

        if view.paged:
            return KFD.fused_decode_layer_paged(
                slot_keys, s, view.k, view.v, view.page_table, pos,
                write_pids, tri(wq), tri(wk), tri(wv), tri(wo), tri(wi),
                tri(wo2), h0, hd=hd, with_tail=with_tail, with_mlp=with_mlp,
                interpret=self.interpret)
        return KFD.fused_decode_layer(
            slot_keys, s, view.k, view.v, pos, tri(wq), tri(wk), tri(wv),
            tri(wo), tri(wi), tri(wo2), h0, hd=hd, with_tail=with_tail,
            with_mlp=with_mlp, interpret=self.interpret)

    def lif(self, currents, *, beta=0.5, v_thresh=1.0):
        return KOPS.lif_fused(
            currents.astype(jnp.float32), beta=beta, v_thresh=v_thresh,
            interpret=self.interpret,
        )

    def spiking_linear(self, key, p, spikes, sim=None, *, part=None):
        sim = sim or _IDEAL_SIM
        p = _linear_parts(p)
        levels, scale = _levels_scale(p, sim)
        flat, unflatten = _flatten_time(spikes)
        out = KOPS.aimc_spiking_linear(
            flat.astype(jnp.float32), levels, scale, p.get("b"),
            interpret=self.interpret,
        )
        return unflatten(out)


# ---------------------------------------------------------------------------
# Metering backend — spike counts x Table-II op energies (eager only)
# ---------------------------------------------------------------------------


class MeteringBackend(_DecodeShims):
    """Wraps any backend and meters energy from **measured** spike counts.

    Every primitive call records its operand/output spike events and
    converts them to picojoules with the Table-II op energies
    (``repro.energy.model.meter_*``), accumulating into :attr:`report`.
    Counting forces a host sync per call, so metering is for *eager*
    forwards — ``engine.forward(..., metering=True)`` — not for jitted
    serving loops (those meter through the decode-step activity counters,
    see ``repro.serving.scheduler``)."""

    def __init__(self, inner: Backend):
        from repro.energy import model as EM

        self.inner = inner
        self.report = EM.EnergyReport()
        self.name = f"metered[{inner.name}]"
        self.differentiable = inner.differentiable
        self.bit_exact = inner.bit_exact

    @staticmethod
    def _count(x) -> float:
        return float(jnp.sum(jnp.asarray(x, jnp.float32)))

    def ssa_attention(self, key, q, k, v, *, causal=False):
        from repro.energy import model as EM

        out = self.inner.ssa_attention(key, q, k, v, causal=causal)
        t, b, h, n, d = q.shape
        qs, ks, vs = self._count(q), self._count(k), self._count(v)
        e = EM.meter_ssa(t, b * h, n, n, d, qs / q.size, ks / k.size,
                         vs / v.size)
        self.report.ssa_pj += e["ssa"]
        self.report.spikes_in += qs + ks + vs
        self.report.spikes_out += self._count(out)
        self.report.calls += 1
        return out

    def decode_attention(self, view, q, spec, *, slot_keys):
        from repro.energy import model as EM

        out = self.inner.decode_attention(view, q, spec, slot_keys=slot_keys)
        t, b, h, n, d = q.shape
        if view.paged:
            kpool, vpool, page_table = view.k, view.v, view.page_table
            mp, kv = page_table.shape[1], kpool.shape[2]
            l = mp * kpool.shape[3]
            rep = h // kv
            # meter the *logical* gathered K/V the tile streams, without
            # ever materialising it: per-page spike totals indexed through
            # the page table give the gathered count at O(pool) cost, and
            # the GQA repeat is a plain multiplier on count and size alike
            kc = jnp.sum(kpool.astype(jnp.float32), axis=(1, 2, 3, 4))  # [P]
            vc = jnp.sum(vpool.astype(jnp.float32), axis=(1, 2, 3, 4))
            qs = self._count(q)
            ks = rep * float(jnp.sum(kc[page_table]))
            vs = rep * float(jnp.sum(vc[page_table]))
            kv_size = b * t * rep * kv * l * d  # the dense gathered view
        else:
            l = view.k.shape[3]
            qs, ks, vs = (self._count(q), self._count(view.k),
                          self._count(view.v))
            kv_size = view.k.size
        e = EM.meter_ssa(t, b * h, n, l, d, qs / q.size, ks / kv_size,
                         vs / kv_size)
        self.report.ssa_pj += e["ssa"]
        self.report.spikes_in += qs + ks + vs
        self.report.spikes_out += self._count(out)
        self.report.calls += 1
        return out

    def lif(self, currents, *, beta=0.5, v_thresh=1.0):
        from repro.energy import constants as C

        out = self.inner.lif(currents, beta=beta, v_thresh=v_thresh)
        self.report.lif_pj += currents.size * C.E_LIF_STEP
        self.report.spikes_out += self._count(out)
        self.report.calls += 1
        return out

    def spiking_linear(self, key, p, spikes, sim=None, *, part=None):
        from repro.energy import model as EM

        out = self.inner.spiking_linear(key, p, spikes, sim, part=part)
        t = spikes.shape[0]
        d_in, d_out = spikes.shape[-1], out.shape[-1]
        tokens = int(spikes.size // (t * d_in))
        ins = self._count(spikes)
        e = EM.meter_spiking_linear(t, tokens, d_in, d_out, ins)
        self.report.aimc_pj += e["aimc"]
        self.report.lif_pj += e["lif"]
        self.report.spikes_in += ins
        self.report.spikes_out += self._count(out)
        self.report.calls += 1
        return out


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------

BACKENDS = {
    "reference": ReferenceBackend,
    "integer": IntegerBackend,
    "pallas": PallasBackend,
}


def register_backend(name: str, factory) -> None:
    """Register a custom backend factory under ``name``."""
    BACKENDS[name] = factory


def get_backend(spec: Union[str, Backend, None], **kwargs) -> Backend:
    """Resolve a backend name (or pass an instance through)."""
    if spec is None:
        return ReferenceBackend()
    if isinstance(spec, str):
        if spec not in BACKENDS:
            raise KeyError(f"unknown backend {spec!r}; known: {sorted(BACKENDS)}")
        return BACKENDS[spec](**kwargs)
    return spec


# ---------------------------------------------------------------------------
# Engine facade
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class XpikeformerEngine:
    """One handle over the paper's models across all compute substrates.

    Built via :meth:`from_config` from a registered arch name (see
    ``repro.configs.xpikeformer.SPIKING_ARCHS``) or a raw
    :class:`SpikingConfig`.  Holds the model params after :meth:`init` /
    :meth:`program` so task-level helpers (:meth:`classify`,
    :meth:`detect_symbols`) are one-liners, but every method also accepts
    explicit ``params`` for functional use.
    """

    cfg: Any  # SpikingConfig (paper models) or ModelConfig (task="lm")
    task: str  # "vit" | "gpt" | "lm"
    backend: Backend
    sim: AIMCSim
    params: Any = None
    # schedulers are cached per (slots, cache_len, moe_impl): their jitted
    # decode/prefill closures are multi-second compiles worth keeping warm
    _schedulers: Dict[Any, Any] = dataclasses.field(
        default_factory=dict, repr=False, compare=False)

    # -- construction --------------------------------------------------

    @classmethod
    def from_config(
        cls,
        name_or_cfg: Union[str, SpikingConfig, Any],
        *,
        task: Optional[str] = None,
        backend: Union[str, Backend] = "reference",
        wmode: str = "ideal",
        aimc_cfg: Optional[AM.AIMCConfig] = None,
        t_seconds: float = 0.0,
        gdc: bool = True,
        reduced: bool = False,
        **backend_kwargs,
    ) -> "XpikeformerEngine":
        """Build an engine from an arch name or config.

        Names resolve against the paper models first
        (``configs.xpikeformer.SPIKING_ARCHS`` — spiking ViT / GPT), then
        against the generic LM-stack registry (``configs.registry`` —
        ``task="lm"``, served via :meth:`generate` / :meth:`serve`);
        ``reduced=True`` picks the registry arch's CPU smoke reduction.
        A raw :class:`SpikingConfig` or ``ModelConfig`` is accepted too.
        """
        from repro.configs.base import ModelConfig

        if isinstance(name_or_cfg, str):
            from repro.configs.xpikeformer import SPIKING_ARCHS
            from repro.configs.registry import ARCHS, get_config, reduced_config

            # "xpikeformer-gpt-*" names exist both as paper models and as
            # LM-stack registry archs; task="lm" forces the registry.
            if name_or_cfg in SPIKING_ARCHS and task != "lm":
                task_, cfg = SPIKING_ARCHS[name_or_cfg]
                task = task or task_
            elif name_or_cfg in ARCHS:
                cfg = reduced_config(name_or_cfg) if reduced else get_config(name_or_cfg)
                task = task or "lm"
            else:
                raise KeyError(
                    f"unknown engine arch {name_or_cfg!r}; known: "
                    f"{sorted(SPIKING_ARCHS)} + registry {sorted(ARCHS)}"
                )
        elif isinstance(name_or_cfg, ModelConfig):
            cfg = name_or_cfg
            task = task or "lm"
        else:
            cfg = name_or_cfg
            if task is None:
                task = "gpt" if cfg.input_dim > 0 else "vit"
        sim = AIMCSim(
            wmode=wmode, cfg=aimc_cfg or AM.AIMCConfig(),
            t_seconds=t_seconds, gdc=gdc,
        )
        return cls(cfg=cfg, task=task, backend=get_backend(backend, **backend_kwargs),
                   sim=sim)

    # -- lifecycle -----------------------------------------------------

    def init(self, key: Array):
        """Initialise (and store) model params."""
        if self.task == "lm":
            from repro.models import transformer as T

            self.params = T.init_params(key, self.cfg)
            return self.params
        init = ST.init_vit if self.task == "vit" else ST.init_gpt
        self.params = init(key, self.cfg)
        return self.params

    def program(self, key: Array, params: Any = None):
        """Program the float weights onto simulated PCM crossbars.

        Replaces every linear leaf by its programmed
        :class:`~repro.aimc_device.AIMCDeviceState` and switches the sim to
        long-term inference mode (wmode="hw").  Programming is a one-shot
        physical act: calling it on an already-programmed tree raises
        (``ValueError``) instead of silently re-wrapping leaves; the same
        ``key`` always programs the same device state.  For ``task="lm"``
        the generic LM stack's spiking-linear weights (attention q/k/v/o,
        MLP in/out) are programmed and everything else stays digital."""
        params = self.params if params is None else params
        assert params is not None, "call init() first or pass params"
        if AD.is_programmed(params):
            raise ValueError(
                "engine.program(): params already hold programmed PCM state; "
                "programming is one-shot — use drift_to()/recalibrate() to "
                "advance the device lifecycle"
            )
        if self.task == "lm":
            self.params = AD.program_lm_tree(key, params, self.sim.cfg)
        else:
            self.params = ST.program_model(key, params, self.sim.cfg)
        self.sim = dataclasses.replace(self.sim, wmode="hw")
        if self.sim.t_seconds > 0:  # engine built with a nonzero device age
            self.params = AD.drift_tree(self.params, self.sim.t_seconds,
                                        self.sim.cfg)
        return self.params

    def drift_to(self, t_seconds: float, params: Any = None):
        """Advance the programmed device clock to ``t_seconds``.

        Pure leaf-value update (shapes/dtypes unchanged), so jitted
        consumers of the params — ``jit_forward`` closures, the serving
        ``decode_step`` — are not recompiled."""
        params = self.params if params is None else params
        self._require_device_state(params)
        self.params = AD.drift_tree_jit(
            params, jnp.float32(t_seconds), self.sim.cfg)
        self.sim = dataclasses.replace(self.sim, t_seconds=float(t_seconds))
        return self.params

    @staticmethod
    def _require_device_state(params) -> None:
        if not AD.has_device_state(params):
            raise ValueError(
                "the drift lifecycle needs AIMCDeviceState leaves — call "
                "engine.program() first (legacy {'hw': dict} trees carry no "
                "device clock and cannot be aged or recalibrated)"
            )

    def recalibrate(self, params: Any = None):
        """Run global drift compensation (GDC, §V-B) at the current device
        time: fold the measured calibration gain into the per-column scales
        of every programmed crossbar."""
        params = self.params if params is None else params
        self._require_device_state(params)
        self.params = AD.recalibrate_tree_jit(params, self.sim.cfg)
        return self.params

    # -- forward -------------------------------------------------------

    def forward(self, x: Array, rng: Array, params: Any = None, *,
                metering: bool = False):
        """Full model forward: images -> class logits (vit), feature
        sequences -> per-token symbol logits (gpt), or token ids [B,S] ->
        next-token logits (lm).

        With ``metering=True`` the spiking primitives run through a
        :class:`MeteringBackend` and the call returns ``(logits, report)``
        where ``report`` is a :class:`repro.energy.model.EnergyReport` —
        measured spike counts x Table-II op energies.  Metering syncs the
        host per primitive call, so it is for eager forwards only."""
        params = self.params if params is None else params
        assert params is not None, "call init() first or pass params"
        backend = MeteringBackend(self.backend) if metering else self.backend
        if self.task == "lm":
            from repro.models import transformer as T

            logits, _ = T.forward(params, {"tokens": x}, self.cfg, rng=rng,
                                  backend=backend, remat="none")
        else:
            fwd = ST.vit_forward if self.task == "vit" else ST.gpt_forward
            logits = fwd(params, x, self.cfg, self.sim, rng, backend=backend)
        if metering:
            return logits, backend.report
        return logits

    def jit_forward(self):
        """A jitted pure function ``(params, x, rng) -> logits`` over the
        engine's (cfg, sim, backend) — for serving / benchmarking loops."""
        cfg, sim, backend = self.cfg, self.sim, self.backend
        if self.task == "lm":
            from repro.models import transformer as T

            return jax.jit(
                lambda params, x, rng: T.forward(
                    params, {"tokens": x}, cfg, rng=rng, backend=backend,
                    remat="none")[0]
            )
        fwd = ST.vit_forward if self.task == "vit" else ST.gpt_forward
        return jax.jit(
            lambda params, x, rng: fwd(params, x, cfg, sim, rng, backend=backend)
        )

    # -- task helpers --------------------------------------------------

    def classify(self, images: Array, rng: Array, params: Any = None) -> Array:
        """[B,H,W,C] images -> [B] predicted class labels."""
        assert self.task == "vit", "classify() is the ViT task"
        return jnp.argmax(self.forward(images, rng, params), axis=-1)

    def detect_symbols(self, feats: Array, rng: Array, params: Any = None) -> Array:
        """[B,L,feat] received-signal features -> [B,L] detected symbols."""
        assert self.task == "gpt", "detect_symbols() is the GPT/ICL task"
        return jnp.argmax(self.forward(feats, rng, params), axis=-1)

    # -- serving (task="lm") -------------------------------------------

    def scheduler(
        self,
        *,
        slots: int = 4,
        cache_len: int = 64,
        params: Any = None,
        pctx: Any = None,
        moe_impl: Optional[str] = None,
        drift: Any = None,
        paged: bool = False,
        page_len: int = 8,
        n_pages: Optional[int] = None,
        decode_kernel: str = "auto",
    ):
        """A :class:`repro.serving.BatchScheduler` over this engine.

        The scheduler's batched ``decode_step`` runs through this engine's
        backend, so reference / integer / pallas serve identically (the
        integer oracle is the bit-exactness contract).  ``paged=True``
        serves spiking SSA configs off the block-paged spike-train KV
        cache (exact prefix sharing + chunked prefill) — bit-identical
        tokens to dense serving.  ``decode_kernel`` picks the kernel
        strategy via :func:`repro.kernels.plan.build_decode_plan`:
        ``"auto"`` runs the fused decode megakernel where (config,
        backend) support it, ``"fused"`` demands it, ``"unfused"`` forces
        the per-primitive path — all bit-identical tokens.  Schedulers are
        cached per (slots, cache_len, moe_impl, paged geometry, kernel)
        and reset on reuse, so repeated :meth:`serve`/:meth:`generate`
        calls keep the compiled decode/prefill functions warm."""
        from repro.serving import BatchScheduler

        assert self.task == "lm", "serving drives the generic LM stack (task='lm')"
        params = self.params if params is None else params
        assert params is not None, "call init() first or pass params"
        key = (slots, cache_len, moe_impl, paged, decode_kernel) + (
            (page_len, n_pages) if paged else ())
        sch = self._schedulers.get(key) if pctx is None else None
        if sch is not None:
            sch.reset()
            sch.set_params(params)
            sch.drift = drift
            return sch
        sch = BatchScheduler(
            params, self.cfg, self.backend, slots=slots, cache_len=cache_len,
            pctx=pctx, moe_impl=moe_impl, drift=drift, paged=paged,
            page_len=page_len, n_pages=n_pages, decode_kernel=decode_kernel,
        )
        if pctx is None:
            self._schedulers[key] = sch
        return sch

    def serve(
        self,
        prompts,
        max_new: int = 16,
        *,
        slots: int = 4,
        cache_len: int = 64,
        seed: int = 0,
        params: Any = None,
        pctx: Any = None,
        moe_impl: Optional[str] = None,
        drift: Any = None,
        paged: bool = False,
        page_len: int = 8,
        n_pages: Optional[int] = None,
        decode_kernel: str = "auto",
    ):
        """Continuous-batching serve: prompts -> (outputs, ServeStats).

        Every request gets the PRN stream ``seed + i`` so results are
        reproducible and independent of batching/admission order.  Pass a
        :class:`repro.aimc_device.DriftPolicy` as ``drift`` (with
        programmed params) to run the PCM drift/recalibration lifecycle;
        per-request energy lands in the scheduler's ``request_energy_j``
        and the returned stats.  ``paged=True`` serves off the block-paged
        spike-train KV cache with exact prefix reuse and chunked prefill."""
        sch = self.scheduler(slots=slots, cache_len=cache_len, params=params,
                             pctx=pctx, moe_impl=moe_impl, drift=drift,
                             paged=paged, page_len=page_len, n_pages=n_pages,
                             decode_kernel=decode_kernel)
        rids = [sch.submit(p, max_new, seed=seed + i) for i, p in enumerate(prompts)]
        outs = sch.run()
        if params is None and sch._programmed:
            # drift is physical: adopt the aged/recalibrated device state so
            # a later serve() (which re-seeds the cached scheduler from
            # self.params) cannot rejuvenate the PCM clock
            self.params = sch.params
        return [outs[r] for r in rids], sch.stats

    def generate(self, prompts, max_new: int = 16, **kwargs):
        """Batch decode: list of token-id prompts -> list of generated
        token-id lists (greedy).  Thin wrapper over :meth:`serve`."""
        outs, _ = self.serve(prompts, max_new, **kwargs)
        return outs

    # -- distributed (mesh) execution ----------------------------------

    def executor(self, mesh, **kwargs):
        """A :class:`repro.distributed.Executor` over this engine's params:
        the whole inference stack placed on a ``(data, model)`` mesh —
        tensor-parallel spiking kernels on ``model``, data-parallel
        continuous batching on ``data``.  Sharded execution on the
        integer/pallas backends is bit-exact vs this engine run on one
        device; see README "Distributed serving"."""
        from repro.distributed import Executor

        assert self.task == "lm", "the distributed executor serves task='lm'"
        assert self.params is not None, "call init() first"
        return Executor(self.params, self.cfg, self.backend, mesh, **kwargs)
