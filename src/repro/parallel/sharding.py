"""Logical-axis sharding rules -> PartitionSpecs.

Every parameter leaf carries logical axis names (ParamDef.axes).  This
module maps them onto the production mesh ``(pod, data, model)``:

* ``model``  — tensor parallelism: ffn/vocab/heads/experts/ssm-inner/lru,
  and the sequence axis of activations / KV caches (sequence parallelism).
* ``data``   — data parallelism for the batch; with FSDP enabled it also
  shards the *minor* dimension of every large weight (ZeRO-3): e.g.
  ``wi [d_model -> data, d_ff -> model]`` is 256-way sharded.
* ``pod``    — inter-pod data parallelism only (batch).  Weights are
  replicated across pods: cross-pod traffic is gradient all-reduce only,
  matching the DCN/ICI bandwidth hierarchy.

Rules are *candidate lists*: the first candidate whose mesh axes exist, are
unused by earlier dims of the same tensor, and evenly divide the dim is
taken; otherwise the dim is replicated.  This gives every architecture a
well-defined layout even when a dim (e.g. qwen's 40 heads or a 49155-entry
vocab) does not divide the 16-way axis.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.layers import ParamDef
from repro.models import transformer as T

# logical axis -> ordered candidates, each a tuple of mesh axes
RULES: Dict[str, Tuple[Tuple[str, ...], ...]] = {
    "batch": (("pod", "data"), ("data",)),
    "seq": (("model",),),
    "vocab": (("model",),),
    "heads": (("model",),),
    "kv_heads": (("model",),),
    "head_dim": (),  # never sharded (small; avoids score all-reduces)
    "ffn": (("model",),),
    "experts": (("model",),),
    "experts_r": (),
    "expert_embed": (),
    "expert_ffn": (("data",),),  # expert tensor-parallel (2-D EP), FSDP-gated
    "ssm_inner": (("model",),),
    "ssm_heads": (("model",),),
    "ssm_conv": (("model",),),
    "lru": (("model",),),
    "lru_in": (),
    "layers": (),  # scan axis
    "embed": (("data",),),  # FSDP (ZeRO-3) minor-dim shard, gated on fsdp
}

_FSDP_GATED = {"embed", "expert_ffn"}


def axis_sizes(mesh: Optional[Mesh]) -> Dict[str, int]:
    if mesh is None:
        return {}
    shape = mesh.shape  # works for Mesh and AbstractMesh alike
    if isinstance(shape, dict):
        return dict(shape)
    return dict(zip(mesh.axis_names, shape))


def spec_for(
    axes: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Optional[Mesh],
    *,
    fsdp: bool = True,
    overrides: Optional[Dict[str, Tuple[Tuple[str, ...], ...]]] = None,
) -> P:
    """Map one tensor's logical axes to a PartitionSpec."""
    if mesh is None:
        return P()
    sizes = axis_sizes(mesh)
    used: set = set()
    out = []
    rules = dict(RULES)
    if overrides:
        rules.update(overrides)
    for name, dim in zip(axes, shape):
        picked = None
        if name is not None and not (name in _FSDP_GATED and not fsdp):
            for cand in rules.get(name, ()):
                if not all(a in sizes for a in cand):
                    continue
                if any(a in used for a in cand):
                    continue
                prod = int(np.prod([sizes[a] for a in cand]))
                if prod > 1 and dim % prod == 0:
                    picked = cand
                    used.update(cand)
                    break
        out.append(picked if picked is None else (picked[0] if len(picked) == 1 else picked))
    # trim trailing Nones for readability
    while out and out[-1] is None:
        out.pop()
    return P(*out)


# small-model layout: replicate every weight, spread the batch over the
# whole mesh — at 256 chips a <1B model is latency-bound, not memory-bound
PURE_DP_OVERRIDES = {
    "vocab": (), "heads": (), "kv_heads": (), "ffn": (), "experts": (),
    "expert_ffn": (), "ssm_inner": (), "ssm_heads": (), "ssm_conv": (),
    "lru": (), "embed": (), "seq": (),
}


def param_pspecs(cfg, mesh: Optional[Mesh], *, fsdp: bool = True, pure_dp: bool = False):
    """PartitionSpec pytree parallel to model params."""
    schema = T.model_schema(cfg)
    overrides = PURE_DP_OVERRIDES if pure_dp else None

    def f(d: ParamDef) -> P:
        return spec_for(d.axes, d.shape, mesh, fsdp=fsdp, overrides=overrides)

    return jax.tree.map(f, schema, is_leaf=lambda x: isinstance(x, ParamDef))


def param_shardings(cfg, mesh: Optional[Mesh], *, fsdp: bool = True):
    specs = param_pspecs(cfg, mesh, fsdp=fsdp)
    if mesh is None:
        return specs
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


# ---------------------------------------------------------------------------
# Activation / batch / cache specs
# ---------------------------------------------------------------------------


def _div(n: int, axes: Tuple[str, ...], sizes: Dict[str, int]) -> bool:
    prod = int(np.prod([sizes[a] for a in axes])) if axes else 1
    return prod > 0 and n % prod == 0


def batch_pspec(mesh: Optional[Mesh], batch: int, *, pure_dp: bool = False) -> Any:
    if mesh is None:
        return P()
    sizes = axis_sizes(mesh)
    cands = RULES["batch"]
    if pure_dp:
        cands = (
            ("pod", "data", "model"), ("data", "model"),
        ) + cands
    for cand in cands:
        if all(a in sizes for a in cand) and _div(batch, cand, sizes):
            return cand[0] if len(cand) == 1 else cand
    return None


def tokens_pspec(mesh: Optional[Mesh], batch: int) -> P:
    return P(batch_pspec(mesh, batch))


def cache_pspecs(cfg, mesh: Optional[Mesh], batch: int, seq_len: int):
    """PartitionSpec tree parallel to transformer.cache_schema.

    KV caches are sharded batch -> (pod, data) and *sequence -> model*
    (distributed flash-decode: softmax/normalisation over a sharded length
    axis is handled by GSPMD with small per-step all-reduces).  SSM / LRU
    states shard their head/width dims over ``model``.
    """
    if mesh is None:
        return jax.tree.map(lambda _: P(), T.cache_schema(cfg, batch, seq_len))
    sizes = axis_sizes(mesh)
    b = batch_pspec(mesh, batch)

    def block_spec(mixer: str):
        if mixer in ("attn", "local"):
            if T._spiking_decode_enabled(cfg):
                # spiking KV trains [B, spike_T, L, KV, hd]: batch over
                # (pod, data) and *KV heads over model* (each SSA engine
                # core caches its own heads' trains — tensor-parallel
                # decode, see repro.distributed).  The cache-length axis
                # stays replicated: the SSA comparators reduce over all of
                # L every step and the per-slot scatter would cross shards.
                kv = "model" if ("model" in sizes
                                 and cfg.num_kv_heads % sizes["model"] == 0) else None
                return {
                    "sk": P(b, None, None, kv, None),
                    "sv": P(b, None, None, kv, None),
                    "pos": P(b),
                }
            L = min(cfg.window_size, seq_len) if mixer == "local" else seq_len
            s = "model" if ("model" in sizes and L % sizes["model"] == 0) else None
            kd = None
            return {
                "k": P(b, s, kd, None),
                "v": P(b, s, kd, None),
                "pos": P(),
            }
        if mixer == "ssd":
            h = "model" if ("model" in sizes) else None
            d_in = cfg.ssm_expand * cfg.d_model
            nh = d_in // cfg.ssm_head_dim
            h = h if (h and nh % sizes["model"] == 0) else None
            ci = "model" if ("model" in sizes and d_in % sizes["model"] == 0) else None
            return {
                "ssd": P(b, h, None, None),
                "conv_x": P(b, None, ci),
                "conv_bc": P(b, None, None),
                "pos": P(),
            }
        if mixer == "rglru":
            w = cfg.rglru_width or cfg.d_model
            ws = "model" if ("model" in sizes and w % sizes["model"] == 0) else None
            return {
                "h": P(b, ws),
                "conv": P(b, None, ws),
                "pos": P(),
            }
        raise ValueError(mixer)

    def stack(tree):
        return jax.tree.map(lambda s: P(None, *s), tree, is_leaf=lambda x: isinstance(x, P))

    out: Dict[str, Any] = {}
    if cfg.num_periods > 0:
        period = {f"blk{i}": block_spec(m) for i, m in enumerate(cfg.block_pattern)}
        out["periods"] = stack(period)
    if cfg.remainder_layers:
        out["remainder"] = {
            f"blk{i}": block_spec(cfg.block_pattern[i])
            for i in range(cfg.remainder_layers)
        }
    return out


def to_shardings(tree, mesh: Optional[Mesh]):
    if mesh is None:
        return tree
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree, is_leaf=lambda x: isinstance(x, P)
    )


def make_pctx(mesh: Optional[Mesh], parallel) -> "Any":
    from repro.models.moe import ParallelCtx

    if mesh is None:
        return ParallelCtx()
    names = mesh.axis_names
    if getattr(parallel, "pure_dp", False):
        return ParallelCtx(
            mesh=mesh,
            dp_axes=tuple(a for a in ("pod", "data", "model") if a in names),
            fsdp_axis=None,
            tp_axis=None,
            seq_shard=False,
        )
    dp = tuple(a for a in ("pod", "data") if a in names)
    return ParallelCtx(
        mesh=mesh,
        dp_axes=dp,
        fsdp_axis="data" if (parallel.fsdp and "data" in names) else None,
        tp_axis="model" if "model" in names else None,
        seq_shard=parallel.seq_shard,
    )
