"""Low-overhead serving metrics: counters, gauges, log-bucket histograms.

The registry is **host-side only** — plain Python floats and ints mutated
from the scheduler / front-door bookkeeping loops, never from inside
jitted code — so attaching it cannot change a single decoded token, a
single booked joule, or the compile count of the decode step (the
telemetry-on-vs-off bit-exactness test in ``tests/test_obs.py`` holds the
whole stack to that).  Overhead per observation is one dict lookup plus a
float add (histograms: one ``bisect`` over ~30 bucket bounds), which is
what keeps the gated ``obs_overhead_rel`` ratio at ~1.0.

Design notes:

* **fixed log-spaced buckets** — histograms quantise into geometric bucket
  bounds chosen at *registration* time (default 1 µs .. ~100 s for
  latencies).  Serving latencies span five orders of magnitude between a
  warm decode step and a cold compile, so log buckets hold relative error
  constant where linear buckets would waste every bin on the tail.
* **label sets, not label dicts, on the hot path** — a metric family keyed
  by a tuple of label *values* (the label *names* are fixed per family),
  so the per-observation cost is hashing a small tuple.
* **exposition** — :func:`render_prometheus` emits Prometheus text format
  0.0.4 (``# HELP`` / ``# TYPE`` + samples, histograms as cumulative
  ``_bucket{le=...}`` series with ``+Inf``/``_sum``/``_count``), served by
  ``GET /metrics``; :meth:`MetricsRegistry.snapshot` returns the same
  state as a nested dict for the richer ``GET /stats``.
"""

from __future__ import annotations

import math
import threading
from bisect import bisect_left
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

LabelValues = Tuple[str, ...]

_INF = float("inf")


def log_buckets(lo: float, hi: float, per_decade: int = 3) -> Tuple[float, ...]:
    """Fixed log-spaced histogram bounds covering ``[lo, hi]``.

    ``per_decade`` bounds per power of ten, snapped to a geometric grid
    anchored at ``lo`` — deterministic for a given (lo, hi, per_decade),
    so exposition output is stable across runs and processes."""
    if not (lo > 0 and hi > lo):
        raise ValueError(f"need 0 < lo < hi, got ({lo}, {hi})")
    n = int(math.ceil(math.log10(hi / lo) * per_decade)) + 1
    ratio = 10.0 ** (1.0 / per_decade)
    return tuple(lo * ratio ** i for i in range(n))


# default latency bounds: 1 us .. ~100 s, 3 per decade (~25 buckets) —
# wide enough for a cold jit compile, fine enough for a warm decode step
LATENCY_BUCKETS = log_buckets(1e-6, 100.0)


class Counter:
    """Monotone counter family; label-free fast path is a float add."""

    kind = "counter"

    def __init__(self, name: str, help: str, label_names: Sequence[str] = ()):
        self.name = name
        self.help = help
        self.label_names = tuple(label_names)
        self.series: Dict[LabelValues, float] = {}
        if not self.label_names:
            self.series[()] = 0.0

    def inc(self, amount: float = 1.0, *labels: str) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease")
        if len(labels) != len(self.label_names):
            raise ValueError(
                f"{self.name} takes labels {self.label_names}, got {labels}")
        self.series[labels] = self.series.get(labels, 0.0) + amount

    def value(self, *labels: str) -> float:
        return self.series.get(labels, 0.0)

    def samples(self) -> Iterable[Tuple[str, LabelValues, float]]:
        for labels, v in self.series.items():
            yield self.name, labels, v

    def snapshot(self):
        if not self.label_names:
            return self.series.get((), 0.0)
        return {",".join(k): v for k, v in self.series.items()}


class Gauge(Counter):
    """Set-to-current-value metric (occupancy, depths, clocks, gains)."""

    kind = "gauge"

    def set(self, value: float, *labels: str) -> None:
        if len(labels) != len(self.label_names):
            raise ValueError(
                f"{self.name} takes labels {self.label_names}, got {labels}")
        self.series[labels] = float(value)

    def inc(self, amount: float = 1.0, *labels: str) -> None:
        self.series[labels] = self.series.get(labels, 0.0) + amount

    def dec(self, amount: float = 1.0, *labels: str) -> None:
        self.inc(-amount, *labels)


class _HistogramSeries:
    __slots__ = ("counts", "sum", "count")

    def __init__(self, n_buckets: int):
        self.counts = [0] * n_buckets  # per-bucket (non-cumulative) counts
        self.sum = 0.0
        self.count = 0


class Histogram:
    """Fixed-bucket histogram family (defaults to log-spaced latency bounds).

    ``bounds`` are upper-inclusive bucket edges; observations above the
    last bound land in the implicit ``+Inf`` bucket.  Exposition follows
    the Prometheus cumulative convention."""

    kind = "histogram"

    def __init__(self, name: str, help: str, label_names: Sequence[str] = (),
                 bounds: Sequence[float] = LATENCY_BUCKETS):
        self.name = name
        self.help = help
        self.label_names = tuple(label_names)
        self.bounds = tuple(float(b) for b in bounds)
        if list(self.bounds) != sorted(set(self.bounds)):
            raise ValueError(f"histogram {name} bounds must strictly increase")
        self.series: Dict[LabelValues, _HistogramSeries] = {}
        if not self.label_names:
            self.series[()] = _HistogramSeries(len(self.bounds) + 1)

    def observe(self, value: float, *labels: str) -> None:
        if len(labels) != len(self.label_names):
            raise ValueError(
                f"{self.name} takes labels {self.label_names}, got {labels}")
        s = self.series.get(labels)
        if s is None:
            s = self.series[labels] = _HistogramSeries(len(self.bounds) + 1)
        s.counts[bisect_left(self.bounds, value)] += 1
        s.sum += value
        s.count += 1

    def bucket_counts(self, *labels: str) -> List[int]:
        """Non-cumulative per-bucket counts (last entry = +Inf bucket)."""
        s = self.series.get(labels)
        return list(s.counts) if s else [0] * (len(self.bounds) + 1)

    def snapshot(self):
        def one(s: _HistogramSeries):
            return {"buckets": list(s.counts), "sum": s.sum, "count": s.count,
                    "bounds": list(self.bounds)}
        if not self.label_names:
            return one(self.series[()])
        return {",".join(k): one(s) for k, s in self.series.items()}


class MetricsRegistry:
    """Name -> metric map with get-or-create registration.

    Registration is threadsafe (the front door's pump thread and the
    asyncio loop both create families lazily); per-sample mutation is a
    GIL-atomic dict/float op and deliberately unlocked — a torn read in an
    exposition scrape costs one sample of staleness, never corruption."""

    def __init__(self, namespace: str = "xpike"):
        self.namespace = namespace
        self._metrics: Dict[str, Counter] = {}  # Counter | Gauge | Histogram
        self._lock = threading.Lock()

    def _register(self, cls, name: str, help: str, label_names, **kw):
        full = f"{self.namespace}_{name}" if self.namespace else name
        with self._lock:
            m = self._metrics.get(full)
            if m is None:
                m = self._metrics[full] = cls(full, help, label_names, **kw)
        # compare kinds, not isinstance: Gauge subclasses Counter, so a
        # gauge re-registered as a counter must still be rejected
        if m.kind != cls.kind or m.label_names != tuple(label_names):
            raise ValueError(
                f"metric {full} re-registered as {cls.__name__}"
                f"{tuple(label_names)} (was {type(m).__name__}"
                f"{m.label_names})")
        return m

    def counter(self, name: str, help: str = "",
                label_names: Sequence[str] = ()) -> Counter:
        return self._register(Counter, name, help, label_names)

    def gauge(self, name: str, help: str = "",
              label_names: Sequence[str] = ()) -> Gauge:
        return self._register(Gauge, name, help, label_names)

    def histogram(self, name: str, help: str = "",
                  label_names: Sequence[str] = (),
                  bounds: Sequence[float] = LATENCY_BUCKETS) -> Histogram:
        return self._register(Histogram, name, help, label_names,
                              bounds=bounds)

    def get(self, full_name: str) -> Optional[Counter]:
        return self._metrics.get(full_name)

    def metrics(self) -> List[Counter]:
        with self._lock:
            return [self._metrics[k] for k in sorted(self._metrics)]

    def snapshot(self) -> Dict[str, dict]:
        """Nested plain-dict view of every family (the ``/stats`` payload)."""
        return {m.name: {"kind": m.kind, "help": m.help,
                         "labels": list(m.label_names),
                         "values": m.snapshot()}
                for m in self.metrics()}


def _fmt(v: float) -> str:
    if v == _INF:
        return "+Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _label_str(names: Sequence[str], values: LabelValues,
               extra: Tuple[Tuple[str, str], ...] = ()) -> str:
    pairs = list(zip(names, values)) + list(extra)
    if not pairs:
        return ""
    def esc(s: str) -> str:
        return s.replace("\\", r"\\").replace('"', r'\"').replace("\n", r"\n")
    return "{" + ",".join(f'{k}="{esc(v)}"' for k, v in pairs) + "}"


def render_prometheus(registry: MetricsRegistry) -> str:
    """Prometheus text exposition format 0.0.4 for ``GET /metrics``."""
    lines: List[str] = []
    for m in registry.metrics():
        lines.append(f"# HELP {m.name} {m.help or m.name}")
        lines.append(f"# TYPE {m.name} {m.kind}")
        if isinstance(m, Histogram):
            for labels, s in m.series.items():
                cum = 0
                for bound, c in zip(m.bounds + (_INF,), s.counts):
                    cum += c
                    ls = _label_str(m.label_names, labels,
                                    (("le", _fmt(bound)),))
                    lines.append(f"{m.name}_bucket{ls} {cum}")
                ls = _label_str(m.label_names, labels)
                lines.append(f"{m.name}_sum{ls} {_fmt(s.sum)}")
                lines.append(f"{m.name}_count{ls} {s.count}")
        else:
            for name, labels, v in m.samples():
                lines.append(
                    f"{name}{_label_str(m.label_names, labels)} {_fmt(v)}")
    return "\n".join(lines) + "\n"
