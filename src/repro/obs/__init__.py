"""Serving observability: metrics registry, lifecycle tracing, flight
recorder and profiler hooks.

One :class:`Telemetry` bundle threads through the whole serving stack —
:class:`~repro.serving.BatchScheduler`, the
:class:`~repro.server.FrontDoor` admission layer and the HTTP transport —
so a single object answers "what is this server doing right now":

* :attr:`Telemetry.metrics` — a :class:`MetricsRegistry` of host-side
  counters / gauges / log-bucket histograms, exposed as Prometheus text
  at ``GET /metrics`` and nested into ``GET /stats``;
* :attr:`Telemetry.tracer` — request-lifecycle span events
  (submit→admit→prefill→first-token→decode→preempt/readmit→finish) to
  pluggable sinks (JSONL via ``--trace-out``, Perfetto export);
* :attr:`Telemetry.recorder` — a flight recorder of recent per-slot
  events, dumped to a postmortem file when a scheduler/page-pool
  invariant guard raises;
* :attr:`Telemetry.profiler` — opt-in ``jax.profiler`` capture of N
  decode steps (``--profile-steps`` / ``--profile-dir``).

Everything here is host-side bookkeeping over the scheduler's existing
Python loop: attaching telemetry never touches jitted code, never changes
a decoded token or a booked joule, and never adds a compile — invariants
held by ``tests/test_obs.py`` and the gated ``obs_overhead_rel`` ratio in
``benchmarks/serving_load.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.obs.metrics import (
    LATENCY_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    log_buckets,
    render_prometheus,
)
from repro.obs.profiler import StepProfiler
from repro.obs.recorder import FlightRecorder
from repro.obs.trace import (
    JsonlSink,
    ListSink,
    Tracer,
    load_jsonl,
    perfetto_export,
    write_perfetto,
)


@dataclasses.dataclass
class Telemetry:
    """The telemetry bundle threaded through scheduler / front door / HTTP."""

    metrics: MetricsRegistry
    tracer: Tracer
    recorder: Optional[FlightRecorder] = None
    profiler: Optional[StepProfiler] = None

    @classmethod
    def create(cls, *, flight_dir: str = ".",
               flight_ring: int = 256,
               profiler: Optional[StepProfiler] = None) -> "Telemetry":
        """Standard bundle: registry + tracer + armed flight recorder (the
        recorder listens to the tracer, so guard-site dumps always have
        recent history even when no external trace sink is attached)."""
        recorder = FlightRecorder(ring_size=flight_ring, out_dir=flight_dir)
        tracer = Tracer([recorder])
        return cls(metrics=MetricsRegistry(), tracer=tracer,
                   recorder=recorder, profiler=profiler)

    def trace(self, event: str, **fields) -> None:
        self.tracer.emit(event, **fields)

    def guard_dump(self, reason: str, **extra) -> Optional[str]:
        """Flight-recorder postmortem for an invariant violation (no-op
        without a recorder); returns the dump path."""
        if self.recorder is None:
            return None
        self.trace("guard_violation", reason=reason, **extra)
        return self.recorder.dump(reason, registry=self.metrics,
                                  extra=extra or None)


__all__ = [
    "Counter",
    "FlightRecorder",
    "Gauge",
    "Histogram",
    "JsonlSink",
    "LATENCY_BUCKETS",
    "ListSink",
    "MetricsRegistry",
    "StepProfiler",
    "Telemetry",
    "Tracer",
    "load_jsonl",
    "log_buckets",
    "perfetto_export",
    "render_prometheus",
    "write_perfetto",
]
