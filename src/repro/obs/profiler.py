"""Opt-in ``jax.profiler`` capture of a window of decode steps.

Kernel-level traces of the fused decode megakernel are one flag away:
``launch/serve.py --profile-steps N --profile-dir DIR`` arms a
:class:`StepProfiler` on the scheduler, which starts a ``jax.profiler``
trace right before decode step ``skip`` (default 1 — step 0 is the jit
compile and would bury the steady state under lowering noise) and stops
it ``N`` steps later.  The capture is TensorBoard/Perfetto-compatible
(``tensorboard --logdir DIR`` or load the ``.trace.json.gz`` into
ui.perfetto.dev).

The profiler is pure host-side control flow around the already-compiled
step — arming it cannot recompile or perturb token streams.  Failures to
start a capture (missing profiler backend in a stripped container) are
reported once and disable the hook rather than killing the serve loop:
profiling is observability, not a correctness dependency.
"""

from __future__ import annotations

import sys
from typing import Optional


class StepProfiler:
    """Capture ``[skip, skip + steps)`` decode steps into ``out_dir``."""

    def __init__(self, steps: int, out_dir: str, *, skip: int = 1):
        if steps < 1:
            raise ValueError(f"profile window must be >= 1 step, got {steps}")
        self.steps = steps
        self.out_dir = out_dir
        self.skip = skip
        self._seen = 0
        self._state = "armed"  # armed -> tracing -> done | failed

    @property
    def tracing(self) -> bool:
        return self._state == "tracing"

    @property
    def done(self) -> bool:
        return self._state in ("done", "failed")

    def tick(self) -> None:
        """Call once per completed decode step (after device sync)."""
        if self.done:
            return
        if self._state == "armed" and self._seen == self.skip:
            try:
                import jax

                jax.profiler.start_trace(self.out_dir)
                self._state = "tracing"
                self._t0_step = self._seen
            except Exception as e:  # pragma: no cover - backend-dependent
                self._state = "failed"
                print(f"[obs] jax.profiler capture unavailable: {e}",
                      file=sys.stderr)
        self._seen += 1
        if self._state == "tracing" and self._seen - self._t0_step >= self.steps:
            self.stop()

    def stop(self) -> Optional[str]:
        """Stop an in-flight capture (also called on scheduler drain)."""
        if self._state != "tracing":
            return None
        try:
            import jax

            jax.profiler.stop_trace()
        except Exception as e:  # pragma: no cover - backend-dependent
            print(f"[obs] jax.profiler stop failed: {e}", file=sys.stderr)
        self._state = "done"
        print(f"[obs] captured {self.steps} decode steps to {self.out_dir} "
              "(tensorboard --logdir, or open the .trace.json.gz in "
              "ui.perfetto.dev)", file=sys.stderr)
        return self.out_dir
