"""Flight recorder: bounded rings of recent trace events + postmortem dump.

A long-running server cannot keep (or ship) a full trace, but the moment a
scheduler or page-pool **invariant guard** fires — double free,
use-after-free, evict of an unoccupied slot — the last few hundred events
are exactly what the postmortem needs.  The :class:`FlightRecorder` is a
trace sink (:mod:`repro.obs.trace`) holding fixed-size rings:

* one **global ring** (admissions, decode steps, GDC recalibrations, ...);
* one ring **per slot**, so the history of the slot that tripped the guard
  is not drowned out by the other slots' traffic.

:meth:`FlightRecorder.dump` writes a single JSON postmortem — the rings,
the violation reason, a wall/monotonic timestamp pair and (when a registry
is attached) the full metrics snapshot — and returns the path.  The
scheduler arms its guard sites (``BatchScheduler.evict``,
``PagePool.release``/``retain`` via :attr:`~repro.serving.pages.PagePool.
on_violation`) to dump *before* re-raising, so the exception the test or
operator sees is unchanged but the evidence is already on disk.

Dumping is deliberately idempotent-ish: each dump gets a fresh numbered
file (``flight-<n>-<reason>.json``) so a cascade of guard hits during
teardown cannot overwrite the first — usually the interesting — one.
"""

from __future__ import annotations

import json
import os
import re
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional

from repro.obs.trace import Event


class FlightRecorder:
    """Fixed-size per-slot + global rings of recent trace events."""

    def __init__(self, ring_size: int = 256, per_slot: int = 64,
                 out_dir: str = "."):
        self.ring_size = ring_size
        self.per_slot = per_slot
        self.out_dir = out_dir
        self._global: Deque[Event] = deque(maxlen=ring_size)
        self._slots: Dict[int, Deque[Event]] = {}
        self.dumps: List[str] = []  # paths written so far
        self._n = 0

    # -- sink protocol (Tracer fan-out) ---------------------------------

    def __call__(self, ev: Event) -> None:
        self.record(ev)

    def record(self, ev: Event) -> None:
        self._global.append(ev)
        slot = ev.get("slot")
        if slot is not None:
            ring = self._slots.get(slot)
            if ring is None:
                ring = self._slots[slot] = deque(maxlen=self.per_slot)
            ring.append(ev)

    # -- postmortem ------------------------------------------------------

    def dump(self, reason: str, *, registry=None,
             extra: Optional[Dict[str, Any]] = None) -> str:
        """Write a postmortem JSON for ``reason``; returns its path."""
        slug = re.sub(r"[^A-Za-z0-9_.-]+", "_", reason)[:48] or "guard"
        self._n += 1
        path = os.path.join(self.out_dir, f"flight-{self._n}-{slug}.json")
        payload: Dict[str, Any] = {
            "reason": reason,
            "ts": time.time(),
            "mono": time.perf_counter(),
            "events": list(self._global),
            "slots": {str(k): list(v) for k, v in self._slots.items()},
        }
        if registry is not None:
            payload["metrics"] = registry.snapshot()
        if extra:
            payload["extra"] = extra
        os.makedirs(self.out_dir or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(payload, f, default=str)
        self.dumps.append(path)
        return path

    def events(self, slot: Optional[int] = None) -> List[Event]:
        if slot is None:
            return list(self._global)
        return list(self._slots.get(slot, ()))
