"""Structured request-lifecycle tracing for the serving stack.

Every interesting transition in a request's life — ``submit`` → ``admit``
→ ``prefill_chunk``\\* → ``first_token`` → ``decode``\\* →
(``preempt`` → ``readmit``)\\* → ``finish`` — is emitted as one
:class:`TraceEvent`, stamped with both the wall clock (``ts``, epoch
seconds: correlation with external logs) and the monotonic clock
(``mono``, ``time.perf_counter()``: all duration math).  The scheduler
additionally emits slot-level events (``evict``, ``gdc_recal``) so a
trace reconstructs exactly what the batch was doing at any step.

Events flow to a **pluggable sink**: any callable taking one event dict.
:class:`JsonlSink` appends one JSON object per line (the ``--trace-out``
flag on ``launch/serve.py``); the flight recorder
(:mod:`repro.obs.recorder`) is just another sink holding per-slot rings.
The tracer itself never blocks the serving loop on I/O policy — a sink
that wants buffering brings its own.

:func:`perfetto_export` converts a list of events to the Chrome/Perfetto
``trace_event`` JSON format: per-request tracks (``tid`` = request id)
with complete spans for the queued / running phases derived from the
lifecycle pairs, and instant events for everything else — load the file
straight into ``ui.perfetto.dev``.
"""

from __future__ import annotations

import json
import time
from typing import Any, Callable, Dict, IO, List, Optional, Union

Event = Dict[str, Any]
Sink = Callable[[Event], None]

# canonical lifecycle event names (the trace schema, see README)
SUBMIT = "submit"
ADMIT = "admit"
READMIT = "readmit"
PREFILL_CHUNK = "prefill_chunk"
HANDOFF = "handoff"
FIRST_TOKEN = "first_token"
DECODE = "decode"
PREEMPT = "preempt"
EVICT = "evict"
FINISH = "finish"
GDC_RECAL = "gdc_recal"
GUARD = "guard_violation"

LIFECYCLE = (SUBMIT, ADMIT, READMIT, PREFILL_CHUNK, HANDOFF, FIRST_TOKEN,
             DECODE, PREEMPT, EVICT, FINISH, GDC_RECAL, GUARD)


class Tracer:
    """Fan-out event emitter; each event is a plain dict.

    Fields: ``event`` (one of :data:`LIFECYCLE` or caller-defined), ``ts``
    (wall epoch s), ``mono`` (perf_counter s), plus whatever keyword
    fields the call site attaches (``rid``, ``slot``, ``tenant``,
    ``step``, ...).  With no sinks attached :meth:`emit` is a no-op
    after one truthiness check, so an always-constructed tracer costs
    nothing until someone listens."""

    def __init__(self, sinks: Optional[List[Sink]] = None):
        self._sinks: List[Sink] = list(sinks or [])

    def add_sink(self, sink: Sink) -> None:
        self._sinks.append(sink)

    def remove_sink(self, sink: Sink) -> None:
        self._sinks.remove(sink)

    @property
    def active(self) -> bool:
        return bool(self._sinks)

    def emit(self, event: str, **fields) -> None:
        if not self._sinks:
            return
        ev: Event = {"event": event, "ts": time.time(),
                     "mono": time.perf_counter()}
        ev.update(fields)
        for sink in self._sinks:
            sink(ev)


class JsonlSink:
    """Append events to a JSONL file (one compact JSON object per line)."""

    def __init__(self, path_or_file: Union[str, IO[str]]):
        if isinstance(path_or_file, str):
            self._f: IO[str] = open(path_or_file, "a")
            self._owned = True
        else:
            self._f = path_or_file
            self._owned = False

    def __call__(self, ev: Event) -> None:
        self._f.write(json.dumps(ev, separators=(",", ":"),
                                 default=_jsonable) + "\n")

    def close(self) -> None:
        self._f.flush()
        if self._owned:
            self._f.close()


class ListSink:
    """Keep events in a plain list (tests, Perfetto export buffers)."""

    def __init__(self):
        self.events: List[Event] = []

    def __call__(self, ev: Event) -> None:
        self.events.append(ev)


def _jsonable(x):
    try:
        return float(x)  # numpy scalars and friends
    except (TypeError, ValueError):
        return str(x)


def load_jsonl(path: str) -> List[Event]:
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def _track(ev: Event) -> int:
    """Perfetto track id for an event: the request when known, else the
    slot (scheduler housekeeping), else track 0."""
    for key in ("fid", "rid"):
        if ev.get(key) is not None:
            return int(ev[key])
    if ev.get("slot") is not None:
        return 100000 + int(ev["slot"])
    return 0


def perfetto_export(events: List[Event]) -> Dict[str, Any]:
    """Chrome/Perfetto ``trace_event`` JSON from a list of trace events.

    Derives per-request complete spans (``ph: "X"``) for the *queued*
    (submit→admit) and *running* (admit→preempt|finish) phases and emits
    every event as an instant (``ph: "i"``) on its request's track, all
    on the monotonic timebase (µs)."""
    out: List[Dict[str, Any]] = []
    if not events:
        return {"traceEvents": [], "displayTimeUnit": "ms"}
    t0 = min(ev["mono"] for ev in events)

    def us(ev: Event) -> float:
        return (ev["mono"] - t0) * 1e6

    open_phase: Dict[int, Event] = {}  # track -> phase-opening event
    spans = {SUBMIT: "queued", ADMIT: "running", READMIT: "running"}
    closers = {ADMIT, READMIT, PREEMPT, FINISH}
    for ev in sorted(events, key=lambda e: e["mono"]):
        tid = _track(ev)
        name = ev["event"]
        if name in closers and tid in open_phase:
            start = open_phase.pop(tid)
            out.append({
                "name": spans[start["event"]], "ph": "X", "pid": 1,
                "tid": tid, "ts": us(start), "dur": us(ev) - us(start),
            })
        if name in spans:
            open_phase[tid] = ev
        args = {k: v for k, v in ev.items()
                if k not in ("event", "ts", "mono")
                and isinstance(v, (int, float, str, bool))}
        out.append({"name": name, "ph": "i", "s": "t", "pid": 1, "tid": tid,
                    "ts": us(ev), "args": args})
    # close dangling phases at the last event so the spans render
    t_end = max(us(ev) for ev in events)
    for tid, start in open_phase.items():
        out.append({"name": spans[start["event"]], "ph": "X", "pid": 1,
                    "tid": tid, "ts": us(start),
                    "dur": max(t_end - us(start), 0.0)})
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_perfetto(events: List[Event], path: str) -> None:
    with open(path, "w") as f:
        json.dump(perfetto_export(events), f)
