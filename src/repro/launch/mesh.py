"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state.  The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else (tests, benches) sees the real single CPU device.

Mesh semantics:
  pod    — inter-pod data parallelism (DCN); gradients all-reduce here.
  data   — intra-pod data parallelism + FSDP weight shard (ZeRO-3).
  model  — tensor / sequence / expert parallelism (ICI minor axis).
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = int(np.prod(shape))
    have = len(jax.devices())
    if have == ndev:
        return jax.make_mesh(shape, axes)
    if have > ndev:  # e.g. 512 forced host devices, single-pod 256 mesh
        from jax.sharding import Mesh

        devs = np.array(jax.devices()[:ndev]).reshape(shape)
        return Mesh(devs, axes)
    raise RuntimeError(
        f"need {ndev} devices for mesh {shape}, have {have}; run under "
        "XLA_FLAGS=--xla_force_host_platform_device_count=512 (dryrun.py sets this)"
    )


def parse_mesh_spec(spec: str) -> Tuple[int, int]:
    """Parse a ``--mesh`` CLI value into a (data, model) shape.

    Accepts ``"2x4"`` / ``"2,4"`` (explicit shape), a single integer
    (``"4"`` = data-parallel only), or ``"auto"`` (all visible devices on
    the data axis)."""
    import jax

    s = spec.strip().lower()
    if s in ("auto", ""):
        return (len(jax.devices()), 1)
    parts = [p for p in s.replace("x", ",").split(",") if p]
    dims = tuple(int(p) for p in parts)
    if len(dims) == 1:
        return (dims[0], 1)
    if len(dims) != 2:
        raise ValueError(
            f"--mesh wants 'DATAxMODEL' (e.g. 2x4), got {spec!r}")
    return dims  # type: ignore[return-value]


def make_serving_mesh(shape: Tuple[int, int]):
    """A ``(data, model)`` mesh over the first data*model visible devices.

    The serving mesh of ``repro.distributed``: ``data`` partitions decode
    slots (data-parallel continuous batching), ``model`` partitions heads /
    crossbar columns (tensor-parallel spiking kernels).  Use
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to emulate an
    N-device host mesh on CPU."""
    import jax
    from jax.sharding import Mesh

    ndev = int(np.prod(shape))
    have = len(jax.devices())
    if have < ndev:
        raise RuntimeError(
            f"mesh {shape} needs {ndev} devices, have {have}; run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={ndev}"
        )
    devs = np.array(jax.devices()[:ndev]).reshape(shape)
    return Mesh(devs, ("data", "model"))


def make_test_mesh(shape: Tuple[int, ...] = (1, 1), axes: Optional[Tuple[str, ...]] = None):
    """Tiny mesh (defaults (1,1) data/model) for CPU tests: gives shard_map
    its axis names without needing multiple devices."""
    import jax
    from jax.sharding import Mesh

    axes = axes or (("pod", "data", "model")[-len(shape):])
    ndev = int(np.prod(shape))
    devs = np.array(jax.devices()[:ndev]).reshape(shape)
    return Mesh(devs, axes)
