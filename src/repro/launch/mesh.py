"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state.  The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else (tests, benches) sees the real single CPU device.

Mesh semantics:
  pod    — inter-pod data parallelism (DCN); gradients all-reduce here.
  data   — intra-pod data parallelism + FSDP weight shard (ZeRO-3).
  model  — tensor / sequence / expert parallelism (ICI minor axis).
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = int(np.prod(shape))
    have = len(jax.devices())
    if have == ndev:
        return jax.make_mesh(shape, axes)
    if have > ndev:  # e.g. 512 forced host devices, single-pod 256 mesh
        from jax.sharding import Mesh

        devs = np.array(jax.devices()[:ndev]).reshape(shape)
        return Mesh(devs, axes)
    raise RuntimeError(
        f"need {ndev} devices for mesh {shape}, have {have}; run under "
        "XLA_FLAGS=--xla_force_host_platform_device_count=512 (dryrun.py sets this)"
    )


def make_test_mesh(shape: Tuple[int, ...] = (1, 1), axes: Optional[Tuple[str, ...]] = None):
    """Tiny mesh (defaults (1,1) data/model) for CPU tests: gives shard_map
    its axis names without needing multiple devices."""
    import jax
    from jax.sharding import Mesh

    axes = axes or (("pod", "data", "model")[-len(shape):])
    ndev = int(np.prod(shape))
    devs = np.array(jax.devices()[:ndev]).reshape(shape)
    return Mesh(devs, axes)
