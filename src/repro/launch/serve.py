"""Batched serving driver: continuous-batching decode loop.

    python -m repro.launch.serve --arch yi-9b --requests 8

A miniature vLLM-style loop over the framework's ``prefill`` +
``decode_step``: requests arrive with different prompt lengths, get
prefilled into per-slot KV caches, then a single fused ``decode_step``
advances every active slot each iteration; finished slots are refilled
from the queue (continuous batching).  Greedy sampling.
"""

from __future__ import annotations

import argparse
import time
from typing import List

import jax
import jax.numpy as jnp

from repro.configs.base import ParallelConfig
from repro.configs.registry import get_config, reduced_config
from repro.models import transformer as T
from repro.models.moe import ParallelCtx
from repro.launch.mesh import make_test_mesh
from repro.parallel import sharding as SH


def serve(
    arch: str,
    *,
    smoke: bool = True,
    n_requests: int = 8,
    slots: int = 4,
    max_new: int = 16,
    cache_len: int = 64,
    seed: int = 0,
):
    cfg = reduced_config(arch) if smoke else get_config(arch)
    if cfg.frontend != "none":
        print(f"[serve] {arch} is a {cfg.family} backbone; serving over stub embeddings")
    if cfg.spiking:
        print(f"[serve] {arch} is a spiking arch; decode serves its rate "
              "(ANN-equivalent) network — spike-train decode has no KV-cache path")
    mesh = make_test_mesh((1, 1))
    parallel = ParallelConfig(moe_impl="ep_a2a" if cfg.is_moe else "dense")
    pctx = SH.make_pctx(mesh, parallel)
    key = jax.random.PRNGKey(seed)
    params = T.init_params(key, cfg)

    step = lambda p, c, t: T.decode_step(p, c, t, cfg, pctx, moe_impl=parallel.moe_impl)
    decode = jax.jit(step)  # batched over all slots
    decode1 = jax.jit(step)  # batch-1 prefill trace (separate shape cache)

    # request queue: random prompts of varying length
    rng = jax.random.PRNGKey(seed + 1)
    queue: List[jnp.ndarray] = [
        jax.random.randint(jax.random.fold_in(rng, i), (int(4 + 3 * (i % 4)),), 0,
                           cfg.vocab_size, jnp.int32)
        for i in range(n_requests)
    ]
    cache = T.init_cache(cfg, slots, cache_len)
    tokens = jnp.zeros((slots, 1), jnp.int32)
    remaining = [0] * slots
    outputs: List[List[int]] = []
    slot_out: List[List[int]] = [[] for _ in range(slots)]
    served = 0
    t0 = time.time()
    decoded_tokens = 0

    def assign_slot(full, one, slot):
        """Write a batch-1 cache into slot ``slot`` of the batched cache.

        Period-stacked leaves are [n_periods, batch, ...]; remainder leaves
        are [batch, ...].  Per-slot ``pos`` counters make this sound: the
        new request resumes from its own prefill position while the other
        slots keep decoding at theirs."""
        out = {}
        if "periods" in full:
            out["periods"] = jax.tree.map(
                lambda f, o: f.at[:, slot].set(o[:, 0]), full["periods"], one["periods"]
            )
        if "remainder" in full:
            out["remainder"] = jax.tree.map(
                lambda f, o: f.at[slot].set(o[0]), full["remainder"], one["remainder"]
            )
        return out

    def feed(slot):
        nonlocal tokens, cache
        prompt = queue.pop(0)
        # prefill: step the whole prompt context through a batch-1 cache,
        # then splice it into this slot (a production server would lower a
        # batched prefill kernel; the cache/positions logic is identical)
        c1 = T.init_cache(cfg, 1, cache_len)
        for tok in prompt[:-1]:
            _, c1 = decode1(params, c1, jnp.full((1, 1), int(tok), jnp.int32))
        cache = assign_slot(cache, c1, slot)
        tokens = tokens.at[slot, 0].set(int(prompt[-1]))
        return int(len(prompt))

    for s in range(slots):
        if queue:
            remaining[s] = max_new
            feed(s)

    while any(r > 0 for r in remaining):
        logits, cache = decode(params, cache, tokens)
        nxt = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)
        tokens = nxt[:, None]
        decoded_tokens += sum(1 for r in remaining if r > 0)
        for s in range(slots):
            if remaining[s] > 0:
                slot_out[s].append(int(nxt[s]))
                remaining[s] -= 1
                if remaining[s] == 0:
                    outputs.append(slot_out[s])
                    slot_out[s] = []
                    served += 1
                    if queue:
                        remaining[s] = max_new
                        feed(s)
    dt = time.time() - t0
    print(f"[serve] served {served} requests, {decoded_tokens} tokens in {dt:.2f}s "
          f"({decoded_tokens/max(dt,1e-9):.1f} tok/s)")
    return outputs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    a = ap.parse_args(argv)
    serve(a.arch, n_requests=a.requests, slots=a.slots, max_new=a.max_new)


if __name__ == "__main__":
    main()
