"""Serving launcher: continuous-batching inference over any --arch.

    python -m repro.launch.serve --arch yi-9b --requests 8
    python -m repro.launch.serve --arch xpikeformer-gpt-4-256 --backend pallas
    python -m repro.launch.serve --arch xpikeformer-gpt-4-256 --paged \\
        --page-len 8                            # paged spike-train KV cache
    python -m repro.launch.serve --arch xpikeformer-gpt-4-256 --program \\
        --drift-step 60 --recal-every 3600      # PCM lifecycle + energy
    python -m repro.launch.serve --arch xpikeformer-gpt-4-256 --http \\
        --port 8000                             # HTTP/SSE front door
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
    python -m repro.launch.serve --arch xpikeformer-gpt-4-256 \\
        --backend pallas --mesh 2x4             # (data, model) mesh serving

Thin CLI over the ``repro.serving`` subsystem: a :class:`~repro.serving.
BatchScheduler` splices requests into free slots mid-flight (continuous
batching), keeps per-slot state in a :class:`~repro.serving.DecodeState`
pytree, and advances every slot with one jit-compiled batched
``decode_step``.  Spiking SSA archs decode through the engine's pluggable
backend (reference / integer / pallas) over spike-train KV caches; all
other archs use the conventional float KV / recurrent-state path.  Greedy
sampling.

``--mesh DATAxMODEL`` places the whole stack on a (data, model) mesh via
:class:`repro.distributed.Executor`: decode slots are data-parallel,
spiking linears / SSA attention run tensor-parallel over ``model``
(bit-exact vs single-device on the integer/pallas backends).

``--program`` programs the spiking-linear weights onto simulated PCM
(:mod:`repro.aimc_device`) before serving; ``--drift-step`` /
``--recal-every`` set the device-seconds-per-decode-step and GDC
recalibration interval of the drift lifecycle (0 = wall clock / never).
Per-request energy (measured spike events x Table-II op energies) prints
with the serve summary.

``--http`` runs the :mod:`repro.server` front door instead of synthetic
requests: ``POST /generate`` streams tokens over SSE through the same
scheduler (admission control, per-tenant energy budgets, ``GET /stats``,
``GET /metrics`` Prometheus exposition), until Ctrl-C; the serve summary
(tok/s, J/token) prints on shutdown.

Observability (:mod:`repro.obs`): ``--trace-out events.jsonl`` appends
request-lifecycle trace events (submit/admit/prefill-chunk/first-token/
decode/preempt/finish, one JSON object per line — convert with
``repro.obs.perfetto_export`` for ``ui.perfetto.dev``);
``--profile-steps N`` captures N decode steps with ``jax.profiler`` into
``--profile-dir``.  Both are opt-in and host-side only: telemetry never
changes a decoded token, a booked joule, or the compile count.
"""

from __future__ import annotations

import argparse
import time
from typing import List

import jax
import jax.numpy as jnp

from repro import aimc_device as AD
from repro.configs.base import ParallelConfig
from repro.configs.registry import get_config, reduced_config
from repro.engine import get_backend
from repro.launch.mesh import make_serving_mesh, make_test_mesh, parse_mesh_spec
from repro.models import transformer as T
from repro.obs import JsonlSink, StepProfiler, Telemetry
from repro.parallel import sharding as SH
from repro.serving import BatchScheduler


def serve(
    arch: str,
    *,
    smoke: bool = True,
    n_requests: int = 8,
    slots: int = 4,
    max_new: int = 16,
    cache_len: int = 64,
    seed: int = 0,
    backend: str = "reference",
    program: bool = False,
    drift_step_s: float = 0.0,
    recal_every_s: float = 0.0,
    mesh_spec: str = "",
    paged: bool = False,
    page_len: int = 8,
    n_pages: int = 0,
    decode_kernel: str = "auto",
    http: bool = False,
    host: str = "127.0.0.1",
    port: int = 8000,
    trace_out: str = "",
    profile_steps: int = 0,
    profile_dir: str = "/tmp/xpike-profile",
):
    """Serve ``n_requests`` synthetic prompts; returns their outputs in
    submission order (continuous batching: a finished slot is refilled from
    the queue without draining the batch)."""
    cfg = reduced_config(arch) if smoke else get_config(arch)
    if cfg.frontend != "none":
        print(f"[serve] {arch} is a {cfg.family} backbone; serving over stub embeddings")
    if cfg.spiking and cfg.attention_kind == "ssa":
        print(f"[serve] {arch} decodes through the '{backend}' backend over "
              "spike-train KV caches (SSA serving path)")
    params = T.init_params(jax.random.PRNGKey(seed), cfg)

    drift = None
    if program:
        if not (cfg.spiking and cfg.attention_kind == "ssa"):
            raise SystemExit(f"--program needs a spiking SSA arch, not {arch}")
        params = AD.program_lm_tree(jax.random.PRNGKey(seed + 42), params,
                                    AD.AIMCConfig())
        drift = AD.DriftPolicy(seconds_per_step=drift_step_s,
                               recal_interval_s=recal_every_s)
        print(f"[serve] programmed spiking linears onto PCM "
              f"(drift {drift_step_s or 'wall-clock'} s/step, "
              f"GDC every {recal_every_s or 'never'} s)")

    paged_kw = dict(paged=paged, page_len=page_len,
                    n_pages=n_pages or None, decode_kernel=decode_kernel)
    if paged:
        print(f"[serve] paged spike-train KV cache: page_len={page_len}, "
              f"pool={n_pages or slots * (cache_len // page_len) + 2} pages, "
              "exact prefix sharing + chunked prefill")
    if mesh_spec:
        from repro.distributed import Executor

        shape = parse_mesh_spec(mesh_spec)
        mesh = make_serving_mesh(shape)
        ex = Executor(params, cfg, get_backend(backend), mesh)
        sch = ex.scheduler(slots=slots, cache_len=cache_len, drift=drift,
                           **paged_kw)
        print(f"[serve] mesh (data={shape[0]}, model={shape[1]}): "
              f"slots data-parallel, spiking kernels tensor-parallel "
              f"(TP {'on' if ex.plan.tp > 1 else 'off'})")
    else:
        mesh = make_test_mesh((1, 1))
        parallel = ParallelConfig(moe_impl="ep_a2a" if cfg.is_moe else "dense")
        pctx = SH.make_pctx(mesh, parallel)
        sch = BatchScheduler(
            params, cfg, get_backend(backend), slots=slots, cache_len=cache_len,
            pctx=pctx, moe_impl=parallel.moe_impl, drift=drift, **paged_kw,
        )
    if sch.plan is not None:
        print(f"[serve] decode kernel: {sch.plan.describe()}")

    # telemetry bundle: metrics registry + tracer + flight recorder, plus
    # the opt-in JSONL trace sink and jax.profiler window.  Host-side only
    # — attaching it never recompiles or changes a token.
    profiler = None
    if profile_steps > 0:
        profiler = StepProfiler(profile_steps, profile_dir)
        print(f"[serve] profiling {profile_steps} decode steps -> "
              f"{profile_dir} (jax.profiler)")
    obs = Telemetry.create(profiler=profiler)
    if trace_out:
        obs.tracer.add_sink(JsonlSink(trace_out))
        print(f"[serve] tracing request lifecycle -> {trace_out} (JSONL)")
    sch.attach_obs(obs)
    if http:
        _serve_http(sch, host=host, port=port)
        return []
    rng = jax.random.PRNGKey(seed + 1)
    prompts: List[jnp.ndarray] = [
        jax.random.randint(jax.random.fold_in(rng, i), (int(4 + 3 * (i % 4)),), 0,
                           cfg.vocab_size, jnp.int32)
        for i in range(n_requests)
    ]
    rids = [sch.submit(p, max_new, seed=seed + i) for i, p in enumerate(prompts)]
    t0 = time.perf_counter()  # duration: monotonic, not wall clock
    outs = sch.run()
    dt = time.perf_counter() - t0
    st = sch.stats
    print(f"[serve] served {st.requests} requests, {st.decoded_tokens} tokens "
          f"in {dt:.2f}s ({st.tokens_per_sec:.1f} tok/s, "
          f"{st.decode_steps} batched decode steps, {st.admissions} admissions)")
    if paged:
        print(f"[serve] pages: peak {st.pages_in_use_peak} in use, "
              f"{st.prefix_hits} prefix hits ({st.prefix_hit_tokens} prompt "
              f"tokens reused), {st.cow_copies} copy-on-writes, "
              f"peak {st.peak_active_slots} concurrent slots")
    if st.energy_j > 0:
        print(f"[serve] energy: {st.energy_j*1e6:.2f} uJ total "
              f"({st.j_per_token*1e9:.1f} nJ/token, "
              f"{st.spike_events:.0f} spike events)")
        worst = max(sch.request_energy_j.items(), key=lambda kv: kv[1])
        print(f"[serve] per-request energy: max rid={worst[0]} "
              f"{worst[1]*1e9:.1f} nJ")
    if program:
        print(f"[serve] device clock t={st.t_device_s:.1f}s, "
              f"{st.recalibrations} GDC recalibrations")
    return [outs[r] for r in rids]


def _serve_http(sch: BatchScheduler, *, host: str, port: int) -> None:
    """Run the async HTTP/SSE front door over an already-built scheduler
    until interrupted, then print the usual serve summary."""
    import asyncio

    from repro.server import FrontDoor, HttpFrontDoor

    async def _run():
        srv = HttpFrontDoor(FrontDoor(sch), host=host, port=port)
        await srv.start()
        print(f"[serve] HTTP front door on http://{srv.host}:{srv.port} "
              "(POST /generate, GET /stats, GET /metrics, GET /healthz); "
              "Ctrl-C to stop", flush=True)
        try:
            await srv._server.serve_forever()
        except asyncio.CancelledError:
            pass
        finally:
            await srv.stop()

    t0 = time.perf_counter()  # duration: monotonic, not wall clock
    try:
        asyncio.run(_run())
    except KeyboardInterrupt:
        pass
    st = sch.stats
    st.wall_s += time.perf_counter() - t0
    print(f"\n[serve] served {st.requests} requests, {st.decoded_tokens} "
          f"tokens ({st.tokens_per_sec:.1f} tok/s, {st.decode_steps} batched "
          f"decode steps, {st.admissions} admissions)")
    if st.energy_j > 0:
        print(f"[serve] energy: {st.energy_j*1e6:.2f} uJ total "
              f"({st.j_per_token*1e9:.1f} nJ/token, "
              f"{st.spike_events:.0f} spike events)")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--backend", default="reference",
                    choices=["reference", "integer", "pallas"])
    ap.add_argument("--mesh", default="",
                    help="serve on a (data, model) mesh, e.g. 2x4 or 4 "
                         "(data-parallel only); needs data*model devices")
    ap.add_argument("--paged", action="store_true", default=False,
                    help="block-paged spike-train KV cache (spiking SSA "
                         "archs): exact prefix sharing + chunked prefill")
    ap.add_argument("--page-len", type=int, default=8,
                    help="tokens per KV page (--paged)")
    ap.add_argument("--pages", type=int, default=0,
                    help="physical page-pool size (--paged; 0 = slots x "
                         "cache_len / page_len + reserved)")
    ap.add_argument("--decode-kernel", default="auto",
                    choices=["auto", "fused", "unfused"],
                    help="decode kernel strategy: 'fused' = one megakernel "
                         "launch per decoder layer (spiking SSA attention "
                         "stacks on the integer/pallas backends); 'auto' "
                         "picks fused where supported")
    ap.add_argument("--http", action="store_true", default=False,
                    help="serve over HTTP/SSE (POST /generate streams "
                         "tokens) instead of running synthetic requests")
    ap.add_argument("--trace-out", default="",
                    help="append request-lifecycle trace events to this "
                         "JSONL file (repro.obs; perfetto_export converts "
                         "it for ui.perfetto.dev)")
    ap.add_argument("--profile-steps", type=int, default=0,
                    help="capture this many decode steps with jax.profiler "
                         "(0 = off)")
    ap.add_argument("--profile-dir", default="/tmp/xpike-profile",
                    help="jax.profiler trace output directory "
                         "(--profile-steps)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8000)
    ap.add_argument("--full", dest="smoke", action="store_false", default=True)
    ap.add_argument("--program", action="store_true", default=False,
                    help="program spiking linears onto simulated PCM first")
    ap.add_argument("--drift-step", type=float, default=0.0,
                    help="device seconds per decode step (0 = wall clock)")
    ap.add_argument("--recal-every", type=float, default=0.0,
                    help="GDC recalibration interval in device seconds (0 = never)")
    a = ap.parse_args(argv)
    serve(a.arch, smoke=a.smoke, n_requests=a.requests, slots=a.slots,
          max_new=a.max_new, cache_len=a.cache_len, backend=a.backend,
          program=a.program, drift_step_s=a.drift_step,
          recal_every_s=a.recal_every, mesh_spec=a.mesh, paged=a.paged,
          page_len=a.page_len, n_pages=a.pages, decode_kernel=a.decode_kernel,
          http=a.http, host=a.host, port=a.port, trace_out=a.trace_out,
          profile_steps=a.profile_steps, profile_dir=a.profile_dir)


if __name__ == "__main__":
    main()
