"""Batched serving driver: continuous-batching decode loop.

    python -m repro.launch.serve --arch yi-9b --requests 8

A miniature vLLM-style loop over the framework's ``prefill`` +
``decode_step``: requests arrive with different prompt lengths, get
prefilled into per-slot KV caches, then a single fused ``decode_step``
advances every active slot each iteration; finished slots are refilled
from the queue (continuous batching).  Greedy sampling.
"""

from __future__ import annotations

import argparse
import time
from typing import List

import jax
import jax.numpy as jnp

from repro.configs.base import ParallelConfig
from repro.configs.registry import get_config, reduced_config
from repro.models import transformer as T
from repro.models.moe import ParallelCtx
from repro.launch.mesh import make_test_mesh
from repro.parallel import sharding as SH


def serve(
    arch: str,
    *,
    smoke: bool = True,
    n_requests: int = 8,
    slots: int = 4,
    max_new: int = 16,
    cache_len: int = 64,
    seed: int = 0,
):
    cfg = reduced_config(arch) if smoke else get_config(arch)
    if cfg.frontend != "none":
        print(f"[serve] {arch} is a {cfg.family} backbone; serving over stub embeddings")
    mesh = make_test_mesh((1, 1))
    parallel = ParallelConfig(moe_impl="ep_a2a" if cfg.is_moe else "dense")
    pctx = SH.make_pctx(mesh, parallel)
    key = jax.random.PRNGKey(seed)
    params = T.init_params(key, cfg)

    decode = jax.jit(
        lambda p, c, t: T.decode_step(p, c, t, cfg, pctx, moe_impl=parallel.moe_impl)
    )

    # request queue: random prompts of varying length
    rng = jax.random.PRNGKey(seed + 1)
    queue: List[jnp.ndarray] = [
        jax.random.randint(jax.random.fold_in(rng, i), (int(4 + 3 * (i % 4)),), 0,
                           cfg.vocab_size, jnp.int32)
        for i in range(n_requests)
    ]
    cache = T.init_cache(cfg, slots, cache_len)
    tokens = jnp.zeros((slots, 1), jnp.int32)
    remaining = [0] * slots
    outputs: List[List[int]] = []
    slot_out: List[List[int]] = [[] for _ in range(slots)]
    served = 0
    t0 = time.time()
    decoded_tokens = 0

    def feed(slot):
        nonlocal tokens
        prompt = queue.pop(0)
        # prefill by stepping the prompt through decode (per-slot cache slice
        # keeps this simple; a production server lowers a batched prefill)
        for tok in prompt[:-1]:
            pass  # prompt context beyond the last token is dropped in smoke mode
        tokens = tokens.at[slot, 0].set(int(prompt[-1]))
        return int(len(prompt))

    for s in range(slots):
        if queue:
            remaining[s] = max_new
            feed(s)

    while any(r > 0 for r in remaining):
        logits, cache = decode(params, cache, tokens)
        nxt = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)
        tokens = nxt[:, None]
        decoded_tokens += sum(1 for r in remaining if r > 0)
        for s in range(slots):
            if remaining[s] > 0:
                slot_out[s].append(int(nxt[s]))
                remaining[s] -= 1
                if remaining[s] == 0:
                    outputs.append(slot_out[s])
                    slot_out[s] = []
                    served += 1
                    if queue:
                        remaining[s] = max_new
                        feed(s)
    dt = time.time() - t0
    print(f"[serve] served {served} requests, {decoded_tokens} tokens in {dt:.2f}s "
          f"({decoded_tokens/max(dt,1e-9):.1f} tok/s)")
    return outputs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    a = ap.parse_args(argv)
    serve(a.arch, n_requests=a.requests, slots=a.slots, max_new=a.max_new)


if __name__ == "__main__":
    main()
