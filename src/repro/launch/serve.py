"""Serving launcher: continuous-batching inference over any --arch.

    python -m repro.launch.serve --arch yi-9b --requests 8
    python -m repro.launch.serve --arch xpikeformer-gpt-4-256 --backend pallas

Thin CLI over the ``repro.serving`` subsystem: a :class:`~repro.serving.
BatchScheduler` splices requests into free slots mid-flight (continuous
batching), keeps per-slot state in a :class:`~repro.serving.DecodeState`
pytree, and advances every slot with one jit-compiled batched
``decode_step``.  Spiking SSA archs decode through the engine's pluggable
backend (reference / integer / pallas) over spike-train KV caches; all
other archs use the conventional float KV / recurrent-state path.  Greedy
sampling.
"""

from __future__ import annotations

import argparse
import time
from typing import List

import jax
import jax.numpy as jnp

from repro.configs.base import ParallelConfig
from repro.configs.registry import get_config, reduced_config
from repro.engine import get_backend
from repro.launch.mesh import make_test_mesh
from repro.models import transformer as T
from repro.parallel import sharding as SH
from repro.serving import BatchScheduler


def serve(
    arch: str,
    *,
    smoke: bool = True,
    n_requests: int = 8,
    slots: int = 4,
    max_new: int = 16,
    cache_len: int = 64,
    seed: int = 0,
    backend: str = "reference",
):
    """Serve ``n_requests`` synthetic prompts; returns their outputs in
    submission order (continuous batching: a finished slot is refilled from
    the queue without draining the batch)."""
    cfg = reduced_config(arch) if smoke else get_config(arch)
    if cfg.frontend != "none":
        print(f"[serve] {arch} is a {cfg.family} backbone; serving over stub embeddings")
    if cfg.spiking and cfg.attention_kind == "ssa":
        print(f"[serve] {arch} decodes through the '{backend}' backend over "
              "spike-train KV caches (SSA serving path)")
    mesh = make_test_mesh((1, 1))
    parallel = ParallelConfig(moe_impl="ep_a2a" if cfg.is_moe else "dense")
    pctx = SH.make_pctx(mesh, parallel)
    params = T.init_params(jax.random.PRNGKey(seed), cfg)

    sch = BatchScheduler(
        params, cfg, get_backend(backend), slots=slots, cache_len=cache_len,
        pctx=pctx, moe_impl=parallel.moe_impl,
    )
    rng = jax.random.PRNGKey(seed + 1)
    prompts: List[jnp.ndarray] = [
        jax.random.randint(jax.random.fold_in(rng, i), (int(4 + 3 * (i % 4)),), 0,
                           cfg.vocab_size, jnp.int32)
        for i in range(n_requests)
    ]
    rids = [sch.submit(p, max_new, seed=seed + i) for i, p in enumerate(prompts)]
    t0 = time.time()
    outs = sch.run()
    dt = time.time() - t0
    st = sch.stats
    print(f"[serve] served {st.requests} requests, {st.decoded_tokens} tokens "
          f"in {dt:.2f}s ({st.decoded_tokens/max(dt,1e-9):.1f} tok/s, "
          f"{st.decode_steps} batched decode steps, {st.admissions} admissions)")
    return [outs[r] for r in rids]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--backend", default="reference",
                    choices=["reference", "integer", "pallas"])
    ap.add_argument("--full", dest="smoke", action="store_false", default=True)
    a = ap.parse_args(argv)
    serve(a.arch, smoke=a.smoke, n_requests=a.requests, slots=a.slots,
          max_new=a.max_new, cache_len=a.cache_len, backend=a.backend)


if __name__ == "__main__":
    main()
