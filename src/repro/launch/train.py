"""Production training launcher with fault tolerance.

    python -m repro.launch.train --arch yi-9b --smoke --steps 50

Features exercised here (and by tests/test_fault_tolerance.py):

* **checkpoint/restart** — periodic async checkpoints; on start the latest
  checkpoint is restored and the data pipeline *seeks* to the restored
  step (batches are a pure function of step, so the replay is exact).
* **elastic restart** — ``--mesh`` may differ between runs; restore
  re-device_puts with the new mesh's sharding plan (launch/elastic.py).
* **failure injection** — ``--fail-at k`` raises mid-run to prove restart
  correctness; the test asserts loss curves with/without the crash match.
* **straggler mitigation** — per-step wall-clock watchdog: steps slower
  than ``--straggler-factor`` x the trailing median are logged and counted;
  at scale the same hook triggers backup-worker reassignment (single-host
  here, so the action is the report + a re-dispatch of the same step,
  which is safe because steps are pure functions).
"""

from __future__ import annotations

import argparse
import statistics
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs.base import ParallelConfig
from repro.configs.registry import get_config, reduced_config
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, MarkovStream
from repro.launch import elastic
from repro.launch.mesh import make_test_mesh
from repro.models.moe import ParallelCtx
from repro.optim import adamw as A
from repro.parallel import sharding as SH
from repro.train import loop as TL


def run(
    arch: str,
    *,
    smoke: bool = True,
    steps: int = 50,
    batch: int = 8,
    seq_len: int = 32,
    ckpt_dir: str = "/tmp/repro_ckpt",
    ckpt_every: int = 20,
    fail_at: int = -1,
    restore: bool = True,
    mesh_shape=(1, 1),
    straggler_factor: float = 3.0,
    seed: int = 0,
    log_every: int = 10,
    lr: float = 1e-3,
    probe: bool = False,
):
    """Train ``arch`` for ``steps``; returns the per-step loss list.

    With ``probe=True`` returns ``(losses, probe_before, probe_after)``
    where the probes are the loss on a *fixed* batch (step 0's) with a
    fixed rng, evaluated before and after training.  Per-step losses are
    each measured on a fresh batch, so for smoke configs whose init sits
    near the stream's entropy floor (tied-embedding archs start
    calibrated) the first-vs-last comparison is dominated by inter-batch
    noise — the fixed-batch probe isolates the optimization signal.
    """
    cfg = reduced_config(arch) if smoke else get_config(arch)
    mesh = make_test_mesh(tuple(mesh_shape))
    parallel = ParallelConfig(moe_impl="ep_a2a" if cfg.is_moe else "dense", remat="none")
    pctx = SH.make_pctx(mesh, parallel)
    opt = A.AdamWConfig(lr=lr, warmup_steps=max(steps // 10, 1), total_steps=steps)

    data = MarkovStream(DataConfig(cfg.vocab_size, seq_len, batch, seed=seed))
    mgr = CheckpointManager(ckpt_dir, keep=2)

    key = jax.random.PRNGKey(seed)
    params, opt_state = TL.init_state(key, cfg, opt, parallel)
    start_step = 0
    if restore and mgr.latest_step() is not None:
        pshard, oshard = elastic.state_shardings(cfg, mesh, opt, fsdp=parallel.fsdp)
        (params, opt_state), start_step = mgr.restore(
            (params, opt_state), shardings=(pshard, oshard)
        )
        print(f"[train] restored step {start_step} from {ckpt_dir}", flush=True)

    step_fn = jax.jit(TL.make_train_step(cfg, pctx, parallel, opt))

    probe_fn = None
    probe_before = None
    if probe:
        from repro.models import transformer as T

        probe_batch = data.batch_at(0)
        probe_rng = jax.random.PRNGKey(seed + 555)
        probe_fn = jax.jit(
            lambda p: T.loss_fn(p, probe_batch, cfg, pctx, moe_impl=parallel.moe_impl,
                                remat="none", rng=probe_rng)[0]
        )
        probe_before = float(probe_fn(params))

    times, losses, stragglers = [], [], 0
    for step in range(start_step, steps):
        if step == fail_at:
            mgr.wait()
            raise RuntimeError(f"injected failure at step {step}")
        t0 = time.time()
        batch_data = data.batch_at(step)
        rng = jax.random.fold_in(jax.random.PRNGKey(seed + 99), step)
        params, opt_state, metrics = step_fn(params, opt_state, batch_data, rng)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        times.append(dt)
        losses.append(loss)
        if len(times) > 5:
            med = statistics.median(times[-20:])
            if dt > straggler_factor * med:
                stragglers += 1
                print(f"[train] straggler: step {step} took {dt:.2f}s "
                      f"(median {med:.2f}s) — re-dispatch hook", flush=True)
        if log_every and step % log_every == 0:
            print(f"[train] step {step:5d} loss {loss:.4f} ({dt*1e3:.0f} ms)", flush=True)
        if ckpt_every and (step + 1) % ckpt_every == 0:
            mgr.save(step + 1, (params, opt_state))
    mgr.save(steps, (params, opt_state), blocking=True)
    print(f"[train] done: final loss {losses[-1]:.4f}, stragglers {stragglers}", flush=True)
    if probe:
        probe_after = float(probe_fn(params))
        print(f"[train] probe loss {probe_before:.4f} -> {probe_after:.4f}", flush=True)
        return losses, probe_before, probe_after
    return losses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--fail-at", type=int, default=-1)
    ap.add_argument("--no-restore", dest="restore", action="store_false")
    ap.add_argument("--mesh", type=int, nargs="+", default=[1, 1])
    ap.add_argument("--seed", type=int, default=0)
    a = ap.parse_args(argv)
    run(
        a.arch, smoke=a.smoke, steps=a.steps, batch=a.batch, seq_len=a.seq_len,
        ckpt_dir=a.ckpt_dir, ckpt_every=a.ckpt_every, fail_at=a.fail_at,
        restore=a.restore, mesh_shape=tuple(a.mesh), seed=a.seed,
    )


if __name__ == "__main__":
    main()
