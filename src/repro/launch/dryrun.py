"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. lowers the real ``train_step`` / ``prefill`` / ``serve_step`` with
     abstract (ShapeDtypeStruct) params, optimizer state, batch and cache —
     no device allocation,
  3. compiles it (SPMD partitioning for 256/512 devices),
  4. records ``memory_analysis()`` (proves it fits 16 GB/chip HBM),
     ``cost_analysis()`` (FLOPs/bytes for the roofline), and the collective
     bytes parsed from the post-partitioning HLO,
  5. writes a JSON record to ``experiments/dryrun/<cell>.json``.

Run one cell:   python -m repro.launch.dryrun --arch yi-9b --shape train_4k --mesh single
Run the grid:   python -m repro.launch.dryrun --all          (subprocess per cell)
"""

import argparse
import dataclasses
import json
import os
import re
import subprocess
import sys
import time
from pathlib import Path

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# TPU v5e-class hardware constants (per chip)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str, default_group: int):
    """Per-device wire bytes by collective kind (ring-algorithm estimates)."""
    out = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    counts = dict.fromkeys(out, 0)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(shape_str)
        g = default_group
        mg = _GROUPS_RE.search(line)
        if mg:
            g = int(mg.group(2))
        else:
            mb = _GROUPS_BRACE_RE.search(line)
            if mb:
                g = len(mb.group(1).split(","))
        g = max(g, 2)
        if kind == "all-gather":
            wire = nbytes * (g - 1) / g  # output is the gathered buffer
        elif kind == "all-reduce":
            wire = 2.0 * nbytes * (g - 1) / g
        elif kind == "reduce-scatter":
            wire = nbytes * (g - 1)  # output is the scattered shard
        elif kind == "all-to-all":
            wire = nbytes * (g - 1) / g
        else:  # collective-permute
            wire = nbytes
        out[kind] += wire
        counts[kind] += 1
    return out, counts


def model_flops(cfg, shape, n_params: int, n_active: int) -> float:
    tokens = shape.global_batch * (shape.seq_len if shape.mode != "decode" else 1)
    mult = 6.0 if shape.mode == "train" else 2.0
    n = n_active if cfg.is_moe else n_params
    return mult * n * tokens


def count_params(abstract_params) -> int:
    import jax

    return int(sum(x.size for x in jax.tree.leaves(abstract_params)))


def count_active_params(cfg, abstract_params) -> int:
    """MoE: replace the expert bank by top_k/E of it."""
    import jax

    total = 0
    # jax.tree.flatten_with_path is jax >= 0.5; fall back to tree_util
    flatten_with_path = getattr(jax.tree, "flatten_with_path",
                                jax.tree_util.tree_flatten_with_path)
    flat = flatten_with_path(abstract_params)[0]
    for path, leaf in flat:
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        frac = 1.0
        if cfg.is_moe and "moe" in keys and any(k in ("wi", "wg", "wo") for k in keys):
            frac = cfg.moe_top_k / cfg.num_experts
        total += leaf.size * frac
    return int(total)


# ---------------------------------------------------------------------------


def _lower_cell(cfg, shape, mesh, parallel, *, opt_dtype: str):
    """Lower+compile one model variant; returns (compiled, lower_s, compile_s)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.data.pipeline import abstract_batch, abstract_inputs
    from repro.models import transformer as T
    from repro.optim import adamw as A
    from repro.parallel import sharding as SH
    from repro.train import loop as TL

    pctx = SH.make_pctx(mesh, parallel)
    pure_dp = getattr(parallel, "pure_dp", False)
    pspecs = SH.param_pspecs(cfg, mesh, fsdp=parallel.fsdp, pure_dp=pure_dp)
    pshard = SH.to_shardings(pspecs, mesh)
    aparams = T.abstract_params(cfg)
    fd = cfg.frontend_dim if cfg.frontend != "none" else 0

    def batch_shardings(ab):
        bspec = SH.batch_pspec(mesh, shape.global_batch, pure_dp=pure_dp)
        return jax.tree.map(
            lambda s: NamedSharding(mesh, P(bspec)), ab
        )

    t0 = time.time()
    if shape.mode == "train":
        opt = A.AdamWConfig(state_dtype=opt_dtype)
        astate = A.abstract_opt_state(aparams, opt)
        ospecs = A.opt_state_pspecs(pspecs, aparams, opt)
        oshard = SH.to_shardings(ospecs, mesh)
        abatch = abstract_batch(cfg.vocab_size, shape.global_batch, shape.seq_len,
                                frontend_dim=fd)
        arng = jax.ShapeDtypeStruct((2,), jnp.uint32)
        step_fn = TL.make_train_step(cfg, pctx, parallel, opt)
        jf = jax.jit(
            step_fn,
            in_shardings=(pshard, oshard, batch_shardings(abatch),
                          NamedSharding(mesh, P())),
            out_shardings=(pshard, oshard, None),
            donate_argnums=(0, 1),
        )
        lowered = jf.lower(aparams, astate, abatch, arng)
    elif shape.mode == "prefill":
        abatch = abstract_inputs(shape.global_batch, shape.seq_len, frontend_dim=fd)

        def prefill_fn(params, batch):
            # spiking archs need an rng for Bernoulli coding; a constant key
            # is fine for lowering/measurement (it constant-folds)
            rng = jax.random.PRNGKey(0) if cfg.spiking else None
            logits, _ = T.forward(params, batch, cfg, pctx,
                                  moe_impl=parallel.moe_impl, remat="none",
                                  rng=rng)
            return logits

        jf = jax.jit(prefill_fn, in_shardings=(pshard, batch_shardings(abatch)))
        lowered = jf.lower(aparams, abatch)
    else:  # decode
        acache = T.cache_schema(cfg, shape.global_batch, shape.seq_len)
        cshard = SH.to_shardings(
            SH.cache_pspecs(cfg, mesh, shape.global_batch, shape.seq_len), mesh
        )
        atok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        tshard = NamedSharding(mesh, SH.tokens_pspec(mesh, shape.global_batch))

        def serve_step(params, cache, tokens):
            return T.decode_step(params, cache, tokens, cfg, pctx,
                                 moe_impl=parallel.moe_impl)

        jf = jax.jit(serve_step, in_shardings=(pshard, cshard, tshard),
                     out_shardings=(None, cshard), donate_argnums=(1,))
        lowered = jf.lower(aparams, acache, atok)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    return compiled, t_lower, time.time() - t0


def _costs_of(compiled, chips):
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0] if cost else {}
    coll, counts = parse_collectives(compiled.as_text(), chips)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": coll,
        "counts": counts,
    }


def run_cell(arch: str, shape_name: str, mesh_kind: str, *, variant: str = "base") -> dict:
    import dataclasses as dc

    import jax

    from repro.configs.base import SHAPES, shape_applicable
    from repro.configs.registry import get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.tuning import model_for, parallel_for
    from repro.models import layers as L
    from repro.models import transformer as T

    cfg = model_for(get_config(arch), variant=variant)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"cell": f"{arch}:{shape_name}:{mesh_kind}", "skipped": why}

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = int(mesh.devices.size)
    parallel = parallel_for(cfg, shape, variant=variant)

    # ---- pass A: deployable (scanned) model — compile proof + memory ----
    compiled, t_lower, t_compile = _lower_cell(cfg, shape, mesh, parallel,
                                               opt_dtype=parallel.opt_state_dtype)
    mem = compiled.memory_analysis()

    # ---- passes B/C/D: cost extrapolation (XLA counts scan bodies once) --
    # B: depth-0 (fixed costs: embed/unembed/loss/optimizer-of-embeddings)
    # C: one-period scanned        -> per-period HBM bytes (flash-like)
    # D: one-period EXACT mode     -> per-period flops + collective bytes
    #    (unrolled, einsum attention, unchunked optimizer: exact HLO counts)
    ratio = cfg.num_layers / cfg.period
    cfg0 = dc.replace(cfg, num_layers=0)
    cfg1 = dc.replace(cfg, num_layers=cfg.period)
    cB, _, _ = _lower_cell(cfg0, shape, mesh, parallel,
                           opt_dtype=parallel.opt_state_dtype)
    costB = _costs_of(cB, chips)
    cC, _, _ = _lower_cell(cfg1, shape, mesh, parallel,
                           opt_dtype=parallel.opt_state_dtype)
    costC = _costs_of(cC, chips)
    L.EXACT_FLOPS_MODE = True
    try:
        cD, _, _ = _lower_cell(cfg1, shape, mesh, parallel,
                               opt_dtype=parallel.opt_state_dtype)
        costD = _costs_of(cD, chips)
    finally:
        L.EXACT_FLOPS_MODE = False

    flops_dev = costB["flops"] + ratio * (costD["flops"] - costB["flops"])
    bytes_dev = costB["bytes"] + ratio * (costC["bytes"] - costB["bytes"])
    coll = {
        k: costB["coll"][k] + ratio * (costD["coll"][k] - costB["coll"][k])
        for k in costB["coll"]
    }
    coll = {k: max(v, 0.0) for k, v in coll.items()}
    coll_counts = costD["counts"]
    coll_bytes_dev = float(sum(coll.values()))

    aparams = T.abstract_params(cfg)
    n_params = count_params(aparams)
    n_active = count_active_params(cfg, aparams)
    mflops = model_flops(cfg, shape, n_params, n_active)

    t_comp = flops_dev / PEAK_FLOPS
    t_mem = bytes_dev / HBM_BW
    t_coll = coll_bytes_dev / ICI_BW
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    bottleneck = max(terms, key=terms.get)

    mem_info = {}
    if mem is not None:
        for attr in (
            "temp_size_in_bytes", "argument_size_in_bytes", "output_size_in_bytes",
            "alias_size_in_bytes", "generated_code_size_in_bytes",
        ):
            v = getattr(mem, attr, None)
            if v is not None:
                mem_info[attr] = int(v)

    rec = {
        "cell": f"{arch}:{shape_name}:{mesh_kind}",
        "variant": variant,
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "chips": chips,
        "mode": shape.mode,
        "parallel": dataclasses.asdict(parallel),
        "n_params": n_params,
        "n_active_params": n_active,
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll,
        "collective_counts": coll_counts,
        "collective_bytes_total_per_device": coll_bytes_dev,
        "model_flops_global": mflops,
        "hlo_flops_global": flops_dev * chips,
        "useful_flops_ratio": mflops / max(flops_dev * chips, 1.0),
        "roofline_terms_s": terms,
        "bottleneck": bottleneck,
        "roofline_step_time_s": max(terms.values()),
        "memory_analysis": mem_info,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
    }
    return rec


# ---------------------------------------------------------------------------


def _cell_path(arch, shape, mesh, variant="base") -> Path:
    tag = f"{arch}__{shape}__{mesh}" + ("" if variant == "base" else f"__{variant}")
    return OUT_DIR / f"{tag}.json"


def _force_host_devices() -> None:
    """Fake 512 host devices so production meshes build on CPU.

    Must run before the first (lazy, in-function) jax import; every jax
    touch in this module happens after main() calls this.  Respects an
    externally set XLA_FLAGS so real-accelerator runs are not clobbered.
    The ``--all`` grid re-invokes this module per cell via subprocess, so
    each child sets it for itself too.
    """
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=512")


def main(argv=None):
    _force_host_devices()
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--variant", default="base", help="perf-tuning variant tag")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--meshes", default="single,multi")
    args = ap.parse_args(argv)

    OUT_DIR.mkdir(parents=True, exist_ok=True)

    if args.all:
        from repro.configs.registry import ARCHS, cells

        todo = []
        for cfg, shape, ok, why in cells(include_skipped=True):
            for mesh in args.meshes.split(","):
                p = _cell_path(cfg.name, shape.name, mesh, args.variant)
                if p.exists() and not args.force:
                    continue
                if not ok:
                    p.write_text(json.dumps(
                        {"cell": f"{cfg.name}:{shape.name}:{mesh}", "skipped": why},
                        indent=1))
                    continue
                todo.append((cfg.name, shape.name, mesh))
        print(f"{len(todo)} cells to compile", flush=True)
        failures = 0
        for arch, shape, mesh in todo:
            cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
                   "--shape", shape, "--mesh", mesh, "--variant", args.variant]
            print(f"--- {arch}:{shape}:{mesh}", flush=True)
            r = subprocess.run(cmd, capture_output=True, text=True)
            if r.returncode != 0:
                failures += 1
                print(f"FAIL {arch}:{shape}:{mesh}\n{r.stdout[-2000:]}\n{r.stderr[-4000:]}",
                      flush=True)
            else:
                print(r.stdout.strip().splitlines()[-1] if r.stdout.strip() else "ok",
                      flush=True)
        print(f"done; {failures} failures", flush=True)
        return 1 if failures else 0

    rec = run_cell(args.arch, args.shape, args.mesh, variant=args.variant)
    p = _cell_path(args.arch, args.shape, args.mesh, args.variant)
    p.write_text(json.dumps(rec, indent=1))
    if "skipped" in rec:
        print(f"SKIP {rec['cell']}: {rec['skipped']}")
    else:
        print(
            f"OK {rec['cell']} compile={rec['compile_s']}s "
            f"bottleneck={rec['bottleneck']} step={rec['roofline_step_time_s']:.4f}s "
            f"mem_temp={rec['memory_analysis'].get('temp_size_in_bytes', 0)/2**30:.2f}GiB"
        )
    return 0


if __name__ == "__main__":
    sys.exit(main())
