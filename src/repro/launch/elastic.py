"""Elastic scaling: recompute the sharding plan for a changed mesh.

When a restart comes up with a different device count (node failures, or
scale-up), the checkpoint (saved unsharded, see checkpoint/manager.py) is
restored with shardings computed *for the new mesh*.  Because all layouts
derive from the logical-axis rules in parallel/sharding.py, the plan is a
pure function of (config, mesh): dims that no longer divide the new axis
sizes fall back to replication automatically.

``resharding_plan`` additionally reports what changed, for operator logs.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh

from repro.optim import adamw as A
from repro.parallel import sharding as SH
from repro.models import transformer as T


def state_shardings(cfg, mesh: Optional[Mesh], opt: A.AdamWConfig, *, fsdp: bool = True):
    """(param shardings, opt-state shardings) for a given mesh."""
    pspecs = SH.param_pspecs(cfg, mesh, fsdp=fsdp)
    aparams = T.abstract_params(cfg)
    ospecs = A.opt_state_pspecs(pspecs, aparams, opt)
    return SH.to_shardings(pspecs, mesh), SH.to_shardings(ospecs, mesh)


def resharding_plan(cfg, old_mesh: Mesh, new_mesh: Mesh, *, fsdp: bool = True) -> Dict[str, Any]:
    """Diff the param layouts between two meshes (for logging/validation)."""
    old = SH.param_pspecs(cfg, old_mesh, fsdp=fsdp)
    new = SH.param_pspecs(cfg, new_mesh, fsdp=fsdp)
    changed = []
    flat_old = jax.tree_util.tree_flatten_with_path(old)[0]
    flat_new = jax.tree.leaves(new)
    for (path, o), n in zip(flat_old, flat_new):
        if tuple(o) != tuple(n):
            changed.append({"param": jax.tree_util.keystr(path), "old": str(o), "new": str(n)})
    return {
        "old_mesh": dict(zip(old_mesh.axis_names, old_mesh.devices.shape)),
        "new_mesh": dict(zip(new_mesh.axis_names, new_mesh.devices.shape)),
        "n_params_relaid": len(changed),
        "changes": changed[:32],
    }
