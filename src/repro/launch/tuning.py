"""Per-(arch, shape) parallelism tuning table.

``parallel_for`` returns the ParallelConfig used by the dry-run and the
launcher.  The ``variant`` tag selects perf-hillclimb configurations so
§Perf iterations are reproducible cells side by side with the baselines.
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig


def parallel_for(cfg: ModelConfig, shape: ShapeConfig, *, variant: str = "base") -> ParallelConfig:
    p = ParallelConfig()
    # arctic-480b: 469B params; int8 Adam moments + full remat are what fit
    # the optimizer on 256 chips (see EXPERIMENTS.md §Dry-run).
    if cfg.name == "arctic-480b":
        p = dataclasses.replace(p, opt_state_dtype="int8", remat="full")
    if cfg.name == "qwen2.5-32b" and shape.mode == "train":
        p = dataclasses.replace(p, remat="block")
    if not cfg.is_moe:
        p = dataclasses.replace(p, moe_impl="dense")

    # ---- hillclimb variants (referenced from EXPERIMENTS.md §Perf) ----
    for tag in variant.split("+"):
        if tag in ("base", ""):
            continue
        elif tag == "prod":
            # shipped production layout = the §Perf hillclimb winners:
            #  * small models (<2B): pure data parallelism (HC1, 3.5x)
            #  * big dense train: int8 Adam + bf16 grads + full remat (HC3)
            #  * MoE decode token-gather is automatic in models/moe.py (HC2)
            n_est = cfg.num_layers * cfg.d_model * cfg.d_model * (
                12 if not cfg.is_moe else 4 + 3 * cfg.num_experts * cfg.d_ff / cfg.d_model
            )
            if n_est < 2e9:
                p = dataclasses.replace(p, pure_dp=True, fsdp=False, seq_shard=False,
                                        grad_dtype="bfloat16", remat="full")
            elif shape.mode == "train":
                p = dataclasses.replace(p, grad_dtype="bfloat16", remat="full",
                                        opt_state_dtype="int8")
        elif tag == "noseq":
            p = dataclasses.replace(p, seq_shard=False)
        elif tag == "nofsdp":
            p = dataclasses.replace(p, fsdp=False)
        elif tag == "remat_none":
            p = dataclasses.replace(p, remat="none")
        elif tag == "remat_full":
            p = dataclasses.replace(p, remat="full")
        elif tag.startswith("mb"):
            p = dataclasses.replace(p, microbatches=int(tag[2:]))
        elif tag == "gradcomp":
            p = dataclasses.replace(p, grad_compression=True)
        elif tag == "opt8":
            p = dataclasses.replace(p, opt_state_dtype="int8")
        elif tag == "optbf16":
            p = dataclasses.replace(p, opt_state_dtype="bfloat16")
        elif tag == "gradbf16":
            p = dataclasses.replace(p, grad_dtype="bfloat16")
        elif tag == "moetok":
            pass  # label-only: records the auto token-gather MoE strategy
        elif tag == "puredp":
            p = dataclasses.replace(p, pure_dp=True, fsdp=False, seq_shard=False)
        elif tag.startswith("chunk"):
            pass  # model-level tag, handled by model_for()
        else:
            raise ValueError(f"unknown variant tag {tag!r}")
    return p


def model_for(cfg: ModelConfig, *, variant: str = "base") -> ModelConfig:
    """Model-level hillclimb overrides (e.g. SSD chunk length)."""
    for tag in variant.split("+"):
        if tag.startswith("chunk") and tag != "chunk":
            cfg = dataclasses.replace(cfg, ssm_chunk=int(tag[5:]))
    return cfg
