"""Async serving front door over :class:`repro.serving.BatchScheduler`.

The :class:`FrontDoor` is the production admission layer between transport
(HTTP/SSE, :mod:`repro.server.http`, or a driver like
``benchmarks/serving_load.py``) and the synchronous continuous-batching
scheduler:

* requests arrive on the event loop (:meth:`FrontDoor.submit`) and are
  queued per tenant;
* one **pump** iteration at a time runs in a worker thread — it applies
  the :class:`~repro.server.admission.AdmissionController`'s decisions
  (priority + token-fairness pick, energy throttling, preemption,
  :class:`~repro.serving.pages.PagePool` backpressure), feeds the
  scheduler, runs one batched ``decode_step``, and streams freshly decoded
  tokens back to per-request :class:`asyncio.Queue`\\ s;
* the scheduler itself is only ever touched from the pump thread, so the
  whole async layer adds **no nondeterminism to token values**: each
  request's stream is the scheduler's pure f(params, prompt, seed) token
  sequence, independent of arrival interleaving, batching, throttling or
  preemption — the differential-test oracle (tests/test_server.py) holds
  the HTTP path to bit-exactness against a direct in-process run.

**Preemption / re-admission.**  When a tenant overruns its joule bucket
mid-flight, its running requests are evicted
(:meth:`repro.serving.BatchScheduler.preempt`) and parked back at the head
of the tenant queue.  On re-admission the request is *resubmitted from its
prompt with the same seed*: purity regenerates the identical token prefix,
the front door replays it silently (asserting bit-equality with what was
already streamed) and the client stream resumes where it left off.  The
replayed decode's extra joules are charged to the tenant — preemption is
not free, and the meter says exactly what it cost.
"""

from __future__ import annotations

import asyncio
import dataclasses
import threading
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Sequence

import numpy as np

from repro.obs import Telemetry
from repro.obs import trace as TR
from repro.server import admission as ADM
from repro.server.admission import AdmissionController, TenantPolicy

PENDING, RUNNING, DONE, FAILED = "pending", "running", "done", "failed"

# all front-door interval math runs on the monotonic clock: TTFT, latency,
# token gaps and bucket-refill deltas must never jump with NTP/wall-clock
# slew.  Wall-clock time.time() survives only where an *epoch timestamp*
# is wanted (trace events stamp both, see repro.obs.trace).
_now = time.perf_counter


class QueueFull(RuntimeError):
    """The front door's pending queue is at capacity (HTTP 429)."""


@dataclasses.dataclass
class RequestResult:
    """Terminal record for one request, attached to its stream."""

    request_id: int
    tenant: str
    tokens: List[int]
    energy_j: float  # metered joules booked to this request (replays incl.)
    ttft_s: float  # submit -> first streamed token
    latency_s: float  # submit -> last token
    preemptions: int
    token_times: List[float]  # monotonic stamp each token was streamed


@dataclasses.dataclass
class _FrontRequest:
    fid: int
    tenant: str
    prompt: np.ndarray
    max_new: int
    seed: int
    q: "asyncio.Queue[Optional[int]]"
    state: str = PENDING
    rid: Optional[int] = None  # current scheduler rid (changes on preempt)
    served: int = 0  # tokens of the CURRENT rid's output processed
    streamed: int = 0  # tokens actually delivered to the client
    tokens: List[int] = dataclasses.field(default_factory=list)
    token_times: List[float] = dataclasses.field(default_factory=list)
    energy_j: float = 0.0
    charged_j: float = 0.0  # energy booked for the current rid so far
    preemptions: int = 0
    new_since_admit: int = 0  # NEW tokens streamed in this admission streak
    last_defer: str = ""  # dedup tag so defer records log transitions only
    t_submit: float = 0.0
    t_first: Optional[float] = None
    t_done: Optional[float] = None
    result: Optional[RequestResult] = None
    error: Optional[str] = None


class TokenStream:
    """Async iterator over one request's generated token ids."""

    def __init__(self, req: _FrontRequest):
        self._req = req

    @property
    def request_id(self) -> int:
        return self._req.fid

    @property
    def result(self) -> Optional[RequestResult]:
        """The terminal :class:`RequestResult` (None until the stream ends)."""
        return self._req.result

    def __aiter__(self) -> "TokenStream":
        return self

    async def __anext__(self) -> int:
        tok = await self._req.q.get()
        if tok is None:
            if self._req.error:
                raise RuntimeError(self._req.error)
            raise StopAsyncIteration
        return tok

    async def tokens(self) -> List[int]:
        """Drain the stream to completion and return all token ids."""
        return [t async for t in self]


class FrontDoor:
    """Asyncio admission layer feeding one :class:`BatchScheduler`.

    ``policies`` maps tenant name -> :class:`TenantPolicy` (unknown tenants
    get ``default_policy``).  ``max_queue`` bounds pending requests across
    all tenants — beyond it :meth:`submit` raises :class:`QueueFull`
    (HTTP 429), the load-shedding backstop above the PagePool/slot
    backpressure that merely *defers*.
    """

    def __init__(
        self,
        scheduler,
        *,
        policies: Optional[Dict[str, TenantPolicy]] = None,
        default_policy: Optional[TenantPolicy] = None,
        max_queue: int = 256,
        idle_s: float = 0.002,
        telemetry: Optional[Telemetry] = None,
        enable_telemetry: bool = True,
    ):
        self.sch = scheduler
        self.adm = AdmissionController(policies, default_policy)
        self.max_queue = max_queue
        self.idle_s = idle_s
        # one Telemetry bundle spans the whole stack: the scheduler's if it
        # already has one, else ``telemetry``, else a fresh default bundle
        # (enable_telemetry=False opts out entirely — the overhead
        # benchmark's baseline leg, see benchmarks/serving_load.py)
        self.obs: Optional[Telemetry] = None
        if enable_telemetry:
            self.obs = (getattr(scheduler, "obs", None) or telemetry
                        or Telemetry.create())
            if getattr(scheduler, "obs", None) is None:
                scheduler.attach_obs(self.obs)
            self.adm.bind_metrics(self.obs.metrics)
            m = self.obs.metrics
            self._h_ttft = m.histogram(
                "ttft_seconds", "submit to first streamed token")
            self._h_tpot = m.histogram(
                "tpot_seconds", "gap between consecutive streamed tokens")
            self._g_pending = m.gauge(
                "frontdoor_queue_depth", "requests pending admission")
            self._g_running = m.gauge(
                "frontdoor_running", "requests holding a scheduler slot")
            self._c_requests = m.counter(
                "frontdoor_requests_total", "terminal request outcomes",
                ("outcome",))
            self._c_preempt = m.counter(
                "frontdoor_preemptions_total", "energy-SLO preemptions")
            self._g_credit = m.gauge(
                "tenant_energy_credit_joules",
                "joule token-bucket level per metered tenant", ("tenant",))
        self._intake: Deque[_FrontRequest] = deque()  # loop -> pump handoff
        self._pending: Dict[str, Deque[_FrontRequest]] = {}
        self._running: Dict[int, _FrontRequest] = {}  # scheduler rid -> req
        self._requests: Dict[int, _FrontRequest] = {}  # fid -> req (all)
        self._results: List[RequestResult] = []
        self._next_fid = 0
        self._pending_count = 0  # intake + per-tenant queues (loop-side gate)
        self._last_refill = _now()
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._task: Optional[asyncio.Task] = None
        self._stopping = False
        self._lock = threading.Lock()  # guards _pending_count across threads
        self.completed = 0
        self.failed = 0
        self.preemptions = 0

    # -- event-loop side ------------------------------------------------

    async def start(self) -> None:
        assert self._task is None, "front door already started"
        self._loop = asyncio.get_running_loop()
        self._stopping = False
        self._task = self._loop.create_task(self._run())

    async def stop(self) -> None:
        """Stop the pump; outstanding streams are failed with 'shutdown'."""
        self._stopping = True
        if self._task is not None:
            await self._task
            self._task = None

    async def submit(self, prompt: Sequence[int], max_new: int, *,
                     seed: Optional[int] = None,
                     tenant: str = "default") -> TokenStream:
        """Queue a request; returns its :class:`TokenStream`.

        ``seed`` fixes the request's spike-PRN stream (defaults to the
        front-door request id) — the same (prompt, seed) streams the same
        tokens no matter how admission interleaves it.  Raises
        :class:`ValueError` on an unservable request (bad shape, exceeds
        ``cache_len`` or the page pool) and :class:`QueueFull` at capacity.
        """
        prompt_np = np.asarray(list(prompt), np.int32)
        if prompt_np.ndim != 1 or prompt_np.shape[0] < 1:
            raise ValueError("prompt must be a non-empty 1-D token list")
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        sch = self.sch
        if prompt_np.shape[0] + max_new > sch.cache_len:
            raise ValueError(
                f"prompt ({prompt_np.shape[0]}) + max_new ({max_new}) "
                f"exceeds cache_len ({sch.cache_len})")
        if sch.paged:
            worst = self._worst_pages(prompt_np.shape[0], max_new)
            usable = sch.n_pages - self._reserved_pages()
            if worst > usable:
                raise ValueError(
                    f"request needs up to {worst} pages but the pool only "
                    f"has {usable} usable — it could never be admitted")
        with self._lock:
            if self._pending_count >= self.max_queue:
                fid = self._next_fid  # not consumed: the request is shed
                self.adm.record(fid, tenant, ADM.DEFER_QUEUE,
                                f"pending={self._pending_count}")
                raise QueueFull(
                    f"{self._pending_count} requests pending (max_queue="
                    f"{self.max_queue})")
            fid = self._next_fid
            self._next_fid += 1
            self._pending_count += 1
        req = _FrontRequest(
            fid=fid, tenant=tenant, prompt=prompt_np, max_new=max_new,
            seed=fid if seed is None else seed, q=asyncio.Queue())
        req.t_submit = _now()
        self._requests[fid] = req
        self._intake.append(req)
        if self.obs is not None:
            self.obs.trace(TR.SUBMIT, fid=fid, tenant=tenant,
                           prompt_len=int(prompt_np.shape[0]),
                           max_new=max_new)
        return TokenStream(req)

    # -- introspection --------------------------------------------------

    @property
    def results(self) -> List[RequestResult]:
        return list(self._results)

    def stats_dict(self) -> Dict[str, Any]:
        """Aggregate serving stats for ``GET /stats``: scheduler
        :class:`~repro.serving.ServeStats` + front-door admission state."""
        st = self.sch.stats
        sched = {f.name: getattr(st, f.name)
                 for f in dataclasses.fields(st)}
        sched["tokens_per_sec"] = st.tokens_per_sec
        sched["j_per_token"] = st.j_per_token
        tenants = {
            name: {
                "priority": t.policy.priority,
                "weight": t.policy.weight,
                "energy_budget_j": t.policy.energy_budget_j,
                "credit_j": (None if t.policy.energy_budget_j is None
                             else t.credit_j),
                "spent_j": t.spent_j,
                "spent_tokens": t.spent_tokens,
                "inflight": t.inflight,
            }
            for name, t in self.adm.tenants.items()
        }
        out = {
            "scheduler": sched,
            "tenants": tenants,
            "pending": self._pending_count,
            "running": len(self._running),
            "completed": self.completed,
            "failed": self.failed,
            "preemptions": self.preemptions,
            "decisions": [dataclasses.asdict(r)
                          for r in list(self.adm.records)[-64:]],
        }
        if self.obs is not None:
            # the full registry snapshot nests under "metrics" — the same
            # families GET /metrics exposes, as structured JSON
            out["metrics"] = self.obs.metrics.snapshot()
        return out

    # -- pump (worker thread) -------------------------------------------

    async def _run(self) -> None:
        loop = self._loop
        while not self._stopping:
            busy = await loop.run_in_executor(None, self._pump_once)
            await asyncio.sleep(0 if busy else self.idle_s)
        self._shutdown_flush()

    def _shutdown_flush(self) -> None:
        for req in self._requests.values():
            if req.state in (PENDING, RUNNING):
                req.error = "front door shutdown"
                req.state = FAILED
                self.failed += 1
                self._finish_signal(req)

    def _finish_signal(self, req: _FrontRequest) -> None:
        self._loop.call_soon_threadsafe(req.q.put_nowait, None)

    def _push_token(self, req: _FrontRequest, tok: int) -> None:
        self._loop.call_soon_threadsafe(req.q.put_nowait, tok)

    def _worst_pages(self, prompt_len: int, max_new: int) -> int:
        return -(-(prompt_len - 1 + max_new) // self.sch.page_len)

    def _reserved_pages(self) -> int:
        from repro.serving import state as ST

        return ST.RESERVED_PAGES

    def _tenant_queue(self, name: str) -> Deque[_FrontRequest]:
        q = self._pending.get(name)
        if q is None:
            q = self._pending[name] = deque()
        return q

    def _pump_once(self) -> bool:
        """One admission + decode + streaming round.  Returns True when any
        work happened (intake, admission, a decode step, streamed tokens)."""
        busy = False
        now = _now()
        self.adm.refill(now - self._last_refill)
        self._last_refill = now
        # 1. drain the loop->pump intake into per-tenant FIFO queues
        while self._intake:
            req = self._intake.popleft()
            self._tenant_queue(req.tenant).append(req)
            busy = True
        # 2. energy preemption: evict running requests of over-budget
        #    tenants; they park at the head of their tenant queue and
        #    re-admit (bit-exact resume) once the bucket refills
        for rid in [r for r in self._running]:
            req = self._running[rid]
            # liveness guard: a running request may only be preempted after
            # it streamed >= 1 NEW token in this admission streak.  Without
            # it, a joule bucket smaller than the restart cost (prefill +
            # replay of already-streamed tokens) livelocks the request —
            # preempted at the exact replay boundary forever, all burn and
            # no progress.  With it every streak advances the stream.
            if req.new_since_admit < 1:
                continue
            if self.adm.should_preempt(req.tenant):
                self.sch.preempt(rid)
                del self._running[rid]
                self.adm.tenant(req.tenant).inflight -= 1
                req.rid = None
                req.served = 0
                req.charged_j = 0.0
                req.state = PENDING
                req.preemptions += 1
                self.preemptions += 1
                req.last_defer = ADM.DEFER_ENERGY
                self.adm.record(req.fid, req.tenant, ADM.PREEMPT_ENERGY,
                                f"credit={self.adm.tenant(req.tenant).credit_j:.3e}J "
                                f"streamed={req.streamed}")
                if self.obs is not None:
                    self._c_preempt.inc()
                    self.obs.trace(TR.PREEMPT, fid=req.fid, rid=rid,
                                   tenant=req.tenant, streamed=req.streamed)
                self._tenant_queue(req.tenant).appendleft(req)
                busy = True
        # 3. admission: strict priority + token fairness, energy throttle,
        #    slot/page backpressure (decisions recorded on transitions)
        busy |= self._admit()
        # 4. one batched decode step
        if self._running:
            self.sch.step()
            busy = True
            self._stream_new_tokens()
        if self.obs is not None:
            self._g_pending.set(float(self._pending_count))
            self._g_running.set(float(len(self._running)))
            for name, st in self.adm.tenants.items():
                if st.policy.energy_budget_j is not None:
                    self._g_credit.set(st.credit_j, name)
        return busy

    def _admit(self) -> bool:
        sch, adm = self.sch, self.adm
        admitted = False
        # the scheduler only claims slots/pages at the next step()'s own
        # admission, so budget locally for what is already committed —
        # free slots minus its queue, free pages minus the queue's worst case
        backlog = sch.queued_requests()
        free = sch.free_slots() - len(backlog)
        pages_free = 0
        if sch.paged:
            pages_free = sch.pages.available() - sum(
                self._worst_pages(len(r.prompt_np), r.max_new)
                for r in backlog)
        while True:
            queued = [t for t, q in self._pending.items() if q]
            if not queued:
                break
            if free <= 0:
                self._record_defer(queued, ADM.DEFER_SLOTS,
                                   f"slots={sch.slots}")
                break
            name = adm.pick(queued)
            if name is None:  # every queued tenant is energy-throttled
                self._record_defer(
                    [t for t in queued if not adm.tenant(t).energy_ok],
                    ADM.DEFER_ENERGY, "bucket empty")
                break
            req = self._pending[name][0]
            worst = 0
            if sch.paged:
                worst = self._worst_pages(len(req.prompt), req.max_new)
                if pages_free < worst:
                    # PagePool backpressure: hold the line until running
                    # requests release pages (head-of-line, no overtaking —
                    # admission order must not depend on request size)
                    self._record_defer([name], ADM.DEFER_PAGES,
                                       f"need={worst} free={pages_free}")
                    break
            self._pending[name].popleft()
            with self._lock:
                self._pending_count -= 1
            rid = sch.submit(req.prompt, req.max_new, seed=req.seed)
            free -= 1
            pages_free -= worst
            req.rid = rid
            req.served = 0
            req.new_since_admit = 0
            req.charged_j = 0.0
            req.state = RUNNING
            req.last_defer = ""
            self._running[rid] = req
            adm.tenant(name).inflight += 1
            decision = ADM.READMIT if req.preemptions else ADM.ADMIT
            adm.record(req.fid, name, decision, f"rid={rid}")
            if self.obs is not None:
                self.obs.trace(
                    TR.READMIT if req.preemptions else TR.ADMIT,
                    fid=req.fid, rid=rid, tenant=name)
            admitted = True
        return admitted

    def _record_defer(self, tenants: List[str], reason: str, detail: str) -> None:
        """Record a defer for each named tenant's head request, once per
        reason transition (so records log state changes, not every pump)."""
        for name in tenants:
            q = self._pending.get(name)
            if not q:
                continue
            head = q[0]
            if head.last_defer != reason:
                head.last_defer = reason
                self.adm.record(head.fid, name, reason, detail)

    def _stream_new_tokens(self) -> None:
        sch = self.sch
        now = _now()
        done: List[int] = []
        for rid, req in self._running.items():
            # energy: charge this rid's delta to the tenant bucket
            booked = sch.request_energy_j.get(rid, 0.0)
            delta = booked - req.charged_j
            if delta > 0:
                req.charged_j = booked
                req.energy_j += delta
                self.adm.charge(req.tenant, delta)
            out = sch.outputs.get(rid, [])
            new_tokens = 0
            while req.served < len(out):
                tok = int(out[req.served])
                if req.served < req.streamed:
                    # replay after preemption: purity must regenerate the
                    # already-streamed prefix bit-exactly
                    if tok != req.tokens[req.served]:
                        req.error = (
                            f"preemption replay diverged at token "
                            f"{req.served}: {tok} != {req.tokens[req.served]}")
                        req.state = FAILED
                        done.append(rid)
                        break
                else:
                    if req.t_first is None:
                        req.t_first = now
                        if self.obs is not None:
                            self._h_ttft.observe(now - req.t_submit)
                            self.obs.trace(TR.FIRST_TOKEN, fid=req.fid,
                                           rid=rid, tenant=req.tenant,
                                           ttft_s=now - req.t_submit)
                    elif self.obs is not None and req.token_times:
                        gap = now - req.token_times[-1]
                        if gap > 0:  # same-step tokens share one stamp
                            self._h_tpot.observe(gap)
                    req.tokens.append(tok)
                    req.token_times.append(now)
                    req.streamed += 1
                    req.new_since_admit += 1
                    new_tokens += 1
                    self._push_token(req, tok)
                req.served += 1
            if new_tokens:
                self.adm.charge(req.tenant, 0.0, tokens=new_tokens)
            if req.state != FAILED and req.streamed >= req.max_new:
                req.state = DONE
                done.append(rid)
        for rid in done:
            req = self._running.pop(rid)
            self.adm.tenant(req.tenant).inflight -= 1
            if req.state == FAILED:
                if self.sch.slot_of(rid) is not None:
                    self.sch.preempt(rid)
                self.failed += 1
                if self.obs is not None:
                    self._c_requests.inc(1.0, "failed")
                    self.obs.trace(TR.FINISH, fid=req.fid, rid=rid,
                                   tenant=req.tenant, outcome="failed",
                                   error=req.error)
                self._finish_signal(req)
                continue
            req.t_done = now
            if self.obs is not None:
                self._c_requests.inc(1.0, "completed")
                self.obs.trace(TR.FINISH, fid=req.fid, rid=rid,
                               tenant=req.tenant, outcome="completed",
                               tokens=req.streamed,
                               latency_s=now - req.t_submit,
                               energy_j=req.energy_j)
            req.result = RequestResult(
                request_id=req.fid, tenant=req.tenant, tokens=list(req.tokens),
                energy_j=req.energy_j,
                ttft_s=(req.t_first or now) - req.t_submit,
                latency_s=now - req.t_submit,
                preemptions=req.preemptions,
                token_times=list(req.token_times),
            )
            self._results.append(req.result)
            self.completed += 1
            self._finish_signal(req)
