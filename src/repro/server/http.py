"""Stdlib-only asyncio HTTP/1.1 + SSE transport for the serving front door.

No aiohttp/uvicorn dependency — the accelerator containers ship bare — so
this is a deliberately small HTTP server over ``asyncio.start_server``
streams, serving three routes:

``POST /generate``
    Body: ``{"prompt": [token ids], "max_new": N, "seed": S?,
    "tenant": "name"?, "stream": true?}``.  With ``stream`` (the default)
    the response is ``text/event-stream`` and tokens are flushed as the
    batched scheduler decodes them::

        event: token
        data: {"index": 0, "token": 1234}

        event: done
        data: {"request_id": 7, "tenant": "default", "tokens": [...],
               "energy_j": ..., "ttft_s": ..., "latency_s": ...,
               "preemptions": 0}

    With ``"stream": false`` the server waits and returns one JSON body
    (the ``done`` payload).  Errors: 400 (malformed/unservable request),
    429 (front-door queue full — load shedding), 503 (shutting down).

``GET /stats``
    JSON: scheduler :class:`~repro.serving.ServeStats` (including
    ``j_per_token`` / ``tokens_per_sec``), per-tenant admission state
    (energy buckets, fairness counters), the recent admission decisions
    and — when telemetry is on — the full metrics-registry snapshot
    under ``"metrics"``.

``GET /metrics``
    Prometheus text exposition (format 0.0.4) of the shared
    :class:`repro.obs.MetricsRegistry`: decode-step latency histograms,
    TTFT/TPOT, page-pool occupancy, admission decisions, device clock,
    GDC gain, energy counters.  404 when the front door was built with
    ``enable_telemetry=False``.

``GET /healthz``
    ``{"ok": true}`` liveness probe.

Connections are ``Connection: close`` — one exchange per connection keeps
the parser trivial and makes the SSE end-of-stream unambiguous.
"""

from __future__ import annotations

import asyncio
import dataclasses
import json
from typing import Any, AsyncIterator, Dict, Optional, Tuple

from repro.obs import render_prometheus
from repro.server.frontdoor import FrontDoor, QueueFull

MAX_BODY = 8 * 1024 * 1024
_REASONS = {200: "OK", 400: "Bad Request", 404: "Not Found",
            405: "Method Not Allowed", 429: "Too Many Requests",
            500: "Internal Server Error", 503: "Service Unavailable"}


def _response(status: int, body: bytes, content_type: str) -> bytes:
    return (
        f"HTTP/1.1 {status} {_REASONS.get(status, 'Unknown')}\r\n"
        f"Content-Type: {content_type}\r\n"
        f"Content-Length: {len(body)}\r\n"
        "Connection: close\r\n\r\n"
    ).encode() + body


def _json_response(status: int, payload: Dict[str, Any]) -> bytes:
    return _response(status, json.dumps(payload).encode(), "application/json")


def _sse_event(event: str, payload: Dict[str, Any]) -> bytes:
    return f"event: {event}\ndata: {json.dumps(payload)}\n\n".encode()


async def _read_request(reader: asyncio.StreamReader
                        ) -> Optional[Tuple[str, str, Dict[str, str], bytes]]:
    """Parse one HTTP/1.1 request: (method, path, headers, body)."""
    try:
        line = await reader.readline()
    except (ConnectionError, asyncio.IncompleteReadError):
        return None
    if not line:
        return None
    parts = line.decode("latin-1").strip().split()
    if len(parts) < 2:
        return None
    method, path = parts[0].upper(), parts[1]
    headers: Dict[str, str] = {}
    while True:
        line = await reader.readline()
        if line in (b"\r\n", b"\n", b""):
            break
        k, _, v = line.decode("latin-1").partition(":")
        headers[k.strip().lower()] = v.strip()
    n = int(headers.get("content-length", "0") or "0")
    if n > MAX_BODY:
        return method, path, headers, b""
    body = await reader.readexactly(n) if n else b""
    return method, path, headers, body


class HttpFrontDoor:
    """The HTTP/SSE server wrapping one :class:`FrontDoor`.

    Use as an async context manager (tests) or via :meth:`serve_forever`
    (the ``launch/serve.py --http`` CLI)::

        async with HttpFrontDoor(front, host="127.0.0.1", port=0) as srv:
            ...  # srv.port is the bound port
    """

    def __init__(self, front: FrontDoor, *, host: str = "127.0.0.1",
                 port: int = 8000):
        self.front = front
        self.host = host
        self.port = port
        self._server: Optional[asyncio.AbstractServer] = None

    async def start(self) -> None:
        await self.front.start()
        self._server = await asyncio.start_server(
            self._handle, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        await self.front.stop()

    async def __aenter__(self) -> "HttpFrontDoor":
        await self.start()
        return self

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    async def serve_forever(self) -> None:
        await self.start()
        try:
            await self._server.serve_forever()
        finally:
            await self.stop()

    # -- request handling ----------------------------------------------

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            parsed = await _read_request(reader)
            if parsed is None:
                return
            method, path, _headers, body = parsed
            if path == "/healthz" and method == "GET":
                writer.write(_json_response(200, {"ok": True}))
            elif path == "/stats" and method == "GET":
                writer.write(_json_response(200, self.front.stats_dict()))
            elif path == "/metrics" and method == "GET":
                if self.front.obs is None:
                    writer.write(_json_response(
                        404, {"error": "telemetry disabled"}))
                else:
                    text = render_prometheus(self.front.obs.metrics)
                    writer.write(_response(
                        200, text.encode(),
                        "text/plain; version=0.0.4; charset=utf-8"))
            elif path == "/generate":
                if method != "POST":
                    writer.write(_json_response(
                        405, {"error": "POST /generate"}))
                else:
                    await self._generate(writer, body)
            else:
                writer.write(_json_response(404, {"error": f"no route {path}"}))
            await writer.drain()
        except (ConnectionError, asyncio.IncompleteReadError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    async def _generate(self, writer: asyncio.StreamWriter, body: bytes) -> None:
        try:
            payload = json.loads(body.decode() or "{}")
            prompt = payload["prompt"]
            max_new = int(payload.get("max_new", 16))
            seed = payload.get("seed")
            tenant = str(payload.get("tenant", "default"))
            stream = bool(payload.get("stream", True))
            if not isinstance(prompt, list):
                raise ValueError("prompt must be a list of token ids")
        except (KeyError, ValueError, TypeError, json.JSONDecodeError) as e:
            writer.write(_json_response(400, {"error": f"bad request: {e}"}))
            return
        try:
            ts = await self.front.submit(
                prompt, max_new, seed=None if seed is None else int(seed),
                tenant=tenant)
        except QueueFull as e:
            writer.write(_json_response(429, {"error": str(e)}))
            return
        except ValueError as e:
            writer.write(_json_response(400, {"error": str(e)}))
            return
        if not stream:
            try:
                await ts.tokens()
            except RuntimeError as e:
                writer.write(_json_response(503, {"error": str(e)}))
                return
            writer.write(_json_response(200, _done_payload(ts)))
            return
        writer.write(
            b"HTTP/1.1 200 OK\r\n"
            b"Content-Type: text/event-stream\r\n"
            b"Cache-Control: no-cache\r\n"
            b"Connection: close\r\n\r\n")
        await writer.drain()
        index = 0
        try:
            async for tok in ts:
                writer.write(_sse_event("token", {"index": index, "token": tok}))
                await writer.drain()
                index += 1
        except RuntimeError as e:  # front door failed/shut down mid-stream
            writer.write(_sse_event("error", {"error": str(e)}))
            return
        writer.write(_sse_event("done", _done_payload(ts)))


def _done_payload(ts) -> Dict[str, Any]:
    res = ts.result
    if res is None:  # stream drained before the pump attached the result
        return {"request_id": ts.request_id}
    return dataclasses.asdict(res)


async def read_sse(reader: asyncio.StreamReader
                   ) -> AsyncIterator[Tuple[str, Dict[str, Any]]]:
    """Client-side SSE parser: yields (event, payload) until the peer
    closes.  Skips the HTTP response headers first — feed it the reader of
    a connection that just sent ``POST /generate``.  Shared by the tests
    and the load generator's ``--http`` mode."""
    while True:  # response headers
        line = await reader.readline()
        if not line or line in (b"\r\n", b"\n"):
            break
    event, data = None, None
    while True:
        line = await reader.readline()
        if not line:
            return
        line = line.decode().rstrip("\n").rstrip("\r")
        if not line:
            if event is not None and data is not None:
                yield event, json.loads(data)
            event, data = None, None
        elif line.startswith("event:"):
            event = line[len("event:"):].strip()
        elif line.startswith("data:"):
            data = line[len("data:"):].strip()
