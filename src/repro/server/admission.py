"""SLO- and energy-aware admission control for the serving front door.

Pure host-side policy logic — no asyncio, no JAX — so every decision the
front door makes is deterministic and unit-testable in isolation:

* **per-tenant priorities** — strict priority classes (lower value serves
  first); within a class, tenants share capacity by weighted
  deficit-round-robin on *decoded tokens* (token-budget fairness: a tenant
  that has consumed more tokens per unit weight waits behind one that has
  consumed fewer).
* **energy SLOs** — each tenant may carry a joule budget
  (:attr:`TenantPolicy.energy_budget_j`) implemented as a token bucket:
  measured per-request energy (:attr:`repro.serving.BatchScheduler.
  request_energy_j`, PR 3's metered spike events x Table-II op energies)
  is charged against the bucket as it accrues, and the bucket refills at
  :attr:`TenantPolicy.refill_j_per_s`.  A tenant with an empty bucket is
  **throttled** (its requests stay queued) and — when
  :attr:`TenantPolicy.preempt` is set — its *running* requests are
  **preempted** (evicted and re-admitted once the bucket refills; token
  purity makes the restarted decode bit-identical, so the client stream
  just resumes).
* **decision records** — every admit / defer / preempt / re-admit is
  appended to :attr:`AdmissionController.records` with its reason, so SLO
  behaviour is observable (``GET /stats``) and assertable in tests.

The controller never touches the scheduler; the front door asks it *what*
to do and then drives :class:`repro.serving.BatchScheduler`.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional

# decision tags recorded per request event
ADMIT = "admit"
READMIT = "readmit"
DEFER_ENERGY = "defer:energy"
DEFER_SLOTS = "defer:slots"
DEFER_PAGES = "defer:pages"
DEFER_QUEUE = "defer:queue"
PREEMPT_ENERGY = "preempt:energy"


@dataclasses.dataclass(frozen=True)
class TenantPolicy:
    """Serving policy for one tenant.

    ``priority`` is a strict class (0 beats 1); ``weight`` divides decoded
    tokens for the fair-share comparison inside a class.  ``energy_budget_j``
    (None = unmetered) is the token-bucket capacity in joules;
    ``refill_j_per_s`` its refill rate.  With ``preempt`` set, a tenant
    that overruns its bucket mid-flight has its running requests evicted
    and re-admitted when the bucket refills (bit-exact resume); otherwise
    the overrun only blocks *new* admissions (soft SLO).
    """

    priority: int = 0
    weight: float = 1.0
    energy_budget_j: Optional[float] = None
    refill_j_per_s: float = 0.0
    preempt: bool = True


@dataclasses.dataclass
class TenantState:
    policy: TenantPolicy
    credit_j: float  # energy token bucket (inf when unmetered)
    spent_j: float = 0.0  # lifetime metered joules
    spent_tokens: int = 0  # lifetime decoded tokens (fairness counter)
    inflight: int = 0  # requests currently holding a slot

    @property
    def fair_share_key(self) -> float:
        return self.spent_tokens / max(self.policy.weight, 1e-9)

    @property
    def energy_ok(self) -> bool:
        return self.policy.energy_budget_j is None or self.credit_j > 0.0


@dataclasses.dataclass(frozen=True)
class AdmissionRecord:
    """One admission-control event: what happened to a request and why."""

    request_id: int
    tenant: str
    decision: str  # ADMIT / READMIT / DEFER_* / PREEMPT_ENERGY
    detail: str = ""


class AdmissionController:
    """Deterministic per-tenant admission, fairness and energy accounting."""

    def __init__(self, policies: Optional[Dict[str, TenantPolicy]] = None,
                 default: Optional[TenantPolicy] = None,
                 max_records: int = 4096, metrics=None):
        self._policies = dict(policies or {})
        self._default = default or TenantPolicy()
        self.tenants: Dict[str, TenantState] = {}
        # decision log: a bounded ring — a long-lived server keeps the
        # *recent* window for /stats and postmortems, while the monotone
        # per-decision counters below carry the lifetime totals
        self.records: Deque[AdmissionRecord] = deque(maxlen=max_records)
        # labeled admit/defer:*/preempt counters (repro.obs registry);
        # None = uninstrumented, the controller stays dependency-free
        self._c_decisions = None
        if metrics is not None:
            self.bind_metrics(metrics)

    def bind_metrics(self, metrics) -> None:
        """Attach a :class:`repro.obs.MetricsRegistry`: every decision
        increments ``xpike_admission_decisions_total{decision,tenant}``."""
        self._c_decisions = metrics.counter(
            "admission_decisions_total",
            "admission-control events by decision tag",
            ("decision", "tenant"))

    # -- tenant bookkeeping --------------------------------------------

    def tenant(self, name: str) -> TenantState:
        st = self.tenants.get(name)
        if st is None:
            pol = self._policies.get(name, self._default)
            credit = (float("inf") if pol.energy_budget_j is None
                      else pol.energy_budget_j)
            st = self.tenants[name] = TenantState(pol, credit)
        return st

    def set_policy(self, name: str, policy: TenantPolicy) -> None:
        """Install/replace a tenant's policy (bucket re-capped, not refilled
        beyond the new budget)."""
        self._policies[name] = policy
        st = self.tenants.get(name)
        if st is not None:
            st.policy = policy
            cap = (float("inf") if policy.energy_budget_j is None
                   else policy.energy_budget_j)
            st.credit_j = min(st.credit_j, cap)

    def grant(self, name: str, joules: float) -> None:
        """Credit a tenant's energy bucket (capped at its budget) — the
        manual-refill hook for operators and deterministic tests."""
        st = self.tenant(name)
        if st.policy.energy_budget_j is not None:
            st.credit_j = min(st.credit_j + joules, st.policy.energy_budget_j)

    def refill(self, dt_s: float) -> None:
        """Advance every tenant's token bucket by ``dt_s`` wall seconds."""
        if dt_s <= 0:
            return
        for st in self.tenants.values():
            if st.policy.energy_budget_j is not None:
                st.credit_j = min(st.credit_j + st.policy.refill_j_per_s * dt_s,
                                  st.policy.energy_budget_j)

    def charge(self, name: str, joules: float, tokens: int = 0) -> None:
        """Book metered energy (and decoded tokens, for fairness) against a
        tenant — called by the front door with the scheduler's per-request
        energy deltas."""
        st = self.tenant(name)
        st.spent_j += joules
        st.spent_tokens += tokens
        if st.policy.energy_budget_j is not None:
            st.credit_j -= joules

    # -- decisions ------------------------------------------------------

    def pick(self, queued_tenants) -> Optional[str]:
        """The tenant whose head-of-queue request should be admitted next:
        strict priority first, then weighted token-fairness (least decoded
        tokens per unit weight), tenant name as the deterministic
        tie-break.  Tenants with an exhausted energy bucket are skipped
        (they stay queued — throttling, not rejection)."""
        best = None
        for name in queued_tenants:
            st = self.tenant(name)
            if not st.energy_ok:
                continue
            key = (st.policy.priority, st.fair_share_key, name)
            if best is None or key < best[0]:
                best = (key, name)
        return None if best is None else best[1]

    def should_preempt(self, name: str) -> bool:
        st = self.tenant(name)
        return (st.policy.energy_budget_j is not None and st.policy.preempt
                and st.credit_j <= 0.0)

    def record(self, request_id: int, tenant: str, decision: str,
               detail: str = "") -> None:
        self.records.append(AdmissionRecord(request_id, tenant, decision, detail))
        if self._c_decisions is not None:
            self._c_decisions.inc(1.0, decision, tenant)

    def decisions(self, request_id: Optional[int] = None) -> List[AdmissionRecord]:
        if request_id is None:
            return list(self.records)
        return [r for r in self.records if r.request_id == request_id]
