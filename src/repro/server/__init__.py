"""Production front door: async streaming HTTP/SSE serving with SLO- and
energy-aware admission control.

Layering (transport down to silicon)::

    HttpFrontDoor   — stdlib asyncio HTTP/1.1 + SSE   (repro.server.http)
        |
    FrontDoor       — async request queue, streaming, preemption/resume
        |             (repro.server.frontdoor)
    AdmissionController — per-tenant priorities, token-budget fairness,
        |             joule buckets (energy SLOs), decision records
        |             (repro.server.admission)
    BatchScheduler  — the existing continuous-batching scheduler
                      (repro.serving): dense or paged, single-device or
                      mesh; tokens stay a pure f(params, prompt, seed), so
                      the whole async stack is differentially testable
                      against a direct in-process run.
"""

from repro.server.admission import (
    AdmissionController,
    AdmissionRecord,
    TenantPolicy,
)
from repro.server.frontdoor import (
    FrontDoor,
    QueueFull,
    RequestResult,
    TokenStream,
)
from repro.server.http import HttpFrontDoor, read_sse

__all__ = [
    "AdmissionController",
    "AdmissionRecord",
    "TenantPolicy",
    "FrontDoor",
    "QueueFull",
    "RequestResult",
    "TokenStream",
    "HttpFrontDoor",
    "read_sse",
]
