"""gemma3-27b [dense] — 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144 — 5:1 local:global, 128k  [hf:google/gemma-3-1b-pt; unverified].

Pattern: five sliding-window (1024) layers followed by one global layer,
cycled over the 62-layer depth (10 full periods + 2 remainder local layers).
``sub_quadratic`` is False (the global layers keep a full KV cache), but the
5:1 interleave makes decode near-linear; per the assignment note gemma3 runs
``long_500k`` (see DESIGN.md skip list)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    block_pattern=("local", "local", "local", "local", "local", "attn"),
    window_size=1024,
    norm_type="rmsnorm",
    act="gelu",
    gated_mlp=True,
    tie_embeddings=True,
).validate()
