"""Architecture registry: --arch <id> -> ModelConfig, plus reduced smoke
configs of the same family for CPU tests."""

from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.configs import (
    arctic_480b,
    gemma3_27b,
    granite_3_8b,
    mamba2_780m,
    musicgen_medium,
    phi35_moe_42b,
    pixtral_12b,
    qwen25_32b,
    recurrentgemma_9b,
    xpikeformer,
    yi_9b,
)
from repro.configs.base import ModelConfig, SHAPES, ShapeConfig, shape_applicable

ARCHS: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        arctic_480b.CONFIG,
        phi35_moe_42b.CONFIG,
        mamba2_780m.CONFIG,
        musicgen_medium.CONFIG,
        pixtral_12b.CONFIG,
        qwen25_32b.CONFIG,
        yi_9b.CONFIG,
        gemma3_27b.CONFIG,
        granite_3_8b.CONFIG,
        recurrentgemma_9b.CONFIG,
        # the paper's spiking GPT decoders on the generic LM stack
        # (spiking=True + SSA attention): --arch xpikeformer-gpt-* works
        # in train/serve/quickstart like any other arch
        xpikeformer.GPT_4_256,
        xpikeformer.GPT_8_512,
    )
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs() -> List[str]:
    return list(ARCHS)


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells, honouring the skip rules."""
    out = []
    for name, cfg in ARCHS.items():
        for shape in SHAPES.values():
            ok, why = shape_applicable(cfg, shape)
            if ok or include_skipped:
                out.append((cfg, shape, ok, why))
    return out


def reduced_config(name: str) -> ModelConfig:
    """Same-family reduced config for CPU smoke tests: small depth/width,
    few experts, tiny vocab — exercises scan periods AND the unrolled
    remainder when the full config has one."""
    cfg = get_config(name)
    period = cfg.period
    layers = 2 * period + (1 if cfg.remainder_layers else 0)
    heads = min(cfg.num_heads, 4) if cfg.num_heads else 0
    kv = max(1, heads * cfg.num_kv_heads // max(cfg.num_heads, 1)) if heads else 0
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=layers,
        d_model=64,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=16 if heads else 0,
        d_ff=0 if cfg.d_ff == 0 else 128,
        moe_dense_ff=0 if cfg.moe_dense_ff == 0 else 128,
        vocab_size=257,
        num_experts=0 if cfg.num_experts == 0 else 4,
        moe_top_k=0 if cfg.moe_top_k == 0 else 2,
        window_size=min(cfg.window_size, 8),
        ssm_state_dim=0 if cfg.ssm_state_dim == 0 else 16,
        ssm_head_dim=16,
        rglru_width=0 if cfg.rglru_width == 0 else 32,
        frontend_dim=0 if cfg.frontend == "none" else 24,
        dtype="float32",
    ).validate()
