"""pixtral-12b [vlm] — 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072 — pixtral-ViT + mistral-nemo  [hf:mistralai/Pixtral-12B-2409;
unverified].

The Pixtral-ViT vision tower is a STUB: ``input_specs()`` provides
precomputed patch embeddings (dim 1024, the ViT hidden width)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,  # mistral-nemo style: 32 heads x 128 != d_model
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1000000.0,
    norm_type="rmsnorm",
    act="silu",
    gated_mlp=True,
    frontend="vision_patches",
    frontend_dim=1024,
).validate()
