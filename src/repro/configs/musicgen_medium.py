"""musicgen-medium [audio] — 48L d_model=1536 24H (GQA kv=24) d_ff=6144
vocab=2048 — decoder-only over EnCodec tokens  [arXiv:2306.05284; hf].

The EnCodec frontend is a STUB: ``input_specs()`` provides precomputed
frame embeddings (dim 128, EnCodec latent width)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    norm_type="layernorm",
    act="gelu",
    gated_mlp=False,
    frontend="audio_frames",
    frontend_dim=128,
).validate()
