"""Xpikeformer paper-scale configs (Tables III & IV).

* ViT encoders 4-384 / 6-512 / 8-768 (image classification) — built by
  ``core/spiking_transformer.py`` (encoder, patch embed, CLS pooling).
* GPT decoders 4-256 / 8-512 (ICL wireless symbol detection) — expressed on
  the generic LM stack with ``spiking=True`` and SSA attention, which is
  exactly Table I's Xpikeformer column.
"""

from repro.configs.base import ModelConfig


def xpikeformer_gpt(depth: int, dim: int, *, vocab: int, T: int = 4, spiking: bool = True,
                    attention_kind: str = "ssa") -> ModelConfig:
    return ModelConfig(
        name=f"xpikeformer-gpt-{depth}-{dim}",
        family="dense",
        num_layers=depth,
        d_model=dim,
        num_heads=max(dim // 64, 1),
        num_kv_heads=max(dim // 64, 1),
        head_dim=64,
        d_ff=4 * dim,
        vocab_size=vocab,
        norm_type="layernorm",
        act="gelu",
        gated_mlp=False,
        spiking=spiking,
        spike_T=T,
        attention_kind=attention_kind,
        rope_theta=10000.0,
        dtype="float32",
    ).validate()


GPT_4_256 = xpikeformer_gpt(4, 256, vocab=64)
GPT_8_512 = xpikeformer_gpt(8, 512, vocab=64)
