"""Xpikeformer paper-scale configs (Tables III & IV).

Two families, two config types:

* ``SPIKING_ARCHS`` — the paper models run by the
  :class:`repro.engine.XpikeformerEngine` (spiking ViT encoders 4-384 /
  6-512 / 8-768 for image classification, spiking GPT decoders 4-256 /
  8-512 for ICL wireless symbol detection), each with a ``-smoke`` variant
  sized for CPU tests.  Values are ``(task, SpikingConfig)``.

* ``xpikeformer_gpt`` — the same GPT decoders expressed on the generic LM
  stack (``models/transformer.py`` with ``spiking=True`` + SSA attention,
  exactly Table I's Xpikeformer column) so they register in
  ``configs/registry.py`` and work with ``--arch xpikeformer-gpt-*`` in the
  training/serving launchers.
"""

from typing import Dict, Tuple

from repro.configs.base import ModelConfig
from repro.core.spiking_transformer import SpikingConfig


def xpikeformer_gpt(depth: int, dim: int, *, vocab: int, T: int = 4, spiking: bool = True,
                    attention_kind: str = "ssa") -> ModelConfig:
    return ModelConfig(
        name=f"xpikeformer-gpt-{depth}-{dim}",
        family="dense",
        num_layers=depth,
        d_model=dim,
        num_heads=max(dim // 64, 1),
        num_kv_heads=max(dim // 64, 1),
        head_dim=64,
        d_ff=4 * dim,
        vocab_size=vocab,
        norm_type="layernorm",
        act="gelu",
        gated_mlp=False,
        spiking=spiking,
        spike_T=T,
        attention_kind=attention_kind,
        rope_theta=10000.0,
        dtype="float32",
    ).validate()


GPT_4_256 = xpikeformer_gpt(4, 256, vocab=64)
GPT_8_512 = xpikeformer_gpt(8, 512, vocab=64)


# ---------------------------------------------------------------------------
# Engine archs: the paper models (core/spiking_transformer.py)
# ---------------------------------------------------------------------------

# ICL MIMO symbol-detection input interface (2x2 antennas, QPSK):
# feat_dim = 2*n_rx + n_classes, vocab = n_classes (data/icl_mimo.py).
_MIMO_FEAT_DIM = 2 * 2 + 16
_MIMO_CLASSES = 16


def _vit(depth: int, dim: int, *, T: int = 4, image_size: int = 32,
         patch_size: int = 4, num_classes: int = 10) -> SpikingConfig:
    return SpikingConfig(
        depth=depth, dim=dim, num_heads=max(dim // 64, 2), T=T, mode="ssa",
        image_size=image_size, patch_size=patch_size, num_classes=num_classes,
    )


def _gpt(depth: int, dim: int, *, T: int = 4) -> SpikingConfig:
    return SpikingConfig(
        depth=depth, dim=dim, num_heads=max(dim // 64, 2), T=T, mode="ssa",
        input_dim=_MIMO_FEAT_DIM, vocab=_MIMO_CLASSES,
    )


SPIKING_ARCHS: Dict[str, Tuple[str, SpikingConfig]] = {
    # paper scales (Tables III / IV)
    "xpikeformer-vit-4-384": ("vit", _vit(4, 384)),
    "xpikeformer-vit-6-512": ("vit", _vit(6, 512)),
    "xpikeformer-vit-8-768": ("vit", _vit(8, 768)),
    "xpikeformer-gpt-4-256": ("gpt", _gpt(4, 256)),
    "xpikeformer-gpt-8-512": ("gpt", _gpt(8, 512)),
    # reduced scales for CPU smoke tests / quickstarts
    "xpikeformer-vit-smoke": (
        "vit", _vit(1, 32, T=3, image_size=16, patch_size=4)
    ),
    "xpikeformer-gpt-smoke": ("gpt", _gpt(1, 32, T=3)),
}

# default aliases
SPIKING_ARCHS["xpikeformer-vit"] = SPIKING_ARCHS["xpikeformer-vit-4-384"]
SPIKING_ARCHS["xpikeformer-gpt"] = SPIKING_ARCHS["xpikeformer-gpt-4-256"]
