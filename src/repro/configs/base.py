"""Config dataclasses: model architecture, run shapes, parallelism.

Every assigned architecture is expressed as a :class:`ModelConfig`; every
(arch x input-shape) dry-run / roofline cell is a :class:`RunConfig`.
Configs are frozen dataclasses so they hash and can key jit caches.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model architecture
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description for the generic LM stack.

    ``block_pattern`` is cycled over the depth: each entry names the token
    mixer of one layer — ``attn`` (global attention), ``local`` (sliding
    window), ``rglru`` (RecurrentGemma RG-LRU), ``ssd`` (Mamba-2 state-space
    duality).  The pattern period is the scan unit: layers are scanned over
    ``num_layers // len(pattern)`` periods with the remainder unrolled.
    """

    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- token mixer pattern ---
    block_pattern: Tuple[str, ...] = ("attn",)
    window_size: int = 1024  # for "local" mixers
    qkv_bias: bool = False
    logit_softcap: float = 0.0
    attn_softcap: float = 0.0

    # --- MoE ---
    num_experts: int = 0
    moe_top_k: int = 0
    moe_dense_ff: int = 0  # arctic-style dense residual MLP alongside MoE
    capacity_factor: float = 1.25

    # --- SSM (Mamba-2 / SSD) ---
    ssm_state_dim: int = 0
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_n_groups: int = 1
    ssm_chunk: int = 256

    # --- hybrid (RecurrentGemma RG-LRU) ---
    rglru_width: int = 0  # 0 -> d_model
    rglru_conv_width: int = 4

    # --- misc architecture ---
    rope_theta: float = 10000.0
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "silu"  # silu | gelu
    gated_mlp: bool = True
    tie_embeddings: bool = False

    # --- the paper's technique (spiking mode) ---
    spiking: bool = False
    spike_T: int = 4
    attention_kind: str = "softmax"  # softmax | ssa | lif  (spiking modes)

    # --- modality frontend stub ---
    frontend: str = "none"  # none | audio_frames | vision_patches
    frontend_dim: int = 0  # embedding dim delivered by the (stub) frontend

    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def period(self) -> int:
        return len(self.block_pattern)

    @property
    def num_periods(self) -> int:
        return self.num_layers // self.period

    @property
    def remainder_layers(self) -> int:
        return self.num_layers % self.period

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def is_attention_free(self) -> bool:
        return all(b in ("ssd",) for b in self.block_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True if no mixer needs a full O(L^2) global KV cache at decode."""
        return all(b in ("ssd", "rglru", "local") for b in self.block_pattern)

    def mixer_of_layer(self, i: int) -> str:
        return self.block_pattern[i % self.period]

    def validate(self) -> "ModelConfig":
        assert self.d_model > 0 and self.num_layers > 0
        if self.num_heads:
            assert self.num_heads % max(self.num_kv_heads, 1) == 0, (
                f"{self.name}: heads {self.num_heads} not a multiple of kv "
                f"heads {self.num_kv_heads}"
            )
        if self.is_moe:
            assert self.moe_top_k > 0
        if "ssd" in self.block_pattern:
            assert self.ssm_state_dim > 0
        return self


# ---------------------------------------------------------------------------
# Input shapes (assigned LM shape grid)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a shape cell is runnable for an arch (per DESIGN.md skip rules).

    ``long_500k`` runs for archs with a sub-quadratic decode path — SSM,
    hybrid, and local-window-dominated stacks (gemma3's 5:1 interleave makes
    decode near-linear).  Pure full-attention archs skip it.
    """
    if shape.name == "long_500k":
        if all(m == "attn" for m in model.block_pattern):
            return False, "long_500k skipped: pure full-attention arch (no sub-quadratic path)"
    return True, ""


# ---------------------------------------------------------------------------
# Parallelism / run configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """How a run is laid out on the mesh.

    Axis names follow ``launch/mesh.py``: ``pod`` (inter-pod DP), ``data``
    (intra-pod DP / FSDP), ``model`` (TP / SP / EP).
    """

    fsdp: bool = True  # shard param minor dims over "data" (ZeRO-3 style)
    seq_shard: bool = True  # sequence-parallel activations over "model"
    pure_dp: bool = False  # small models: replicate weights, batch over ALL axes
    remat: str = "block"  # none | block | full
    opt_state_dtype: str = "float32"  # float32 | bfloat16 | int8
    grad_compression: bool = False  # int8 error-feedback all-reduce
    grad_dtype: str = "native"  # native | bfloat16 (cast before cross-chip reduce)
    microbatches: int = 1  # gradient accumulation steps
    moe_impl: str = "ep_a2a"  # ep_a2a | dense
    param_dtype: str = "bfloat16"


@dataclasses.dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    parallel: ParallelConfig = ParallelConfig()

    @property
    def cell(self) -> str:
        return f"{self.model.name}:{self.shape.name}"
