"""mamba2-780m [ssm] — 48L d_model=1536 (attn-free) d_ff=0 vocab=50280,
ssm_state=128 — SSD (state-space duality)  [arXiv:2405.21060; unverified]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,  # attention-free, no FFN: the SSD block is the whole layer
    vocab_size=50280,
    block_pattern=("ssd",),
    ssm_state_dim=128,
    ssm_conv_width=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_n_groups=1,
    norm_type="rmsnorm",
    tie_embeddings=True,
).validate()
