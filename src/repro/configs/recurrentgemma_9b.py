"""recurrentgemma-9b [hybrid] — 38L d_model=4096 16H (GQA kv=1) d_ff=12288
vocab=256000 — RG-LRU + local attn, 1:2  [arXiv:2402.19427; unverified].

Pattern: (rglru, rglru, local-attention) cycled — 12 full periods + 2
remainder RG-LRU layers.  Fully sub-quadratic (the only attention is a
2048-token sliding window), so it runs ``long_500k``."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("rglru", "rglru", "local"),
    window_size=2048,
    rglru_width=4096,
    rglru_conv_width=4,
    norm_type="rmsnorm",
    act="gelu",
    gated_mlp=True,
    tie_embeddings=True,
).validate()
