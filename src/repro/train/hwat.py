"""Two-stage Xpikeformer training (paper §V-A): CT then HWAT.

1. Conventional training (CT): ideal full-precision forward/backward with
   surrogate gradients for the spiking nonlinearities.
2. Hardware-aware training (HWAT): quantisation + PCM programming noise
   injected in the forward pass (straight-through), backward stays ideal.

Generic over the paper models (ViT / GPT): caller supplies a
``forward(params, inputs, sim, rng) -> logits`` and a loss adapter.
AdamW is reused from optim/ (paper trains with AdamW [52]).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.spiking_transformer import AIMCSim
from repro.optim import adamw as A

Array = jax.Array


def xent_loss(logits: Array, labels: Array, mask: Optional[Array] = None) -> Array:
    lf = logits.astype(jnp.float32)
    nll = jax.nn.logsumexp(lf, -1) - jnp.take_along_axis(
        lf, labels[..., None], axis=-1
    )[..., 0]
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def make_step(forward: Callable, opt: A.AdamWConfig, sim: AIMCSim):
    def loss_fn(params, batch, rng):
        logits = forward(params, batch, sim, rng)
        return xent_loss(logits, batch["labels"], batch.get("mask"))

    @jax.jit
    def step(params, opt_state, batch, rng):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch, rng)
        params, opt_state, m = A.apply_updates(params, grads, opt_state, opt)
        return params, opt_state, loss

    return step


def train_stage(
    params,
    forward: Callable,
    data_fn: Callable[[Array], Dict[str, Array]],
    *,
    steps: int,
    sim: AIMCSim,
    lr: float = 1e-3,
    seed: int = 0,
    log_every: int = 0,
):
    """Run one training stage; data_fn(key) -> batch."""
    opt = A.AdamWConfig(lr=lr, warmup_steps=max(steps // 20, 1), total_steps=steps,
                        weight_decay=0.01, grad_clip=1.0)
    opt_state = A.init_opt_state(params, opt)
    step = make_step(forward, opt, sim)
    key = jax.random.PRNGKey(seed)
    losses = []
    for i in range(steps):
        kd, kf = jax.random.split(jax.random.fold_in(key, i))
        batch = data_fn(kd)
        params, opt_state, loss = step(params, opt_state, batch, kf)
        losses.append(float(loss))
        if log_every and (i % log_every == 0 or i == steps - 1):
            print(f"  step {i:4d} loss {float(loss):.4f}", flush=True)
    return params, losses


def two_stage_train(
    params,
    forward: Callable,
    data_fn: Callable,
    *,
    ct_steps: int,
    hwat_steps: int,
    aimc_cfg=None,
    lr: float = 1e-3,
    seed: int = 0,
    log_every: int = 0,
):
    """CT (ideal) then HWAT (noisy forward).  Returns (params, loss curves)."""
    from repro.core.aimc import AIMCConfig

    cfg = aimc_cfg or AIMCConfig()
    params, l1 = train_stage(
        params, forward, data_fn, steps=ct_steps,
        sim=AIMCSim(wmode="ideal", cfg=cfg), lr=lr, seed=seed, log_every=log_every,
    )
    l2 = []
    if hwat_steps > 0:
        params, l2 = train_stage(
            params, forward, data_fn, steps=hwat_steps,
            sim=AIMCSim(wmode="hwat", cfg=cfg), lr=lr * 0.3, seed=seed + 1,
            log_every=log_every,
        )
    return params, {"ct": l1, "hwat": l2}


def train_and_program(
    params,
    forward: Callable,
    data_fn: Callable,
    *,
    ct_steps: int,
    hwat_steps: int,
    program_key=None,
    aimc_cfg=None,
    lr: float = 1e-3,
    seed: int = 0,
    log_every: int = 0,
):
    """The full paper pipeline: CT -> HWAT -> program onto PCM.

    Returns ``(programmed_params, curves)`` where every linear leaf is an
    :class:`repro.aimc_device.AIMCDeviceState` at t = 0 — ready for the
    ``drift_to`` / ``recalibrate`` inference lifecycle (Fig. 7 / Table V).
    """
    from repro import aimc_device as AD
    from repro.core.aimc import AIMCConfig

    cfg = aimc_cfg or AIMCConfig()
    params, curves = two_stage_train(
        params, forward, data_fn, ct_steps=ct_steps, hwat_steps=hwat_steps,
        aimc_cfg=cfg, lr=lr, seed=seed, log_every=log_every,
    )
    key = jax.random.PRNGKey(seed + 2) if program_key is None else program_key
    return AD.program_tree(key, params, cfg), curves
