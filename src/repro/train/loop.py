"""Train-step factory: value_and_grad + microbatch accumulation + AdamW.

The returned ``train_step(params, opt_state, batch, rng)`` is a single pure
function lowered by the launcher/dry-run with pjit.  Features:

* microbatch gradient accumulation via ``lax.scan`` — besides fitting
  memory, it lets XLA's latency-hiding scheduler overlap the gradient
  reduce-scatter of microbatch i with the compute of microbatch i+1;
* optional EF-int8 gradient compression round trip (cross-pod wire format);
* moe aux-loss mixing, global-norm clipping, schedule inside the step.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models import transformer as T
from repro.models.moe import ParallelCtx
from repro.optim import adamw as A
from repro.optim import compression as C

Array = jax.Array


def make_loss(cfg: ModelConfig, pctx: ParallelCtx, parallel: ParallelConfig):
    def loss_f(params, batch, rng):
        loss, metrics = T.loss_fn(
            params, batch, cfg, pctx,
            moe_impl=parallel.moe_impl, remat=parallel.remat,
            rng=rng if cfg.spiking else None,
        )
        return loss, metrics

    return loss_f


def _split_microbatches(batch, n: int):
    return jax.tree.map(lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]), batch)


def make_train_step(
    cfg: ModelConfig,
    pctx: ParallelCtx,
    parallel: ParallelConfig,
    opt: A.AdamWConfig,
) -> Callable:
    loss_f = make_loss(cfg, pctx, parallel)
    grad_f = jax.value_and_grad(loss_f, has_aux=True)

    def train_step(params, opt_state, batch, rng):
        nmb = parallel.microbatches
        if nmb > 1:
            mb = _split_microbatches(batch, nmb)

            def acc(carry, xs):
                g_acc, l_acc = carry
                mb_i, kk = xs
                (loss, _), g = grad_f(params, mb_i, kk)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, g)
                return (g_acc, l_acc + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)
            keys = jax.random.split(rng, nmb)
            (grads, loss_sum), _ = jax.lax.scan(acc, (g0, jnp.float32(0)), (mb, keys))
            grads = jax.tree.map(lambda g: g / nmb, grads)
            loss = loss_sum / nmb
            metrics: Dict[str, Array] = {}
        else:
            (loss, metrics), grads = grad_f(params, batch, rng)

        if parallel.grad_dtype == "bfloat16":
            # cast before the cross-chip reduction: the data-parallel grad
            # all-reduce then moves bf16, not the fp32 loss-path cotangents
            grads = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)

        if parallel.grad_compression:
            # EF-int8 round trip (wire format of the cross-pod reduce)
            ef = opt_state.get("ef")
            grads, new_ef = C.compress_decompress(grads, ef)
        params, new_state, om = A.apply_updates(params, grads, opt_state, opt)
        if parallel.grad_compression:
            new_state["ef"] = new_ef
        out_metrics = {"loss": loss, **om}
        if metrics:
            out_metrics.update(metrics)
        return params, new_state, out_metrics

    return train_step


def init_state(key: Array, cfg: ModelConfig, opt: A.AdamWConfig, parallel: ParallelConfig):
    params = T.init_params(key, cfg)
    opt_state = A.init_opt_state(params, opt)
    if parallel.grad_compression:
        opt_state["ef"] = C.init_ef_state(params)
    return params, opt_state


def abstract_state(cfg: ModelConfig, opt: A.AdamWConfig, parallel: ParallelConfig):
    params = T.abstract_params(cfg)
    opt_state = A.abstract_opt_state(params, opt)
    if parallel.grad_compression:
        opt_state["ef"] = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.bfloat16), params
        )
    return params, opt_state
