"""The unified decode-kernel surface: KVView / AttnSpec / DecodePlan.

One small module, three dataclasses, one factory — so every consumer of
the spiking decode path (``models/transformer.py``, ``serving/scheduler``,
``distributed/backend.py``, ``launch/serve.py``) selects kernels from the
same place instead of branching on ``paged=`` flags and positional
``i_max``/``h0`` soup at each call site:

* :class:`KVView` — the K/V storage union a decode step attends over:
  a slot-dense spike-train cache (``page_table is None``) or a block-paged
  pool addressed through a per-slot page table.  Backends take a view and
  dispatch internally; callers stop caring which layout they hold.
* :class:`AttnSpec` — the static attention geometry: logical cache
  capacity ``i_max`` (the comparator PRN range), the tensor-parallel
  global-head offset ``h0``, and the GQA group factor.
* :class:`DecodePlan` — which kernel strategy a serving stack runs:
  ``kernel="fused"`` routes every decoder layer through the single
  megakernel launch (:mod:`repro.kernels.decode_fused`), ``"unfused"``
  keeps the per-primitive path.  Built once per scheduler by
  :func:`build_decode_plan` and closed over by the jitted decode step, so
  kernel selection can never cause a recompile mid-serve.

``build_decode_plan(cfg, backend, kernel="auto")`` resolves ``auto`` to
the fused megakernel exactly where it is supported (spiking SSA stacks of
pure attention blocks on a backend that implements
``decode_layer_fused``) and falls back to the unfused path elsewhere;
``kernel="fused"`` raises instead of silently degrading.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax

Array = jax.Array


@dataclasses.dataclass
class KVView:
    """What a decode step attends over — dense cache or paged pool.

    Dense: ``k``/``v`` are per-slot spike caches ``[B, T, L, KV, hd]``
    (uint8) and ``page_table`` is ``None``.  Paged: ``k``/``v`` are global
    page pools ``[n_pages, T, KV, page_len, hd]`` and ``page_table``
    ``[B, max_pages]`` (int32) maps each slot's logical blocks to physical
    pages (page 0 = the permanently-zero null page)."""

    k: Array
    v: Array
    page_table: Optional[Array] = None

    @property
    def paged(self) -> bool:
        return self.page_table is not None

    @classmethod
    def dense(cls, k: Array, v: Array) -> "KVView":
        return cls(k=k, v=v)

    @classmethod
    def from_pool(cls, kpool: Array, vpool: Array, page_table: Array) -> "KVView":
        return cls(k=kpool, v=vpool, page_table=page_table)


jax.tree_util.register_pytree_node(
    KVView,
    lambda view: ((view.k, view.v, view.page_table), None),
    lambda _, leaves: KVView(*leaves),
)


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    """Static decode-attention geometry.

    ``i_max`` — logical cache capacity: the exclusive upper bound of the
    per-position comparator PRN draws (``r_a ~ U{0..i_max-1}``), which must
    equal the *logical* cache length regardless of physical layout so dense
    and paged serving draw identical streams.  ``h0`` — global index of
    this caller's first head (tensor-parallel shards pass their offset so
    each shard draws exactly the single-device oracle's per-head streams).
    ``groups`` — GQA repeat factor (query heads per KV head), informational
    for dense views (callers pre-repeat) and shape-checked for paged."""

    i_max: int
    h0: Any = 0  # int, or a traced scalar inside shard_map bodies
    groups: int = 1


@dataclasses.dataclass(frozen=True)
class DecodePlan:
    """A resolved kernel strategy for one serving stack.

    Hashable and closure-static: the scheduler builds it once and the
    jitted decode step closes over it, preserving the one-compile-per-
    scheduler-lifetime invariant."""

    layout: str = "dense"  # "dense" | "paged"
    kernel: str = "unfused"  # "fused" | "unfused"
    page_len: int = 0  # tokens per KV page (paged layouts)
    reasons: Tuple[str, ...] = ()  # why auto resolved the way it did

    @property
    def fused(self) -> bool:
        return self.kernel == "fused"

    def describe(self) -> str:
        geo = f", page_len={self.page_len}" if self.layout == "paged" else ""
        why = f" ({'; '.join(self.reasons)})" if self.reasons else ""
        return f"DecodePlan({self.layout}, {self.kernel}{geo}){why}"


def _fused_supported(cfg, backend) -> Tuple[bool, str]:
    """Can this (config, backend) pair run the fused decode megakernel?"""
    if not (getattr(cfg, "spiking", False)
            and getattr(cfg, "attention_kind", "") == "ssa"):
        return False, "fused decode needs a spiking SSA config"
    if not all(m in ("attn", "local") for m in cfg.block_pattern):
        return False, f"non-attention mixers in pattern {cfg.block_pattern}"
    if getattr(cfg, "is_moe", False):
        return False, "MoE FFN tails decode on the rate interface"
    if backend is None or not callable(
            getattr(backend, "decode_layer_fused", None)):
        name = getattr(backend, "name", backend)
        return False, f"backend {name!r} has no decode_layer_fused"
    return True, "fused megakernel supported"


def build_decode_plan(cfg, backend=None, *, layout: str = "dense",
                      kernel: str = "auto", page_len: int = 8) -> DecodePlan:
    """Resolve one :class:`DecodePlan` for a serving stack.

    ``kernel``: ``"auto"`` picks the fused megakernel where supported and
    the unfused per-primitive path elsewhere; ``"fused"`` demands it (and
    raises ``ValueError`` when the config/backend cannot run it);
    ``"unfused"`` forces the per-primitive path.  ``layout`` mirrors the
    scheduler's ``paged=`` choice; ``page_len`` only matters for paged."""
    if layout not in ("dense", "paged"):
        raise ValueError(f"layout must be dense|paged, got {layout!r}")
    if kernel not in ("auto", "fused", "unfused"):
        raise ValueError(f"kernel must be auto|fused|unfused, got {kernel!r}")
    ok, why = _fused_supported(cfg, backend)
    if kernel == "fused" and not ok:
        raise ValueError(f"decode kernel 'fused' unsupported: {why}")
    resolved = "fused" if (kernel == "fused" or (kernel == "auto" and ok)) \
        else "unfused"
    return DecodePlan(layout=layout, kernel=resolved,
                      page_len=page_len if layout == "paged" else 0,
                      reasons=(why,))
