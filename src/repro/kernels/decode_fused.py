"""Pallas megakernel: one launch per spiking decoder layer (dense & paged).

The unfused decode path crosses LIF -> spiking-linear -> SSA-decode -> FFN
as separate ``pallas_call``s with bit-unpack/repack and HBM round-trips
between every primitive.  This module executes the *whole* decoder layer
per launch:

* spike trains are packed to uint32 bit-planes once at layer entry and
  stay packed in VMEM end to end (32 AND-gates per VPU op, popcount
  accumulation — the SSA engine's counter array, §IV-B);
* the T-loop is *outside* the head loop (per E2ATST's temporal-spatial
  dataflow analysis): each packed K/V operand is reused across all T
  timesteps of the step before the next head's operands are touched;
* Q/K/V projections, the one-query SSA row, attention-out and the FFN
  tail all run in scratch; nothing non-binary reaches HBM.

Dense layout: a single program (no grid) holding the step's whole
``[B, T, L, KV, hd]`` cache block.  Paged layout: grid ``(slot, page)``
riding the same scalar-prefetch page-table dereference as
``ssa_decode_paged_kernel`` — each program DMAs exactly one physical page
and popcount-accumulates into an int32 scratch across pages.

New-token handling is *additive* instead of scatter-inside-kernel: the
caller passes the **pre-scatter** cache (the row at each slot's ``pos``
is all-zero by the serving invariant), the kernel computes the new K/V
trains itself and adds their score/output contribution
``s_new * v_new`` on top of the cached counts.  Because a zero row
contributes zero AND-counts and ``0 > r`` never fires for the
non-negative comparator draws, this is bit-identical to attending over
the post-scatter cache.  The caller scatters the returned ``k_new`` /
``v_new`` afterwards.  Slots whose write position is masked (dense:
``pos >= L``; paged: the write page not reachable through the slot's
page table — e.g. idle slots parked on the trash page) get their
position comparator forced to an unbeatable value, matching the oracle's
dropped-scatter semantics.

Float-rounding discipline (see ``kernels/ref.py``): spike counts are
exact integers, so every dot is exact under any blocking; scale and bias
are committed as separate f32 roundings; membranes run through a value
carry (``fori_loop``), one committed rounding per step — bit-identical
to :func:`repro.kernels.ref.aimc_spiking_linear_ref` and hence to the
fused-layer oracles :func:`repro.kernels.ref.decode_layer_ref` /
:func:`repro.kernels.ref.decode_layer_paged_ref` under the property
harness.  ``interpret=True`` (the CPU test/bench path) executes these
bodies exactly; in-body padding/repeat keeps shapes free.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import ops as KOPS

Array = jax.Array

# a comparator draw no AND-count can beat: disables the new-token term
_INVALID_RS = 2 ** 30


def _pack_lanes(x: Array) -> Array:
    """Pack binary [..., n] (n % 32 == 0) into uint32 lanes (last axis)."""
    *lead, n = x.shape
    xr = x.reshape(*lead, n // 32, 32).astype(jnp.uint32)
    w = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(xr * w, axis=-1, dtype=jnp.uint32)


def _pad_last(x: Array, mult: int = 32) -> Array:
    pad = (-x.shape[-1]) % mult
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    return x


def _lif_chain(pre: Array, beta: float, v_thresh: float) -> Array:
    """LIF membrane recursion over the leading T axis, value-carried.

    Same committed op sequence per step as ``ref.lif_ref``'s ``lax.scan``
    (mul, add, compare, reset-multiply — each one f32 rounding), so the
    spike trains are bit-identical."""
    t = pre.shape[0]

    def step(ti, carry):
        v, out = carry
        cur = lax.dynamic_slice_in_dim(pre, ti, 1, axis=0)[0]
        v = beta * v + cur
        spk = (v >= v_thresh).astype(jnp.float32)
        out = lax.dynamic_update_slice_in_dim(out, spk[None], ti, axis=0)
        return v * (1.0 - spk), out

    _, out = lax.fori_loop(
        0, t, step,
        (jnp.zeros(pre.shape[1:], jnp.float32), jnp.zeros_like(pre)))
    return out


def _lin_lif(x: Array, w, *, beta: float, v_thresh: float) -> Array:
    """Quantised crossbar + LIF on [T, ..., d_in] integer-valued f32 input.

    ``w`` is an ``(int8 levels, f32 scale, f32 bias)`` triple.  Counts are
    exact integers (dot exact under any blocking); ``* scale`` and
    ``+ bias`` commit one rounding each, then the membrane chain — the
    oracle's exact float structure, for any batch slice of the input."""
    lv, sc, bi = w
    lead = x.shape[:-1]
    pre = jnp.dot(x.reshape(-1, x.shape[-1]), lv.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    pre = pre.reshape(*lead, -1)
    pre = pre * sc
    pre = pre + bi
    return _lif_chain(pre, beta, v_thresh)


def draw_layer_prns(slot_keys: Array, t: int, h: int, l: int, hd: int,
                    h0: Union[int, Array] = 0) -> Tuple[Array, Array]:
    """Per-(slot, global head) comparator draws for one fused layer step.

    Thin reshape over :func:`repro.kernels.ops.draw_slot_decode_prns`
    (same streams as the unfused path: ``r_s ~ U{0..hd-1}`` per cached
    position, ``r_a ~ U{0..L-1}`` per output lane, ``i_max = L``) to the
    fused kernels' ``rs [B,T,H,L]`` / ``ra [B,T,H,hd]`` layouts."""
    rs, ra = KOPS.draw_slot_decode_prns(slot_keys, t, h, l, hd, l, h0)
    b = slot_keys.shape[0]
    return rs.reshape(b, t, h, l), ra.reshape(b, t, h, hd)


def _rs_at_pos(rs4: Array, pos: Array, valid: Array) -> Array:
    """The score-comparator draw each slot's *new* token must beat.

    Gathers ``rs[b, :, :, pos[b]]`` — the draw the oracle's post-scatter
    cache row at ``pos`` sees — and forces it unbeatable where the write
    is masked, reproducing the oracle's dropped-scatter semantics."""
    l = rs4.shape[-1]
    idx = jnp.clip(pos, 0, l - 1).astype(jnp.int32)
    rsp = jnp.take_along_axis(rs4, idx[:, None, None, None], axis=3)[..., 0]
    return jnp.where(valid[:, None, None], rsp, jnp.int32(_INVALID_RS))


def _norm_w(w):
    lv, sc, bi = w
    if bi is None:
        bi = jnp.zeros_like(sc, dtype=jnp.float32)
    return (lv, sc.astype(jnp.float32), bi.astype(jnp.float32))


def _read_w(it):
    return (next(it)[...], next(it)[...], next(it)[...])


# ---------------------------------------------------------------------------
# Dense megakernel: one program per layer step
# ---------------------------------------------------------------------------


def _fused_dense_body(*refs, t: int, hd: int, h: int, kv: int,
                      with_tail: bool, with_mlp: bool,
                      beta: float, v_thresh: float):
    it = iter(refs)
    s_ref = next(it)
    sk_ref = next(it)
    sv_ref = next(it)
    rs_ref = next(it)
    ra_ref = next(it)
    rsp_ref = next(it)
    wq = _read_w(it)
    wk = _read_w(it)
    wv = _read_w(it)
    wo = _read_w(it) if with_tail else None
    wi = _read_w(it) if (with_tail and with_mlp) else None
    wo2 = _read_w(it) if (with_tail and with_mlp) else None
    out_ref = next(it)
    kn_ref = next(it)
    vn_ref = next(it)

    kw = dict(beta=beta, v_thresh=v_thresh)
    s = s_ref[...]  # [T, B, d] integer-valued f32
    b = s.shape[1]
    rep = h // kv

    # --- projections (packed spikes never leave this body) ---
    q = _lin_lif(s, wq, **kw).reshape(t, b, h, hd)
    k_new = _lin_lif(s, wk, **kw).reshape(t, b, kv, hd)
    v_new = _lin_lif(s, wv, **kw).reshape(t, b, kv, hd)
    kn_ref[...] = k_new.astype(jnp.uint8)
    vn_ref[...] = v_new.astype(jnp.uint8)

    # --- pack at layer entry: lanes along hd ---
    qp = _pack_lanes(_pad_last(jnp.moveaxis(q, 0, 1)))  # [B,T,H,Wd]
    kc = jnp.moveaxis(sk_ref[...], 3, 2)  # [B,T,KV,L,hd] u8
    vc = jnp.moveaxis(sv_ref[...], 3, 2)
    if rep > 1:
        kc = jnp.repeat(kc, rep, axis=2)
        vc = jnp.repeat(vc, rep, axis=2)
    kcp = _pack_lanes(_pad_last(kc))  # [B,T,H,L,Wd]

    # --- score stage: popcount(q & k_cache) vs r_s ---
    counts_s = jnp.sum(lax.population_count(qp[:, :, :, None, :] & kcp),
                       axis=-1).astype(jnp.int32)  # [B,T,H,L]
    s_spk = (counts_s > rs_ref[...]).astype(jnp.int32)

    # --- output stage: repack score spikes along the cache axis ---
    sp = _pack_lanes(_pad_last(s_spk))  # [B,T,H,Wl]
    vcp = _pack_lanes(_pad_last(jnp.moveaxis(vc, -2, -1)))  # [B,T,H,hd,Wl]
    counts_a = jnp.sum(lax.population_count(sp[:, :, :, None, :] & vcp),
                       axis=-1).astype(jnp.int32)  # [B,T,H,hd]

    # --- new token, additively (cache row at pos is zero pre-scatter) ---
    knp = _pack_lanes(_pad_last(jnp.moveaxis(k_new, 0, 1)))  # [B,T,KV,Wd]
    vnb = jnp.moveaxis(v_new, 0, 1).astype(jnp.int32)  # [B,T,KV,hd]
    if rep > 1:
        knp = jnp.repeat(knp, rep, axis=2)
        vnb = jnp.repeat(vnb, rep, axis=2)
    cnt_new = jnp.sum(lax.population_count(qp & knp),
                      axis=-1).astype(jnp.int32)  # [B,T,H]
    s_new = (cnt_new > rsp_ref[...]).astype(jnp.int32)
    counts_a = counts_a + s_new[..., None] * vnb

    a = (counts_a > ra_ref[...]).astype(jnp.float32)  # [B,T,H,hd]
    at = jnp.moveaxis(a, 0, 1).reshape(t, b, h * hd)

    if not with_tail:
        out_ref[...] = at
        return
    s1 = s + _lin_lif(at, wo, **kw)
    if with_mlp:
        h1 = _lin_lif(s1, wi, **kw)
        s1 = s1 + _lin_lif(h1, wo2, **kw)
    out_ref[...] = s1


@partial(jax.jit, static_argnames=("hd", "with_tail", "with_mlp", "beta",
                                   "v_thresh", "interpret"))
def fused_decode_layer(
    slot_keys: Array,  # [B, 2] uint32 per-slot PRNG keys
    s: Array,  # [T, B, d] integer-valued f32 residual spike stream
    sk: Array,  # [B, T, L, KV, hd] uint8 pre-scatter key cache
    sv: Array,  # [B, T, L, KV, hd] uint8 pre-scatter value cache
    pos: Array,  # [B] int32 write positions (rows >= pos are zero)
    wq, wk, wv,  # (levels int8 [d_in,d_out], scale f32, bias f32|None)
    wo=None, wi=None, wo2=None,
    h0: Union[int, Array] = 0,  # global index of this shard's first head
    *,
    hd: int,
    with_tail: bool = True,
    with_mlp: bool = True,
    beta: float = 0.5,
    v_thresh: float = 1.0,
    interpret: bool = True,
) -> Tuple[Array, Array, Array]:
    """One fused spiking decoder layer step over a dense slot cache.

    Returns ``(s_out [T,B,d], k_new [T,B,KV,hd] u8, v_new)`` — the caller
    scatters ``k_new``/``v_new`` into the cache at ``pos`` afterwards.
    ``with_tail=False`` returns the attention train ``a [T,B,H*hd]``
    instead (the tensor-parallel shard building block; ``h0`` names the
    shard's first global head and may be traced).  Bit-exact vs
    :func:`repro.kernels.ref.decode_layer_ref` given the same slot keys.
    """
    t, b, d = s.shape
    l, kv = sk.shape[2], sk.shape[3]
    wq, wk, wv = _norm_w(wq), _norm_w(wk), _norm_w(wv)
    h = wq[0].shape[1] // hd
    rs4, ra4 = draw_layer_prns(slot_keys, t, h, l, hd, h0)
    rsp = _rs_at_pos(rs4, pos, pos < l)
    operands = [s.astype(jnp.float32), sk.astype(jnp.uint8),
                sv.astype(jnp.uint8), rs4, ra4, rsp]
    operands += list(wq) + list(wk) + list(wv)
    if with_tail:
        operands += list(_norm_w(wo))
        if with_mlp:
            operands += list(_norm_w(wi)) + list(_norm_w(wo2))
    ds = d if with_tail else h * hd
    body = partial(_fused_dense_body, t=t, hd=hd, h=h, kv=kv,
                   with_tail=with_tail, with_mlp=with_mlp,
                   beta=beta, v_thresh=v_thresh)
    out_s, kn, vn = pl.pallas_call(
        body,
        out_shape=(
            jax.ShapeDtypeStruct((t, b, ds), jnp.float32),
            jax.ShapeDtypeStruct((t, b, kv, hd), jnp.uint8),
            jax.ShapeDtypeStruct((t, b, kv, hd), jnp.uint8),
        ),
        interpret=interpret,
    )(*operands)
    return out_s, kn, vn


# ---------------------------------------------------------------------------
# Paged megakernel: grid (slot, page-table column), page axis innermost
# ---------------------------------------------------------------------------


def _fused_paged_body(*refs, t: int, hd: int, h: int, kv: int,
                      with_tail: bool, with_mlp: bool,
                      beta: float, v_thresh: float):
    it = iter(refs)
    tbl_ref = next(it)  # scalar-prefetched page table (used by index maps)
    s_ref = next(it)  # [T, 1, d]
    kp_ref = next(it)  # [1, T, KV, PLp, Wd] u32 — one key page
    vp_ref = next(it)  # [1, T, KV, hd, Wp] u32 — one value page
    rs_ref = next(it)  # [1, T, H, 1, PLp]
    rsp_ref = next(it)  # [1, T, H]
    ra_ref = next(it)  # [1, T, H, hd]
    wq = _read_w(it)
    wk = _read_w(it)
    wv = _read_w(it)
    wo = _read_w(it) if with_tail else None
    wi = _read_w(it) if (with_tail and with_mlp) else None
    wo2 = _read_w(it) if (with_tail and with_mlp) else None
    out_ref = next(it)  # [T, 1, ds]
    kn_ref = next(it)  # [T, 1, KV, hd]
    vn_ref = next(it)
    qp_scr = next(it)  # VMEM [T, H, Wd] u32 — packed query, page-invariant
    acc_ref = next(it)  # VMEM [T, H, hd] i32 — output AND-count accumulator

    del tbl_ref  # consumed by the block index maps, not the body
    kw = dict(beta=beta, v_thresh=v_thresh)
    rep = h // kv
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _project():
        # Per-slot projections: LIF is elementwise over the batch, so the
        # B=1 slice is bit-identical to the full-batch oracle's row.
        s = s_ref[:, 0]  # [T, d]
        q = _lin_lif(s, wq, **kw).reshape(t, h, hd)
        k_new = _lin_lif(s, wk, **kw).reshape(t, kv, hd)
        v_new = _lin_lif(s, wv, **kw).reshape(t, kv, hd)
        kn_ref[:, 0] = k_new.astype(jnp.uint8)
        vn_ref[:, 0] = v_new.astype(jnp.uint8)
        qp = _pack_lanes(_pad_last(q))  # [T, H, Wd]
        qp_scr[...] = qp
        # new-token term, additively (see module docstring)
        knp = _pack_lanes(_pad_last(k_new))  # [T, KV, Wd]
        vnb = v_new.astype(jnp.int32)
        if rep > 1:
            knp = jnp.repeat(knp, rep, axis=1)
            vnb = jnp.repeat(vnb, rep, axis=1)
        cnt_new = jnp.sum(lax.population_count(qp & knp),
                          axis=-1).astype(jnp.int32)  # [T, H]
        s_new = (cnt_new > rsp_ref[0]).astype(jnp.int32)
        acc_ref[...] = s_new[..., None] * vnb

    # every page: popcount(q & k_page) vs this page's r_s slice, repack the
    # score spikes along the in-page axis, accumulate output AND-counts.
    # Integer sums commute, so page-order accumulation == dense reduction.
    qp = qp_scr[...]
    kp = kp_ref[0]  # [T, KV, PLp, Wd]
    vp = vp_ref[0]  # [T, KV, hd, Wp]
    if rep > 1:
        kp = jnp.repeat(kp, rep, axis=1)
        vp = jnp.repeat(vp, rep, axis=1)
    counts_s = jnp.sum(lax.population_count(qp[:, :, None, :] & kp),
                       axis=-1).astype(jnp.int32)  # [T, H, PLp]
    s_spk = (counts_s > rs_ref[0, :, :, 0]).astype(jnp.int32)
    sp = _pack_lanes(s_spk)  # [T, H, Wp] (PLp is a 32-multiple)
    acc_ref[...] += jnp.sum(lax.population_count(sp[:, :, None, :] & vp),
                            axis=-1).astype(jnp.int32)

    @pl.when(j == pl.num_programs(1) - 1)
    def _fire():
        a = (acc_ref[...] > ra_ref[0]).astype(jnp.float32)  # [T, H, hd]
        at = a.reshape(t, h * hd)
        if not with_tail:
            out_ref[:, 0] = at
            return
        s1 = s_ref[:, 0] + _lin_lif(at, wo, **kw)
        if with_mlp:
            h1 = _lin_lif(s1, wi, **kw)
            s1 = s1 + _lin_lif(h1, wo2, **kw)
        out_ref[:, 0] = s1


def _w_specs(wq, wk, wv, wo, wi, wo2):
    specs = []
    for w in (wq, wk, wv, wo, wi, wo2):
        if w is None:
            continue
        lv, sc, bi = w
        specs.append(pl.BlockSpec(lv.shape, lambda ib, j, tbl: (0, 0)))
        specs.append(pl.BlockSpec(sc.shape, lambda ib, j, tbl: (0,)))
        specs.append(pl.BlockSpec(bi.shape, lambda ib, j, tbl: (0,)))
    return specs


@partial(jax.jit, static_argnames=("hd", "with_tail", "with_mlp", "beta",
                                   "v_thresh", "interpret"))
def fused_decode_layer_paged(
    slot_keys: Array,  # [B, 2] uint32 per-slot PRNG keys
    s: Array,  # [T, B, d] integer-valued f32 residual spike stream
    kpool: Array,  # [P, T, KV, page_len, hd] uint8 pre-scatter key pool
    vpool: Array,  # [P, T, KV, page_len, hd] uint8 pre-scatter value pool
    page_table: Array,  # [B, MP] int32 page ids (0 = null page)
    pos: Array,  # [B] int32 logical write positions
    write_pids: Array,  # [B] int32 physical pages the new K/V scatter into
    wq, wk, wv,  # (levels int8, scale f32, bias f32|None) triples
    wo=None, wi=None, wo2=None,
    h0: Union[int, Array] = 0,
    *,
    hd: int,
    with_tail: bool = True,
    with_mlp: bool = True,
    beta: float = 0.5,
    v_thresh: float = 1.0,
    interpret: bool = True,
) -> Tuple[Array, Array, Array]:
    """One fused spiking decoder layer step over a block-paged KV pool.

    The paged twin of :func:`fused_decode_layer`: K/V pages ride the
    scalar-prefetch page-table grid (one physical page DMA'd per program,
    the dense cache never materialised), the output counts accumulate in
    VMEM scratch across pages, and the projections/FFN fire in the first/
    last page program of each slot.  The new token's contribution is
    added only where the write page is actually reachable through the
    slot's table (``table[b, pos // page_len] == write_pids[b]``) — idle
    slots park writes on the unreachable trash page, exactly the unfused
    paged semantics.  Bit-exact vs
    :func:`repro.kernels.ref.decode_layer_paged_ref`.
    """
    t, b, d = s.shape
    kv, pl_ = kpool.shape[2], kpool.shape[3]
    mp = page_table.shape[1]
    l = mp * pl_
    wq, wk, wv = _norm_w(wq), _norm_w(wk), _norm_w(wv)
    wo = _norm_w(wo) if with_tail else None
    wi = _norm_w(wi) if (with_tail and with_mlp) else None
    wo2 = _norm_w(wo2) if (with_tail and with_mlp) else None
    h = wq[0].shape[1] // hd
    rs4, ra4 = draw_layer_prns(slot_keys, t, h, l, hd, h0)
    reach = jnp.take_along_axis(
        page_table, jnp.clip(pos // pl_, 0, mp - 1)[:, None], axis=1)[:, 0]
    rsp = _rs_at_pos(rs4, pos, (pos < l) & (reach == write_pids))

    # pack the pools: K along hd lanes, V along the (padded) in-page axis
    p_pad = (-pl_) % 32
    plp = pl_ + p_pad
    kf = kpool.astype(jnp.uint8)
    vf = vpool.astype(jnp.uint8)
    if p_pad:
        pad5 = ((0, 0),) * 3 + ((0, p_pad), (0, 0))
        kf = jnp.pad(kf, pad5)
        vf = jnp.pad(vf, pad5)
    kpp = _pack_lanes(_pad_last(kf))  # [P,T,KV,PLp,Wd]
    vpp = _pack_lanes(jnp.moveaxis(vf, 3, -1))  # [P,T,KV,hd,Wp]
    wd, wp = kpp.shape[-1], vpp.shape[-1]
    rs5 = rs4.reshape(b, t, h, mp, pl_)
    if p_pad:  # padded positions: zero K spikes vs zero draws — 0 > 0 never
        rs5 = jnp.pad(rs5, ((0, 0),) * 4 + ((0, p_pad),))

    ds = d if with_tail else h * hd
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, mp),
        in_specs=[
            pl.BlockSpec((t, 1, d), lambda ib, j, tbl: (0, ib, 0)),
            pl.BlockSpec((1, t, kv, plp, wd),
                         lambda ib, j, tbl: (tbl[ib, j], 0, 0, 0, 0)),
            pl.BlockSpec((1, t, kv, hd, wp),
                         lambda ib, j, tbl: (tbl[ib, j], 0, 0, 0, 0)),
            pl.BlockSpec((1, t, h, 1, plp),
                         lambda ib, j, tbl: (ib, 0, 0, j, 0)),
            pl.BlockSpec((1, t, h), lambda ib, j, tbl: (ib, 0, 0)),
            pl.BlockSpec((1, t, h, hd), lambda ib, j, tbl: (ib, 0, 0, 0)),
        ] + _w_specs(wq, wk, wv, wo, wi, wo2),
        out_specs=[
            pl.BlockSpec((t, 1, ds), lambda ib, j, tbl: (0, ib, 0)),
            pl.BlockSpec((t, 1, kv, hd), lambda ib, j, tbl: (0, ib, 0, 0)),
            pl.BlockSpec((t, 1, kv, hd), lambda ib, j, tbl: (0, ib, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((t, h, wd), jnp.uint32),
            pltpu.VMEM((t, h, hd), jnp.int32),
        ],
    )
    body = partial(_fused_paged_body, t=t, hd=hd, h=h, kv=kv,
                   with_tail=with_tail, with_mlp=with_mlp,
                   beta=beta, v_thresh=v_thresh)
    operands = [s.astype(jnp.float32), kpp, vpp, rs5, rsp, ra4]
    operands += list(wq) + list(wk) + list(wv)
    if with_tail:
        operands += list(wo)
        if with_mlp:
            operands += list(wi) + list(wo2)
    out_s, kn, vn = pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=(
            jax.ShapeDtypeStruct((t, b, ds), jnp.float32),
            jax.ShapeDtypeStruct((t, b, kv, hd), jnp.uint8),
            jax.ShapeDtypeStruct((t, b, kv, hd), jnp.uint8),
        ),
        interpret=interpret,
    )(page_table.astype(jnp.int32), *operands)
    return out_s, kn, vn
