"""Pallas TPU kernel: fused LIF neuron over the spike-time axis.

The ASIC's LIF unit is a shift register (beta=0.5 right shift) + comparator
fed directly by crossbar partial sums (§IV-A-2).  The TPU analogue fuses
the whole T-step membrane recurrence into one kernel so the non-binary
membrane/current sequence never round-trips to HBM — the same
"no intermediate pre-activation storage" insight as the row-block-wise
mapping.

Grid tiles the flattened feature axis; each program loops T steps in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _lif_kernel(cur_ref, out_ref, *, t_steps: int, beta: float, v_thresh: float):
    # loop-carried membrane: the fori_loop carry commits one f32 rounding
    # per step, exactly like the oracle's lax.scan — a static unroll would
    # let the backend evaluate the whole T-step mul/add chain at wider
    # precision and flip threshold-straddling comparators vs lif_ref (see
    # kernels/ref.py "Float-rounding discipline")
    def step(t, v):
        cur = pl.load(cur_ref, (pl.ds(t, 1), slice(None)))[0].astype(jnp.float32)
        v = beta * v + cur
        spike = (v >= v_thresh).astype(jnp.float32)
        pl.store(out_ref, (pl.ds(t, 1), slice(None)),
                 spike.astype(out_ref.dtype)[None])
        return v * (1.0 - spike)

    jax.lax.fori_loop(0, t_steps, step,
                      jnp.zeros(cur_ref.shape[1:], jnp.float32))


def lif_kernel(
    currents: Array,  # [T, M] float
    *,
    beta: float = 0.5,
    v_thresh: float = 1.0,
    block: int = 4096,
    interpret: bool = False,
) -> Array:
    t, m = currents.shape
    block = min(block, m)
    assert m % block == 0, "ops.py pads the feature axis"
    kern = functools.partial(_lif_kernel, t_steps=t, beta=beta, v_thresh=v_thresh)
    return pl.pallas_call(
        kern,
        grid=(m // block,),
        in_specs=[pl.BlockSpec((t, block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((t, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((t, m), jnp.uint8),
        interpret=interpret,
    )(currents)
