"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function computes the same integer/bit-exact semantics as its kernel
from *unpacked* inputs.  Kernel tests sweep shapes/dtypes and
``assert_allclose`` (exact for the integer kernels) against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


# Float-rounding discipline for the LIF membrane contract
# --------------------------------------------------------
# The bit-exactness contract needs kernel and oracle to take *exactly* the
# same f32 roundings.  XLA only commits a rounding at materialisation
# points (buffer stores, loop carries) — inside a fused elementwise chain
# it may evaluate mul+add sequences at wider precision, and a membrane
# that truly sits within one ulp of the LIF threshold then flips its
# comparator depending on how the chain was fused (found by the
# property-based differential suite, tests/test_property_backends.py).
# The oracles below therefore run every membrane recursion through
# ``lax.scan`` (one committed rounding per step, at the carry) and
# materialise the scaled pre-activations before the scan; the Pallas
# kernels mirror that structure exactly — pre-activations stored to a VMEM
# ref (store = rounding), membrane carried through ``lax.fori_loop``.
# With ``beta`` a power of two (the hardware's shift-register decay) the
# remaining per-step expression ``beta*v + pre`` is a single add of
# committed f32 values, whose comparison against the threshold is exact
# real arithmetic — deterministic on every backend.


def ssa_attention_ref(
    q: Array,  # [G, N, D] binary int
    k: Array,  # [G, N, D]
    v: Array,  # [G, N, D]
    rs: Array,  # [G, N, N] int32 in [0, D)
    ra: Array,  # [G, N, D] int32 in [0, N)
    *,
    causal: bool = False,
) -> Array:
    """Bit-exact SSA tile semantics (Algorithm 1 with explicit LFSR input)."""
    qi = q.astype(jnp.int32)
    ki = k.astype(jnp.int32)
    vi = v.astype(jnp.int32)
    counts_s = jnp.einsum("gnd,gmd->gnm", qi, ki)
    if causal:
        n = q.shape[1]
        mask = jnp.tril(jnp.ones((n, n), jnp.int32))
        counts_s = counts_s * mask
    s = (counts_s > rs).astype(jnp.int32)
    counts_a = jnp.einsum("gnm,gmd->gnd", s, vi)
    return (counts_a > ra).astype(jnp.uint8)


def ssa_decode_ref(
    q: Array,  # [G, 1, D] binary int — the new token's query spikes
    k: Array,  # [G, L, D] cached key spike train (zero rows beyond pos)
    v: Array,  # [G, L, D] cached value spike train
    rs: Array,  # [G, 1, L] int32 in [0, D)
    ra: Array,  # [G, 1, D] int32 in [0, I_max)
) -> Array:
    """Bit-exact one-query SSA decode against a cached spike-train KV.

    The serving counterpart of :func:`ssa_attention_ref`: one stochastic
    attention row (the token being decoded) against the slot's whole KV
    cache.  No explicit validity mask is needed — positions beyond the
    slot's ``pos`` hold zero spikes, whose AND-counts are 0 and can never
    beat a non-negative comparator draw.  The output comparator range
    ``I_max`` is the *cache capacity* (the hardware tile dimension), fixed
    per §IV-B-2 regardless of how many cached tokens are valid.
    """
    qi = q.astype(jnp.int32)
    ki = k.astype(jnp.int32)
    vi = v.astype(jnp.int32)
    counts_s = jnp.einsum("gnd,gld->gnl", qi, ki)
    s = (counts_s > rs).astype(jnp.int32)
    counts_a = jnp.einsum("gnl,gld->gnd", s, vi)
    return (counts_a > ra).astype(jnp.uint8)


def gather_kv_pages_ref(pool: Array, page_table: Array) -> Array:
    """Materialise a slot-dense KV view from a paged spike-train pool.

    ``pool [P, T, KV, page_len, d]`` holds physical spike pages; ``page_table
    [B, MP]`` maps each slot's logical blocks to pages (entry 0 is the
    permanently-zero *null page*, so unallocated blocks read as all-zero
    spikes and mask themselves out of the SSA comparators).  Returns the
    dense ``[T, B, KV, MP*page_len, d]`` view a non-paged decode would see.
    """
    g = pool[page_table]  # [B, MP, T, KV, page_len, d]
    g = jnp.moveaxis(g, 2, 0)  # [T, B, MP, KV, page_len, d]
    g = jnp.swapaxes(g, 2, 3)  # [T, B, KV, MP, page_len, d]
    return g.reshape(g.shape[:3] + (-1, g.shape[-1]))


def ssa_decode_paged_ref(
    q: Array,  # [B, T, H, 1, D] binary — the new tokens' query spikes
    kpool: Array,  # [P, T, KV, page_len, D] key spike page pool
    vpool: Array,  # [P, T, KV, page_len, D] value spike page pool
    page_table: Array,  # [B, MP] int32 page ids (0 = null page)
    rs: Array,  # [B, T, H, 1, L] int32 in [0, D), L = MP*page_len
    ra: Array,  # [B, T, H, 1, D] int32 in [0, I_max)
) -> Array:
    """Bit-exact paged SSA decode: one query row against page-gathered KV.

    The block-paged counterpart of :func:`ssa_decode_ref`: each slot's
    cached K/V spike trains live in pool pages addressed through its page
    table, and the stochastic attention row reduces over the gathered
    logical positions in table order (page j covers logical positions
    ``[j*page_len, (j+1)*page_len)``), so given the same comparator
    integers the output is bit-identical to the dense oracle over the
    materialised cache.  Null-page (unallocated) positions hold zero
    spikes and can never beat a non-negative comparator draw.  GQA is
    folded here: KV heads repeat across the query-head group.
    """
    b, t, h = q.shape[:3]
    kv = kpool.shape[2]
    kf = gather_kv_pages_ref(kpool, page_table)  # [T, B, KV, L, D]
    vf = gather_kv_pages_ref(vpool, page_table)
    if kv != h:
        rep = h // kv
        kf = jnp.repeat(kf, rep, axis=2)
        vf = jnp.repeat(vf, rep, axis=2)
    qi = jnp.moveaxis(q, 1, 0).astype(jnp.int32)  # [T, B, H, 1, D]
    ki = kf.astype(jnp.int32)
    vi = vf.astype(jnp.int32)
    counts_s = jnp.einsum("tbhnd,tbhld->tbhnl", qi, ki)
    s = (counts_s > jnp.moveaxis(rs, 1, 0)).astype(jnp.int32)
    counts_a = jnp.einsum("tbhnl,tbhld->tbhnd", s, vi)
    out = (counts_a > jnp.moveaxis(ra, 1, 0)).astype(jnp.uint8)
    return jnp.moveaxis(out, 0, 1)  # [B, T, H, 1, D]


def lif_ref(currents: Array, *, beta: float = 0.5, v_thresh: float = 1.0) -> Array:
    """[T, M] currents -> [T, M] uint8 spikes (Eqs. 2-3)."""

    def step(v, i_t):
        v = beta * v + i_t.astype(jnp.float32)
        s = (v >= v_thresh).astype(jnp.float32)
        return v * (1.0 - s), s.astype(jnp.uint8)

    _, out = jax.lax.scan(step, jnp.zeros(currents.shape[1:], jnp.float32), currents)
    return out


def drift_requantize_ref(levels: Array, eps: Array, nu: Array, t_seconds,
                         *, t0: float, img_gain: int = 1) -> Array:
    """Digital execution image of a drifted PCM array (programmed-state fold).

    ``clip(round((levels + eps) * (max(t, t0)/t0)^-nu * img_gain))`` — the
    drifted analog conductances as the shared ADC re-digitises them onto
    the full int8 image grid (``img_gain`` integer steps per programming
    level).  The drift power is evaluated as exp/log so the Pallas
    ``drift_requantize_kernel`` executes the identical op sequence."""
    t = jnp.maximum(jnp.asarray(t_seconds, jnp.float32), t0)
    df = jnp.exp(-nu * jnp.log(t / t0))
    g = (levels + eps) * df * float(img_gain)
    return jnp.clip(jnp.round(g), -127, 127).astype(jnp.int8)


def aimc_programmed_linear_ref(
    spikes: Array,  # [T, B, d_in] binary
    levels: Array,  # [d_in, d_out] f32 programmed integer levels
    eps: Array,  # [d_in, d_out] f32 frozen programming error
    nu: Array,  # [d_in, d_out] f32 per-device drift exponents
    scale: Array,  # [d_out] f32 programmed per-column scale
    t_seconds,  # scalar device time
    gdc_gain,  # scalar global drift-compensation gain (stale between recals)
    bias: Array = None,
    *,
    t0: float,
    img_gain: int = 1,
    beta: float = 0.5,
    v_thresh: float = 1.0,
) -> Array:
    """Programmed-state spiking linear oracle: the digital-datapath
    semantics every backend must reproduce at a fixed device time.

    Drift + GDC fold into the two matmul operands — the int8 drifted image
    and the per-column f32 ``scale * gdc_gain / img_gain`` — then the LIF
    dynamics run exactly as in :func:`aimc_spiking_linear_ref`."""
    levels_t = drift_requantize_ref(levels, eps, nu, t_seconds, t0=t0,
                                    img_gain=img_gain)
    eff_scale = (scale * gdc_gain / float(img_gain)).astype(jnp.float32)
    return aimc_spiking_linear_ref(spikes, levels_t, eff_scale, bias,
                                   beta=beta, v_thresh=v_thresh)


def aimc_counts_ref(spikes: Array, w_levels: Array) -> Array:
    """[T,B,d_out] f32 integer-valued crossbar counts (pre-scale, pre-LIF).

    The shard-local half of a row-parallel spiking linear: partial counts
    from one d_in shard, exact under f32 addition (integer-valued), so the
    cross-shard psum reproduces the single-device accumulation bit-for-bit."""
    return jnp.einsum(
        "tbi,io->tbo", spikes.astype(jnp.float32), w_levels.astype(jnp.float32)
    )


def aimc_spiking_linear_ref(
    spikes: Array,  # [T, B, d_in] binary
    w_levels: Array,  # [d_in, d_out] int8
    scale: Array,  # [d_out] f32
    bias: Array = None,  # [d_out] f32 digital per-column bias
    *,
    beta: float = 0.5,
    v_thresh: float = 1.0,
) -> Array:
    """[T,B,d_out] uint8: LIF over per-timestep quantised crossbar MVMs."""
    pre = jnp.einsum(
        "tbi,io->tbo", spikes.astype(jnp.float32), w_levels.astype(jnp.float32)
    ) * scale[None, None, :]
    if bias is not None:
        pre = pre + bias.astype(jnp.float32)[None, None, :]

    def step(v, i_t):
        v = beta * v + i_t
        s = (v >= v_thresh).astype(jnp.float32)
        return v * (1.0 - s), s.astype(jnp.uint8)

    _, out = jax.lax.scan(step, jnp.zeros(pre.shape[1:], jnp.float32), pre)
    return out


# ---------------------------------------------------------------------------
# Fused decode layer (the megakernel oracle)
# ---------------------------------------------------------------------------


def _lin_lif_ref(x: Array, w, *, beta: float, v_thresh: float) -> Array:
    """One quantised crossbar + LIF stage on [T, B, d_in] integer-valued f32
    inputs; ``w`` is an (int8 levels, f32 scale, f32 bias | None) triple."""
    levels, scale, bias = w
    return aimc_spiking_linear_ref(
        x.astype(jnp.float32), levels, scale, bias,
        beta=beta, v_thresh=v_thresh).astype(jnp.float32)


def _ssa_decode_row_ref(q, kf, vf, k_new, v_new, pos, rs, ra):
    """One-query SSA over a *post-scatter* dense cache view.

    q [T,B,H,hd]; kf/vf [B,T,L,KV,hd] uint8 pre-scatter (zero rows at and
    beyond each slot's pos); k_new/v_new [T,B,KV,hd]; pos [B]; rs
    [B,T,H,L]; ra [B,T,H,hd].  Scatters the new token at ``pos`` and runs
    the exact integer comparator math of :func:`ssa_decode_ref` over the
    whole cache — the semantics the fused kernels must reproduce."""
    b = kf.shape[0]
    h, kv = q.shape[2], kf.shape[3]
    barange = jnp.arange(b)
    kf = kf.at[barange, :, pos].set(jnp.moveaxis(k_new, 0, 1).astype(jnp.uint8))
    vf = vf.at[barange, :, pos].set(jnp.moveaxis(v_new, 0, 1).astype(jnp.uint8))
    ki = jnp.transpose(kf, (0, 1, 3, 2, 4)).astype(jnp.int32)  # [B,T,KV,L,hd]
    vi = jnp.transpose(vf, (0, 1, 3, 2, 4)).astype(jnp.int32)
    if kv != h:
        rep = h // kv
        ki = jnp.repeat(ki, rep, axis=2)
        vi = jnp.repeat(vi, rep, axis=2)
    qi = jnp.moveaxis(q, 0, 1).astype(jnp.int32)  # [B,T,H,hd]
    counts_s = jnp.einsum("bthd,bthld->bthl", qi, ki)
    s = (counts_s > rs).astype(jnp.int32)
    counts_a = jnp.einsum("bthl,bthld->bthd", s, vi)
    a = (counts_a > ra).astype(jnp.float32)  # [B,T,H,hd]
    return jnp.moveaxis(a, 0, 1).reshape(q.shape[0], b, h * q.shape[3])


def decode_layer_ref(
    s: Array,  # [T, B, d] integer-valued f32 residual spike stream
    sk: Array,  # [B, T, L, KV, hd] uint8 pre-scatter key cache
    sv: Array,  # [B, T, L, KV, hd] uint8 pre-scatter value cache
    pos: Array,  # [B] int32 write position (rows >= pos must be zero)
    wq, wk, wv, wo, wi, wo2,  # (levels int8, scale f32, bias f32|None)
    rs: Array,  # [B, T, H, L] int32 comparator draws, U{0..hd-1}
    ra: Array,  # [B, T, H, hd] int32 comparator draws, U{0..i_max-1}
    *,
    hd: int,
    with_tail: bool = True,
    with_mlp: bool = True,
    beta: float = 0.5,
    v_thresh: float = 1.0,
):
    """Integer oracle for one fused spiking decoder layer (dense cache).

    Op-for-op the unfused decode path of ``models/transformer.py`` —
    Q/K/V spiking linears, scatter-at-pos, one-query SSA over the whole
    cache, attention-out, residual, FFN tail — composed from the same
    per-primitive oracles the backends are validated against, so
    integer-fused == integer-unfused by construction and the Pallas
    megakernel is fuzzed against this single function.

    Returns ``(s_out [T,B,d], k_new [T,B,KV,hd] uint8, v_new)``; with
    ``with_tail=False`` the first element is the attention spike train
    ``a [T,B,H*hd]`` instead (the tensor-parallel shard building block).
    """
    t, b, _ = s.shape
    kw = dict(beta=beta, v_thresh=v_thresh)
    q = _lin_lif_ref(s, wq, **kw).reshape(t, b, -1, hd)
    k_new = _lin_lif_ref(s, wk, **kw).reshape(t, b, -1, hd)
    v_new = _lin_lif_ref(s, wv, **kw).reshape(t, b, -1, hd)
    a = _ssa_decode_row_ref(q, sk, sv, k_new, v_new, pos, rs, ra)
    k_new = k_new.astype(jnp.uint8)
    v_new = v_new.astype(jnp.uint8)
    if not with_tail:
        return a, k_new, v_new
    s1 = s + _lin_lif_ref(a, wo, **kw)
    if with_mlp:
        h1 = _lin_lif_ref(s1, wi, **kw)
        s1 = s1 + _lin_lif_ref(h1, wo2, **kw)
    return s1, k_new, v_new


def decode_layer_paged_ref(
    s: Array,  # [T, B, d]
    kpool: Array,  # [P, T, KV, page_len, hd] uint8 pre-scatter page pool
    vpool: Array,  # [P, T, KV, page_len, hd]
    page_table: Array,  # [B, MP] int32 (0 = null page)
    pos: Array,  # [B] logical write positions
    write_pids: Array,  # [B] physical pages the new K/V trains scatter into
    wq, wk, wv, wo, wi, wo2,
    rs: Array,  # [B, T, H, L] int32, L = MP*page_len
    ra: Array,  # [B, T, H, hd] int32
    *,
    hd: int,
    with_tail: bool = True,
    with_mlp: bool = True,
    beta: float = 0.5,
    v_thresh: float = 1.0,
):
    """Paged mirror of :func:`decode_layer_ref`: scatter the new K/V into
    each slot's designated physical page, then attend over the page-table-
    gathered logical cache — exactly the unfused paged decode semantics
    (content reachable through the table; the trash page never is)."""
    t, b, _ = s.shape
    page_len = kpool.shape[3]
    kw = dict(beta=beta, v_thresh=v_thresh)
    q = _lin_lif_ref(s, wq, **kw).reshape(t, b, -1, hd)
    k_new = _lin_lif_ref(s, wk, **kw).reshape(t, b, -1, hd)
    v_new = _lin_lif_ref(s, wv, **kw).reshape(t, b, -1, hd)
    off = pos % page_len
    kp = kpool.at[write_pids, :, :, off].set(
        jnp.moveaxis(k_new, 0, 1).astype(jnp.uint8))
    vp = vpool.at[write_pids, :, :, off].set(
        jnp.moveaxis(v_new, 0, 1).astype(jnp.uint8))
    kf = gather_kv_pages_ref(kp, page_table)  # [T, B, KV, L, hd]
    vf = gather_kv_pages_ref(vp, page_table)
    h, kv = q.shape[2], kpool.shape[2]
    if kv != h:
        rep = h // kv
        kf = jnp.repeat(kf, rep, axis=2)
        vf = jnp.repeat(vf, rep, axis=2)
    qi = q.astype(jnp.int32)  # [T,B,H,hd]
    counts_s = jnp.einsum("tbhd,tbhld->tbhl", qi, kf.astype(jnp.int32))
    sp = (counts_s > jnp.moveaxis(rs, 1, 0)).astype(jnp.int32)
    counts_a = jnp.einsum("tbhl,tbhld->tbhd", sp, vf.astype(jnp.int32))
    a = (counts_a > jnp.moveaxis(ra, 1, 0)).astype(jnp.float32)
    a = a.reshape(t, b, -1)
    k_new = k_new.astype(jnp.uint8)
    v_new = v_new.astype(jnp.uint8)
    if not with_tail:
        return a, k_new, v_new
    s1 = s + _lin_lif_ref(a, wo, **kw)
    if with_mlp:
        h1 = _lin_lif_ref(s1, wi, **kw)
        s1 = s1 + _lin_lif_ref(h1, wo2, **kw)
    return s1, k_new, v_new
