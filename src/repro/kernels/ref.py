"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function computes the same integer/bit-exact semantics as its kernel
from *unpacked* inputs.  Kernel tests sweep shapes/dtypes and
``assert_allclose`` (exact for the integer kernels) against these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def ssa_attention_ref(
    q: Array,  # [G, N, D] binary int
    k: Array,  # [G, N, D]
    v: Array,  # [G, N, D]
    rs: Array,  # [G, N, N] int32 in [0, D)
    ra: Array,  # [G, N, D] int32 in [0, N)
    *,
    causal: bool = False,
) -> Array:
    """Bit-exact SSA tile semantics (Algorithm 1 with explicit LFSR input)."""
    qi = q.astype(jnp.int32)
    ki = k.astype(jnp.int32)
    vi = v.astype(jnp.int32)
    counts_s = jnp.einsum("gnd,gmd->gnm", qi, ki)
    if causal:
        n = q.shape[1]
        mask = jnp.tril(jnp.ones((n, n), jnp.int32))
        counts_s = counts_s * mask
    s = (counts_s > rs).astype(jnp.int32)
    counts_a = jnp.einsum("gnm,gmd->gnd", s, vi)
    return (counts_a > ra).astype(jnp.uint8)


def ssa_decode_ref(
    q: Array,  # [G, 1, D] binary int — the new token's query spikes
    k: Array,  # [G, L, D] cached key spike train (zero rows beyond pos)
    v: Array,  # [G, L, D] cached value spike train
    rs: Array,  # [G, 1, L] int32 in [0, D)
    ra: Array,  # [G, 1, D] int32 in [0, I_max)
) -> Array:
    """Bit-exact one-query SSA decode against a cached spike-train KV.

    The serving counterpart of :func:`ssa_attention_ref`: one stochastic
    attention row (the token being decoded) against the slot's whole KV
    cache.  No explicit validity mask is needed — positions beyond the
    slot's ``pos`` hold zero spikes, whose AND-counts are 0 and can never
    beat a non-negative comparator draw.  The output comparator range
    ``I_max`` is the *cache capacity* (the hardware tile dimension), fixed
    per §IV-B-2 regardless of how many cached tokens are valid.
    """
    qi = q.astype(jnp.int32)
    ki = k.astype(jnp.int32)
    vi = v.astype(jnp.int32)
    counts_s = jnp.einsum("gnd,gld->gnl", qi, ki)
    s = (counts_s > rs).astype(jnp.int32)
    counts_a = jnp.einsum("gnl,gld->gnd", s, vi)
    return (counts_a > ra).astype(jnp.uint8)


def lif_ref(currents: Array, *, beta: float = 0.5, v_thresh: float = 1.0) -> Array:
    """[T, M] currents -> [T, M] uint8 spikes (Eqs. 2-3)."""

    def step(v, i_t):
        v = beta * v + i_t.astype(jnp.float32)
        s = (v >= v_thresh).astype(jnp.float32)
        return v * (1.0 - s), s.astype(jnp.uint8)

    _, out = jax.lax.scan(step, jnp.zeros(currents.shape[1:], jnp.float32), currents)
    return out


def drift_requantize_ref(levels: Array, eps: Array, nu: Array, t_seconds,
                         *, t0: float, img_gain: int = 1) -> Array:
    """Digital execution image of a drifted PCM array (programmed-state fold).

    ``clip(round((levels + eps) * (max(t, t0)/t0)^-nu * img_gain))`` — the
    drifted analog conductances as the shared ADC re-digitises them onto
    the full int8 image grid (``img_gain`` integer steps per programming
    level).  The drift power is evaluated as exp/log so the Pallas
    ``drift_requantize_kernel`` executes the identical op sequence."""
    t = jnp.maximum(jnp.asarray(t_seconds, jnp.float32), t0)
    df = jnp.exp(-nu * jnp.log(t / t0))
    g = (levels + eps) * df * float(img_gain)
    return jnp.clip(jnp.round(g), -127, 127).astype(jnp.int8)


def aimc_programmed_linear_ref(
    spikes: Array,  # [T, B, d_in] binary
    levels: Array,  # [d_in, d_out] f32 programmed integer levels
    eps: Array,  # [d_in, d_out] f32 frozen programming error
    nu: Array,  # [d_in, d_out] f32 per-device drift exponents
    scale: Array,  # [d_out] f32 programmed per-column scale
    t_seconds,  # scalar device time
    gdc_gain,  # scalar global drift-compensation gain (stale between recals)
    bias: Array = None,
    *,
    t0: float,
    img_gain: int = 1,
    beta: float = 0.5,
    v_thresh: float = 1.0,
) -> Array:
    """Programmed-state spiking linear oracle: the digital-datapath
    semantics every backend must reproduce at a fixed device time.

    Drift + GDC fold into the two matmul operands — the int8 drifted image
    and the per-column f32 ``scale * gdc_gain / img_gain`` — then the LIF
    dynamics run exactly as in :func:`aimc_spiking_linear_ref`."""
    levels_t = drift_requantize_ref(levels, eps, nu, t_seconds, t0=t0,
                                    img_gain=img_gain)
    eff_scale = (scale * gdc_gain / float(img_gain)).astype(jnp.float32)
    return aimc_spiking_linear_ref(spikes, levels_t, eff_scale, bias,
                                   beta=beta, v_thresh=v_thresh)


def aimc_counts_ref(spikes: Array, w_levels: Array) -> Array:
    """[T,B,d_out] f32 integer-valued crossbar counts (pre-scale, pre-LIF).

    The shard-local half of a row-parallel spiking linear: partial counts
    from one d_in shard, exact under f32 addition (integer-valued), so the
    cross-shard psum reproduces the single-device accumulation bit-for-bit."""
    return jnp.einsum(
        "tbi,io->tbo", spikes.astype(jnp.float32), w_levels.astype(jnp.float32)
    )


def aimc_spiking_linear_ref(
    spikes: Array,  # [T, B, d_in] binary
    w_levels: Array,  # [d_in, d_out] int8
    scale: Array,  # [d_out] f32
    bias: Array = None,  # [d_out] f32 digital per-column bias
    *,
    beta: float = 0.5,
    v_thresh: float = 1.0,
) -> Array:
    """[T,B,d_out] uint8: LIF over per-timestep quantised crossbar MVMs."""
    pre = jnp.einsum(
        "tbi,io->tbo", spikes.astype(jnp.float32), w_levels.astype(jnp.float32)
    ) * scale[None, None, :]
    if bias is not None:
        pre = pre + bias.astype(jnp.float32)[None, None, :]

    def step(v, i_t):
        v = beta * v + i_t
        s = (v >= v_thresh).astype(jnp.float32)
        return v * (1.0 - s), s.astype(jnp.uint8)

    _, out = jax.lax.scan(step, jnp.zeros(pre.shape[1:], jnp.float32), pre)
    return out
