"""Pallas TPU kernel: stochastic spiking attention (SSA), bit-packed.

TPU adaptation of the paper's N x N array of stochastic attention cells
(§IV-B): the ASIC streams 1-bit Q/K/V through AND gates + counters; on TPU
we pack 32 timestep-lanes... no — we pack the *contraction axis* into
uint32 lanes so one VPU ``and`` + ``population_count`` replaces 32 AND
gates + counter increments:

  stage 1 (scores):   contraction over d_k  -> Q,K packed along d_k
  stage 2 (output):   contraction over n'   -> S packed in-kernel along n',
                                               V packed along n'

The Bernoulli comparators use *externally supplied* uniform random integers
(r_s in [0,d_k), r_a in [0,N)) — mirroring the SSA engine's shared LFSR
array feeding the tiles (§IV-B-3), and making the kernel bit-exact
reproducible against the pure-jnp oracle in ``ref.py``.

Grid: one program per (t, b*h) pair — the hardware pipelines timesteps
through the same stateless tile, we parallelise them.  Block shapes keep
the whole [N, N] score tile in VMEM (N <= 128 per the paper's edge-AI
sizing; ops.py tiles larger N).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _popcount(x: Array) -> Array:
    return lax.population_count(x)


def _pack_bits_kernel_axis(s: Array) -> Array:
    """Pack binary int32 [.., n, ..] -> uint32 along a *leading-of-last-two*
    axis inside the kernel: s [N, N] -> [N, N//32] (pack axis = -1)."""
    n = s.shape[-1]
    w = n // 32
    s3 = s.reshape(*s.shape[:-1], w, 32).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return jnp.sum(s3 * weights, axis=-1, dtype=jnp.uint32)


def _ssa_kernel(qp_ref, kp_ref, vp_ref, rs_ref, ra_ref, out_ref, *, n: int, d: int, causal: bool):
    """One (t, b*h) tile.

    qp [N, Wd] u32   — Q packed along d_k
    kp [N, Wd] u32   — K packed along d_k
    vp [Wn, D] u32   — V packed along n'
    rs [N, N] i32    — LFSR integers for the score comparators
    ra [N, D] i32    — LFSR integers for the output comparators
    out [N, D] u8    — binary attention output A^t
    """
    qp = qp_ref[0]
    kp = kp_ref[0]
    # stage 1: counts[i,j] = popcount_d(q_i & k_j)   (AND + counter, §IV-B-2)
    anded = qp[:, None, :] & kp[None, :, :]  # [N, N, Wd]
    counts_s = jnp.sum(_popcount(anded), axis=-1).astype(jnp.int32)
    if causal:
        ii = lax.broadcasted_iota(jnp.int32, (n, n), 0)
        jj = lax.broadcasted_iota(jnp.int32, (n, n), 1)
        counts_s = jnp.where(jj <= ii, counts_s, 0)
    s = (counts_s > rs_ref[0]).astype(jnp.int32)  # Bernoulli comparator

    # stage 2: pack S along n', AND with packed V, popcount over n'
    sp = _pack_bits_kernel_axis(s)  # [N, Wn]
    vp = vp_ref[0]  # [Wn, D]
    anded2 = sp[:, :, None] & vp[None, :, :]  # [N, Wn, D]
    counts_a = jnp.sum(_popcount(anded2), axis=1).astype(jnp.int32)
    out_ref[0] = (counts_a > ra_ref[0]).astype(jnp.uint8)


def _ssa_decode_body(qp_ref, kp_ref, vp_ref, rs_ref, ra_ref, out_ref):
    """One (b, t, h) decode cell: a single stochastic attention row.

    qp [1, Wd] u32   — the new token's query spikes, packed along d_k
    kp [L, Wd] u32   — cached key train, packed along d_k
    vp [Wl, D] u32   — cached value train, packed along the cache axis
    rs [1, L] i32    — LFSR integers for the score comparators
    ra [1, D] i32    — LFSR integers for the output comparators
    out [1, D] u8    — the token's binary attention output

    Invalid (not-yet-written / freed) cache rows are all-zero, so their
    AND-counts are 0 and never beat a comparator draw — validity masking
    is implicit, which is what lets one fixed-shape kernel serve every
    slot of a continuous batch regardless of per-slot position.
    """
    qp = qp_ref[0]  # [1, Wd]
    kp = kp_ref[0]  # [L, Wd]
    # stage 1: counts[j] = popcount_d(q & k_j)
    anded = qp & kp  # [L, Wd] (q broadcast over cache rows)
    counts_s = jnp.sum(_popcount(anded), axis=-1).astype(jnp.int32)[None, :]
    s = (counts_s > rs_ref[0]).astype(jnp.int32)  # [1, L]
    # stage 2: pack S along the cache axis, AND with packed V, popcount
    sp = _pack_bits_kernel_axis(s)  # [1, Wl]
    anded2 = jnp.swapaxes(sp, 0, 1) & vp_ref[0]  # [Wl, D]
    counts_a = jnp.sum(_popcount(anded2), axis=0).astype(jnp.int32)[None, :]
    out_ref[0] = (counts_a > ra_ref[0]).astype(jnp.uint8)


def ssa_decode_kernel(
    qp: Array,  # [G, 1, Wd] u32  (G = B*T*H fused grid axis)
    kp: Array,  # [G, L, Wd] u32
    vp: Array,  # [G, Wl, D] u32
    rs: Array,  # [G, 1, L] i32
    ra: Array,  # [G, 1, D] i32
    *,
    interpret: bool = False,
) -> Array:
    g, l, wd = kp.shape
    wl = vp.shape[1]
    d = vp.shape[2]
    return pl.pallas_call(
        _ssa_decode_body,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, 1, wd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, l, wd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, wl, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1, l), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((g, 1, d), jnp.uint8),
        interpret=interpret,
    )(qp, kp, vp, rs, ra)


def _ssa_decode_paged_body(tbl_ref, qp_ref, kp_ref, vp_ref, rs_ref, ra_ref,
                           out_ref, acc_ref):
    """One (slot, timestep, head, page) paged decode cell.

    The stochastic attention row of :func:`_ssa_decode_body`, decomposed
    over the slot's KV pages: the grid's last axis walks the slot's page
    table (the K/V block specs gather page ``tbl[b, j]`` straight from the
    physical pool via scalar-prefetch index maps — the dense cache is never
    materialised), and the output AND-counts accumulate in ``acc_ref``
    across pages.  This is exact: the score comparator is elementwise per
    cached position (no cross-position normalisation in SSA), and the
    output counts are integer sums, so any page-order accumulation
    reproduces the dense reduction bit-for-bit.

    qp [1, Wd] u32    — the new token's query spikes, packed along d_k
    kp [PLp, Wd] u32  — ONE key page (gathered through the page table)
    vp [Wp, D] u32    — one value page, packed along the in-page position
    rs [1, PLp] i32   — this page's slice of the score-comparator integers
    ra [1, D] i32     — output-comparator integers (page-invariant)
    acc [1, D] i32    — output AND-count accumulator (VMEM scratch)
    out [1, D] u8     — binary attention output, written at the last page
    """
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qp = qp_ref[0, 0, 0]  # [1, Wd]
    kp = kp_ref[0, 0, 0]  # [PLp, Wd]
    counts_s = jnp.sum(_popcount(qp & kp), axis=-1).astype(jnp.int32)[None, :]
    s = (counts_s > rs_ref[0, 0, 0]).astype(jnp.int32)  # [1, PLp]
    sp = _pack_bits_kernel_axis(s)  # [1, Wp]
    anded = jnp.swapaxes(sp, 0, 1) & vp_ref[0, 0, 0]  # [Wp, D]
    acc_ref[...] += jnp.sum(_popcount(anded), axis=0).astype(jnp.int32)[None, :]

    @pl.when(j == pl.num_programs(3) - 1)
    def _fire():
        out_ref[0, 0, 0] = (acc_ref[...] > ra_ref[0, 0, 0]).astype(jnp.uint8)


def ssa_decode_paged_kernel(
    page_table: Array,  # [B, MP] i32 page ids (scalar-prefetched)
    qp: Array,  # [B, T, H, 1, Wd] u32
    kp: Array,  # [P, T, KV, PLp, Wd] u32 — the physical key page pool
    vp: Array,  # [P, T, KV, Wp, D] u32 — value pool, packed along position
    rs: Array,  # [B, T, H, 1, MP*PLp] i32
    ra: Array,  # [B, T, H, 1, D] i32
    *,
    interpret: bool = False,
) -> Array:
    """Paged SSA decode: grid (slot, timestep, head, page-table column).

    The page table rides scalar prefetch so the K/V block index maps can
    dereference it — each program DMAs exactly one physical page out of the
    pool, never a dense per-slot cache.  GQA is folded into the index maps
    (query head ``ih`` reads KV head ``ih // (H // KV)``)."""
    b, t, h, _, wd = qp.shape
    mp = page_table.shape[1]
    plp = kp.shape[3]
    wp, d = vp.shape[3], vp.shape[4]
    rep = h // kp.shape[2]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, t, h, mp),
        in_specs=[
            pl.BlockSpec((1, 1, 1, 1, wd), lambda ib, it, ih, j, tbl: (ib, it, ih, 0, 0)),
            pl.BlockSpec((1, 1, 1, plp, wd),
                         lambda ib, it, ih, j, tbl: (tbl[ib, j], it, ih // rep, 0, 0)),
            pl.BlockSpec((1, 1, 1, wp, d),
                         lambda ib, it, ih, j, tbl: (tbl[ib, j], it, ih // rep, 0, 0)),
            pl.BlockSpec((1, 1, 1, 1, plp), lambda ib, it, ih, j, tbl: (ib, it, ih, 0, j)),
            pl.BlockSpec((1, 1, 1, 1, d), lambda ib, it, ih, j, tbl: (ib, it, ih, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, 1, d),
                               lambda ib, it, ih, j, tbl: (ib, it, ih, 0, 0)),
        scratch_shapes=[pltpu.VMEM((1, d), jnp.int32)],
    )
    return pl.pallas_call(
        _ssa_decode_paged_body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, t, h, 1, d), jnp.uint8),
        interpret=interpret,
    )(page_table, qp, kp, vp, rs, ra)


def ssa_attention_kernel(
    qp: Array,  # [G, N, Wd] u32  (G = T*B*H fused grid axis)
    kp: Array,  # [G, N, Wd] u32
    vp: Array,  # [G, Wn, D] u32
    rs: Array,  # [G, N, N] i32
    ra: Array,  # [G, N, D] i32
    *,
    n: int,
    d: int,
    causal: bool,
    interpret: bool = False,
) -> Array:
    g, _, wd = qp.shape
    wn = vp.shape[1]
    kern = functools.partial(_ssa_kernel, n=n, d=d, causal=causal)
    return pl.pallas_call(
        kern,
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, n, wd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, wd), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, wn, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, n, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((g, n, d), jnp.uint8),
        interpret=interpret,
    )(qp, kp, vp, rs, ra)
