"""Pallas TPU kernel: fused AIMC spiking linear (crossbar MVM + LIF over T).

Maps the paper's spiking-neuron tile (§IV-A) onto the TPU memory
hierarchy:

  PCM crossbar 128x128 tiles       ->  128x128 VMEM weight blocks (int8
                                       5-bit levels, per-column f32 scale)
  O(1) analog MVM                  ->  MXU dot per timestep
  row-block partial sums -> CSA    ->  in-register f32 accumulation over
                                       the d_in grid axis ("arbitrary"
                                       revisiting order, accumulate into
                                       the output block)
  LIF shift-register + comparator  ->  fused membrane update on the last
                                       d_in block — the T non-binary
                                       pre-activations NEVER reach HBM,
                                       which is exactly the row-block-wise
                                       mapping's point (§IV-A-2).

Grid: (batch tiles, d_out tiles, d_in tiles); the d_in axis is the
innermost (sequential) axis so the membrane/current scratch lives in VMEM
across the accumulation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _kernel(s_ref, w_ref, scale_ref, bias_ref, out_ref, acc_ref, *, t_steps: int,
            n_in_blocks: int, beta: float, v_thresh: float):
    ib = pl.program_id(2)

    @pl.when(ib == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[...].astype(jnp.float32)  # [bin, bout] int8 levels
    for t in range(t_steps):
        st = s_ref[t].astype(jnp.float32)  # [bb, bin] binary spikes
        acc_ref[t] = acc_ref[t] + jnp.dot(st, w, preferred_element_type=jnp.float32)

    @pl.when(ib == n_in_blocks - 1)
    def _fire():
        scale = scale_ref[...].astype(jnp.float32)  # [bout]
        bias = bias_ref[...].astype(jnp.float32)  # [bout], digital per-column
        # mirror the oracle's rounding structure exactly (see
        # kernels/ref.py "Float-rounding discipline"): first COMMIT the
        # scaled pre-activations — the store to the VMEM scratch is a
        # materialisation, i.e. one f32 rounding of counts*scale+bias,
        # matching the oracle's pre array — then run the membrane through
        # a loop CARRY (one committed rounding per step, like lax.scan).
        # A fully unrolled chain would let the backend keep the whole
        # T-step recursion at wider precision and flip comparators whose
        # membrane sits within one ulp of v_thresh (found by the
        # property-based differential suite).
        for t in range(t_steps):
            acc_ref[t] = acc_ref[t] * scale[None, :] + bias[None, :]

        def step(t, v):
            pre = pl.load(acc_ref, (pl.ds(t, 1), slice(None), slice(None)))[0]
            v = beta * v + pre
            spike = (v >= v_thresh).astype(jnp.float32)
            pl.store(out_ref, (pl.ds(t, 1), slice(None), slice(None)),
                     spike.astype(out_ref.dtype)[None])
            return v * (1.0 - spike)

        jax.lax.fori_loop(0, t_steps, step,
                          jnp.zeros(acc_ref.shape[1:], jnp.float32))


def _counts_kernel(s_ref, w_ref, out_ref, acc_ref, *, t_steps: int,
                   n_in_blocks: int):
    """Crossbar MVM accumulation only — no scale/bias/LIF.

    The shard-local half of a *row-parallel* (d_in-sharded) spiking linear:
    each mesh shard accumulates its rows' integer spike counts, the counts
    are psum'd across the ``model`` axis, and the LIF dynamics fire once on
    the reduced currents (see ``repro.distributed.backend``).  Keeping the
    partial sums in integer-valued f32 makes the cross-shard reduction
    exact, so sharded == single-device bit-for-bit."""
    ib = pl.program_id(2)

    @pl.when(ib == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = w_ref[...].astype(jnp.float32)  # [bin, bout] int8 levels
    for t in range(t_steps):
        st = s_ref[t].astype(jnp.float32)  # [bb, bin] binary spikes
        acc_ref[t] = acc_ref[t] + jnp.dot(st, w, preferred_element_type=jnp.float32)

    @pl.when(ib == n_in_blocks - 1)
    def _flush():
        for t in range(t_steps):
            out_ref[t] = acc_ref[t]


def aimc_matmul_counts_kernel(
    spikes: Array,  # [T, B, d_in] binary (any float/int dtype)
    w_levels: Array,  # [d_in, d_out] int8 conductance levels
    *,
    block_b: int = 128,
    block_in: int = 128,
    block_out: int = 128,
    interpret: bool = False,
) -> Array:
    """[T, B, d_out] f32 integer-valued crossbar counts (pre-LIF)."""
    t, b, d_in = spikes.shape
    d_out = w_levels.shape[1]
    block_b = min(block_b, b)
    block_in = min(block_in, d_in)
    block_out = min(block_out, d_out)
    assert b % block_b == 0 and d_in % block_in == 0 and d_out % block_out == 0
    nb, ni, no = b // block_b, d_in // block_in, d_out // block_out
    kern = functools.partial(_counts_kernel, t_steps=t, n_in_blocks=ni)
    return pl.pallas_call(
        kern,
        grid=(nb, no, ni),  # d_in innermost: sequential accumulation
        in_specs=[
            pl.BlockSpec((t, block_b, block_in), lambda ib, io, ii: (0, ib, ii)),
            pl.BlockSpec((block_in, block_out), lambda ib, io, ii: (ii, io)),
        ],
        out_specs=pl.BlockSpec((t, block_b, block_out), lambda ib, io, ii: (0, ib, io)),
        out_shape=jax.ShapeDtypeStruct((t, b, d_out), jnp.float32),
        scratch_shapes=[pltpu.VMEM((t, block_b, block_out), jnp.float32)],
        interpret=interpret,
    )(spikes, w_levels)


def _requant_kernel(t_ref, lv_ref, eps_ref, nu_ref, out_ref, *, t0: float,
                    img_gain: int):
    """Drift-requantise one [block_in, block_out] crossbar tile.

    The calibration-time fold of the programmed-state path: re-digitise the
    drifted analog conductances ``(levels + eps) * (t/t0)^-nu`` onto the
    full int8 image grid (``img_gain`` steps per programming level), so the
    execution hot loop stays a plain int8 MXU matmul.  The op sequence
    (maximum, exp/log power, gain, round, clip) matches
    ``repro.aimc_device._requantize`` / ``kernels.ref.drift_requantize_ref``
    exactly — bit-exactness of the fold is part of the kernel contract."""
    t = jnp.maximum(t_ref[0], t0)
    df = jnp.exp(-nu_ref[...] * jnp.log(t / t0))
    g = (lv_ref[...] + eps_ref[...]) * df * float(img_gain)
    out_ref[...] = jnp.clip(jnp.round(g), -127, 127).astype(jnp.int8)


def drift_requantize_kernel(
    levels: Array,  # [d_in, d_out] f32 programmed integer levels
    eps: Array,  # [d_in, d_out] f32 programming error (level units)
    nu: Array,  # [d_in, d_out] f32 per-device drift exponents
    t_seconds: Array,  # [1] f32 device time (traced — no recompile on change)
    *,
    t0: float,
    img_gain: int = 1,
    block_in: int = 128,
    block_out: int = 128,
    interpret: bool = False,
) -> Array:
    d_in, d_out = levels.shape
    block_in = min(block_in, d_in)
    block_out = min(block_out, d_out)
    assert d_in % block_in == 0 and d_out % block_out == 0
    kern = functools.partial(_requant_kernel, t0=t0, img_gain=img_gain)
    tile = pl.BlockSpec((block_in, block_out), lambda i, j: (i, j))
    return pl.pallas_call(
        kern,
        grid=(d_in // block_in, d_out // block_out),
        in_specs=[pl.BlockSpec((1,), lambda i, j: (0,)), tile, tile, tile],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((d_in, d_out), jnp.int8),
        interpret=interpret,
    )(t_seconds, levels, eps, nu)


def aimc_spiking_linear_kernel(
    spikes: Array,  # [T, B, d_in] binary (any float/int dtype)
    w_levels: Array,  # [d_in, d_out] int8 (5-bit conductance-pair levels)
    scale: Array,  # [d_out] f32 per-column scale
    bias: Array,  # [d_out] f32 digital bias added to each timestep's current
    *,
    beta: float = 0.5,
    v_thresh: float = 1.0,
    block_b: int = 128,
    block_in: int = 128,
    block_out: int = 128,
    interpret: bool = False,
) -> Array:
    t, b, d_in = spikes.shape
    d_out = w_levels.shape[1]
    block_b = min(block_b, b)
    block_in = min(block_in, d_in)
    block_out = min(block_out, d_out)
    assert b % block_b == 0 and d_in % block_in == 0 and d_out % block_out == 0
    nb, ni, no = b // block_b, d_in // block_in, d_out // block_out
    kern = functools.partial(
        _kernel, t_steps=t, n_in_blocks=ni, beta=beta, v_thresh=v_thresh
    )
    return pl.pallas_call(
        kern,
        grid=(nb, no, ni),  # d_in innermost: sequential accumulation
        in_specs=[
            pl.BlockSpec((t, block_b, block_in), lambda ib, io, ii: (0, ib, ii)),
            pl.BlockSpec((block_in, block_out), lambda ib, io, ii: (ii, io)),
            pl.BlockSpec((block_out,), lambda ib, io, ii: (io,)),
            pl.BlockSpec((block_out,), lambda ib, io, ii: (io,)),
        ],
        out_specs=pl.BlockSpec((t, block_b, block_out), lambda ib, io, ii: (0, ib, io)),
        out_shape=jax.ShapeDtypeStruct((t, b, d_out), jnp.uint8),
        # per-timestep pre-activation accumulator lives in VMEM across the
        # sequential d_in grid axis — never written to HBM
        scratch_shapes=[pltpu.VMEM((t, block_b, block_out), jnp.float32)],
        interpret=interpret,
    )(spikes, w_levels, scale, bias)
