"""Jit'd public wrappers for the Pallas kernels: packing, padding, PRNs.

These are what the framework calls; each wrapper
* packs binary spike operands into uint32 lanes (32 AND-gates per VPU op),
* pads shapes to kernel block multiples,
* draws the comparator integers from a counter-based PRNG (the software
  stand-in for the SSA engine's shared 32-bit LFSR array — all four bytes
  of each word are used, per §IV-B-3 / core.spikes.split_prn_bytes),
* and exposes ``interpret=`` so CPU tests execute the kernel body exactly.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.kernels.aimc_matmul import (aimc_matmul_counts_kernel,
                                       aimc_spiking_linear_kernel,
                                       drift_requantize_kernel)
from repro.kernels.lif import lif_kernel
from repro.kernels.ssa_attention import (ssa_attention_kernel,
                                         ssa_decode_kernel,
                                         ssa_decode_paged_kernel)

Array = jax.Array


def pack_bits(x: Array, axis: int = -1) -> Array:
    """Pack a binary array into uint32 along ``axis`` (size % 32 == 0)."""
    x = jnp.moveaxis(x, axis, -1)
    *lead, n = x.shape
    w = n // 32
    xr = x.reshape(*lead, w, 32).astype(jnp.uint32)
    weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    packed = jnp.sum(xr * weights, axis=-1, dtype=jnp.uint32)
    return jnp.moveaxis(packed, -1, axis)


def unpack_bits(x: Array, n: int, axis: int = -1) -> Array:
    xm = jnp.moveaxis(x, axis, -1)
    bits = (xm[..., :, None] >> jnp.arange(32, dtype=jnp.uint32)) & jnp.uint32(1)
    out = bits.reshape(*xm.shape[:-1], xm.shape[-1] * 32)[..., :n]
    return jnp.moveaxis(out.astype(jnp.uint8), -1, axis)


def draw_comparator_prns(key: Array, shape_s: Tuple[int, ...], shape_a: Tuple[int, ...],
                         d: int, n: int) -> Tuple[Array, Array]:
    """Uniform integers for the two Bernoulli comparator banks.

    r_s ~ U{0..d-1}, r_a ~ U{0..n-1}; with d and n powers of two these are
    exactly the low bits of an LFSR word (§IV-B-2)."""
    k1, k2 = jax.random.split(key)
    rs = jax.random.randint(k1, shape_s, 0, d, dtype=jnp.int32)
    ra = jax.random.randint(k2, shape_a, 0, n, dtype=jnp.int32)
    return rs, ra


@partial(jax.jit, static_argnames=("causal", "interpret"))
def ssa_attention_packed(
    q: Array,  # [T, B, H, N, D] binary (any int/float in {0,1})
    k: Array,
    v: Array,
    key: Array,
    *,
    causal: bool = False,
    interpret: bool = True,
) -> Array:
    """Bit-packed SSA attention; returns uint8 spikes [T,B,H,N,D].

    N and D may be arbitrary: the wrapper zero-pads both pack axes up to
    multiples of 32 and slices the result back.  The comparator PRNs are
    drawn at the *logical* (unpadded) shapes with the *logical* ranges
    (r_s ~ U{0..d-1}, r_a ~ U{0..n-1}) so the output is bit-identical to
    the unpadded integer oracle given the same key — padded q/k rows and
    v columns are all-zero, so their AND-counts are 0 and can never beat
    a non-negative comparator draw."""
    t, b, h, n, d = q.shape
    g = t * b * h
    # comparator integers at logical shapes/ranges (bit-exactness contract)
    rs, ra = draw_comparator_prns(key, (g, n, n), (g, n, d), d, n)
    n_pad = (-n) % 32
    d_pad = (-d) % 32
    np_, dp_ = n + n_pad, d + d_pad
    qf = q.reshape(g, n, d).astype(jnp.uint8)
    kf = k.reshape(g, n, d).astype(jnp.uint8)
    vf = v.reshape(g, n, d).astype(jnp.uint8)
    if n_pad or d_pad:
        pad = ((0, 0), (0, n_pad), (0, d_pad))
        qf = jnp.pad(qf, pad)
        kf = jnp.pad(kf, pad)
        vf = jnp.pad(vf, pad)
        rs = jnp.pad(rs, ((0, 0), (0, n_pad), (0, n_pad)))
        ra = jnp.pad(ra, ((0, 0), (0, n_pad), (0, d_pad)))
    qp = pack_bits(qf, axis=-1)  # [G, N, D/32]
    kp = pack_bits(kf, axis=-1)
    vp = pack_bits(vf, axis=-2)  # pack over n': [G, N/32, D]
    out = ssa_attention_kernel(
        qp, kp, vp, rs, ra, n=np_, d=dp_, causal=causal, interpret=interpret
    )
    return out[:, :n, :d].reshape(t, b, h, n, d)


def draw_slot_decode_prns(
    slot_keys: Array,  # [B, 2] uint32 — per-slot PRNG keys
    t: int, h: int, l: int, d: int, i_max: int,
    h0: Union[int, Array] = 0,
) -> Tuple[Array, Array]:
    """Per-(slot, head) comparator integers for one SSA decode step.

    Each serving slot draws from its *own* key so the stream a request sees
    depends only on (request seed, position) — never on which other
    requests share the batch.  That is the bit-exactness contract of
    continuous batching: admitting a request mid-flight cannot perturb the
    spikes of already-running slots.

    Within a slot, every attention head draws from ``fold_in(slot_key,
    global_head_index)`` — the stream is ``f(seed, pos, head)``.  Per-head
    keying is what makes *tensor-parallel* decode bit-exact: a shard that
    owns heads ``[h0, h0+h)`` of a mesh-sharded SSA engine passes its
    global head offset ``h0`` (possibly traced, e.g. derived from
    ``lax.axis_index``) and draws exactly the integers the single-device
    oracle draws for those heads (see ``repro.distributed``).

    Returns ``(rs [B,T*H,1,L], ra [B,T*H,1,D])`` — t-major over the T*H
    axis, matching the (b, t, h) grid order of the packed decode wrapper —
    with r_s ~ U{0..d-1}, r_a ~ U{0..i_max-1}.
    """
    heads = jnp.asarray(h0) + jnp.arange(h)

    def per_slot(key):
        def per_head(hi):
            kh = jax.random.fold_in(key, hi)
            return draw_comparator_prns(kh, (t, 1, l), (t, 1, d), d, i_max)

        rs, ra = jax.vmap(per_head)(heads)  # [H, T, 1, *]
        return (jnp.moveaxis(rs, 0, 1).reshape(t * h, 1, l),
                jnp.moveaxis(ra, 0, 1).reshape(t * h, 1, d))

    return jax.vmap(per_slot)(slot_keys)


@partial(jax.jit, static_argnames=("i_max", "interpret"))
def ssa_attention_decode_packed(
    q: Array,  # [T, B, H, 1, D] binary — the new token's query spikes
    k: Array,  # [T, B, H, L, D] cached key spike train (zeros beyond pos)
    v: Array,  # [T, B, H, L, D] cached value spike train
    slot_keys: Array,  # [B, 2] uint32 per-slot PRNG keys
    h0: Union[int, Array] = 0,  # global index of q's first head (TP shards)
    *,
    i_max: int,
    interpret: bool = True,
) -> Array:
    """Bit-packed SSA decode step; returns uint8 spikes [T,B,H,1,D].

    The serving entry point for the popcount SSA tile: one query row per
    (slot, timestep, head) against that slot's cached KV train.  L and D
    are zero-padded to multiples of 32 (zero spikes never beat a
    comparator draw, exactly the :func:`ssa_attention_packed` argument);
    the comparator PRNs are drawn per (slot, global head) at logical
    shapes so the output is bit-identical to the unpadded integer oracle —
    independent of which other slots are in flight *and* of how the heads
    are sharded across a mesh (``h0`` names the shard's first global head;
    it may be traced, e.g. ``lax.axis_index(...) * h_local``).
    """
    t, b, h, n1, d = q.shape
    l = k.shape[3]
    rs, ra = draw_slot_decode_prns(slot_keys, t, h, l, d, i_max, h0)
    g = b * t * h
    # grid order (b, t, h): matches the [B, T*H, ...] PRN layout
    qf = jnp.moveaxis(q, 1, 0).reshape(g, 1, d).astype(jnp.uint8)
    kf = jnp.moveaxis(k, 1, 0).reshape(g, l, d).astype(jnp.uint8)
    vf = jnp.moveaxis(v, 1, 0).reshape(g, l, d).astype(jnp.uint8)
    rs = rs.reshape(g, 1, l)
    ra = ra.reshape(g, 1, d)
    l_pad = (-l) % 32
    d_pad = (-d) % 32
    if l_pad or d_pad:
        qf = jnp.pad(qf, ((0, 0), (0, 0), (0, d_pad)))
        kf = jnp.pad(kf, ((0, 0), (0, l_pad), (0, d_pad)))
        vf = jnp.pad(vf, ((0, 0), (0, l_pad), (0, d_pad)))
        rs = jnp.pad(rs, ((0, 0), (0, 0), (0, l_pad)))
        ra = jnp.pad(ra, ((0, 0), (0, 0), (0, d_pad)))
    qp = pack_bits(qf, axis=-1)  # [G, 1, D/32]
    kp = pack_bits(kf, axis=-1)  # [G, L, D/32]
    vp = pack_bits(vf, axis=-2)  # [G, L/32, D]
    out = ssa_decode_kernel(qp, kp, vp, rs, ra, interpret=interpret)
    out = out[:, :, :d].reshape(b, t, h, 1, d)
    return jnp.moveaxis(out, 0, 1)


def gather_kv_pages(pool: Array, page_table: Array) -> Array:
    """Paged pool -> dense per-slot KV view (the non-kernel backends' path).

    ``pool [P, T, KV, page_len, d]`` + ``page_table [B, MP]`` -> ``[T, B,
    KV, MP*page_len, d]``: page ``table[b, j]`` lands at logical positions
    ``[j*page_len, (j+1)*page_len)`` of slot ``b``.  Table entry 0 is the
    permanently-zero null page, so unallocated blocks read as zero spikes
    (comparator-masked for free)."""
    g = pool[page_table]  # [B, MP, T, KV, page_len, d]
    g = jnp.moveaxis(g, 2, 0)  # [T, B, MP, KV, page_len, d]
    g = jnp.swapaxes(g, 2, 3)  # [T, B, KV, MP, page_len, d]
    return g.reshape(g.shape[:3] + (-1, g.shape[-1]))


@partial(jax.jit, static_argnames=("i_max", "interpret"))
def ssa_attention_decode_paged_packed(
    q: Array,  # [T, B, H, 1, D] binary — the new tokens' query spikes
    kpool: Array,  # [P, T, KV, page_len, D] key spike page pool
    vpool: Array,  # [P, T, KV, page_len, D] value spike page pool
    page_table: Array,  # [B, MP] int32 page ids (0 = null page)
    slot_keys: Array,  # [B, 2] uint32 per-slot PRNG keys
    h0: Union[int, Array] = 0,  # global index of q's first head (TP shards)
    *,
    i_max: int,
    interpret: bool = True,
) -> Array:
    """Bit-packed *paged* SSA decode step; returns uint8 spikes [T,B,H,1,D].

    The block-paged serving entry point: K/V spike trains live in a global
    physical page pool and each slot addresses its blocks through a page
    table, which the kernel dereferences via scalar-prefetch index maps —
    no dense per-slot cache is ever materialised in the kernel's address
    stream.  The comparator PRNs are drawn per (slot, global head) at the
    *logical* cache geometry ``L = MP * page_len`` with the same
    ``f(seed, pos, head)`` streams as the dense path, so paged decode is
    bit-identical to :func:`ssa_attention_decode_packed` over the
    materialised cache (and to the integer oracle
    :func:`repro.kernels.ref.ssa_decode_paged_ref`).  In-page position and
    spike-lane padding to 32-lane multiples is zero-filled: padded
    positions pair zero K spikes with zero comparator draws, and ``0 > 0``
    never fires, so they contribute nothing.  GQA repeats KV heads inside
    the kernel's index maps instead of materialising repeated pools."""
    t, b, h, n1, d = q.shape
    pl_ = kpool.shape[3]
    mp = page_table.shape[1]
    l = mp * pl_
    rs, ra = draw_slot_decode_prns(slot_keys, t, h, l, d, i_max, h0)
    # pad the in-page position axis and the spike-lane axis to 32-multiples
    p_pad = (-pl_) % 32
    d_pad = (-d) % 32
    plp, dp = pl_ + p_pad, d + d_pad
    qf = jnp.moveaxis(q, 1, 0).reshape(b, t, h, 1, d).astype(jnp.uint8)
    kf = kpool.astype(jnp.uint8)
    vf = vpool.astype(jnp.uint8)
    rs = rs.reshape(b, t, h, 1, mp, pl_)
    ra = ra.reshape(b, t, h, 1, d)
    if p_pad or d_pad:
        qf = jnp.pad(qf, ((0, 0),) * 4 + ((0, d_pad),))
        kf = jnp.pad(kf, ((0, 0),) * 3 + ((0, p_pad), (0, d_pad)))
        vf = jnp.pad(vf, ((0, 0),) * 3 + ((0, p_pad), (0, d_pad)))
        rs = jnp.pad(rs, ((0, 0),) * 5 + ((0, p_pad),))
        ra = jnp.pad(ra, ((0, 0),) * 4 + ((0, d_pad),))
    rs = rs.reshape(b, t, h, 1, mp * plp)
    qp = pack_bits(qf, axis=-1)  # [B, T, H, 1, Wd]
    kp = pack_bits(kf, axis=-1)  # [P, T, KV, PLp, Wd]
    vp = pack_bits(vf, axis=-2)  # [P, T, KV, Wp, Dp]
    out = ssa_decode_paged_kernel(
        page_table.astype(jnp.int32), qp, kp, vp, rs, ra, interpret=interpret)
    return jnp.moveaxis(out[..., :d], 0, 1)  # [T, B, H, 1, D]


@partial(jax.jit, static_argnames=("beta", "v_thresh", "interpret"))
def lif_fused(currents: Array, *, beta: float = 0.5, v_thresh: float = 1.0,
              interpret: bool = True) -> Array:
    """Fused LIF over [T, ...] currents; returns uint8 spikes."""
    t = currents.shape[0]
    flat = currents.reshape(t, -1)
    m = flat.shape[1]
    block = 4096
    pad = (-m) % min(block, max(m, 1))
    if m < block:
        block = m + pad
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    out = lif_kernel(flat, beta=beta, v_thresh=v_thresh, block=block,
                     interpret=interpret)
    return out[:, :m].reshape(currents.shape)


@partial(jax.jit, static_argnames=("beta", "v_thresh", "interpret"))
def aimc_spiking_linear(
    spikes: Array,  # [T, B, d_in]
    w_levels: Array,  # [d_in, d_out] int8
    scale: Array,  # [d_out]
    bias: Optional[Array] = None,  # [d_out] digital per-column bias
    *,
    beta: float = 0.5,
    v_thresh: float = 1.0,
    interpret: bool = True,
) -> Array:
    t, b, d_in = spikes.shape
    d_out = w_levels.shape[1]

    def rup(x, m):
        return (x + m - 1) // m * m

    bb = rup(b, 8) if b < 128 else rup(b, 128)
    di = rup(d_in, 128)
    do = rup(d_out, 128)
    sp = jnp.pad(spikes, ((0, 0), (0, bb - b), (0, di - d_in)))
    wp = jnp.pad(w_levels, ((0, di - d_in), (0, do - d_out)))
    sc = jnp.pad(scale, (0, do - d_out))
    if bias is None:
        bi = jnp.zeros((do,), jnp.float32)
    else:
        bi = jnp.pad(bias.astype(jnp.float32), (0, do - d_out))
    out = aimc_spiking_linear_kernel(
        sp, wp, sc, bi, beta=beta, v_thresh=v_thresh,
        block_b=min(bb, 128), block_in=128, block_out=128, interpret=interpret,
    )
    return out[:, :b, :d_out]


@partial(jax.jit, static_argnames=("interpret",))
def aimc_matmul_counts(
    spikes: Array,  # [T, B, d_in]
    w_levels: Array,  # [d_in, d_out] int8
    *,
    interpret: bool = True,
) -> Array:
    """[T, B, d_out] f32 integer-valued crossbar counts (pre-scale/LIF).

    The shard-local programmed-AIMC matmul of a *row-parallel* spiking
    linear: each mesh shard runs this over its d_in rows, the counts psum
    across the ``model`` axis (exact — integer-valued f32), and scale/bias/
    LIF fire once on the reduced currents.  Zero-padded to kernel block
    multiples and sliced back, like :func:`aimc_spiking_linear`."""
    t, b, d_in = spikes.shape
    d_out = w_levels.shape[1]

    def rup(x, m):
        return (x + m - 1) // m * m

    bb = rup(b, 8) if b < 128 else rup(b, 128)
    di = rup(d_in, 128)
    do = rup(d_out, 128)
    sp = jnp.pad(spikes, ((0, 0), (0, bb - b), (0, di - d_in)))
    wp = jnp.pad(w_levels, ((0, di - d_in), (0, do - d_out)))
    out = aimc_matmul_counts_kernel(
        sp, wp, block_b=min(bb, 128), block_in=128, block_out=128,
        interpret=interpret,
    )
    return out[:, :b, :d_out]


@partial(jax.jit, static_argnames=("t0", "img_gain", "interpret"))
def drift_requantize(
    levels: Array,  # [d_in, d_out] f32 programmed integer levels
    eps: Array,  # [d_in, d_out] f32 frozen programming error (level units)
    nu: Array,  # [d_in, d_out] f32 per-device drift exponents
    t_seconds: Array,  # scalar f32 device time (traced)
    *,
    t0: float,
    img_gain: int = 1,
    interpret: bool = True,
) -> Array:
    """Drifted-conductance requantisation on the Pallas path.

    The calibration-time fold that keeps the programmed-state hot loop an
    int8 MXU matmul: re-digitise ``(levels+eps) * (t/t0)^-nu * img_gain``
    onto the full int8 image grid.  Zero-padded to 128x128 tile multiples and sliced
    back; bit-exact vs :func:`repro.kernels.ref.drift_requantize_ref` (and
    ``repro.aimc_device.drift_to``) for any shape."""
    d_in, d_out = levels.shape

    def rup(x, m):
        return (x + m - 1) // m * m

    di, do = rup(d_in, 128), rup(d_out, 128)
    pad = ((0, di - d_in), (0, do - d_out))
    out = drift_requantize_kernel(
        jnp.pad(levels.astype(jnp.float32), pad),
        jnp.pad(eps.astype(jnp.float32), pad),
        jnp.pad(nu.astype(jnp.float32), pad),
        jnp.reshape(t_seconds, (1,)).astype(jnp.float32),
        t0=t0, img_gain=img_gain, interpret=interpret,
    )
    return out[:d_in, :d_out]


@partial(jax.jit, static_argnames=("t0", "img_gain", "beta", "v_thresh",
                                   "interpret"))
def aimc_spiking_linear_programmed(
    spikes: Array,  # [T, B, d_in]
    levels: Array,  # [d_in, d_out] f32 programmed integer levels
    eps: Array,  # [d_in, d_out] f32 frozen programming error
    nu: Array,  # [d_in, d_out] f32 per-device drift exponents
    scale: Array,  # [d_out] f32 programmed per-column scale
    t_seconds: Array,  # scalar device time
    gdc_gain: Array,  # scalar GDC gain (stale between recalibrations)
    bias: Optional[Array] = None,
    *,
    t0: float,
    img_gain: int = 1,
    beta: float = 0.5,
    v_thresh: float = 1.0,
    interpret: bool = True,
) -> Array:
    """End-to-end programmed-state spiking linear on the Pallas path.

    Fold kernel (:func:`drift_requantize`) + int8 matmul/LIF kernel
    (:func:`aimc_spiking_linear`); bit-exact vs
    :func:`repro.kernels.ref.aimc_programmed_linear_ref` at fixed
    ``t_seconds``.  Production serving keeps the folded ``levels_t`` /
    ``eff_scale`` cached in :class:`repro.aimc_device.AIMCDeviceState` and
    calls :func:`aimc_spiking_linear` directly; this wrapper is the
    one-shot (fold-on-the-fly) variant used by tests and drift studies."""
    levels_t = drift_requantize(levels, eps, nu, t_seconds, t0=t0,
                                img_gain=img_gain, interpret=interpret)
    eff_scale = (scale * gdc_gain / float(img_gain)).astype(jnp.float32)
    return aimc_spiking_linear(spikes, levels_t, eff_scale, bias, beta=beta,
                               v_thresh=v_thresh, interpret=interpret)
