"""PagePool: host-side accounting for the block-paged spike-train KV cache.

The device side of paged serving is dumb on purpose — a zero-initialised
physical page pool plus per-slot page tables inside
:class:`repro.serving.state.PagedDecodeState`.  Everything stateful lives
here, in O(pages) host bookkeeping:

* **free list + refcounts** — a page is writable iff its refcount is 1;
  releasing the last reference frees it (the scheduler zeroes freed pages
  on device before reuse).  Double-free and foreign-page release raise.
* **prefix cache** — an exact-match LRU map from *chained block keys* to
  physical pages: a full prompt block is keyed by ``(parent chain id,
  its own page_len tokens)``, where the parent id names the cache entry
  of the preceding block (0 = the empty prefix).  The chain makes
  matching exact by construction — a hit proves the whole token prefix
  matches link by link — while hashing only O(page_len) tokens per block
  instead of the O(n_ctx) full-prefix tuple (chain ids are never reused,
  so a dropped-and-re-registered parent can never falsely adopt stale
  children).  Because prefill spike randomness is keyed by (content,
  position) — :func:`repro.serving.state.content_keys` — a hit is
  *bit-identical* sharing: the new request's page table points at the
  very pages an earlier request filled.  The cache holds its own
  reference on every registered page, so shared prefixes survive the
  registering request's eviction; under pool pressure, LRU entries whose
  pages are cache-only (refcount 1) are dropped to free pages.
* **reservations** — admission reserves a request's worst-case page need
  up front, so mid-flight allocation can never deadlock the pool:
  admission blocks on free pages, running slots never do.

Copy-on-write pairs with the refcounts: registered (shared) pages are
pristine — prompt content plus a zero tail — and any slot about to write
into a page it does not own exclusively first copies the valid prefix to a
fresh page (``state.pool_copy_page``) and repoints its table.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.state import NULL_PAGE, RESERVED_PAGES, TRASH_PAGE


class PagePool:
    """Refcounted physical-page accounting + exact-prefix page cache."""

    def __init__(self, n_pages: int, page_len: int):
        if n_pages <= RESERVED_PAGES:
            raise ValueError(
                f"n_pages ({n_pages}) must exceed the {RESERVED_PAGES} "
                "reserved pages (null + trash)")
        self.n_pages = n_pages
        self.page_len = page_len
        self.refcount = np.zeros(n_pages, np.int32)
        self.refcount[NULL_PAGE] = self.refcount[TRASH_PAGE] = 1  # immortal
        self._free: List[int] = list(range(n_pages - 1, RESERVED_PAGES - 1, -1))
        self._reserved = 0
        # chained-block key -> (page id, chain id | None); insertion order
        # is the LRU order.  Chain ids are fresh monotone ints (0 = the
        # empty-prefix root) so evicted parents can never be confused with
        # later re-registrations.
        self._prefix: "OrderedDict[Tuple, Tuple[int, Optional[int]]]" = \
            OrderedDict()
        self._next_chain = 1
        # stats
        self.prefix_hits = 0
        self.prefix_misses = 0
        self.cow_copies = 0
        self.peak_in_use = 0
        # invariant-guard hook: called with the violation message right
        # before a double-free / use-after-free raise, so a flight
        # recorder (repro.obs) can dump a postmortem while the rings still
        # hold the events that led here.  The raise always proceeds.
        self.on_violation: Optional[Callable[[str], None]] = None

    def _violate(self, msg: str) -> str:
        if self.on_violation is not None:
            self.on_violation(msg)
        return msg

    # -- capacity -------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.n_pages - RESERVED_PAGES - len(self._free)

    def available(self) -> int:
        """Pages allocatable without eating someone else's reservation."""
        return len(self._free) - self._reserved

    def reserve(self, n: int) -> None:
        if n > self.available():
            raise RuntimeError(
                f"page reservation of {n} exceeds available {self.available()}")
        self._reserved += n

    def unreserve(self, n: int) -> None:
        assert 0 <= n <= self._reserved, "unbalanced page reservation"
        self._reserved -= n

    # -- alloc / refcount ----------------------------------------------

    def alloc(self, *, reserved: bool = False) -> int:
        """Take a free (zeroed) page; ``reserved=True`` consumes one unit of
        the caller's prior :meth:`reserve`."""
        if not self._free:
            raise RuntimeError("page pool exhausted (reservation bug?)")
        if reserved:
            self.unreserve(1)
        pid = self._free.pop()
        assert self.refcount[pid] == 0
        self.refcount[pid] = 1
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return pid

    def retain(self, pid: int) -> None:
        """Add a reference to a live page (prefix hit / cache registration)."""
        if pid in (NULL_PAGE, TRASH_PAGE):
            raise ValueError(self._violate(f"cannot retain reserved page {pid}"))
        if self.refcount[pid] <= 0:
            raise ValueError(
                self._violate(f"retain of dead page {pid} (use-after-free)"))
        self.refcount[pid] += 1

    def release(self, pid: int) -> bool:
        """Drop a reference; returns True when the page became free (the
        caller must zero it on device before it can be reused).  Releasing
        an already-free page raises — the double-free guard."""
        if pid in (NULL_PAGE, TRASH_PAGE):
            raise ValueError(
                self._violate(f"cannot release reserved page {pid}"))
        if self.refcount[pid] <= 0:
            raise ValueError(self._violate(f"double free of page {pid}"))
        self.refcount[pid] -= 1
        if self.refcount[pid] == 0:
            self._free.append(int(pid))
            return True
        return False

    # -- prefix cache ---------------------------------------------------

    def prefix_lookup(self, key: Tuple) -> Optional[Tuple[int, Optional[int]]]:
        """Look a chained block key up; on hit, retains the page for the
        caller, refreshes the entry's LRU position and returns ``(page id,
        chain id)`` — the chain id keys the next block's lookup."""
        ent = self._prefix.get(key)
        if ent is None:
            self.prefix_misses += 1
            return None
        self._prefix.move_to_end(key)
        self.retain(ent[0])
        self.prefix_hits += 1
        return ent

    def prefix_register(self, key: Tuple, pid: int, *,
                        chain: bool = False) -> Optional[int]:
        """Publish a pristine page under a chained block key (the cache
        takes its own reference) and return the entry's chain id
        (``chain=True`` mints one for full blocks so later blocks can link
        to it; partial tails are leaves).  If the key is already cached —
        e.g. two identical prompts prefilled concurrently — nothing is
        retained and the existing chain id is returned so the caller's
        chain stays canonical."""
        ent = self._prefix.get(key)
        if ent is not None:
            return ent[1]
        self.retain(pid)
        cid = None
        if chain:
            cid = self._next_chain
            self._next_chain += 1
        self._prefix[key] = (int(pid), cid)
        return cid

    def prefix_evict(self, need: int) -> List[int]:
        """Drop LRU prefix entries until ``need`` pages can be freed (only
        entries whose page is cache-only — refcount 1 — actually free a
        page; shared entries are dropped from the index but their pages
        live on under the sharing slots).  Returns freed page ids for the
        caller to zero on device."""
        freed: List[int] = []
        while len(freed) < need and self._prefix:
            key, (pid, _) = self._prefix.popitem(last=False)
            if self.release(pid):
                freed.append(pid)
        return freed

    def prefix_contains(self, key: Tuple) -> bool:
        return key in self._prefix

    def prefix_len(self) -> int:
        return len(self._prefix)

    def cached_pages(self) -> Dict[Tuple, int]:
        return {k: ent[0] for k, ent in self._prefix.items()}
