"""Continuous-batching serving subsystem for the Xpikeformer engine.

Architecture (see README "Serving" / "Paged spike-train KV cache"):

    BatchScheduler  — admission / eviction over a request queue
        |
    DecodeState     — slot-dense cache pytree (spiking KV trains or ANN KV /
        |             recurrent state) + per-slot tokens / seeds / occupancy
    PagedDecodeState— or the block-paged layout: a global spike-page pool +
        |             per-slot page tables, refcounted host-side (PagePool)
        |             with copy-on-write and an exact-prefix page cache
    decode_step     — ONE jit-compiled batched step through the engine's
                      pluggable Backend (reference / integer / pallas); in
                      paged mode chunked prefill rides the same step
"""

from repro.serving.pages import PagePool
from repro.serving.scheduler import BatchScheduler, Request, ServeStats
from repro.serving.state import (
    NULL_PAGE,
    RESERVED_PAGES,
    TRASH_PAGE,
    DecodeState,
    PagedDecodeState,
    content_keys,
    init_paged_state,
    init_state,
    make_decode_fn,
    make_paged_decode_fn,
    make_prefill_fn,
    paged_admit_slot,
    paged_release_slot,
    paged_set_table_entry,
    pool_copy_page,
    pool_zero_pages,
    release_slot,
    slot_slice,
    slot_splice,
    slot_zero,
    splice_request,
)

__all__ = [
    "BatchScheduler",
    "PagePool",
    "Request",
    "ServeStats",
    "DecodeState",
    "PagedDecodeState",
    "NULL_PAGE",
    "TRASH_PAGE",
    "RESERVED_PAGES",
    "content_keys",
    "init_state",
    "init_paged_state",
    "make_decode_fn",
    "make_paged_decode_fn",
    "make_prefill_fn",
    "paged_admit_slot",
    "paged_release_slot",
    "paged_set_table_entry",
    "pool_copy_page",
    "pool_zero_pages",
    "release_slot",
    "slot_slice",
    "slot_splice",
    "slot_zero",
    "splice_request",
]
