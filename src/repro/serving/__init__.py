"""Continuous-batching serving subsystem for the Xpikeformer engine.

Architecture (see README "Serving"):

    BatchScheduler  — admission / eviction over a request queue
        |
    DecodeState     — slot-major cache pytree (spiking KV trains or ANN KV /
        |             recurrent state) + per-slot tokens / seeds / occupancy
        |
    decode_step     — ONE jit-compiled batched step through the engine's
                      pluggable Backend (reference / integer / pallas)
"""

from repro.serving.scheduler import BatchScheduler, Request, ServeStats
from repro.serving.state import (
    DecodeState,
    init_state,
    make_decode_fn,
    make_prefill_fn,
    release_slot,
    slot_slice,
    slot_splice,
    slot_zero,
    splice_request,
)

__all__ = [
    "BatchScheduler",
    "Request",
    "ServeStats",
    "DecodeState",
    "init_state",
    "make_decode_fn",
    "make_prefill_fn",
    "release_slot",
    "slot_slice",
    "slot_splice",
    "slot_zero",
    "splice_request",
]
