"""BatchScheduler: continuous-batching request scheduler over DecodeState.

A miniature vLLM-style serving loop for the Xpikeformer engine:

* **admission** — pending requests splice into free slots *mid-flight*
  (prefilled batch-1 through the same decode path, then scattered into the
  batch), so the running slots never wait for the batch to drain.  Per-slot
  PRN stream ids + per-slot position counters make admission bit-exact for
  already-running slots: a request's token stream is a pure function of
  (params, prompt, seed), never of batch composition.
* **eviction** — finished (or explicitly evicted) slots release their state:
  cache leaves are zeroed, which both frees the logical page and masks the
  slot out of the spiking comparators.
* **decode** — one jit-compiled batched ``decode_step`` advances every slot;
  the scheduler only does O(slots) host bookkeeping per step.
* **drift lifecycle** — when the params hold programmed PCM state
  (:class:`repro.aimc_device.AIMCDeviceState`) and a
  :class:`~repro.aimc_device.DriftPolicy` is set, the scheduler advances
  the device clock from the decode-step wall clock (or a fixed per-step
  quantum) and runs periodic GDC recalibration.  Both are pure leaf-value
  pytree updates, so the jitted ``decode_step`` is **never recompiled** by
  aging or recalibration.
* **energy metering** — every decode step returns per-slot measured
  spike-event counts; the scheduler converts them to joules (event count x
  per-event op energy + static per-token cost, Table-II constants) and
  accounts them per request (:attr:`BatchScheduler.request_energy_j`) and
  in :class:`ServeStats`.

The decode math runs through the engine's pluggable :class:`~repro.engine.
Backend` for spiking SSA configs (reference / integer / pallas serve
identically — the integer oracle is the correctness contract) and the
conventional float path otherwise.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import aimc_device as AD
from repro.energy import model as EM
from repro.models import transformer as T
from repro.models.moe import ParallelCtx
from repro.serving import state as ST

Array = jax.Array


@dataclasses.dataclass
class Request:
    rid: int
    prompt: Array  # [P] int32
    max_new: int
    seed: int


@dataclasses.dataclass
class ServeStats:
    requests: int = 0
    data_shards: int = 1  # mesh data-axis size (1 = single device)
    model_shards: int = 1  # mesh model-axis size
    decode_steps: int = 0
    decoded_tokens: int = 0
    prefill_tokens: int = 0
    admissions: int = 0
    evictions: int = 0
    wall_s: float = 0.0  # whole serve loop (admission/prefill included)
    decode_s: float = 0.0  # batched decode_step calls only
    spike_events: float = 0.0  # measured residual-stream spike events
    energy_j: float = 0.0  # metered inference energy (events x op energies)
    t_device_s: float = 0.0  # PCM device clock at the last decode step
    recalibrations: int = 0  # GDC recalibrations run by the drift policy

    @property
    def tokens_per_sec(self) -> float:
        """End-to-end decoded-token throughput (prefill time included)."""
        return self.decoded_tokens / max(self.wall_s, 1e-9)

    @property
    def decode_tokens_per_sec(self) -> float:
        """Decode-phase throughput: tokens per second spent inside the
        batched ``decode_step`` — the batching win, independent of how
        prompts were prefilled (the batch-1 prefill scan is the same work
        in any slot configuration)."""
        return self.decoded_tokens / max(self.decode_s, 1e-9)


def _bucket(n: int, minimum: int = 8) -> int:
    """Pad prompt lengths to power-of-two buckets (one prefill compile each)."""
    b = minimum
    while b < n:
        b *= 2
    return b


class BatchScheduler:
    """Continuous-batching scheduler: submit prompts, run, collect outputs.

    Greedy decoding; a request finishes after ``max_new`` tokens.  Outputs
    are collected in :attr:`outputs` (rid -> list of generated token ids).
    """

    def __init__(
        self,
        params: Any,
        cfg,
        backend=None,
        *,
        slots: int = 4,
        cache_len: int = 64,
        pctx: Optional[ParallelCtx] = None,
        moe_impl: Optional[str] = None,
        drift: Optional[AD.DriftPolicy] = None,
        placement=None,
    ):
        self.placement = placement  # repro.distributed.Executor | None
        if placement is not None:
            params = placement.place_params(params)
        self.params = params
        self.cfg = cfg
        self.backend = backend
        self.slots = slots
        self.cache_len = cache_len
        self.pctx = pctx or ParallelCtx()
        self.moe_impl = moe_impl or ("ep_a2a" if cfg.is_moe else "dense")
        self.drift = drift
        self.state = self._place_state(ST.init_state(cfg, slots, cache_len))
        if placement is None:
            decode_out = prefill_out = None
            prefill_backend = backend
            self._splice = ST.splice_request_jit
            self._release = ST.release_slot_jit
        else:
            # mesh serving: slots ride the data axis, spiking kernels are
            # tensor-parallel over model; out-shardings are pinned so the
            # compiled decode feeds itself without resharding/recompiling
            decode_out = placement.decode_out_shardings(slots, cache_len)
            prefill_out = placement.replicated
            prefill_backend = placement.prefill_backend
            state_sh = placement.state_shardings(slots, cache_len)
            self._splice = jax.jit(ST.splice_request, out_shardings=state_sh)
            self._release = jax.jit(ST.release_slot, out_shardings=state_sh)
        self._decode = ST.make_decode_fn(cfg, self.pctx, backend, self.moe_impl,
                                         out_shardings=decode_out)
        self._prefill = ST.make_prefill_fn(cfg, self.pctx, prefill_backend,
                                           self.moe_impl,
                                           out_shardings=prefill_out)
        self._queue: Deque[Request] = deque()
        self._slot_req: List[Optional[Request]] = [None] * slots
        self._remaining: List[int] = [0] * slots
        self.outputs: Dict[int, List[int]] = {}
        # per-request measured energy / spike events (rid -> totals)
        self.request_energy_j: Dict[int, float] = {}
        self.request_spikes: Dict[int, float] = {}
        self.stats = self._fresh_stats()
        self._next_rid = 0
        # PCM device clock (drift lifecycle): picks up wherever the
        # programmed params already are — the device does not rejuvenate
        self._t_device = AD.device_time(params)
        self._last_recal = self._t_device
        self._t_image = self._t_device  # device time of the last image fold
        self._programmed = AD.has_device_state(params)
        self.stats.t_device_s = self._t_device
        # static per-decoded-token energy for spiking SSA configs (the
        # activity-independent ADC/periphery/LIF/comparator work)
        if getattr(cfg, "spiking", False) and cfg.attention_kind == "ssa":
            self._e_token_pj = EM.lm_decode_token_energy_pj(
                cfg.d_model, cfg.num_heads, cfg.resolved_head_dim, cfg.d_ff,
                cfg.num_layers, cfg.spike_T, cache_len, cfg.vocab_size)
        else:
            self._e_token_pj = 0.0
        self._e_event_pj = EM.decode_synapse_energy_pj()

    def _fresh_stats(self) -> ServeStats:
        if self.placement is None:
            return ServeStats()
        return ServeStats(data_shards=self.placement.data,
                          model_shards=self.placement.model)

    def _place_state(self, state):
        return state if self.placement is None else self.placement.place_state(state)

    def _place_params(self, params):
        return params if self.placement is None else self.placement.place_params(params)

    def set_params(self, params: Any) -> None:
        """Swap the served params (e.g. a newly-programmed tree) and re-read
        the device lifecycle bookkeeping from them."""
        self.params = self._place_params(params)
        self._programmed = AD.has_device_state(params)
        self._t_device = AD.device_time(params)
        self._last_recal = self._t_device
        self._t_image = self._t_device
        self.stats.t_device_s = self._t_device

    def reset(self) -> None:
        """Drop all requests and state but keep the compiled step functions
        (fresh server, warm jit cache — used by benchmarks and tests).
        The PCM device clock is *not* reset: drift is physical."""
        self.state = self._place_state(
            ST.init_state(self.cfg, self.slots, self.cache_len))
        self._queue.clear()
        self._slot_req = [None] * self.slots
        self._remaining = [0] * self.slots
        self.outputs = {}
        self.request_energy_j = {}
        self.request_spikes = {}
        self.stats = self._fresh_stats()
        self.stats.t_device_s = self._t_device

    # -- request intake ------------------------------------------------

    def submit(self, prompt: Sequence[int], max_new: int, seed: Optional[int] = None) -> int:
        """Queue a request; returns its rid.  ``seed`` fixes the request's
        spike PRN stream (defaults to the rid) — the same (prompt, seed)
        decodes identically no matter how it is batched."""
        prompt = jnp.asarray(prompt, jnp.int32)
        assert prompt.ndim == 1 and prompt.shape[0] >= 1, "prompt must be [P>=1]"
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        if prompt.shape[0] + max_new > self.cache_len:
            raise ValueError(
                f"prompt ({prompt.shape[0]}) + max_new ({max_new}) exceeds "
                f"cache_len ({self.cache_len})"
            )
        rid = self._next_rid
        self._next_rid += 1
        self._queue.append(Request(rid, prompt, max_new,
                                   rid if seed is None else seed))
        self.stats.requests += 1
        return rid

    # -- slot management -----------------------------------------------

    def admit(self) -> int:
        """Splice queued requests into free slots (continuous batching).

        Prefills each admitted prompt through a batch-1 scan of the same
        decode path, then scatters the filled cache into the slot while
        the other slots' state is untouched.  Returns #admitted."""
        admitted = 0
        for slot in range(self.slots):
            if not self._queue or self._slot_req[slot] is not None:
                continue
            req = self._queue.popleft()
            p = req.prompt
            n_ctx = int(p.shape[0]) - 1  # last prompt token feeds the first decode
            padded = _bucket(max(n_ctx, 1))
            prompt_pad = jnp.zeros((padded,), jnp.int32).at[:n_ctx].set(p[:-1])
            cache1 = T.init_cache(self.cfg, 1, self.cache_len)
            cache1, pre_act = self._prefill(
                self.params, prompt_pad, jnp.int32(n_ctx),
                jnp.uint32(req.seed), cache1,
            )
            self.state = self._splice(
                self.state, slot, cache1, p[-1], jnp.uint32(req.seed))
            self._slot_req[slot] = req
            self._remaining[slot] = req.max_new
            self.outputs[req.rid] = []
            # prefill energy is prompt-length dependent: book the measured
            # prompt spike events + static per-token cost at admission
            spikes = float(pre_act)
            e_j = (spikes * self._e_event_pj + n_ctx * self._e_token_pj) * 1e-12
            self.request_spikes[req.rid] = (
                self.request_spikes.get(req.rid, 0.0) + spikes)
            self.request_energy_j[req.rid] = (
                self.request_energy_j.get(req.rid, 0.0) + e_j)
            self.stats.spike_events += spikes
            self.stats.energy_j += e_j
            self.stats.prefill_tokens += n_ctx
            self.stats.admissions += 1
            admitted += 1
        return admitted

    def evict(self, slot: int, requeue: bool = False) -> None:
        """Release a slot's state (zero cache pages, clear occupancy).

        With ``requeue=True`` the in-flight request restarts from its
        prompt on a later admission (preemption); otherwise its collected
        output is kept as-is."""
        req = self._slot_req[slot]
        if req is not None and requeue:
            self._queue.appendleft(req)
            self.outputs.pop(req.rid, None)
        self._slot_req[slot] = None
        self._remaining[slot] = 0
        self.state = self._release(self.state, slot)
        self.stats.evictions += 1

    # -- serving loop --------------------------------------------------

    def step(self) -> int:
        """Admit, then advance every active slot one token.  Returns the
        number of tokens decoded (0 when idle).

        Each step also (a) meters energy — the decode returns per-slot
        measured spike-event counts, converted to joules and booked against
        the slot's request — and (b) advances the PCM drift lifecycle when
        a :class:`~repro.aimc_device.DriftPolicy` is set on programmed
        params (device clock from decode wall time, periodic GDC
        recalibration), without recompiling the jitted decode."""
        self.admit()
        if not any(r is not None for r in self._slot_req):
            return 0
        t0 = time.time()
        logits, self.state, act = self._decode(self.params, self.state)
        nxt = np.asarray(self.state.tokens)  # syncs the step
        step_s = time.time() - t0
        self.stats.decode_s += step_s
        self.stats.decode_steps += 1
        act = np.asarray(act)
        decoded = 0
        for slot in range(self.slots):
            req = self._slot_req[slot]
            if req is None:
                continue
            self.outputs[req.rid].append(int(nxt[slot]))
            decoded += 1
            spikes = float(act[slot])
            e_j = (spikes * self._e_event_pj + self._e_token_pj) * 1e-12
            self.request_spikes[req.rid] = (
                self.request_spikes.get(req.rid, 0.0) + spikes)
            self.request_energy_j[req.rid] = (
                self.request_energy_j.get(req.rid, 0.0) + e_j)
            self.stats.spike_events += spikes
            self.stats.energy_j += e_j
            self._remaining[slot] -= 1
            if self._remaining[slot] == 0:
                self.evict(slot)
        self.stats.decoded_tokens += decoded
        self._advance_device_clock(step_s)
        return decoded

    def _advance_device_clock(self, step_wall_s: float) -> None:
        """Drift lifecycle: age the programmed PCM state and periodically
        GDC-recalibrate, per the :class:`~repro.aimc_device.DriftPolicy`.

        Leaf-value-only pytree updates (``drift_tree_jit`` /
        ``recalibrate_tree_jit``): shapes, dtypes and the params treedef are
        unchanged, so the compiled ``decode_step`` stays warm.

        The scalar clock advances every step, but the O(params) image fold
        (drift re-quantisation) only runs when the drift factor has moved
        by at least ~half an int8 image LSB since the last fold — at device
        ages of hours a per-token refresh could not change a single image
        level and would just burn host time — and always right before a
        GDC recalibration (which reads the state's own clock)."""
        pol = self.drift
        if pol is None or not self._programmed:
            return
        dt = pol.seconds_per_step if pol.seconds_per_step > 0 else (
            step_wall_s * pol.time_scale)
        self._t_device += dt
        due_recal = (pol.recal_interval_s > 0
                     and self._t_device - self._last_recal >= pol.recal_interval_s)
        # half-LSB criterion: (t/t_image)^-nu_mean moved by > 0.5/127
        ratio = (1.0 - 0.5 / 127.0) ** (-1.0 / max(pol.cfg.drift_nu_mean, 1e-3))
        due_image = self._t_device >= max(self._t_image,
                                          pol.cfg.drift_t0_s) * ratio
        if due_recal or due_image:
            self.params = self._place_params(AD.drift_tree_jit(
                self.params, jnp.float32(self._t_device), pol.cfg))
            self._t_image = self._t_device
        if due_recal:
            self.params = self._place_params(
                AD.recalibrate_tree_jit(self.params, pol.cfg))
            self._last_recal = self._t_device
            self.stats.recalibrations += 1
        self.stats.t_device_s = self._t_device

    def run(self) -> Dict[int, List[int]]:
        """Serve until the queue and all slots drain; returns outputs."""
        t0 = time.time()
        while self._queue or any(r is not None for r in self._slot_req):
            self.step()
        self.stats.wall_s += time.time() - t0
        return self.outputs
