"""BatchScheduler: continuous-batching request scheduler over DecodeState.

A miniature vLLM-style serving loop for the Xpikeformer engine:

* **admission** — pending requests splice into free slots *mid-flight*
  (prefilled batch-1 through the same decode path, then scattered into the
  batch), so the running slots never wait for the batch to drain.  Per-slot
  PRN stream ids + per-slot position counters make admission bit-exact for
  already-running slots: a request's token stream is a pure function of
  (params, prompt, seed), never of batch composition.
* **eviction** — finished (or explicitly evicted) slots release their state:
  cache leaves are zeroed, which both frees the logical page and masks the
  slot out of the spiking comparators.
* **decode** — one jit-compiled batched ``decode_step`` advances every slot;
  the scheduler only does O(slots) host bookkeeping per step.
* **paged serving** (``paged=True``, spiking SSA configs) — K/V spike
  trains live in a global block-paged pool
  (:class:`~repro.serving.state.PagedDecodeState` +
  :class:`~repro.serving.pages.PagePool`): refcounted pages with
  copy-on-write, an exact-prefix cache that maps identical prompt prefixes
  onto the *same physical pages* (bit-identical spike trains — prefill PRN
  streams are content-keyed, see
  :func:`~repro.serving.state.content_keys`), **chunked prefill** (prompt
  tokens ride the same batched decode step as everyone else's decode, one
  position per step per prefilling slot, instead of a batch-1
  prefill-then-splice), and admission that blocks on *free pages* rather
  than free slots.  Generated token streams are bit-identical to dense
  serving on the bit-exact backends.
* **drift lifecycle** — when the params hold programmed PCM state
  (:class:`repro.aimc_device.AIMCDeviceState`) and a
  :class:`~repro.aimc_device.DriftPolicy` is set, the scheduler advances
  the device clock from the decode-step wall clock (or a fixed per-step
  quantum) and runs periodic GDC recalibration.  Both are pure leaf-value
  pytree updates, so the jitted ``decode_step`` is **never recompiled** by
  aging or recalibration.
* **energy metering** — every decode step returns per-slot measured
  spike-event counts; the scheduler converts them to joules (event count x
  per-event op energy + static per-token cost, Table-II constants) and
  accounts them per request (:attr:`BatchScheduler.request_energy_j`) and
  in :class:`ServeStats`.

The decode math runs through the engine's pluggable :class:`~repro.engine.
Backend` for spiking SSA configs (reference / integer / pallas serve
identically — the integer oracle is the correctness contract) and the
conventional float path otherwise.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import aimc_device as AD
from repro.energy import model as EM
from repro.kernels.plan import build_decode_plan
from repro.models import transformer as T
from repro.models.moe import ParallelCtx
from repro.obs import Telemetry
from repro.obs import trace as TR
from repro.serving import state as ST
from repro.serving.pages import PagePool

Array = jax.Array

# paged-slot lifecycle: consuming prompt positions -> feeding the last
# prompt token on the request's own PRN stream -> riding greedy argmax
PREFILL, HANDOFF, DECODE = "prefill", "handoff", "decode"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: Array  # [P] int32
    max_new: int
    seed: int
    # host-side views, filled by submit(): the prompt as numpy, its context
    # length (prompt minus the last token, which seeds decode), and the
    # per-position content keys that make prefill spike randomness a pure
    # function of (token prefix, position) — the prefix-sharing contract
    prompt_np: Optional[np.ndarray] = None
    ckeys: Optional[np.ndarray] = None

    @property
    def n_ctx(self) -> int:
        return len(self.prompt_np) - 1


@dataclasses.dataclass
class ServeStats:
    requests: int = 0
    data_shards: int = 1  # mesh data-axis size (1 = single device)
    model_shards: int = 1  # mesh model-axis size
    decode_steps: int = 0
    decoded_tokens: int = 0
    prefill_tokens: int = 0
    admissions: int = 0
    evictions: int = 0
    wall_s: float = 0.0  # whole serve loop (admission/prefill included)
    decode_s: float = 0.0  # batched decode_step calls only
    spike_events: float = 0.0  # measured residual-stream spike events
    energy_j: float = 0.0  # metered inference energy (events x op energies)
    t_device_s: float = 0.0  # PCM device clock at the last decode step
    recalibrations: int = 0  # GDC recalibrations run by the drift policy
    # paged serving (zeros on the dense path)
    prefix_hits: int = 0  # prefix-cache page hits across admissions
    prefix_hit_tokens: int = 0  # prompt positions skipped via shared pages
    cow_copies: int = 0  # copy-on-write page duplications
    pages_in_use_peak: int = 0  # peak simultaneously-referenced pool pages
    peak_active_slots: int = 0  # max slots concurrently occupied

    @property
    def tokens_per_sec(self) -> float:
        """End-to-end decoded-token throughput (prefill time included)."""
        return self.decoded_tokens / max(self.wall_s, 1e-9)

    @property
    def j_per_token(self) -> float:
        """Metered joules per decoded token.

        Same guarded-denominator convention as :attr:`tokens_per_sec` /
        :attr:`decode_tokens_per_sec` (``max(x, 1e-9)``).  Zero-token
        behaviour: with nothing decoded *and* nothing metered this is
        ``0.0``; with booked energy but zero decoded tokens (a
        prefill-only or all-preempted run) it is astronomically large —
        deliberately, since the true cost per delivered token of such a
        run is unbounded, and the old ``max(decoded_tokens, 1)`` floor
        silently under-reported it as ``energy_j``."""
        return self.energy_j / max(self.decoded_tokens, 1e-9)

    @property
    def decode_tokens_per_sec(self) -> float:
        """Decode-phase throughput: tokens per second spent inside the
        batched ``decode_step`` — the batching win, independent of how
        prompts were prefilled (the batch-1 prefill scan is the same work
        in any slot configuration)."""
        return self.decoded_tokens / max(self.decode_s, 1e-9)


def _bucket(n: int, minimum: int = 8) -> int:
    """Pad prompt lengths to power-of-two buckets (one prefill compile each)."""
    b = minimum
    while b < n:
        b *= 2
    return b


class BatchScheduler:
    """Continuous-batching scheduler: submit prompts, run, collect outputs.

    Greedy decoding; a request finishes after ``max_new`` tokens.  Outputs
    are collected in :attr:`outputs` (rid -> list of generated token ids).
    """

    def __init__(
        self,
        params: Any,
        cfg,
        backend=None,
        *,
        slots: int = 4,
        cache_len: int = 64,
        pctx: Optional[ParallelCtx] = None,
        moe_impl: Optional[str] = None,
        drift: Optional[AD.DriftPolicy] = None,
        placement=None,
        paged: bool = False,
        page_len: int = 8,
        n_pages: Optional[int] = None,
        decode_kernel: str = "auto",
        obs: Optional[Telemetry] = None,
    ):
        self.placement = placement  # repro.distributed.Executor | None
        if placement is not None:
            params = placement.place_params(params)
        self.params = params
        self.cfg = cfg
        self.backend = backend
        self.slots = slots
        self.cache_len = cache_len
        self.pctx = pctx or ParallelCtx()
        self.moe_impl = moe_impl or ("ep_a2a" if cfg.is_moe else "dense")
        self.drift = drift
        self.paged = bool(paged)
        # one DecodePlan per scheduler lifetime: the jitted decode step
        # closes over it, so kernel selection can never recompile mid-serve
        if T._spiking_decode_enabled(cfg):
            self.plan = build_decode_plan(
                cfg, backend, layout="paged" if self.paged else "dense",
                kernel=decode_kernel, page_len=page_len)
        else:
            if decode_kernel == "fused":
                raise ValueError(
                    "decode kernel 'fused' needs a spiking SSA config, "
                    f"not {cfg.name!r}")
            self.plan = None
        if self.paged:
            if not T.paged_decode_supported(cfg):
                raise ValueError(
                    "paged serving needs a spiking SSA stack of pure "
                    f"attention blocks, not {cfg.name!r}")
            if cache_len % page_len:
                raise ValueError(
                    f"cache_len ({cache_len}) must be a multiple of "
                    f"page_len ({page_len})")
            self.page_len = page_len
            self.max_pages = cache_len // page_len
            # default pool: the same cache memory a dense server of this
            # slot count would allocate (prefix sharing turns that budget
            # into extra concurrency)
            self.n_pages = (slots * self.max_pages + ST.RESERVED_PAGES
                            if n_pages is None else n_pages)
            self.pages = PagePool(self.n_pages, page_len)
            self.state = self._place_state(ST.init_paged_state(
                cfg, slots, cache_len, page_len, self.n_pages))
            if placement is None:
                decode_out = None
                self._admit_slot = ST.paged_admit_slot_jit
                self._release_slot = ST.paged_release_slot_jit
                self._set_entry = ST.paged_set_table_entry_jit
                self._zero_pages_fn = ST.pool_zero_pages_jit
                self._copy_page = ST.pool_copy_page_jit
            else:
                decode_out = placement.paged_decode_out_shardings(
                    slots, cache_len, self.n_pages, page_len)
                state_sh = placement.paged_state_shardings(
                    slots, cache_len, self.n_pages, page_len)
                self._admit_slot = jax.jit(ST.paged_admit_slot,
                                           out_shardings=state_sh)
                self._release_slot = jax.jit(ST.paged_release_slot,
                                             out_shardings=state_sh)
                self._set_entry = jax.jit(ST.paged_set_table_entry,
                                          out_shardings=state_sh)
                self._zero_pages_fn = jax.jit(ST.pool_zero_pages,
                                              out_shardings=state_sh)
                self._copy_page = jax.jit(ST.pool_copy_page,
                                          out_shardings=state_sh)
            self._decode = ST.make_paged_decode_fn(
                cfg, self.pctx, backend, out_shardings=decode_out,
                plan=self.plan)
            self._prefill = None
            # host mirrors: page-table rows, per-slot logical positions,
            # prefill cursors, slot phases, outstanding page reservations
            self._table_rows = np.full((slots, self.max_pages), ST.NULL_PAGE,
                                       np.int32)
            self._slot_pos = [0] * slots
            self._cursor = [0] * slots
            self._phase = [DECODE] * slots
            self._slot_reserved = [0] * slots
            self._chain = [0] * slots  # prefix-cache chain id per slot
        else:
            self.state = self._place_state(ST.init_state(cfg, slots, cache_len))
            if placement is None:
                decode_out = prefill_out = None
                prefill_backend = backend
                self._splice = ST.splice_request_jit
                self._release = ST.release_slot_jit
            else:
                # mesh serving: slots ride the data axis, spiking kernels are
                # tensor-parallel over model; out-shardings are pinned so the
                # compiled decode feeds itself without resharding/recompiling
                decode_out = placement.decode_out_shardings(slots, cache_len)
                prefill_out = placement.replicated
                prefill_backend = placement.prefill_backend
                state_sh = placement.state_shardings(slots, cache_len)
                self._splice = jax.jit(ST.splice_request, out_shardings=state_sh)
                self._release = jax.jit(ST.release_slot, out_shardings=state_sh)
            self._decode = ST.make_decode_fn(cfg, self.pctx, backend,
                                             self.moe_impl,
                                             out_shardings=decode_out,
                                             plan=self.plan)
            self._prefill = ST.make_prefill_fn(cfg, self.pctx, prefill_backend,
                                               self.moe_impl,
                                               out_shardings=prefill_out)
        self._queue: Deque[Request] = deque()
        self._slot_req: List[Optional[Request]] = [None] * slots
        self._remaining: List[int] = [0] * slots
        self.outputs: Dict[int, List[int]] = {}
        # per-request measured energy / spike events (rid -> totals)
        self.request_energy_j: Dict[int, float] = {}
        self.request_spikes: Dict[int, float] = {}
        self.stats = self._fresh_stats()
        self._next_rid = 0
        # PCM device clock (drift lifecycle): picks up wherever the
        # programmed params already are — the device does not rejuvenate
        self._t_device = AD.device_time(params)
        self._last_recal = self._t_device
        self._t_image = self._t_device  # device time of the last image fold
        self._programmed = AD.has_device_state(params)
        self.stats.t_device_s = self._t_device
        # static per-decoded-token energy for spiking SSA configs (the
        # activity-independent ADC/periphery/LIF/comparator work)
        if getattr(cfg, "spiking", False) and cfg.attention_kind == "ssa":
            self._e_token_pj = EM.lm_decode_token_energy_pj(
                cfg.d_model, cfg.num_heads, cfg.resolved_head_dim, cfg.d_ff,
                cfg.num_layers, cfg.spike_T, cache_len, cfg.vocab_size)
        else:
            self._e_token_pj = 0.0
        self._e_event_pj = EM.decode_synapse_energy_pj()
        # telemetry: host-side only (see repro.obs) — binding it can never
        # touch the jitted step or change a token/joule
        self.obs: Optional[Telemetry] = None
        self._stat_marks: Dict[str, float] = {}
        self._pool_marks: Dict[str, float] = {}
        if obs is not None:
            self.attach_obs(obs)

    # -- telemetry -------------------------------------------------------

    # ServeStats fields mirrored into monotone counters (single source of
    # truth stays ServeStats; the registry syncs by delta once per step)
    _STAT_COUNTERS = (
        ("decode_steps", "decode_steps_total",
         "batched decode_step invocations"),
        ("decoded_tokens", "decoded_tokens_total", "greedy tokens decoded"),
        ("prefill_tokens", "prefill_tokens_total",
         "prompt positions prefilled (chunked or batch-1)"),
        ("admissions", "admissions_total", "requests admitted into slots"),
        ("evictions", "evictions_total", "slot evictions (finish or preempt)"),
        ("spike_events", "spike_events_total",
         "measured residual-stream spike events"),
        ("energy_j", "energy_joules_total",
         "metered inference energy (spike events x op energies)"),
        ("recalibrations", "gdc_recalibrations_total",
         "GDC recalibrations run by the drift policy"),
        ("prefix_hits", "prefix_page_hits_total",
         "prefix-cache page hits across admissions"),
        ("prefix_hit_tokens", "prefix_hit_tokens_total",
         "prompt positions skipped via shared pages"),
        ("cow_copies", "cow_copies_total", "copy-on-write page duplications"),
    )

    def attach_obs(self, obs: Telemetry) -> None:
        """Install (or replace) the telemetry bundle: resolve metric
        handles once and arm the page-pool guard dump sites."""
        self.obs = obs
        m = obs.metrics
        self._h_step = m.histogram(
            "decode_step_seconds", "batched decode_step latency")
        self._g_active = m.gauge("active_slots", "slots holding a request")
        self._g_queue = m.gauge(
            "scheduler_queue_depth", "submitted-not-yet-admitted requests")
        self._g_clock = m.gauge(
            "device_clock_seconds", "PCM device clock (drift lifecycle)")
        self._g_gain = m.gauge(
            "gdc_gain_mean", "mean GDC gain across programmed crossbars "
            "(set at bind and after each recalibration)")
        self._g_pages_in_use = m.gauge(
            "pool_pages_in_use", "physical KV pages referenced")
        self._g_pages_free = m.gauge(
            "pool_pages_free", "physical KV pages on the free list")
        self._c_lookups = m.counter(
            "prefix_lookups_total", "prefix-cache block lookups",
            ("result",))
        self._stat_counters = {
            field: m.counter(name, help) for field, name, help in
            self._STAT_COUNTERS}
        self._stat_marks = {f: 0.0 for f, _, _ in self._STAT_COUNTERS}
        self._pool_marks = {"hit": 0.0, "miss": 0.0}
        self._g_clock.set(self._t_device)
        if self._programmed:
            self._g_gain.set(AD.gdc_gain_summary(self.params))
        self._sync_stat_counters()
        self._arm_pool_guard()

    def detach_obs(self) -> None:
        """Remove the telemetry bundle (the exact inverse of
        :meth:`attach_obs`): metric handles are dropped, the page-pool
        guard hook is disarmed, and subsequent runs book nothing.  The
        registry itself is untouched — counters keep their lifetime
        values.  A later re-attach rebases the delta marks at zero and
        mirrors the scheduler's *current* ServeStats as fresh deltas,
        so call :meth:`reset` between detach and a re-attach to the
        same registry to avoid double-booking the interlude."""
        self.obs = None
        self._stat_counters = {}
        self._stat_marks = {}
        if self.paged:
            self.pages.on_violation = None

    def _arm_pool_guard(self) -> None:
        if self.obs is not None and self.paged:
            self.pages.on_violation = self._on_guard

    def _on_guard(self, reason: str) -> None:
        """Invariant-guard dump site (PagePool double-free/use-after-free,
        evict-unoccupied): postmortem first, the raise proceeds after."""
        if self.obs is not None:
            self.obs.guard_dump(reason)

    def _sync_stat_counters(self) -> None:
        """Mirror ServeStats into the registry's counters by delta."""
        st = self.stats
        marks = self._stat_marks
        for field, counter in self._stat_counters.items():
            cur = float(getattr(st, field))
            delta = cur - marks[field]
            if delta > 0:
                counter.inc(delta)
                marks[field] = cur

    def _obs_step(self, step_s: float, decoded: int) -> None:
        """Per-decode-step telemetry: latency histogram, occupancy gauges,
        counter sync, pool stats, profiler window."""
        obs = self.obs
        if obs is None:
            return
        self._h_step.observe(step_s)
        self._g_active.set(sum(r is not None for r in self._slot_req))
        self._g_queue.set(len(self._queue))
        if self.paged:
            pool = self.pages
            self._g_pages_in_use.set(pool.in_use)
            self._g_pages_free.set(pool.free_pages)
            for result, cur in (("hit", pool.prefix_hits),
                                ("miss", pool.prefix_misses)):
                delta = cur - self._pool_marks[result]
                if delta > 0:
                    self._c_lookups.inc(delta, result)
                    self._pool_marks[result] = cur
        self._sync_stat_counters()
        if obs.profiler is not None:
            obs.profiler.tick()

    def _fresh_stats(self) -> ServeStats:
        if self.placement is None:
            return ServeStats()
        return ServeStats(data_shards=self.placement.data,
                          model_shards=self.placement.model)

    def _place_state(self, state):
        return state if self.placement is None else self.placement.place_state(state)

    def _place_params(self, params):
        return params if self.placement is None else self.placement.place_params(params)

    def set_params(self, params: Any) -> None:
        """Swap the served params (e.g. a newly-programmed tree) and re-read
        the device lifecycle bookkeeping from them."""
        self.params = self._place_params(params)
        self._programmed = AD.has_device_state(params)
        self._t_device = AD.device_time(params)
        self._last_recal = self._t_device
        self._t_image = self._t_device
        self.stats.t_device_s = self._t_device

    def reset(self) -> None:
        """Drop all requests and state but keep the compiled step functions
        (fresh server, warm jit cache — used by benchmarks and tests).
        The PCM device clock is *not* reset: drift is physical."""
        if self.paged:
            self.pages = PagePool(self.n_pages, self.page_len)
            self.state = self._place_state(ST.init_paged_state(
                self.cfg, self.slots, self.cache_len, self.page_len,
                self.n_pages))
            self._table_rows[:] = ST.NULL_PAGE
            self._slot_pos = [0] * self.slots
            self._cursor = [0] * self.slots
            self._phase = [DECODE] * self.slots
            self._slot_reserved = [0] * self.slots
            self._chain = [0] * self.slots
        else:
            self.state = self._place_state(
                ST.init_state(self.cfg, self.slots, self.cache_len))
        self._queue.clear()
        self._slot_req = [None] * self.slots
        self._remaining = [0] * self.slots
        self.outputs = {}
        self.request_energy_j = {}
        self.request_spikes = {}
        self.stats = self._fresh_stats()
        self.stats.t_device_s = self._t_device
        if self.obs is not None:
            # counters are lifetime-monotone; only the delta marks rebase
            # onto the fresh ServeStats / PagePool
            self._stat_marks = {f: 0.0 for f, _, _ in self._STAT_COUNTERS}
            self._pool_marks = {"hit": 0.0, "miss": 0.0}
            self._arm_pool_guard()

    # -- request intake ------------------------------------------------

    def submit(self, prompt: Sequence[int], max_new: int, seed: Optional[int] = None) -> int:
        """Queue a request; returns its rid.  ``seed`` fixes the request's
        spike PRN stream (defaults to the rid) — the same (prompt, seed)
        decodes identically no matter how it is batched."""
        prompt = jnp.asarray(prompt, jnp.int32)
        assert prompt.ndim == 1 and prompt.shape[0] >= 1, "prompt must be [P>=1]"
        if max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {max_new}")
        if prompt.shape[0] + max_new > self.cache_len:
            raise ValueError(
                f"prompt ({prompt.shape[0]}) + max_new ({max_new}) exceeds "
                f"cache_len ({self.cache_len})"
            )
        if self.paged:
            worst = -(-(int(prompt.shape[0]) - 1 + max_new) // self.page_len)
            usable = self.n_pages - ST.RESERVED_PAGES
            if worst > usable:
                raise ValueError(
                    f"request needs up to {worst} pages but the pool only "
                    f"has {usable} usable — it could never be admitted")
        rid = self._next_rid
        self._next_rid += 1
        pnp = np.asarray(prompt, np.int32)
        # content keys for the prompt context (prefill PRN streams): pure
        # functions of the token prefix, so identical prefixes prefill
        # bit-identically on every serving path — dense or paged
        req = Request(rid, prompt, max_new, rid if seed is None else seed,
                      prompt_np=pnp, ckeys=ST.content_keys(pnp[:-1]))
        self._queue.append(req)
        self.stats.requests += 1
        if self.obs is not None:
            self.obs.trace(TR.SUBMIT, rid=rid, prompt_len=int(pnp.shape[0]),
                           max_new=max_new, seed=req.seed)
        return rid

    # -- slot management -----------------------------------------------

    def admit(self) -> int:
        """Admit queued requests into free slots (continuous batching).

        Dense mode prefills each admitted prompt through a batch-1 scan of
        the same decode path, then scatters the filled cache into the slot
        while the other slots' state is untouched.  Paged mode reserves
        pages, resolves prefix-cache hits, and leaves the remaining prompt
        positions to chunked prefill inside the batched step — admission
        blocks on *free pages*, not just free slots.  Returns #admitted."""
        if self.paged:
            return self._admit_paged()
        admitted = 0
        for slot in range(self.slots):
            if not self._queue or self._slot_req[slot] is not None:
                continue
            req = self._queue.popleft()
            p = req.prompt
            n_ctx = int(p.shape[0]) - 1  # last prompt token feeds the first decode
            padded = _bucket(max(n_ctx, 1))
            prompt_pad = jnp.zeros((padded,), jnp.int32).at[:n_ctx].set(p[:-1])
            ckeys_pad = np.zeros((padded,), np.uint32)
            ckeys_pad[:n_ctx] = req.ckeys
            cache1 = T.init_cache(self.cfg, 1, self.cache_len)
            cache1, pre_act = self._prefill(
                self.params, prompt_pad, jnp.int32(n_ctx),
                jnp.asarray(ckeys_pad), cache1,
            )
            self.state = self._splice(
                self.state, slot, cache1, p[-1], jnp.uint32(req.seed))
            self._slot_req[slot] = req
            self._remaining[slot] = req.max_new
            self.outputs[req.rid] = []
            # prefill energy is prompt-length dependent: book the measured
            # prompt spike events + static per-token cost at admission
            spikes = float(pre_act)
            e_j = (spikes * self._e_event_pj + n_ctx * self._e_token_pj) * 1e-12
            self.request_spikes[req.rid] = (
                self.request_spikes.get(req.rid, 0.0) + spikes)
            self.request_energy_j[req.rid] = (
                self.request_energy_j.get(req.rid, 0.0) + e_j)
            self.stats.spike_events += spikes
            self.stats.energy_j += e_j
            self.stats.prefill_tokens += n_ctx
            self.stats.admissions += 1
            admitted += 1
            if self.obs is not None:
                self.obs.trace(TR.ADMIT, rid=req.rid, slot=slot,
                               prefill_tokens=n_ctx, mode="dense")
        self.stats.peak_active_slots = max(
            self.stats.peak_active_slots,
            sum(r is not None for r in self._slot_req))
        return admitted

    # -- paged-mode page plumbing --------------------------------------

    def _zero_freed(self, pids: List[int]) -> None:
        """Zero freed physical pages on device (fixed-size jitted batches,
        padded with the trash page so the step compiles once)."""
        chunk = self.max_pages
        for i in range(0, len(pids), chunk):
            batch = np.full((chunk,), ST.TRASH_PAGE, np.int32)
            part = pids[i:i + chunk]
            batch[:len(part)] = part
            self.state = self._zero_pages_fn(self.state, jnp.asarray(batch))

    def _cow(self, slot: int, tp: int, src: int, keep_upto: int) -> int:
        """Copy-on-write: give ``slot`` exclusive ownership of logical block
        ``tp`` by copying the shared page's valid prefix (``< keep_upto``
        in-page positions) into a fresh page and repointing its table."""
        dst = self.pages.alloc(reserved=True)
        self._slot_reserved[slot] -= 1
        self.state = self._copy_page(self.state, jnp.int32(src),
                                     jnp.int32(dst), jnp.int32(keep_upto))
        self.state = self._set_entry(self.state, jnp.int32(slot),
                                     jnp.int32(tp), jnp.int32(dst))
        self._table_rows[slot, tp] = dst
        if self.pages.release(src):  # cache entry may have been LRU-evicted
            self._zero_freed([src])
        self.pages.cow_copies += 1
        return dst

    def _register_prefix(self, slot: int, upto: int) -> None:
        """Publish the page holding context positions up to ``upto`` (the
        end of a just-completed block, or the whole context for a partial
        tail block) in the prefix cache, keyed by (parent chain id, the
        block's own tokens) — O(page_len) hashing per block, exact by
        chain construction.  Tail blocks cost one reserved page later —
        the registrant's next write copy-on-writes — so they are
        registered opportunistically, only when the pool has slack."""
        req = self._slot_req[slot]
        tp = (upto - 1) // self.page_len
        key = (self._chain[slot],
               tuple(req.prompt_np[tp * self.page_len:upto].tolist()))
        pid = int(self._table_rows[slot, tp])
        if pid == ST.NULL_PAGE:
            return
        if upto % self.page_len:  # partial tail block: a chain leaf
            if self.pages.prefix_contains(key) or self.pages.available() < 1:
                return
            self.pages.reserve(1)
            self._slot_reserved[slot] += 1
            self.pages.prefix_register(key, pid, chain=False)
            return
        # full block: adopt the (new or already-canonical) chain id so the
        # slot's next block links to it
        self._chain[slot] = self.pages.prefix_register(key, pid, chain=True)

    def _admit_paged(self) -> int:
        """Paged admission: exact-prefix page hits + worst-case page
        reservation.  FIFO order is preserved — a request that cannot
        reserve its pages blocks the queue (head-of-line) rather than
        being overtaken, so admission order never depends on prompt sizes."""
        admitted = 0
        for slot in range(self.slots):
            if not self._queue or self._slot_req[slot] is not None:
                continue
            req = self._queue[0]
            ctx = req.prompt_np[:-1]
            n_ctx = req.n_ctx
            pl_ = self.page_len
            total_pages = -(-(n_ctx + req.max_new) // pl_)
            # leading-chain prefix match: full blocks while the chain is
            # unbroken (each link keyed by (parent chain id, block
            # tokens)), then — only off the complete full-block chain —
            # the partial tail leaf
            hits: List[int] = []
            chain = 0  # the empty-prefix root
            k = pl_
            while k <= n_ctx:
                ent = self.pages.prefix_lookup(
                    (chain, tuple(ctx[k - pl_:k].tolist())))
                if ent is None:
                    break
                hits.append(ent[0])
                chain = ent[1]
                k += pl_
            partial_pid = None
            if len(hits) == n_ctx // pl_ and n_ctx % pl_:
                ent = self.pages.prefix_lookup(
                    (chain, tuple(ctx[len(hits) * pl_:].tolist())))
                partial_pid = None if ent is None else ent[0]
            # worst-case unshared pages (a partial hit still allocates its
            # page at the copy-on-write); block on pool pressure
            needed = total_pages - len(hits)
            if self.pages.available() < needed:
                freed = self.pages.prefix_evict(
                    needed - self.pages.available())
                if freed:
                    self._zero_freed(freed)
            if self.pages.available() < needed:
                # hand the hit refs back; prefix_evict may already have
                # dropped these pages' cache entries, in which case ours
                # was the last ref and the page must be zeroed before reuse
                freed = [pid for pid in hits if self.pages.release(pid)]
                if partial_pid is not None and self.pages.release(partial_pid):
                    freed.append(partial_pid)
                if freed:
                    self._zero_freed(freed)
                break
            self._queue.popleft()
            self.pages.reserve(needed)
            row = np.full((self.max_pages,), ST.NULL_PAGE, np.int32)
            row[:len(hits)] = hits
            cursor = len(hits) * pl_
            if partial_pid is not None:
                row[n_ctx // pl_] = partial_pid
                cursor = n_ctx
            self._table_rows[slot] = row
            self.state = self._admit_slot(
                self.state, jnp.int32(slot), jnp.asarray(row),
                jnp.uint32(req.seed), jnp.int32(cursor))
            self._slot_req[slot] = req
            self._remaining[slot] = req.max_new
            self._slot_pos[slot] = cursor
            self._cursor[slot] = cursor
            self._phase[slot] = PREFILL if cursor < n_ctx else HANDOFF
            self._slot_reserved[slot] = needed
            self._chain[slot] = chain  # registrations link after the hits
            self.outputs[req.rid] = []
            self.stats.prefix_hit_tokens += cursor
            self.stats.prefix_hits += len(hits) + (partial_pid is not None)
            self.stats.admissions += 1
            admitted += 1
            if self.obs is not None:
                self.obs.trace(TR.ADMIT, rid=req.rid, slot=slot, mode="paged",
                               prefix_hit_tokens=cursor,
                               reserved_pages=needed)
        self.stats.peak_active_slots = max(
            self.stats.peak_active_slots,
            sum(r is not None for r in self._slot_req))
        return admitted

    def free_slots(self) -> int:
        """Slots not currently holding a request (queued submissions are
        *not* counted — they only claim a slot at the next ``step()``'s
        admission; see :meth:`queued_requests`)."""
        return sum(r is None for r in self._slot_req)

    def queued_requests(self) -> List[Request]:
        """Submitted-but-not-yet-admitted requests, FIFO order (the front
        door reads this to budget slots/pages it has already committed)."""
        return list(self._queue)

    def slot_of(self, rid: int) -> Optional[int]:
        """The slot currently decoding request ``rid`` (None when the
        request is queued, finished, or unknown)."""
        for slot, req in enumerate(self._slot_req):
            if req is not None and req.rid == rid:
                return slot
        return None

    def preempt(self, rid: int) -> Request:
        """Yank request ``rid`` out of the server — release its slot (or
        drop it from the queue) and forget its collected output — and
        return the :class:`Request` so the caller controls re-admission.

        Unlike ``evict(slot, requeue=True)`` (which re-queues internally
        and re-admits at the very next step), preemption hands scheduling
        *back to the caller*: the front door re-submits the same (prompt,
        max_new, seed) when the tenant's energy bucket refills, and token
        purity makes the restarted decode bit-identical — already-streamed
        tokens replay exactly.  Energy already booked to ``rid`` stays
        booked (preemption does not refund the joules it wasted)."""
        slot = self.slot_of(rid)
        if slot is not None:
            req = self._slot_req[slot]
            if self.obs is not None:
                self.obs.trace(TR.PREEMPT, rid=rid, slot=slot,
                               streamed=len(self.outputs.get(rid, ())))
            self.evict(slot)
            self.outputs.pop(rid, None)
            return req
        for req in self._queue:
            if req.rid == rid:
                self._queue.remove(req)
                self.outputs.pop(rid, None)
                return req
        raise ValueError(f"preempt of unknown/finished request rid={rid}")

    def evict(self, slot: int, requeue: bool = False) -> None:
        """Release a slot's state (zero or refcount-release cache pages,
        clear occupancy).

        With ``requeue=True`` the in-flight request restarts from its
        prompt on a later admission (preemption); otherwise its collected
        output is kept as-is.  Evicting an unoccupied slot raises — the
        use-after-evict / double-free guard."""
        req = self._slot_req[slot]
        if req is None:
            self._on_guard(f"evict of unoccupied slot {slot}")
            raise ValueError(f"evict of unoccupied slot {slot} "
                             "(double-evict or use-after-evict)")
        if self.obs is not None:
            self.obs.trace(TR.EVICT, rid=req.rid, slot=slot, requeue=requeue)
        if requeue:
            self._queue.appendleft(req)
            self.outputs.pop(req.rid, None)
        self._slot_req[slot] = None
        self._remaining[slot] = 0
        if self.paged:
            freed = []
            for pid in self._table_rows[slot]:
                if pid != ST.NULL_PAGE and self.pages.release(int(pid)):
                    freed.append(int(pid))
            if freed:
                self._zero_freed(freed)
            self.pages.unreserve(self._slot_reserved[slot])
            self._slot_reserved[slot] = 0
            self._table_rows[slot] = ST.NULL_PAGE
            self._slot_pos[slot] = 0
            self._cursor[slot] = 0
            self._phase[slot] = DECODE
            self._chain[slot] = 0
            self.state = self._release_slot(self.state, jnp.int32(slot))
        else:
            self.state = self._release(self.state, slot)
        self.stats.evictions += 1

    # -- serving loop --------------------------------------------------

    def _book_position(self, rid: int, spikes: float) -> None:
        """Book one served position's energy — measured spike events x the
        per-event op energy plus the static per-token cost — against the
        request and the aggregate stats.  One formula for dense decode,
        paged decode and paged chunked-prefill positions, so the
        paged==dense energy equality holds by construction."""
        e_j = (spikes * self._e_event_pj + self._e_token_pj) * 1e-12
        self.request_spikes[rid] = self.request_spikes.get(rid, 0.0) + spikes
        self.request_energy_j[rid] = (
            self.request_energy_j.get(rid, 0.0) + e_j)
        self.stats.spike_events += spikes
        self.stats.energy_j += e_j

    def step(self) -> int:
        """Admit, then advance every active slot one token.  Returns the
        number of tokens decoded (0 when idle).

        Each step also (a) meters energy — the decode returns per-slot
        measured spike-event counts, converted to joules and booked against
        the slot's request — and (b) advances the PCM drift lifecycle when
        a :class:`~repro.aimc_device.DriftPolicy` is set on programmed
        params (device clock from decode wall time, periodic GDC
        recalibration), without recompiling the jitted decode."""
        if self.paged:
            return self._step_paged()
        self.admit()
        if not any(r is not None for r in self._slot_req):
            return 0
        t0 = time.perf_counter()  # monotonic: durations must survive NTP
        logits, self.state, act = self._decode(self.params, self.state)
        nxt = np.asarray(self.state.tokens)  # syncs the step
        step_s = time.perf_counter() - t0
        self.stats.decode_s += step_s
        self.stats.decode_steps += 1
        act = np.asarray(act)
        obs = self.obs
        decoded = 0
        for slot in range(self.slots):
            req = self._slot_req[slot]
            if req is None:
                continue
            out = self.outputs[req.rid]
            out.append(int(nxt[slot]))
            decoded += 1
            if obs is not None:
                obs.trace(TR.FIRST_TOKEN if len(out) == 1 else TR.DECODE,
                          rid=req.rid, slot=slot, token=int(nxt[slot]),
                          pos=len(out))
            self._book_position(req.rid, float(act[slot]))
            self._remaining[slot] -= 1
            if self._remaining[slot] == 0:
                if obs is not None:
                    obs.trace(TR.FINISH, rid=req.rid, slot=slot,
                              tokens=len(out))
                self.evict(slot)
        self.stats.decoded_tokens += decoded
        self._advance_device_clock(step_s)
        self._obs_step(step_s, decoded)
        return decoded

    def _step_paged(self) -> int:
        """One paged batched step: chunked prefill and decode interleaved.

        Each occupied slot advances one position — a *prompt* position
        (chunked prefill: the next context token fed on its content-keyed
        PRN stream), the admission handoff (the last prompt token on the
        request's own stream), or a decode position (greedy argmax riding
        the state).  Before the step, every writing slot is guaranteed an
        exclusive physical page for its target block (allocation at block
        boundaries, copy-on-write off shared pages); idle slots write the
        trash page.  One jitted function serves all of it, compiled once.
        Returns #tokens decoded (prompt chunks don't count)."""
        self.admit()
        if not any(r is not None for r in self._slot_req):
            return 0
        b = self.slots
        feed_tok = np.zeros((b,), np.int32)
        feed_seed = np.zeros((b,), np.uint32)
        feed_mask = np.zeros((b,), bool)
        write_pids = np.full((b,), ST.TRASH_PAGE, np.int32)
        for slot in range(b):
            req = self._slot_req[slot]
            if req is None:
                feed_mask[slot] = True  # pin idle slots to token 0 / stream 0
                continue
            p = self._slot_pos[slot]
            tp, off = divmod(p, self.page_len)
            pid = int(self._table_rows[slot, tp])
            if pid == ST.NULL_PAGE:  # block boundary: open a fresh page
                pid = self.pages.alloc(reserved=True)
                self._slot_reserved[slot] -= 1
                self._table_rows[slot, tp] = pid
                self.state = self._set_entry(self.state, jnp.int32(slot),
                                             jnp.int32(tp), jnp.int32(pid))
            elif self.pages.refcount[pid] > 1:  # shared (prefix cache): CoW
                pid = self._cow(slot, tp, pid, off)
            write_pids[slot] = pid
            phase = self._phase[slot]
            if phase == PREFILL:
                cur = self._cursor[slot]
                feed_tok[slot] = req.prompt_np[cur]
                feed_seed[slot] = req.ckeys[cur]
                feed_mask[slot] = True
            elif phase == HANDOFF:
                feed_tok[slot] = req.prompt_np[-1]
                feed_seed[slot] = req.seed
                feed_mask[slot] = True
        t0 = time.perf_counter()  # monotonic: durations must survive NTP
        logits, self.state, act = self._decode(
            self.params, self.state, jnp.asarray(feed_tok),
            jnp.asarray(feed_seed), jnp.asarray(feed_mask),
            jnp.asarray(write_pids))
        nxt = np.asarray(self.state.tokens)  # syncs the step
        step_s = time.perf_counter() - t0
        self.stats.decode_s += step_s
        self.stats.decode_steps += 1
        act = np.asarray(act)
        obs = self.obs
        decoded = 0
        for slot in range(b):
            req = self._slot_req[slot]
            if req is None:
                continue
            self._slot_pos[slot] += 1
            phase = self._phase[slot]
            if phase == PREFILL:
                self._cursor[slot] += 1
                cur = self._cursor[slot]
                self.stats.prefill_tokens += 1
                if obs is not None:
                    obs.trace(TR.PREFILL_CHUNK, rid=req.rid, slot=slot,
                              pos=cur, n_ctx=req.n_ctx)
                if cur % self.page_len == 0:  # completed block: publish it
                    self._register_prefix(slot, cur)
                if cur == req.n_ctx:
                    if req.n_ctx % self.page_len:
                        self._register_prefix(slot, req.n_ctx)
                    self._phase[slot] = HANDOFF
            else:
                out = self.outputs[req.rid]
                out.append(int(nxt[slot]))
                decoded += 1
                if obs is not None:
                    obs.trace(TR.FIRST_TOKEN if len(out) == 1 else TR.DECODE,
                              rid=req.rid, slot=slot, token=int(nxt[slot]),
                              pos=len(out))
                if phase == HANDOFF:
                    self._phase[slot] = DECODE
                self._remaining[slot] -= 1
            self._book_position(req.rid, float(act[slot]))
            if self._remaining[slot] == 0:
                if obs is not None:
                    obs.trace(TR.FINISH, rid=req.rid, slot=slot,
                              tokens=len(self.outputs[req.rid]))
                self.evict(slot)
        self.stats.decoded_tokens += decoded
        self.stats.pages_in_use_peak = max(self.stats.pages_in_use_peak,
                                           self.pages.peak_in_use)
        self.stats.cow_copies = self.pages.cow_copies
        self._advance_device_clock(step_s)
        self._obs_step(step_s, decoded)
        return decoded

    def _advance_device_clock(self, step_wall_s: float) -> None:
        """Drift lifecycle: age the programmed PCM state and periodically
        GDC-recalibrate, per the :class:`~repro.aimc_device.DriftPolicy`.

        Leaf-value-only pytree updates (``drift_tree_jit`` /
        ``recalibrate_tree_jit``): shapes, dtypes and the params treedef are
        unchanged, so the compiled ``decode_step`` stays warm.

        The scalar clock advances every step, but the O(params) image fold
        (drift re-quantisation) only runs when the drift factor has moved
        by at least ~half an int8 image LSB since the last fold — at device
        ages of hours a per-token refresh could not change a single image
        level and would just burn host time — and always right before a
        GDC recalibration (which reads the state's own clock)."""
        pol = self.drift
        if pol is None or not self._programmed:
            return
        dt = pol.seconds_per_step if pol.seconds_per_step > 0 else (
            step_wall_s * pol.time_scale)
        self._t_device += dt
        due_recal = (pol.recal_interval_s > 0
                     and self._t_device - self._last_recal >= pol.recal_interval_s)
        # half-LSB criterion: (t/t_image)^-nu_mean moved by > 0.5/127
        ratio = (1.0 - 0.5 / 127.0) ** (-1.0 / max(pol.cfg.drift_nu_mean, 1e-3))
        due_image = self._t_device >= max(self._t_image,
                                          pol.cfg.drift_t0_s) * ratio
        if due_recal or due_image:
            self.params = self._place_params(AD.drift_tree_jit(
                self.params, jnp.float32(self._t_device), pol.cfg))
            self._t_image = self._t_device
        if due_recal:
            self.params = self._place_params(
                AD.recalibrate_tree_jit(self.params, pol.cfg))
            self._last_recal = self._t_device
            self.stats.recalibrations += 1
            if self.obs is not None:
                # one host read per recal event (rare): the post-recal gain
                # is *the* signal that GDC actually repaired the drift
                gain = AD.gdc_gain_summary(self.params)
                self._g_gain.set(gain)
                self.obs.trace(TR.GDC_RECAL, t_device_s=self._t_device,
                               gain=gain, n=self.stats.recalibrations)
        self.stats.t_device_s = self._t_device
        if self.obs is not None:
            self._g_clock.set(self._t_device)

    def run(self) -> Dict[int, List[int]]:
        """Serve until the queue and all slots drain; returns outputs."""
        t0 = time.perf_counter()  # monotonic: wall_s is a duration
        while self._queue or any(r is not None for r in self._slot_req):
            self.step()
        self.stats.wall_s += time.perf_counter() - t0
        if self.obs is not None and self.obs.profiler is not None:
            self.obs.profiler.stop()  # close a capture wider than the run
        return self.outputs
