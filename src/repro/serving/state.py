"""Serving state: slot-dense DecodeState and the block-paged PagedDecodeState.

Two cache organisations back the continuous batch:

* :class:`DecodeState` — the slot-dense layout: the model cache pytree (ANN
  float KV / recurrent state, or binary spike-train KV for SSA configs)
  keeps one fixed-length region per slot.  Admission and eviction are
  O(slot) scatter updates; freed slots are *zeroed*, which both releases
  the logical region and masks the slot out of the spiking comparators
  (zero AND-counts never spike; ANN caches make stale keys unreachable via
  ``pos = 0``).
* :class:`PagedDecodeState` — the block-paged layout for spiking SSA
  configs: K/V spike trains live in a global physical page pool
  (``models.transformer.paged_pool_schema``) and each slot addresses its
  logical blocks through a row of the page table.  Pages are refcounted
  host-side (:class:`repro.serving.pages.PagePool`) with copy-on-write,
  and a content-keyed prefix cache maps identical prompt prefixes onto the
  *same physical pages* — exact, bit-identical sharing, because prefill
  spike randomness is keyed by (content, position), not by request
  (:func:`content_keys`).  Physical page 0 is the permanently-zero *null
  page* (unallocated blocks read as zero spikes), page 1 the *trash page*
  idle slots write into; both keep every step fixed-shape so the jitted
  decode compiles exactly once.

Cache leaves come in two stackings (see ``models/transformer.py``):
``periods`` leaves are ``[n_periods, slots|n_pages, ...]`` (layer-scanned)
and ``remainder`` leaves drop the leading period axis — the slot/page
helpers below absorb that split so callers never touch it.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models import transformer as T
from repro.models.moe import ParallelCtx

Array = jax.Array

# reserved physical pages of every paged pool
NULL_PAGE = 0  # permanently zero; the target of unallocated table entries
TRASH_PAGE = 1  # where idle slots' decode writes land; never read
RESERVED_PAGES = 2


@dataclasses.dataclass
class DecodeState:
    """One continuous batch: model cache + per-slot serving counters.

    cache   — model cache pytree (per-slot ``pos`` counters inside leaves)
    tokens  — [slots] int32, next input token per slot
    seeds   — [slots] uint32, per-request PRN stream id (spiking decode)
    active  — [slots] bool, slot occupancy
    """

    cache: Any
    tokens: Array
    seeds: Array
    active: Array


jax.tree_util.register_pytree_node(
    DecodeState,
    lambda s: ((s.cache, s.tokens, s.seeds, s.active), None),
    lambda _, c: DecodeState(*c),
)


def init_state(cfg, slots: int, cache_len: int) -> DecodeState:
    """A fresh, empty continuous batch of ``slots`` slots."""
    return DecodeState(
        cache=T.init_cache(cfg, slots, cache_len),
        tokens=jnp.zeros((slots,), jnp.int32),
        seeds=jnp.zeros((slots,), jnp.uint32),
        active=jnp.zeros((slots,), bool),
    )


# ---------------------------------------------------------------------------
# Slot-level cache surgery
# ---------------------------------------------------------------------------


def _map_cache(cache, f_periods, f_remainder, *rest):
    out = {}
    if "periods" in cache:
        out["periods"] = jax.tree.map(
            f_periods, cache["periods"], *[r["periods"] for r in rest])
    if "remainder" in cache:
        out["remainder"] = jax.tree.map(
            f_remainder, cache["remainder"], *[r["remainder"] for r in rest])
    return out


def slot_slice(cache, slot) -> Any:
    """A batch-1 view of one slot's cache."""
    return _map_cache(
        cache,
        lambda a: lax.dynamic_slice_in_dim(a, slot, 1, axis=1),
        lambda a: lax.dynamic_slice_in_dim(a, slot, 1, axis=0),
    )


def slot_splice(cache, one, slot) -> Any:
    """Write a batch-1 cache into slot ``slot`` of the batched cache."""
    return _map_cache(
        cache,
        lambda a, o: lax.dynamic_update_slice_in_dim(a, o.astype(a.dtype), slot, axis=1),
        lambda a, o: lax.dynamic_update_slice_in_dim(a, o.astype(a.dtype), slot, axis=0),
        one,
    )


def slot_zero(cache, slot) -> Any:
    """Zero one slot's cache leaves (state release: pos=0, spike trains=0)."""
    return _map_cache(
        cache,
        lambda a: a.at[:, slot].set(jnp.zeros((), a.dtype)),
        lambda a: a.at[slot].set(jnp.zeros((), a.dtype)),
    )


def splice_request(state: DecodeState, slot, cache1, token, seed) -> DecodeState:
    """Admit a prefilled request into ``slot`` (continuous-batching splice)."""
    return DecodeState(
        cache=slot_splice(state.cache, cache1, slot),
        tokens=state.tokens.at[slot].set(token),
        seeds=state.seeds.at[slot].set(seed),
        active=state.active.at[slot].set(True),
    )


def release_slot(state: DecodeState, slot) -> DecodeState:
    """Evict: zero the slot's cache and mark it free."""
    return DecodeState(
        cache=slot_zero(state.cache, slot),
        tokens=state.tokens.at[slot].set(0),
        seeds=state.seeds.at[slot].set(0),
        active=state.active.at[slot].set(False),
    )


# ---------------------------------------------------------------------------
# Content-keyed prefill PRN streams
# ---------------------------------------------------------------------------


def _splitmix32(x: int) -> int:
    """32-bit splitmix finaliser (int -> int in [0, 2^32), well-mixed)."""
    x = (x + 0x9E3779B9) & 0xFFFFFFFF
    x ^= x >> 16
    x = (x * 0x21F0AAAD) & 0xFFFFFFFF
    x ^= x >> 15
    x = (x * 0x735A2D97) & 0xFFFFFFFF
    x ^= x >> 15
    return x


def content_keys(tokens) -> np.ndarray:
    """Per-position *content* PRN stream ids for prompt prefill.

    ``key[i] = H(tokens[0..i])`` — a rolling hash chain, so the spike
    randomness drawn at prompt position ``i`` depends only on the token
    prefix up to ``i`` (plus the position itself, folded in downstream by
    ``_slot_base_keys``), never on the request.  Two requests sharing a
    prompt prefix therefore produce *bit-identical* K/V spike trains for
    the shared positions — the property that lets the paged prefix cache
    map them onto the same physical pages.  Decode keeps per-request
    seeds, so generations still diverge per request.

    (A 32-bit hash collision between different prefixes only makes them
    share comparator randomness, never content — harmless.  The prefix
    cache itself matches on exact token tuples, not on this hash.)
    """
    toks = np.asarray(tokens, np.int64)
    out = np.empty(toks.shape[0], np.uint32)
    h = 0x1C0FFEE5
    for i, t in enumerate(toks):
        h = _splitmix32(h ^ _splitmix32(int(t) & 0xFFFFFFFF))
        out[i] = h
    return out


# ---------------------------------------------------------------------------
# Block-paged serving state (spiking SSA configs)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PagedDecodeState:
    """One paged continuous batch: global KV page pool + per-slot counters.

    pool        — paged KV pool pytree (``kp/vp [.., n_pages, T, KV,
                  page_len, hd]`` leaves; no slot axis)
    page_table  — [slots, max_pages] int32 physical page per logical block
                  (NULL_PAGE = unallocated: reads as zero spikes)
    pos         — [slots] int32, each slot's next logical write position
    tokens      — [slots] int32, next input token per slot
    seeds       — [slots] uint32, per-slot PRN stream id
    active      — [slots] bool, slot occupancy
    """

    pool: Any
    page_table: Array
    pos: Array
    tokens: Array
    seeds: Array
    active: Array

    @property
    def page_len(self) -> int:
        leaf = jax.tree.leaves(self.pool)[0]
        return leaf.shape[-2]

    @property
    def n_pages(self) -> int:
        # leaves are [n_pages, ...] (remainder) or [periods, n_pages, ...]
        leaf = jax.tree.leaves(self.pool)[0]
        return leaf.shape[-5]


jax.tree_util.register_pytree_node(
    PagedDecodeState,
    lambda s: ((s.pool, s.page_table, s.pos, s.tokens, s.seeds, s.active), None),
    lambda _, c: PagedDecodeState(*c),
)


def init_paged_state(cfg, slots: int, cache_len: int, page_len: int,
                     n_pages: int) -> PagedDecodeState:
    """A fresh paged batch: all pages free and zeroed, all tables null."""
    assert cache_len % page_len == 0, (
        f"cache_len ({cache_len}) must be a multiple of page_len ({page_len})")
    assert n_pages > RESERVED_PAGES, "pool needs pages beyond null+trash"
    return PagedDecodeState(
        pool=T.init_paged_pool(cfg, n_pages, page_len),
        page_table=jnp.full((slots, cache_len // page_len), NULL_PAGE,
                            jnp.int32),
        pos=jnp.zeros((slots,), jnp.int32),
        tokens=jnp.zeros((slots,), jnp.int32),
        seeds=jnp.zeros((slots,), jnp.uint32),
        active=jnp.zeros((slots,), bool),
    )


def _map_pool(pool, f):
    return jax.tree.map(
        lambda a: f(a) if a.ndim == 5 else jax.vmap(f)(a), pool)


def paged_admit_slot(state: PagedDecodeState, slot, table_row, seed, pos
                     ) -> PagedDecodeState:
    """Open a slot: install its (prefix-hit-prefilled) page-table row and
    starting position (the first *unshared* prompt position — prefix-cache
    hits skip straight past their pages); the scheduler feeds tokens and
    PRN stream ids per step."""
    return dataclasses.replace(
        state,
        page_table=state.page_table.at[slot].set(table_row),
        pos=state.pos.at[slot].set(pos),
        seeds=state.seeds.at[slot].set(seed),
        active=state.active.at[slot].set(True),
    )


def paged_release_slot(state: PagedDecodeState, slot) -> PagedDecodeState:
    """Close a slot: null its table row and zero its counters.  (The host
    :class:`~repro.serving.pages.PagePool` decides which of its pages are
    actually freed — shared pages live on under other refs.)"""
    return dataclasses.replace(
        state,
        page_table=state.page_table.at[slot].set(NULL_PAGE),
        pos=state.pos.at[slot].set(0),
        tokens=state.tokens.at[slot].set(0),
        seeds=state.seeds.at[slot].set(0),
        active=state.active.at[slot].set(False),
    )


def paged_set_table_entry(state: PagedDecodeState, slot, idx, pid
                          ) -> PagedDecodeState:
    """Point one logical block of one slot at a physical page."""
    return dataclasses.replace(
        state, page_table=state.page_table.at[slot, idx].set(pid))


def pool_zero_pages(state: PagedDecodeState, pids: Array) -> PagedDecodeState:
    """Zero a fixed-size batch of physical pages (freed pages must read as
    zero spikes before reuse; pad the batch with TRASH_PAGE ids)."""
    def z(leaf):
        return leaf.at[pids].set(jnp.zeros((), leaf.dtype))

    return dataclasses.replace(state, pool=_map_pool(state.pool, z))


def pool_copy_page(state: PagedDecodeState, src, dst, keep_upto
                   ) -> PagedDecodeState:
    """Copy-on-write: duplicate page ``src`` into ``dst``, keeping only
    in-page positions ``< keep_upto`` (later offsets are zeroed so the new
    owner's unwritten tail stays comparator-masked)."""
    def cp(leaf):  # [n_pages, T, KV, page_len, hd]
        page = leaf[src]
        keep = (jnp.arange(leaf.shape[-2]) < keep_upto)[None, None, :, None]
        return leaf.at[dst].set(jnp.where(keep, page, 0).astype(leaf.dtype))

    return dataclasses.replace(state, pool=_map_pool(state.pool, cp))


paged_admit_slot_jit = jax.jit(paged_admit_slot)
paged_release_slot_jit = jax.jit(paged_release_slot)
paged_set_table_entry_jit = jax.jit(paged_set_table_entry)
pool_zero_pages_jit = jax.jit(pool_zero_pages)
pool_copy_page_jit = jax.jit(pool_copy_page)


# ---------------------------------------------------------------------------
# Jitted step / prefill factories
# ---------------------------------------------------------------------------


def make_paged_decode_fn(cfg, pctx: ParallelCtx, backend,
                         out_shardings=None, plan=None):
    """The single jitted batched step of a *paged* server — decode and
    chunked prefill ride the same compiled function.

    ``(params, state, feed_tok [B], feed_seed [B], feed_mask [B],
    write_pids [B]) -> (logits, state', activity)``.  Slots with
    ``feed_mask`` take their input token and PRN stream id from the feed
    (chunked prefill: the next prompt token keyed by its *content key*;
    admission handoff: the last prompt token keyed by the request seed) —
    everything else rides the state like the dense step (greedy next-token
    written back).  ``write_pids`` names each slot's private physical page
    for this step's K/V write (the scheduler guarantees refcount-1
    ownership via copy-on-write; idle slots point at the trash page).  The
    fed seed persists into ``state.seeds``, so after the admission handoff
    the slot keeps decoding on its request stream with no further feeds.
    Every argument keeps one fixed shape: the step compiles exactly once
    for the server's lifetime (drift/GDC param updates stay
    leaf-value-only, as in :func:`make_decode_fn`).
    """

    def step(params, state: PagedDecodeState, feed_tok, feed_seed, feed_mask,
             write_pids):
        tok = jnp.where(feed_mask, feed_tok, state.tokens)
        seed = jnp.where(feed_mask, feed_seed, state.seeds)
        logits, pool, act = T.paged_decode_step(
            params, state.pool, state.page_table, tok[:, None], state.pos,
            seed, write_pids, cfg, pctx, backend=backend, plan=plan)
        nxt = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)
        st = dataclasses.replace(state, pool=pool, pos=state.pos + 1,
                                 tokens=nxt, seeds=seed)
        return logits, st, act

    if out_shardings is None:
        return jax.jit(step)
    return jax.jit(step, out_shardings=out_shardings)


def make_decode_fn(cfg, pctx: ParallelCtx, backend, moe_impl: str,
                   out_shardings=None, plan=None):
    """The single jit-compiled batched decode step over the whole batch.

    ``(params, state) -> (logits [slots,1,V], state', activity [slots])`` —
    every active slot advances one token (greedy next-token written back
    into ``state.tokens``).  Runs entirely through the engine backend's
    spiking primitives for SSA configs; the conventional float path
    otherwise.  ``activity`` is each slot's measured spike-event count this
    step (zeros on the float path) — the scheduler turns it into
    per-request energy.  ``params`` may hold programmed
    ``AIMCDeviceState`` leaves; the drift lifecycle only rewrites leaf
    *values*, so one compile serves the server's whole lifetime.

    ``out_shardings`` (mesh serving — ``repro.distributed``) pins the
    (logits, state, activity) placements so the output state always
    matches the input state's sharding: the compiled step feeds itself
    without resharding or recompiling.
    """

    def step(params, state: DecodeState):
        logits, cache, act = T.decode_step(
            params, state.cache, state.tokens[:, None], cfg, pctx,
            moe_impl=moe_impl, backend=backend, seeds=state.seeds,
            with_activity=True, plan=plan,
        )
        nxt = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)
        return logits, dataclasses.replace(state, cache=cache, tokens=nxt), act

    if out_shardings is None:
        return jax.jit(step)
    return jax.jit(step, out_shardings=out_shardings)


def make_prefill_fn(cfg, pctx: ParallelCtx, backend, moe_impl: str,
                    out_shardings=None):
    """Batch-1 prompt prefill through the *same* decode path as serving.

    ``(params, prompt [P], length, seeds [P], cache1) -> (cache1',
    activity)`` — scans the padded prompt through single-token decode,
    gating cache updates on ``idx < length`` so one compiled scan serves
    every prompt in a padding bucket.  Going through ``decode_step`` (not
    the training forward) keeps prefill bit-identical to decoding the
    prompt token by token, which is what makes batched serving exactly
    reproduce single-slot decoding.  ``seeds`` carries one PRN stream id
    per prompt position — the *content keys* of :func:`content_keys`, so
    prefill spike randomness is a pure function of (token prefix,
    position) and identical prompt prefixes produce bit-identical spike
    trains on every serving path, dense or paged.  ``activity`` is the
    prompt's total spike-event count (valid positions only) — prefill
    energy is prompt-length dependent and is booked against the request at
    admission.
    """

    def prefill(params, prompt, length, seeds, cache1):
        def body(carry, xs):
            c, act = carry
            tok, sd, idx = xs
            _, c2, a = T.decode_step(
                params, c, tok[None, None], cfg, pctx, moe_impl=moe_impl,
                backend=backend, seeds=sd[None],
                with_activity=True,
            )
            keep = idx < length
            c = jax.tree.map(lambda a_, b_: jnp.where(keep, b_, a_), c, c2)
            act = act + jnp.where(keep, a[0], 0.0)
            return (c, act), None

        (cache1, act), _ = lax.scan(
            body, (cache1, jnp.zeros((), jnp.float32)),
            (prompt, seeds.astype(jnp.uint32),
             jnp.arange(prompt.shape[0])))
        return cache1, act

    if out_shardings is None:
        return jax.jit(prefill)
    # mesh serving: the batch-1 prefill result is replicated (splice
    # scatters it into the data-sharded batch afterwards)
    return jax.jit(prefill, out_shardings=out_shardings)


splice_request_jit = jax.jit(splice_request)
release_slot_jit = jax.jit(release_slot)
