"""DecodeState: the per-slot serving state pytree + slot alloc/free ops.

The serving analogue of a paged KV cache: one :class:`DecodeState` holds the
whole continuous batch — the model cache pytree (ANN float KV / recurrent
state, or binary spike-train KV for SSA configs), the next input token per
slot, the per-slot PRN stream ids, and the slot occupancy mask.  Every leaf
is slot-major, so admission and eviction are O(slot) scatter updates while
the jitted ``decode_step`` keeps one fixed shape for the lifetime of the
server.

Cache leaves come in two stackings (see ``models/transformer.py``):
``periods`` leaves are ``[n_periods, slots, ...]`` (layer-scanned) and
``remainder`` leaves are ``[slots, ...]`` — the slot helpers below absorb
that split so callers never touch it.

Freed slots are *zeroed*, not just masked: for spiking SSA caches a zero
K/V train is what masks the slot's stale positions out of the hardware
comparators (zero AND-counts never spike), and for ANN caches ``pos = 0``
makes stale keys unreachable.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import transformer as T
from repro.models.moe import ParallelCtx

Array = jax.Array


@dataclasses.dataclass
class DecodeState:
    """One continuous batch: model cache + per-slot serving counters.

    cache   — model cache pytree (per-slot ``pos`` counters inside leaves)
    tokens  — [slots] int32, next input token per slot
    seeds   — [slots] uint32, per-request PRN stream id (spiking decode)
    active  — [slots] bool, slot occupancy
    """

    cache: Any
    tokens: Array
    seeds: Array
    active: Array


jax.tree_util.register_pytree_node(
    DecodeState,
    lambda s: ((s.cache, s.tokens, s.seeds, s.active), None),
    lambda _, c: DecodeState(*c),
)


def init_state(cfg, slots: int, cache_len: int) -> DecodeState:
    """A fresh, empty continuous batch of ``slots`` slots."""
    return DecodeState(
        cache=T.init_cache(cfg, slots, cache_len),
        tokens=jnp.zeros((slots,), jnp.int32),
        seeds=jnp.zeros((slots,), jnp.uint32),
        active=jnp.zeros((slots,), bool),
    )


# ---------------------------------------------------------------------------
# Slot-level cache surgery
# ---------------------------------------------------------------------------


def _map_cache(cache, f_periods, f_remainder, *rest):
    out = {}
    if "periods" in cache:
        out["periods"] = jax.tree.map(
            f_periods, cache["periods"], *[r["periods"] for r in rest])
    if "remainder" in cache:
        out["remainder"] = jax.tree.map(
            f_remainder, cache["remainder"], *[r["remainder"] for r in rest])
    return out


def slot_slice(cache, slot) -> Any:
    """A batch-1 view of one slot's cache."""
    return _map_cache(
        cache,
        lambda a: lax.dynamic_slice_in_dim(a, slot, 1, axis=1),
        lambda a: lax.dynamic_slice_in_dim(a, slot, 1, axis=0),
    )


def slot_splice(cache, one, slot) -> Any:
    """Write a batch-1 cache into slot ``slot`` of the batched cache."""
    return _map_cache(
        cache,
        lambda a, o: lax.dynamic_update_slice_in_dim(a, o.astype(a.dtype), slot, axis=1),
        lambda a, o: lax.dynamic_update_slice_in_dim(a, o.astype(a.dtype), slot, axis=0),
        one,
    )


def slot_zero(cache, slot) -> Any:
    """Zero one slot's cache leaves (state release: pos=0, spike trains=0)."""
    return _map_cache(
        cache,
        lambda a: a.at[:, slot].set(jnp.zeros((), a.dtype)),
        lambda a: a.at[slot].set(jnp.zeros((), a.dtype)),
    )


def splice_request(state: DecodeState, slot, cache1, token, seed) -> DecodeState:
    """Admit a prefilled request into ``slot`` (continuous-batching splice)."""
    return DecodeState(
        cache=slot_splice(state.cache, cache1, slot),
        tokens=state.tokens.at[slot].set(token),
        seeds=state.seeds.at[slot].set(seed),
        active=state.active.at[slot].set(True),
    )


def release_slot(state: DecodeState, slot) -> DecodeState:
    """Evict: zero the slot's cache and mark it free."""
    return DecodeState(
        cache=slot_zero(state.cache, slot),
        tokens=state.tokens.at[slot].set(0),
        seeds=state.seeds.at[slot].set(0),
        active=state.active.at[slot].set(False),
    )


# ---------------------------------------------------------------------------
# Jitted step / prefill factories
# ---------------------------------------------------------------------------


def make_decode_fn(cfg, pctx: ParallelCtx, backend, moe_impl: str,
                   out_shardings=None):
    """The single jit-compiled batched decode step over the whole batch.

    ``(params, state) -> (logits [slots,1,V], state', activity [slots])`` —
    every active slot advances one token (greedy next-token written back
    into ``state.tokens``).  Runs entirely through the engine backend's
    spiking primitives for SSA configs; the conventional float path
    otherwise.  ``activity`` is each slot's measured spike-event count this
    step (zeros on the float path) — the scheduler turns it into
    per-request energy.  ``params`` may hold programmed
    ``AIMCDeviceState`` leaves; the drift lifecycle only rewrites leaf
    *values*, so one compile serves the server's whole lifetime.

    ``out_shardings`` (mesh serving — ``repro.distributed``) pins the
    (logits, state, activity) placements so the output state always
    matches the input state's sharding: the compiled step feeds itself
    without resharding or recompiling.
    """

    def step(params, state: DecodeState):
        logits, cache, act = T.decode_step(
            params, state.cache, state.tokens[:, None], cfg, pctx,
            moe_impl=moe_impl, backend=backend, seeds=state.seeds,
            with_activity=True,
        )
        nxt = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)
        return logits, dataclasses.replace(state, cache=cache, tokens=nxt), act

    if out_shardings is None:
        return jax.jit(step)
    return jax.jit(step, out_shardings=out_shardings)


def make_prefill_fn(cfg, pctx: ParallelCtx, backend, moe_impl: str,
                    out_shardings=None):
    """Batch-1 prompt prefill through the *same* decode path as serving.

    ``(params, prompt [P], length, seed, cache1) -> (cache1', activity)`` —
    scans the padded prompt through single-token decode, gating cache
    updates on ``idx < length`` so one compiled scan serves every prompt in
    a padding bucket.  Going through ``decode_step`` (not the training
    forward) keeps prefill bit-identical to decoding the prompt token by
    token, which is what makes batched serving exactly reproduce
    single-slot decoding.  ``activity`` is the prompt's total spike-event
    count (valid positions only) — prefill energy is prompt-length
    dependent and is booked against the request at admission.
    """

    def prefill(params, prompt, length, seed, cache1):
        def body(carry, xs):
            c, act = carry
            tok, idx = xs
            _, c2, a = T.decode_step(
                params, c, tok[None, None], cfg, pctx, moe_impl=moe_impl,
                backend=backend, seeds=jnp.full((1,), seed, jnp.uint32),
                with_activity=True,
            )
            keep = idx < length
            c = jax.tree.map(lambda a_, b_: jnp.where(keep, b_, a_), c, c2)
            act = act + jnp.where(keep, a[0], 0.0)
            return (c, act), None

        (cache1, act), _ = lax.scan(
            body, (cache1, jnp.zeros((), jnp.float32)),
            (prompt, jnp.arange(prompt.shape[0])))
        return cache1, act

    if out_shardings is None:
        return jax.jit(prefill)
    # mesh serving: the batch-1 prefill result is replicated (splice
    # scatters it into the data-sharded batch afterwards)
    return jax.jit(prefill, out_shardings=out_shardings)


splice_request_jit = jax.jit(splice_request)
release_slot_jit = jax.jit(release_slot)
