"""45 nm CMOS energy/latency/area constants (paper §VII refs [54][55][32]).

Digital op energies follow Horowitz (ISSCC'14) / Pedram et al. [54] as the
paper does; AIMC tile costs follow DNN+NeuroSim-style modelling with the
Table II configuration (PCM, 128x128, 5-bit ADC shared 1:8).  Values are
picojoules unless noted.  The paper's own numbers are derived with
NeuroSim V1.4 + Cadence 45 nm synthesis; we document every constant here
and validate the *ratios* of Fig. 8/9/10 and Table VI in
benchmarks/fig8_energy.py (see EXPERIMENTS.md §Paper-claims).
"""

# ---- digital arithmetic (45 nm, pJ/op) ----
# Raw gate-level numbers follow Horowitz/Pedram; *system-level* per-MAC
# energies (with pipeline registers, operand staging, control) are fitted
# within plausible ranges so the four-design comparison reproduces the
# paper's reported ratios — attention MACs on a time-multiplexed digital
# engine cost several x a systolic FF MAC (the A^3/SwiftTron observation
# the paper builds on).  Every fitted value is marked (fit).
E_ADD_INT8 = 0.03
E_ADD_INT16 = 0.05
E_ADD_INT32 = 0.1
E_MUL_INT8 = 0.2
E_MUL_INT32 = 3.1
E_MAC_FF = 0.204  # (fit) systolic, weight-stationary, high reuse
E_MAC_ATTN = 2.9  # (fit) dynamic x dynamic operands, time-multiplexed
# engine with repeated parameter reads (SwiftTron's stated overhead)
E_MAC_INT8 = E_ADD_INT32 + E_MUL_INT8  # gate-level reference value

# bit-level / SNN ops
E_AND = 0.0015  # 2-input AND gate toggle
E_CNT8 = 0.015  # 8-bit ripple counter increment (fit)
E_CMP8 = 0.03  # 8-bit comparator (Bernoulli encoder)
E_LFSR32 = 0.12  # 32-bit LFSR step, amortised over 4 tapped bytes
E_LIF_STEP = 0.25  # shift + add + compare + reset (per neuron per step)
SNN_SPIKE_RATE = 0.19  # event-driven: adds fire only on spikes [15]

# nonlinearities (per element, second-order poly approx as in [34])
E_SOFTMAX_EL = 4.0
E_LAYERNORM_EL = 2.5
E_GELU_EL = 1.5

# ---- memory (on-chip SRAM, pJ/byte) ----
E_SRAM_RD = 1.2
E_SRAM_WR = 1.4
DIGITAL_RELOAD = 15.0  # (fit) operand re-reads of tiled digital dataflows
SNN_RELOAD = 3.0  # (fit) event-driven dataflow re-reads less

# ---- AIMC (PCM crossbar, Table II config) ----
# per 128x128-tile full read (one binary input vector cycle).  Fractions
# fitted to Fig. 9's AIMC breakdown (periphery 85.9 / accum 12.1 / ADC 2.0
# / crossbar ~0 %); absolute scale fitted to the paper's 0.30 mJ/inference
# on ViT-8-768 (Table VI).
E_XBAR_TILE_READ = 0.5  # analog array read is negligible (Fig. 9)
E_ADC_CONV = 0.0074  # (fit) effective amortised 5-bit conversion w/ 1:8 sharing
ADC_PER_TILE = 128  # 16 shared readouts x 8 mux cycles
E_ACCUM_TILE = 5.7  # (fit) CSA/differential adders per tile read
E_PERIPH_TILE = 40.5  # (fit) decoders, mux control, switches, buffers

XBAR = 128  # crossbar dimension (cells)

# ---- latency (200 MHz system clock, Table VI) ----
CLK_NS = 5.0
T_XBAR_READ_NS = 100.0  # analog settle + readout per mux cycle
MUX_CYCLES = 8
T_PERIPH_PER_TILE_NS = 30.5  # serial routing/decode/buffer per read (the 92%)
T_SSA_CYCLE_NS = CLK_NS  # SSA tile: d_K cycles per matrix (§IV-C)
SSA_PIPE_STALL = 1.2  # pipeline bubble factor between timesteps
AIMC_TILE_PARALLEL = 8192  # concurrently reading SAs across the chip

# ---- area (45 nm) ----
A_PCM_CELL_UM2 = 0.025  # ~6 F^2 differential pair (F = 45 nm) per cell
A_ADC_UM2 = 500.0  # compact 5-bit SAR
A_SAC_UM2 = 200.0  # one stochastic attention cell (gates+counter+FIFO)
A_LIF_UM2 = 1100.0
A_PERIPH_FACTOR = 3.25  # periphery+interconnect vs core (76.5% of total)

# ---- GPU reference points (Fig. 10(b), NVIDIA RTX A2000) ----
GPU_ANN_VIT_8_768_MS = 4.75  # measured ANN-ViT latency the paper compares to
GPU_SNN_SLOWDOWN = 3.14  # spiking transformer on GPU vs ANN on GPU
