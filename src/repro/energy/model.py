"""Analytical energy/latency/area model for the four §VII designs.

Given a transformer workload (depth, dim, tokens, T) this counts the ops
and memory traffic of:

  ANN-Quant        — SOTA digital INT8 accelerator (SwiftTron-like) [34]
  ANN-Quant+AIMC   — same, feed-forward/linear moved to PCM crossbars
  SNN-Digi-Opt     — ideal digital ASIC of a Spikformer-style SNN [15]
  Xpikeformer      — AIMC engine + SSA engine (this paper)

and converts them to energy with energy/constants.py.  The same op counts
drive the latency and area estimates (Fig. 10, Table VI).
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.energy import constants as C


@dataclasses.dataclass(frozen=True)
class Workload:
    depth: int
    dim: int
    tokens: int  # sequence length N
    heads: int = 0
    mlp_ratio: int = 4
    T_xpike: int = 7  # converged spike lengths (Table III: 8-768 ImageNet)
    T_snn: int = 4
    classes: int = 1000

    @property
    def d_head(self) -> int:
        h = self.heads or max(self.dim // 64, 1)
        return self.dim // h

    @property
    def n_heads(self) -> int:
        return self.heads or max(self.dim // 64, 1)


def _linear_macs(w: Workload) -> float:
    """MACs in all static-weight layers per inference (QKV/out/FF/head)."""
    d, n = w.dim, w.tokens
    per_layer = n * d * d * 4 + n * d * (w.mlp_ratio * d) * 2
    return w.depth * per_layer + n * d * w.classes


def _attn_macs(w: Workload) -> float:
    d, n = w.dim, w.tokens
    return w.depth * (n * n * d * 2)  # QK^T and SV


def _act_bytes(w: Workload, bytes_per_el: float) -> float:
    """Activation traffic per layer boundary (read + write), INT8 elements."""
    d, n = w.dim, w.tokens
    per_layer = n * d * 6 + n * n * w.n_heads  # qkv/ff ins/outs + attn matrix
    return w.depth * per_layer * bytes_per_el


def _aimc_tile_reads(w: Workload, timesteps: int) -> float:
    """Row-block tile reads: ceil(d/128)*ceil(out/128) tiles per matrix."""
    import math

    d, n = w.dim, w.tokens

    def tiles(i, o):
        return math.ceil(i / C.XBAR) * math.ceil(o / C.XBAR)

    per_layer = 4 * tiles(d, d) + tiles(d, w.mlp_ratio * d) + tiles(w.mlp_ratio * d, d)
    total_tiles = w.depth * per_layer + tiles(d, w.classes)
    return total_tiles * n * timesteps


def _aimc_energy(tile_reads: float) -> Dict[str, float]:
    xbar = tile_reads * C.E_XBAR_TILE_READ
    # 16 shared readouts x 8 mux cycles = one conversion per column per read
    adc = tile_reads * C.ADC_PER_TILE * C.E_ADC_CONV
    acc = tile_reads * C.E_ACCUM_TILE
    periph = tile_reads * C.E_PERIPH_TILE
    return {"crossbar": xbar, "adc": adc, "accum": acc, "periphery": periph}


def _nonlinear_energy(w: Workload) -> float:
    return w.depth * (
        w.tokens * w.tokens * w.n_heads * C.E_SOFTMAX_EL
        + 2 * w.tokens * w.dim * C.E_LAYERNORM_EL
        + w.tokens * w.mlp_ratio * w.dim * C.E_GELU_EL
    )


def energy_ann_quant(w: Workload) -> Dict[str, float]:
    compute = (
        _linear_macs(w) * C.E_MAC_FF
        + _attn_macs(w) * C.E_MAC_ATTN
        + _nonlinear_energy(w)
    )
    mem = _act_bytes(w, 1.0) * C.DIGITAL_RELOAD * (C.E_SRAM_RD + C.E_SRAM_WR) / 2
    return {"compute": compute, "memory": mem}


def energy_ann_aimc(w: Workload) -> Dict[str, float]:
    aimc = _aimc_energy(_aimc_tile_reads(w, timesteps=1))
    attn = _attn_macs(w) * C.E_MAC_ATTN
    # paper: "ANN-Quant and ANN-Quant+AIMC consume the same high amount of
    # memory access energy, as AIMC does not reduce intermediate data
    # storage overhead"
    mem = _act_bytes(w, 1.0) * C.DIGITAL_RELOAD * (C.E_SRAM_RD + C.E_SRAM_WR) / 2
    return {"compute": sum(aimc.values()) + attn + _nonlinear_energy(w),
            "memory": mem, "aimc_breakdown": aimc}


def energy_snn_digital(w: Workload) -> Dict[str, float]:
    """Ideal digital spiking transformer [15]: event-driven masked adds."""
    t = w.T_snn
    compute = t * C.SNN_SPIKE_RATE * (
        _linear_macs(w) * C.E_ADD_INT16 + _attn_macs(w) * C.E_ADD_INT16 * 2
    )
    lif = t * w.depth * (w.tokens * w.dim * 4) * C.E_LIF_STEP
    # memory: binary activations (1/8 byte) but T x non-binary preactivations
    d, n = w.dim, w.tokens
    binary = t * w.depth * n * d * 6 / 8.0
    preact = t * w.depth * (n * d * 6 + n * n * w.n_heads)  # INT8, stored + read
    mem = (binary * C.SNN_RELOAD + preact) * (C.E_SRAM_RD + C.E_SRAM_WR)
    return {"compute": compute + lif, "memory": mem}


def energy_xpikeformer(w: Workload) -> Dict[str, float]:
    t = w.T_xpike
    aimc = _aimc_energy(_aimc_tile_reads(w, timesteps=t))
    # SSA engine: AND+counter per (n,n',d) per t, comparators, LFSR, FIFO
    d_h, n, H = w.d_head, w.tokens, w.n_heads
    per_layer = H * (
        n * n * d_h * (C.E_AND + C.E_CNT8) * 2  # scores + output stages
        + n * n * C.E_CMP8 + n * d_h * C.E_CMP8
        + n * n * C.E_LFSR32 / 4
    )
    ssa = t * w.depth * per_layer
    lif = t * w.depth * (w.tokens * w.dim * 4) * C.E_LIF_STEP  # in-tile LIF units
    residual = t * w.depth * w.tokens * w.dim * 2 * C.E_ADD_INT8
    # memory: binary streams only; no attention intermediates, no preacts
    mem_bytes = t * w.depth * (w.tokens * w.dim * 6) / 8.0
    mem = mem_bytes * (C.E_SRAM_RD + C.E_SRAM_WR)
    return {
        "compute": sum(aimc.values()) + ssa + lif + residual,
        "memory": mem,
        "aimc_breakdown": aimc,
        "ssa": ssa,
        "other": lif + residual,
    }


def all_designs(w: Workload) -> Dict[str, Dict[str, float]]:
    return {
        "ANN-Quant": energy_ann_quant(w),
        "ANN-Quant+AIMC": energy_ann_aimc(w),
        "SNN-Digi-Opt": energy_snn_digital(w),
        "Xpikeformer": energy_xpikeformer(w),
    }


def total(e: Dict[str, float]) -> float:
    return e["compute"] + e["memory"]


# ---------------------------------------------------------------------------
# Event-level metering (spike counts x Table-II op energies)
# ---------------------------------------------------------------------------
#
# The functions above are *analytical* (assumed spike rates, whole-model op
# counts).  The meters below are driven by **measured** spike counts from a
# live forward/decode — the engine's ``forward(..., metering=True)`` and the
# serving scheduler's per-request accounting feed them (see
# ``repro.engine.MeteringBackend`` / ``repro.serving.scheduler``).


@dataclasses.dataclass
class EnergyReport:
    """Accumulated energy of one metered forward (picojoules per component).

    ``spikes_in`` / ``spikes_out`` are the measured spike-event counts into
    and out of the metered primitives — the quantities the event-driven
    terms scale with."""

    aimc_pj: float = 0.0
    ssa_pj: float = 0.0
    lif_pj: float = 0.0
    spikes_in: float = 0.0
    spikes_out: float = 0.0
    calls: int = 0

    @property
    def total_pj(self) -> float:
        return self.aimc_pj + self.ssa_pj + self.lif_pj

    @property
    def total_j(self) -> float:
        return self.total_pj * 1e-12

    def as_dict(self) -> Dict[str, float]:
        return {
            "aimc_pj": self.aimc_pj, "ssa_pj": self.ssa_pj,
            "lif_pj": self.lif_pj, "total_pj": self.total_pj,
            "total_j": self.total_j, "spikes_in": self.spikes_in,
            "spikes_out": self.spikes_out, "calls": float(self.calls),
        }


def meter_spiking_linear(t_steps: int, tokens: int, d_in: int, d_out: int,
                         in_spikes: float) -> Dict[str, float]:
    """Energy (pJ) of one spiking-linear call through the AIMC tiles.

    Tile reads happen once per (timestep, token) per 128x128 tile; the
    analog array term is event-driven (word lines pulse only on input
    spikes, so it scales with the measured input rate) while ADC /
    accumulation / periphery run every read.  LIF fires per output neuron
    per timestep."""
    import math

    tiles = math.ceil(d_in / C.XBAR) * math.ceil(d_out / C.XBAR)
    reads = tiles * t_steps * tokens
    in_rate = in_spikes / max(t_steps * tokens * d_in, 1)
    aimc = reads * (
        C.E_XBAR_TILE_READ * in_rate
        + C.ADC_PER_TILE * C.E_ADC_CONV + C.E_ACCUM_TILE + C.E_PERIPH_TILE
    )
    lif = t_steps * tokens * d_out * C.E_LIF_STEP
    return {"aimc": aimc, "lif": lif}


def meter_ssa(t_steps: int, groups: int, n: int, l: int, d: int,
              q_rate: float, k_rate: float, v_rate: float) -> Dict[str, float]:
    """Energy (pJ) of one SSA attention call (score + output stages).

    AND gates evaluate every (query, key, channel) triple; the ripple
    counters increment only on AND-true events, estimated from the measured
    operand rates (independent-operand approximation; the score-spike rate
    entering the output stage is taken as the comparator median 0.5).
    Comparators fire once per score / output element; the shared 32-bit
    LFSR amortises over 4 tapped bytes."""
    evals = t_steps * groups * n * l * d
    and_gates = 2 * evals * C.E_AND
    counters = evals * (q_rate * k_rate + 0.5 * v_rate) * C.E_CNT8
    comps = t_steps * groups * (n * l + n * d) * C.E_CMP8
    lfsr = t_steps * groups * (n * l + n * d) / 4.0 * C.E_LFSR32
    return {"ssa": and_gates + counters + comps + lfsr}


def decode_synapse_energy_pj() -> float:
    """Energy per residual-stream spike event in the serving decode path.

    The per-event cost a cached spike contributes downstream: one crossbar
    word-line pulse across the row's tiles plus the SSA AND/counter work it
    gates.  Used with *measured* per-slot spike counts from the jitted
    ``decode_step`` (which cannot host-meter per call) to apportion a
    request's event-driven energy."""
    return C.E_XBAR_TILE_READ + C.E_AND + C.E_CNT8


def lm_decode_token_energy_pj(d_model: int, n_heads: int, head_dim: int,
                              d_ff: int, depth: int, spike_T: int,
                              cache_len: int, vocab: int) -> float:
    """Static (activity-independent) energy per decoded token (pJ).

    The per-read ADC / accumulation / periphery and per-neuron LIF terms of
    the six spiking matrices of each block, plus the SSA comparator / LFSR
    banks over the cache — everything that runs whether or not a given
    synapse spikes.  The event-driven remainder is added from measured
    spike counts via :func:`decode_synapse_energy_pj`."""
    import math

    d_attn = n_heads * head_dim

    def tile_reads(d_in, d_out):
        return math.ceil(d_in / C.XBAR) * math.ceil(d_out / C.XBAR) * spike_T

    reads = depth * (
        3 * tile_reads(d_model, d_attn) + tile_reads(d_attn, d_model)
        + tile_reads(d_model, d_ff) + tile_reads(d_ff, d_model)
    )
    aimc = reads * (C.ADC_PER_TILE * C.E_ADC_CONV + C.E_ACCUM_TILE
                    + C.E_PERIPH_TILE)
    lif = depth * spike_T * (2 * d_attn + 2 * d_model + d_ff + d_model) * C.E_LIF_STEP
    ssa = depth * spike_T * n_heads * (
        (cache_len + head_dim) * C.E_CMP8
        + (cache_len + head_dim) / 4.0 * C.E_LFSR32
    )
    head = d_model * vocab * C.E_MAC_FF  # digital unembed
    return aimc + lif + ssa + head


# ---------------------------------------------------------------------------
# Latency (Fig. 10) and area (Table VI)
# ---------------------------------------------------------------------------


def latency_xpikeformer_ms(w: Workload) -> Dict[str, float]:
    import math

    t = w.T_xpike
    d = w.dim

    def tiles_rows(i):
        return math.ceil(i / C.XBAR)

    # AIMC: reads pipelined across tiles within a layer; serial over layers
    # and tokens; readout = 8 mux cycles per read.
    reads = w.depth * 6 * w.tokens * t  # 6 matrices/layer, row blocks parallel
    aimc_ns = reads * C.T_XBAR_READ_NS * C.MUX_CYCLES / C.AIMC_TILE_PARALLEL
    # SSA tile: ~d_K cycles per matrix per timestep, tokens/heads pipelined
    ssa_ns = w.depth * t * 2 * w.d_head * C.T_SSA_CYCLE_NS * C.SSA_PIPE_STALL
    # global data movement/routing/control is serial per read (Fig. 10: >92%)
    periph_ns = reads * C.T_PERIPH_PER_TILE_NS
    other_ns = 0.06 * (aimc_ns + ssa_ns + periph_ns)
    total_ns = aimc_ns + ssa_ns + periph_ns + other_ns
    return {
        "total_ms": total_ns / 1e6,
        "aimc_frac": aimc_ns / total_ns,
        "ssa_frac": ssa_ns / total_ns,
        "periphery_frac": periph_ns / total_ns,
        "other_frac": other_ns / total_ns,
    }


def area_xpikeformer_mm2(w: Workload, params: float) -> Dict[str, float]:
    cells = params / 1.0  # one differential pair per weight
    xbar_mm2 = cells * C.A_PCM_CELL_UM2 / 1e6
    n_tiles = cells / (C.XBAR * C.XBAR)
    adc_mm2 = n_tiles * 16 * C.A_ADC_UM2 / 1e6
    lif_mm2 = n_tiles * 16 * C.A_LIF_UM2 / 1e6
    ssa_mm2 = (w.tokens * w.tokens * C.A_SAC_UM2) * w.n_heads / 1e6
    core = xbar_mm2 + adc_mm2 + lif_mm2 + ssa_mm2
    periph = core * C.A_PERIPH_FACTOR
    return {
        "total_mm2": core + periph,
        "aimc_core_frac": (xbar_mm2 + adc_mm2 + lif_mm2) / (core + periph),
        "ssa_frac": ssa_mm2 / (core + periph),
        "periphery_frac": periph / (core + periph),
    }
