"""Modality frontend STUBS (per assignment spec).

``[audio]`` (musicgen) and ``[vlm]`` (pixtral) architectures specify the
transformer *backbone* only; the EnCodec tokenizer / Pixtral-ViT vision
tower are out of scope.  ``input_specs()`` therefore provides *precomputed*
frame/patch embeddings — these helpers generate matching synthetic features
for smoke tests and describe the abstract input signature for the dry-run.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def frontend_feature_dim(cfg: ModelConfig) -> int:
    return cfg.frontend_dim


def synth_frontend_batch(key, cfg: ModelConfig, batch: int, seq_len: int) -> Dict[str, jax.Array]:
    """Synthetic precomputed embeddings + targets for smoke tests/examples."""
    k1, k2 = jax.random.split(key)
    emb = jax.random.normal(k1, (batch, seq_len, cfg.frontend_dim), jnp.float32)
    tgt = jax.random.randint(k2, (batch, seq_len), 0, cfg.vocab_size, jnp.int32)
    return {"embeddings": emb, "targets": tgt}
