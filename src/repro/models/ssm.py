"""Mamba-2 (state-space duality, SSD) mixer — chunked matmul formulation.

The SSD recurrence per head (head dim P, state dim S):

    h_t = a_t * h_{t-1} + B_t (dt_t x_t)^T        h in R^{S x P}
    y_t = C_t^T h_t + D x_t

is evaluated in training/prefill with the chunked algorithm of Dao & Gu
(arXiv:2405.21060): the sequence is cut into chunks of Q tokens; within a
chunk the quadratic "attention" form runs on the MXU, across chunks a short
scan carries the [S, P] state.  This keeps everything matmul-shaped — the
TPU adaptation of the paper's selective-scan kernel.

Sharding notes: projections are kept *separate* (w_z / w_x / w_bc / w_dt)
rather than fused, so the inner dimension of each can be tensor-sharded over
the ``model`` axis without splits crossing shard boundaries.  SSD heads are
sharded over ``model`` (48 heads / 16 = 3 for mamba2-780m); B/C groups are
small and replicated.

Decode is the plain O(1)-per-token recurrence on a carried state.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import ParamDef

Array = jax.Array

CHUNK = 256


def ssd_dims(cfg) -> Tuple[int, int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    heads = d_inner // cfg.ssm_head_dim
    return d_inner, heads, cfg.ssm_head_dim, cfg.ssm_state_dim


def ssd_schema(cfg) -> Dict[str, ParamDef]:
    d = cfg.d_model
    d_in, h, p, s = ssd_dims(cfg)
    g = cfg.ssm_n_groups
    k = cfg.ssm_conv_width
    return {
        "w_z": ParamDef((d, d_in), ("embed", "ssm_inner")),
        "w_x": ParamDef((d, d_in), ("embed", "ssm_inner")),
        "w_bc": ParamDef((d, 2 * g * s), ("embed", None)),
        "w_dt": ParamDef((d, h), ("embed", "ssm_heads")),
        "conv_x_w": ParamDef((k, d_in), (None, "ssm_inner")),
        "conv_x_b": ParamDef((d_in,), ("ssm_inner",), init="zeros"),
        "conv_bc_w": ParamDef((k, 2 * g * s), (None, None)),
        "conv_bc_b": ParamDef((2 * g * s,), (None,), init="zeros"),
        "a_log": ParamDef((h,), ("ssm_heads",), init="ones"),
        "dt_bias": ParamDef((h,), ("ssm_heads",), init="zeros"),
        "d_skip": ParamDef((h,), ("ssm_heads",), init="ones"),
        "norm_scale": ParamDef((d_in,), ("ssm_inner",), init="ones"),
        "w_out": ParamDef((d_in, d), ("ssm_inner", "embed")),
    }


def _depthwise_causal_conv(x: Array, w: Array, b: Array) -> Array:
    """x [B,L,C], w [K,C] depthwise causal conv + silu."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):  # K=4: unrolled taps, stays fused
        out = out + xp[:, i : i + x.shape[1], :] * w[i].astype(x.dtype)
    return jax.nn.silu(out + b.astype(x.dtype))


def _ssd_chunked(xh, a, bmat, cmat, *, chunk: int = CHUNK):
    """Chunked SSD core.

    xh   [B,L,H,P]   dt-scaled inputs
    a    [B,L,H]     per-step decay in (0,1] (float32)
    bmat [B,L,G,S], cmat [B,L,G,S]
    Returns y [B,L,H,P] and final state [B,H,S,P].
    """
    b, L, h, p = xh.shape
    g, s = bmat.shape[2], bmat.shape[3]
    q = min(chunk, L)
    nc = L // q
    hg = h // g

    def chunk(t):
        return t.reshape(b, nc, q, *t.shape[2:])

    xh_c, a_c = chunk(xh), chunk(a)
    b_c, c_c = chunk(bmat), chunk(cmat)

    la = jnp.log(jnp.maximum(a_c, 1e-20))  # [B,NC,Q,H] f32
    cum = jnp.cumsum(la, axis=2)

    # ---- intra-chunk (quadratic, MXU-friendly) ----
    dec = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,NC,Q,Q,H]
    mask = jnp.tril(jnp.ones((q, q), bool))
    # mask BEFORE exp: future entries have dec > 0, and exp(dec)=inf would
    # poison the backward pass through jnp.where (0 * inf = NaN)
    dec = jnp.where(mask[None, None, :, :, None], dec, -1e30)
    gamma = jnp.exp(dec).astype(xh.dtype)
    cb = jnp.einsum("bnigs,bnjgs->bnijg", c_c, b_c)  # [B,NC,Q,Q,G]
    # expand groups to heads inside the einsum via a [G, H/G] head reshape
    gam_h = gamma.reshape(b, nc, q, q, g, hg)
    y_intra = jnp.einsum("bnijg,bnijgh,bnjghp->bnighp", cb, gam_h, xh_c.reshape(b, nc, q, g, hg, p))
    y_intra = y_intra.reshape(b, nc, q, h, p)

    # ---- chunk states ----
    rem = jnp.exp(cum[:, :, -1:, :] - cum).astype(xh.dtype)  # [B,NC,Q,H]
    states = jnp.einsum(
        "bnqgs,bnqgh,bnqghp->bnghsp",
        b_c,
        rem.reshape(b, nc, q, g, hg),
        xh_c.reshape(b, nc, q, g, hg, p),
    ).reshape(b, nc, h, s, p)

    # ---- inter-chunk scan ----
    a_chunk = jnp.exp(cum[:, :, -1, :]).astype(xh.dtype)  # [B,NC,H]

    def step(carry, inp):
        st, ac = inp
        new = carry * ac[..., None, None] + st
        return new, carry  # emit state *entering* the chunk

    init = jnp.zeros((b, h, s, p), xh.dtype)
    final, prev = lax.scan(
        step, init, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(a_chunk, 1, 0))
    )
    prev = jnp.moveaxis(prev, 0, 1)  # [B,NC,H,S,P]

    into = jnp.exp(cum).astype(xh.dtype)  # decay chunk-start -> step (incl.)
    y_inter = jnp.einsum(
        "bnqgs,bnqgh,bnghsp->bnqghp",
        c_c,
        into.reshape(b, nc, q, g, hg),
        prev.reshape(b, nc, g, hg, s, p),
    ).reshape(b, nc, q, h, p)

    y = (y_intra + y_inter).reshape(b, L, h, p)
    return y, final


def _gated_out(params, y: Array, z: Array, x_dtype):
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)).astype(x_dtype)
    y = y * params["norm_scale"].astype(x_dtype)
    return y @ params["w_out"].astype(x_dtype)


def _constrain_inner(t: Array, pctx) -> Array:
    """Shard the SSD inner/head dim over the model axis (batch over DP)."""
    if pctx is None or pctx.mesh is None or pctx.tp_axis is None:
        return t
    if t.shape[-1] % pctx.tp_size:
        return t
    from jax.sharding import NamedSharding, PartitionSpec as P

    return jax.lax.with_sharding_constraint(
        t, NamedSharding(pctx.mesh, P(pctx.dp_axes or None, None, pctx.tp_axis))
    )


def ssd_mixer(params, x: Array, cfg, *, return_state: bool = False, pctx=None):
    """Full Mamba-2 block for train/prefill. x [B,L,D] -> [B,L,D]."""
    d_in, h, p, s = ssd_dims(cfg)
    g = cfg.ssm_n_groups
    b, L, _ = x.shape

    z = _constrain_inner(x @ params["w_z"].astype(x.dtype), pctx)
    xc_pre = _constrain_inner(x @ params["w_x"].astype(x.dtype), pctx)
    bc_pre = x @ params["w_bc"].astype(x.dtype)
    dt = _constrain_inner(x @ params["w_dt"].astype(x.dtype), pctx)

    xc = _depthwise_causal_conv(xc_pre, params["conv_x_w"], params["conv_x_b"])
    bc = _depthwise_causal_conv(bc_pre, params["conv_bc_w"], params["conv_bc_b"])
    bmat, cmat = jnp.split(bc, 2, axis=-1)

    dtf = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    a = jnp.exp(-jnp.exp(params["a_log"].astype(jnp.float32)) * dtf)  # [B,L,H]

    xh = (xc.reshape(b, L, h, p) * dtf[..., None].astype(x.dtype)).astype(x.dtype)
    y, state = _ssd_chunked(xh, a, bmat.reshape(b, L, g, s), cmat.reshape(b, L, g, s),
                            chunk=cfg.ssm_chunk)
    y = y + params["d_skip"].astype(x.dtype)[None, None, :, None] * xc.reshape(b, L, h, p)
    out = _gated_out(params, y.reshape(b, L, d_in), z, x.dtype)
    if return_state:
        k = cfg.ssm_conv_width
        cx = jnp.pad(xc_pre, ((0, 0), (max(k - 1 - L, 0), 0), (0, 0)))[:, -(k - 1) :, :]
        cbc = jnp.pad(bc_pre, ((0, 0), (max(k - 1 - L, 0), 0), (0, 0)))[:, -(k - 1) :, :]
        return out, {
            "ssd": state,
            "conv_x": cx,
            "conv_bc": cbc,
            "pos": jnp.full((b,), L, jnp.int32),
        }
    return out


def ssd_cache_schema(cfg, batch: int):
    d_in, h, p, s = ssd_dims(cfg)
    g = cfg.ssm_n_groups
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    k = cfg.ssm_conv_width
    return {
        "ssd": jax.ShapeDtypeStruct((batch, h, s, p), dt),
        "conv_x": jax.ShapeDtypeStruct((batch, k - 1, d_in), dt),
        "conv_bc": jax.ShapeDtypeStruct((batch, k - 1, 2 * g * s), dt),
        "pos": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }


def ssd_decode(params, x: Array, cache: Dict[str, Array], cfg):
    """One-token decode. x [B,1,D]."""
    d_in, h, p, s = ssd_dims(cfg)
    g = cfg.ssm_n_groups
    b = x.shape[0]
    hg = h // g

    z = x @ params["w_z"].astype(x.dtype)
    xc_pre = x @ params["w_x"].astype(x.dtype)
    bc_pre = x @ params["w_bc"].astype(x.dtype)
    dt = x @ params["w_dt"].astype(x.dtype)

    hist_x = jnp.concatenate([cache["conv_x"].astype(x.dtype), xc_pre], axis=1)
    hist_bc = jnp.concatenate([cache["conv_bc"].astype(x.dtype), bc_pre], axis=1)
    xc = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", hist_x, params["conv_x_w"].astype(x.dtype))
        + params["conv_x_b"].astype(x.dtype)
    )
    bc = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", hist_bc, params["conv_bc_w"].astype(x.dtype))
        + params["conv_bc_b"].astype(x.dtype)
    )
    bmat, cmat = jnp.split(bc, 2, axis=-1)

    dtf = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))[:, 0]
    a = jnp.exp(-jnp.exp(params["a_log"].astype(jnp.float32)) * dtf)  # [B,H]

    xh = (xc.reshape(b, h, p) * dtf[..., None].astype(x.dtype)).astype(x.dtype)
    bh = jnp.repeat(bmat.reshape(b, g, s), hg, axis=1)
    ch = jnp.repeat(cmat.reshape(b, g, s), hg, axis=1)
    st = cache["ssd"].astype(x.dtype) * a[..., None, None].astype(x.dtype) + jnp.einsum(
        "bhs,bhp->bhsp", bh, xh
    )
    y = jnp.einsum("bhs,bhsp->bhp", ch, st)
    y = y + params["d_skip"].astype(x.dtype)[None, :, None] * xc.reshape(b, h, p)
    out = _gated_out(params, y.reshape(b, 1, d_in), z, x.dtype)
    new_cache = {
        "ssd": st.astype(cache["ssd"].dtype),
        "conv_x": hist_x[:, 1:, :].astype(cache["conv_x"].dtype),
        "conv_bc": hist_bc[:, 1:, :].astype(cache["conv_bc"].dtype),
        "pos": jnp.broadcast_to(cache["pos"], (b,)) + 1,
    }
    return out, new_cache
