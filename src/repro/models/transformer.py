"""Generic LM stack covering all assigned architecture families.

One parameterised decoder-only stack supports:

* dense transformers (qwen2.5, yi, granite, musicgen, pixtral backbones)
* local:global interleaved attention (gemma3)
* MoE (arctic, phi3.5) with EP all-to-all expert parallelism
* attention-free SSM (mamba2, SSD) and hybrid RG-LRU + local attn
  (recurrentgemma)
* the paper's spiking mode (``cfg.spiking``): LIF feed-forward + SSA
  stochastic spiking attention over spike trains of length ``cfg.spike_T``.

Layers are grouped into *periods* (the block-pattern cycle) and scanned with
``lax.scan`` so the HLO is O(1) in depth; the remainder (depth % period) is
unrolled.  Every forward path (train loss, prefill, single-token decode) is
pure-functional and jit/pjit-lowerable with abstract params.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import layers as L
from repro.models import moe as M
from repro.models import rglru as R
from repro.models import ssm as S
from repro.models.layers import ParamDef
from repro.models.moe import ParallelCtx
from repro.core import spikes as SP
from repro.core import ssa as SSA
from repro.core.spiking_transformer import _default_backend
from repro.kernels.plan import AttnSpec, DecodePlan, KVView

Array = jax.Array


def model_dtype(cfg: ModelConfig):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.dtype]


# ---------------------------------------------------------------------------
# Schemas
# ---------------------------------------------------------------------------


def block_schema(cfg: ModelConfig, mixer: str) -> Dict[str, Any]:
    s: Dict[str, Any] = {"norm1": L.norm_schema(cfg.d_model)}
    if mixer in ("attn", "local"):
        s["mixer"] = L.attention_schema(cfg)
    elif mixer == "ssd":
        s["mixer"] = S.ssd_schema(cfg)
    elif mixer == "rglru":
        s["mixer"] = R.rglru_schema(cfg)
    else:
        raise ValueError(mixer)
    if cfg.d_ff > 0:
        s["norm2"] = L.norm_schema(cfg.d_model)
        if cfg.is_moe:
            s["moe"] = M.moe_schema(cfg)
            if cfg.moe_dense_ff > 0:
                s["mlp"] = L.mlp_schema(cfg, cfg.moe_dense_ff)
        else:
            s["mlp"] = L.mlp_schema(cfg)
    return s


def _stack_defs(schema: Any, n: int) -> Any:
    def f(d: ParamDef) -> ParamDef:
        return dataclasses.replace(
            d,
            shape=(n,) + d.shape,
            axes=("layers",) + d.axes,
            fan_in=d.shape[0] if len(d.shape) > 1 else None,
        )

    return jax.tree.map(f, schema, is_leaf=lambda x: isinstance(x, ParamDef))


def model_schema(cfg: ModelConfig) -> Dict[str, Any]:
    period = {f"blk{i}": block_schema(cfg, m) for i, m in enumerate(cfg.block_pattern)}
    s: Dict[str, Any] = {
        "embed": L.embed_schema(cfg),
        "final_norm": L.norm_schema(cfg.d_model),
    }
    if cfg.num_periods > 0:
        s["periods"] = _stack_defs(period, cfg.num_periods)
    if cfg.remainder_layers:
        s["remainder"] = {
            f"blk{i}": block_schema(cfg, cfg.block_pattern[i])
            for i in range(cfg.remainder_layers)
        }
    if not cfg.tie_embeddings:
        s["unembed"] = L.unembed_schema(cfg)
    if cfg.frontend != "none":
        s["frontend"] = {
            "proj": ParamDef((cfg.frontend_dim, cfg.d_model), (None, "embed"))
        }
    return s


def init_params(key: Array, cfg: ModelConfig):
    return L.init_tree(key, model_schema(cfg), model_dtype(cfg))


def abstract_params(cfg: ModelConfig):
    return L.abstract_tree(model_schema(cfg), model_dtype(cfg))


# ---------------------------------------------------------------------------
# Activation sharding hints
# ---------------------------------------------------------------------------


def shard_x(x: Array, pctx: ParallelCtx, *, seq_sharded: bool) -> Array:
    if pctx.mesh is None:
        return x
    from jax.sharding import NamedSharding

    return lax.with_sharding_constraint(
        x, NamedSharding(pctx.mesh, pctx.x_spec(seq_sharded))
    )


# ---------------------------------------------------------------------------
# Conventional (ANN) block
# ---------------------------------------------------------------------------


def _apply_block(
    params, x: Array, positions: Array, cfg: ModelConfig, pctx: ParallelCtx, mixer: str,
    *, moe_impl: str, seq_sharded: bool,
) -> Tuple[Array, Array]:
    """Residual block: norm -> mixer -> +res ; norm -> ffn -> +res."""
    aux = jnp.zeros((), jnp.float32)
    h = L.apply_norm(cfg.norm_type, params["norm1"], x)
    if mixer == "attn":
        h = L.attention(params["mixer"], h, positions, cfg)
    elif mixer == "local":
        h = L.attention(params["mixer"], h, positions, cfg, window=cfg.window_size)
    elif mixer == "ssd":
        h = S.ssd_mixer(params["mixer"], h, cfg, pctx=pctx)
    elif mixer == "rglru":
        h = R.rglru_mixer(params["mixer"], h, cfg, pctx=pctx)
    x = shard_x(x + h, pctx, seq_sharded=seq_sharded)
    if "norm2" in params:
        h = L.apply_norm(cfg.norm_type, params["norm2"], x)
        y = jnp.zeros_like(x)
        if "moe" in params:
            ym, aux = M.moe_apply(
                params["moe"], h, cfg, pctx, impl=moe_impl, seq_sharded=seq_sharded
            )
            y = y + ym
        if "mlp" in params:
            y = y + L.mlp(params["mlp"], h, cfg)
        x = shard_x(x + y, pctx, seq_sharded=seq_sharded)
    return x, aux


def _apply_block_decode(
    params, x: Array, cache, cfg: ModelConfig, pctx: ParallelCtx, mixer: str, *, moe_impl: str
):
    h = L.apply_norm(cfg.norm_type, params["norm1"], x)
    if mixer == "attn":
        h, cache = L.attention_decode(params["mixer"], h, cache, cfg)
    elif mixer == "local":
        h, cache = L.attention_decode(params["mixer"], h, cache, cfg, window=cfg.window_size)
    elif mixer == "ssd":
        h, cache = S.ssd_decode(params["mixer"], h, cache, cfg)
    elif mixer == "rglru":
        h, cache = R.rglru_decode(params["mixer"], h, cache, cfg)
    x = x + h
    if "norm2" in params:
        h = L.apply_norm(cfg.norm_type, params["norm2"], x)
        y = jnp.zeros_like(x)
        if "moe" in params:
            ym, _ = M.moe_apply(
                params["moe"], h, cfg, pctx, impl=moe_impl, seq_sharded=False
            )
            y = y + ym
        if "mlp" in params:
            y = y + L.mlp(params["mlp"], h, cfg)
        x = x + y
    return x, cache


# ---------------------------------------------------------------------------
# Spiking block (the paper's technique as a first-class mode)
# ---------------------------------------------------------------------------


def _lin_operand(w, d_in: int, dtype=None):
    """A spiking-linear weight operand for ``backend.spiking_linear``.

    Programmed PCM state (:class:`repro.aimc_device.AIMCDeviceState`, from
    ``engine.program`` / ``aimc_device.program_lm_tree``) passes through
    as-is — it is already the ``[d_in, d_out]`` crossbar view; float arrays
    keep the legacy reshape-to-matrix behaviour."""
    from repro.aimc_device import AIMCDeviceState

    if isinstance(w, AIMCDeviceState):
        return w
    return w.astype(dtype or jnp.float32).reshape(d_in, -1)


def _spiking_attention(params, s: Array, cfg: ModelConfig, key: Array, backend) -> Array:
    """SSA attention over spike trains s [T,B,S,d] (paper Eq. 6).

    All spiking primitives (Q/K/V/O spiking linears and the SSA core) come
    from ``backend`` — the same dispatch as the paper models in
    ``core/spiking_transformer.py``, so the generic LM stack runs on any
    substrate (reference / integer / pallas)."""
    T, b, n, d = s.shape
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    kv = cfg.num_kv_heads
    ks = jax.random.split(key, 5)

    def proj(w, kk):  # LIF(W s^t): spiking Q/K/V generation (Table I)
        out = backend.spiking_linear(kk, _lin_operand(w, d, s.dtype), s,
                                     part="col")
        return out.reshape(T, b, n, -1, hd)

    q = proj(params["wq"], ks[0])  # [T,B,S,H,hd]
    k = proj(params["wk"], ks[1])
    v = proj(params["wv"], ks[2])
    if kv != h:  # GQA: repeat kv spike heads across the group
        rep = h // kv
        k = jnp.repeat(k, rep, axis=3)
        v = jnp.repeat(v, rep, axis=3)
    qh = jnp.moveaxis(q, 3, 2).reshape(T, b, h, n, hd)
    kh = jnp.moveaxis(k, 3, 2).reshape(T, b, h, n, hd)
    vh = jnp.moveaxis(v, 3, 2).reshape(T, b, h, n, hd)
    if cfg.attention_kind == "lif":
        a = SSA.lif_spiking_attention(
            qh.astype(s.dtype), kh.astype(s.dtype), vh.astype(s.dtype), causal=True
        )
    else:
        a = backend.ssa_attention(ks[3], qh, kh, vh, causal=True)
    a = jnp.moveaxis(a.reshape(T, b, h, n, hd), 2, 3).reshape(T, b, n, h * hd)
    # LIF on the output projection (spiking neuron tile semantics)
    return backend.spiking_linear(
        ks[4], _lin_operand(params["wo"], h * hd, s.dtype), a, part="row")


def _spiking_mlp(params, s: Array, cfg: ModelConfig, key: Array, backend) -> Array:
    """LIF(W2 LIF(W1 s^t)) — Table I feed-forward row."""
    k1, k2 = jax.random.split(key)
    h = backend.spiking_linear(k1, _lin_operand(params["wi"], s.shape[-1], s.dtype), s,
                               part="col")
    return backend.spiking_linear(k2, _lin_operand(params["wo"], h.shape[-1], s.dtype), h,
                                  part="row")


def _apply_block_spiking(
    params, s: Array, cfg: ModelConfig, pctx: ParallelCtx, mixer: str, key: Array,
    backend=None,
) -> Tuple[Array, Array]:
    """Spiking residual block over spike trains s [T,B,N,d].

    Residuals add spike trains directly (integer-valued streams, as in
    Spikformer/Xpikeformer — Table I: no inter-layer normalisation).
    Attention-free mixers (ssd/rglru) run on the *rate* interface — the
    paper's technique does not apply to them (DESIGN.md §Arch-applicability).
    """
    backend = backend or _default_backend()
    aux = jnp.zeros((), jnp.float32)
    k1, k2 = jax.random.split(key)
    if mixer in ("attn", "local"):
        h = _spiking_attention(params["mixer"], s, cfg, k1, backend)
    else:
        rate = SP.rate_decode(s)  # [B,N,d]
        if mixer == "ssd":
            y = S.ssd_mixer(params["mixer"], rate, cfg)
        else:
            y = R.rglru_mixer(params["mixer"], rate, cfg)
        h = SP.rate_encode(k1, jax.nn.sigmoid(y), s.shape[0])
    s = s + h
    if "norm2" in params:
        if "moe" in params:
            rate = SP.rate_decode(s)
            ym, aux = M.moe_apply(params["moe"], rate, cfg, pctx, impl="dense")
            y = SP.rate_encode(k2, jax.nn.sigmoid(ym), s.shape[0])
        else:
            y = _spiking_mlp(params["mlp"], s, cfg, k2, backend)
        s = s + y
    return s, aux


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _embed_inputs(params, batch: Dict[str, Array], cfg: ModelConfig) -> Array:
    dt = model_dtype(cfg)
    if cfg.frontend != "none":
        x = batch["embeddings"].astype(dt) @ params["frontend"]["proj"].astype(dt)
    else:
        x = L.embed(params["embed"], batch["tokens"], dt)
    return x * jnp.asarray(jnp.sqrt(cfg.d_model), dt)


def _unembed(params, x: Array, cfg: ModelConfig) -> Array:
    x = L.apply_norm(cfg.norm_type, params["final_norm"], x)
    if cfg.tie_embeddings:
        return x @ params["embed"]["table"].astype(x.dtype).T
    return L.unembed(params["unembed"], x, cfg)


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "block":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)  # "full": save nothing


def forward(
    params,
    batch: Dict[str, Array],
    cfg: ModelConfig,
    pctx: ParallelCtx = ParallelCtx(),
    *,
    moe_impl: str = "ep_a2a",
    remat: str = "block",
    rng: Optional[Array] = None,
    backend=None,
) -> Tuple[Array, Array]:
    """Train/prefill forward -> (logits [B,S,V], moe aux loss)."""
    if cfg.spiking:
        return _forward_spiking(params, batch, cfg, pctx, rng=rng, backend=backend)
    x = _embed_inputs(params, batch, cfg)
    b, sl, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(sl, dtype=jnp.int32), (b, sl))
    seq_ok = pctx.seq_shard and (pctx.tp_size > 1) and (sl % max(pctx.tp_size, 1) == 0)
    x = shard_x(x, pctx, seq_sharded=seq_ok)
    aux = jnp.zeros((), jnp.float32)

    def period_body(carry, period_params):
        x, aux = carry
        for i, mixer in enumerate(cfg.block_pattern):
            x, a = _apply_block(
                period_params[f"blk{i}"], x, positions, cfg, pctx, mixer,
                moe_impl=moe_impl, seq_sharded=seq_ok,
            )
            aux = aux + a
        return (x, aux), None

    if cfg.num_periods > 0:
        if L.EXACT_FLOPS_MODE:
            # unrolled: every period's ops appear in the HLO (exact costs)
            for pi in range(cfg.num_periods):
                pp = jax.tree.map(lambda t: t[pi], params["periods"])
                (x, aux), _ = period_body((x, aux), pp)
        else:
            body = _remat(period_body, remat)
            (x, aux), _ = lax.scan(body, (x, aux), params["periods"])
    if cfg.remainder_layers:
        for i in range(cfg.remainder_layers):
            x, a = _apply_block(
                params["remainder"][f"blk{i}"], x, positions, cfg, pctx,
                cfg.block_pattern[i], moe_impl=moe_impl, seq_sharded=seq_ok,
            )
            aux = aux + a
    logits = _unembed(params, x, cfg)
    return logits, aux


def _forward_spiking(params, batch, cfg: ModelConfig, pctx: ParallelCtx, *, rng,
                     backend=None):
    """Spiking forward: rate-encode, spiking blocks over T, rate-decode logits."""
    assert rng is not None, "spiking forward needs an rng for Bernoulli coding"
    backend = backend or _default_backend()
    x = _embed_inputs(params, batch, cfg)
    k_enc, k_blocks = jax.random.split(rng)
    s = SP.rate_encode(k_enc, jax.nn.sigmoid(x), cfg.spike_T)  # [T,B,S,d]
    aux = jnp.zeros((), jnp.float32)

    n_blocks = cfg.num_periods + (1 if cfg.remainder_layers else 0)
    keys = jax.random.split(k_blocks, max(n_blocks, 1))

    def period_body(carry, xs):
        s, aux = carry
        period_params, key = xs
        kk = jax.random.split(key, cfg.period)
        for i, mixer in enumerate(cfg.block_pattern):
            s, a = _apply_block_spiking(
                period_params[f"blk{i}"], s, cfg, pctx, mixer, kk[i], backend
            )
            aux = aux + a
        return (s, aux), None

    if cfg.num_periods > 0:
        (s, aux), _ = lax.scan(period_body, (s, aux), (params["periods"], keys[: cfg.num_periods]))
    if cfg.remainder_layers:
        kk = jax.random.split(keys[-1], cfg.remainder_layers)
        for i in range(cfg.remainder_layers):
            s, a = _apply_block_spiking(
                params["remainder"][f"blk{i}"], s, cfg, pctx, cfg.block_pattern[i],
                kk[i], backend,
            )
            aux = aux + a
    # rate-decode the stream, then unembed (paper: loss on time-averaged output)
    x = SP.rate_decode(s.astype(jnp.float32)).astype(model_dtype(cfg))
    logits = _unembed(params, x, cfg)
    return logits, aux


# ---------------------------------------------------------------------------
# Loss / train objective
# ---------------------------------------------------------------------------


def softmax_xent(logits: Array, targets: Array, mask: Optional[Array] = None) -> Array:
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def loss_fn(
    params, batch, cfg: ModelConfig, pctx: ParallelCtx = ParallelCtx(),
    *, moe_impl: str = "ep_a2a", remat: str = "block", rng: Optional[Array] = None,
    aux_weight: float = 0.01, backend=None,
) -> Tuple[Array, Dict[str, Array]]:
    if cfg.frontend != "none":
        inputs = {"embeddings": batch["embeddings"]}
        targets = batch["targets"]
        mask = batch.get("mask")
    else:
        inputs = {"tokens": batch["tokens"][:, :-1]}
        targets = batch["tokens"][:, 1:]
        mask = batch.get("mask")
    logits, aux = forward(params, inputs, cfg, pctx, moe_impl=moe_impl, remat=remat,
                          rng=rng, backend=backend)
    xent = softmax_xent(logits, targets, mask)
    loss = xent + aux_weight * aux
    return loss, {"xent": xent, "moe_aux": aux}


# ---------------------------------------------------------------------------
# Decode (serve) path
# ---------------------------------------------------------------------------


def spiking_attention_cache_schema(cfg: ModelConfig, batch: int, seq_len: int):
    """Per-slot spiking KV cache: binary K/V spike trains per position.

    Unlike the ANN cache (one vector per position) the SSA engine caches the
    whole ``spike_T``-step spike train of every key/value token — the
    serving analogue of the hardware streaming 1-bit K/V planes through the
    attention tile.  uint8 storage, so the cache is *smaller* than the ANN
    float cache whenever ``spike_T < 4 * bytes_per_float``.  Positions
    beyond ``pos`` are all-zero, which masks them out of the SSA comparators
    for free (zero AND-counts never spike)."""
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "sk": jax.ShapeDtypeStruct((batch, cfg.spike_T, seq_len, kv, hd), jnp.uint8),
        "sv": jax.ShapeDtypeStruct((batch, cfg.spike_T, seq_len, kv, hd), jnp.uint8),
        "pos": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }


def _spiking_decode_enabled(cfg: ModelConfig) -> bool:
    """Spiking serve path: SSA attention decodes over spike-train KV caches.

    Other spiking attention kinds (``lif``) keep the rate (ANN-equivalent)
    decode path — their attention is membrane-stateful across timesteps and
    has no streaming tile in the paper."""
    return cfg.spiking and cfg.attention_kind == "ssa"


def _block_cache_schema(cfg: ModelConfig, mixer: str, batch: int, seq_len: int):
    if mixer in ("attn", "local") and _spiking_decode_enabled(cfg):
        return spiking_attention_cache_schema(cfg, batch, seq_len)
    if mixer == "attn":
        return L.attention_cache_schema(cfg, batch, seq_len)
    if mixer == "local":
        return L.attention_cache_schema(cfg, batch, seq_len, window=cfg.window_size)
    if mixer == "ssd":
        return S.ssd_cache_schema(cfg, batch)
    if mixer == "rglru":
        return R.rglru_cache_schema(cfg, batch)
    raise ValueError(mixer)


def cache_schema(cfg: ModelConfig, batch: int, seq_len: int):
    """Abstract (ShapeDtypeStruct) cache pytree for a full model."""

    def stack(tree, n):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), tree
        )

    out: Dict[str, Any] = {}
    if cfg.num_periods > 0:
        period = {
            f"blk{i}": _block_cache_schema(cfg, m, batch, seq_len)
            for i, m in enumerate(cfg.block_pattern)
        }
        out["periods"] = stack(period, cfg.num_periods)
    if cfg.remainder_layers:
        out["remainder"] = {
            f"blk{i}": _block_cache_schema(cfg, cfg.block_pattern[i], batch, seq_len)
            for i in range(cfg.remainder_layers)
        }
    return out


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, *, filled: int = 0):
    """Materialise a zero cache; ``filled`` marks tokens as already present."""

    def zero(s):
        if s.dtype == jnp.int32:  # per-slot "pos" counters
            return jnp.full(s.shape, filled, jnp.int32)
        return jnp.zeros(s.shape, s.dtype)

    return jax.tree.map(zero, cache_schema(cfg, batch, seq_len))


# ---------------------------------------------------------------------------
# Spiking decode (SSA serving path)
# ---------------------------------------------------------------------------


def _first_pos(cache) -> Array:
    """The per-slot position counters ([B] int32) from the first block."""
    if "periods" in cache:
        return cache["periods"]["blk0"]["pos"][0]
    return cache["remainder"]["blk0"]["pos"]


def _slot_base_keys(seeds: Array, pos: Array) -> Array:
    """Per-slot PRNG keys for one decode step: f(request seed, position).

    ``jnp.stack([0, seed])`` is exactly ``jax.random.PRNGKey(seed)`` for
    32-bit seeds, so a request's spike randomness depends only on its own
    (seed, position) — never on batch composition.  This is what makes
    continuous-batching admission bit-exact for already-running slots."""
    base = jnp.stack([jnp.zeros_like(seeds), seeds], axis=-1).astype(jnp.uint32)
    return jax.vmap(jax.random.fold_in)(base, pos)


def _slot_rate_encode(keys: Array, x: Array, t: int) -> Array:
    """Per-slot Bernoulli rate coding: x [B,1,d] -> spikes [T,B,1,d]."""
    return jax.vmap(
        lambda kk, xb: SP.rate_encode(kk, jax.nn.sigmoid(xb.astype(jnp.float32)), t),
        in_axes=(0, 0), out_axes=1,
    )(keys, x)


def _spiking_attention_decode(params, s: Array, cache, cfg: ModelConfig,
                              slot_keys: Array, backend):
    """One-token SSA decode against the slot's cached K/V spike trains.

    s [T,B,1,d] is the new token's spike train.  The Q/K/V/O projections are
    the backend's spiking linears (same primitives as prefill/forward); the
    new K/V trains are scattered into the per-slot cache at ``pos`` and the
    query attends to the whole cache — zero (unwritten / freed) positions
    mask themselves out of the comparators."""
    t, b, _, d = s.shape
    h, hd, kv = cfg.num_heads, cfg.resolved_head_dim, cfg.num_kv_heads

    def proj(w):  # LIF(W s^t) -> [T,B,heads,hd]
        out = backend.spiking_linear(None, _lin_operand(w, d), s, part="col")
        return out.reshape(t, b, -1, hd)

    q = proj(params["wq"])  # [T,B,H,hd]
    k_new = proj(params["wk"])  # [T,B,KV,hd]
    v_new = proj(params["wv"])
    pos = jnp.broadcast_to(cache["pos"], (b,))
    barange = jnp.arange(b)
    sk = cache["sk"].at[barange, :, pos].set(
        jnp.moveaxis(k_new, 0, 1).astype(jnp.uint8))
    sv = cache["sv"].at[barange, :, pos].set(
        jnp.moveaxis(v_new, 0, 1).astype(jnp.uint8))
    lcap = sk.shape[2]
    # [B,T,L,KV,hd] -> [T,B,KV,L,hd] -> GQA repeat -> [T,B,H,L,hd]
    kf = jnp.transpose(sk, (1, 0, 3, 2, 4))
    vf = jnp.transpose(sv, (1, 0, 3, 2, 4))
    if kv != h:
        rep = h // kv
        kf = jnp.repeat(kf, rep, axis=2)
        vf = jnp.repeat(vf, rep, axis=2)
    a = backend.decode_attention(
        KVView.dense(kf, vf), q[:, :, :, None, :],
        AttnSpec(i_max=lcap, groups=h // kv), slot_keys=slot_keys)
    a = a.reshape(t, b, 1, h * hd).astype(s.dtype)
    out = backend.spiking_linear(None, _lin_operand(params["wo"], h * hd), a,
                                 part="row")
    return out, {"sk": sk, "sv": sv, "pos": pos + 1}


def _spiking_decode_ffn_tail(params, s: Array, cfg: ModelConfig,
                             pctx: ParallelCtx, keys_for, backend) -> Array:
    """The FFN half of a spiking decode block (norm2/mlp/moe), shared by the
    slot-dense and paged decode paths so the two are op-for-op identical."""
    if "norm2" not in params:
        return s
    if "moe" in params:
        rate = SP.rate_decode(s.astype(jnp.float32)).astype(model_dtype(cfg))
        ym, _ = M.moe_apply(params["moe"], rate, cfg, pctx, impl="dense")
        return s + _slot_rate_encode(keys_for(200003), ym, s.shape[0])
    h1 = backend.spiking_linear(
        None, _lin_operand(params["mlp"]["wi"], s.shape[-1]), s, part="col")
    return s + backend.spiking_linear(
        None, _lin_operand(params["mlp"]["wo"], h1.shape[-1]),
        h1.astype(s.dtype), part="row").astype(s.dtype)


def _fused_block_weights(params, cfg: ModelConfig, d: int):
    """Weight operands for ``backend.decode_layer_fused``: the same
    ``_lin_operand`` leaves the unfused per-primitive path feeds to
    ``spiking_linear``, so fused and unfused quantise identically."""
    h, hd = cfg.num_heads, cfg.resolved_head_dim
    mx = params["mixer"]
    wq = _lin_operand(mx["wq"], d)
    wk = _lin_operand(mx["wk"], d)
    wv = _lin_operand(mx["wv"], d)
    wo = _lin_operand(mx["wo"], h * hd)
    with_mlp = "norm2" in params and "moe" not in params
    wi = wo2 = None
    if with_mlp:
        wi = _lin_operand(params["mlp"]["wi"], d)
        wo2 = _lin_operand(params["mlp"]["wo"], cfg.d_ff)
    return wq, wk, wv, wo, wi, wo2, with_mlp


def _fused_block_spiking_decode(params, s: Array, cache, cfg: ModelConfig,
                                slot_keys: Array, backend):
    """One decoder block as a single fused-kernel launch (dense cache).

    The backend's megakernel computes projections, SSA decode, attention-out
    and the FFN tail in one pass over the *pre-scatter* cache (the row at
    ``pos`` is zero by the serving invariant, and the kernel adds the new
    token's contribution additively), then the returned K/V trains scatter
    into the cache here — bit-identical to scatter-then-attend."""
    t, b, _, d = s.shape
    wq, wk, wv, wo, wi, wo2, with_mlp = _fused_block_weights(params, cfg, d)
    pos = jnp.broadcast_to(cache["pos"], (b,))
    out, k_new, v_new = backend.decode_layer_fused(
        slot_keys, s[:, :, 0, :], KVView.dense(cache["sk"], cache["sv"]),
        pos, wq, wk, wv, wo, wi, wo2, hd=cfg.resolved_head_dim,
        with_mlp=with_mlp)
    barange = jnp.arange(b)
    sk = cache["sk"].at[barange, :, pos].set(
        jnp.moveaxis(k_new, 0, 1).astype(jnp.uint8))
    sv = cache["sv"].at[barange, :, pos].set(
        jnp.moveaxis(v_new, 0, 1).astype(jnp.uint8))
    return (out[:, :, None, :].astype(s.dtype),
            {"sk": sk, "sv": sv, "pos": pos + 1})


def _fused_block_spiking_decode_paged(params, s: Array, blk_pool,
                                      cfg: ModelConfig, page_table: Array,
                                      pos: Array, write_pids: Array,
                                      slot_keys: Array, backend):
    """Paged mirror of :func:`_fused_block_spiking_decode`: one megakernel
    launch over the pre-scatter page pool, then the returned K/V trains
    scatter into each slot's designated physical page."""
    t, b, _, d = s.shape
    wq, wk, wv, wo, wi, wo2, with_mlp = _fused_block_weights(params, cfg, d)
    out, k_new, v_new = backend.decode_layer_fused(
        slot_keys, s[:, :, 0, :],
        KVView.from_pool(blk_pool["kp"], blk_pool["vp"], page_table),
        pos, wq, wk, wv, wo, wi, wo2, hd=cfg.resolved_head_dim,
        write_pids=write_pids, with_mlp=with_mlp)
    off = pos % blk_pool["kp"].shape[3]
    kp = blk_pool["kp"].at[write_pids, :, :, off].set(
        jnp.moveaxis(k_new, 0, 1).astype(jnp.uint8))
    vp = blk_pool["vp"].at[write_pids, :, :, off].set(
        jnp.moveaxis(v_new, 0, 1).astype(jnp.uint8))
    return out[:, :, None, :].astype(s.dtype), {"kp": kp, "vp": vp}


def _apply_block_spiking_decode(params, s: Array, cache, cfg: ModelConfig,
                                pctx: ParallelCtx, mixer: str, slot_keys: Array,
                                uid, backend, plan: Optional[DecodePlan] = None):
    """Spiking residual block, decode flavour (mirrors _apply_block_spiking)."""

    def keys_for(tag):
        return jax.vmap(lambda kk: jax.random.fold_in(kk, tag + uid))(slot_keys)

    if mixer in ("attn", "local"):
        if plan is not None and plan.fused:
            return _fused_block_spiking_decode(
                params, s, cache, cfg, keys_for(1), backend)
        h, cache = _spiking_attention_decode(
            params["mixer"], s, cache, cfg, keys_for(1), backend)
        s = s + h.astype(s.dtype)
    else:
        # attention-free mixers run on the rate interface (as in the forward)
        rate = SP.rate_decode(s.astype(jnp.float32)).astype(model_dtype(cfg))
        if mixer == "ssd":
            y, cache = S.ssd_decode(params["mixer"], rate, cache, cfg)
        else:
            y, cache = R.rglru_decode(params["mixer"], rate, cache, cfg)
        s = s + _slot_rate_encode(keys_for(100003), y, s.shape[0])
    s = _spiking_decode_ffn_tail(params, s, cfg, pctx, keys_for, backend)
    return s, cache


def _decode_step_spiking(params, cache, tokens: Array, cfg: ModelConfig,
                         pctx: ParallelCtx, backend, seeds: Array,
                         plan: Optional[DecodePlan] = None):
    """One spiking decode step, entirely through the backend's primitives.

    tokens [B,1], seeds [B] uint32 (per-slot request stream ids) ->
    (logits [B,1,V], new cache, activity [B]).  All sampling (rate coding,
    SSA comparators) is keyed per slot by f(seed, pos), so a slot's output
    stream is invariant to which other requests share the batch.

    ``activity`` counts each slot's residual-stream spike events this step
    (input coding + after every block) — the measured quantity the serving
    layer multiplies by per-event op energies for per-request metering."""
    dt = model_dtype(cfg)
    x = L.embed(params["embed"], tokens, dt) * jnp.asarray(jnp.sqrt(cfg.d_model), dt)
    pos0 = _first_pos(cache)
    slot_keys = _slot_base_keys(seeds, pos0)
    enc_keys = jax.vmap(lambda kk: jax.random.fold_in(kk, 0))(slot_keys)
    s = _slot_rate_encode(enc_keys, x, cfg.spike_T)  # [T,B,1,d] float32

    def slot_events(st):  # [T,B,1,d] -> [B] spike events
        return jnp.sum(st.astype(jnp.float32), axis=(0, 2, 3))

    act = slot_events(s)
    new_cache: Dict[str, Any] = {}
    if cfg.num_periods > 0:
        def period_body(carry, xs):
            s, act = carry
            pp, pc, pidx = xs
            nc = {}
            for i, mixer in enumerate(cfg.block_pattern):
                s, c = _apply_block_spiking_decode(
                    pp[f"blk{i}"], s, pc[f"blk{i}"], cfg, pctx, mixer,
                    slot_keys, pidx * cfg.period + i, backend, plan)
                nc[f"blk{i}"] = c
                act = act + slot_events(s)
            return (s, act), nc

        (s, act), new_cache["periods"] = lax.scan(
            period_body, (s, act),
            (params["periods"], cache["periods"], jnp.arange(cfg.num_periods)))
    if cfg.remainder_layers:
        rem = {}
        base_uid = cfg.num_periods * cfg.period
        for i in range(cfg.remainder_layers):
            s, c = _apply_block_spiking_decode(
                params["remainder"][f"blk{i}"], s, cache["remainder"][f"blk{i}"],
                cfg, pctx, cfg.block_pattern[i], slot_keys, base_uid + i,
                backend, plan)
            rem[f"blk{i}"] = c
            act = act + slot_events(s)
        new_cache["remainder"] = rem
    xr = SP.rate_decode(s.astype(jnp.float32)).astype(dt)
    logits = _unembed(params, xr, cfg)
    return logits, new_cache, act


# ---------------------------------------------------------------------------
# Block-paged spiking decode (paged spike-train KV cache)
# ---------------------------------------------------------------------------


def paged_decode_supported(cfg: ModelConfig) -> bool:
    """Paged spike-train KV caching serves spiking SSA stacks whose every
    mixer is an attention block — attention-free mixers (ssd/rglru) carry
    recurrent state with no position axis to page."""
    return _spiking_decode_enabled(cfg) and all(
        m in ("attn", "local") for m in cfg.block_pattern)


def paged_pool_schema(cfg: ModelConfig, n_pages: int, page_len: int):
    """Abstract paged KV pool: per-layer physical spike pages, no slot axis.

    Each attention block's dense ``sk/sv [B, T, L, KV, hd]`` cache becomes a
    global ``kp/vp [n_pages, T, KV, page_len, hd]`` page pool shared by all
    serving slots; slots address blocks through an external page table.
    Physical page 0 is the permanently-zero *null page* (unallocated table
    entries read as zero spikes — comparator-masked) and page 1 is the
    *trash page* inactive slots write into (never referenced by a table),
    so one fixed-shape decode step serves any occupancy pattern."""
    assert paged_decode_supported(cfg), (
        "paged KV caching needs a spiking SSA stack of pure attention "
        f"blocks, not {cfg.block_pattern}")
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    leaf = jax.ShapeDtypeStruct((n_pages, cfg.spike_T, kv, page_len, hd),
                                jnp.uint8)
    blk = {"kp": leaf, "vp": leaf}

    def stack(tree, n):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), tree)

    out: Dict[str, Any] = {}
    if cfg.num_periods > 0:
        period = {f"blk{i}": dict(blk) for i in range(cfg.period)}
        out["periods"] = stack(period, cfg.num_periods)
    if cfg.remainder_layers:
        out["remainder"] = {
            f"blk{i}": dict(blk) for i in range(cfg.remainder_layers)}
    return out


def init_paged_pool(cfg: ModelConfig, n_pages: int, page_len: int):
    """Materialise an all-zero page pool (every page starts free & zeroed)."""
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        paged_pool_schema(cfg, n_pages, page_len))


def _spiking_attention_decode_paged(params, s: Array, blk_pool, cfg: ModelConfig,
                                    page_table: Array, pos: Array,
                                    write_pids: Array, slot_keys: Array,
                                    backend):
    """One-token SSA decode against the block-paged KV pool.

    The paged mirror of :func:`_spiking_attention_decode`: the new token's
    K/V spike trains scatter into the *physical* page each slot's scheduler
    designated (``write_pids [B]`` — the slot's private page for logical
    block ``pos // page_len``, or the trash page for idle slots), and the
    query attends through the page table via the backend's paged decode.
    Q/K/V/O projections are the same backend spiking linears as the dense
    path, so for identical logical cache content the two paths are
    bit-identical on the bit-exact substrates."""
    t, b, _, d = s.shape
    h, hd, kv = cfg.num_heads, cfg.resolved_head_dim, cfg.num_kv_heads

    def proj(w):  # LIF(W s^t) -> [T,B,heads,hd]
        out = backend.spiking_linear(None, _lin_operand(w, d), s, part="col")
        return out.reshape(t, b, -1, hd)

    q = proj(params["wq"])  # [T,B,H,hd]
    k_new = proj(params["wk"])  # [T,B,KV,hd]
    v_new = proj(params["wv"])
    page_len = blk_pool["kp"].shape[3]
    off = pos % page_len
    # scatter each slot's new K/V train into its designated physical page
    kp = blk_pool["kp"].at[write_pids, :, :, off].set(
        jnp.moveaxis(k_new, 0, 1).astype(jnp.uint8))
    vp = blk_pool["vp"].at[write_pids, :, :, off].set(
        jnp.moveaxis(v_new, 0, 1).astype(jnp.uint8))
    i_max = page_table.shape[1] * page_len  # logical cache capacity
    a = backend.decode_attention(
        KVView.from_pool(kp, vp, page_table), q[:, :, :, None, :],
        AttnSpec(i_max=i_max, groups=h // kv), slot_keys=slot_keys)
    a = a.reshape(t, b, 1, h * hd).astype(s.dtype)
    out = backend.spiking_linear(None, _lin_operand(params["wo"], h * hd), a,
                                 part="row")
    return out, {"kp": kp, "vp": vp}


def _apply_block_spiking_decode_paged(params, s: Array, blk_pool,
                                      cfg: ModelConfig, pctx: ParallelCtx,
                                      page_table: Array, pos: Array,
                                      write_pids: Array, slot_keys: Array,
                                      uid, backend,
                                      plan: Optional[DecodePlan] = None):
    """Spiking residual block over the paged pool (decode flavour)."""

    def keys_for(tag):
        return jax.vmap(lambda kk: jax.random.fold_in(kk, tag + uid))(slot_keys)

    if plan is not None and plan.fused:
        return _fused_block_spiking_decode_paged(
            params, s, blk_pool, cfg, page_table, pos, write_pids,
            keys_for(1), backend)
    h, blk_pool = _spiking_attention_decode_paged(
        params["mixer"], s, blk_pool, cfg, page_table, pos, write_pids,
        keys_for(1), backend)
    s = s + h.astype(s.dtype)
    s = _spiking_decode_ffn_tail(params, s, cfg, pctx, keys_for, backend)
    return s, blk_pool


def paged_decode_step(params, pool, page_table: Array, tokens: Array,
                      pos: Array, seeds: Array, write_pids: Array,
                      cfg: ModelConfig, pctx: ParallelCtx = ParallelCtx(),
                      *, backend=None, plan: Optional[DecodePlan] = None):
    """One spiking decode step over the block-paged KV pool.

    tokens [B,1], pos [B] (each slot's logical write position), seeds [B]
    uint32 (the PRN stream id this step — the request seed during decode,
    the *content key* of the position during chunked prefill), write_pids
    [B] (each slot's private physical page for block ``pos // page_len``;
    the trash page for idle slots) -> (logits [B,1,V], new pool, activity
    [B]).

    All sampling is keyed ``f(seed, pos, ...)`` exactly as the dense
    :func:`decode_step`, and the K/V content reachable through a slot's
    page table equals its dense cache, so paged serving is bit-identical
    to dense serving on the bit-exact backends — while prompt prefixes
    shared between requests resolve to the *same physical pages*."""
    backend = backend or _default_backend()
    assert paged_decode_supported(cfg)
    dt = model_dtype(cfg)
    x = L.embed(params["embed"], tokens, dt) * jnp.asarray(jnp.sqrt(cfg.d_model), dt)
    slot_keys = _slot_base_keys(seeds, pos)
    enc_keys = jax.vmap(lambda kk: jax.random.fold_in(kk, 0))(slot_keys)
    s = _slot_rate_encode(enc_keys, x, cfg.spike_T)  # [T,B,1,d] float32

    def slot_events(st):  # [T,B,1,d] -> [B] spike events
        return jnp.sum(st.astype(jnp.float32), axis=(0, 2, 3))

    act = slot_events(s)
    new_pool: Dict[str, Any] = {}
    if cfg.num_periods > 0:
        def period_body(carry, xs):
            s, act = carry
            pp, pc, pidx = xs
            nc = {}
            for i in range(cfg.period):
                s, c = _apply_block_spiking_decode_paged(
                    pp[f"blk{i}"], s, pc[f"blk{i}"], cfg, pctx, page_table,
                    pos, write_pids, slot_keys, pidx * cfg.period + i, backend,
                    plan)
                nc[f"blk{i}"] = c
                act = act + slot_events(s)
            return (s, act), nc

        (s, act), new_pool["periods"] = lax.scan(
            period_body, (s, act),
            (params["periods"], pool["periods"], jnp.arange(cfg.num_periods)))
    if cfg.remainder_layers:
        rem = {}
        base_uid = cfg.num_periods * cfg.period
        for i in range(cfg.remainder_layers):
            s, c = _apply_block_spiking_decode_paged(
                params["remainder"][f"blk{i}"], s, pool["remainder"][f"blk{i}"],
                cfg, pctx, page_table, pos, write_pids, slot_keys,
                base_uid + i, backend, plan)
            rem[f"blk{i}"] = c
            act = act + slot_events(s)
        new_pool["remainder"] = rem
    xr = SP.rate_decode(s.astype(jnp.float32)).astype(dt)
    logits = _unembed(params, xr, cfg)
    return logits, new_pool, act


def decode_step(
    params, cache, tokens: Array, cfg: ModelConfig, pctx: ParallelCtx = ParallelCtx(),
    *, moe_impl: str = "ep_a2a", backend=None, seeds: Optional[Array] = None,
    with_activity: bool = False, plan: Optional[DecodePlan] = None,
):
    """One decoding step. tokens [B,1] -> (logits [B,1,V], new cache).

    Spiking SSA configs decode through the pluggable backend's spiking
    primitives over spike-train KV caches (``seeds [B]`` supplies the
    per-slot PRN stream ids; defaults to zeros).  All other configs use the
    conventional float decode path and ignore ``backend``/``seeds``.

    ``with_activity=True`` appends a per-slot spike-event count ``[B]`` to
    the return (zeros on the conventional path) — the measured input to the
    serving layer's per-request energy metering."""
    if _spiking_decode_enabled(cfg):
        if seeds is None:
            seeds = jnp.zeros((tokens.shape[0],), jnp.uint32)
        logits, new_cache, act = _decode_step_spiking(
            params, cache, tokens, cfg, pctx, backend or _default_backend(),
            seeds, plan)
        if with_activity:
            return logits, new_cache, act
        return logits, new_cache
    dt = model_dtype(cfg)
    x = L.embed(params["embed"], tokens, dt) * jnp.asarray(jnp.sqrt(cfg.d_model), dt)

    def period_body(x, xs):
        period_params, period_cache = xs
        new_cache = {}
        for i, mixer in enumerate(cfg.block_pattern):
            x, c = _apply_block_decode(
                period_params[f"blk{i}"], x, period_cache[f"blk{i}"], cfg, pctx, mixer,
                moe_impl=moe_impl,
            )
            new_cache[f"blk{i}"] = c
        return x, new_cache

    new_cache: Dict[str, Any] = {}
    if cfg.num_periods > 0:
        if L.EXACT_FLOPS_MODE:
            caches = []
            for pi in range(cfg.num_periods):
                pp = jax.tree.map(lambda t: t[pi], params["periods"])
                pc = jax.tree.map(lambda t: t[pi], cache["periods"])
                x, nc = period_body(x, (pp, pc))
                caches.append(nc)
            new_cache["periods"] = jax.tree.map(
                lambda *xs: jnp.stack(xs), *caches
            )
        else:
            x, new_cache["periods"] = lax.scan(
                period_body, x, (params["periods"], cache["periods"])
            )
    if cfg.remainder_layers:
        rem = {}
        for i in range(cfg.remainder_layers):
            x, c = _apply_block_decode(
                params["remainder"][f"blk{i}"], x, cache["remainder"][f"blk{i}"],
                cfg, pctx, cfg.block_pattern[i], moe_impl=moe_impl,
            )
            rem[f"blk{i}"] = c
        new_cache["remainder"] = rem
    logits = _unembed(params, x, cfg)
    if with_activity:  # conventional path: no spike events
        return logits, new_cache, jnp.zeros((tokens.shape[0],), jnp.float32)
    return logits, new_cache


def prefill(
    params, batch, cfg: ModelConfig, pctx: ParallelCtx = ParallelCtx(),
    *, moe_impl: str = "ep_a2a",
):
    """Prefill forward returning logits (cache production handled by caller
    via decode over the tail in serving; the dry-run lowers this as the
    prefill workload)."""
    return forward(params, batch, cfg, pctx, moe_impl=moe_impl, remat="none")
