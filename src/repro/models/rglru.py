"""RecurrentGemma's RG-LRU recurrent block (arXiv:2402.19427).

Recurrence (per channel):

    r_t = sigmoid(W_r x_t + b_r)            recurrence gate
    i_t = sigmoid(W_i x_t + b_i)            input gate
    log a_t = -c * softplus(Lambda) * r_t   (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The full block is: linear in-proj to (x, gate), short causal depthwise conv
on x, RG-LRU, then out-proj of h * gelu(gate).  Training/prefill evaluates
the linear recurrence with ``lax.associative_scan`` (log-depth, parallel on
the batch/width axes — the TPU-native replacement for the paper's fused GPU
scan kernel); decode carries h.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import ParamDef

Array = jax.Array

_C = 8.0


def rglru_width(cfg) -> int:
    return cfg.rglru_width or cfg.d_model


def rglru_schema(cfg) -> Dict[str, ParamDef]:
    d = cfg.d_model
    w = rglru_width(cfg)
    k = cfg.rglru_conv_width
    return {
        "w_x": ParamDef((d, w), ("embed", "lru")),
        "w_gate": ParamDef((d, w), ("embed", "lru")),
        "conv_w": ParamDef((k, w), (None, "lru")),
        "conv_b": ParamDef((w,), ("lru",), init="zeros"),
        "w_r": ParamDef((w, w), ("lru", "lru_in")),
        "b_r": ParamDef((w,), ("lru",), init="zeros"),
        "w_i": ParamDef((w, w), ("lru", "lru_in")),
        "b_i": ParamDef((w,), ("lru",), init="zeros"),
        "lam": ParamDef((w,), ("lru",), init="ones"),
        "w_out": ParamDef((w, d), ("lru", "embed")),
    }


def _constrain_w(t: Array, pctx) -> Array:
    """Shard the LRU width over the model axis (and batch over DP).

    The recurrence is elementwise over width, so width-sharding makes the
    whole scan embarrassingly parallel on the TP axis — the right layout
    even though the surrounding blocks are sequence-sharded."""
    if pctx is None or pctx.mesh is None or pctx.tp_axis is None:
        return t
    if t.shape[-1] % pctx.tp_size:
        return t
    from jax.sharding import NamedSharding, PartitionSpec as P

    return jax.lax.with_sharding_constraint(
        t, NamedSharding(pctx.mesh, P(pctx.dp_axes or None, None, pctx.tp_axis))
    )


def _conv(x: Array, w: Array, b: Array) -> Array:
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + xp[:, i : i + x.shape[1], :] * w[i].astype(x.dtype)
    return out + b.astype(x.dtype)


def _gates(params, xc: Array):
    r = jax.nn.sigmoid(xc @ params["w_r"].astype(xc.dtype) + params["b_r"].astype(xc.dtype))
    i = jax.nn.sigmoid(xc @ params["w_i"].astype(xc.dtype) + params["b_i"].astype(xc.dtype))
    log_a = (-_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r.astype(jnp.float32))
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, beta, i


def rglru_mixer(params, x: Array, cfg, *, return_state: bool = False, pctx=None):
    """x [B,L,D] -> [B,L,D] (train/prefill, associative scan over L)."""
    b, L, _ = x.shape
    xin = _constrain_w(x @ params["w_x"].astype(x.dtype), pctx)
    gate = _constrain_w(x @ params["w_gate"].astype(x.dtype), pctx)
    xc = _constrain_w(_conv(xin, params["conv_w"], params["conv_b"]), pctx)

    a, beta, i = _gates(params, xc)
    bterm = (beta * (i * xc).astype(jnp.float32)).astype(jnp.float32)  # [B,L,W]

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    a_sc, h = lax.associative_scan(combine, (a, bterm), axis=1)
    y = (h.astype(x.dtype) * jax.nn.gelu(gate)) @ params["w_out"].astype(x.dtype)
    if return_state:
        k = cfg.rglru_conv_width
        conv_tail = jnp.pad(xin, ((0, 0), (max(k - 1 - L, 0), 0), (0, 0)))[:, -(k - 1) :, :]
        return y, {
            "h": h[:, -1, :],
            "conv": conv_tail,
            "pos": jnp.full((b,), L, jnp.int32),
        }
    return y


def rglru_cache_schema(cfg, batch: int):
    w = rglru_width(cfg)
    k = cfg.rglru_conv_width
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {
        "h": jax.ShapeDtypeStruct((batch, w), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, k - 1, w), dt),
        "pos": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }


def rglru_decode(params, x: Array, cache, cfg):
    """One-token decode. x [B,1,D]."""
    b = x.shape[0]
    xin = x @ params["w_x"].astype(x.dtype)  # [B,1,W]
    gate = x @ params["w_gate"].astype(x.dtype)
    hist = jnp.concatenate([cache["conv"].astype(x.dtype), xin], axis=1)
    xc = (
        jnp.einsum("bkc,kc->bc", hist, params["conv_w"].astype(x.dtype))
        + params["conv_b"].astype(x.dtype)
    )[:, None, :]
    a, beta, i = _gates(params, xc)
    h = a[:, 0] * cache["h"] + (beta[:, 0] * (i[:, 0] * xc[:, 0]).astype(jnp.float32))
    y = (h[:, None, :].astype(x.dtype) * jax.nn.gelu(gate)) @ params["w_out"].astype(x.dtype)
    return y, {
        "h": h,
        "conv": hist[:, 1:, :].astype(cache["conv"].dtype),
        "pos": jnp.broadcast_to(cache["pos"], (b,)) + 1,
    }
