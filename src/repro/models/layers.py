"""Core neural layers for the generic LM stack.

Pure-functional: every layer is ``apply(params, x, ...)`` with params a dict
of jnp arrays.  Parameter *schemas* (shape + logical sharding axes) live
beside the initialisers so the sharding layer (parallel/sharding.py) can map
every leaf to a PartitionSpec without instantiating weights.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array

# ---------------------------------------------------------------------------
# Parameter schema plumbing
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """Declarative parameter: shape, logical axes, init scale."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis name per dim (or None)
    init: str = "normal"  # normal | zeros | ones
    scale: float = 1.0
    fan_in: Optional[int] = None  # override (e.g. layer-stacked params)

    def materialise(self, key: Array, dtype) -> Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, dtype)
        fan = self.fan_in or (self.shape[0] if len(self.shape) > 1 else max(self.shape[0], 1))
        std = self.scale / math.sqrt(fan)
        return (jax.random.normal(key, self.shape, jnp.float32) * std).astype(dtype)


def init_tree(key: Array, schema: Any, dtype) -> Any:
    """Materialise a pytree of ParamDefs into arrays (one fold of the key)."""
    leaves, treedef = jax.tree.flatten(schema, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))
    out = [d.materialise(k, dtype) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)


def abstract_tree(schema: Any, dtype) -> Any:
    leaves, treedef = jax.tree.flatten(schema, is_leaf=lambda x: isinstance(x, ParamDef))
    out = [jax.ShapeDtypeStruct(d.shape, dtype) for d in leaves]
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Normalisation
# ---------------------------------------------------------------------------


def norm_schema(d: int) -> Dict[str, ParamDef]:
    return {"scale": ParamDef((d,), ("embed",), init="ones")}


def rmsnorm(params: Dict[str, Array], x: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def layernorm(params: Dict[str, Array], x: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def apply_norm(kind: str, params, x: Array) -> Array:
    return rmsnorm(params, x) if kind == "rmsnorm" else layernorm(params, x)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """Rotate ``x [..., S, H, D]`` by ``positions [..., S]`` (broadcastable)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)  # [D/2]
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # [...,S,1,D/2]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, global + sliding-window), train/prefill and decode paths
# ---------------------------------------------------------------------------


def attention_schema(cfg) -> Dict[str, Any]:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    s: Dict[str, Any] = {
        "wq": ParamDef((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamDef((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamDef((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamDef((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamDef((h, hd), ("heads", "head_dim"), init="zeros")
        s["bk"] = ParamDef((kv, hd), ("kv_heads", "head_dim"), init="zeros")
        s["bv"] = ParamDef((kv, hd), ("kv_heads", "head_dim"), init="zeros")
    return s


def _qkv(params, x: Array, positions: Array, cfg) -> Tuple[Array, Array, Array]:
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q: Array, k: Array, v: Array, mask: Optional[Array], softcap: float) -> Array:
    """Grouped scaled-dot-product attention.  q [B,S,H,D], k/v [B,L,K,D]."""
    b, s, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, d)
    scores = jnp.einsum("bskgd,blkd->bkgsl", qg, k).astype(jnp.float32)
    scores = scores / math.sqrt(d)
    if softcap > 0:
        scores = jnp.tanh(scores / softcap) * softcap
    if mask is not None:
        scores = jnp.where(mask, scores, jnp.float32(-1e30))
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgsl,blkd->bskgd", w, v)
    return out.reshape(b, s, h, d)


def causal_mask(s: int, l: int, offset: int = 0) -> Array:
    """[s, l] boolean mask: query i attends to key j iff j <= i + offset."""
    qi = jnp.arange(s)[:, None] + offset
    kj = jnp.arange(l)[None, :]
    return kj <= qi


def window_mask(s: int, l: int, window: int, offset: int = 0) -> Array:
    qi = jnp.arange(s)[:, None] + offset
    kj = jnp.arange(l)[None, :]
    return (kj <= qi) & (kj > qi - window)


_FLASH_BLOCK = 1024
_FLASH_MIN_SEQ = 2048

# Exact-flops measurement mode (launch/dryrun.py): XLA's cost analysis
# counts a lax.scan body ONCE regardless of trip count, so the roofline
# measurement variant replaces scanned attention (flash / banded-Q-scan)
# with scan-free equivalents whose HLO flops are exact.  Never enabled for
# real execution.
EXACT_FLOPS_MODE = False


def _flash_attention(q: Array, k: Array, v: Array, softcap: float, *, window: int = 0,
                     blk: int = _FLASH_BLOCK) -> Array:
    """Online-softmax (flash) causal attention: lax.scan over KV blocks.

    Never materialises the [S, S] score matrix — the score block
    [B,K,G,S,blk] is the peak transient.  This is the pure-XLA analogue of
    a flash kernel (the real TPU kernel would be Pallas; on this CPU
    container the dry-run must stay XLA-lowerable at 512 devices).
    """
    b, s, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    nb = s // blk
    qg = q.reshape(b, s, kvh, g, d)
    scale = 1.0 / math.sqrt(d)
    q_pos = jnp.arange(s)

    kb = jnp.moveaxis(k.reshape(b, nb, blk, kvh, d), 1, 0)  # [nb,B,blk,K,D]
    vb = jnp.moveaxis(v.reshape(b, nb, blk, kvh, d), 1, 0)

    def step(carry, xs):
        m, l, acc = carry
        j, k_j, v_j = xs
        k_pos = j * blk + jnp.arange(blk)
        sblk = jnp.einsum("bskgd,blkd->bkgsl", qg, k_j).astype(jnp.float32) * scale
        if softcap > 0:
            sblk = jnp.tanh(sblk / softcap) * softcap
        mask = k_pos[None, :] <= q_pos[:, None]
        if window:
            mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
        sblk = jnp.where(mask[None, None, None], sblk, -1e30)
        m_new = jnp.maximum(m, jnp.max(sblk, axis=-1))
        p = jnp.exp(sblk - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgsl,blkd->bskgd", p.astype(q.dtype), v_j)
        acc = acc * jnp.moveaxis(corr, 3, 1)[..., None].astype(q.dtype) + pv
        return (m_new, l, acc), None

    m0 = jnp.full((b, kvh, g, s), -1e30, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, s), jnp.float32)
    acc0 = jnp.zeros((b, s, kvh, g, d), q.dtype)
    (m, l, acc), _ = lax.scan(
        jax.checkpoint(step), (m0, l0, acc0), (jnp.arange(nb), kb, vb)
    )
    out = acc / jnp.moveaxis(jnp.maximum(l, 1e-20), 3, 1)[..., None].astype(q.dtype)
    return out.reshape(b, s, h, d)


def _banded_local_vmap(q: Array, k: Array, v: Array, cfg, window: int) -> Array:
    """Scan-free block-banded sliding window (exact-flops variant)."""
    b, s, h, d = q.shape
    nb = s // window
    kvh = k.shape[2]
    qb = q.reshape(b, nb, window, h, d)
    kb = k.reshape(b, nb, window, kvh, d)
    vb = v.reshape(b, nb, window, kvh, d)
    k_prev = jnp.pad(kb[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))
    v_prev = jnp.pad(vb[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))
    kk = jnp.concatenate([k_prev, kb], axis=2)
    vv = jnp.concatenate([v_prev, vb], axis=2)
    base = window_mask(window, 2 * window, window, offset=window)
    first = base & (jnp.arange(2 * window)[None, :] >= window)
    mask = jnp.where(jnp.arange(nb)[:, None, None] == 0, first, base)
    out = jax.vmap(
        lambda qq, kkk, vvv, m: _sdpa(qq, kkk, vvv, m[None, None, None], cfg.attn_softcap),
        in_axes=(1, 1, 1, 0), out_axes=1,
    )(qb, kk, vv, mask)
    return out.reshape(b, s, h, d)


def _banded_local(q: Array, k: Array, v: Array, cfg, window: int) -> Array:
    """Sliding-window attention, lax.scan over Q blocks of size ``window``.

    Each Q block attends to exactly (itself + predecessor block): FLOPs are
    O(S * 2w), the peak transient one [w, 2w] score block."""
    b, s, h, d = q.shape
    nb = s // window
    kvh = k.shape[2]
    qb = jnp.moveaxis(q.reshape(b, nb, window, h, d), 1, 0)
    kb = jnp.moveaxis(k.reshape(b, nb, window, kvh, d), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nb, window, kvh, d), 1, 0)
    k_prev = jnp.concatenate([jnp.zeros_like(kb[:1]), kb[:-1]], axis=0)
    v_prev = jnp.concatenate([jnp.zeros_like(vb[:1]), vb[:-1]], axis=0)

    base = window_mask(window, 2 * window, window, offset=window)
    first = base & (jnp.arange(2 * window)[None, :] >= window)

    def step(_, xs):
        i, qq, kk1, kk2, vv1, vv2 = xs
        kk = jnp.concatenate([kk1, kk2], axis=1)  # [B,2w,K,D]
        vv = jnp.concatenate([vv1, vv2], axis=1)
        mask = jnp.where(i == 0, first, base)
        o = _sdpa(qq, kk, vv, mask[None, None, None], cfg.attn_softcap)
        return None, o

    _, ob = lax.scan(
        jax.checkpoint(step), None, (jnp.arange(nb), qb, k_prev, kb, v_prev, vb)
    )
    return jnp.moveaxis(ob, 0, 1).reshape(b, s, h, d)


def attention(params, x: Array, positions: Array, cfg, *, window: int = 0) -> Array:
    """Full (or sliding-window) causal self-attention for train/prefill.

    Long sequences use the flash (online-softmax) path for global layers
    and the banded Q-block scan for sliding-window layers; short sequences
    use the plain masked einsum.
    """
    b, s, _ = x.shape
    q, k, v = _qkv(params, x, positions, cfg)
    blk = _FLASH_BLOCK
    while blk > 128 and s % blk:
        blk //= 2
    if window and s > 2 * window and s % window == 0:
        fn = _banded_local_vmap if EXACT_FLOPS_MODE else _banded_local
        out = fn(q, k, v, cfg, window)
    elif not EXACT_FLOPS_MODE and s >= _FLASH_MIN_SEQ and s % blk == 0:
        out = _flash_attention(q, k, v, cfg.attn_softcap, window=window, blk=blk)
    else:
        m = window_mask(s, s, window) if window else causal_mask(s, s)
        out = _sdpa(q, k, v, m[None, None, None], cfg.attn_softcap)
    return jnp.einsum("bshd,hdk->bsk", out, params["wo"].astype(x.dtype))


def attention_decode(
    params, x: Array, cache: Dict[str, Array], cfg, *, window: int = 0
) -> Tuple[Array, Dict[str, Array]]:
    """Single-token decode with a KV cache.

    cache: {"k": [B,L,K,D], "v": [B,L,K,D], "pos": [B] int32} — ``pos`` is
    the per-slot number of valid tokens, so batched decode can serve
    requests at *different* sequence positions (continuous batching: each
    slot prefills independently and advances in lockstep afterwards).  For
    windowed layers the cache is a ring buffer of length ``window``.
    """
    b, s, _ = x.shape
    assert s == 1
    pos = jnp.broadcast_to(cache["pos"], (b,))  # [B] per-slot positions
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    positions = pos[:, None]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    L = cache["k"].shape[1]
    slot = (pos % jnp.int32(window)) if window else pos
    barange = jnp.arange(b)
    ck = cache["k"].at[barange, slot].set(k[:, 0].astype(cache["k"].dtype))
    cv = cache["v"].at[barange, slot].set(v[:, 0].astype(cache["v"].dtype))
    idx = jnp.arange(L)
    if window:
        valid = idx[None, :] < jnp.minimum(pos + 1, L)[:, None]
    else:
        valid = idx[None, :] <= pos[:, None]
    out = _sdpa(q, ck.astype(q.dtype), cv.astype(q.dtype),
                valid[:, None, None, None, :], cfg.attn_softcap)
    y = jnp.einsum("bshd,hdk->bsk", out, params["wo"].astype(x.dtype))
    return y, {"k": ck, "v": cv, "pos": pos + 1}


def attention_cache_schema(cfg, batch: int, seq_len: int, *, window: int = 0):
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    L = min(window, seq_len) if window else seq_len
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    return {
        "k": jax.ShapeDtypeStruct((batch, L, kv, hd), dt),
        "v": jax.ShapeDtypeStruct((batch, L, kv, hd), dt),
        "pos": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }


# ---------------------------------------------------------------------------
# MLP (dense feed-forward)
# ---------------------------------------------------------------------------


def mlp_schema(cfg, d_ff: Optional[int] = None) -> Dict[str, ParamDef]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    s = {
        "wi": ParamDef((d, f), ("embed", "ffn")),
        "wo": ParamDef((f, d), ("ffn", "embed")),
    }
    if cfg.gated_mlp:
        s["wg"] = ParamDef((d, f), ("embed", "ffn"))
    return s


def _act(kind: str, x: Array) -> Array:
    return jax.nn.silu(x) if kind == "silu" else jax.nn.gelu(x)


def mlp(params, x: Array, cfg) -> Array:
    h = x @ params["wi"].astype(x.dtype)
    if "wg" in params:
        h = _act(cfg.act, x @ params["wg"].astype(x.dtype)) * h
    else:
        h = _act(cfg.act, h)
    return h @ params["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_schema(cfg) -> Dict[str, ParamDef]:
    s = {"table": ParamDef((cfg.vocab_size, cfg.d_model), ("vocab", "embed"))}
    return s


def embed(params, tokens: Array, dtype) -> Array:
    return params["table"].astype(dtype)[tokens]


def unembed_schema(cfg) -> Dict[str, ParamDef]:
    return {"w": ParamDef((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))}


def unembed(params, x: Array, cfg) -> Array:
    logits = x @ params["w"].astype(x.dtype)
    if cfg.logit_softcap > 0:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return logits
