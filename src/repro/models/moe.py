"""Mixture-of-Experts with 2-D expert parallelism.

Two interchangeable implementations:

* ``moe_dense``  — every expert computed on every token, combined with the
  router's top-k weights.  O(E) FLOPs: only for reduced smoke-test configs.

* ``moe_ep``     — production path under ``jax.shard_map``.  Experts are
  sharded over the ``model`` mesh axis (expert parallelism, EP) and each
  expert's d_ff dimension is sharded over the ``data`` axis (expert tensor
  parallelism) so that a 480B-expert bank (arctic) fits 256 chips.  Token
  routing is sort-based (MegaBlocks-style, no O(T*E*C) one-hot dispatch
  tensors) with fixed per-destination capacity and drop-on-overflow:

      sender (i,j):   sort (token,choice) pairs by destination column,
                      pack send buffer [M, C, d]
      all_to_all over "model":    route tokens to their expert column
      all_gather over "data":     un-shard the d_ff dimension of the local
                                  experts' weights (ZeRO-3: weights live
                                  sharded, are gathered just-in-time per
                                  layer, and gradients reduce-scatter back
                                  via the shard_map transpose)
      expert compute:  [E_l, C2, d] x [E_l, d, f] -> act -> [E_l, C2, d]
      all_to_all back over "model", weighted combine at the sender.

  Gathering *weights* (O(E_l * d * f) once per layer) instead of *tokens*
  (O(16x tokens * d) per layer) keeps both the transient memory and the
  ICI bytes bounded at arctic-480b scale — see EXPERIMENTS.md §Perf.

  The ``pod`` axis is untouched: expert weights are replicated across pods
  and each pod routes its own tokens (hierarchical EP — no inter-pod
  all-to-all, which would cross the slow DCN links).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models.layers import ParamDef, _act

Array = jax.Array

# jax.shard_map landed in jax 0.6; on the pinned 0.4.x it lives under
# jax.experimental (with replication checking named check_rep, off by
# default behaviourally equivalent to check_vma=False here).
if hasattr(jax, "shard_map"):
    _shard_map = partial(jax.shard_map, check_vma=False)
else:  # jax < 0.6
    from jax.experimental.shard_map import shard_map as _eshard_map

    _shard_map = partial(_eshard_map, check_rep=False)


# ---------------------------------------------------------------------------
# Parallel context — how the surrounding program is laid out on the mesh
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    """Mesh axis bookkeeping threaded through the model."""

    mesh: Any = None  # jax.sharding.Mesh | None (None => single-device)
    dp_axes: Tuple[str, ...] = ()  # batch axes, e.g. ("pod", "data")
    fsdp_axis: Optional[str] = None  # "data" (d_ff shard of experts, ZeRO)
    tp_axis: Optional[str] = None  # "model"
    seq_shard: bool = True  # activations [B,S,d]: S over tp_axis

    @property
    def tp_size(self) -> int:
        if self.mesh is None or self.tp_axis is None:
            return 1
        return self.mesh.shape[self.tp_axis]

    @property
    def fsdp_size(self) -> int:
        if self.mesh is None or self.fsdp_axis is None:
            return 1
        return self.mesh.shape[self.fsdp_axis]

    def x_spec(self, seq_sharded: bool) -> P:
        b = self.dp_axes if self.dp_axes else None
        s = self.tp_axis if (seq_sharded and self.tp_axis) else None
        return P(b, s, None)


# ---------------------------------------------------------------------------
# Schema
# ---------------------------------------------------------------------------


def moe_schema(cfg) -> Dict[str, ParamDef]:
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    s = {
        "router": ParamDef((d, e), ("embed", "experts_r")),
        "wi": ParamDef((e, d, f), ("experts", "expert_embed", "expert_ffn")),
        "wo": ParamDef((e, f, d), ("experts", "expert_ffn", "expert_embed")),
    }
    if cfg.gated_mlp:
        s["wg"] = ParamDef((e, d, f), ("experts", "expert_embed", "expert_ffn"))
    return s


# ---------------------------------------------------------------------------
# Router
# ---------------------------------------------------------------------------


def router(params, x: Array, cfg) -> Tuple[Array, Array, Array]:
    """Top-k routing. Returns (weights [.. ,k], idx [.., k] int32, aux_loss)."""
    logits = (x @ params["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = lax.top_k(probs, cfg.moe_top_k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    # Switch-style load-balance auxiliary loss.
    e = cfg.num_experts
    me = jnp.mean(probs.reshape(-1, e), axis=0)
    one_hot = jax.nn.one_hot(idx.reshape(-1, cfg.moe_top_k), e, dtype=jnp.float32)
    ce = jnp.mean(jnp.sum(one_hot, axis=1), axis=0)
    aux = e * jnp.sum(me * ce)
    return weights.astype(x.dtype), idx.astype(jnp.int32), aux


# ---------------------------------------------------------------------------
# Dense (reference / smoke-test) implementation
# ---------------------------------------------------------------------------


def moe_dense(params, x: Array, cfg) -> Tuple[Array, Array]:
    weights, idx, aux = router(params, x, cfg)
    h = jnp.einsum("bsd,edf->bsef", x, params["wi"].astype(x.dtype))
    if "wg" in params:
        g = jnp.einsum("bsd,edf->bsef", x, params["wg"].astype(x.dtype))
        h = _act(cfg.act, g) * h
    else:
        h = _act(cfg.act, h)
    y_all = jnp.einsum("bsef,efd->bsed", h, params["wo"].astype(x.dtype))
    sel = jax.nn.one_hot(idx, cfg.num_experts, dtype=x.dtype)  # [B,S,k,E]
    comb = jnp.einsum("bske,bsk->bse", sel, weights)
    return jnp.einsum("bsed,bse->bsd", y_all, comb), aux


# ---------------------------------------------------------------------------
# Sort-based EP implementation (shard_map)
# ---------------------------------------------------------------------------


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _ep_local(
    x: Array,  # [T_l, d]   local tokens
    weights: Array,  # [T_l, k]
    idx: Array,  # [T_l, k]   global expert ids
    wi: Array,  # [E_l, d, f_l]
    wg: Optional[Array],
    wo: Array,  # [E_l, f_l, d]
    *,
    cfg,
    tp_axis: Optional[str],
    fsdp_axis: Optional[str],
    tp_size: int,
    fsdp_size: int,
    capacity: int,
    token_gather: bool = False,
) -> Array:
    """Per-device body of the EP MoE (runs inside shard_map)."""
    T_l, d = x.shape
    k = cfg.moe_top_k
    E = cfg.num_experts
    E_l = E // tp_size  # local experts per model column
    M, C = tp_size, capacity
    Pn = T_l * k

    # ---- 1. flatten (token, choice) pairs, sort by destination column ----
    flat_idx = idx.reshape(Pn)
    flat_w = weights.reshape(Pn)
    tok_of = jnp.arange(Pn, dtype=jnp.int32) // k
    dest = flat_idx // E_l  # destination model column
    local_e = flat_idx % E_l  # expert within the column
    order = jnp.argsort(dest, stable=True)
    dest_s, tok_s, le_s = dest[order], tok_of[order], local_e[order]
    # rank within destination group
    starts = jnp.cumsum(jnp.bincount(dest_s, length=M)) - jnp.bincount(dest_s, length=M)
    rank = jnp.arange(Pn, dtype=jnp.int32) - starts[dest_s].astype(jnp.int32)
    keep = rank < C
    slot = jnp.where(keep, rank, C - 1)

    send_x = jnp.zeros((M, C, d), x.dtype)
    send_x = send_x.at[dest_s, slot].add(jnp.where(keep[:, None], x[tok_s], 0))
    send_e = jnp.full((M, C), E_l, jnp.int32)  # E_l == "empty" sentinel
    send_e = send_e.at[dest_s, slot].min(jnp.where(keep, le_s, E_l))

    # ---- 2. route to the expert column ----
    if tp_axis is not None:
        recv_x = lax.all_to_all(send_x, tp_axis, 0, 0, tiled=True)
        recv_e = lax.all_to_all(send_e, tp_axis, 0, 0, tiled=True)
    else:
        recv_x, recv_e = send_x, send_e
    rx = recv_x.reshape(M * C, d)
    re = recv_e.reshape(M * C)
    Tg = rx.shape[0]

    # ---- 3. un-shard the d_ff dimension: gather WEIGHTS or TOKENS ----
    # Training (many tokens): gather the d_ff-sharded expert weights
    # (ZeRO-3, O(E_l*d*f) per layer).  Decode (few tokens): gather the
    # tokens over the data axis instead (O(R*M*C*d), kilobytes at decode)
    # and psum_scatter the f_l-partial outputs back — §Perf HC2.
    if (not token_gather) and fsdp_axis is not None and fsdp_size > 1:
        wi = lax.all_gather(wi, fsdp_axis, axis=2, tiled=True)  # [E_l, d, f]
        wo = lax.all_gather(wo, fsdp_axis, axis=1, tiled=True)  # [E_l, f, d]
        if wg is not None:
            wg = lax.all_gather(wg, fsdp_axis, axis=2, tiled=True)
    if token_gather and fsdp_axis is not None and fsdp_size > 1:
        rx = lax.all_gather(rx, fsdp_axis, axis=0, tiled=True)  # [R*M*C, d]
        re = lax.all_gather(re, fsdp_axis, axis=0, tiled=True)
        Tg = rx.shape[0]

    # ---- 4. group by local expert (sort + fixed capacity), compute ----
    C2 = _round_up(min(Tg, max(int(Tg // max(E_l, 1) * 1.25), 8)), 8)
    order2 = jnp.argsort(re, stable=True)
    re_s = re[order2]
    cnt = jnp.bincount(re_s, length=E_l + 1)
    st = jnp.cumsum(cnt) - cnt
    rank2 = jnp.arange(Tg, dtype=jnp.int32) - st[re_s].astype(jnp.int32)
    keep2 = (rank2 < C2) & (re_s < E_l)
    slot2 = jnp.where(keep2, rank2, C2 - 1)
    eid2 = jnp.where(keep2, re_s, 0)

    xg = jnp.zeros((E_l, C2, d), x.dtype)
    xg = xg.at[eid2, slot2].add(jnp.where(keep2[:, None], rx[order2], 0))

    h = jnp.einsum("ecd,edf->ecf", xg, wi.astype(x.dtype))
    if wg is not None:
        h = _act(cfg.act, jnp.einsum("ecd,edf->ecf", xg, wg.astype(x.dtype))) * h
    else:
        h = _act(cfg.act, h)
    yg = jnp.einsum("ecf,efd->ecd", h, wo.astype(x.dtype))

    # ---- 5. un-group, return to origin row ----
    ry = jnp.zeros((Tg, d), x.dtype)
    ry = ry.at[order2].add(jnp.where(keep2[:, None], yg[eid2, slot2], 0))
    if token_gather and fsdp_axis is not None and fsdp_size > 1:
        # sum the f_l partial outputs AND return each token to its row
        ry = lax.psum_scatter(ry, fsdp_axis, scatter_dimension=0, tiled=True)
    ry = ry.reshape(M, C, d)

    # ---- 6. route back and combine at the sender ----
    if tp_axis is not None:
        back = lax.all_to_all(ry, tp_axis, 0, 0, tiled=True)
    else:
        back = ry
    y = jnp.zeros((T_l, d), x.dtype)
    contrib = jnp.where(keep[:, None], back[dest_s, slot] * flat_w[order][:, None], 0)
    y = y.at[tok_s].add(contrib)
    return y


def moe_ep(params, x: Array, cfg, pctx: ParallelCtx, *, seq_sharded: bool) -> Tuple[Array, Array]:
    """EP MoE: router outside (GSPMD), dispatch/compute inside shard_map."""
    b, s, d = x.shape
    weights, idx, aux = router(params, x, cfg)

    tp = pctx.tp_size
    fs = pctx.fsdp_size
    # per-device local token count
    denom = tp if (seq_sharded and pctx.tp_axis) else 1
    for ax in pctx.dp_axes:
        denom *= pctx.mesh.shape[ax] if pctx.mesh is not None else 1
    T_l = max((b * s) // max(denom, 1), 1)
    cap = _round_up(int(T_l * cfg.moe_top_k * cfg.capacity_factor / tp) + 1, 8)

    # strategy: gather whichever is smaller — expert weights (training) or
    # the routed tokens (decode); see _ep_local step 3.
    n_mats = 3 if "wg" in params else 2
    weight_bytes = (cfg.num_experts // max(tp, 1)) * cfg.d_model * cfg.d_ff * n_mats
    token_bytes = 2 * fs * tp * cap * cfg.d_model  # gather + psum_scatter
    token_gather = token_bytes < weight_bytes

    body = partial(
        _ep_local,
        cfg=cfg,
        tp_axis=pctx.tp_axis,
        fsdp_axis=pctx.fsdp_axis,
        tp_size=tp,
        fsdp_size=fs,
        capacity=cap,
        token_gather=token_gather,
    )

    gated = "wg" in params

    def mapped(xl, wl, il, wi, wo, *maybe_wg):
        bl, sl, _ = xl.shape
        y = body(
            xl.reshape(bl * sl, d),
            wl.reshape(bl * sl, -1),
            il.reshape(bl * sl, -1),
            wi,
            maybe_wg[0] if maybe_wg else None,
            wo,
        )
        return y.reshape(bl, sl, d)

    extra = (params["wg"],) if gated else ()
    if pctx.mesh is None:
        y = mapped(x, weights, idx, params["wi"], params["wo"], *extra)
        return y, aux

    xs = pctx.x_spec(seq_sharded)
    wspec_in = P(pctx.tp_axis, None, pctx.fsdp_axis)  # wi/wg [E, d, f_l]
    wspec_out = P(pctx.tp_axis, pctx.fsdp_axis, None)  # wo [E, f_l, d]
    in_specs = (xs, xs, xs, wspec_in, wspec_out) + ((wspec_in,) if gated else ())
    y = _shard_map(
        mapped,
        mesh=pctx.mesh,
        in_specs=in_specs,
        out_specs=xs,
    )(x, weights, idx, params["wi"], params["wo"], *extra)
    return y, aux


def moe_apply(
    params, x: Array, cfg, pctx: ParallelCtx, *, impl: str = "ep_a2a", seq_sharded: bool = True
) -> Tuple[Array, Array]:
    if impl == "dense":
        return moe_dense(params, x, cfg)
    return moe_ep(params, x, cfg, pctx, seq_sharded=seq_sharded)
