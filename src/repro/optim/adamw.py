"""AdamW with configurable optimizer-state precision.

No optax in this container — implemented from scratch.  Distributed-
optimization features for 1000+-node training:

* ``state_dtype="int8"`` — block/row-quantised first and second moments
  (8-bit Adam).  At arctic-480b scale this is the difference between the
  optimizer fitting 256 chips (≈15 GB/chip) or not (≈19 GB/chip); see
  EXPERIMENTS.md §Dry-run.  Each moment is stored as int8 with a per-row
  (last-axis) float32 scale; small leaves (<=4096 elems) stay float32.
* decoupled weight decay, global-norm clipping, cosine/linear schedules.

The state pytree mirrors the params pytree per leaf, so the same
PartitionSpec tree shards params, grads, and both moments (scales reuse the
leading-axes spec).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

_QUANT_MIN_SIZE = 4096


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    state_dtype: str = "float32"  # float32 | bfloat16 | int8
    warmup_steps: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"  # cosine | linear | constant


def schedule_lr(cfg: AdamWConfig, step: Array) -> Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (s - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    if cfg.schedule == "cosine":
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - frac
    else:
        decay = 1.0
    return cfg.lr * warm * decay


# ---------------------------------------------------------------------------
# int8 moment quantisation
# ---------------------------------------------------------------------------


def _quantised(p: Array) -> bool:
    return p.size > _QUANT_MIN_SIZE and p.ndim >= 2


def _q_zero(p: Array) -> Dict[str, Array]:
    return {
        "q": jnp.zeros(p.shape, jnp.int8),
        "scale": jnp.zeros(p.shape[:-1], jnp.float32),
    }


def _q_enc(x: Array, *, signed_sqrt: bool = True) -> Dict[str, Array]:
    """Row-wise int8 with signed-sqrt companding: q ~ sign(x) sqrt(|x|).

    The sqrt mapping halves the dynamic range per row — essential for the
    second moment, whose within-row spread otherwise exceeds 8 bits."""
    y = jnp.sign(x) * jnp.sqrt(jnp.abs(x)) if signed_sqrt else x
    amax = jnp.max(jnp.abs(y), axis=-1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(y / scale[..., None]), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)}


def _q_dec(s: Dict[str, Array], *, signed_sqrt: bool = True) -> Array:
    y = s["q"].astype(jnp.float32) * s["scale"][..., None]
    return jnp.sign(y) * y * y if signed_sqrt else y


def _moment_zero(p: Array, dtype: str):
    if dtype == "int8" and _quantised(p):
        return _q_zero(p)
    dt = jnp.bfloat16 if dtype == "bfloat16" else jnp.float32
    return jnp.zeros(p.shape, dt)


def _moment_read(m, p: Array, dtype: str) -> Array:
    if dtype == "int8" and _quantised(p):
        return _q_dec(m)
    return m.astype(jnp.float32)


def _moment_write(x: Array, p: Array, dtype: str):
    if dtype == "int8" and _quantised(p):
        return _q_enc(x)
    dt = jnp.bfloat16 if dtype == "bfloat16" else jnp.float32
    return x.astype(dt)


# ---------------------------------------------------------------------------
# Optimizer API
# ---------------------------------------------------------------------------


def init_opt_state(params, cfg: AdamWConfig):
    return {
        "m": jax.tree.map(lambda p: _moment_zero(p, cfg.state_dtype), params),
        "v": jax.tree.map(lambda p: _moment_zero(p, cfg.state_dtype), params),
        "step": jnp.zeros((), jnp.int32),
    }


def _abstract_quantised(p) -> bool:
    size = 1
    for d in p.shape:
        size *= d
    return size > _QUANT_MIN_SIZE and len(p.shape) >= 2


def abstract_opt_state(abstract_params, cfg: AdamWConfig):
    def f(p):
        if cfg.state_dtype == "int8" and _abstract_quantised(p):
            return {
                "q": jax.ShapeDtypeStruct(p.shape, jnp.int8),
                "scale": jax.ShapeDtypeStruct(p.shape[:-1], jnp.float32),
            }
        dt = jnp.bfloat16 if cfg.state_dtype == "bfloat16" else jnp.float32
        return jax.ShapeDtypeStruct(p.shape, dt)

    return {
        "m": jax.tree.map(f, abstract_params),
        "v": jax.tree.map(f, abstract_params),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def global_norm(tree) -> Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = schedule_lr(cfg, step)
    gn = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9)) if cfg.grad_clip > 0 else 1.0

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd_core(p, g, m, v, *, qstate: bool):
        gf = g.astype(jnp.float32) * clip
        mf = _q_dec(m) if qstate else m.astype(jnp.float32)
        vf = _q_dec(v) if qstate else v.astype(jnp.float32)
        mf = cfg.b1 * mf + (1.0 - cfg.b1) * gf
        vf = cfg.b2 * vf + (1.0 - cfg.b2) * gf * gf
        mhat = mf / b1c
        vhat = vf / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        if qstate:
            return new_p, _q_enc(mf), _q_enc(vf)
        return new_p, mf.astype(m.dtype), vf.astype(v.dtype)

    def upd(p, g, m, v):
        qstate = cfg.state_dtype == "int8" and _quantised(p)
        from repro.models import layers as _L

        if p.ndim >= 3 and p.shape[0] > 1 and not _L.EXACT_FLOPS_MODE:
            # layer-stacked leaf: chunk the fp32 update over dim 0 so the
            # dequant/update/requant transients are one layer, not the stack
            def body(_, xs):
                return None, upd_core(*xs, qstate=qstate)

            _, (np_, nm, nv) = jax.lax.scan(body, None, (p, g, m, v))
            return np_, nm, nv
        return upd_core(p, g, m, v, qstate=qstate)

    is_q = lambda x: isinstance(x, dict) and set(x) == {"q", "scale"}
    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = jax.tree.flatten(state["m"], is_leaf=is_q)[0]
    flat_v = jax.tree.flatten(state["v"], is_leaf=is_q)[0]
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gn, "lr": lr}


def opt_state_pspecs(param_pspecs_tree, abstract_params, cfg: AdamWConfig):
    """PartitionSpec tree for the optimizer state (parallel to init_opt_state)."""
    from jax.sharding import PartitionSpec as P

    def g(spec, p):
        if cfg.state_dtype == "int8" and _abstract_quantised(p):
            sub = tuple(spec)[: len(p.shape) - 1]
            return {"q": spec, "scale": P(*sub)}
        return spec

    is_spec = lambda x: isinstance(x, P)
    m = jax.tree.map(g, param_pspecs_tree, abstract_params, is_leaf=is_spec)
    return {"m": m, "v": m, "step": P()}
