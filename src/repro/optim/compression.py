"""Error-feedback int8 gradient compression.

For 1000+-node runs the cross-pod gradient all-reduce crosses DCN links an
order of magnitude slower than ICI.  Quantising gradients to int8 with an
error-feedback (EF) residual keeps the *optimisation trajectory* unbiased
(the residual re-injects quantisation error on the next step — Karimireddy
et al., "Error Feedback Fixes SignSGD").

``compress_decompress`` is the quantise->dequantise round trip applied to
the (already reduced) gradients inside ``train_step``; on real hardware the
int8 payload is what crosses the DCN link (the wire format is the ``q`` +
per-row ``scale`` pair, 4.06x smaller than fp32, 2.03x smaller than bf16).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def init_ef_state(params):
    """Zero error-feedback residuals (same shapes as grads, float32)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)


def _q_roundtrip(x: Array) -> Array:
    if x.ndim < 2 or x.size <= 4096:
        return x  # small leaves pass through uncompressed
    amax = jnp.max(jnp.abs(x), axis=-1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale[..., None]), -127, 127)
    return q * scale[..., None]


def compress_decompress(grads, ef_state) -> Tuple[Any, Any]:
    """EF-int8 round trip: returns (compressed grads, new EF residuals)."""

    def f(g, e):
        gf = g.astype(jnp.float32) + e.astype(jnp.float32)
        c = _q_roundtrip(gf)
        return c.astype(g.dtype), (gf - c).astype(e.dtype)

    out = jax.tree.map(f, grads, ef_state)
    comp = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_ef = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return comp, new_ef


def compressed_bytes_ratio() -> float:
    """Wire-format size vs bf16: int8 payload + 1/row scale ~= 0.51."""
    return 0.51
