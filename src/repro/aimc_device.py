"""First-class AIMC device state: the programmed-PCM lifecycle as a pytree.

Before this module, "programmed hardware" was a loose ``{"hw": {...}}``
dict convention that only the reference backend's dense ``jnp`` simulation
understood — the integer and pallas backends silently fell back to ideal
quantised weights, and nothing in the system modelled *when* the inference
happens.  :class:`AIMCDeviceState` makes the device a first-class citizen:

* **program** — quantise float weights to 5-bit differential-pair levels
  (Table II), freeze per-device programming error and drift exponents, set
  the device clock to t = 0;
* **drift_to** — advance the device clock: conductances decay as
  ``G(t) = G0 * (t / t0) ** -nu`` (Joshi et al. 2020) and the *digital
  execution image* (``levels_t`` — the drifted conductances as the ADC
  re-quantises them) is refreshed.  A pure pytree -> pytree update: shapes
  and dtypes never change, so jitted consumers (the serving
  ``decode_step``) are **not recompiled**;
* **recalibrate** — global drift compensation (GDC, paper §V-B): read the
  calibration column sums through the crossbar at the current t and fold
  the measured gain into the per-column scales.  Between recalibrations
  the gain is *stale* — that is exactly the accuracy-vs-time behaviour of
  Fig. 7 / Table V, and what a long-running server periodically repairs.

Execution semantics per backend (see ``repro.engine``):

* ``reference`` — full analog simulation (:func:`analog_matmul`): per-device
  drift, read noise, shared-ADC quantisation, stale GDC gain;
* ``integer`` / ``pallas`` — the digital datapath: an int8 MXU matmul over
  ``levels_t`` times the per-column f32 :attr:`AIMCDeviceState.eff_scale`.
  Drift + GDC are folded into those two operands at ``drift_to`` /
  ``recalibrate`` time, so the hot loop stays a plain int8 matmul and the
  two backends remain bit-identical.

This module is also the single source of truth for Table-II weight
quantisation (:func:`quantize_weights`) — the engine backends, HWAT and
programming all share it.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import aimc as AM
from repro.core.aimc import AIMCConfig

Array = jax.Array


# ---------------------------------------------------------------------------
# Table-II quantisation (single source of truth)
# ---------------------------------------------------------------------------


def quantize_weights(w: Array, cfg: AIMCConfig) -> Tuple[Array, Array]:
    """Float weights ``[..., d_in, d_out]`` -> (integer levels, column scale).

    The one entry point for Table-II weight quantisation, shared by the
    engine backends' on-the-fly path, HWAT's noisy forward and PCM
    programming — a thin composition of the rank-generic core helpers
    (per-column max-abs maps to ``cfg.levels``; every leading axis, e.g. a
    stacked layer-period axis, quantises independently)."""
    scale = AM.column_scale(w, cfg).astype(jnp.float32)
    return AM.quantize_levels(w, scale, cfg), scale


def _drift_factor(nu: Array, t_seconds: Array, cfg: AIMCConfig) -> Array:
    """``(max(t, t0) / t0) ** -nu``, written as exp/log so the Pallas
    requantise kernel and the jnp oracle evaluate the identical op sequence
    (bit-exactness of the fold is part of the kernel contract)."""
    t = jnp.maximum(jnp.asarray(t_seconds, jnp.float32), cfg.drift_t0_s)
    return jnp.exp(-nu * jnp.log(t / cfg.drift_t0_s))


def image_gain(cfg: AIMCConfig) -> int:
    """Integer gain of the digital execution image.

    The *programming* grid is 5-bit (±``cfg.levels``), but the int8 MXU
    operand has head-room to spare — re-digitising the drifted
    conductances at the finest integer gain that cannot saturate (levels
    plus 4 sigma of programming error) keeps the fold's rounding error
    ~``image_gain``x smaller than re-using the programming grid, which is
    what lets GDC recover most of the drift-induced error."""
    return max(int(127.0 // (cfg.levels * (1.0 + 4.0 * cfg.prog_noise_sigma))), 1)


# ---------------------------------------------------------------------------
# The device state pytree
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AIMCDeviceState:
    """Programmed PCM crossbar state for one weight matrix ``[..., d_in, d_out]``.

    Immutable programming record (set once by :func:`program`):

    levels    — ideal integer conductance-pair levels (f32-held ints)
    eps       — programming error in level units, frozen at program time
    nu        — per-device drift exponents
    scale     — per-column float scale ``[..., d_out]``

    Mutable lifecycle leaves (updated by :func:`drift_to` / :func:`recalibrate`;
    same shapes/dtypes forever, so updates never trigger recompilation):

    t_seconds — device clock ``[...]`` (seconds since programming)
    gdc_gain  — global drift-compensation gain ``[...]`` measured at the
                last recalibration (1.0 at program time; *stale* until the
                next recalibration)
    levels_t  — int8 drifted-and-requantised levels on the *image grid*
                (programming grid x :func:`image_gain`): the digital
                execution image of the analog array at ``t_seconds``,
                consumed directly by the int8 MXU matmul
    img_inv   — ``1 / image_gain`` ``[...]``, folded into
                :attr:`eff_scale` so the image grid is transparent to
                consumers

    Leading axes are free: a layer-scanned stack programs as one state whose
    leaves all carry the stack axis, so ``lax.scan`` slices it like any
    other parameter leaf.
    """

    levels: Array
    eps: Array
    nu: Array
    scale: Array
    t_seconds: Array
    gdc_gain: Array
    levels_t: Array
    img_inv: Array

    @property
    def eff_scale(self) -> Array:
        """Per-column f32 scale with the GDC gain and the image-grid gain
        folded in — the second operand of the digital programmed-state
        matmul."""
        return (self.scale * (self.gdc_gain * self.img_inv)[..., None]
                ).astype(jnp.float32)

    @property
    def analog_scale(self) -> Array:
        """Per-column scale for the *analog* path (programming-grid level
        units): programmed scale x stale GDC gain, no image-grid factor."""
        return (self.scale * self.gdc_gain[..., None]).astype(jnp.float32)

    @property
    def shape(self) -> Tuple[int, ...]:
        return self.levels.shape


jax.tree_util.register_pytree_node(
    AIMCDeviceState,
    lambda s: ((s.levels, s.eps, s.nu, s.scale, s.t_seconds, s.gdc_gain,
                s.levels_t, s.img_inv), None),
    lambda _, c: AIMCDeviceState(*c),
)


def _requantize(levels: Array, eps: Array, nu: Array, t_seconds: Array,
                cfg: AIMCConfig, img_gain) -> Array:
    """Drifted conductances re-digitised onto the int8 image grid.

    ``round((levels + eps) * drift * img_gain)`` is what a calibration
    read through the shared ADC digitises the drifted array to — the
    digital execution image at time t, at the full int8 resolution.
    ``img_gain`` is the grid chosen at *program* time (scalar, or a
    per-matrix array broadcastable over the trailing two axes): the image
    grid is a physical property of the programmed array, never re-derived
    from a caller's cfg."""
    g = (levels + eps) * _drift_factor(nu, t_seconds[..., None, None], cfg)
    g = g * img_gain
    return jnp.clip(jnp.round(g), -127, 127).astype(jnp.int8)


def program(key: Array, w: Array, cfg: AIMCConfig) -> AIMCDeviceState:
    """Program float weights ``[..., d_in, d_out]`` onto simulated PCM.

    Quantises (Table II), samples the frozen programming error and
    per-device drift exponents, and sets the device clock to t = 0 with a
    unit GDC gain."""
    k1, k2 = jax.random.split(key)
    levels, scale = quantize_weights(w, cfg)
    levels = levels.astype(jnp.float32)
    eps = cfg.prog_noise_sigma * cfg.levels * jax.random.normal(
        k1, w.shape, jnp.float32)
    nu = cfg.drift_nu_mean + cfg.drift_nu_sigma * jax.random.normal(
        k2, w.shape, jnp.float32)
    nu = jnp.maximum(nu, 0.0)
    lead = w.shape[:-2]
    t0 = jnp.zeros(lead, jnp.float32)
    gain = jnp.ones(lead, jnp.float32)
    return AIMCDeviceState(
        levels=levels, eps=eps, nu=nu, scale=scale, t_seconds=t0,
        gdc_gain=gain,
        levels_t=_requantize(levels, eps, nu, t0, cfg,
                             float(image_gain(cfg))),
        img_inv=jnp.full(lead, 1.0 / image_gain(cfg), jnp.float32),
    )


def drift_to(state: AIMCDeviceState, t_seconds, cfg: AIMCConfig,
             ) -> AIMCDeviceState:
    """Advance the device clock to ``t_seconds`` (absolute, since program).

    Refreshes the digital execution image ``levels_t``; does **not** touch
    the GDC gain — compensation only moves at :func:`recalibrate`.  Pure
    pytree -> pytree with unchanged shapes/dtypes (no recompilation)."""
    t = jnp.broadcast_to(jnp.asarray(t_seconds, jnp.float32),
                         state.t_seconds.shape)
    # the image grid is frozen at program time: recover it from the state
    # (round repairs fp32 reciprocal error, e.g. 1/7), never from `cfg` —
    # a drift policy built with a different AIMCConfig must not re-image
    # the array on a different grid
    img_gain = jnp.round(1.0 / state.img_inv)[..., None, None]
    return dataclasses.replace(
        state, t_seconds=t,
        levels_t=_requantize(state.levels, state.eps, state.nu, t, cfg,
                             img_gain),
    )


def recalibrate(state: AIMCDeviceState, cfg: AIMCConfig) -> AIMCDeviceState:
    """Global drift compensation (paper §V-B) at the current device time.

    Hardware reads the summed absolute conductance with a calibration input
    at t and rescales by ``sum |G(t_program)| / sum |G(t)|`` — one scalar
    per crossbar ('global', not per-device).  The measured gain is folded
    into :attr:`AIMCDeviceState.eff_scale` until the next recalibration.

    The calibration read goes through the shared ADC, so both sums are
    taken over the *digitised image* of the array (the int8 image grid) and
    accumulated as integers.  Integer accumulation is associativity-free:
    a mesh-sharded crossbar (``repro.distributed``) psums per-shard partial
    reads and measures bit-identically to the single-device oracle — the
    analog float sum would differ in the last ulp under a partitioned
    reduction and break sharded-vs-single-device bit-exactness.

    Both images are recomputed from the frozen programming record rather
    than trusting ``levels_t`` (which a caller may not have refreshed to
    the current clock) — recalibration is a rare event, so the two extra
    O(d_in*d_out) folds buy robustness over a cached-sum micro-win."""
    img_gain = jnp.round(1.0 / state.img_inv)[..., None, None]
    img0 = _requantize(state.levels, state.eps, state.nu,
                       jnp.zeros_like(state.t_seconds), cfg, img_gain)
    imgt = _requantize(state.levels, state.eps, state.nu, state.t_seconds,
                       cfg, img_gain)
    g0 = jnp.sum(jnp.abs(img0.astype(jnp.int32)), axis=(-2, -1))
    gt = jnp.sum(jnp.abs(imgt.astype(jnp.int32)), axis=(-2, -1))
    gain = g0.astype(jnp.float32) / jnp.maximum(gt, 1).astype(jnp.float32)
    return dataclasses.replace(state, gdc_gain=gain)


# ---------------------------------------------------------------------------
# Analog execution (reference backend)
# ---------------------------------------------------------------------------


def analog_matmul(key: Optional[Array], x: Array, state: AIMCDeviceState,
                  cfg: AIMCConfig) -> Array:
    """``x [..., d_in] @ W`` through the full analog crossbar simulation.

    Row-block-wise mapping with shared-ADC quantisation and optional read
    noise (``key``), per-device drift at the state's ``t_seconds``, and the
    *stored* (possibly stale) GDC gain — the lifecycle-aware counterpart of
    ``core.aimc.aimc_matmul``.  2-D states only (the per-matrix view that
    model layers hand to the backends)."""
    assert state.levels.ndim == 2, "analog_matmul executes one crossbar array"
    d_in, d_out = state.levels.shape
    df = _drift_factor(state.nu, state.t_seconds, cfg)
    g = (state.levels + state.eps) * df  # level units, drifted
    rows = cfg.crossbar_rows
    pad = (-d_in) % rows
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
        g = jnp.pad(g, [(0, pad), (0, 0)])
    nb = g.shape[0] // rows
    xb = x.reshape(*x.shape[:-1], nb, rows)
    gb = g.reshape(nb, rows, d_out)
    partial = jnp.einsum("...br,brd->...bd", xb.astype(jnp.float32), gb)
    if key is not None and cfg.read_noise_sigma > 0:
        partial = partial + cfg.read_noise_sigma * cfg.levels * jax.random.normal(
            key, partial.shape, jnp.float32)
    partial = AM._adc(partial, cfg)
    out = jnp.sum(partial, axis=-2)  # exact digital accumulation (CSA)
    return out * state.analog_scale


# ---------------------------------------------------------------------------
# Tree-level lifecycle (whole-model params)
# ---------------------------------------------------------------------------


def _is_state(x: Any) -> bool:
    return isinstance(x, AIMCDeviceState)


def is_programmed(tree: Any) -> bool:
    """True if any leaf of ``tree`` is an :class:`AIMCDeviceState` (or a
    legacy ``{"hw": {...}}`` programmed dict)."""
    found = False

    def visit(x):
        nonlocal found
        if _is_state(x):
            found = True
            return True
        if isinstance(x, dict) and "hw" in x:
            found = True
            return True
        return False

    jax.tree.flatten(tree, is_leaf=visit)
    return found


def has_device_state(tree: Any) -> bool:
    """True if any leaf is an :class:`AIMCDeviceState` proper.

    Stricter than :func:`is_programmed`: legacy ``{"hw": {...}}`` dicts
    count as programmed (they must not be re-programmed) but carry no
    device clock — the drift/recalibration lifecycle cannot act on them."""
    found = False

    def visit(x):
        nonlocal found
        if _is_state(x):
            found = True
            return True
        return False

    jax.tree.flatten(tree, is_leaf=visit)
    return found


def program_tree(key: Array, params: Any, cfg: AIMCConfig) -> Any:
    """Replace every ``{"w", "b"}`` linear leaf by its programmed state.

    The paper-model (ViT/GPT) programming path; raises if the tree already
    holds programmed state — programming is a one-shot physical act, and
    double-programming used to silently re-wrap leaves."""
    if is_programmed(params):
        raise ValueError(
            "params are already programmed onto PCM (AIMCDeviceState leaves "
            "present); program once, then use drift_to()/recalibrate() for "
            "the device lifecycle"
        )

    def is_lin(x):
        return isinstance(x, dict) and "w" in x and "b" in x

    leaves, treedef = jax.tree.flatten(params, is_leaf=is_lin)
    keys = jax.random.split(key, max(len(leaves), 1))
    out = []
    for leaf, k in zip(leaves, keys):
        if is_lin(leaf):
            out.append({"hw": program(k, leaf["w"], cfg), "b": leaf["b"]})
        else:
            out.append(leaf)
    return jax.tree.unflatten(treedef, out)


def _matrix_view(name: str, w: Array) -> Array:
    """Collapse a structured linear weight to its ``[..., d_in, d_out]``
    crossbar view (the LM stack stores attention weights per-head)."""
    if name in ("wq", "wk", "wv"):  # [..., d, h, hd] -> [..., d, h*hd]
        return w.reshape(*w.shape[:-2], w.shape[-2] * w.shape[-1])
    if name == "wo" and w.ndim >= 3:  # [..., h, hd, d] -> [..., h*hd, d]
        return w.reshape(*w.shape[:-3], w.shape[-3] * w.shape[-2], w.shape[-1])
    return w


def program_lm_tree(key: Array, params: Any, cfg: AIMCConfig) -> Any:
    """Program the generic LM stack's spiking-linear weights onto PCM.

    Walks the ``periods`` / ``remainder`` block subtrees and replaces the
    weights the spiking path executes through ``backend.spiking_linear`` —
    attention ``wq/wk/wv/wo`` and MLP ``wi/wo`` — by
    :class:`AIMCDeviceState` (stacked period leaves keep their leading layer
    axis, so ``lax.scan`` slices them like any array leaf).  Norms, embed /
    unembed and MoE routing stay digital, matching the paper's split (AIMC
    for feed-forward and fully-connected layers only)."""
    if is_programmed(params):
        raise ValueError(
            "params are already programmed onto PCM; program once, then use "
            "drift_to()/recalibrate()"
        )
    params = dict(params)
    _n = [0]

    def next_key():
        k = jax.random.fold_in(key, _n[0])
        _n[0] += 1
        return k

    def prog_block(blk):
        blk = dict(blk)
        mix = blk.get("mixer")
        if isinstance(mix, dict) and {"wq", "wk", "wv", "wo"} <= set(mix):
            mix = dict(mix)
            for name in ("wq", "wk", "wv", "wo"):
                mix[name] = program(
                    next_key(), _matrix_view(name, mix[name]).astype(jnp.float32),
                    cfg)
            blk["mixer"] = mix
        mlp = blk.get("mlp")
        if isinstance(mlp, dict) and {"wi", "wo"} <= set(mlp):
            mlp = dict(mlp)
            for name in ("wi", "wo"):
                mlp[name] = program(next_key(), mlp[name].astype(jnp.float32), cfg)
            blk["mlp"] = mlp
        return blk

    for group in ("periods", "remainder"):
        if group in params:
            params[group] = {
                bk: prog_block(bv) for bk, bv in params[group].items()
            }
    return params


def _map_states(fn, tree: Any) -> Any:
    return jax.tree.map(
        lambda x: fn(x) if _is_state(x) else x, tree, is_leaf=_is_state)


def drift_tree(params: Any, t_seconds, cfg: AIMCConfig) -> Any:
    """Advance every device state in a param tree to ``t_seconds``."""
    return _map_states(lambda s: drift_to(s, t_seconds, cfg), params)


def recalibrate_tree(params: Any, cfg: AIMCConfig) -> Any:
    """GDC-recalibrate every device state in a param tree (at its own t)."""
    return _map_states(lambda s: recalibrate(s, cfg), params)


def device_time(params: Any) -> float:
    """Max device-clock value across a tree (0.0 if nothing is programmed)."""
    ts = [
        float(jnp.max(leaf.t_seconds))
        for leaf in jax.tree.leaves(params, is_leaf=_is_state) if _is_state(leaf)
    ]
    return max(ts) if ts else 0.0


def gdc_gain_summary(params: Any) -> float:
    """Mean GDC gain across every programmed crossbar in a tree (1.0 if
    nothing is programmed).

    The serving telemetry reads this once per recalibration event — the
    post-recal gain is the live health signal of the drift lifecycle: it
    climbs between recalibrations exactly as the conductances decay and
    snaps toward the drift-compensation factor when GDC runs.  One small
    host read per (rare) recal, never on the decode hot path."""
    gains = [
        float(jnp.mean(leaf.gdc_gain))
        for leaf in jax.tree.leaves(params, is_leaf=_is_state) if _is_state(leaf)
    ]
    return sum(gains) / len(gains) if gains else 1.0


# ---------------------------------------------------------------------------
# Serving drift policy
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DriftPolicy:
    """How a long-running server advances PCM device time (drift lifecycle).

    seconds_per_step — fixed device-time advance per batched decode step;
                       0.0 means "use wall clock" (each decode step adds its
                       measured wall duration x ``time_scale``).  Fixed
                       steps make soak tests and replays deterministic.
    time_scale       — device seconds per wall-clock second (accelerated
                       aging for studies; 1.0 = real time).
    recal_interval_s — run GDC recalibration whenever this much device time
                       has passed since the last one; 0.0 disables
                       periodic recalibration (drift accumulates forever —
                       the paper's "without GDC" rows).
    cfg              — the AIMC configuration (Table II) for the updates.
    """

    seconds_per_step: float = 0.0
    time_scale: float = 1.0
    recal_interval_s: float = 0.0
    cfg: AIMCConfig = dataclasses.field(default_factory=AIMCConfig)


# jitted tree updates for the serving hot loop: t is traced, so advancing
# the clock re-uses one compiled update per param treedef (no recompiles)
drift_tree_jit = jax.jit(drift_tree, static_argnames=("cfg",))
recalibrate_tree_jit = jax.jit(recalibrate_tree, static_argnames=("cfg",))
