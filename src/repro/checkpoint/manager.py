"""Fault-tolerant checkpointing: atomic, async, retention, elastic restore.

Layout:  <dir>/step_<k>/            (atomic: written as .tmp then renamed)
            manifest.json           tree structure, shapes, dtypes, step
            leaf_00000.npy ...      one file per leaf (ml_dtypes handles
                                    bfloat16 round-trip)
         <dir>/LATEST               text file with the newest step

Design points for 1000+-node runs:

* **Atomicity** — a crash mid-write never corrupts a restorable state: the
  rename is the commit point, LATEST is updated after.
* **Async** — ``save()`` device_get's the state (cheap, snapshots values)
  and hands serialisation to a background thread; the train loop keeps
  stepping.  ``wait()`` joins before exit.
* **Elastic restore** — leaves are saved *unsharded* (gathered); restore
  ``device_put``s them with the **target** mesh's shardings, so restoring
  onto a different mesh shape (scale up/down) or a different parallelism
  layout needs no conversion step.  ``launch/elastic.py`` computes the new
  spec tree.
* **Retention** — keep the newest ``keep`` checkpoints, delete older ones
  only after a successful commit (never delete the last good state).
"""

from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

Array = jax.Array

_NATIVE_KINDS = set("biufc")


def _np_dtype(name: str) -> np.dtype:
    try:
        dt = np.dtype(name)
        if dt.kind in _NATIVE_KINDS:
            return dt
    except TypeError:
        pass
    return np.dtype(getattr(ml_dtypes, name))  # bfloat16, float8_*, ...


def _save_leaf(path: Path, arr: np.ndarray) -> None:
    if arr.dtype.kind in _NATIVE_KINDS:
        np.save(path, arr, allow_pickle=False)
    else:  # ml_dtypes custom dtype: store raw bytes, dtype lives in manifest
        np.save(path, np.frombuffer(arr.tobytes(), np.uint8), allow_pickle=False)


def _load_leaf(path: Path, shape, dtype_name: str) -> np.ndarray:
    raw = np.load(path, allow_pickle=False)
    dt = _np_dtype(dtype_name)
    if raw.dtype == np.uint8 and dt.kind not in _NATIVE_KINDS:
        return raw.view(dt).reshape(shape)
    return raw


def _tree_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(p), v) for p, v in flat]


class CheckpointManager:
    def __init__(self, directory: str | Path, *, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, state, *, blocking: bool = False) -> None:
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        self.wait()
        t = threading.Thread(target=self._guarded_write, args=(step, host_state),
                             daemon=True)
        t.start()
        self._thread = t
        if blocking:
            self.wait()

    def wait(self) -> None:
        """Join the in-flight save; re-raises any exception it hit (a
        silently dropped checkpoint is worse than a crashed train loop)."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _guarded_write(self, step: int, host_state) -> None:
        try:
            self._write(step, host_state)
        except BaseException as e:  # surfaced by the next wait()/save()
            self._error = e

    @staticmethod
    def _treedef_hex(host_state) -> Optional[str]:
        """Proto-serialized treedef, or None for trees with user-defined
        pytree nodes (e.g. AIMCDeviceState), which the proto can't encode.
        The manifest treedef is informational — restore() rebuilds the
        structure from ``state_like`` — so a None here must not fail the
        save."""
        try:
            return jax.tree_util.tree_structure(
                host_state).serialize_using_proto().hex()
        except (ValueError, TypeError):
            return None

    def _write(self, step: int, host_state) -> None:
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f".tmp_step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        leaves, treedef = jax.tree.flatten(host_state)
        manifest = {
            "step": step,
            "treedef": self._treedef_hex(host_state),
            "leaves": [],
        }
        for i, leaf in enumerate(leaves):
            arr = np.asarray(leaf)
            _save_leaf(tmp / f"leaf_{i:05d}.npy", arr)
            manifest["leaves"].append({"shape": list(arr.shape), "dtype": str(arr.dtype)})
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)  # commit point
        (self.dir / "LATEST").write_text(str(step))
        self._gc(step)

    def _gc(self, newest: int) -> None:
        steps = sorted(self.steps())
        for s in steps[: max(len(steps) - self.keep, 0)]:
            if s != newest:
                shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ---------------------------------------------------------- restore
    def steps(self) -> List[int]:
        return [
            int(p.name.split("_")[1])
            for p in self.dir.glob("step_*")
            if (p / "manifest.json").exists()
        ]

    def latest_step(self) -> Optional[int]:
        marker = self.dir / "LATEST"
        if marker.exists():
            s = int(marker.read_text().strip())
            if (self.dir / f"step_{s:08d}" / "manifest.json").exists():
                return s
        steps = self.steps()
        return max(steps) if steps else None

    def restore(self, state_like, *, step: Optional[int] = None, shardings=None):
        """Restore into the structure of ``state_like``; if ``shardings`` is
        given (pytree of NamedSharding, possibly for a NEW mesh), leaves are
        device_put with it — this is the elastic-resharding path."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        src = self.dir / f"step_{step:08d}"
        manifest = json.loads((src / "manifest.json").read_text())
        _, treedef = jax.tree.flatten(state_like)
        leaves = []
        for i, meta in enumerate(manifest["leaves"]):
            leaves.append(
                _load_leaf(src / f"leaf_{i:05d}.npy", tuple(meta["shape"]), meta["dtype"])
            )
        state = jax.tree.unflatten(treedef, leaves)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s) if s is not None else jax.device_put(x),
                state,
                shardings,
            )
        else:
            state = jax.tree.map(jax.device_put, state)
        return state, step
