"""Block-paged spike-train KV cache: paged serving == dense serving, bitwise.

The paged-serving contracts (see ``repro/serving``):

* **paged == dense, bit-exact** — a full ``BatchScheduler`` run off the
  block-paged pool (chunked prefill riding the batched step, page
  allocation at block boundaries, copy-on-write off shared pages,
  admissions and evictions mid-flight) decodes exactly the tokens of the
  dense single-device integer oracle, on every bit-exact substrate.
* **exact prefix reuse** — prefill PRN streams are content-keyed
  (``state.content_keys``), so identical prompt prefixes produce
  bit-identical spike trains; the prefix cache maps them onto the *same
  physical pages* and the skipped prefill provably changes nothing about
  the generated tokens.
* **page accounting** — refcounts, reservations, the LRU prefix cache and
  copy-on-write never leak or double-free pages; admission blocks on free
  pages, not free slots.  A pure-Python oracle scheduler replays random
  submit/step/evict/preempt traces and must agree with the real scheduler
  on slot occupancy, page refcounts, completion sets and ``ServeStats``
  token accounting at every step.
* **drift + GDC** — programmed-PCM execution (drifted and recalibrated
  device state) serves identically paged and dense, and the drift policy
  lifecycle never recompiles the single jitted paged step.
"""

import numpy as np

import jax
import jax.numpy as jnp
import pytest

from repro import aimc_device as AD
from repro.configs.registry import reduced_config
from repro.engine import IntegerBackend, get_backend
from repro.models import transformer as T
from repro.serving import BatchScheduler

SPIKING = "xpikeformer-gpt-4-256"


@pytest.fixture(scope="module")
def spiking_setup():
    cfg = reduced_config(SPIKING)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompt(i, length):
    return list(range(3 + i, 3 + i + length))


def _run(sch, prompts, max_new, seed0=100):
    rids = [sch.submit(p, max_new, seed=seed0 + i)
            for i, p in enumerate(prompts)]
    outs = sch.run()
    return [outs[r] for r in rids]


# ---------------------------------------------------------------------------
# Paged == dense (the acceptance criterion)
# ---------------------------------------------------------------------------


def test_paged_matches_dense_full_run(spiking_setup, engine_backend):
    """Ragged prompts through fewer slots than requests — admissions,
    evictions and chunked prefill all engaged — decode the dense
    scheduler's exact tokens on the CI-matrix backend."""
    cfg, params = spiking_setup
    be = get_backend(engine_backend)
    prompts = [_prompt(i, 3 + (3 * i) % 7) for i in range(5)]
    dense = BatchScheduler(params, cfg, be, slots=2, cache_len=32)
    ref = _run(dense, prompts, 5)
    paged = BatchScheduler(params, cfg, be, slots=2, cache_len=32,
                           paged=True, page_len=8)
    got = _run(paged, prompts, 5)
    if be.bit_exact:  # integer/pallas: exact; reference floats may
        assert got == ref, "paged serving diverged from the dense scheduler"
    else:  # reassociate across the different prefill batch shapes
        assert [len(o) for o in got] == [len(o) for o in ref]
        assert all(0 <= t < cfg.vocab_size for o in got for t in o)
    assert paged.stats.admissions == 5 and paged.stats.evictions == 5
    assert paged.stats.prefill_tokens == sum(len(p) - 1 for p in prompts)
    assert paged._decode._cache_size() == 1, "paged decode_step recompiled"


def test_paged_pallas_bit_exact_vs_dense_integer_oracle(spiking_setup):
    """The paged popcount kernel path (scalar-prefetch page gathering)
    serves bit-identically to the *dense integer oracle* through the whole
    scheduler — kernels, paging and scheduling all in the loop."""
    from repro.engine import PallasBackend

    cfg, params = spiking_setup
    prompts = [_prompt(i, 4 + i) for i in range(4)]
    ref = _run(BatchScheduler(params, cfg, IntegerBackend(), slots=2,
                              cache_len=32), prompts, 4)
    got = _run(BatchScheduler(params, cfg, PallasBackend(), slots=2,
                              cache_len=32, paged=True, page_len=8),
               prompts, 4)
    assert got == ref


def test_prefix_cache_hit_is_exact_and_skips_prefill(spiking_setup,
                                                     engine_backend):
    """A second request with an identical prompt verifiably hits the
    prefix cache (full blocks + the partial tail), skips its whole-context
    prefill, and still generates exactly the dense scheduler's tokens."""
    cfg, params = spiking_setup
    be = get_backend(engine_backend)
    shared = _prompt(7, 17)  # n_ctx=16: two full 8-blocks at page_len=8
    dense = BatchScheduler(params, cfg, be, slots=2, cache_len=32)
    paged = BatchScheduler(params, cfg, be, slots=2, cache_len=32,
                           paged=True, page_len=8)
    ref1 = _run(dense, [shared], 4, seed0=1)
    dense.reset()
    ref2 = _run(dense, [shared], 4, seed0=2)

    got1 = _run(paged, [shared], 4, seed0=1)  # cold: fills + registers pages
    assert paged.stats.prefix_hit_tokens == 0
    got2 = _run(paged, [shared], 4, seed0=2)  # warm: same prompt, new seed
    if get_backend(engine_backend).bit_exact:
        assert got1 == ref1 and got2 == ref2
    else:
        assert [len(o) for o in got1 + got2] == [4, 4]
    st = paged.stats
    assert st.prefix_hit_tokens == 16, "second request must reuse all blocks"
    assert st.prefix_hits == 2
    # prefill compute really was skipped: only the cold request prefilled
    assert st.prefill_tokens == 16
    assert paged.pages.prefix_hits == 2 and paged.pages.prefix_misses >= 1


def test_partial_block_hit_triggers_copy_on_write(spiking_setup):
    """A shared *partial* tail block is served copy-on-write: the hitting
    request gets a private copy before its first decode write, the cached
    page stays pristine for future hits, and tokens stay bit-exact."""
    cfg, params = spiking_setup
    shared = _prompt(3, 6)  # n_ctx=5: one partial block at page_len=8
    dense = BatchScheduler(params, cfg, IntegerBackend(), slots=2, cache_len=32)
    paged = BatchScheduler(params, cfg, IntegerBackend(), slots=2,
                           cache_len=32, paged=True, page_len=8)
    refs = []
    for seed0 in (1, 2, 3):
        dense.reset()
        refs.append(_run(dense, [shared], 3, seed0=seed0))
    outs = [_run(paged, [shared], 3, seed0=s) for s in (1, 2, 3)]
    assert outs == refs
    st = paged.stats
    # request 1 CoWs off its own registered tail page; 2 and 3 CoW off the
    # cache's pristine page at admission
    assert st.cow_copies >= 3
    assert st.prefix_hit_tokens == 10  # 5 skipped context tokens, twice


def test_admission_blocks_on_free_pages_not_slots(spiking_setup):
    """With plenty of slots but a tiny pool, admission queues requests on
    page pressure and serves them as pages free — and the served tokens
    still match the dense scheduler."""
    cfg, params = spiking_setup
    prompts = [_prompt(i, 9) for i in range(4)]  # 2 pages per request
    dense = BatchScheduler(params, cfg, IntegerBackend(), slots=4, cache_len=16)
    ref = _run(dense, prompts, 6)
    # 4 slots x 2 blocks would want 8 pages; give the pool 4 usable
    paged = BatchScheduler(params, cfg, IntegerBackend(), slots=4,
                           cache_len=16, paged=True, page_len=8, n_pages=6)
    got = _run(paged, prompts, 6)
    assert got == ref
    st = paged.stats
    assert st.pages_in_use_peak <= 4, "pool over-committed"
    assert st.peak_active_slots < 4, "page pressure should gate admission"
    assert st.admissions == 4, "queued requests must still serve eventually"


def test_failed_admission_zeroes_last_ref_prefix_pages(spiking_setup):
    """Regression: when admission retains prefix-hit pages, then pool
    pressure LRU-drops those very cache entries, the failure path's
    release is the page's LAST ref — it must be zeroed before reuse, or a
    later slot reads phantom stale spike trains through the null-page
    invariant."""
    cfg, params = spiking_setup
    shared = _prompt(7, 17)  # 2 full blocks at page_len=8
    other = _prompt(40, 9)  # 1 block, disjoint tokens
    dense = BatchScheduler(params, cfg, IntegerBackend(), slots=2, cache_len=32)
    ref_b = _run(dense, [shared], 3, seed0=5)
    paged = BatchScheduler(params, cfg, IntegerBackend(), slots=2,
                           cache_len=32, paged=True, page_len=8, n_pages=6)
    _run(paged, [shared], 3, seed0=1)  # registers 2 prefix pages
    assert paged.pages.prefix_len() == 2
    # occupy the pool: 'other' reserves/allocates its 2 pages...
    paged.submit(other, 8, seed=2)
    paged.step()
    # ...then the shared-prefix request can't reserve (hits retained, then
    # the LRU eviction drops exactly the hit entries, freeing nothing)
    rb = paged.submit(shared, 3, seed=5)
    paged.step()
    assert paged.pages.prefix_len() == 0, "pressure must drop LRU entries"
    from repro.serving import NULL_PAGE, RESERVED_PAGES

    occupied = {int(p) for p in paged._table_rows.ravel() if p != NULL_PAGE}
    for leaf in jax.tree.leaves(paged.state.pool):
        arr = np.moveaxis(np.asarray(leaf), -5, 0)
        for pid in range(RESERVED_PAGES, paged.n_pages):
            if pid not in occupied:
                assert arr[pid].sum() == 0, f"freed page {pid} not zeroed"
    outs = paged.run()  # 'other' drains, then the shared request serves
    assert outs[rb] == ref_b[0], "request served off a dirty recycled page"


def test_paged_preemption_requeue_matches_dense(spiking_setup):
    """Mid-flight eviction with requeue (preemption) replays the same way
    paged and dense; the preempted request's pages are released."""
    cfg, params = spiking_setup
    prompts = [_prompt(i, 4 + i) for i in range(3)]

    def run_with_preempt(sch):
        rids = [sch.submit(p, 4, seed=100 + i) for i, p in enumerate(prompts)]
        for _ in range(2):
            sch.step()
        sch.evict(0, requeue=True)
        outs = sch.run()
        return [outs[r] for r in rids]

    ref = run_with_preempt(
        BatchScheduler(params, cfg, IntegerBackend(), slots=2, cache_len=32))
    paged = BatchScheduler(params, cfg, IntegerBackend(), slots=2,
                           cache_len=32, paged=True, page_len=8)
    got = run_with_preempt(paged)
    assert got == ref
    # all slot references released at drain (only cache entries may remain)
    live = paged.pages.refcount[2:]
    assert int(live.sum()) == paged.pages.prefix_len()


def test_engine_serve_paged_api(spiking_setup):
    """engine.serve(paged=True) wires the paged geometry through and
    matches its own dense serve on the integer substrate."""
    from repro.engine import XpikeformerEngine

    cfg, params = spiking_setup
    eng = XpikeformerEngine.from_config(cfg, backend="integer")
    eng.params = params
    prompts = [_prompt(0, 4), _prompt(1, 6)]
    ref, _ = eng.serve(prompts, max_new=4, slots=2, cache_len=32)
    got, st = eng.serve(prompts, max_new=4, slots=2, cache_len=32,
                        paged=True, page_len=8)
    assert got == ref
    assert st.pages_in_use_peak > 0


def test_paged_rejects_unsupported_arch():
    """ANN / recurrent-state archs have no position axis to page."""
    cfg = reduced_config("yi-9b")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="paged serving"):
        BatchScheduler(params, cfg, None, slots=2, cache_len=32, paged=True)


def test_oversized_request_raises_at_submit(spiking_setup):
    cfg, params = spiking_setup
    sch = BatchScheduler(params, cfg, IntegerBackend(), slots=2, cache_len=32,
                         paged=True, page_len=8, n_pages=4)  # 2 usable pages
    with pytest.raises(ValueError, match="could never be admitted"):
        sch.submit(_prompt(0, 17), 8)


# ---------------------------------------------------------------------------
# Programmed PCM: drift + GDC through the paged path
# ---------------------------------------------------------------------------


def _programmed(spiking_setup):
    cfg, params = spiking_setup
    acfg = AD.AIMCConfig(drift_nu_sigma=0.005, prog_noise_sigma=0.01)
    return cfg, AD.program_lm_tree(jax.random.PRNGKey(42), params, acfg), acfg


def test_paged_programmed_drift_gdc_matches_dense(spiking_setup):
    """Programmed-PCM execution — fresh, day-drifted, and drifted+GDC
    device state — serves bit-identically paged and dense (the drift
    lifecycle is a pure param-leaf change, orthogonal to cache layout)."""
    cfg, hw, acfg = _programmed(spiking_setup)
    aged = AD.drift_tree(hw, 86400.0, acfg)
    recal = AD.recalibrate_tree(aged, acfg)
    prompts = [_prompt(i, 4 + i) for i in range(3)]
    for tree in (hw, aged, recal):
        ref = _run(BatchScheduler(tree, cfg, IntegerBackend(), slots=2,
                                  cache_len=32), prompts, 4)
        got = _run(BatchScheduler(tree, cfg, IntegerBackend(), slots=2,
                                  cache_len=32, paged=True, page_len=8),
                   prompts, 4)
        assert got == ref, "programmed paged serving diverged from dense"


def test_paged_drift_policy_soak(spiking_setup, engine_backend):
    """The DriftPolicy lifecycle (clock advance per step, periodic GDC)
    runs through the paged scheduler without recompiling the jitted step
    and keeps serving valid tokens."""
    cfg, hw, acfg = _programmed(spiking_setup)
    pol = AD.DriftPolicy(seconds_per_step=600.0, recal_interval_s=2400.0,
                         cfg=acfg)
    sch = BatchScheduler(hw, cfg, get_backend(engine_backend), slots=2,
                         cache_len=32, drift=pol, paged=True, page_len=8)
    rids = [sch.submit(_prompt(i, 3 + i), 6, seed=10 + i) for i in range(4)]
    outs = sch.run()
    st = sch.stats
    assert all(len(outs[r]) == 6 for r in rids)
    assert all(0 <= t < cfg.vocab_size for r in rids for t in outs[r])
    assert st.t_device_s == 600.0 * st.decode_steps
    assert st.recalibrations >= 2, "periodic GDC must have fired"
    assert sch._decode._cache_size() == 1, \
        "drift lifecycle must not recompile the paged decode_step"


# ---------------------------------------------------------------------------
# Scheduler trace oracle (pure-Python reference bookkeeping)
# ---------------------------------------------------------------------------


class _OraclePage:
    __slots__ = ("ref",)

    def __init__(self):
        self.ref = 1


class OracleScheduler:
    """A pure-Python model of the paged scheduler's *bookkeeping* — no
    jax, no model, no spike math.  It mirrors the admission / chunked
    prefill / eviction state machine and the page economics (reservations,
    refcounts, LRU prefix cache, copy-on-write) from the spec in
    ``repro/serving``, and is replayed op-for-op against the real
    ``BatchScheduler`` to pin the host accounting down."""

    def __init__(self, slots, cache_len, page_len, n_pages):
        self.slots, self.page_len = slots, page_len
        self.max_pages = cache_len // page_len
        self.usable = n_pages - 2  # null + trash
        self.free = self.usable
        self.reserved = 0
        # chained-block key -> (page, chain id | None); insertion = LRU
        self.cache = {}
        self.next_chain = 1
        self.queue = []  # (rid, prompt list, max_new)
        self.slot_req = [None] * slots
        self.table = [[None] * self.max_pages for _ in range(slots)]
        self.phase = ["decode"] * slots
        self.cursor = [0] * slots
        self.pos = [0] * slots
        self.remaining = [0] * slots
        self.slot_reserved = [0] * slots
        self.chain = [0] * slots
        self.done = {}  # rid -> n generated
        self.prefill_tokens = 0
        self.decoded_tokens = 0
        self.admissions = 0
        self.evictions = 0
        self.prefix_hit_tokens = 0

    # -- page economics -------------------------------------------------

    def _alloc(self, slot):
        assert self.free > 0
        self.free -= 1
        self.reserved -= 1
        self.slot_reserved[slot] -= 1
        return _OraclePage()

    def _release(self, page):
        page.ref -= 1
        assert page.ref >= 0
        if page.ref == 0:
            self.free += 1

    def _available(self):
        return self.free - self.reserved

    def _prefix_evict(self, need):
        freed = 0
        while freed < need and self.cache:
            key = next(iter(self.cache))
            page, _ = self.cache.pop(key)
            before = self.free
            self._release(page)
            freed += self.free - before

    # -- ops --------------------------------------------------------------

    def submit(self, rid, prompt, max_new):
        self.queue.append((rid, list(prompt), max_new))

    def admit(self):
        for slot in range(self.slots):
            if not self.queue or self.slot_req[slot] is not None:
                continue
            rid, prompt, max_new = self.queue[0]
            ctx = prompt[:-1]
            n_ctx = len(ctx)
            pl = self.page_len
            total = -(-(n_ctx + max_new) // pl)
            hits = []
            chain = 0
            k = pl
            while k <= n_ctx:
                key = (chain, tuple(ctx[k - pl:k]))
                ent = self.cache.get(key)
                if ent is None:
                    break
                self.cache[key] = self.cache.pop(key)  # LRU refresh
                ent[0].ref += 1
                hits.append(ent[0])
                chain = ent[1]
                k += pl
            partial = None
            if len(hits) == n_ctx // pl and n_ctx % pl:
                key = (chain, tuple(ctx[len(hits) * pl:]))
                ent = self.cache.get(key)
                if ent is not None:
                    self.cache[key] = self.cache.pop(key)
                    ent[0].ref += 1
                    partial = ent[0]
            needed = total - len(hits)
            if self._available() < needed:
                self._prefix_evict(needed - self._available())
            if self._available() < needed:
                for p in hits:
                    self._release(p)
                if partial is not None:
                    self._release(partial)
                return
            self.queue.pop(0)
            self.reserved += needed
            self.slot_reserved[slot] = needed
            row = [None] * self.max_pages
            for j, p in enumerate(hits):
                row[j] = p
            cursor = len(hits) * pl
            if partial is not None:
                row[n_ctx // pl] = partial
                cursor = n_ctx
            self.table[slot] = row
            self.slot_req[slot] = (rid, prompt, max_new)
            self.phase[slot] = "prefill" if cursor < n_ctx else "handoff"
            self.cursor[slot] = cursor
            self.pos[slot] = cursor
            self.remaining[slot] = max_new
            self.chain[slot] = chain
            self.done[rid] = 0
            self.prefix_hit_tokens += cursor
            self.admissions += 1

    def evict(self, slot, requeue=False):
        rid, prompt, max_new = self.slot_req[slot]
        if requeue:
            self.queue.insert(0, (rid, prompt, max_new))
            self.done.pop(rid, None)
        for page in self.table[slot]:
            if page is not None:
                self._release(page)
        self.reserved -= self.slot_reserved[slot]
        self.slot_reserved[slot] = 0
        self.table[slot] = [None] * self.max_pages
        self.slot_req[slot] = None
        self.phase[slot] = "decode"
        self.pos[slot] = self.cursor[slot] = self.remaining[slot] = 0
        self.chain[slot] = 0
        self.evictions += 1

    def step(self):
        self.admit()
        if not any(r is not None for r in self.slot_req):
            return
        for slot in range(self.slots):
            if self.slot_req[slot] is None:
                continue
            tp = self.pos[slot] // self.page_len
            off = self.pos[slot] % self.page_len
            page = self.table[slot][tp]
            if page is None:
                self.table[slot][tp] = self._alloc(slot)
            elif page.ref > 1:  # copy-on-write
                self.table[slot][tp] = self._alloc(slot)
                self._release(page)
        for slot in range(self.slots):
            req = self.slot_req[slot]
            if req is None:
                continue
            rid, prompt, max_new = req
            ctx = prompt[:-1]
            self.pos[slot] += 1
            if self.phase[slot] == "prefill":
                self.cursor[slot] += 1
                cur = self.cursor[slot]
                self.prefill_tokens += 1
                if cur % self.page_len == 0:
                    self._register(slot, ctx, cur)
                if cur == len(ctx):
                    if len(ctx) % self.page_len:
                        self._register(slot, ctx, len(ctx))
                    self.phase[slot] = "handoff"
            else:
                self.done[rid] += 1
                self.decoded_tokens += 1
                self.phase[slot] = "decode"
                self.remaining[slot] -= 1
                if self.remaining[slot] == 0:
                    self.evict(slot)

    def _register(self, slot, ctx, upto):
        tp = (upto - 1) // self.page_len
        key = (self.chain[slot], tuple(ctx[tp * self.page_len:upto]))
        page = self.table[slot][tp]
        if page is None:
            return
        if upto % self.page_len:  # partial tail leaf
            if key in self.cache or self._available() < 1:
                return
            self.reserved += 1
            self.slot_reserved[slot] += 1
            page.ref += 1
            self.cache[key] = (page, None)
            return
        ent = self.cache.get(key)
        if ent is not None:
            self.chain[slot] = ent[1]
            return
        page.ref += 1
        cid = self.next_chain
        self.next_chain += 1
        self.cache[key] = (page, cid)
        self.chain[slot] = cid

    def run(self):
        guard = 0
        while self.queue or any(r is not None for r in self.slot_req):
            self.step()
            guard += 1
            assert guard < 10_000

    # -- observable state -------------------------------------------------

    def snapshot(self):
        pages = {id(p): p.ref for row in self.table for p in row if p}
        for p, _ in self.cache.values():
            pages[id(p)] = p.ref
        return {
            "occupied": [r is not None for r in self.slot_req],
            "refcounts": sorted(pages.values()),
            "free": self.free,
            "cache_entries": len(self.cache),
            "done": dict(self.done),
            "prefill_tokens": self.prefill_tokens,
            "decoded_tokens": self.decoded_tokens,
            "admissions": self.admissions,
            "evictions": self.evictions,
            "prefix_hit_tokens": self.prefix_hit_tokens,
        }


def _real_snapshot(sch):
    live = sch.pages.refcount[2:]
    return {
        "occupied": [r is not None for r in sch._slot_req],
        "refcounts": sorted(int(r) for r in live if r > 0),
        "free": sch.pages.free_pages,
        "cache_entries": sch.pages.prefix_len(),
        "done": {rid: len(toks) for rid, toks in sch.outputs.items()},
        "prefill_tokens": sch.stats.prefill_tokens,
        "decoded_tokens": sch.stats.decoded_tokens,
        "admissions": sch.stats.admissions,
        "evictions": sch.stats.evictions,
        "prefix_hit_tokens": sch.stats.prefix_hit_tokens,
    }


def test_scheduler_trace_oracle(spiking_setup):
    """Randomised submit/step/evict/preempt traces: the real scheduler's
    occupancy, page refcounts, free/cache counts, completion sets and
    token accounting must track the pure-Python oracle exactly, step by
    step, across several seeds (prompt contents are drawn from a small
    pool so prefix hits, partial-tail CoW and page pressure all occur)."""
    cfg, params = spiking_setup
    for trace_seed in (0, 1, 2):
        rng = np.random.RandomState(trace_seed)
        slots, cache_len, page_len = 3, 16, 4
        n_pages = slots * (cache_len // page_len) + 2 - 2 * trace_seed
        sch = BatchScheduler(params, cfg, IntegerBackend(), slots=slots,
                             cache_len=cache_len, paged=True,
                             page_len=page_len, n_pages=n_pages)
        orc = OracleScheduler(slots, cache_len, page_len, n_pages)
        rid = 0
        for op_i in range(40):
            op = rng.choice(["submit", "step", "step", "step", "preempt"])
            if op == "submit":
                base = int(rng.randint(0, 3))  # small pool -> shared prefixes
                length = int(rng.randint(2, 9))
                max_new = int(rng.randint(1, 5))
                prompt = _prompt(base, length)
                sch.submit(prompt, max_new, seed=rid)
                orc.submit(rid, prompt, max_new)
                rid += 1
            elif op == "step":
                sch.step()
                orc.step()
            else:  # preempt the first occupied slot, if any
                occ = [i for i, r in enumerate(sch._slot_req) if r is not None]
                if occ:
                    sch.evict(occ[0], requeue=True)
                    orc.evict(occ[0], requeue=True)
            real, want = _real_snapshot(sch), orc.snapshot()
            assert real == want, (
                f"trace {trace_seed} diverged at op {op_i} ({op}):\n"
                f"real   {real}\noracle {want}")
        sch.run()
        orc.run()
        assert _real_snapshot(sch) == orc.snapshot()
        # every request completed in full
        for r, toks in sch.outputs.items():
            assert orc.done[r] == len(toks)
