"""Continuous-batching scheduler correctness.

The serving contracts (see ``repro/serving``):

* **admission bit-exactness** — splicing a new request into a free slot
  mid-flight cannot perturb already-running slots: on the integer backend
  (the hardware oracle) a request's token stream is a pure function of
  (params, prompt, seed), never of batch composition.
* **eviction frees state** — an evicted slot's cache pages are zeroed
  (which is also what masks the slot out of the spiking comparators).
* **ragged generate == single-slot decode** — batch-serving ragged prompt
  lengths gives exactly the tokens of decoding each prompt alone.
* **backend matrix** — the same scheduler serves on every engine backend
  (CI sweeps XPIKE_BACKEND); pallas serving is bit-exact vs integer.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import reduced_config
from repro.engine import IntegerBackend, PallasBackend, get_backend
from repro.models import transformer as T
from repro.serving import BatchScheduler, slot_slice

SPIKING = "xpikeformer-gpt-4-256"
ANN = "yi-9b"


@pytest.fixture(scope="module")
def spiking_setup():
    cfg = reduced_config(SPIKING)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def matrix_scheduler(spiking_setup, engine_backend):
    """A scheduler on the CI-matrix backend (XPIKE_BACKEND env; reference
    by default).  The admission/eviction/reproducibility contracts are
    backend-generic — per-slot PRN keying holds on every substrate — so
    each matrix leg genuinely exercises its own backend here."""
    cfg, params = spiking_setup
    return BatchScheduler(params, cfg, get_backend(engine_backend),
                          slots=2, cache_len=32)


def _prompt(i, length):
    return list(range(3 + i, 3 + i + length))


def test_admission_keeps_running_slots_bit_exact(matrix_scheduler):
    """Admit B while A is mid-flight: A's tokens must not change at all."""
    sch = matrix_scheduler
    sch.reset()
    sch.submit(_prompt(0, 5), 6, seed=11)
    alone = dict(sch.run())

    sch.reset()
    ra = sch.submit(_prompt(0, 5), 6, seed=11)
    sch.step()
    sch.step()  # A has decoded 2 tokens; B not yet submitted
    rb = sch.submit(_prompt(1, 3), 4, seed=22)
    out = sch.run()
    assert out[ra] == alone[0], "mid-flight admission perturbed a running slot"
    assert len(out[rb]) == 4

    # and B itself is reproducible: alone == admitted-mid-flight
    sch.reset()
    rb_alone = sch.submit(_prompt(1, 3), 4, seed=22)
    alone_b = sch.run()[rb_alone]
    assert out[rb] == alone_b


def test_eviction_frees_slot_state(matrix_scheduler):
    sch = matrix_scheduler
    sch.reset()
    sch.submit(_prompt(0, 4), 6, seed=1)
    sch.submit(_prompt(1, 4), 6, seed=2)
    sch.step()
    assert bool(sch.state.active[0]) and bool(sch.state.active[1])
    sch.evict(0)
    assert not bool(sch.state.active[0])
    one = slot_slice(sch.state.cache, 0)
    for leaf in jax.tree.leaves(one):
        assert float(jnp.abs(leaf.astype(jnp.float32)).sum()) == 0.0, \
            "evicted slot retains cache state"
    # slot 1 keeps serving; slot 0 is reusable by the queue
    r3 = sch.submit(_prompt(2, 3), 3, seed=3)
    out = sch.run()
    assert len(out[r3]) == 3


@pytest.mark.parametrize("arch", [ANN, SPIKING])
def test_ragged_generate_matches_single_slot(arch, spiking_setup, engine_backend):
    """Batched ragged-length serving == each prompt decoded alone (on the
    CI-matrix backend for the spiking arch — the property is backend-generic)."""
    if arch == SPIKING:
        cfg, params = spiking_setup
        backend = get_backend(engine_backend)
    else:
        cfg = reduced_config(arch)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        backend = None
    prompts = [_prompt(0, 2), _prompt(1, 7), _prompt(2, 4), _prompt(3, 5)]
    sch = BatchScheduler(params, cfg, backend, slots=4, cache_len=32)
    rids = [sch.submit(p, 5, seed=100 + i) for i, p in enumerate(prompts)]
    batched = sch.run()

    solo = BatchScheduler(params, cfg, backend, slots=1, cache_len=32)
    for i, p in enumerate(prompts):
        solo.reset()
        rid = solo.submit(p, 5, seed=100 + i)
        assert solo.run()[rid] == batched[rids[i]], f"prompt {i} diverged"


@pytest.mark.skipif(
    os.environ.get("XPIKE_BACKEND", "reference") != "reference",
    reason="backend-independent parity test; runs once (tier1 / reference leg), "
           "not in every matrix leg",
)
def test_pallas_serving_bit_exact_vs_integer(spiking_setup):
    """The packed-popcount decode kernel serves bit-identically to the
    integer oracle — through the whole scheduler, not just per-op."""
    cfg, params = spiking_setup
    prompts = [_prompt(0, 4), _prompt(1, 6)]
    outs = {}
    for be in (IntegerBackend(), PallasBackend()):
        sch = BatchScheduler(params, cfg, be, slots=2, cache_len=32)
        rids = [sch.submit(p, 4, seed=7 + i) for i, p in enumerate(prompts)]
        out = sch.run()
        outs[be.name] = [out[r] for r in rids]
    assert outs["integer"] == outs["pallas"]


def test_generate_on_selected_backend(spiking_setup, engine_backend):
    """Engine-level batch API on the CI-matrix backend (XPIKE_BACKEND)."""
    from repro.engine import XpikeformerEngine

    cfg, params = spiking_setup
    eng = XpikeformerEngine.from_config(cfg, backend=engine_backend)
    eng.params = params
    outs = eng.generate([_prompt(0, 3), _prompt(1, 5)], max_new=4,
                        slots=2, cache_len=32)
    assert [len(o) for o in outs] == [4, 4]
    vocab = cfg.vocab_size
    assert all(0 <= t < vocab for o in outs for t in o)


def test_serving_energy_metering(matrix_scheduler):
    """Per-request energy: measured spike events x op energies, conserved
    between the per-request map and the aggregate stats."""
    sch = matrix_scheduler
    sch.reset()
    ra = sch.submit(_prompt(0, 4), 5, seed=1)
    rb = sch.submit(_prompt(1, 6), 3, seed=2)
    sch.run()
    st = sch.stats
    assert st.spike_events > 0 and st.energy_j > 0
    assert set(sch.request_energy_j) == {ra, rb}
    assert all(v > 0 for v in sch.request_energy_j.values())
    total = sum(sch.request_energy_j.values())
    assert abs(total - st.energy_j) < 1e-9 * max(st.energy_j, 1.0)
    # 5 + 3 decoded tokens worth of static energy is a lower bound
    assert st.energy_j >= 8 * sch._e_token_pj * 1e-12 * 0.99


def _programmed_setup(spiking_setup):
    from repro import aimc_device as AD

    cfg, params = spiking_setup
    # low-scatter device config: GDC (a *global* compensation) is the
    # paper's answer to near-uniform drift; heavy per-device nu scatter is
    # exactly what it cannot repair
    acfg = AD.AIMCConfig(drift_nu_sigma=0.005, prog_noise_sigma=0.01)
    hw = AD.program_lm_tree(jax.random.PRNGKey(42), params, acfg)
    return cfg, hw, acfg


def test_scheduler_drift_soak(spiking_setup, engine_backend):
    """Lifecycle soak on the CI-matrix backend: the scheduler advances the
    device clock per decode step, fires periodic GDC recalibrations, keeps
    serving valid tokens — and never recompiles the jitted decode_step."""
    from repro import aimc_device as AD

    cfg, hw, acfg = _programmed_setup(spiking_setup)
    pol = AD.DriftPolicy(seconds_per_step=600.0, recal_interval_s=2400.0,
                         cfg=acfg)
    sch = BatchScheduler(hw, cfg, get_backend(engine_backend), slots=2,
                         cache_len=32, drift=pol)
    rids = [sch.submit(_prompt(i, 3 + i), 6, seed=10 + i) for i in range(4)]
    outs = sch.run()
    st = sch.stats
    assert all(len(outs[r]) == 6 for r in rids)
    assert all(0 <= t < cfg.vocab_size for r in rids for t in outs[r])
    assert st.t_device_s == 600.0 * st.decode_steps
    assert st.recalibrations >= 2, "periodic GDC must have fired"
    assert sch._decode._cache_size() == 1, \
        "drift lifecycle must not recompile decode_step"
    assert st.energy_j > 0 and len(sch.request_energy_j) == 4


def test_scheduler_gdc_recovers_half_logit_error(spiking_setup):
    """Acceptance bound (on the integer hardware oracle): after a day of
    drift, one GDC recalibration recovers >= half of the drift-induced
    logit error of the batched decode step — through leaf-value-only param
    updates (the compiled decode_step is reused for all three variants)."""
    from repro import aimc_device as AD

    cfg, hw, acfg = _programmed_setup(spiking_setup)
    sch = BatchScheduler(hw, cfg, IntegerBackend(), slots=2, cache_len=32)
    sch.submit(_prompt(0, 5), 8, seed=1)
    sch.submit(_prompt(1, 4), 8, seed=2)
    sch.admit()
    sch.step()
    sch.step()
    state = sch.state  # frozen mid-serve snapshot

    l0, _, _ = sch._decode(hw, state)
    hw_drift = AD.drift_tree(hw, 86400.0, acfg)
    ld, _, _ = sch._decode(hw_drift, state)
    lr, _, _ = sch._decode(AD.recalibrate_tree(hw_drift, acfg), state)
    err_nc = float(jnp.mean(jnp.abs(ld - l0)))
    err_gdc = float(jnp.mean(jnp.abs(lr - l0)))
    assert err_nc > 0.0, "a day of drift must perturb the logits"
    assert err_gdc <= 0.5 * err_nc, (
        f"GDC recovered too little: {err_gdc:.4f} vs no-GDC {err_nc:.4f}")
    assert sch._decode._cache_size() == 1, \
        "lifecycle param updates must reuse the compiled decode_step"


def test_engine_serve_keeps_device_aging_across_calls(spiking_setup):
    """Drift is physical: a second engine.serve() on the cached scheduler
    must continue from the aged device clock, not rejuvenate it from a
    stale engine param tree."""
    from repro import aimc_device as AD
    from repro.engine import XpikeformerEngine

    cfg, params = spiking_setup
    eng = XpikeformerEngine.from_config(cfg, backend="integer")
    eng.params = params
    eng.program(jax.random.PRNGKey(5))
    pol = AD.DriftPolicy(seconds_per_step=300.0)
    _, st1 = eng.serve([_prompt(0, 3)], max_new=3, slots=2, cache_len=32,
                       drift=pol)
    assert st1.t_device_s == 300.0 * st1.decode_steps
    assert AD.device_time(eng.params) == st1.t_device_s, \
        "engine must adopt the aged device state after serve()"
    _, st2 = eng.serve([_prompt(1, 3)], max_new=3, slots=2, cache_len=32,
                       drift=pol)
    assert st2.t_device_s == st1.t_device_s + 300.0 * st2.decode_steps, \
        "second serve() must continue aging, not restart at t=0"


def test_decode_state_pytree_roundtrip(spiking_setup):
    """DecodeState is a jit-transparent pytree; slot splice/zero invert."""
    from repro.serving import init_state, release_slot, splice_request

    cfg, _ = spiking_setup
    st = init_state(cfg, 3, 16)
    one = T.init_cache(cfg, 1, 16)
    one = jax.tree.map(lambda a: jnp.ones_like(a), one)
    st2 = splice_request(st, 1, one, jnp.int32(5), jnp.uint32(9))
    assert bool(st2.active[1]) and int(st2.tokens[1]) == 5
    got = slot_slice(st2.cache, 1)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(one)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b.astype(a.dtype)))
    st3 = release_slot(st2, 1)
    assert not bool(st3.active[1])
    for leaf in jax.tree.leaves(slot_slice(st3.cache, 1)):
        assert float(jnp.abs(leaf.astype(jnp.float32)).sum()) == 0.0
