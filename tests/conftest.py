import os
import sys
import types

# Force an 8-device host platform (before the jax import below initialises
# the backend) so the distributed-execution tests (tests/test_distributed.py)
# exercise a real (data, model) mesh in tier-1.  Measured overhead on the
# rest of the suite is nil — single-device computations still run on device
# 0.  An explicit device-count in the caller's XLA_FLAGS wins.
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8").strip()

import jax
import pytest

jax.config.update("jax_enable_x64", False)

# ---------------------------------------------------------------------------
# Optional-dependency gate: hypothesis.
#
# The property tests use a small, fixed subset of the hypothesis API
# (@given with keyword strategies: integers / sampled_from / booleans /
# just, plus @settings).  When the real package is available (CI installs
# it from pyproject.toml) it is used unchanged; on bare containers without
# it we install a deterministic fallback that degrades each @given test to
# a fixed, well-spread sample of the strategy product space — the suite
# still collects and genuinely exercises the properties, just on fixed
# seeds instead of shrinking random search.
# ---------------------------------------------------------------------------

try:  # pragma: no cover - environment-dependent
    import hypothesis  # noqa: F401
except ModuleNotFoundError:  # pragma: no cover - environment-dependent

    class _Strategy:
        def __init__(self, examples):
            self.examples = list(examples)

    def _integers(lo, hi):
        span = hi - lo
        vals = {lo, hi, lo + span // 2, lo + span // 3, lo + (2 * span) // 3,
                lo + 1 if span >= 1 else lo, hi - 1 if span >= 1 else hi}
        return _Strategy(sorted(v for v in vals if lo <= v <= hi))

    def _sampled_from(seq):
        return _Strategy(seq)

    def _booleans():
        return _Strategy([False, True])

    def _just(value):
        return _Strategy([value])

    _MAX_FALLBACK_EXAMPLES = 8

    def _given(**strategies):
        names = list(strategies)

        def deco(fn):
            def wrapper(*args, **kwargs):
                pools = [strategies[n].examples for n in names]
                total = 1
                for p in pools:
                    total *= max(len(p), 1)
                n_runs = min(total, _MAX_FALLBACK_EXAMPLES)
                # deterministic, well-spread walk of the product space:
                # golden-ratio (Fibonacci) index hashing decorrelates the
                # mixed-radix digits, unlike aligned round-robin cycles
                seen = set()
                for i in range(total):
                    idx = (i * 2654435761) % total
                    if idx in seen:
                        continue
                    seen.add(idx)
                    drawn = {}
                    for n, p in zip(names, pools):
                        idx, r = divmod(idx, max(len(p), 1))
                        drawn[n] = p[r]
                    fn(*args, **kwargs, **drawn)
                    if len(seen) >= n_runs:
                        break

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.hypothesis_fallback = True
            return wrapper

        return deco

    def _settings(**_kwargs):
        def deco(fn):
            return fn

        return deco

    _stub = types.ModuleType("hypothesis")
    _stub.given = _given
    _stub.settings = _settings
    _stub.HealthCheck = types.SimpleNamespace(
        too_slow="too_slow", data_too_large="data_too_large",
        filter_too_much="filter_too_much")
    _strategies = types.ModuleType("hypothesis.strategies")
    _strategies.integers = _integers
    _strategies.sampled_from = _sampled_from
    _strategies.booleans = _booleans
    _strategies.just = _just
    _stub.strategies = _strategies
    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _strategies


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def engine_backend():
    """The engine backend under test, selected by the XPIKE_BACKEND env var.

    CI's backend-matrix job runs the engine/serving tests once per backend
    (reference | integer | pallas); locally it defaults to "reference".
    Tests that exercise *the selected* substrate (rather than comparing
    substrates) should take this fixture instead of hard-coding a name.
    """
    name = os.environ.get("XPIKE_BACKEND", "reference")
    from repro.engine import BACKENDS

    assert name in BACKENDS, f"XPIKE_BACKEND={name!r} not in {sorted(BACKENDS)}"
    return name
