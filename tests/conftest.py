import itertools
import os
import sys
import types

# Force an 8-device host platform (before the jax import below initialises
# the backend) so the distributed-execution tests (tests/test_distributed.py)
# exercise a real (data, model) mesh in tier-1.  Measured overhead on the
# rest of the suite is nil — single-device computations still run on device
# 0.  An explicit device-count in the caller's XLA_FLAGS wins.
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=8").strip()

import jax
import pytest

jax.config.update("jax_enable_x64", False)

# ---------------------------------------------------------------------------
# Optional-dependency gate: hypothesis.
#
# The property tests use a small, fixed subset of the hypothesis API
# (@given + integers/sampled_from strategies).  When the real package is
# available (CI installs it from pyproject.toml) it is used unchanged; on
# bare containers without it we install a deterministic fallback that runs
# each @given test over a small round-robin sweep of the strategy domains,
# so the suite still collects and exercises the properties.
# ---------------------------------------------------------------------------

try:  # pragma: no cover - environment-dependent
    import hypothesis  # noqa: F401
except ModuleNotFoundError:  # pragma: no cover - environment-dependent

    class _Strategy:
        def __init__(self, examples):
            self.examples = list(examples)

    def _integers(lo, hi):
        mid = (lo + hi) // 2
        vals = sorted({lo, mid, hi})
        return _Strategy(vals)

    def _sampled_from(seq):
        return _Strategy(seq)

    def _booleans():
        return _Strategy([False, True])

    def _given(**strategies):
        names = list(strategies)

        def deco(fn):
            def wrapper(*args, **kwargs):
                pools = [strategies[n].examples for n in names]
                longest = max(len(p) for p in pools)
                n_runs = min(max(longest, 1) + 2, 8)
                cycles = [itertools.cycle(p) for p in pools]
                for _ in range(n_runs):
                    drawn = {n: next(c) for n, c in zip(names, cycles)}
                    fn(*args, **kwargs, **drawn)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco

    def _settings(**_kwargs):
        def deco(fn):
            return fn

        return deco

    _stub = types.ModuleType("hypothesis")
    _stub.given = _given
    _stub.settings = _settings
    _strategies = types.ModuleType("hypothesis.strategies")
    _strategies.integers = _integers
    _strategies.sampled_from = _sampled_from
    _strategies.booleans = _booleans
    _stub.strategies = _strategies
    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _strategies


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def engine_backend():
    """The engine backend under test, selected by the XPIKE_BACKEND env var.

    CI's backend-matrix job runs the engine/serving tests once per backend
    (reference | integer | pallas); locally it defaults to "reference".
    Tests that exercise *the selected* substrate (rather than comparing
    substrates) should take this fixture instead of hard-coding a name.
    """
    name = os.environ.get("XPIKE_BACKEND", "reference")
    from repro.engine import BACKENDS

    assert name in BACKENDS, f"XPIKE_BACKEND={name!r} not in {sorted(BACKENDS)}"
    return name
