"""Mesh-sharded distributed execution: sharded == single-device, bit-exact.

The subsystem contracts (see ``repro/distributed``):

* **forward bit-exactness** — the full spiking forward placed on a
  (data, model) mesh (batch data-parallel, spiking linears / SSA attention
  tensor-parallel) produces bit-identical logits to the single-device
  backend: every sharded reduction is over integer-valued operands and
  every PRN draw is at logical shapes.
* **scheduler bit-exactness** — a whole ``BatchScheduler.run()`` with
  mid-flight admission and evictions on a >=4-device mesh decodes exactly
  the single-device integer oracle's tokens, on both digital substrates
  (integer and pallas).
* **programmed-AIMC lifecycle** — the drift + GDC path (device clock
  advance, image refolds, integer-sum calibration reads) is sharding-
  invariant, and none of it recompiles the jitted decode step.
* **placement** — device-state leaves get per-field specs on the crossbar
  matrix view; the spiking KV cache shards its head axis over ``model``.

These tests run on the 8-device host platform forced by conftest
(``--xla_force_host_platform_device_count=8``); they skip gracefully if a
caller overrides XLA_FLAGS with fewer devices.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import aimc_device as AD
from repro.configs.registry import reduced_config
from repro.engine import IntegerBackend, PallasBackend
from repro.launch.mesh import make_serving_mesh, parse_mesh_spec
from repro.models import transformer as T
from repro.parallel import sharding as SH
from repro.serving import BatchScheduler

SPIKING = "xpikeformer-gpt-4-256"
ANN = "yi-9b"

needs_mesh = pytest.mark.skipif(
    len(jax.devices()) < 8, reason="needs the 8-device host platform")


@pytest.fixture(scope="module")
def spiking_setup():
    cfg = reduced_config(SPIKING)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def mesh():
    if len(jax.devices()) < 8:
        pytest.skip("needs the 8-device host platform")
    return make_serving_mesh((2, 4))


def _prompt(i, length):
    return list(range(3 + i, 3 + i + length))


def _oracle_run(cfg, params, prompts, max_new, *, slots=2, cache_len=32,
                seed0=100, drift=None, evict_after=None):
    sch = BatchScheduler(params, cfg, IntegerBackend(), slots=slots,
                         cache_len=cache_len, drift=drift)
    rids = [sch.submit(p, max_new, seed=seed0 + i) for i, p in enumerate(prompts)]
    if evict_after is not None:
        for _ in range(evict_after):
            sch.step()
        sch.evict(0, requeue=True)
    outs = sch.run()
    return [outs[r] for r in rids], sch


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


@needs_mesh
@pytest.mark.parametrize("backend_cls", [IntegerBackend, PallasBackend])
def test_mesh_forward_bit_exact(spiking_setup, mesh, backend_cls):
    """Full spiking forward on the (2, 4) mesh == single device, bitwise."""
    from repro.distributed import Executor

    cfg, params = spiking_setup
    tokens = jax.random.randint(jax.random.PRNGKey(7), (4, 12), 0,
                                cfg.vocab_size, jnp.int32)
    rng = jax.random.PRNGKey(3)
    ref = T.forward(params, {"tokens": tokens}, cfg, rng=rng,
                    backend=backend_cls(), remat="none")[0]
    ex = Executor(params, cfg, backend_cls(), mesh)
    got = ex.forward(tokens, rng)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


# ---------------------------------------------------------------------------
# Scheduler decode (the acceptance criterion)
# ---------------------------------------------------------------------------


@needs_mesh
@pytest.mark.parametrize("backend_cls", [IntegerBackend, PallasBackend])
def test_sharded_scheduler_bit_exact_vs_integer_oracle(
        spiking_setup, mesh, backend_cls):
    """Sharded integer/pallas decode through a full BatchScheduler.run()
    with mid-flight admissions and evictions on a (2, 4) mesh decodes the
    single-device integer oracle's tokens bit-for-bit — and the jitted
    sharded decode_step compiles exactly once."""
    from repro.distributed import Executor

    cfg, params = spiking_setup
    # 5 ragged prompts through 2 slots: finished slots evict and the queue
    # splices new requests mid-flight (continuous batching)
    prompts = [_prompt(i, 3 + (2 * i) % 5) for i in range(5)]
    ref, ref_sch = _oracle_run(cfg, params, prompts, 5)
    assert ref_sch.stats.admissions == 5 and ref_sch.stats.evictions == 5

    ex = Executor(params, cfg, backend_cls(), mesh)
    outs, stats = ex.serve(prompts, max_new=5, slots=2, cache_len=32, seed=100)
    assert outs == ref, f"sharded {backend_cls.__name__} diverged from oracle"
    assert stats.admissions == 5 and stats.evictions == 5
    assert (stats.data_shards, stats.model_shards) == (2, 4)
    (sch,) = ex._schedulers.values()
    assert sch._decode._cache_size() == 1, "sharded decode_step recompiled"


@needs_mesh
@pytest.mark.parametrize("backend_cls", [IntegerBackend, PallasBackend])
def test_sharded_paged_scheduler_bit_exact(spiking_setup, mesh, backend_cls):
    """Block-paged serving on the (2, 4) mesh — page pool with KV heads
    sharded over ``model``, page tables/slots over ``data``, chunked
    prefill riding the sharded step — decodes the *dense* single-device
    integer oracle's tokens bit-for-bit, including a shared-prefix pair
    that hits the prefix cache."""
    from repro.distributed import Executor

    cfg, params = spiking_setup
    prompts = [_prompt(i, 3 + (2 * i) % 5) for i in range(5)]
    ref, _ = _oracle_run(cfg, params, prompts, 5)

    ex = Executor(params, cfg, backend_cls(), mesh)
    outs, stats = ex.serve(prompts, max_new=5, slots=2, cache_len=32,
                           seed=100, paged=True, page_len=8)
    assert outs == ref, f"mesh paged {backend_cls.__name__} diverged"
    sch = ex.scheduler(slots=2, cache_len=32, paged=True, page_len=8)
    assert sch._decode._cache_size() == 1, "mesh paged decode recompiled"

    # shared-prefix pair: second serve hits the pages the first registered
    shared = _prompt(9, 17)
    ref1, _ = _oracle_run(cfg, params, [shared], 4, seed0=1)
    ref2, _ = _oracle_run(cfg, params, [shared], 4, seed0=2)
    sch = ex.scheduler(slots=2, cache_len=32, paged=True, page_len=8)
    r1 = sch.submit(shared, 4, seed=1)
    o1 = dict(sch.run())
    r2 = sch.submit(shared, 4, seed=2)
    o2 = dict(sch.run())
    assert [o1[r1]] == ref1 and [o2[r2]] == ref2
    assert sch.stats.prefix_hit_tokens == 16, "prefix cache must hit on mesh"


@needs_mesh
@pytest.mark.parametrize("backend_cls", [IntegerBackend, PallasBackend])
def test_sharded_fused_decode_head_parallel(spiking_setup, mesh, backend_cls):
    """``decode_kernel='fused'`` on the (2, 4) mesh: the megakernel's
    attention stage runs head-parallel inside shard_map (per-shard global
    ``h0`` offsets, column-sliced Q/K/V), the FFN tail rides the row/col-
    parallel spiking linears — and the whole serve decodes the
    single-device *unfused* integer oracle's tokens bit-for-bit, dense and
    paged, with exactly one decode compile."""
    from repro.distributed import Executor

    cfg, params = spiking_setup
    prompts = [_prompt(i, 3 + (2 * i) % 5) for i in range(4)]
    ref, _ = _oracle_run(cfg, params, prompts, 5)

    ex = Executor(params, cfg, backend_cls(), mesh)
    outs, stats = ex.serve(prompts, max_new=5, slots=2, cache_len=32,
                           seed=100, decode_kernel="fused")
    assert outs == ref, f"sharded fused {backend_cls.__name__} diverged"
    assert (stats.data_shards, stats.model_shards) == (2, 4)
    sch = ex.scheduler(slots=2, cache_len=32, decode_kernel="fused")
    assert sch.plan.fused
    assert sch._decode._cache_size() == 1, "sharded fused decode recompiled"

    # the paged megakernel rides the same head-parallel shard over the pool
    pouts, _ = ex.serve(prompts, max_new=5, slots=2, cache_len=32, seed=100,
                        paged=True, page_len=8, decode_kernel="fused")
    assert pouts == ref, f"sharded paged fused {backend_cls.__name__} diverged"


@needs_mesh
def test_sharded_preemption_matches_single_device(spiking_setup, mesh):
    """Explicit mid-run eviction with requeue (preemption) replays the same
    way sharded and unsharded."""
    from repro.distributed import Executor

    cfg, params = spiking_setup
    prompts = [_prompt(i, 4 + i) for i in range(3)]
    ref, _ = _oracle_run(cfg, params, prompts, 4, evict_after=2)

    ex = Executor(params, cfg, IntegerBackend(), mesh)
    sch = ex.scheduler(slots=2, cache_len=32)
    rids = [sch.submit(p, 4, seed=100 + i) for i, p in enumerate(prompts)]
    sch.step()
    sch.step()
    sch.evict(0, requeue=True)
    outs = sch.run()
    assert [outs[r] for r in rids] == ref


@needs_mesh
def test_dp_only_mesh_and_ann_arch(spiking_setup, mesh):
    """Data-parallel-only placement: an (8, 1) mesh for the spiking arch
    and the (2, 4) mesh for an ANN arch (params replicated, slots sharded)
    both reproduce single-device serving."""
    from repro.distributed import Executor

    cfg, params = spiking_setup
    prompts = [_prompt(i, 4) for i in range(4)]
    ref, _ = _oracle_run(cfg, params, prompts, 4, slots=4)
    dp_mesh = make_serving_mesh((8, 1))
    ex = Executor(params, cfg, IntegerBackend(), dp_mesh)
    outs, stats = ex.serve(prompts, max_new=4, slots=4, cache_len=32, seed=100)
    assert outs == ref
    assert stats.data_shards == 8 and stats.model_shards == 1

    acfg = reduced_config(ANN)
    aparams = T.init_params(jax.random.PRNGKey(0), acfg)
    sch = BatchScheduler(aparams, acfg, None, slots=4, cache_len=32)
    rids = [sch.submit(p, 4, seed=100 + i) for i, p in enumerate(prompts)]
    aref = [sch.run()[r] for r in rids]
    ex2 = Executor(aparams, acfg, None, mesh)
    aouts, _ = ex2.serve(prompts, max_new=4, slots=4, cache_len=32, seed=100)
    assert aouts == aref


# ---------------------------------------------------------------------------
# Programmed AIMC: drift + GDC on the mesh
# ---------------------------------------------------------------------------


@needs_mesh
def test_sharded_programmed_drift_gdc_bit_exact(spiking_setup, mesh):
    """The programmed-PCM lifecycle on the mesh — per-step clock advance,
    image refolds, periodic GDC recalibration (integer-sum calibration
    reads) — serves bit-identically to the single-device oracle and never
    recompiles the decode step."""
    from repro.distributed import Executor

    cfg, params = spiking_setup
    acfg = AD.AIMCConfig(drift_nu_sigma=0.005, prog_noise_sigma=0.01)
    hw = AD.program_lm_tree(jax.random.PRNGKey(42), params, acfg)
    pol = AD.DriftPolicy(seconds_per_step=600.0, recal_interval_s=2400.0,
                         cfg=acfg)
    prompts = [_prompt(i, 3 + i) for i in range(4)]
    ref, ref_sch = _oracle_run(cfg, hw, prompts, 6, seed0=10, drift=pol)
    assert ref_sch.stats.recalibrations >= 2

    ex = Executor(hw, cfg, IntegerBackend(), mesh)
    outs, stats = ex.serve(prompts, max_new=6, slots=2, cache_len=32, seed=10,
                           drift=pol)
    assert outs == ref
    assert stats.recalibrations == ref_sch.stats.recalibrations
    assert stats.t_device_s == ref_sch.stats.t_device_s
    assert stats.energy_j > 0 and abs(stats.energy_j - ref_sch.stats.energy_j) \
        <= 1e-9 * max(stats.energy_j, 1.0)
    (sch,) = ex._schedulers.values()
    assert sch._decode._cache_size() == 1, \
        "drift/GDC lifecycle recompiled the sharded decode_step"


@needs_mesh
def test_recalibrate_is_sharding_invariant(spiking_setup, mesh):
    """The GDC calibration read (integer image sums) measures the exact
    same gain on sharded and replicated device state."""
    from repro.distributed import param_pspecs_for_tree

    cfg, params = spiking_setup
    acfg = AD.AIMCConfig()
    hw = AD.program_lm_tree(jax.random.PRNGKey(1), params, acfg)
    hw = AD.drift_tree(hw, 86400.0, acfg)
    ref = AD.recalibrate_tree(hw, acfg)

    specs = param_pspecs_for_tree(cfg, hw, mesh)
    hw_sharded = jax.device_put(hw, SH.to_shardings(specs, mesh))
    got = AD.recalibrate_tree_jit(hw_sharded, acfg)

    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Placement rules
# ---------------------------------------------------------------------------


@needs_mesh
def test_param_pspecs_for_tree_device_state(spiking_setup, mesh):
    """Programmed leaves get per-field crossbar-view specs: Q/K/V/MLP-in
    column-sharded, attention-out/MLP-out row-sharded, scalars replicated."""
    from repro.distributed import param_pspecs_for_tree

    cfg, params = spiking_setup
    hw = AD.program_lm_tree(jax.random.PRNGKey(1), params, AD.AIMCConfig())
    specs = param_pspecs_for_tree(cfg, hw, mesh)
    blk = specs["periods"]["blk0"]
    wq, wo = blk["mixer"]["wq"], blk["mixer"]["wo"]
    # stacked period leaves: [layers, d_in, d_out]
    assert tuple(wq.levels_t) == (None, None, "model")
    assert tuple(wq.scale) == (None, "model")
    assert tuple(wo.levels_t) == (None, "model", None)
    assert tuple(wo.scale) == ()
    assert tuple(wq.t_seconds) == () and tuple(wq.gdc_gain) == ()
    mlp = blk["mlp"]
    assert tuple(mlp["wi"].levels_t) == (None, None, "model")
    assert tuple(mlp["wo"].levels_t) == (None, "model", None)


@needs_mesh
def test_cache_pspecs_shard_spiking_kv_heads(spiking_setup, mesh):
    """The spiking KV cache shards its head axis over ``model`` and the
    slot axis over ``data``; DecodeState vectors ride ``data``."""
    from repro.distributed import Executor

    cfg, params = spiking_setup
    cs = SH.cache_pspecs(cfg, mesh, 4, 32)
    sk = cs["periods"]["blk0"]["sk"]
    # [layers, B, spike_T, L, KV, hd]
    assert tuple(sk) == (None, "data", None, None, "model", None)
    ex = Executor(params, cfg, IntegerBackend(), mesh)
    ss = ex.state_specs(4, 32)
    assert tuple(ss.tokens) == ("data",)
    assert tuple(ss.seeds) == ("data",)


def test_parse_mesh_spec():
    assert parse_mesh_spec("2x4") == (2, 4)
    assert parse_mesh_spec("2,4") == (2, 4)
    assert parse_mesh_spec("4") == (4, 1)
    assert parse_mesh_spec("auto")[1] == 1
    with pytest.raises(ValueError):
        parse_mesh_spec("2x2x2")


# ---------------------------------------------------------------------------
# Shard-local kernel ops
# ---------------------------------------------------------------------------


def test_aimc_matmul_counts_matches_ref(rng):
    """The counts kernel (shard-local programmed-AIMC matmul) == oracle."""
    from repro.kernels import ops as KOPS
    from repro.kernels import ref as KREF

    k1, k2 = jax.random.split(rng)
    spikes = jax.random.bernoulli(k1, 0.4, (3, 5, 48)).astype(jnp.float32)
    levels = jax.random.randint(k2, (48, 33), -15, 16, jnp.int32).astype(jnp.int8)
    got = KOPS.aimc_matmul_counts(spikes, levels)
    ref = KREF.aimc_counts_ref(spikes, levels)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_per_head_decode_prns_offset_slices():
    """A shard drawing heads [h0, h0+h) gets exactly the oracle's rows for
    those heads — the f(seed, pos, head) stream contract of TP decode."""
    from repro.kernels import ops as KOPS

    keys = jnp.asarray([[0, 5], [0, 9]], jnp.uint32)
    t, h, l, d, i_max = 3, 4, 16, 8, 16
    rs, ra = KOPS.draw_slot_decode_prns(keys, t, h, l, d, i_max)
    rs2, ra2 = KOPS.draw_slot_decode_prns(keys, t, 2, l, d, i_max, h0=2)
    full = rs.reshape(2, t, h, 1, l)[:, :, 2:4]
    shard = rs2.reshape(2, t, 2, 1, l)
    np.testing.assert_array_equal(np.asarray(full), np.asarray(shard))
    full_a = ra.reshape(2, t, h, 1, d)[:, :, 2:4]
    shard_a = ra2.reshape(2, t, 2, 1, d)
    np.testing.assert_array_equal(np.asarray(full_a), np.asarray(shard_a))
