"""Fault tolerance end-to-end: crash + restart == uninterrupted run."""

import shutil

import pytest

from repro.launch import train as LT


@pytest.mark.slow
def test_crash_restart_replays_exactly(tmp_path):
    """A run killed at step 12 and restarted must reach the same final loss
    as an uninterrupted run: checkpoints are exact and the data pipeline is
    seekable (batch = f(seed, step))."""
    d1 = tmp_path / "a"
    losses_ref = LT.run("yi-9b", steps=20, ckpt_dir=str(d1), ckpt_every=5,
                        log_every=0, seed=3)

    d2 = tmp_path / "b"
    with pytest.raises(RuntimeError, match="injected failure"):
        LT.run("yi-9b", steps=20, ckpt_dir=str(d2), ckpt_every=5, fail_at=12,
               log_every=0, seed=3)
    losses_resumed = LT.run("yi-9b", steps=20, ckpt_dir=str(d2), ckpt_every=5,
                            log_every=0, seed=3)
    # resumed run starts from step 10 (last checkpoint) -> last 10 losses align
    assert abs(losses_resumed[-1] - losses_ref[-1]) < 1e-4


@pytest.mark.slow
def test_training_reduces_loss(tmp_path):
    losses, probe0, probe1 = LT.run(
        "mamba2-780m", steps=30, ckpt_dir=str(tmp_path / "c"),
        ckpt_every=0, log_every=0, seed=1, probe=True,
    )
    # fixed-batch probe (see test_system): the mamba2 smoke init sits at the
    # Markov stream's entropy floor, so fresh-batch first-vs-last deltas are
    # noise; the fixed-batch gain after 30 steps is ~0.4 — deterministic.
    assert probe1 < probe0 - 0.05
