"""AIMC device-state subsystem: program / drift_to / recalibrate lifecycle.

Contracts under test (see ``repro/aimc_device.py``):

* :func:`~repro.aimc_device.quantize_weights` is the single source of
  truth for Table-II quantisation — identical to the 2-D core helpers;
* programming is deterministic in the key and **one-shot** (a second
  ``program`` on the same tree raises instead of double-wrapping leaves);
* ``drift_to`` decays the digital execution image and never changes leaf
  shapes/dtypes; ``recalibrate`` folds the measured GDC gain into the
  per-column scales and recovers the global drift factor;
* the Pallas drift-requantise fold and the programmed-state spiking
  linear are **bit-exact** vs the ``kernels/ref.py`` oracles at any fixed
  device time;
* through full model forwards, drifted logit error grows without GDC and
  recalibration recovers it (paper §V-B / Fig. 7), with the pallas and
  integer backends bit-identical at every lifecycle point.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import aimc_device as AD
from repro.core import aimc as AM
from repro.core.aimc import AIMCConfig
from repro.kernels import ops as KOPS
from repro.kernels import ref as KREF

CFG = AIMCConfig()


def _state(rng, shape=(70, 40), cfg=CFG, scale=0.1):
    w = jax.random.normal(rng, shape) * scale
    return w, AD.program(jax.random.fold_in(rng, 1), w, cfg)


# ---------------------------------------------------------------------------
# Quantisation dedup + programming
# ---------------------------------------------------------------------------


def test_quantize_weights_matches_core_helpers(rng):
    """The deduplicated quantiser == the original 2-D core pair."""
    w = jax.random.normal(rng, (96, 33)) * 0.2
    levels, scale = AD.quantize_weights(w, CFG)
    scale0 = AM.column_scale(w, CFG)
    levels0 = AM.quantize_levels(w, scale0, CFG)
    np.testing.assert_allclose(np.asarray(scale), np.asarray(scale0), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(levels), np.asarray(levels0))


def test_quantize_weights_rank_generic(rng):
    """Stacked leading axes quantise per-matrix (for scanned layer stacks)."""
    w = jax.random.normal(rng, (3, 40, 16)) * 0.1
    levels, scale = AD.quantize_weights(w, CFG)
    assert levels.shape == (3, 40, 16) and scale.shape == (3, 16)
    for i in range(3):
        l2, s2 = AD.quantize_weights(w[i], CFG)
        np.testing.assert_array_equal(np.asarray(levels[i]), np.asarray(l2))
        np.testing.assert_allclose(np.asarray(scale[i]), np.asarray(s2))


def test_program_deterministic_and_fresh_image(rng):
    w, st = _state(rng)
    _, st2 = _state(rng)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(st2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert st.levels_t.dtype == jnp.int8
    assert float(st.t_seconds) == 0.0 and float(st.gdc_gain) == 1.0
    # at t=0 the digital image is the re-digitised programmed conductance
    # (full int8 image grid: image_gain steps per programming level)
    gain = AD.image_gain(CFG)
    np.testing.assert_array_equal(
        np.asarray(st.levels_t),
        np.asarray(jnp.clip(jnp.round((st.levels + st.eps) * gain), -127,
                            127).astype(jnp.int8)))


def test_program_tree_is_one_shot(rng):
    tree = {"lin": {"w": jax.random.normal(rng, (16, 8)), "b": jnp.zeros(8)},
            "other": jnp.ones(3)}
    pt = AD.program_tree(rng, tree, CFG)
    assert AD.is_programmed(pt) and not AD.is_programmed(tree)
    assert isinstance(pt["lin"]["hw"], AD.AIMCDeviceState)
    with pytest.raises(ValueError, match="already programmed"):
        AD.program_tree(rng, pt, CFG)
    with pytest.raises(ValueError, match="already programmed"):
        AD.program_lm_tree(rng, {"periods": {"blk0": {}}, "x": pt}, CFG)


def test_engine_program_is_one_shot(rng):
    from repro.engine import XpikeformerEngine

    eng = XpikeformerEngine.from_config("xpikeformer-vit-smoke",
                                        backend="integer")
    eng.init(rng)
    eng.program(jax.random.fold_in(rng, 3))
    with pytest.raises(ValueError, match="one-shot"):
        eng.program(jax.random.fold_in(rng, 4))


# ---------------------------------------------------------------------------
# Drift + GDC lifecycle
# ---------------------------------------------------------------------------


def test_drift_decays_digital_image(rng):
    _, st = _state(rng)
    mags = []
    for t in (0.0, 3600.0, 86400.0, 3.15e7):
        st_t = AD.drift_to(st, t, CFG)
        assert float(st_t.t_seconds) == t
        mags.append(int(jnp.sum(jnp.abs(st_t.levels_t.astype(jnp.int32)))))
        # lifecycle updates never change shapes/dtypes (no-recompile contract)
        for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(st_t)):
            assert a.shape == b.shape and a.dtype == b.dtype
    assert mags[0] > mags[1] > mags[2] > mags[3] > 0


def test_recalibrate_recovers_global_drift(rng):
    """GDC gain restores the effective weights in weight space (§V-B)."""
    w, st = _state(rng)
    w_hat = np.asarray(st.levels_t.astype(jnp.float32) * st.eff_scale)
    base = float(np.mean(np.abs(w_hat - np.asarray(w))))
    st_d = AD.drift_to(st, 86400.0, CFG)
    err_nc = float(np.mean(np.abs(
        np.asarray(st_d.levels_t.astype(jnp.float32) * st_d.eff_scale) - np.asarray(w))))
    st_r = AD.recalibrate(st_d, CFG)
    assert float(st_r.gdc_gain) > 1.1  # conductance decayed, gain compensates
    err_gdc = float(np.mean(np.abs(
        np.asarray(st_r.levels_t.astype(jnp.float32) * st_r.eff_scale) - np.asarray(w))))
    assert err_nc > 2.0 * base  # drift hurt
    assert err_gdc < 0.5 * err_nc  # GDC recovered most of it


def test_drift_keeps_program_time_image_grid(rng):
    """The image grid is frozen at program time: drifting with a *different*
    AIMCConfig (different image_gain) must not re-image the array on the
    caller's grid — t=0 drift under any cfg is a no-op on levels_t."""
    cfg_prog = AIMCConfig(prog_noise_sigma=0.01)  # image_gain 8
    cfg_other = AIMCConfig(prog_noise_sigma=0.03)  # image_gain 7
    assert AD.image_gain(cfg_prog) != AD.image_gain(cfg_other)
    w = jax.random.normal(rng, (48, 24)) * 0.1
    st = AD.program(rng, w, cfg_prog)
    st2 = AD.drift_to(st, 0.0, cfg_other)
    np.testing.assert_array_equal(np.asarray(st.levels_t),
                                  np.asarray(st2.levels_t))


def test_lifecycle_requires_device_state(rng):
    """Legacy {'hw': dict} trees count as programmed (no re-programming)
    but cannot be aged — engine.drift_to must raise, not silently no-op."""
    from repro.engine import XpikeformerEngine

    legacy = {"lin": {"hw": {"levels": jnp.ones((4, 2)), "eps": jnp.zeros((4, 2)),
                             "nu": jnp.zeros((4, 2)), "scale": jnp.ones(2)},
                      "b": jnp.zeros(2)}}
    assert AD.is_programmed(legacy) and not AD.has_device_state(legacy)
    eng = XpikeformerEngine.from_config("xpikeformer-vit-smoke",
                                        backend="integer")
    with pytest.raises(ValueError, match="device clock"):
        eng.drift_to(60.0, params=legacy)
    with pytest.raises(ValueError, match="device clock"):
        eng.recalibrate(params=legacy)


def test_drift_tree_and_device_time(rng):
    tree = {"a": {"w": jax.random.normal(rng, (12, 6)), "b": jnp.zeros(6)}}
    pt = AD.program_tree(rng, tree, CFG)
    assert AD.device_time(pt) == 0.0
    pt2 = AD.drift_tree(pt, 123.0, CFG)
    assert AD.device_time(pt2) == 123.0
    pt3 = AD.recalibrate_tree(pt2, CFG)
    assert float(pt3["a"]["hw"].gdc_gain) > 1.0
    # jitted variants agree with the eager ones
    pt4 = AD.drift_tree_jit(pt, jnp.float32(123.0), CFG)
    for a, b in zip(jax.tree.leaves(pt2), jax.tree.leaves(pt4)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Kernel-vs-oracle bit-exactness (the programmed-state Pallas path)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(64, 32), (70, 40), (128, 128), (300, 17)])
def test_drift_requantize_kernel_bit_exact(shape, rng):
    """Pallas fold kernel == jnp oracle == device drift_to, any shape/t."""
    _, st = _state(rng, shape)
    for t in (0.0, 60.0, 3600.0, 1e6, 3.15e7):
        want = KREF.drift_requantize_ref(st.levels, st.eps, st.nu, t,
                                         t0=CFG.drift_t0_s,
                                         img_gain=AD.image_gain(CFG))
        got = KOPS.drift_requantize(st.levels, st.eps, st.nu, jnp.float32(t),
                                    t0=CFG.drift_t0_s,
                                    img_gain=AD.image_gain(CFG))
        dev = AD.drift_to(st, t, CFG).levels_t
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))
        np.testing.assert_array_equal(np.asarray(want), np.asarray(dev))


def test_programmed_spiking_linear_kernel_bit_exact(rng):
    """Fold + int8 matmul/LIF pallas path == programmed-state oracle."""
    _, st = _state(rng, (70, 40))
    sp = (jax.random.uniform(jax.random.fold_in(rng, 2), (3, 5, 70)) < 0.4
          ).astype(jnp.float32)
    bias = jax.random.normal(jax.random.fold_in(rng, 3), (40,)) * 0.1
    st = AD.recalibrate(AD.drift_to(st, 7200.0, CFG), CFG)
    for t in (0.0, 7200.0, 1e6):
        want = KREF.aimc_programmed_linear_ref(
            sp, st.levels, st.eps, st.nu, st.scale, t, st.gdc_gain, bias,
            t0=CFG.drift_t0_s, img_gain=AD.image_gain(CFG))
        got = KOPS.aimc_spiking_linear_programmed(
            sp, st.levels, st.eps, st.nu, st.scale, jnp.float32(t),
            st.gdc_gain, bias, t0=CFG.drift_t0_s, img_gain=AD.image_gain(CFG))
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


def test_cached_fold_matches_oracle(rng):
    """The production path (cached levels_t/eff_scale into the int8 matmul
    kernel) == the fold-on-the-fly oracle at the state's own t."""
    _, st = _state(rng, (70, 40))
    st = AD.recalibrate(AD.drift_to(st, 86400.0, CFG), CFG)
    sp = (jax.random.uniform(jax.random.fold_in(rng, 2), (4, 3, 70)) < 0.5
          ).astype(jnp.float32)
    want = KREF.aimc_programmed_linear_ref(
        sp, st.levels, st.eps, st.nu, st.scale, float(st.t_seconds),
        st.gdc_gain, None, t0=CFG.drift_t0_s, img_gain=AD.image_gain(CFG))
    got = KOPS.aimc_spiking_linear(sp, st.levels_t, st.eff_scale, None)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


# ---------------------------------------------------------------------------
# Full-model drift behaviour (paper §V-B) + backend parity
# ---------------------------------------------------------------------------


def _programmed_engines(rng, backend):
    from repro.engine import XpikeformerEngine

    acfg = AIMCConfig(drift_nu_sigma=0.005, prog_noise_sigma=0.01)
    eng = XpikeformerEngine.from_config("xpikeformer-gpt-smoke",
                                        backend=backend, aimc_cfg=acfg)
    eng.init(rng)
    eng.program(jax.random.fold_in(rng, 3))
    return eng, acfg


@pytest.mark.parametrize("backend", ["integer", "reference"])
def test_drift_degrades_and_gdc_recovers_logits(backend, rng):
    """Accuracy-vs-t lifecycle on the paper models: logit error vs the
    freshly-programmed model grows with device time without GDC, and
    recalibration recovers part of it (§V-B behaviour).

    The paper models execute *every* linear — including the classifier
    head — through the AIMC crossbars, so the shared-ADC bin noise floors
    the achievable recovery here; the quantitative >= half-recovery bound
    lives in the serving soak test (``test_serving.py``), where the LM
    unembed is digital as in the serving engine."""
    from repro.data.icl_mimo import MIMOConfig, sample_batch as mimo_batch

    eng, _ = _programmed_engines(rng, backend)
    x = mimo_batch(jax.random.fold_in(rng, 1), MIMOConfig(), 4)["features"]
    fwd_rng = jax.random.fold_in(rng, 2)
    l0 = eng.forward(x, fwd_rng)

    errs_nc = {}
    for t in (3600.0, 86400.0, 2.6e6):
        eng.drift_to(t)
        errs_nc[t] = float(jnp.mean(jnp.abs(eng.forward(x, fwd_rng) - l0)))
    assert errs_nc[2.6e6] > errs_nc[3600.0] > 0.0, \
        "drift should degrade monotonically"
    # recalibrate after an hour of drift: GDC folds the measured gain back
    eng.drift_to(3600.0)
    eng.recalibrate()
    err_gdc = float(jnp.mean(jnp.abs(eng.forward(x, fwd_rng) - l0)))
    assert err_gdc < errs_nc[3600.0], "GDC must recover logit error"


def test_programmed_lifecycle_pallas_bit_exact_vs_integer(rng):
    """integer == pallas bit-for-bit at every lifecycle point (program,
    drift, recalibrate) through a full model forward."""
    from repro.data.icl_mimo import MIMOConfig, sample_batch as mimo_batch
    from repro.engine import XpikeformerEngine

    x = mimo_batch(jax.random.fold_in(rng, 1), MIMOConfig(), 4)["features"]
    ei, acfg = _programmed_engines(rng, "integer")
    ep = XpikeformerEngine.from_config("xpikeformer-gpt-smoke",
                                       backend="pallas", aimc_cfg=acfg)
    ep.sim = dataclasses.replace(ep.sim, wmode="hw")
    for stage in ("programmed", "drifted", "recalibrated"):
        if stage == "drifted":
            ei.drift_to(86400.0)
        elif stage == "recalibrated":
            ei.recalibrate()
        ep.params = ei.params
        li = ei.forward(x, jax.random.fold_in(rng, 2))
        lp = ep.forward(x, jax.random.fold_in(rng, 2))
        np.testing.assert_array_equal(np.asarray(li), np.asarray(lp),
                                      err_msg=f"diverged at {stage}")


def test_forward_metering_reports_energy(rng):
    """engine.forward(metering=True): measured spike counts -> joules."""
    from repro.engine import XpikeformerEngine

    eng = XpikeformerEngine.from_config("xpikeformer-gpt-smoke",
                                        backend="integer")
    eng.init(rng)
    from repro.data.icl_mimo import MIMOConfig, sample_batch as mimo_batch

    x = mimo_batch(jax.random.fold_in(rng, 1), MIMOConfig(), 2)["features"]
    logits, report = eng.forward(x, jax.random.fold_in(rng, 2), metering=True)
    plain = eng.forward(x, jax.random.fold_in(rng, 2))
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(plain))
    d = report.as_dict()
    assert d["aimc_pj"] > 0 and d["ssa_pj"] > 0 and d["lif_pj"] > 0
    assert d["total_j"] > 0 and d["spikes_in"] > 0 and report.calls > 0
