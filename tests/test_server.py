"""HTTP/SSE front door: differential bit-exactness vs the direct scheduler,
energy-budget throttling/preemption, PagePool backpressure, load shedding.

The serving front door (:mod:`repro.server`) must be a *transparent* layer:
whatever it does to a request — queueing, interleaved admission, energy
throttling, preemption + re-admission — the streamed token ids must equal a
direct in-process ``BatchScheduler`` run of the same (params, prompt, seed).
That purity is what makes the async stack testable at all, so almost every
test here ends in an exact-sequence comparison.

Runs on the CI backend matrix (``engine_backend``): the transport and
admission layers are substrate-generic, so each leg exercises its own
backend end to end (reference | integer | pallas).
"""

import asyncio
import json

import jax
import numpy as np
import pytest

from repro.configs.registry import reduced_config
from repro.engine import get_backend
from repro.models import transformer as T
from repro.server import (
    FrontDoor,
    HttpFrontDoor,
    QueueFull,
    TenantPolicy,
    read_sse,
)
from repro.server import admission as ADM
from repro.serving import BatchScheduler

SPIKING = "xpikeformer-gpt-4-256"


@pytest.fixture(scope="module")
def spiking_setup():
    cfg = reduced_config(SPIKING)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


@pytest.fixture(scope="module")
def dense_sched(spiking_setup, engine_backend):
    cfg, params = spiking_setup
    return BatchScheduler(params, cfg, get_backend(engine_backend),
                          slots=2, cache_len=32)


@pytest.fixture(scope="module")
def paged_sched(spiking_setup, engine_backend):
    cfg, params = spiking_setup
    return BatchScheduler(params, cfg, get_backend(engine_backend),
                          slots=3, cache_len=32, paged=True, page_len=8,
                          n_pages=8)  # 6 usable: null/trash reserved


def _prompt(i, length=5):
    return list(range(3 + i, 3 + i + length))


def _oracle(sch, jobs):
    """Direct in-process run of (prompt, max_new, seed) jobs on ``sch``.

    Also the jit warmup for the front-door runs: compiled steps are
    per-scheduler-instance, so the oracle and the front door must share
    one."""
    sch.reset()
    rids = [sch.submit(p, mn, seed=s) for p, mn, s in jobs]
    outs = sch.run()
    res = [list(outs[r]) for r in rids]
    sch.reset()
    return res


# -- differential: HTTP/SSE == direct scheduler ---------------------------


async def _sse_generate(host, port, prompt, max_new, seed):
    """POST /generate over a real socket; returns (token list, done dict)."""
    body = json.dumps({"prompt": prompt, "max_new": max_new,
                       "seed": seed}).encode()
    reader, writer = await asyncio.open_connection(host, port)
    try:
        writer.write(
            (f"POST /generate HTTP/1.1\r\nHost: {host}\r\n"
             f"Content-Type: application/json\r\n"
             f"Content-Length: {len(body)}\r\n\r\n").encode() + body)
        await writer.drain()
        toks, done = [], None
        async for ev, payload in read_sse(reader):
            if ev == "token":
                assert payload["index"] == len(toks)  # in-order, gapless
                toks.append(payload["token"])
            elif ev == "done":
                done = payload
        return toks, done
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionError, OSError):
            pass


def _http_differential(sch, jobs):
    want = _oracle(sch, jobs)

    async def go():
        async with HttpFrontDoor(FrontDoor(sch), port=0) as srv:
            return await asyncio.gather(*(
                _sse_generate(srv.host, srv.port, p, mn, s)
                for p, mn, s in jobs))

    got = asyncio.run(go())
    for (toks, done), want_toks in zip(got, want):
        assert toks == want_toks  # bit-exact through queue + SSE transport
        assert done is not None and done["tokens"] == want_toks
        assert done["ttft_s"] >= 0 and done["latency_s"] >= done["ttft_s"]
    return got


def test_http_sse_matches_direct_dense(dense_sched):
    """4 concurrent SSE streams over 2 slots == the direct scheduler."""
    jobs = [(_prompt(i), 4 + (i % 2), 20 + i) for i in range(4)]
    _http_differential(dense_sched, jobs)


def test_http_sse_matches_direct_paged(paged_sched):
    """Same contract over the paged spike-train KV cache."""
    jobs = [(_prompt(i), 4, 40 + i) for i in range(3)]
    _http_differential(paged_sched, jobs)


def test_http_stats_and_errors(dense_sched):
    """GET /stats surfaces j_per_token; malformed/unknown routes get 4xx."""
    sch = dense_sched
    _oracle(sch, [(_prompt(0), 3, 7)])  # warm + leave stats reset

    async def go():
        async with HttpFrontDoor(FrontDoor(sch), port=0) as srv:
            toks, _done = await _sse_generate(srv.host, srv.port,
                                              _prompt(0), 3, 7)
            assert len(toks) == 3

            async def raw(req: bytes) -> bytes:
                reader, writer = await asyncio.open_connection(
                    srv.host, srv.port)
                writer.write(req)
                await writer.drain()
                data = await reader.read()
                writer.close()
                return data

            stats = await raw(b"GET /stats HTTP/1.1\r\n\r\n")
            assert b"200" in stats.split(b"\r\n", 1)[0]
            payload = json.loads(stats.split(b"\r\n\r\n", 1)[1])
            assert payload["scheduler"]["decoded_tokens"] >= 3
            assert "j_per_token" in payload["scheduler"]
            assert payload["completed"] >= 1

            bad = await raw(b"POST /generate HTTP/1.1\r\n"
                            b"Content-Length: 2\r\n\r\n{}")
            assert b"400" in bad.split(b"\r\n", 1)[0]
            lost = await raw(b"GET /nope HTTP/1.1\r\n\r\n")
            assert b"404" in lost.split(b"\r\n", 1)[0]
            wrong = await raw(b"GET /generate HTTP/1.1\r\n\r\n")
            assert b"405" in wrong.split(b"\r\n", 1)[0]

    asyncio.run(go())


def test_http_metrics_scrape_live(paged_sched):
    """GET /metrics serves well-formed Prometheus text while SSE streams are
    in flight, and the scrape never perturbs the token streams (runs on the
    CI backend matrix)."""
    sch = paged_sched
    jobs = [(_prompt(i), 4, 50 + i) for i in range(2)]
    want = _oracle(sch, jobs)

    async def raw_get(host, port, path):
        reader, writer = await asyncio.open_connection(host, port)
        writer.write(f"GET {path} HTTP/1.1\r\n\r\n".encode())
        await writer.drain()
        data = await reader.read()
        writer.close()
        return data

    async def go():
        async with HttpFrontDoor(FrontDoor(sch), port=0) as srv:
            gens = [asyncio.create_task(
                _sse_generate(srv.host, srv.port, p, mn, s))
                for p, mn, s in jobs]
            live = await raw_get(srv.host, srv.port, "/metrics")
            got = await asyncio.gather(*gens)
            done = await raw_get(srv.host, srv.port, "/metrics")
            return live, done, got

    live, done, got = asyncio.run(go())
    for data in (live, done):
        head, _, _body = data.partition(b"\r\n\r\n")
        assert b"200" in head.split(b"\r\n", 1)[0]
        assert b"text/plain; version=0.0.4" in head
    text = done.partition(b"\r\n\r\n")[2].decode()
    assert "# TYPE xpike_decode_step_seconds histogram" in text
    assert "xpike_decode_steps_total" in text
    assert 'xpike_admission_decisions_total{decision="admit"' in text
    for (toks, _), want_toks in zip(got, want):
        assert toks == want_toks  # scraping never perturbs the stream


# -- energy SLOs: throttle, preempt, re-admit -----------------------------


def test_energy_budget_defers_until_granted(dense_sched):
    """A tenant with an empty joule bucket is throttled (defer:energy), and
    its request proceeds — bit-exact — once credit is granted."""
    sch = dense_sched
    jobs = [(_prompt(0), 4, 91)]
    want = _oracle(sch, jobs)[0]

    async def go():
        front = FrontDoor(sch, policies={
            "broke": TenantPolicy(energy_budget_j=1e-30, refill_j_per_s=0.0,
                                  preempt=False)})
        front.adm.tenant("broke").credit_j = 0.0  # bucket already drained
        await front.start()
        try:
            ts = await front.submit(*jobs[0][:2], seed=jobs[0][2],
                                    tenant="broke")
            await asyncio.sleep(0.3)  # pump runs; request must stay parked
            assert ts.result is None
            tags = [r.decision
                    for r in front.adm.decisions(ts.request_id)]
            assert ADM.DEFER_ENERGY in tags
            front.adm.grant("broke", 1.0)  # ample credit: finish unthrottled
            toks = await ts.tokens()
            assert toks == want
            assert ts.result.preemptions == 0
        finally:
            await front.stop()

    asyncio.run(go())


def test_energy_preemption_readmits_bit_exact(dense_sched):
    """A budget below the request's total cost forces preempt -> re-admit
    cycles (with periodic top-ups); the client stream must still be the
    exact oracle sequence, each streak making forward progress."""
    sch = dense_sched
    jobs = [(_prompt(1), 6, 77)]
    want = _oracle(sch, jobs)[0]
    full_j = None  # measured below; spiking archs meter > 0
    sch.reset()
    rid = sch.submit(*jobs[0][:2], seed=jobs[0][2])
    sch.run()
    full_j = sch.request_energy_j[rid]
    sch.reset()
    if full_j <= 0:
        pytest.skip("backend books no energy; preemption trigger needs a meter")

    async def go():
        front = FrontDoor(sch, policies={
            "metered": TenantPolicy(energy_budget_j=full_j * 0.4,
                                    refill_j_per_s=0.0)})
        await front.start()
        try:
            ts = await front.submit(*jobs[0][:2], seed=jobs[0][2],
                                    tenant="metered")

            async def topup():
                while front._requests[ts.request_id].result is None:
                    await asyncio.sleep(0.05)
                    if front.adm.tenant("metered").credit_j <= 0:
                        front.adm.grant("metered", full_j * 0.4)

            task = asyncio.create_task(topup())
            toks = await ts.tokens()
            task.cancel()
            assert toks == want  # replay after each preempt is invisible
            assert ts.result.preemptions >= 1
            tags = [r.decision for r in front.adm.decisions(ts.request_id)]
            assert ADM.PREEMPT_ENERGY in tags and ADM.READMIT in tags
        finally:
            await front.stop()

    asyncio.run(go())


# -- PagePool backpressure ------------------------------------------------


def test_pagepool_backpressure_defers_then_completes(paged_sched):
    """Requests whose worst-case reservations exceed the free pool are held
    at the front door (defer:pages) and admitted as pages free up; every
    stream still matches the oracle."""
    sch = paged_sched
    # worst case ceil((5-1+20)/8) = 3 pages each over a 6-usable-page pool:
    # two requests exhaust the pages while the third slot is still free, so
    # the burst must hit the pages gate (not the slots gate) before the
    # last request is admitted
    jobs = [(_prompt(i), 20, 60 + i) for i in range(3)]
    want = _oracle(sch, jobs)

    async def go():
        front = FrontDoor(sch)
        await front.start()
        try:
            streams = [await front.submit(p, mn, seed=s)
                       for p, mn, s in jobs]
            got = [await ts.tokens() for ts in streams]
            assert got == want
            tags = [r.decision for r in front.adm.records]
            assert ADM.DEFER_PAGES in tags  # backpressure actually engaged
        finally:
            await front.stop()

    asyncio.run(go())


# -- load shedding and validation -----------------------------------------


def test_queue_full_sheds_and_records(dense_sched):
    sch = dense_sched
    sch.reset()

    async def go():
        front = FrontDoor(sch, max_queue=1)
        # not started: nothing drains the queue, so the second submit sheds
        await front.submit(_prompt(0), 4, seed=1)
        with pytest.raises(QueueFull):
            await front.submit(_prompt(1), 4, seed=2)
        assert any(r.decision == ADM.DEFER_QUEUE
                   for r in front.adm.records)

    asyncio.run(go())
    sch.reset()


def test_submit_validation(dense_sched):
    sch = dense_sched
    sch.reset()

    async def go():
        front = FrontDoor(sch)
        with pytest.raises(ValueError):
            await front.submit([], 4, seed=1)  # empty prompt
        with pytest.raises(ValueError):
            await front.submit(_prompt(0), 0, seed=1)  # no tokens asked
        with pytest.raises(ValueError):
            # prompt + max_new overruns cache_len=32
            await front.submit(list(range(1, 30)), 16, seed=1)

    asyncio.run(go())


def test_priority_admits_before_fairness(dense_sched):
    """With one free slot and two queued tenants, the strictly-higher
    priority class is admitted first regardless of arrival order."""
    sch = dense_sched
    jobs = [(_prompt(3), 3, 31), (_prompt(4), 3, 32)]
    _oracle(sch, jobs)  # warmup only

    async def go():
        front = FrontDoor(sch, policies={
            "batch": TenantPolicy(priority=1),
            "inter": TenantPolicy(priority=0)})
        # don't start the pump yet: both requests must be queued before the
        # first admission pass so the pick order is observable
        lo = await front.submit(*jobs[0][:2], seed=jobs[0][2], tenant="batch")
        hi = await front.submit(*jobs[1][:2], seed=jobs[1][2], tenant="inter")
        await front.start()
        try:
            await asyncio.gather(lo.tokens(), hi.tokens())
            admits = [r for r in front.adm.records
                      if r.decision == ADM.ADMIT]
            assert [r.tenant for r in admits[:2]] == ["inter", "batch"]
        finally:
            await front.stop()

    asyncio.run(go())
