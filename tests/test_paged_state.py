"""Slot/page surgery: DecodeState + PagedDecodeState state management.

Direct unit coverage of the serving-state primitives (satellite of the
paged-KV-cache PR):

* eviction really releases state — paged slots refcount-release their
  pages and freed pages are zeroed on device (spiking comparators see
  nothing); dense ANN slots become unreachable via ``pos = 0``;
* splice round-trips through both cache stackings (``periods`` scan leaves
  and unrolled ``remainder`` leaves);
* the page economics guard rails: double-free, use-after-free retain,
  evicting an unoccupied slot, and foreign-page release all raise;
* pool page copy (copy-on-write) keeps exactly the valid prefix.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import reduced_config
from repro.engine import IntegerBackend
from repro.models import transformer as T
from repro.serving import (NULL_PAGE, RESERVED_PAGES, TRASH_PAGE,
                           BatchScheduler, PagePool, init_paged_state,
                           init_state, paged_admit_slot, paged_release_slot,
                           pool_copy_page, pool_zero_pages, release_slot,
                           slot_slice, splice_request)

SPIKING = "xpikeformer-gpt-4-256"
ANN = "yi-9b"


def _pages_first(leaf) -> np.ndarray:
    """Pool leaf -> numpy with the physical-page axis leading (the page
    axis sits at -5 in both the periods and remainder stackings)."""
    return np.moveaxis(np.asarray(leaf), -5, 0)


@pytest.fixture(scope="module")
def spiking_cfg():
    return reduced_config(SPIKING)


@pytest.fixture(scope="module")
def remainder_cfg():
    """A spiking SSA config whose depth does not divide its period, so the
    cache carries BOTH stackings: scan-stacked ``periods`` leaves and
    unrolled ``remainder`` leaves."""
    base = reduced_config(SPIKING)
    cfg = dataclasses.replace(base, name="xpike-remainder-smoke",
                              block_pattern=("attn", "attn"), num_layers=3)
    cfg = cfg.validate()
    assert cfg.num_periods == 1 and cfg.remainder_layers == 1
    return cfg


# ---------------------------------------------------------------------------
# Dense slot surgery
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", [SPIKING, ANN])
def test_dense_eviction_releases_state(arch):
    """Evicted dense slots are zeroed: spiking KV trains read as no spikes,
    ANN caches make stale keys unreachable (``pos = 0``)."""
    cfg = reduced_config(arch)
    st = init_state(cfg, 2, 16)
    one = jax.tree.map(lambda a: jnp.ones_like(a), T.init_cache(cfg, 1, 16))
    st = splice_request(st, 1, one, jnp.int32(7), jnp.uint32(3))
    st = release_slot(st, 1)
    assert not bool(st.active[1])
    for leaf in jax.tree.leaves(slot_slice(st.cache, 1)):
        assert float(jnp.abs(leaf.astype(jnp.float32)).sum()) == 0.0, \
            "evicted slot retains cache state"


def test_dense_splice_roundtrips_both_stackings(remainder_cfg):
    """slot_splice/slot_slice invert through periods AND remainder leaves."""
    cfg = remainder_cfg
    st = init_state(cfg, 3, 16)
    one = T.init_cache(cfg, 1, 16)
    one = jax.tree.map(
        lambda a: (jnp.arange(a.size, dtype=jnp.float32) % 2).reshape(a.shape
                                                                      ).astype(a.dtype), one)
    assert "periods" in one and "remainder" in one
    st = splice_request(st, 2, one, jnp.int32(5), jnp.uint32(9))
    got = slot_slice(st.cache, 2)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(one)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b.astype(a.dtype)))
    # other slots untouched
    for leaf in jax.tree.leaves(slot_slice(st.cache, 0)):
        assert float(jnp.abs(leaf.astype(jnp.float32)).sum()) == 0.0


# ---------------------------------------------------------------------------
# Paged slot surgery
# ---------------------------------------------------------------------------


def test_paged_pool_schema_covers_both_stackings(remainder_cfg):
    pool = T.init_paged_pool(remainder_cfg, 6, 8)
    assert "periods" in pool and "remainder" in pool
    assert pool["periods"]["blk0"]["kp"].ndim == 6  # [layers, P, T, KV, pl, hd]
    assert pool["remainder"]["blk0"]["kp"].ndim == 5


def test_paged_eviction_releases_and_zeroes_pages(spiking_cfg):
    """Through a real scheduler run: after eviction the slot's exclusive
    pages return to the free list zeroed; prefix-cached pages survive
    exactly once each."""
    cfg = spiking_cfg
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    sch = BatchScheduler(params, cfg, IntegerBackend(), slots=2, cache_len=32,
                         paged=True, page_len=8)
    rid = sch.submit(list(range(3, 14)), 3, seed=1)  # n_ctx=10: 2 pages
    sch.run()
    # slot released: only prefix-cache references may remain
    live = sch.pages.refcount[RESERVED_PAGES:]
    assert int((live > 0).sum()) == sch.pages.prefix_len()
    assert (live <= 1).all(), "evicted slot left extra page references"
    # every free page is zeroed on device
    table = np.asarray(sch.state.page_table)
    assert (table == NULL_PAGE).all()
    free_mask = np.ones(sch.n_pages, bool)
    free_mask[:RESERVED_PAGES] = False
    free_mask[np.asarray(sch.pages.refcount) > 0] = False
    for leaf in jax.tree.leaves(sch.state.pool):
        arr = _pages_first(leaf)
        assert arr[free_mask].sum() == 0, "freed page not zeroed"
    assert len(sch.outputs[rid]) == 3


def test_paged_admit_release_roundtrip(spiking_cfg):
    st = init_paged_state(spiking_cfg, 2, 32, 8, 10)
    row = jnp.asarray([3, 4, NULL_PAGE, NULL_PAGE], jnp.int32)
    st = paged_admit_slot(st, 1, row, jnp.uint32(7), jnp.int32(16))
    assert bool(st.active[1]) and int(st.pos[1]) == 16
    np.testing.assert_array_equal(np.asarray(st.page_table[1]), np.asarray(row))
    st = paged_release_slot(st, 1)
    assert not bool(st.active[1]) and int(st.pos[1]) == 0
    assert (np.asarray(st.page_table[1]) == NULL_PAGE).all()


def test_pool_copy_page_keeps_valid_prefix(spiking_cfg):
    """Copy-on-write semantics: the copy carries in-page positions below
    ``keep_upto`` and zeros above (the new owner's unwritten tail must stay
    comparator-masked)."""
    st = init_paged_state(spiking_cfg, 1, 32, 8, 6)
    ones = jax.tree.map(lambda a: jnp.ones_like(a), st.pool)
    st = dataclasses.replace(st, pool=ones)
    st = pool_copy_page(st, jnp.int32(3), jnp.int32(4), jnp.int32(5))
    for leaf in jax.tree.leaves(st.pool):
        arr = _pages_first(leaf)  # [P, ..., page_len, hd]
        assert (arr[4, ..., :5, :] == 1).all(), "valid prefix lost in CoW"
        assert (arr[4, ..., 5:, :] == 0).all(), "CoW leaked the stale tail"
        assert (arr[3] == 1).all(), "CoW touched the source page"


def test_pool_zero_pages(spiking_cfg):
    st = init_paged_state(spiking_cfg, 1, 32, 8, 6)
    st = dataclasses.replace(
        st, pool=jax.tree.map(lambda a: jnp.ones_like(a), st.pool))
    st = pool_zero_pages(st, jnp.asarray([2, 5], jnp.int32))
    for leaf in jax.tree.leaves(st.pool):
        arr = _pages_first(leaf)
        assert arr[[2, 5]].sum() == 0 and (arr[[3, 4]] == 1).all()


# ---------------------------------------------------------------------------
# PagePool guard rails
# ---------------------------------------------------------------------------


def test_pagepool_double_free_raises():
    pool = PagePool(8, 8)
    pid = pool.alloc()
    assert pool.release(pid) is True
    with pytest.raises(ValueError, match="double free"):
        pool.release(pid)


def test_pagepool_use_after_free_raises():
    pool = PagePool(8, 8)
    pid = pool.alloc()
    pool.release(pid)
    with pytest.raises(ValueError, match="use-after-free"):
        pool.retain(pid)


def test_pagepool_reserved_pages_are_immortal():
    pool = PagePool(8, 8)
    for pid in (NULL_PAGE, TRASH_PAGE):
        with pytest.raises(ValueError):
            pool.release(pid)
        with pytest.raises(ValueError):
            pool.retain(pid)


def test_pagepool_reservations_gate_alloc():
    pool = PagePool(RESERVED_PAGES + 3, 8)
    pool.reserve(2)
    assert pool.available() == 1
    with pytest.raises(RuntimeError, match="reservation"):
        pool.reserve(2)
    a = pool.alloc(reserved=True)
    assert pool.available() == 1 and pool.free_pages == 2
    pool.release(a)


def test_pagepool_prefix_cache_lru_and_refcounts():
    pool = PagePool(RESERVED_PAGES + 4, 8)
    pids = [pool.alloc() for _ in range(3)]
    chains = []
    for i, pid in enumerate(pids):
        chains.append(pool.prefix_register(("k", i), pid, chain=True))
        pool.release(pid)  # slot drops its ref; cache keeps the page alive
    assert pool.free_pages == 1
    assert chains == sorted(chains) and len(set(chains)) == 3  # fresh ids
    hit = pool.prefix_lookup(("k", 0))  # refreshes LRU position
    assert hit == (pids[0], chains[0])
    # re-registering an existing key retains nothing, returns the canonical id
    assert pool.prefix_register(("k", 0), pids[0], chain=True) == chains[0]
    pool.release(pids[0])
    # eviction walks LRU: entry 1 is now the oldest
    freed = pool.prefix_evict(1)
    assert freed == [pids[1]]
    assert not pool.prefix_contains(("k", 1)) and pool.prefix_contains(("k", 0))


def test_scheduler_evict_unoccupied_slot_raises(spiking_cfg):
    params = T.init_params(jax.random.PRNGKey(0), spiking_cfg)
    for paged in (False, True):
        sch = BatchScheduler(params, spiking_cfg, IntegerBackend(), slots=2,
                             cache_len=32, paged=paged, page_len=8)
        rid = sch.submit([3, 4, 5], 2, seed=0)
        sch.run()
        assert len(sch.outputs[rid]) == 2
        with pytest.raises(ValueError, match="use-after-evict"):
            sch.evict(0)  # the run already evicted the finished slot