"""Data pipeline determinism + task dataset correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.icl_mimo import MIMOConfig, ber, class_bits, sample_batch as mimo_batch
from repro.data.pipeline import DataConfig, MarkovStream, abstract_batch, abstract_inputs
from repro.data.synthetic_images import ImageConfig, sample_batch as img_batch


def test_pipeline_seekable_and_deterministic():
    cfg = DataConfig(vocab_size=257, seq_len=16, global_batch=4, seed=5)
    a, b = MarkovStream(cfg), MarkovStream(cfg)
    for step in (0, 3, 100):
        np.testing.assert_array_equal(np.asarray(a.batch_at(step)["tokens"]),
                                      np.asarray(b.batch_at(step)["tokens"]))
    assert not np.array_equal(np.asarray(a.batch_at(0)["tokens"]),
                              np.asarray(a.batch_at(1)["tokens"]))


def test_pipeline_host_slice_partitions():
    cfg = DataConfig(vocab_size=64, seq_len=8, global_batch=8)
    s = MarkovStream(cfg)
    batch = s.batch_at(0)
    parts = [s.host_slice(batch, i, 4)["tokens"] for i in range(4)]
    np.testing.assert_array_equal(np.concatenate([np.asarray(p) for p in parts]),
                                  np.asarray(batch["tokens"]))


def test_markov_stream_is_learnable():
    """Order-2 structure: conditional entropy < marginal entropy."""
    cfg = DataConfig(vocab_size=64, seq_len=256, global_batch=16, seed=0)
    toks = np.asarray(MarkovStream(cfg).batch_at(0)["tokens"]).reshape(-1)
    marg = np.bincount(toks, minlength=64) + 1e-9
    pm = marg / marg.sum()
    h_marg = -(pm * np.log(pm)).sum()
    # entropy of next given prev (order-1 proxy)
    joint = np.zeros((64, 64)) + 1e-9
    for a, b in zip(toks[:-1], toks[1:]):
        joint[a, b] += 1
    pj = joint / joint.sum()
    cond = pj / pj.sum(1, keepdims=True)
    h_cond = -(pj * np.log(cond)).sum()
    assert h_cond < 0.9 * h_marg


def test_abstract_specs_shapes():
    b = abstract_batch(100, 4, 16)
    assert b["tokens"].shape == (4, 17)
    i = abstract_inputs(4, 16, frontend_dim=8)
    assert i["embeddings"].shape == (4, 16, 8)


def test_mimo_perfect_predictions_zero_ber(rng):
    cfg = MIMOConfig(n_tx=2, n_rx=2)
    batch = mimo_batch(rng, cfg, 8)
    logits = jax.nn.one_hot(batch["labels"], cfg.n_classes) * 10.0
    assert float(ber(logits, batch["labels"], batch["mask"], cfg)) == 0.0


def test_mimo_random_predictions_half_ber(rng):
    cfg = MIMOConfig(n_tx=2, n_rx=2)
    batch = mimo_batch(rng, cfg, 64)
    logits = jax.random.normal(rng, batch["labels"].shape + (cfg.n_classes,))
    b = float(ber(logits, batch["labels"], batch["mask"], cfg))
    assert 0.35 < b < 0.65


def test_mimo_feature_layout(rng):
    cfg = MIMOConfig(n_tx=2, n_rx=2)
    batch = mimo_batch(rng, cfg, 4)
    f = np.asarray(batch["features"])
    assert f.shape == (4, cfg.seq_len, cfg.feat_dim)
    # query positions have zero one-hot part; answer positions zero y part
    assert np.abs(f[:, 0::2, 2 * cfg.n_rx:]).sum() == 0
    assert np.abs(f[:, 1::2, : 2 * cfg.n_rx]).sum() == 0
    assert np.asarray(batch["mask"])[:, 1::2].sum() == 0


def test_class_bits_roundtrip():
    import itertools

    for c in range(16):
        bits = np.asarray(class_bits(jnp.int32(c), 2))
        assert int(sum(b << i for i, b in enumerate(bits))) == c


def test_images_batch(rng):
    cfg = ImageConfig(size=16)
    b = img_batch(rng, cfg, 8)
    assert b["images"].shape == (8, 16, 16, 3)
    assert float(b["images"].min()) >= 0.0 and float(b["images"].max()) <= 1.0
    assert int(b["labels"].max()) < cfg.num_classes
