"""Energy/latency/area model must reproduce the paper's reported numbers."""

import pytest

from repro.energy.model import (Workload, all_designs, area_xpikeformer_mm2,
                                energy_xpikeformer, latency_xpikeformer_ms, total)

W = Workload(depth=8, dim=768, tokens=196, T_xpike=7, T_snn=4, classes=1000)


def test_fig8_ratios_vit_8_768():
    d = all_designs(W)
    tx = total(d["Xpikeformer"])
    assert 9.6 <= total(d["ANN-Quant"]) / tx <= 13.5  # paper: 9.6-13x
    assert 4.5 <= total(d["ANN-Quant+AIMC"]) / tx <= 6.2  # paper: 5.4-5.9x
    assert 1.7 <= total(d["SNN-Digi-Opt"]) / tx <= 2.1  # paper: 1.8-1.9x


def test_table6_absolute_numbers():
    e = total(energy_xpikeformer(W)) / 1e9
    assert 0.25 <= e <= 0.37  # paper: 0.30 mJ
    lat = latency_xpikeformer_ms(W)["total_ms"]
    assert 1.9 <= lat <= 2.5  # paper: 2.18 ms
    params = 8 * (4 * 768 * 768 + 8 * 768 * 768) + 768 * 1000
    area = area_xpikeformer_mm2(W, params)["total_mm2"]
    assert 700 <= area <= 870  # paper: 784 mm^2


def test_fig9_breakdown():
    e = energy_xpikeformer(W)
    tc = e["compute"]
    aimc = sum(e["aimc_breakdown"].values())
    assert abs(aimc / tc - 0.784) < 0.05
    assert abs(e["ssa"] / tc - 0.189) < 0.05
    ab = e["aimc_breakdown"]
    assert abs(ab["periphery"] / aimc - 0.859) < 0.05
    assert abs(ab["adc"] / aimc - 0.020) < 0.02


def test_fig10_breakdown_and_speedups():
    lat = latency_xpikeformer_ms(W)
    assert lat["periphery_frac"] > 0.9
    assert lat["aimc_frac"] < 0.01
    assert 0.01 < lat["ssa_frac"] < 0.04
    from repro.energy import constants as C

    ann_speedup = C.GPU_ANN_VIT_8_768_MS / lat["total_ms"]
    assert 1.9 <= ann_speedup <= 2.5  # paper: 2.18x
    snn_speedup = ann_speedup * C.GPU_SNN_SLOWDOWN
    assert 6.0 <= snn_speedup <= 7.6  # paper: 6.85x


def test_energy_scales_with_T():
    import dataclasses

    hi = dataclasses.replace(W, T_xpike=14)
    assert total(energy_xpikeformer(hi)) > total(energy_xpikeformer(W))


def test_memory_energy_ann_equals_aimc():
    d = all_designs(W)
    assert d["ANN-Quant"]["memory"] == d["ANN-Quant+AIMC"]["memory"]
