"""SSD (Mamba-2) and RG-LRU correctness: chunked == naive recurrence,
decode == prefill."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import rglru as R
from repro.models import ssm as S
from repro.models.layers import init_tree


SSD_CFG = ModelConfig(
    name="t", family="ssm", num_layers=1, d_model=32, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=64, block_pattern=("ssd",), ssm_state_dim=16,
    ssm_head_dim=16, ssm_expand=2, dtype="float32",
)

LRU_CFG = ModelConfig(
    name="t", family="hybrid", num_layers=1, d_model=32, num_heads=2, num_kv_heads=1,
    d_ff=64, vocab_size=64, block_pattern=("rglru",), rglru_width=32, dtype="float32",
)


def _naive_ssd(xh, a, bmat, cmat):
    """Direct per-step recurrence h_t = a_t h + B x ; y_t = C h_t."""
    b, L, h, p = xh.shape
    g, s = bmat.shape[2], bmat.shape[3]
    hg = h // g
    bh = jnp.repeat(bmat, hg, axis=2)
    ch = jnp.repeat(cmat, hg, axis=2)

    def step(carry, t):
        st = carry * a[:, t, :, None, None] + jnp.einsum("bhs,bhp->bhsp", bh[:, t], xh[:, t])
        y = jnp.einsum("bhs,bhsp->bhp", ch[:, t], st)
        return st, y

    st0 = jnp.zeros((b, h, s, p))
    final, ys = jax.lax.scan(step, st0, jnp.arange(L))
    return jnp.moveaxis(ys, 0, 1), final


def test_ssd_chunked_matches_naive(rng):
    b, L, h, p, g, s = 2, 512, 2, 16, 1, 16
    ks = jax.random.split(rng, 4)
    xh = jax.random.normal(ks[0], (b, L, h, p)) * 0.3
    a = jax.nn.sigmoid(jax.random.normal(ks[1], (b, L, h)) + 2.0)
    bmat = jax.random.normal(ks[2], (b, L, g, s)) * 0.3
    cmat = jax.random.normal(ks[3], (b, L, g, s)) * 0.3
    y, final = S._ssd_chunked(xh, a, bmat, cmat)
    y_ref, final_ref = _naive_ssd(xh, a, bmat, cmat)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-3)
    np.testing.assert_allclose(np.asarray(final), np.asarray(final_ref), atol=2e-3)


def test_ssd_decode_matches_prefill(rng):
    b, L = 2, 8
    params = init_tree(rng, S.ssd_schema(SSD_CFG), jnp.float32)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (b, L, SSD_CFG.d_model)) * 0.5
    full, state = S.ssd_mixer(params, x, SSD_CFG, return_state=True)

    cache = jax.tree.map(
        lambda sd: jnp.zeros(sd.shape, sd.dtype) if sd.shape != () else jnp.int32(0),
        S.ssd_cache_schema(SSD_CFG, b),
    )
    outs = []
    for i in range(L):
        y, cache = S.ssd_decode(params, x[:, i : i + 1], cache, SSD_CFG)
        outs.append(y)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=3e-3)
    np.testing.assert_allclose(np.asarray(cache["ssd"]), np.asarray(state["ssd"]), atol=3e-3)


def _naive_rglru(a, bterm):
    def step(h, t):
        h = a[:, t] * h + bterm[:, t]
        return h, h

    h0 = jnp.zeros(a.shape[0:1] + a.shape[2:])
    _, hs = jax.lax.scan(step, h0, jnp.arange(a.shape[1]))
    return jnp.moveaxis(hs, 0, 1)


def test_rglru_scan_matches_sequential(rng):
    b, L, w = 2, 64, 8
    a = jax.nn.sigmoid(jax.random.normal(rng, (b, L, w)))
    bt = jax.random.normal(jax.random.fold_in(rng, 1), (b, L, w))

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, bt), axis=1)
    np.testing.assert_allclose(np.asarray(h), np.asarray(_naive_rglru(a, bt)), atol=1e-4)


def test_rglru_decode_matches_prefill(rng):
    b, L = 2, 8
    params = init_tree(rng, R.rglru_schema(LRU_CFG), jnp.float32)
    x = jax.random.normal(jax.random.fold_in(rng, 2), (b, L, LRU_CFG.d_model)) * 0.5
    full, state = R.rglru_mixer(params, x, LRU_CFG, return_state=True)
    cache = jax.tree.map(
        lambda sd: jnp.zeros(sd.shape, sd.dtype) if sd.shape != () else jnp.int32(0),
        R.rglru_cache_schema(LRU_CFG, b),
    )
    outs = []
    for i in range(L):
        y, cache = R.rglru_decode(params, x[:, i : i + 1], cache, LRU_CFG)
        outs.append(y)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=3e-4)
    np.testing.assert_allclose(np.asarray(cache["h"]), np.asarray(state["h"]), atol=3e-4)
