"""End-to-end system behaviour: launcher training, serving, dry-run plumbing."""

import json
import subprocess
import sys
from pathlib import Path

import pytest

from repro.launch import serve as LS
from repro.launch import train as LT


@pytest.mark.slow
def test_launcher_trains_and_checkpoints(tmp_path):
    losses, probe0, probe1 = LT.run(
        "granite-3-8b", steps=25, ckpt_dir=str(tmp_path), ckpt_every=10,
        log_every=0, seed=2, probe=True,
    )
    assert len(losses) == 25
    # fixed-batch probe: per-step losses are fresh batches, and the
    # tied-embedding smoke starts calibrated at the stream's entropy floor,
    # so first-vs-last fresh-batch loss is inter-batch noise (~+-0.05) while
    # the trained model's gain on a held-fixed batch is ~0.3 — deterministic.
    assert probe1 < probe0 - 0.05
    steps = {p.name for p in tmp_path.glob("step_*")}
    assert any(s.endswith("00000025") for s in steps)


@pytest.mark.slow
def test_launcher_moe_arch(tmp_path):
    losses = LT.run("phi3.5-moe-42b-a6.6b", steps=12, ckpt_dir=str(tmp_path),
                    ckpt_every=0, log_every=0)
    assert losses[-1] < losses[0] * 1.2  # moves; MoE smoke is noisy


def test_serve_continuous_batching():
    outs = LS.serve("yi-9b", n_requests=5, slots=2, max_new=4, cache_len=32)
    assert len(outs) == 5
    assert all(len(o) == 4 for o in outs)


def test_dryrun_collective_parser():
    from repro.launch.dryrun import parse_collectives

    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(bf16[8,8]{1,0} %x), replica_groups=[16,16]<=[256]
  %ar = f32[64]{0} all-reduce(f32[64]{0} %y), replica_groups={{0,1,2,3}}
  %a2a = bf16[16,640,7168]{2,1,0} all-to-all(bf16[16,640,7168]{2,1,0} %z), replica_groups=[16,16]<=[256]
"""
    bytes_by, counts = parse_collectives(hlo, 256)
    assert counts["all-gather"] == 1 and counts["all-reduce"] == 1
    assert counts["all-to-all"] == 1
    assert bytes_by["all-gather"] == pytest.approx(8 * 128 * 2 * 15 / 16)
    assert bytes_by["all-reduce"] == pytest.approx(2 * 64 * 4 * 3 / 4)


def test_dryrun_grid_results_exist():
    """The multi-pod dry-run grid must be green: every (arch x shape x mesh)
    cell either compiled or is a documented skip."""
    d = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
    if not d.exists():
        pytest.skip("dry-run grid not generated yet")
    recs = [json.loads(p.read_text()) for p in d.glob("*.json")]
    base = [r for r in recs if r.get("variant", "base") == "base" or "skipped" in r]
    compiled = [r for r in base if "skipped" not in r]
    assert len(compiled) >= 60, f"only {len(compiled)} compiled cells"
    multi = [r for r in compiled if r.get("mesh") == "multi"]
    assert len(multi) >= 30  # the pod axis shards for every runnable cell
    for r in compiled:
        assert r["flops_per_device"] > 0
        assert r["roofline_step_time_s"] > 0
