"""SSA algorithm tests: convergence to rate product, causality, baselines."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import spikes as SP
from repro.core import ssa as SSA


def _rates(key, shape):
    return jax.random.uniform(key, shape)


def test_ssa_integer_converges_to_rate(rng):
    """Property at the heart of Eq. (6): rate(SSA) -> clipped rate product."""
    b, h, n, d = 2, 2, 16, 32
    ks = jax.random.split(rng, 4)
    qr, kr, vr = (_rates(k, (b, h, n, d)) for k in ks[:3])
    expected = SSA.ssa_attention_rate(qr, kr, vr)
    errs = []
    for T in (8, 128):
        kk = jax.random.fold_in(ks[3], T)
        enc = jax.random.split(kk, 4)
        q = SP.rate_encode(enc[0], qr, T, straight_through=False).astype(jnp.int32)
        k = SP.rate_encode(enc[1], kr, T, straight_through=False).astype(jnp.int32)
        v = SP.rate_encode(enc[2], vr, T, straight_through=False).astype(jnp.int32)
        out = SSA.ssa_attention_integer(enc[3], q, k, v)
        errs.append(float(jnp.mean(jnp.abs(out.astype(jnp.float32).mean(0) - expected))))
    assert errs[1] < errs[0]  # more timesteps -> closer to the rate product
    assert errs[1] < 0.06


def test_ssa_causal_no_future_leak(rng):
    t, b, h, n, d = 4, 1, 1, 8, 32
    ks = jax.random.split(rng, 4)
    q = jax.random.bernoulli(ks[0], 0.5, (t, b, h, n, d)).astype(jnp.int32)
    k1 = jax.random.bernoulli(ks[1], 0.5, (t, b, h, n, d)).astype(jnp.int32)
    v1 = jax.random.bernoulli(ks[2], 0.5, (t, b, h, n, d)).astype(jnp.int32)
    # perturb ONLY the last token of k/v: outputs at tokens < n-1 must not move
    k2 = k1.at[..., -1, :].set(1 - k1[..., -1, :])
    v2 = v1.at[..., -1, :].set(1 - v1[..., -1, :])
    o1 = SSA.ssa_attention_integer(ks[3], q, k1, v1, causal=True)
    o2 = SSA.ssa_attention_integer(ks[3], q, k2, v2, causal=True)
    np.testing.assert_array_equal(np.asarray(o1[..., :-1, :]), np.asarray(o2[..., :-1, :]))


def test_ssa_differentiable(rng):
    t, b, h, n, d = 2, 1, 1, 4, 8
    ks = jax.random.split(rng, 3)

    def loss(x):
        q = SP.rate_encode(ks[0], jax.nn.sigmoid(x), t)
        out = SSA.ssa_attention(ks[1], q, q, q)
        return jnp.sum(out)

    g = jax.grad(loss)(jax.random.normal(ks[2], (b, h, n, d)))
    assert jnp.isfinite(g).all()
    assert float(jnp.abs(g).sum()) > 0


def test_lif_attention_baseline_shape(rng):
    t, b, h, n, d = 3, 2, 2, 8, 16
    q = jax.random.bernoulli(rng, 0.4, (t, b, h, n, d)).astype(jnp.float32)
    out = SSA.lif_spiking_attention(q, q, q, causal=True)
    assert out.shape == (t, b, h, n, d)
    assert set(np.unique(np.asarray(out))) <= {0.0, 1.0}


def test_ann_attention_matches_softmax(rng):
    b, h, n, d = 1, 1, 6, 8
    ks = jax.random.split(rng, 3)
    q, k, v = (jax.random.normal(kk, (b, h, n, d)) for kk in ks)
    out = SSA.ann_attention(q, k, v, causal=False)
    w = jax.nn.softmax(jnp.einsum("bhnd,bhmd->bhnm", q, k) / jnp.sqrt(d * 1.0), axis=-1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(jnp.einsum("bhnm,bhmd->bhnd", w, v)), rtol=1e-5)


@settings(deadline=None, max_examples=10)
@given(n=st.sampled_from([4, 8]), d=st.sampled_from([8, 16]), t=st.integers(1, 4))
def test_ssa_shapes_property(n, d, t):
    key = jax.random.PRNGKey(n * 100 + d + t)
    q = jax.random.bernoulli(key, 0.5, (t, 1, 1, n, d)).astype(jnp.int32)
    out = SSA.ssa_attention_integer(key, q, q, q)
    assert out.shape == (t, 1, 1, n, d)
    assert out.dtype == jnp.uint8
