"""Property-based differential fuzzing of the backend matrix.

Hypothesis-driven random shapes / timesteps / seeds asserting the engine's
substrate-interchangeability contract op by op:

* **pallas == integer, bit-exact, always** — the packed popcount kernels
  (full-sequence SSA, dense decode, *paged* decode with h0 head offsets)
  and the fused crossbar/LIF kernels reproduce the integer oracle exactly
  for any shape, including the padding paths (non-multiple-of-32 lane and
  position axes, GQA head groups).
* **reference joins the bit-exact set where its float math is exact** —
  LIF over identical currents, drift re-quantisation (deterministic), and
  ``spiking_linear`` whenever the float weights are exactly representable
  on the quantisation grid with a power-of-two column scale (every partial
  product and sum is then a dyadic rational inside the f32 mantissa, so
  reference == integer == pallas bit-for-bit).  For the stochastic SSA ops
  the reference backend draws *uniform-float* comparators rather than the
  LFSR integers, so it is distribution-equal but not bit-equal — those
  assertions stop at the digital pair by design (see ``repro.engine``).

Under real hypothesis (CI) each property explores randomised examples;
without it, the conftest fallback shim degrades to a deterministic,
well-spread sample of each strategy product — fixed seeds, same
assertions.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.engine import IntegerBackend, PallasBackend, ReferenceBackend
from repro.kernels import ops as KOPS
from repro.kernels import ref as KREF

INT = IntegerBackend()
PAL = PallasBackend()
REF = ReferenceBackend()

_SET = dict(max_examples=8, deadline=None)


def _key(seed):
    return jax.random.PRNGKey(seed)


def _bern(key, p, shape):
    return jax.random.bernoulli(key, p, shape).astype(jnp.uint8)


def _eq(a, b, msg):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=msg)


# ---------------------------------------------------------------------------
# SSA attention (full sequence)
# ---------------------------------------------------------------------------


@settings(**_SET)
@given(t=st.integers(1, 3), n=st.integers(1, 21), d=st.sampled_from([8, 16, 33]),
       h=st.integers(1, 2), seed=st.integers(0, 2**31 - 1),
       causal=st.booleans())
def test_ssa_attention_pallas_matches_integer(t, n, d, h, seed, causal):
    """Packed popcount SSA == integer oracle for arbitrary (T, N, D, H),
    causal or not — including N/D that exercise the zero-pad lanes."""
    ks = jax.random.split(_key(seed), 4)
    q = _bern(ks[0], 0.5, (t, 1, h, n, d))
    k = _bern(ks[1], 0.4, (t, 1, h, n, d))
    v = _bern(ks[2], 0.6, (t, 1, h, n, d))
    out_i = INT.ssa_attention(ks[3], q, k, v, causal=causal)
    out_p = PAL.ssa_attention(ks[3], q, k, v, causal=causal)
    _eq(out_i, out_p, f"ssa_attention t={t} n={n} d={d} causal={causal}")


# ---------------------------------------------------------------------------
# SSA decode — dense and paged, with TP head offsets
# ---------------------------------------------------------------------------


@settings(**_SET)
@given(t=st.integers(1, 3), l=st.sampled_from([4, 16, 33]),
       d=st.sampled_from([8, 16]), h=st.sampled_from([1, 2, 4]),
       seed=st.integers(0, 2**31 - 1), h0=st.integers(0, 5))
def test_ssa_decode_pallas_matches_integer_with_h0(t, l, d, h, seed, h0):
    """Dense decode kernel == integer oracle for any cache length / head
    count, and the ``h0`` global-head offset selects exactly the oracle's
    PRN rows (the tensor-parallel shard contract)."""
    ks = jax.random.split(_key(seed), 4)
    b = 2
    q = _bern(ks[0], 0.5, (t, b, h, 1, d))
    k = _bern(ks[1], 0.4, (t, b, h, l, d))
    v = _bern(ks[2], 0.5, (t, b, h, l, d))
    slot_keys = jax.random.randint(ks[3], (b, 2), 0, 2**31 - 1,
                                   jnp.int32).astype(jnp.uint32)
    out_i = INT.ssa_attention_decode(slot_keys, q, k, v, i_max=l, h0=h0)
    out_p = PAL.ssa_attention_decode(slot_keys, q, k, v, i_max=l, h0=h0)
    _eq(out_i, out_p, f"ssa_decode t={t} l={l} d={d} h={h} h0={h0}")
    if h % 2 == 0:  # sharding by heads reproduces the full call exactly
        half = h // 2
        parts = [
            PAL.ssa_attention_decode(
                slot_keys, q[:, :, s * half:(s + 1) * half],
                k[:, :, s * half:(s + 1) * half],
                v[:, :, s * half:(s + 1) * half], i_max=l, h0=h0 + s * half)
            for s in range(2)
        ]
        _eq(jnp.concatenate(parts, axis=2), out_p, "h0 shard split diverged")


@settings(**_SET)
@given(t=st.integers(1, 3), page_len=st.sampled_from([4, 8, 32]),
       mp=st.integers(1, 4), d=st.sampled_from([8, 16]),
       hkv=st.sampled_from([(1, 1), (2, 1), (4, 2)]),
       seed=st.integers(0, 2**31 - 1), h0=st.integers(0, 3))
def test_ssa_decode_paged_matches_integer_and_dense(t, page_len, mp, d, hkv,
                                                    seed, h0):
    """Paged decode (scalar-prefetch page gathering) == the paged integer
    oracle == the dense decode over the materialised cache, for any page
    geometry, GQA grouping, null-page pattern and head offset."""
    h, kv = hkv
    ks = jax.random.split(_key(seed), 6)
    b = 2
    n_pages = 2 + b * mp
    q = _bern(ks[0], 0.5, (t, b, h, 1, d))
    kpool = _bern(ks[1], 0.4, (n_pages, t, kv, page_len, d))
    vpool = _bern(ks[2], 0.5, (n_pages, t, kv, page_len, d))
    kpool = kpool.at[0].set(0)  # null page invariant
    vpool = vpool.at[0].set(0)
    table = jax.random.randint(ks[3], (b, mp), 0, n_pages, jnp.int32)
    table = jnp.where(jax.random.bernoulli(ks[4], 0.3, (b, mp)), 0, table)
    slot_keys = jax.random.randint(ks[5], (b, 2), 0, 2**31 - 1,
                                   jnp.int32).astype(jnp.uint32)
    i_max = mp * page_len
    out_i = INT.ssa_attention_decode_paged(slot_keys, q, kpool, vpool, table,
                                           i_max=i_max, h0=h0)
    out_p = PAL.ssa_attention_decode_paged(slot_keys, q, kpool, vpool, table,
                                           i_max=i_max, h0=h0)
    _eq(out_i, out_p, f"paged decode pl={page_len} mp={mp} h={h} kv={kv}")
    # dense equivalence over the gathered view
    kf = KOPS.gather_kv_pages(kpool, table)
    vf = KOPS.gather_kv_pages(vpool, table)
    if kv != h:
        kf = jnp.repeat(kf, h // kv, axis=2)
        vf = jnp.repeat(vf, h // kv, axis=2)
    dense = INT.ssa_attention_decode(slot_keys, q, kf, vf, i_max=i_max, h0=h0)
    _eq(out_p, dense, "paged != dense over materialised cache")


# ---------------------------------------------------------------------------
# Spiking linear (crossbar MVM + LIF) — col/row parts, all three backends
# ---------------------------------------------------------------------------


def _dyadic_weights(key, d_in, d_out, levels=15, scale=2.0**-3):
    """Float weights exactly on the quantisation grid with a power-of-two
    column scale: every backend's arithmetic is then exact, so reference
    == integer == pallas bit-for-bit (see module docstring)."""
    lv = jax.random.randint(key, (d_in, d_out), -levels, levels + 1, jnp.int32)
    lv = lv.at[0].set(levels)  # pin each column's amax to `levels`
    return (lv * scale).astype(jnp.float32)


@settings(**_SET)
@given(t=st.integers(1, 4), b=st.integers(1, 3),
       d_in=st.sampled_from([8, 24, 64]), d_out=st.sampled_from([16, 33]),
       part=st.sampled_from(["col", "row"]), seed=st.integers(0, 2**31 - 1),
       bias=st.booleans())
def test_spiking_linear_all_backends_bit_exact(t, b, d_in, d_out, part, seed,
                                               bias):
    """LIF(W s) over binary trains: all THREE backends agree bit-for-bit on
    dyadic-grid weights, for both tensor-parallel part hints and arbitrary
    (T, B, d_in, d_out) incl. pad paths."""
    ks = jax.random.split(_key(seed), 3)
    spikes = _bern(ks[0], 0.5, (t, b, d_in)).astype(jnp.float32)
    w = _dyadic_weights(ks[1], d_in, d_out)
    p = {"w": w, "b": (jnp.arange(d_out, dtype=jnp.float32) * 0.25
                       if bias else None)}
    out_r = REF.spiking_linear(None, p, spikes, part=part)
    out_i = INT.spiking_linear(None, p, spikes, part=part)
    out_p = PAL.spiking_linear(None, p, spikes, part=part)
    _eq(out_i, out_p, f"integer != pallas ({t},{b},{d_in},{d_out},{part})")
    _eq(out_r.astype(jnp.uint8), out_i,
        f"reference != integer on dyadic grid ({t},{b},{d_in},{d_out})")


@settings(**_SET)
@given(t=st.integers(1, 3), d_in=st.sampled_from([16, 48]),
       d_out=st.sampled_from([16, 40]), seed=st.integers(0, 2**31 - 1))
def test_spiking_linear_row_counts_psum_decomposition(t, d_in, d_out, seed):
    """The row-parallel decomposition contract: shard-local integer counts
    summed across an input-row split reproduce the fused kernel exactly
    (what ``distributed.ShardedBackend`` relies on for ``part='row'``)."""
    ks = jax.random.split(_key(seed), 3)
    spikes = _bern(ks[0], 0.5, (t, 2, d_in)).astype(jnp.float32)
    levels = jax.random.randint(ks[1], (d_in, d_out), -15, 16,
                                jnp.int32).astype(jnp.int8)
    scale = (jax.random.randint(ks[2], (d_out,), 1, 8, jnp.int32)
             .astype(jnp.float32) * 0.125)
    half = d_in // 2
    counts = (KOPS.aimc_matmul_counts(spikes[..., :half], levels[:half])
              + KOPS.aimc_matmul_counts(spikes[..., half:], levels[half:]))
    pre = counts * scale[None, None, :]
    split = KREF.lif_ref(pre.reshape(t, -1)).reshape(pre.shape)
    fused = KREF.aimc_spiking_linear_ref(spikes, levels, scale)
    _eq(split, fused, "row-split counts diverged from fused kernel")


# ---------------------------------------------------------------------------
# Drift re-quantisation (deterministic: kernel == oracle everywhere)
# ---------------------------------------------------------------------------


@settings(**_SET)
@given(d_in=st.sampled_from([8, 130]), d_out=st.sampled_from([16, 129]),
       t_s=st.sampled_from([0.0, 25.0, 3600.0, 86400.0]),
       img_gain=st.sampled_from([1, 4]), seed=st.integers(0, 2**31 - 1))
def test_drift_requantize_kernel_matches_ref(d_in, d_out, t_s, img_gain, seed):
    """The Pallas drift-fold kernel re-digitises drifted conductances onto
    the int8 image grid bit-identically to the oracle for any shape
    (incl. >1 tile), device age and image gain."""
    ks = jax.random.split(_key(seed), 3)
    levels = jax.random.randint(ks[0], (d_in, d_out), -15, 16,
                                jnp.int32).astype(jnp.float32)
    eps = 0.3 * jax.random.normal(ks[1], (d_in, d_out), jnp.float32)
    nu = 0.05 + 0.02 * jax.random.normal(ks[2], (d_in, d_out), jnp.float32)
    got = KOPS.drift_requantize(levels, eps, nu, jnp.float32(t_s), t0=1.0,
                                img_gain=img_gain)
    want = KREF.drift_requantize_ref(levels, eps, nu, t_s, t0=1.0,
                                     img_gain=img_gain)
    _eq(got, want, f"drift_requantize ({d_in},{d_out},t={t_s},g={img_gain})")


# ---------------------------------------------------------------------------
# LIF (deterministic: all three substrates)
# ---------------------------------------------------------------------------


@settings(**_SET)
@given(t=st.integers(1, 6), m=st.sampled_from([1, 7, 300]),
       seed=st.integers(0, 2**31 - 1))
def test_lif_all_backends_bit_exact(t, m, seed):
    """The fused-membrane kernel, the integer oracle and the reference
    surrogate-gradient LIF all emit identical spikes for identical
    currents (quarter-grid currents keep every membrane value exact)."""
    cur = (jax.random.randint(_key(seed), (t, m), -8, 9, jnp.int32)
           .astype(jnp.float32) * 0.25)
    out_i = INT.lif(cur)
    out_p = PAL.lif(cur)
    out_r = REF.lif(cur)
    _eq(out_i, out_p, f"lif integer != pallas (t={t}, m={m})")
    _eq(out_i, out_r.astype(jnp.uint8), f"lif integer != reference (t={t})")
