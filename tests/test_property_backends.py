"""Property-based differential fuzzing of the backend matrix.

Hypothesis-driven random shapes / timesteps / seeds asserting the engine's
substrate-interchangeability contract op by op:

* **pallas == integer, bit-exact, always** — the packed popcount kernels
  (full-sequence SSA, dense decode, *paged* decode with h0 head offsets)
  and the fused crossbar/LIF kernels reproduce the integer oracle exactly
  for any shape, including the padding paths (non-multiple-of-32 lane and
  position axes, GQA head groups).
* **reference joins the bit-exact set where its float math is exact** —
  LIF over identical currents, drift re-quantisation (deterministic), and
  ``spiking_linear`` whenever the float weights are exactly representable
  on the quantisation grid with a power-of-two column scale (every partial
  product and sum is then a dyadic rational inside the f32 mantissa, so
  reference == integer == pallas bit-for-bit).  For the stochastic SSA ops
  the reference backend draws *uniform-float* comparators rather than the
  LFSR integers, so it is distribution-equal but not bit-equal — those
  assertions stop at the digital pair by design (see ``repro.engine``).

Under real hypothesis (CI) each property explores randomised examples;
without it, the conftest fallback shim degrades to a deterministic,
well-spread sample of each strategy product — fixed seeds, same
assertions.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.engine import IntegerBackend, PallasBackend, ReferenceBackend
from repro.kernels import ops as KOPS
from repro.kernels import ref as KREF
from repro.kernels.plan import AttnSpec, KVView

INT = IntegerBackend()
PAL = PallasBackend()
REF = ReferenceBackend()

_SET = dict(max_examples=8, deadline=None)


def _key(seed):
    return jax.random.PRNGKey(seed)


def _bern(key, p, shape):
    return jax.random.bernoulli(key, p, shape).astype(jnp.uint8)


def _eq(a, b, msg):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=msg)


# ---------------------------------------------------------------------------
# SSA attention (full sequence)
# ---------------------------------------------------------------------------


@settings(**_SET)
@given(t=st.integers(1, 3), n=st.integers(1, 21), d=st.sampled_from([8, 16, 33]),
       h=st.integers(1, 2), seed=st.integers(0, 2**31 - 1),
       causal=st.booleans())
def test_ssa_attention_pallas_matches_integer(t, n, d, h, seed, causal):
    """Packed popcount SSA == integer oracle for arbitrary (T, N, D, H),
    causal or not — including N/D that exercise the zero-pad lanes."""
    ks = jax.random.split(_key(seed), 4)
    q = _bern(ks[0], 0.5, (t, 1, h, n, d))
    k = _bern(ks[1], 0.4, (t, 1, h, n, d))
    v = _bern(ks[2], 0.6, (t, 1, h, n, d))
    out_i = INT.ssa_attention(ks[3], q, k, v, causal=causal)
    out_p = PAL.ssa_attention(ks[3], q, k, v, causal=causal)
    _eq(out_i, out_p, f"ssa_attention t={t} n={n} d={d} causal={causal}")


# ---------------------------------------------------------------------------
# SSA decode — dense and paged, with TP head offsets
# ---------------------------------------------------------------------------


@settings(**_SET)
@given(t=st.integers(1, 3), l=st.sampled_from([4, 16, 33]),
       d=st.sampled_from([8, 16]), h=st.sampled_from([1, 2, 4]),
       seed=st.integers(0, 2**31 - 1), h0=st.integers(0, 5))
def test_ssa_decode_pallas_matches_integer_with_h0(t, l, d, h, seed, h0):
    """Dense decode kernel == integer oracle for any cache length / head
    count, and the ``h0`` global-head offset selects exactly the oracle's
    PRN rows (the tensor-parallel shard contract)."""
    ks = jax.random.split(_key(seed), 4)
    b = 2
    q = _bern(ks[0], 0.5, (t, b, h, 1, d))
    k = _bern(ks[1], 0.4, (t, b, h, l, d))
    v = _bern(ks[2], 0.5, (t, b, h, l, d))
    slot_keys = jax.random.randint(ks[3], (b, 2), 0, 2**31 - 1,
                                   jnp.int32).astype(jnp.uint32)
    view = KVView.dense(k, v)
    out_i = INT.decode_attention(view, q, AttnSpec(i_max=l, h0=h0),
                                 slot_keys=slot_keys)
    out_p = PAL.decode_attention(view, q, AttnSpec(i_max=l, h0=h0),
                                 slot_keys=slot_keys)
    _eq(out_i, out_p, f"ssa_decode t={t} l={l} d={d} h={h} h0={h0}")
    if h % 2 == 0:  # sharding by heads reproduces the full call exactly
        half = h // 2
        parts = [
            PAL.decode_attention(
                KVView.dense(k[:, :, s * half:(s + 1) * half],
                             v[:, :, s * half:(s + 1) * half]),
                q[:, :, s * half:(s + 1) * half],
                AttnSpec(i_max=l, h0=h0 + s * half), slot_keys=slot_keys)
            for s in range(2)
        ]
        _eq(jnp.concatenate(parts, axis=2), out_p, "h0 shard split diverged")


@settings(**_SET)
@given(t=st.integers(1, 3), page_len=st.sampled_from([4, 8, 32]),
       mp=st.integers(1, 4), d=st.sampled_from([8, 16]),
       hkv=st.sampled_from([(1, 1), (2, 1), (4, 2)]),
       seed=st.integers(0, 2**31 - 1), h0=st.integers(0, 3))
def test_ssa_decode_paged_matches_integer_and_dense(t, page_len, mp, d, hkv,
                                                    seed, h0):
    """Paged decode (scalar-prefetch page gathering) == the paged integer
    oracle == the dense decode over the materialised cache, for any page
    geometry, GQA grouping, null-page pattern and head offset."""
    h, kv = hkv
    ks = jax.random.split(_key(seed), 6)
    b = 2
    n_pages = 2 + b * mp
    q = _bern(ks[0], 0.5, (t, b, h, 1, d))
    kpool = _bern(ks[1], 0.4, (n_pages, t, kv, page_len, d))
    vpool = _bern(ks[2], 0.5, (n_pages, t, kv, page_len, d))
    kpool = kpool.at[0].set(0)  # null page invariant
    vpool = vpool.at[0].set(0)
    table = jax.random.randint(ks[3], (b, mp), 0, n_pages, jnp.int32)
    table = jnp.where(jax.random.bernoulli(ks[4], 0.3, (b, mp)), 0, table)
    slot_keys = jax.random.randint(ks[5], (b, 2), 0, 2**31 - 1,
                                   jnp.int32).astype(jnp.uint32)
    i_max = mp * page_len
    view = KVView.from_pool(kpool, vpool, table)
    spec = AttnSpec(i_max=i_max, h0=h0, groups=h // kv)
    out_i = INT.decode_attention(view, q, spec, slot_keys=slot_keys)
    out_p = PAL.decode_attention(view, q, spec, slot_keys=slot_keys)
    _eq(out_i, out_p, f"paged decode pl={page_len} mp={mp} h={h} kv={kv}")
    # dense equivalence over the gathered view
    kf = KOPS.gather_kv_pages(kpool, table)
    vf = KOPS.gather_kv_pages(vpool, table)
    if kv != h:
        kf = jnp.repeat(kf, h // kv, axis=2)
        vf = jnp.repeat(vf, h // kv, axis=2)
    dense = INT.decode_attention(KVView.dense(kf, vf), q,
                                 AttnSpec(i_max=i_max, h0=h0),
                                 slot_keys=slot_keys)
    _eq(out_p, dense, "paged != dense over materialised cache")


# ---------------------------------------------------------------------------
# Fused decode layer (one megakernel per decoder layer) vs the integer oracle
# ---------------------------------------------------------------------------


def _layer_ws(key, dims, bias):
    """One dyadic-grid weight dict per (d_in, d_out) stage — quarter-grid
    biases keep every backend's arithmetic exact (see module docstring)."""
    out = []
    for i, (di, do) in enumerate(dims):
        kw, kb = jax.random.split(jax.random.fold_in(key, i))
        b = (jax.random.randint(kb, (do,), -4, 5, jnp.int32)
             .astype(jnp.float32) * 0.25) if bias else None
        out.append({"w": _dyadic_weights(kw, di, do), "b": b})
    return out


def _slice_cols(p, lo, hi):
    """Column-shard one weight dict (what the TP shards hold)."""
    return {"w": p["w"][:, lo:hi],
            "b": None if p["b"] is None else p["b"][lo:hi]}


@settings(**_SET)
@given(t=st.integers(1, 2), l=st.sampled_from([4, 16]),
       hkv=st.sampled_from([(2, 2), (4, 2)]),
       seed=st.integers(0, 2**31 - 1), bias=st.booleans(), mlp=st.booleans())
def test_fused_decode_layer_dense_matches_integer_oracle(t, l, hkv, seed,
                                                         bias, mlp):
    """The dense megakernel == the integer fused-layer oracle bit-for-bit
    (residual out AND new K/V trains) for any cache length, GQA grouping,
    bias/MLP combination — and the head-sharded ``h0`` split of the
    attention stage (column-sliced Q/K/V, ``with_tail=False``) concatenates
    to the full launch exactly (the tensor-parallel shard contract)."""
    h, kv = hkv
    d, hd, d_ff = 16, 8, 24
    b = 2
    ks = jax.random.split(_key(seed), 5)
    s = _bern(ks[0], 0.5, (t, b, d)).astype(jnp.float32)
    pos = jax.random.randint(ks[1], (b,), 0, l, jnp.int32)
    live = (jnp.arange(l)[None, :] < pos[:, None]).astype(jnp.uint8)
    sk = _bern(ks[2], 0.4, (b, t, l, kv, hd)) * live[:, None, :, None, None]
    sv = _bern(ks[3], 0.5, (b, t, l, kv, hd)) * live[:, None, :, None, None]
    slot_keys = jax.random.randint(ks[4], (b, 2), 0, 2**31 - 1,
                                   jnp.int32).astype(jnp.uint32)
    wq, wk, wv, wo, wi, wo2 = _layer_ws(
        _key(seed ^ 0xA5A5), [(d, h * hd), (d, kv * hd), (d, kv * hd),
                              (h * hd, d), (d, d_ff), (d_ff, d)], bias)
    view = KVView.dense(sk, sv)
    args = (slot_keys, s, view, pos, wq, wk, wv, wo, wi, wo2)
    out_i = INT.decode_layer_fused(*args, hd=hd, with_mlp=mlp)
    out_p = PAL.decode_layer_fused(*args, hd=hd, with_mlp=mlp)
    for gi, gp, name in zip(out_i, out_p, ("s_out", "k_new", "v_new")):
        _eq(gi, gp, f"fused dense {name} t={t} l={l} h={h} kv={kv}")
    # attention-stage building block + TP h0 shard split
    a_full, kn, vn = PAL.decode_layer_fused(
        slot_keys, s, view, pos, wq, wk, wv, hd=hd, with_tail=False)
    _eq(a_full, INT.decode_layer_fused(
        slot_keys, s, view, pos, wq, wk, wv, hd=hd, with_tail=False)[0],
        "fused with_tail=False diverged from oracle")
    hloc, kvloc = h // 2, kv // 2
    parts = [
        PAL.decode_layer_fused(
            slot_keys, s,
            KVView.dense(sk[:, :, :, sh * kvloc:(sh + 1) * kvloc],
                         sv[:, :, :, sh * kvloc:(sh + 1) * kvloc]),
            pos,
            _slice_cols(wq, sh * hloc * hd, (sh + 1) * hloc * hd),
            _slice_cols(wk, sh * kvloc * hd, (sh + 1) * kvloc * hd),
            _slice_cols(wv, sh * kvloc * hd, (sh + 1) * kvloc * hd),
            hd=hd, h0=sh * hloc, with_tail=False)
        for sh in range(2)
    ]
    _eq(jnp.concatenate([p[0] for p in parts], axis=-1), a_full,
        "h0 shard split of fused attention stage diverged")
    _eq(jnp.concatenate([p[1] for p in parts], axis=2), kn,
        "h0 shard split of fused k_new diverged")
    _eq(jnp.concatenate([p[2] for p in parts], axis=2), vn,
        "h0 shard split of fused v_new diverged")


@settings(**_SET)
@given(t=st.integers(1, 2), page_len=st.sampled_from([4, 8]),
       mp=st.integers(1, 3), hkv=st.sampled_from([(2, 2), (4, 2)]),
       seed=st.integers(0, 2**31 - 1), mlp=st.booleans())
def test_fused_decode_layer_paged_matches_integer_oracle(t, page_len, mp,
                                                         hkv, seed, mlp):
    """The paged megakernel (scalar-prefetch page-table grid) == the paged
    integer fused-layer oracle for any page geometry, null-page pattern and
    GQA grouping — under the serving invariants the scheduler maintains
    (exclusive write pages, zero pre-scatter write slot)."""
    h, kv = hkv
    d, hd, d_ff = 16, 8, 24
    b = 2
    l = mp * page_len
    n_pages = 4 + b * mp
    ks = jax.random.split(_key(seed), 6)
    s = _bern(ks[0], 0.5, (t, b, d)).astype(jnp.float32)
    kpool = _bern(ks[1], 0.4, (n_pages, t, kv, page_len, hd))
    vpool = _bern(ks[2], 0.5, (n_pages, t, kv, page_len, hd))
    kpool = kpool.at[0].set(0)  # null page invariant
    vpool = vpool.at[0].set(0)
    # random shared read-only pages (>= 4) + null holes; each slot's write
    # page (2 / 3) is exclusively owned, as CoW guarantees in serving
    table = jax.random.randint(ks[3], (b, mp), 4, n_pages, jnp.int32)
    table = jnp.where(jax.random.bernoulli(ks[4], 0.3, (b, mp)), 0, table)
    pos = jax.random.randint(ks[5], (b,), 0, l, jnp.int32)
    barange = jnp.arange(b)
    write_pids = jnp.asarray([2, 3], jnp.int32)
    table = table.at[barange, pos // page_len].set(write_pids)
    off = pos % page_len
    kpool = kpool.at[write_pids, :, :, off].set(0)  # pre-scatter zero slot
    vpool = vpool.at[write_pids, :, :, off].set(0)
    slot_keys = jax.random.randint(jax.random.fold_in(ks[5], 1), (b, 2), 0,
                                   2**31 - 1, jnp.int32).astype(jnp.uint32)
    wq, wk, wv, wo, wi, wo2 = _layer_ws(
        _key(seed ^ 0x5A5A), [(d, h * hd), (d, kv * hd), (d, kv * hd),
                              (h * hd, d), (d, d_ff), (d_ff, d)], True)
    view = KVView.from_pool(kpool, vpool, table)
    args = (slot_keys, s, view, pos, wq, wk, wv, wo, wi, wo2)
    kw = dict(hd=hd, write_pids=write_pids, with_mlp=mlp)
    out_i = INT.decode_layer_fused(*args, **kw)
    out_p = PAL.decode_layer_fused(*args, **kw)
    for gi, gp, name in zip(out_i, out_p, ("s_out", "k_new", "v_new")):
        _eq(gi, gp, f"fused paged {name} pl={page_len} mp={mp} h={h} kv={kv}")
    # TP h0 shard split over the pool's KV axis, with_tail=False
    a_full = PAL.decode_layer_fused(
        slot_keys, s, view, pos, wq, wk, wv, hd=hd, write_pids=write_pids,
        with_tail=False)[0]
    hloc, kvloc = h // 2, kv // 2
    parts = [
        PAL.decode_layer_fused(
            slot_keys, s,
            KVView.from_pool(kpool[:, :, sh * kvloc:(sh + 1) * kvloc],
                             vpool[:, :, sh * kvloc:(sh + 1) * kvloc], table),
            pos,
            _slice_cols(wq, sh * hloc * hd, (sh + 1) * hloc * hd),
            _slice_cols(wk, sh * kvloc * hd, (sh + 1) * kvloc * hd),
            _slice_cols(wv, sh * kvloc * hd, (sh + 1) * kvloc * hd),
            hd=hd, h0=sh * hloc, write_pids=write_pids, with_tail=False)[0]
        for sh in range(2)
    ]
    _eq(jnp.concatenate(parts, axis=-1), a_full,
        "h0 shard split of paged fused attention stage diverged")


# ---------------------------------------------------------------------------
# Deprecated decode shims — warn, and forward bit-exactly
# ---------------------------------------------------------------------------


def test_decode_shims_warn_and_forward_bit_exactly():
    """``ssa_attention_decode`` / ``ssa_attention_decode_paged`` are
    deprecation shims over ``decode_attention(view, q, spec)``: every
    backend emits DeprecationWarning and returns the exact same bits."""
    t, b, h, l, d = 2, 2, 2, 8, 16
    ks = jax.random.split(_key(0), 6)
    q = _bern(ks[0], 0.5, (t, b, h, 1, d))
    k = _bern(ks[1], 0.4, (t, b, h, l, d))
    v = _bern(ks[2], 0.5, (t, b, h, l, d))
    slot_keys = jax.random.randint(ks[3], (b, 2), 0, 2**31 - 1,
                                   jnp.int32).astype(jnp.uint32)
    for be in (REF, INT, PAL):
        with pytest.warns(DeprecationWarning, match="decode_attention"):
            old = be.ssa_attention_decode(slot_keys, q, k, v, i_max=l, h0=1)
        new = be.decode_attention(KVView.dense(k, v), q,
                                  AttnSpec(i_max=l, h0=1),
                                  slot_keys=slot_keys)
        _eq(old, new, f"{be.name} dense decode shim diverged")
    page_len, mp = 4, 2
    kpool = _bern(ks[4], 0.4, (2 + b * mp, t, h, page_len, d))
    vpool = _bern(ks[5], 0.5, (2 + b * mp, t, h, page_len, d))
    kpool = kpool.at[0].set(0)
    vpool = vpool.at[0].set(0)
    table = jnp.asarray([[2, 3], [4, 0]], jnp.int32)
    for be in (REF, INT, PAL):
        with pytest.warns(DeprecationWarning, match="decode_attention"):
            old = be.ssa_attention_decode_paged(slot_keys, q, kpool, vpool,
                                                table, i_max=mp * page_len)
        new = be.decode_attention(KVView.from_pool(kpool, vpool, table), q,
                                  AttnSpec(i_max=mp * page_len, groups=1),
                                  slot_keys=slot_keys)
        _eq(old, new, f"{be.name} paged decode shim diverged")


# ---------------------------------------------------------------------------
# Spiking linear (crossbar MVM + LIF) — col/row parts, all three backends
# ---------------------------------------------------------------------------


def _dyadic_weights(key, d_in, d_out, levels=15, scale=2.0**-3):
    """Float weights exactly on the quantisation grid with a power-of-two
    column scale: every backend's arithmetic is then exact, so reference
    == integer == pallas bit-for-bit (see module docstring)."""
    lv = jax.random.randint(key, (d_in, d_out), -levels, levels + 1, jnp.int32)
    lv = lv.at[0].set(levels)  # pin each column's amax to `levels`
    return (lv * scale).astype(jnp.float32)


@settings(**_SET)
@given(t=st.integers(1, 4), b=st.integers(1, 3),
       d_in=st.sampled_from([8, 24, 64]), d_out=st.sampled_from([16, 33]),
       part=st.sampled_from(["col", "row"]), seed=st.integers(0, 2**31 - 1),
       bias=st.booleans())
def test_spiking_linear_all_backends_bit_exact(t, b, d_in, d_out, part, seed,
                                               bias):
    """LIF(W s) over binary trains: all THREE backends agree bit-for-bit on
    dyadic-grid weights, for both tensor-parallel part hints and arbitrary
    (T, B, d_in, d_out) incl. pad paths."""
    ks = jax.random.split(_key(seed), 3)
    spikes = _bern(ks[0], 0.5, (t, b, d_in)).astype(jnp.float32)
    w = _dyadic_weights(ks[1], d_in, d_out)
    p = {"w": w, "b": (jnp.arange(d_out, dtype=jnp.float32) * 0.25
                       if bias else None)}
    out_r = REF.spiking_linear(None, p, spikes, part=part)
    out_i = INT.spiking_linear(None, p, spikes, part=part)
    out_p = PAL.spiking_linear(None, p, spikes, part=part)
    _eq(out_i, out_p, f"integer != pallas ({t},{b},{d_in},{d_out},{part})")
    _eq(out_r.astype(jnp.uint8), out_i,
        f"reference != integer on dyadic grid ({t},{b},{d_in},{d_out})")


@settings(**_SET)
@given(t=st.integers(1, 3), d_in=st.sampled_from([16, 48]),
       d_out=st.sampled_from([16, 40]), seed=st.integers(0, 2**31 - 1))
def test_spiking_linear_row_counts_psum_decomposition(t, d_in, d_out, seed):
    """The row-parallel decomposition contract: shard-local integer counts
    summed across an input-row split reproduce the fused kernel exactly
    (what ``distributed.ShardedBackend`` relies on for ``part='row'``)."""
    ks = jax.random.split(_key(seed), 3)
    spikes = _bern(ks[0], 0.5, (t, 2, d_in)).astype(jnp.float32)
    levels = jax.random.randint(ks[1], (d_in, d_out), -15, 16,
                                jnp.int32).astype(jnp.int8)
    scale = (jax.random.randint(ks[2], (d_out,), 1, 8, jnp.int32)
             .astype(jnp.float32) * 0.125)
    half = d_in // 2
    counts = (KOPS.aimc_matmul_counts(spikes[..., :half], levels[:half])
              + KOPS.aimc_matmul_counts(spikes[..., half:], levels[half:]))
    pre = counts * scale[None, None, :]
    split = KREF.lif_ref(pre.reshape(t, -1)).reshape(pre.shape)
    fused = KREF.aimc_spiking_linear_ref(spikes, levels, scale)
    _eq(split, fused, "row-split counts diverged from fused kernel")


# ---------------------------------------------------------------------------
# Drift re-quantisation (deterministic: kernel == oracle everywhere)
# ---------------------------------------------------------------------------


@settings(**_SET)
@given(d_in=st.sampled_from([8, 130]), d_out=st.sampled_from([16, 129]),
       t_s=st.sampled_from([0.0, 25.0, 3600.0, 86400.0]),
       img_gain=st.sampled_from([1, 4]), seed=st.integers(0, 2**31 - 1))
def test_drift_requantize_kernel_matches_ref(d_in, d_out, t_s, img_gain, seed):
    """The Pallas drift-fold kernel re-digitises drifted conductances onto
    the int8 image grid bit-identically to the oracle for any shape
    (incl. >1 tile), device age and image gain."""
    ks = jax.random.split(_key(seed), 3)
    levels = jax.random.randint(ks[0], (d_in, d_out), -15, 16,
                                jnp.int32).astype(jnp.float32)
    eps = 0.3 * jax.random.normal(ks[1], (d_in, d_out), jnp.float32)
    nu = 0.05 + 0.02 * jax.random.normal(ks[2], (d_in, d_out), jnp.float32)
    got = KOPS.drift_requantize(levels, eps, nu, jnp.float32(t_s), t0=1.0,
                                img_gain=img_gain)
    want = KREF.drift_requantize_ref(levels, eps, nu, t_s, t0=1.0,
                                     img_gain=img_gain)
    _eq(got, want, f"drift_requantize ({d_in},{d_out},t={t_s},g={img_gain})")


# ---------------------------------------------------------------------------
# LIF (deterministic: all three substrates)
# ---------------------------------------------------------------------------


@settings(**_SET)
@given(t=st.integers(1, 6), m=st.sampled_from([1, 7, 300]),
       seed=st.integers(0, 2**31 - 1))
def test_lif_all_backends_bit_exact(t, m, seed):
    """The fused-membrane kernel, the integer oracle and the reference
    surrogate-gradient LIF all emit identical spikes for identical
    currents (quarter-grid currents keep every membrane value exact)."""
    cur = (jax.random.randint(_key(seed), (t, m), -8, 9, jnp.int32)
           .astype(jnp.float32) * 0.25)
    out_i = INT.lif(cur)
    out_p = PAL.lif(cur)
    out_r = REF.lif(cur)
    _eq(out_i, out_p, f"lif integer != pallas (t={t}, m={m})")
    _eq(out_i, out_r.astype(jnp.uint8), f"lif integer != reference (t={t})")
