"""Optimizer tests: convergence, int8 states, schedules, EF compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw as A
from repro.optim import compression as C


def _quadratic_params(rng):
    return {"w": jax.random.normal(rng, (8, 513)) * 2.0, "b": jnp.ones((3,))}


def _run(params, cfg, steps=200):
    state = A.init_opt_state(params, cfg)
    for _ in range(steps):
        grads = jax.tree.map(lambda p: p.astype(jnp.float32), params)  # grad of |p|^2/2
        params, state, m = A.apply_updates(params, grads, state, cfg)
    return params, m


def test_adamw_converges_to_zero(rng):
    params = _quadratic_params(rng)
    cfg = A.AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=1, total_steps=10_000,
                        schedule="constant")
    params, _ = _run(params, cfg, steps=400)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_int8_state_tracks_fp32(rng):
    p0 = _quadratic_params(rng)
    cfg32 = A.AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=1, schedule="constant")
    cfg8 = A.AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=1, schedule="constant",
                         state_dtype="int8")
    pa, _ = _run(jax.tree.map(jnp.array, p0), cfg32, 100)
    pb, _ = _run(jax.tree.map(jnp.array, p0), cfg8, 100)
    # int8 moments follow the fp32 trajectory closely on a smooth problem
    diff = float(jnp.mean(jnp.abs(pa["w"] - pb["w"])))
    scale = float(jnp.mean(jnp.abs(p0["w"] - pa["w"])))
    assert diff < 0.15 * scale


def test_int8_state_structure(rng):
    params = {"w": jnp.zeros((64, 128))}
    cfg = A.AdamWConfig(state_dtype="int8")
    state = A.init_opt_state(params, cfg)
    assert set(state["m"]["w"]) == {"q", "scale"}
    assert state["m"]["w"]["q"].dtype == jnp.int8
    assert state["m"]["w"]["scale"].shape == (64,)


def test_schedule_shapes():
    cfg = A.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, schedule="cosine")
    lrs = [float(A.schedule_lr(cfg, jnp.int32(s))) for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(0.5)
    assert lrs[2] == pytest.approx(1.0)
    assert 0 < lrs[3] < 1.0
    assert lrs[4] == pytest.approx(0.0, abs=1e-6)


def test_grad_clipping_bounds_update(rng):
    params = {"w": jnp.zeros((4, 4))}
    cfg = A.AdamWConfig(lr=0.1, grad_clip=1.0, warmup_steps=1, schedule="constant")
    state = A.init_opt_state(params, cfg)
    huge = {"w": jnp.full((4, 4), 1e6)}
    _, _, m = A.apply_updates(params, huge, state, cfg)
    assert float(m["grad_norm"]) > 1e5  # reported raw


def test_ef_compression_error_feedback(rng):
    """Accumulated compressed sum ~= accumulated true sum (EF property)."""
    g = {"w": jax.random.normal(rng, (16, 4096)) * 0.01}
    ef = C.init_ef_state(g)
    total_c = jnp.zeros_like(g["w"])
    for i in range(20):
        gi = {"w": g["w"] * (1.0 + 0.1 * i)}
        c, ef = C.compress_decompress(gi, ef)
        total_c = total_c + c["w"]
    total_true = g["w"] * sum(1.0 + 0.1 * i for i in range(20))
    rel = float(jnp.linalg.norm(total_c - total_true) / jnp.linalg.norm(total_true))
    assert rel < 0.02  # residual re-injection keeps the sum unbiased


def test_compression_small_leaves_passthrough(rng):
    g = {"b": jnp.array([1.0, 2.0, 3.0])}
    ef = C.init_ef_state(g)
    c, _ = C.compress_decompress(g, ef)
    np.testing.assert_array_equal(np.asarray(c["b"]), np.asarray(g["b"]))
