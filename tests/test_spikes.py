"""Unit + property tests for spike coding, LIF, and Bernoulli neurons."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import spikes as SP


def test_rate_encode_statistics(rng):
    x = jnp.linspace(0.0, 1.0, 11)
    s = SP.rate_encode(rng, x, T=4096, straight_through=False)
    rates = jnp.mean(s, axis=0)
    np.testing.assert_allclose(rates, x, atol=0.03)


def test_rate_encode_clips(rng):
    x = jnp.array([-0.5, 1.5])
    s = SP.rate_encode(rng, x, T=512, straight_through=False)
    assert float(jnp.mean(s[:, 0])) == 0.0
    assert float(jnp.mean(s[:, 1])) == 1.0


def test_lif_fires_and_resets():
    # constant current 0.6, beta 0.5, thresh 1.0:
    # v: 0.6, 0.9, 1.05 -> fire+reset, 0.6, 0.9, 1.05 -> fire ...
    cur = jnp.full((9, 1), 0.6)
    out = SP.lif(cur)
    np.testing.assert_array_equal(out[:, 0], [0, 0, 1, 0, 0, 1, 0, 0, 1])


def test_lif_never_fires_below_threshold():
    cur = jnp.full((50, 1), 0.4)  # fixed point v* = 0.8 < 1.0
    assert float(SP.lif(cur).sum()) == 0.0


def test_heaviside_surrogate_gradient():
    g = jax.grad(lambda v: SP.heaviside_st(v, 2.0).sum())(jnp.array([0.5, -0.5]))
    assert (g > 0).all()  # fast-sigmoid surrogate is positive everywhere


def test_bernoulli_st_gradient_is_identity():
    p = jnp.array([0.3, 0.7])
    u = jnp.array([0.5, 0.5])
    g = jax.grad(lambda pp: SP.bernoulli_st(pp, u).sum())(p)
    np.testing.assert_array_equal(g, jnp.ones_like(p))


@settings(deadline=None, max_examples=20)
@given(count=st.integers(0, 64), imax=st.sampled_from([16, 32, 64]))
def test_bnl_integer_probability(count, imax):
    """P(spike) == count/imax exactly (hardware comparator semantics)."""
    count = min(count, imax)
    key = jax.random.PRNGKey(count * 131 + imax)
    counts = jnp.full((4096,), count, jnp.int32)
    s = SP.bnl_integer(key, counts, imax)
    rate = float(jnp.mean(s))
    assert abs(rate - count / imax) < 0.05


def test_split_prn_bytes():
    w = jnp.array([0x04030201], jnp.uint32)
    b = SP.split_prn_bytes(w)
    np.testing.assert_array_equal(np.asarray(b[0]), [1, 2, 3, 4])


def test_spiking_linear_carries_membrane(rng):
    spikes = jnp.ones((4, 2, 8))
    w = jnp.full((8, 3), 0.1)  # per-step current 0.8: v = .8, 1.2 -> fires
    out = SP.spiking_linear(spikes, w, None)
    assert out.shape == (4, 2, 3)
    np.testing.assert_array_equal(np.asarray(out[:, 0, 0]), [0, 1, 0, 1])
