"""Checkpoint manager: roundtrip, retention, atomicity, elastic restore."""

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import reduced_config
from repro.launch import elastic
from repro.launch.mesh import make_test_mesh
from repro.optim import adamw as A


def _state(rng):
    return {
        "params": {"w": jax.random.normal(rng, (8, 16)).astype(jnp.bfloat16),
                   "b": jnp.arange(5, dtype=jnp.int32)},
        "step": jnp.int32(7),
    }


def test_roundtrip_exact(tmp_path, rng):
    mgr = CheckpointManager(tmp_path, keep=3)
    state = _state(rng)
    mgr.save(7, state, blocking=True)
    restored, step = mgr.restore(state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype  # bfloat16 survives the round trip


def test_retention_keeps_newest(tmp_path, rng):
    mgr = CheckpointManager(tmp_path, keep=2)
    state = _state(rng)
    for s in (1, 2, 3, 4):
        mgr.save(s, state, blocking=True)
    assert sorted(mgr.steps()) == [3, 4]
    assert mgr.latest_step() == 4


def test_atomicity_ignores_partial(tmp_path, rng):
    mgr = CheckpointManager(tmp_path, keep=3)
    state = _state(rng)
    mgr.save(5, state, blocking=True)
    # simulate a crashed write: tmp dir + a final dir without manifest
    (tmp_path / ".tmp_step_00000009").mkdir()
    broken = tmp_path / "step_00000010"
    broken.mkdir()
    (broken / "leaf_00000.npy").write_bytes(b"garbage")
    assert mgr.latest_step() == 5
    restored, step = mgr.restore(state)
    assert step == 5


def test_elastic_restore_new_mesh(tmp_path, rng):
    """Save under one mesh, restore under a different mesh's sharding plan."""
    cfg = reduced_config("yi-9b")
    from repro.models import transformer as T

    params = T.init_params(rng, cfg)
    opt = A.AdamWConfig()
    state = A.init_opt_state(params, opt)
    mgr = CheckpointManager(tmp_path, keep=1)
    mgr.save(3, (params, state), blocking=True)

    new_mesh = make_test_mesh((1, 1, 1))  # pod/data/model axes this time
    pshard, oshard = elastic.state_shardings(cfg, new_mesh, opt)
    (p2, s2), step = mgr.restore((params, state), shardings=(pshard, oshard))
    assert step == 3
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_aimc_device_state_roundtrip_decode_exact(tmp_path, rng):
    """Save -> restore a *programmed, aged* AIMC device tree; restored
    params must decode bit-exactly on the integer backend.

    Regression coverage for two silent-save bugs: trees containing
    user-defined pytree nodes (AIMCDeviceState) crashed the manifest's
    proto treedef serialization, and the async save thread swallowed the
    exception — the checkpoint just never appeared."""
    from repro import aimc_device as AD
    from repro.engine import get_backend
    from repro.models import transformer as T
    from repro.serving import BatchScheduler

    cfg = reduced_config("xpikeformer-gpt-4-256")
    params = T.init_params(rng, cfg)
    acfg = AD.AIMCConfig()
    dev = AD.program_lm_tree(jax.random.fold_in(rng, 1), params, acfg)
    dev = AD.drift_tree(dev, 3600.0, cfg=acfg)  # an hour of conductance drift
    assert AD.has_device_state(dev)

    mgr = CheckpointManager(tmp_path, keep=1)
    mgr.save(11, dev, blocking=True)  # wait() inside re-raises write errors
    manifest = json.loads(
        (tmp_path / "step_00000011" / "manifest.json").read_text())
    assert manifest["treedef"] is None  # user-defined nodes: best-effort only
    restored, step = mgr.restore(dev)
    assert step == 11
    for a, b in zip(jax.tree.leaves(dev), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert np.asarray(a).dtype == np.asarray(b).dtype

    def decode(p):
        sch = BatchScheduler(p, cfg, get_backend("integer"), slots=1,
                             cache_len=16)
        r = sch.submit([3, 4, 5, 6], 4, seed=5)
        return sch.run()[r]

    assert decode(restored) == decode(dev)


def test_save_thread_errors_surface_in_wait(tmp_path, rng, monkeypatch):
    """A background save that dies must raise at the next wait(), not
    vanish."""
    mgr = CheckpointManager(tmp_path, keep=1)

    def boom(step, host_state):
        raise OSError("disk on fire")

    monkeypatch.setattr(mgr, "_write", boom)
    mgr.save(1, {"w": jnp.zeros(3)})
    with pytest.raises(OSError, match="disk on fire"):
        mgr.wait()
    # the error is consumed: the manager stays usable for the next save
    monkeypatch.undo()
    mgr.save(2, {"w": jnp.zeros(3)}, blocking=True)
    assert mgr.latest_step() == 2


def test_resharding_plan_reports(rng):
    cfg = reduced_config("yi-9b")
    m1 = make_test_mesh((1, 1))
    m2 = make_test_mesh((1, 1, 1))
    plan = elastic.resharding_plan(cfg, m1, m2)
    assert "old_mesh" in plan and "new_mesh" in plan
    assert plan["new_mesh"] == {"pod": 1, "data": 1, "model": 1}
