"""Layer tests: flash/banded attention equivalence, RoPE, decode parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models import layers as L


CFG = ModelConfig(
    name="t", family="dense", num_layers=1, d_model=32, num_heads=4,
    num_kv_heads=2, head_dim=8, d_ff=64, vocab_size=64, dtype="float32",
)


def _qkv_rand(key, b, s, h, kvh, d):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kvh, d))
    v = jax.random.normal(ks[2], (b, s, kvh, d))
    return q, k, v


def test_flash_matches_masked_einsum(rng):
    q, k, v = _qkv_rand(rng, 2, 2048, 4, 2, 16)
    ref = L._sdpa(q, k, v, L.causal_mask(2048, 2048)[None, None, None], 0.0)
    out = L._flash_attention(q, k, v, 0.0, blk=512)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_with_softcap(rng):
    q, k, v = _qkv_rand(rng, 1, 2048, 2, 2, 16)
    ref = L._sdpa(q, k, v, L.causal_mask(2048, 2048)[None, None, None], 30.0)
    out = L._flash_attention(q, k, v, 30.0, blk=1024)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_banded_matches_window_mask(rng):
    q, k, v = _qkv_rand(rng, 2, 1024, 4, 2, 16)
    w = 128
    ref = L._sdpa(q, k, v, L.window_mask(1024, 1024, w)[None, None, None], 0.0)

    class C:
        attn_softcap = 0.0

    out = L._banded_local(q, k, v, C, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_rope_relative_property(rng):
    """<rope(q,i), rope(k,j)> depends only on i-j."""
    d = 16
    q = jax.random.normal(rng, (1, 1, 1, d))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (1, 1, 1, d))

    def dot_at(i, j):
        qi = L.apply_rope(q, jnp.array([[i]]), 10000.0)
        kj = L.apply_rope(k, jnp.array([[j]]), 10000.0)
        return float(jnp.sum(qi * kj))

    assert abs(dot_at(3, 1) - dot_at(10, 8)) < 1e-4
    assert abs(dot_at(0, 0) - dot_at(7, 7)) < 1e-4


def test_attention_decode_matches_prefill(rng):
    """Step-by-step decode with KV cache == teacher-forced full attention."""
    b, s = 2, 12
    params = L.init_tree(rng, L.attention_schema(CFG), jnp.float32)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (b, s, CFG.d_model))
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    full = L.attention(params, x, positions, CFG)

    cache = jax.tree.map(
        lambda sd: jnp.zeros(sd.shape, sd.dtype) if sd.shape != () else jnp.int32(0),
        L.attention_cache_schema(CFG, b, s),
    )
    outs = []
    for i in range(s):
        y, cache = L.attention_decode(params, x[:, i : i + 1], cache, CFG)
        outs.append(y)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=2e-4)


def test_local_attention_decode_ring_buffer(rng):
    b, s, w = 1, 10, 4
    params = L.init_tree(rng, L.attention_schema(CFG), jnp.float32)
    x = jax.random.normal(jax.random.fold_in(rng, 3), (b, s, CFG.d_model))
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    full = L.attention(params, x, positions, CFG, window=w)
    cache = jax.tree.map(
        lambda sd: jnp.zeros(sd.shape, sd.dtype) if sd.shape != () else jnp.int32(0),
        L.attention_cache_schema(CFG, b, s, window=w),
    )
    outs = []
    for i in range(s):
        y, cache = L.attention_decode(params, x[:, i : i + 1], cache, CFG, window=w)
        outs.append(y)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full), atol=2e-4)


def test_gqa_grouping(rng):
    q, k, v = _qkv_rand(rng, 1, 8, 4, 2, 8)
    out = L._sdpa(q, k, v, L.causal_mask(8, 8)[None, None, None], 0.0)
    assert out.shape == (1, 8, 4, 8)


def test_mlp_gated(rng):
    params = L.init_tree(rng, L.mlp_schema(CFG), jnp.float32)
    x = jax.random.normal(rng, (2, 3, CFG.d_model))
    out = L.mlp(params, x, CFG)
    assert out.shape == x.shape
