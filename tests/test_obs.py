"""Observability layer (:mod:`repro.obs`): metrics registry, Prometheus
exposition, lifecycle tracing, flight recorder, profiler hooks.

Two invariants anchor everything here:

* **no perturbation** — a scheduler run with the full telemetry bundle
  attached streams the same tokens and books the same joules as a
  telemetry-off run, and the jitted decode_step still compiles exactly
  once (telemetry is host-side bookkeeping, never jitted code);
* **single source of truth** — the registry's counters mirror
  :class:`~repro.serving.ServeStats` by delta, so ``/stats``,
  ``/metrics`` and the scheduler's own stats can never disagree.

The scheduler-facing tests run on the CI backend matrix
(``engine_backend``); the registry/tracer/recorder units are pure host
Python and run once.
"""

import json
import re

import jax
import pytest

from repro.configs.registry import reduced_config
from repro.engine import get_backend
from repro.models import transformer as T
from repro.obs import (
    LATENCY_BUCKETS,
    JsonlSink,
    ListSink,
    MetricsRegistry,
    StepProfiler,
    Telemetry,
    Tracer,
    load_jsonl,
    log_buckets,
    perfetto_export,
    render_prometheus,
)
from repro.obs import trace as TR
from repro.server import FrontDoor
from repro.serving import BatchScheduler

SPIKING = "xpikeformer-gpt-4-256"


@pytest.fixture(scope="module")
def spiking_setup():
    cfg = reduced_config(SPIKING)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


# -- metrics registry -----------------------------------------------------


def test_log_buckets_geometry():
    b = log_buckets(1e-6, 100.0, per_decade=3)
    assert b[0] == pytest.approx(1e-6)
    assert b[-1] >= 100.0
    ratios = [hi / lo for lo, hi in zip(b, b[1:])]
    for r in ratios:  # constant geometric step: 10^(1/3)
        assert r == pytest.approx(10.0 ** (1 / 3))
    assert b == LATENCY_BUCKETS
    with pytest.raises(ValueError):
        log_buckets(0.0, 1.0)
    with pytest.raises(ValueError):
        log_buckets(1.0, 1.0)


def test_histogram_bucket_counts():
    """Observations land in the right (upper-inclusive) bucket; the last
    entry is the +Inf bucket."""
    reg = MetricsRegistry(namespace="")
    h = reg.histogram("lat", "t", bounds=(1.0, 10.0, 100.0))
    for v in (0.5, 1.0, 5.0, 10.0, 50.0, 1000.0):
        h.observe(v)
    # le=1: {0.5, 1.0}; le=10: {5, 10}; le=100: {50}; +Inf: {1000}
    assert h.bucket_counts() == [2, 2, 1, 1]
    snap = h.snapshot()
    assert snap["count"] == 6 and snap["sum"] == pytest.approx(1066.5)
    assert snap["bounds"] == [1.0, 10.0, 100.0]
    # labeled series are independent
    h2 = reg.histogram("lab", "t", ("k",), bounds=(1.0,))
    h2.observe(0.5, "a")
    h2.observe(2.0, "b")
    assert h2.bucket_counts("a") == [1, 0]
    assert h2.bucket_counts("b") == [0, 1]
    assert h2.bucket_counts("never") == [0, 0]


def test_counter_gauge_semantics():
    reg = MetricsRegistry()
    c = reg.counter("reqs_total", "r", ("outcome",))
    c.inc(1.0, "ok")
    c.inc(2.0, "ok")
    c.inc(1.0, "err")
    assert c.value("ok") == 3.0 and c.value("err") == 1.0
    with pytest.raises(ValueError):
        c.inc(-1.0, "ok")  # counters are monotone
    with pytest.raises(ValueError):
        c.inc(1.0)  # label arity enforced
    g = reg.gauge("depth", "d")
    g.set(5)
    g.dec(2)
    assert g.value() == 3.0
    # get-or-create: same object back, mismatis rejected
    assert reg.counter("reqs_total", "r", ("outcome",)) is c
    with pytest.raises(ValueError):
        reg.gauge("reqs_total")  # kind mismatch
    with pytest.raises(ValueError):
        reg.counter("reqs_total", "r", ("other",))  # label mismatch
    assert reg.get("xpike_reqs_total") is c  # namespaced lookup


def test_render_prometheus_golden():
    """Exact exposition text for a small registry (format 0.0.4)."""
    reg = MetricsRegistry(namespace="t")
    c = reg.counter("reqs_total", "requests", ("outcome",))
    c.inc(3, "ok")
    c.inc(1, "err")
    reg.gauge("depth", "queue depth").set(2)
    h = reg.histogram("lat_seconds", "latency", bounds=(0.125, 1.0))
    for v in (0.0625, 0.5, 5.0):  # exact binary floats: stable reprs
        h.observe(v)
    assert render_prometheus(reg) == (
        "# HELP t_depth queue depth\n"
        "# TYPE t_depth gauge\n"
        "t_depth 2\n"
        "# HELP t_lat_seconds latency\n"
        "# TYPE t_lat_seconds histogram\n"
        't_lat_seconds_bucket{le="0.125"} 1\n'
        't_lat_seconds_bucket{le="1"} 2\n'
        't_lat_seconds_bucket{le="+Inf"} 3\n'
        "t_lat_seconds_sum 5.5625\n"
        "t_lat_seconds_count 3\n"
        "# HELP t_reqs_total requests\n"
        "# TYPE t_reqs_total counter\n"
        't_reqs_total{outcome="ok"} 3\n'
        't_reqs_total{outcome="err"} 1\n'
    )


_SAMPLE_RE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*"  # metric name
    r'(\{[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*"'
    r'(,[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*")*\})?'  # labels
    r" -?(\d+(\.\d+)?([eE][+-]?\d+)?|inf|nan)$", re.IGNORECASE)


def assert_prometheus_well_formed(text: str) -> None:
    """Every line is a HELP/TYPE comment or a well-formed sample; every
    histogram's cumulative buckets are nondecreasing and end at _count."""
    assert text.endswith("\n")
    buckets = {}
    counts = {}
    for line in text.rstrip("\n").split("\n"):
        if line.startswith("# HELP ") or line.startswith("# TYPE "):
            continue
        assert _SAMPLE_RE.match(line), f"malformed sample line: {line!r}"
        name = line.split("{")[0].split(" ")[0]
        value = float(line.rsplit(" ", 1)[1])
        if "_bucket{" in line:
            series = line.rsplit(" ", 1)[0]
            key = (name, re.sub(r'le="[^"]*",?', "", series))
            buckets.setdefault(key, []).append(value)
        elif name.endswith("_count"):
            counts[line.rsplit(" ", 1)[0]] = value
    for (name, _series_key), cum in buckets.items():
        assert cum == sorted(cum), f"{name}: buckets not cumulative"
        # the +Inf bucket must equal the series _count
        base = name[:-len("_bucket")]
        matching = [v for k, v in counts.items() if k.startswith(base)]
        assert cum[-1] in matching, f"{name}: +Inf bucket != _count"


def test_label_escaping():
    reg = MetricsRegistry(namespace="")
    c = reg.counter("odd_total", "h", ("who",))
    c.inc(1.0, 'quo"te\\back\nline')
    text = render_prometheus(reg)
    assert r'who="quo\"te\\back\nline"' in text
    assert_prometheus_well_formed(text)


# -- tracer / sinks -------------------------------------------------------


def test_tracer_noop_without_sinks():
    tr = Tracer()
    assert not tr.active
    tr.emit(TR.SUBMIT, rid=1)  # must not raise, must not allocate a sink
    sink = ListSink()
    tr.add_sink(sink)
    tr.emit(TR.ADMIT, rid=1, slot=0)
    assert tr.active and len(sink.events) == 1
    ev = sink.events[0]
    assert ev["event"] == TR.ADMIT and ev["rid"] == 1
    assert "ts" in ev and "mono" in ev
    tr.remove_sink(sink)
    tr.emit(TR.FINISH, rid=1)
    assert len(sink.events) == 1


def test_jsonl_sink_roundtrip(tmp_path):
    import numpy as np

    path = str(tmp_path / "trace.jsonl")
    sink = JsonlSink(path)
    tr = Tracer([sink])
    tr.emit(TR.SUBMIT, rid=0, prompt_len=4)
    tr.emit(TR.DECODE, rid=0, token=np.int32(7))  # numpy -> jsonable
    sink.close()
    evs = load_jsonl(path)
    assert [e["event"] for e in evs] == [TR.SUBMIT, TR.DECODE]
    assert evs[1]["token"] == 7.0
    assert evs[0]["mono"] <= evs[1]["mono"]


def test_perfetto_export_spans():
    sink = ListSink()
    tr = Tracer([sink])
    tr.emit(TR.SUBMIT, rid=0)
    tr.emit(TR.ADMIT, rid=0, slot=1)
    tr.emit(TR.FIRST_TOKEN, rid=0, token=5)
    tr.emit(TR.FINISH, rid=0)
    tr.emit(TR.GDC_RECAL, n=4)  # no rid: lands on track 0
    out = perfetto_export(sink.events)
    assert out["displayTimeUnit"] == "ms"
    evs = out["traceEvents"]
    spans = [(e["name"], e["tid"]) for e in evs if e["ph"] == "X"]
    assert ("queued", 0) in spans  # submit -> admit
    assert ("running", 0) in spans  # admit -> finish
    instants = [e for e in evs if e["ph"] == "i"]
    assert len(instants) == 5
    for e in evs:
        assert e["ts"] >= 0.0
        if e["ph"] == "X":
            assert e["dur"] >= 0.0
    assert perfetto_export([]) == {"traceEvents": [],
                                   "displayTimeUnit": "ms"}
    # a dangling phase (no finish) is closed at trace end
    dangling = perfetto_export(sink.events[:2])
    assert any(e["ph"] == "X" and e["name"] == "running"
               for e in dangling["traceEvents"])


def test_step_profiler_window(monkeypatch):
    """start_trace fires at step ``skip``, stop after ``steps`` more."""
    calls = []
    monkeypatch.setattr(jax.profiler, "start_trace",
                        lambda d: calls.append(("start", d)))
    monkeypatch.setattr(jax.profiler, "stop_trace",
                        lambda: calls.append(("stop", None)))
    p = StepProfiler(2, "/tmp/prof", skip=1)
    p.tick()  # step 0: skipped (compile step)
    assert not p.tracing and calls == []
    p.tick()  # step 1: capture starts
    assert calls == [("start", "/tmp/prof")] and p.tracing
    p.tick()  # step 2: window [1, 3) complete -> stop
    assert calls[-1][0] == "stop" and p.done
    p.tick()  # further ticks are no-ops
    assert len(calls) == 2
    with pytest.raises(ValueError):
        StepProfiler(0, "/tmp/prof")


# -- scheduler integration (backend matrix) -------------------------------


def _jobs():
    return [(list(range(3 + i, 9 + i)), 4, 70 + i) for i in range(3)]


def _run(sch, jobs):
    sch.reset()
    rids = [sch.submit(p, mn, seed=s) for p, mn, s in jobs]
    outs = sch.run()
    return [list(outs[r]) for r in rids]


@pytest.fixture(scope="module")
def paged_sched(spiking_setup, engine_backend):
    cfg, params = spiking_setup
    return BatchScheduler(params, cfg, get_backend(engine_backend),
                          slots=2, cache_len=32, paged=True, page_len=8,
                          n_pages=12)


def test_telemetry_bitexact_and_compile_once(paged_sched, tmp_path):
    """Attaching the full bundle changes no token, no joule, no compile."""
    sch = paged_sched
    jobs = _jobs()
    want = _run(sch, jobs)  # telemetry-off baseline (also jit warmup)
    base_energy = sch.stats.energy_j
    base_spikes = sch.stats.spike_events

    sink = ListSink()
    obs = Telemetry.create(flight_dir=str(tmp_path))
    obs.tracer.add_sink(sink)
    sch.attach_obs(obs)
    got = _run(sch, jobs)
    assert got == want  # bit-exact token streams
    assert sch.stats.energy_j == base_energy  # bit-exact energy
    assert sch.stats.spike_events == base_spikes
    assert sch._decode._cache_size() == 1, \
        "attaching telemetry recompiled the decode step"

    # lifecycle trace covers the whole request arc
    names = {e["event"] for e in sink.events}
    assert {TR.SUBMIT, TR.ADMIT, TR.PREFILL_CHUNK, TR.FIRST_TOKEN,
            TR.DECODE, TR.FINISH, TR.EVICT} <= names
    finishes = [e for e in sink.events if e["event"] == TR.FINISH]
    assert {e["rid"] for e in finishes} == {e["rid"] for e in sink.events
                                            if e["event"] == TR.SUBMIT}


def test_counters_mirror_serve_stats(paged_sched):
    """Registry counters == ServeStats after a run, and stay lifetime-
    monotone across reset() while ServeStats rebases."""
    sch = paged_sched
    if sch.obs is None:
        sch.attach_obs(Telemetry.create())
    jobs = _jobs()
    _run(sch, jobs)
    m = sch.obs.metrics
    marks = {}
    for field, name, _help in BatchScheduler._STAT_COUNTERS:
        counter = m.get(f"xpike_{name}")
        assert counter is not None, name
        marks[name] = counter.value()
    st = sch.stats
    # first run since the counters existed may have prior totals; compare
    # deltas over one more run instead of absolutes
    _run(sch, jobs)
    st2 = sch.stats
    for field, name, _help in BatchScheduler._STAT_COUNTERS:
        delta = m.get(f"xpike_{name}").value() - marks[name]
        assert delta == pytest.approx(float(getattr(st2, field))), \
            f"counter {name} does not mirror ServeStats.{field}"
    # gauges reflect the drained server
    assert m.get("xpike_active_slots").value() == 0
    assert m.get("xpike_scheduler_queue_depth").value() == 0
    # exposition of the live registry is well-formed Prometheus text
    assert_prometheus_well_formed(render_prometheus(m))


def test_frontdoor_stats_nests_registry(paged_sched):
    """GET /stats ``metrics`` block == registry snapshot == ServeStats."""
    import asyncio

    sch = paged_sched
    jobs = _jobs()
    want = _run(sch, jobs)

    async def go():
        front = FrontDoor(sch)
        await front.start()
        try:
            streams = [await front.submit(p, mn, seed=s)
                       for p, mn, s in jobs]
            got = [await ts.tokens() for ts in streams]
            return front, got
        finally:
            await front.stop()

    sch.reset()
    front, got = asyncio.run(go())
    assert got == want  # telemetry-on front door stays bit-exact
    stats = front.stats_dict()
    assert json.loads(json.dumps(stats)) == stats  # JSON-serializable
    snap = stats["metrics"]
    assert snap == front.obs.metrics.snapshot()
    decoded = snap["xpike_decoded_tokens_total"]
    assert decoded["kind"] == "counter"
    # lifetime counter >= this run's ServeStats (earlier runs accumulate)
    assert decoded["values"] >= stats["scheduler"]["decoded_tokens"]
    assert "xpike_ttft_seconds" in snap
    assert snap["xpike_frontdoor_requests_total"]["values"]["completed"] \
        >= len(jobs)
    admits = snap["xpike_admission_decisions_total"]["values"]
    assert any(k.startswith("admit") for k in admits)


def test_flight_recorder_dumps_on_pool_guard(spiking_setup, tmp_path):
    """A PagePool double-free still raises, and the armed recorder writes
    a postmortem first (events + metrics snapshot + reason)."""
    cfg, params = spiking_setup
    sch = BatchScheduler(params, cfg, get_backend("reference"),
                         slots=2, cache_len=32, paged=True, page_len=8,
                         n_pages=12)
    obs = Telemetry.create(flight_dir=str(tmp_path))
    sch.attach_obs(obs)
    sch.submit(list(range(3, 9)), 2, seed=1)  # traces feed the ring

    pid = sch.pages.alloc()
    sch.pages.release(pid)  # refcount 1 -> 0: page freed
    with pytest.raises(ValueError, match="double free"):
        sch.pages.release(pid)
    assert len(obs.recorder.dumps) == 1
    dump = json.load(open(obs.recorder.dumps[0]))
    assert "double free" in dump["reason"]
    assert any(e["event"] == TR.SUBMIT for e in dump["events"])
    assert "xpike_decode_steps_total" in dump["metrics"]

    with pytest.raises(ValueError, match="use-after-free"):
        sch.pages.retain(pid)
    with pytest.raises(ValueError, match="unoccupied"):
        sch.evict(1)  # slot 1 never held a request
    assert len(obs.recorder.dumps) == 3  # one postmortem per guard hit
    assert len(set(obs.recorder.dumps)) == 3  # fresh file each time


def test_flight_recorder_per_slot_rings():
    from repro.obs import FlightRecorder

    rec = FlightRecorder(ring_size=4, per_slot=2)
    for i in range(10):
        rec.record({"event": TR.DECODE, "slot": i % 2, "step": i})
    assert len(rec.events()) == 4  # global ring bounded
    assert [e["step"] for e in rec.events(slot=0)] == [6, 8]
    assert [e["step"] for e in rec.events(slot=1)] == [7, 9]
    assert rec.events(slot=5) == []


def test_j_per_token_zero_token_convention(spiking_setup):
    """The documented denominator convention: 0 when nothing decoded and
    nothing booked; astronomically large (not a crash, not 0) when energy
    was booked before any token landed."""
    from repro.serving import ServeStats

    st = ServeStats()
    assert st.j_per_token == 0.0
    st.energy_j = 1e-6
    assert st.j_per_token == pytest.approx(1e-6 / 1e-9)
    st.decoded_tokens = 4
    assert st.j_per_token == pytest.approx(1e-6 / 4)
