"""MoE tests: EP (shard_map) vs dense reference, capacity drops, 8-device
all-to-all in a subprocess."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.launch.mesh import make_test_mesh
from repro.models import moe as M
from repro.models.layers import init_tree


def _cfg(experts=4, cf=8.0):
    return ModelConfig(
        name="t", family="moe", num_layers=1, d_model=16, num_heads=2,
        num_kv_heads=2, d_ff=32, vocab_size=64, num_experts=experts, moe_top_k=2,
        capacity_factor=cf, dtype="float32",
    )


def test_ep_matches_dense_when_no_drops(rng):
    """With generous capacity the sort-based EP path must equal the dense
    reference exactly (same router, same experts)."""
    cfg = _cfg(cf=16.0)
    params = init_tree(rng, M.moe_schema(cfg), jnp.float32)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (2, 8, cfg.d_model))
    y_dense, aux_d = M.moe_dense(params, x, cfg)
    mesh = make_test_mesh((1, 1))
    pctx = M.ParallelCtx(mesh=mesh, dp_axes=("data",), fsdp_axis="data",
                         tp_axis="model", seq_shard=False)
    y_ep, aux_e = M.moe_ep(params, x, cfg, pctx, seq_sharded=False)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_dense), atol=1e-5)
    np.testing.assert_allclose(float(aux_d), float(aux_e), rtol=1e-6)


def test_ep_differentiable(rng):
    cfg = _cfg()
    params = init_tree(rng, M.moe_schema(cfg), jnp.float32)
    x = jax.random.normal(rng, (1, 8, cfg.d_model))
    mesh = make_test_mesh((1, 1))
    pctx = M.ParallelCtx(mesh=mesh, dp_axes=("data",), fsdp_axis="data",
                         tp_axis="model", seq_shard=False)

    def loss(p):
        y, aux = M.moe_ep(p, x, cfg, pctx, seq_sharded=False)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(params)
    for name in ("wi", "wo", "router"):
        assert float(jnp.abs(g[name]).sum()) > 0, f"no grad for {name}"


def test_capacity_drops_tokens(rng):
    """With capacity_factor << 1 some tokens are dropped -> output rows of 0."""
    cfg = _cfg(cf=0.1)
    params = init_tree(rng, M.moe_schema(cfg), jnp.float32)
    x = jax.random.normal(rng, (1, 64, cfg.d_model))
    mesh = make_test_mesh((1, 1))
    pctx = M.ParallelCtx(mesh=mesh, dp_axes=("data",), fsdp_axis="data",
                         tp_axis="model", seq_shard=False)
    y, _ = M.moe_ep(params, x, cfg, pctx, seq_sharded=False)
    zero_rows = int(jnp.sum(jnp.all(y[0] == 0, axis=-1)))
    assert zero_rows > 0


def test_router_topk_normalised(rng):
    cfg = _cfg()
    params = init_tree(rng, M.moe_schema(cfg), jnp.float32)
    x = jax.random.normal(rng, (2, 8, cfg.d_model))
    w, idx, aux = M.router(params, x, cfg)
    np.testing.assert_allclose(np.asarray(jnp.sum(w, -1)), 1.0, rtol=1e-5)
    assert int(idx.max()) < cfg.num_experts
    assert float(aux) >= 1.0 - 1e-3  # aux >= 1 at balance, by construction


SUBPROCESS_PROG = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs.base import ModelConfig
    from repro.models import moe as M
    from repro.models.layers import init_tree
    from jax.sharding import Mesh
    cfg = ModelConfig(name="t", family="moe", num_layers=1, d_model=16,
                      num_heads=2, num_kv_heads=2, d_ff=32, vocab_size=64,
                      num_experts=4, moe_top_k=2, capacity_factor=16.0,
                      dtype="float32")
    rng = jax.random.PRNGKey(0)
    params = init_tree(rng, M.moe_schema(cfg), jnp.float32)
    x = jax.random.normal(jax.random.fold_in(rng, 1), (4, 8, cfg.d_model))
    mesh = Mesh(np.array(jax.devices()).reshape(2, 2, 2), ("pod", "data", "model"))
    pctx = M.ParallelCtx(mesh=mesh, dp_axes=("pod", "data"), fsdp_axis="data",
                         tp_axis="model", seq_shard=True)
    y_dense, _ = M.moe_dense(params, x, cfg)
    y_ep, _ = jax.jit(lambda p, xx: M.moe_ep(p, xx, cfg, pctx, seq_sharded=True))(params, x)
    err = float(jnp.max(jnp.abs(y_ep - y_dense)))
    assert err < 1e-4, f"EP vs dense mismatch on 8-dev mesh: {err}"
    print("OK", err)
    """
)


def test_ep_all_to_all_8_devices():
    """Real all_to_all/all_gather across an 8-device (2,2,2) host mesh."""
    r = subprocess.run([sys.executable, "-c", SUBPROCESS_PROG], capture_output=True,
                       text=True, timeout=300,
                       env={**__import__("os").environ, "PYTHONPATH": "src"})
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
