"""Per-arch smoke tests (REQUIRED: reduced config of the same family, one
forward/train step on CPU, shape + no-NaN assertions) plus decode parity
and spiking-mode integration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig, shape_applicable
from repro.configs.registry import ARCHS, cells, reduced_config
from repro.models import transformer as T
from repro.models.frontends import synth_frontend_batch
from repro.models.moe import ParallelCtx
from repro.optim import adamw as A


PCTX = ParallelCtx()


def _batch_for(cfg, key, b=2, s=16):
    if cfg.frontend != "none":
        return synth_frontend_batch(key, cfg, b, s)
    return {"tokens": jax.random.randint(key, (b, s + 1), 0, cfg.vocab_size, jnp.int32)}


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_train_step(arch, rng):
    """One full forward + backward + optimizer step on the reduced config."""
    cfg = reduced_config(arch)
    params = T.init_params(rng, cfg)
    batch = _batch_for(cfg, jax.random.fold_in(rng, 1))

    def loss_f(p):
        # rng: required by spiking archs (Bernoulli coding), ignored by ANN
        loss, m = T.loss_fn(p, batch, cfg, PCTX, moe_impl="dense", remat="none",
                            rng=jax.random.fold_in(rng, 2))
        return loss

    loss, grads = jax.value_and_grad(loss_f)(params)
    assert jnp.isfinite(loss), f"{arch}: NaN loss"
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gn) and float(gn) > 0, f"{arch}: bad grads"
    opt = A.AdamWConfig(lr=1e-3)
    state = A.init_opt_state(params, opt)
    new_params, state, m = A.apply_updates(params, grads, state, opt)
    assert jnp.isfinite(m["grad_norm"])


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_decode_shapes(arch, rng):
    cfg = reduced_config(arch)
    params = T.init_params(rng, cfg)
    cache = T.init_cache(cfg, 2, 32)
    logits, cache2 = T.decode_step(params, cache, jnp.zeros((2, 1), jnp.int32), cfg,
                                   PCTX, moe_impl="dense")
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert jnp.isfinite(logits.astype(jnp.float32)).all(), f"{arch}: NaN decode logits"


@pytest.mark.parametrize("arch", ["yi-9b", "gemma3-27b", "recurrentgemma-9b", "mamba2-780m"])
def test_decode_matches_forward(arch, rng):
    """Teacher-forced decode logits == full forward logits (cache parity)."""
    cfg = reduced_config(arch)
    params = T.init_params(rng, cfg)
    b, s = 2, 12
    tokens = jax.random.randint(jax.random.fold_in(rng, 2), (b, s), 0, cfg.vocab_size)
    full, _ = T.forward(params, {"tokens": tokens}, cfg, PCTX, moe_impl="dense",
                        remat="none")
    cache = T.init_cache(cfg, b, s)
    outs = []
    for i in range(s):
        lg, cache = T.decode_step(params, cache, tokens[:, i : i + 1], cfg, PCTX,
                                  moe_impl="dense")
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32), np.asarray(full, np.float32),
                               atol=5e-3)


def test_spiking_mode_forward(rng):
    """The paper's technique as a first-class mode of the generic LM."""
    import dataclasses

    cfg = dataclasses.replace(reduced_config("yi-9b"), spiking=True, spike_T=4,
                              attention_kind="ssa")
    params = T.init_params(rng, cfg)
    batch = {"tokens": jax.random.randint(rng, (2, 9), 0, cfg.vocab_size, jnp.int32)}
    loss, m = T.loss_fn(params, batch, cfg, PCTX, moe_impl="dense", remat="none",
                        rng=jax.random.fold_in(rng, 7))
    assert jnp.isfinite(loss)
    g = jax.grad(lambda p: T.loss_fn(p, batch, cfg, PCTX, moe_impl="dense",
                                     remat="none", rng=rng)[0])(params)
    gn = sum(float(jnp.abs(x).sum()) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


def test_spiking_mode_lif_attention(rng):
    import dataclasses

    cfg = dataclasses.replace(reduced_config("granite-3-8b"), spiking=True, spike_T=3,
                              attention_kind="lif")
    params = T.init_params(rng, cfg)
    batch = {"tokens": jax.random.randint(rng, (1, 9), 0, cfg.vocab_size, jnp.int32)}
    loss, _ = T.loss_fn(params, batch, cfg, PCTX, moe_impl="dense", remat="none", rng=rng)
    assert jnp.isfinite(loss)


def test_cells_enumeration():
    all_cells = cells(include_skipped=True)
    runnable = [c for c in all_cells if c[2]]
    skipped = [c for c in all_cells if not c[2]]
    assert len(all_cells) == 48
    assert len(runnable) == 39
    assert {c[0].name for c in skipped} == {
        "arctic-480b", "phi3.5-moe-42b-a6.6b", "musicgen-medium", "pixtral-12b",
        "qwen2.5-32b", "yi-9b", "granite-3-8b",
        "xpikeformer-gpt-4-256", "xpikeformer-gpt-8-512",
    }


def test_remainder_layers_used(rng):
    """gemma3 (62 = 10x6 + 2) must route through remainder params."""
    cfg = reduced_config("gemma3-27b")
    assert cfg.remainder_layers > 0
    params = T.init_params(rng, cfg)
    assert "remainder" in params
    batch = _batch_for(cfg, rng)

    def loss_of(p):
        return T.loss_fn(p, batch, cfg, PCTX, moe_impl="dense", remat="none")[0]

    g = jax.grad(loss_of)(params)
    rem_g = sum(float(jnp.abs(x).sum()) for x in jax.tree.leaves(g["remainder"]))
    assert rem_g > 0  # remainder blocks get gradient
