"""Sharding rule tests against the abstract production mesh (no devices)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs.base import SHAPES
from repro.configs.registry import ARCHS, get_config
from repro.models import transformer as T
from repro.optim import adamw as A
from repro.parallel import sharding as SH

MESH = AbstractMesh((("data", 16), ("model", 16)))
MESH3 = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


def test_spec_divisibility_fallback():
    # qwen: 40 heads don't divide 16 -> replicated; d_ff 27648 does -> model
    s = SH.spec_for(("embed", "heads", "head_dim"), (5120, 40, 128), MESH)
    assert tuple(s) in (("data",), ("data", None), ("data", None, None))
    s = SH.spec_for(("embed", "ffn"), (5120, 27648), MESH)
    assert tuple(s) == ("data", "model")


def test_spec_axis_used_once():
    # both dims want "model": only the first gets it
    s = SH.spec_for(("vocab", "ffn"), (32000, 4864), MESH)
    assert tuple(s) == ("model",)


def test_fsdp_gate():
    s = SH.spec_for(("embed", "ffn"), (4096, 12800), MESH, fsdp=False)
    assert tuple(s) == (None, "model")


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_specs_all_archs(arch):
    """Every leaf gets a valid spec whose sharded dims divide the axis."""
    cfg = get_config(arch)
    specs = SH.param_pspecs(cfg, MESH)
    schema = T.model_schema(cfg)
    sizes = SH.axis_sizes(MESH)
    flat_s = jax.tree.leaves(specs)
    flat_d = jax.tree.leaves(schema, is_leaf=lambda x: hasattr(x, "axes"))
    assert len(flat_s) == len(flat_d)
    for spec, d in zip(flat_s, flat_d):
        for dim, entry in zip(d.shape, tuple(spec)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            prod = int(np.prod([sizes[a] for a in axes]))
            assert dim % prod == 0, f"{arch}: {d.shape} {spec}"


def test_moe_expert_specs_match_shardmap_contract():
    cfg = get_config("arctic-480b")
    specs = SH.param_pspecs(cfg, MESH)
    wi = specs["periods"]["blk0"]["moe"]["wi"]
    wo = specs["periods"]["blk0"]["moe"]["wo"]
    assert tuple(wi) == (None, "model", None, "data")  # [layers, E, d, f]
    assert tuple(wo) == (None, "model", "data")  # [layers, E, f, d] (d trimmed)


def test_batch_and_cache_specs():
    assert SH.batch_pspec(MESH3, 256) == ("pod", "data")
    assert SH.batch_pspec(MESH3, 1) is None
    cfg = get_config("qwen2.5-32b")
    cs = SH.cache_pspecs(cfg, MESH, 128, 32768)
    kspec = cs["periods"]["blk0"]["k"]
    assert tuple(kspec)[:3] == (None, "data", "model")  # [layers, B, S, ...]


def test_opt_state_specs_parallel():
    cfg = get_config("arctic-480b")
    pspecs = SH.param_pspecs(cfg, MESH)
    aparams = T.abstract_params(cfg)
    opt = A.AdamWConfig(state_dtype="int8")
    ospecs = A.opt_state_pspecs(pspecs, aparams, opt)
    wi_m = ospecs["m"]["periods"]["blk0"]["moe"]["wi"]
    assert set(wi_m) == {"q", "scale"}


@pytest.mark.parametrize("mesh", [MESH, MESH3])
def test_make_pctx(mesh):
    from repro.configs.base import ParallelConfig

    pctx = SH.make_pctx(mesh, ParallelConfig())
    assert pctx.tp_axis == "model"
    assert pctx.fsdp_axis == "data"
    assert pctx.tp_size == 16
