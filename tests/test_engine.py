"""Backend-parity tests for the unified XpikeformerEngine API.

The contract of ``repro.engine``:

* ``pallas`` (interpret=True) is **bit-exact** against the ``integer``
  hardware oracle given the same PRNG key — through the *full* spiking
  ViT and GPT forwards, not just per-kernel.
* ``reference`` (float + straight-through) agrees with ``integer`` in
  distribution: at T=32 the time-averaged outputs (read out linearly by
  the classifier head, so logit differences == rate differences) match
  within a statistical tolerance.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.xpikeformer import SPIKING_ARCHS
from repro.data.icl_mimo import MIMOConfig, sample_batch as mimo_batch
from repro.engine import (BACKENDS, IntegerBackend, PallasBackend,
                          ReferenceBackend, XpikeformerEngine, get_backend)

ARCH_INPUTS = {
    "xpikeformer-vit-smoke": lambda key: jax.random.uniform(key, (4, 16, 16, 3)),
    "xpikeformer-gpt-smoke": lambda key: mimo_batch(key, MIMOConfig(), 4)["features"],
}


def _engine(name, backend, T=None, params=None):
    task, cfg = SPIKING_ARCHS[name]
    if T is not None:
        cfg = dataclasses.replace(cfg, T=T)
    eng = XpikeformerEngine.from_config(cfg, task=task, backend=backend)
    eng.params = params
    return eng


@pytest.mark.parametrize("arch", sorted(ARCH_INPUTS))
def test_pallas_bit_exact_vs_integer_oracle(arch, rng):
    """Full-model forward: pallas kernels == integer hardware oracle, bit for bit."""
    x = ARCH_INPUTS[arch](jax.random.fold_in(rng, 1))
    ei = _engine(arch, "integer")
    params = ei.init(rng)
    ep = _engine(arch, "pallas", params=params)
    li = ei.forward(x, jax.random.fold_in(rng, 2))
    lp = ep.forward(x, jax.random.fold_in(rng, 2))
    np.testing.assert_array_equal(np.asarray(li), np.asarray(lp))


@pytest.mark.parametrize("arch", sorted(ARCH_INPUTS))
def test_reference_matches_integer_rates(arch, rng):
    """reference vs integer output rates at T=32 (statistical tolerance).

    The head reads the pooled firing rates linearly, so logit agreement is
    rate agreement.  5-bit weight quantisation on the integer side plus
    finite-T sampling noise bound the gap well below the logit scale."""
    x = ARCH_INPUTS[arch](jax.random.fold_in(rng, 1))
    er = _engine(arch, "reference", T=32)
    params = er.init(rng)
    ei = _engine(arch, "integer", T=32, params=params)
    lr_ = er.forward(x, jax.random.fold_in(rng, 2))
    li = ei.forward(x, jax.random.fold_in(rng, 2))
    scale = float(jnp.mean(jnp.abs(lr_)))
    assert float(jnp.mean(jnp.abs(lr_ - li))) < max(0.1 * scale, 0.05)


@pytest.mark.parametrize("arch", sorted(ARCH_INPUTS))
def test_all_backends_one_call_site(arch, rng):
    """The acceptance contract: one engine call site, every backend."""
    x = ARCH_INPUTS[arch](jax.random.fold_in(rng, 1))
    params = _engine(arch, "reference").init(rng)
    for backend in sorted(BACKENDS):
        eng = _engine(arch, backend, params=params)
        logits = eng.forward(x, jax.random.fold_in(rng, 2))
        assert jnp.isfinite(logits).all(), f"{backend}: non-finite logits"


def test_programmed_inference_stays_bit_exact(rng):
    """program() -> PCM state; integer and pallas still agree bit-for-bit."""
    arch = "xpikeformer-vit-smoke"
    x = ARCH_INPUTS[arch](jax.random.fold_in(rng, 1))
    ei = _engine(arch, "integer")
    ei.init(rng)
    hw = ei.program(jax.random.fold_in(rng, 3))
    assert ei.sim.wmode == "hw"
    ep = _engine(arch, "pallas", params=hw)
    ep.sim = ei.sim
    li = ei.forward(x, jax.random.fold_in(rng, 2))
    lp = ep.forward(x, jax.random.fold_in(rng, 2))
    np.testing.assert_array_equal(np.asarray(li), np.asarray(lp))


def test_programmed_lifecycle_on_matrix_backend(rng, engine_backend):
    """program -> drift -> recalibrate executes on the CI-matrix backend
    (XPIKE_BACKEND): every substrate runs the programmed device state, and
    lifecycle updates change leaf values only (jit caches stay warm)."""
    arch = "xpikeformer-gpt-smoke"
    x = ARCH_INPUTS[arch](jax.random.fold_in(rng, 1))
    eng = _engine(arch, engine_backend)
    eng.init(rng)
    eng.program(jax.random.fold_in(rng, 3))
    treedef = jax.tree.structure(eng.params)
    shapes = [(l.shape, l.dtype) for l in jax.tree.leaves(eng.params)]
    for t in (0.0, 3600.0, 3.15e7):
        eng.drift_to(t)
        eng.recalibrate()
        logits = eng.forward(x, jax.random.fold_in(rng, 2))
        assert jnp.isfinite(logits).all(), f"{engine_backend} t={t}"
        assert jax.tree.structure(eng.params) == treedef
        assert [(l.shape, l.dtype) for l in jax.tree.leaves(eng.params)] == shapes


def test_task_helpers(rng):
    vit = _engine("xpikeformer-vit-smoke", "pallas")
    vit.init(rng)
    images = ARCH_INPUTS["xpikeformer-vit-smoke"](jax.random.fold_in(rng, 1))
    labels = vit.classify(images, jax.random.fold_in(rng, 2))
    assert labels.shape == (4,) and labels.dtype in (jnp.int32, jnp.int64)

    gpt = _engine("xpikeformer-gpt-smoke", "integer")
    gpt.init(rng)
    feats = ARCH_INPUTS["xpikeformer-gpt-smoke"](jax.random.fold_in(rng, 1))
    syms = gpt.detect_symbols(feats, jax.random.fold_in(rng, 2))
    assert syms.shape == feats.shape[:2]


def test_reference_backend_is_differentiable(rng):
    eng = _engine("xpikeformer-vit-smoke", "reference")
    params = eng.init(rng)
    images = ARCH_INPUTS["xpikeformer-vit-smoke"](jax.random.fold_in(rng, 1))

    def loss(p):
        return jnp.sum(eng.forward(images, jax.random.fold_in(rng, 2), p) ** 2)

    g = jax.grad(loss)(params)
    gn = sum(float(jnp.abs(l).sum()) for l in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


def test_backend_registry():
    assert isinstance(get_backend("reference"), ReferenceBackend)
    assert isinstance(get_backend("integer"), IntegerBackend)
    assert isinstance(get_backend(None), ReferenceBackend)
    pb = get_backend("pallas", interpret=True)
    assert isinstance(pb, PallasBackend) and pb.interpret
    inst = IntegerBackend()
    assert get_backend(inst) is inst
    with pytest.raises(KeyError):
        get_backend("tpu-v7")
    with pytest.raises(KeyError):
        XpikeformerEngine.from_config("not-an-arch")


def test_generic_lm_stack_backend_dispatch(rng):
    """models/transformer.py spiking path runs on a non-default backend."""
    from repro.configs.registry import reduced_config
    from repro.models import transformer as T

    cfg = reduced_config("xpikeformer-gpt-4-256")
    params = T.init_params(rng, cfg)
    batch = {"tokens": jax.random.randint(rng, (2, 17), 0, cfg.vocab_size, jnp.int32)}
    for backend in ("integer", "pallas"):
        loss, _ = T.loss_fn(params, batch, cfg, moe_impl="dense", remat="none",
                            rng=rng, backend=get_backend(backend))
        assert jnp.isfinite(loss)
