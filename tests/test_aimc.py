"""AIMC simulation tests: quantisation, noise, drift, GDC, HWAT."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aimc as AM


CFG = AM.AIMCConfig()


def test_quantisation_error_bounded(rng):
    w = jax.random.normal(rng, (64, 32)) * 0.1
    scale = AM.column_scale(w, CFG)
    lv = AM.quantize_levels(w, scale, CFG)
    err = jnp.abs(lv * scale - w)
    assert float(jnp.max(err / jnp.maximum(scale, 1e-9))) <= 0.5 + 1e-6


def test_program_and_ideal_inference_close(rng):
    cfg = AM.AIMCConfig(prog_noise_sigma=0.0, read_noise_sigma=0.0)
    w = jax.random.normal(rng, (256, 64)) * 0.1
    hw = AM.program_weights(rng, w, cfg)
    x = (jax.random.uniform(jax.random.fold_in(rng, 1), (8, 256)) < 0.4).astype(jnp.float32)
    out = AM.aimc_matmul(None, x, hw, cfg, t_seconds=0.0)
    ideal = x @ w
    # only quantisation (5-bit weights + 5-bit ADC) separates them
    assert float(jnp.mean(jnp.abs(out - ideal))) < 0.25 * float(jnp.std(ideal))


def test_drift_decays_conductance(rng):
    w = jnp.abs(jax.random.normal(rng, (32, 16))) * 0.1
    hw = AM.program_weights(rng, w, CFG)
    g0 = jnp.sum(jnp.abs(AM.effective_weights(hw, 0.0, CFG)))
    g1 = jnp.sum(jnp.abs(AM.effective_weights(hw, 3.15e7, CFG)))
    assert float(g1) < float(g0)


def test_gdc_restores_scale(rng):
    w = jax.random.normal(rng, (256, 64)) * 0.1
    cfg = AM.AIMCConfig(prog_noise_sigma=0.0, read_noise_sigma=0.0)
    hw = AM.program_weights(rng, w, cfg)
    x = (jax.random.uniform(jax.random.fold_in(rng, 1), (16, 256)) < 0.4).astype(jnp.float32)
    year = 3.15e7
    out_nc = AM.aimc_matmul(None, x, hw, cfg, t_seconds=year, gdc=False)
    out_gdc = AM.aimc_matmul(None, x, hw, cfg, t_seconds=year, gdc=True)
    ideal = x @ w
    err_nc = float(jnp.mean(jnp.abs(out_nc - ideal)))
    err_gdc = float(jnp.mean(jnp.abs(out_gdc - ideal)))
    assert err_gdc < err_nc  # GDC recovers the global drift factor


def test_hwat_weights_straight_through_grad(rng):
    w = jax.random.normal(rng, (32, 16)) * 0.1
    g = jax.grad(lambda ww: AM.hwat_weights(rng, ww, CFG).sum())(w)
    np.testing.assert_allclose(np.asarray(g), np.ones_like(np.asarray(g)), rtol=1e-6)


def test_row_block_mapping_matches_unblocked(rng):
    """Accumulating per-128-row-block partial sums == full matmul (no ADC)."""
    # ADC step of exactly 1.0 level: integer partial sums pass through exact
    cfg = AM.AIMCConfig(prog_noise_sigma=0.0, read_noise_sigma=0.0, adc_bits=16,
                        adc_fullscale_rows=(2 ** 16 - 1) / (2 * 15))
    w = jax.random.normal(rng, (300, 40)) * 0.05
    hw = AM.program_weights(rng, w, cfg)
    x = (jax.random.uniform(jax.random.fold_in(rng, 2), (4, 300)) < 0.5).astype(jnp.float32)
    out = AM.aimc_matmul(None, x, hw, cfg)
    expect = x @ (hw["levels"] * hw["scale"])
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-4, atol=1e-4)
