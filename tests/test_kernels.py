"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle.

All three kernels are integer/bit-exact, so the assertion is equality."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


@pytest.mark.parametrize("t,b,h,n,d", [
    (1, 1, 1, 32, 32),
    (2, 2, 2, 64, 32),
    (3, 1, 2, 32, 64),
    (2, 1, 1, 128, 64),
])
@pytest.mark.parametrize("causal", [False, True])
def test_ssa_kernel_matches_ref(t, b, h, n, d, causal):
    key = jax.random.PRNGKey(n + d + t)
    ks = jax.random.split(key, 4)
    q = jax.random.bernoulli(ks[0], 0.3, (t, b, h, n, d)).astype(jnp.uint8)
    k = jax.random.bernoulli(ks[1], 0.5, (t, b, h, n, d)).astype(jnp.uint8)
    v = jax.random.bernoulli(ks[2], 0.6, (t, b, h, n, d)).astype(jnp.uint8)
    out = ops.ssa_attention_packed(q, k, v, ks[3], causal=causal, interpret=True)
    g = t * b * h
    rs, ra = ops.draw_comparator_prns(ks[3], (g, n, n), (g, n, d), d, n)
    exp = ref.ssa_attention_ref(
        q.reshape(g, n, d), k.reshape(g, n, d), v.reshape(g, n, d), rs, ra, causal=causal
    ).reshape(t, b, h, n, d)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


@pytest.mark.parametrize("dtype", [jnp.uint8, jnp.int32, jnp.float32, jnp.bfloat16])
def test_ssa_kernel_input_dtypes(dtype):
    key = jax.random.PRNGKey(0)
    q = jax.random.bernoulli(key, 0.5, (1, 1, 1, 32, 32)).astype(dtype)
    out = ops.ssa_attention_packed(q, q, q, key, interpret=True)
    assert out.dtype == jnp.uint8


def test_pack_unpack_roundtrip():
    key = jax.random.PRNGKey(3)
    x = jax.random.bernoulli(key, 0.5, (5, 96)).astype(jnp.uint8)
    np.testing.assert_array_equal(np.asarray(ops.unpack_bits(ops.pack_bits(x), 96)),
                                  np.asarray(x))


@pytest.mark.parametrize("t,m", [(1, 128), (4, 4096), (8, 5000), (16, 33)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lif_kernel_matches_ref(t, m, dtype):
    key = jax.random.PRNGKey(t * m)
    cur = (jax.random.normal(key, (t, m)) * 1.3).astype(dtype)
    out = ops.lif_fused(cur, interpret=True)
    exp = ref.lif_ref(cur)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


@pytest.mark.parametrize("beta,th", [(0.5, 1.0), (0.9, 0.5)])
def test_lif_kernel_params(beta, th):
    cur = jnp.full((6, 256), 0.4, jnp.float32)
    out = ops.lif_fused(cur, beta=beta, v_thresh=th, interpret=True)
    exp = ref.lif_ref(cur, beta=beta, v_thresh=th)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


@pytest.mark.parametrize("t,b,din,dout", [
    (2, 8, 128, 128),
    (4, 17, 200, 130),
    (1, 128, 256, 384),
    (7, 3, 64, 512),
])
def test_aimc_kernel_matches_ref(t, b, din, dout):
    key = jax.random.PRNGKey(din + dout)
    ks = jax.random.split(key, 3)
    sp = jax.random.bernoulli(ks[0], 0.35, (t, b, din)).astype(jnp.float32)
    w = jax.random.randint(ks[1], (din, dout), -15, 16, jnp.int8)
    sc = jax.random.uniform(ks[2], (dout,), jnp.float32, 0.01, 0.1)
    out = ops.aimc_spiking_linear(sp, w, sc, interpret=True)
    exp = ref.aimc_spiking_linear_ref(sp, w, sc)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


@settings(deadline=None, max_examples=8)
@given(t=st.integers(1, 5), b=st.integers(1, 9),
       din=st.sampled_from([32, 100, 128]), dout=st.sampled_from([64, 128, 130]))
def test_aimc_kernel_property(t, b, din, dout):
    key = jax.random.PRNGKey(t * 1000 + b * 100 + din + dout)
    sp = jax.random.bernoulli(key, 0.4, (t, b, din)).astype(jnp.float32)
    w = jax.random.randint(key, (din, dout), -15, 16, jnp.int8)
    sc = jnp.full((dout,), 0.05, jnp.float32)
    out = ops.aimc_spiking_linear(sp, w, sc, interpret=True)
    exp = ref.aimc_spiking_linear_ref(sp, w, sc)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))
