"""Paper-model tests: spiking ViT/GPT in all three modes + AIMC wmodes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aimc import AIMCConfig
from repro.core.spiking_transformer import (AIMCSim, SpikingConfig, gpt_forward,
                                            init_gpt, init_vit, program_model,
                                            vit_forward)
from repro.data.icl_mimo import MIMOConfig, sample_batch as mimo_batch
from repro.data.synthetic_images import ImageConfig, sample_batch as img_batch
from repro.train.hwat import train_stage, two_stage_train


@pytest.mark.parametrize("mode", ["ann", "lif", "ssa"])
def test_vit_forward_modes(mode, rng):
    icfg = ImageConfig(size=16)
    vcfg = SpikingConfig(depth=1, dim=32, num_heads=2, T=3, mode=mode,
                         image_size=16, patch_size=4)
    params = init_vit(rng, vcfg)
    b = img_batch(rng, icfg, 4)
    logits = vit_forward(params, b["images"], vcfg, AIMCSim(), rng)
    assert logits.shape == (4, 10)
    assert jnp.isfinite(logits).all()


@pytest.mark.parametrize("mode", ["ann", "ssa"])
def test_gpt_forward_modes(mode, rng):
    mcfg = MIMOConfig()
    gcfg = SpikingConfig(depth=1, dim=32, num_heads=2, T=3, mode=mode,
                         input_dim=mcfg.feat_dim, vocab=mcfg.n_classes)
    params = init_gpt(rng, gcfg)
    b = mimo_batch(rng, mcfg, 4)
    logits = gpt_forward(params, b["features"], gcfg, AIMCSim(), rng)
    assert logits.shape == (4, mcfg.seq_len, mcfg.n_classes)
    assert jnp.isfinite(logits).all()


def test_gpt_causality(rng):
    """ANN mode: perturbing the last token cannot change earlier logits."""
    mcfg = MIMOConfig()
    gcfg = SpikingConfig(depth=2, dim=32, num_heads=2, T=1, mode="ann",
                         input_dim=mcfg.feat_dim, vocab=mcfg.n_classes)
    params = init_gpt(rng, gcfg)
    b = mimo_batch(rng, mcfg, 2)
    f1 = b["features"]
    f2 = f1.at[:, -1, :].add(10.0)
    l1 = gpt_forward(params, f1, gcfg, AIMCSim(), rng)
    l2 = gpt_forward(params, f2, gcfg, AIMCSim(), rng)
    np.testing.assert_allclose(np.asarray(l1[:, :-1]), np.asarray(l2[:, :-1]), atol=1e-5)


def test_hwat_then_program_pipeline(rng):
    """CT -> HWAT -> program -> drifted inference end-to-end."""
    icfg = ImageConfig(size=16)
    vcfg = SpikingConfig(depth=1, dim=32, num_heads=2, T=3, mode="ssa",
                         image_size=16, patch_size=4)
    params = init_vit(rng, vcfg)
    fwd = lambda p, b, sim, r: vit_forward(p, b["images"], vcfg, sim, r)
    data = lambda k: img_batch(k, icfg, 16)
    params, curves = two_stage_train(params, fwd, data, ct_steps=5, hwat_steps=3,
                                     lr=1e-3)
    assert len(curves["ct"]) == 5 and len(curves["hwat"]) == 3
    from repro import aimc_device as AD

    acfg = AIMCConfig()
    hw = program_model(rng, params, acfg)
    b = img_batch(rng, icfg, 4)
    for t in (0.0, 3.15e7):
        # device lifecycle: drift the programmed state to t, then GDC
        drifted = AD.recalibrate_tree(AD.drift_tree(hw, t, acfg), acfg)
        logits = vit_forward(drifted, b["images"], vcfg,
                             AIMCSim(wmode="hw"), rng)
        assert jnp.isfinite(logits).all()


def test_ct_training_reduces_loss(rng):
    mcfg = MIMOConfig()
    gcfg = SpikingConfig(depth=1, dim=32, num_heads=2, T=1, mode="ann",
                         input_dim=mcfg.feat_dim, vocab=mcfg.n_classes)
    params = init_gpt(rng, gcfg)
    fwd = lambda p, b, sim, r: gpt_forward(p, b["features"], gcfg, sim, r)
    data = lambda k: mimo_batch(k, mcfg, 32)
    params, losses = train_stage(params, fwd, data, steps=40, sim=AIMCSim(), lr=3e-3)
    assert losses[-1] < losses[0]
