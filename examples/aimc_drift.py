"""AIMC device-state walkthrough: train -> HWAT -> program -> drift -> GDC.

    PYTHONPATH=src python examples/aimc_drift.py            # ~2 min on CPU
    PYTHONPATH=src python examples/aimc_drift.py --steps 200

Demonstrates the full PCM lifecycle of `repro.aimc_device` on the paper's
ICL symbol-detection task (spiking GPT, Table IV):

1. two-stage training (conventional + hardware-aware, §V-A);
2. `engine.program()` — weights become `AIMCDeviceState` pytrees
   (5-bit differential-pair levels, frozen programming error, per-device
   drift exponents, device clock at t=0);
3. `engine.drift_to(t)` — conductances decay as G(t) = G0 (t/t0)^-nu; the
   digital execution image (int8 `levels_t`) refreshes without recompiling
   anything, and symbol-detection accuracy degrades;
4. `engine.recalibrate()` — global drift compensation (§V-B) folds the
   measured calibration gain into the per-column scales and recovers most
   of the accuracy;
5. the same programmed state served with a `DriftPolicy`: the continuous-
   batching scheduler ages the device from the decode clock, runs periodic
   GDC, and meters per-request energy from measured spike counts.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro import aimc_device as AD
from repro.core.aimc import AIMCConfig
from repro.core.spiking_transformer import SpikingConfig, gpt_forward, init_gpt
from repro.data.icl_mimo import MIMOConfig, sample_batch
from repro.engine import XpikeformerEngine
from repro.train.hwat import two_stage_train

HOUR, DAY, MONTH, YEAR = 3600.0, 86400.0, 2.592e6, 3.1536e7


def accuracy(eng, feats, labels, mask, rng):
    logits = eng.forward(feats, rng)
    hit = (jnp.argmax(logits, -1) == labels) * mask
    acc = float(jnp.sum(hit) / jnp.maximum(jnp.sum(mask), 1.0))
    return acc, logits


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60, help="CT training steps")
    args = ap.parse_args(argv)

    mcfg = MIMOConfig()
    gcfg = SpikingConfig(depth=1, dim=32, num_heads=2, T=6, mode="ssa",
                         input_dim=mcfg.feat_dim, vocab=mcfg.n_classes)
    acfg = AIMCConfig()

    # -- 1. CT + HWAT ---------------------------------------------------
    params = init_gpt(jax.random.PRNGKey(0), gcfg)
    fwd = lambda p, b, sim, rng: gpt_forward(p, b["features"], gcfg, sim, rng)
    data = lambda k: sample_batch(k, mcfg, 64)
    params, curves = two_stage_train(
        params, fwd, data, ct_steps=args.steps, hwat_steps=args.steps // 4,
        aimc_cfg=acfg, lr=2e-3, log_every=max(args.steps // 4, 1))
    print(f"CT loss {curves['ct'][0]:.3f} -> {curves['ct'][-1]:.3f}")

    # -- 2. program onto PCM -------------------------------------------
    eng = XpikeformerEngine.from_config(gcfg, task="gpt", backend="reference",
                                        aimc_cfg=acfg)
    eng.params = params
    eng.program(jax.random.PRNGKey(42))  # one-shot: second call would raise
    test = sample_batch(jax.random.PRNGKey(7), mcfg, 256)
    rng = jax.random.PRNGKey(5)
    base, logits0 = accuracy(eng, test["features"], test["labels"],
                             test["mask"], rng)
    scale = float(jnp.mean(jnp.abs(logits0)))
    print(f"t=0 (just programmed)  acc {base:.3f}")

    # -- 3/4. drift then recalibrate -----------------------------------
    # logit error vs the freshly-programmed model isolates drift from
    # finite training: it grows with t without GDC and recalibration
    # recovers most of it (paper §V-B / Fig. 7) at any training budget
    def err(lg):
        return float(jnp.mean(jnp.abs(lg - logits0))) / scale

    hw0 = eng.params  # pristine programmed tree (gdc_gain = 1)
    for label, t in (("1 hour", HOUR), ("1 day", DAY), ("1 month", MONTH),
                     ("1 year", YEAR)):
        # each row restarts from the pristine tree: recalibrate() stores a
        # stale gain, which would otherwise bleed into the next "no GDC" row
        eng.params = hw0
        eng.drift_to(t)
        drifted, lg_d = accuracy(eng, test["features"], test["labels"],
                                 test["mask"], rng)
        eng.recalibrate()
        recal, lg_r = accuracy(eng, test["features"], test["labels"],
                               test["mask"], rng)
        print(f"t={label:8s} no GDC: acc {drifted:.3f} logit-err {err(lg_d):.3f}"
              f"  ->  GDC: acc {recal:.3f} logit-err {err(lg_r):.3f}")

    # -- 5. the lifecycle in the serving loop ---------------------------
    srv = XpikeformerEngine.from_config("xpikeformer-gpt-4-256", task="lm",
                                        backend="integer", reduced=True)
    srv.init(jax.random.PRNGKey(1))
    srv.program(jax.random.PRNGKey(43))
    policy = AD.DriftPolicy(seconds_per_step=HOUR, recal_interval_s=12 * HOUR)
    prompts = [[3, 5, 7, 9], [4, 6], [2, 8, 1]]
    outs, stats = srv.serve(prompts, max_new=8, slots=2, cache_len=32,
                            drift=policy)
    print(f"served {stats.requests} requests on aging PCM: "
          f"device clock {stats.t_device_s/HOUR:.0f} h, "
          f"{stats.recalibrations} GDC recalibrations, "
          f"{stats.energy_j*1e9:.1f} nJ metered "
          f"({stats.spike_events:.0f} spike events)")


if __name__ == "__main__":
    main()
