"""End-to-end driver: Xpikeformer-GPT on ICL MIMO symbol detection (§VI Task 2).

    PYTHONPATH=src python examples/icl_symbol_detection.py            # quick
    PYTHONPATH=src python examples/icl_symbol_detection.py --paper    # 4-256,
                                                           paper-scale training

Trains the decoder-only spiking transformer with the paper's two-stage
recipe (CT then HWAT), programs the weights onto simulated PCM, and reports
BER at deployment time t=0 and after one year of conductance drift with
GDC on/off — the full §V/§VI pipeline in one script.
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core.aimc import AIMCConfig
from repro.core.spiking_transformer import (AIMCSim, SpikingConfig, gpt_forward,
                                            init_gpt, program_model)
from repro.data.icl_mimo import MIMOConfig, ber, sample_batch
from repro.engine import XpikeformerEngine
from repro.train.hwat import two_stage_train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true", help="paper-scale 4-256 model")
    ap.add_argument("--antennas", type=int, default=2, choices=[2, 4])
    ap.add_argument("--T", type=int, default=6)
    ap.add_argument("--backend", default="reference",
                    choices=["reference", "integer", "pallas"],
                    help="compute backend for deployment-time inference")
    args = ap.parse_args()

    mcfg = MIMOConfig(n_tx=args.antennas, n_rx=args.antennas)
    depth, dim, steps = (4, 256, 1500) if args.paper else (2, 96, 250)
    gcfg = SpikingConfig(depth=depth, dim=dim, num_heads=max(dim // 64, 2), T=args.T,
                         mode="ssa", input_dim=mcfg.feat_dim, vocab=mcfg.n_classes)
    acfg = AIMCConfig()
    print(f"Xpikeformer-GPT {depth}-{dim}, T={args.T}, {args.antennas}x{args.antennas} "
          f"antennas ({mcfg.n_classes} classes), {steps} CT steps")

    params = init_gpt(jax.random.PRNGKey(0), gcfg)
    fwd = lambda p, b, sim, rng: gpt_forward(p, b["features"], gcfg, sim, rng)
    data = lambda k: sample_batch(k, mcfg, 64)

    t0 = time.time()
    params, curves = two_stage_train(params, fwd, data, ct_steps=steps,
                                     hwat_steps=steps // 5, aimc_cfg=acfg,
                                     lr=2e-3, log_every=max(steps // 10, 1))
    print(f"trained in {time.time()-t0:.0f}s; "
          f"CT loss {curves['ct'][0]:.3f}->{curves['ct'][-1]:.3f}")

    test = sample_batch(jax.random.PRNGKey(777), mcfg, 512)
    hw = program_model(jax.random.PRNGKey(42), params, acfg)
    if args.backend != "reference":
        print("  note: PCM drift/GDC are analog effects modeled only by the "
              "reference backend; drifted rows run on it, deploy (t=0) on "
              f"--backend {args.backend}")
    for label, t, gdc in (("deploy (t=0)", 0.0, True),
                          ("1 year, no GDC", 3.15e7, False),
                          ("1 year, GDC", 3.15e7, True)):
        backend = args.backend if t == 0.0 else "reference"
        eng = XpikeformerEngine.from_config(gcfg, task="gpt", backend=backend,
                                            wmode="hw", aimc_cfg=acfg)
        eng.params = hw
        if t > 0:  # device lifecycle: age the PCM state, optionally GDC
            eng.drift_to(t)
            if gdc:
                eng.recalibrate()
        logits = eng.forward(test["features"], jax.random.PRNGKey(5))
        b = float(ber(logits, test["labels"], test["mask"], mcfg))
        print(f"  BER [{label:16s}, {backend}] = {b:.4f}")


if __name__ == "__main__":
    main()
