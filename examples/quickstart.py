"""Quickstart: the framework in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --arch xpikeformer-gpt-4-256

1. Pick an assigned architecture (--arch, default yi-9b) at smoke scale —
   the paper's own decoders are registered as xpikeformer-gpt-*.
2. Train it for 30 steps on the deterministic synthetic LM stream.
3. Decode 16 tokens with the KV cache.
4. Show the spiking (Xpikeformer) mode of the same architecture.
5. Run the paper's spiking ViT through the unified XpikeformerEngine on
   every compute backend (reference / integer / pallas).
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ParallelConfig
from repro.configs.registry import list_archs, reduced_config
from repro.data.pipeline import DataConfig, MarkovStream
from repro.models import transformer as T
from repro.models.moe import ParallelCtx
from repro.optim import adamw as A
from repro.train import loop as TL


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b", choices=list_archs())
    ap.add_argument("--steps", type=int, default=30)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    print(f"== {args.arch} (reduced: {cfg.num_layers}L d={cfg.d_model}) ==")
    parallel = ParallelConfig(moe_impl="dense", remat="none")
    pctx = ParallelCtx()
    opt = A.AdamWConfig(lr=1e-3, warmup_steps=3, total_steps=args.steps)

    key = jax.random.PRNGKey(0)
    params, opt_state = TL.init_state(key, cfg, opt, parallel)
    step_fn = jax.jit(TL.make_train_step(cfg, pctx, parallel, opt))
    data = MarkovStream(DataConfig(cfg.vocab_size, 32, 8))

    for step in range(args.steps):
        batch = data.batch_at(step)
        params, opt_state, m = step_fn(params, opt_state, batch,
                                       jax.random.fold_in(key, step))
        if step % 10 == 0 or step == args.steps - 1:
            print(f"  step {step:3d}  loss {float(m['loss']):.4f}")

    # --- decode with the KV cache ---
    cache = T.init_cache(cfg, 1, 64)
    tok = jnp.zeros((1, 1), jnp.int32)
    out = []
    for _ in range(16):
        logits, cache = T.decode_step(params, cache, tok, cfg, pctx, moe_impl="dense")
        tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        out.append(int(tok[0, 0]))
    print("  greedy decode:", out)

    # --- the paper's technique: same arch, spiking mode ---
    if not cfg.is_attention_free:
        scfg = dataclasses.replace(cfg, spiking=True, spike_T=4, attention_kind="ssa")
        sparams = T.init_params(key, scfg)
        batch = data.batch_at(0)
        loss, _ = T.loss_fn(sparams, batch, scfg, pctx, moe_impl="dense",
                            remat="none", rng=key)
        print(f"  spiking (SSA, T=4) forward loss: {float(loss):.4f}")

    # --- unified engine: one model, pluggable compute backends ---
    from repro.engine import XpikeformerEngine

    print("== XpikeformerEngine: spiking ViT on all backends ==")
    images = jax.random.uniform(jax.random.fold_in(key, 7), (4, 16, 16, 3))
    eparams = None
    for backend in ("reference", "integer", "pallas"):
        eng = XpikeformerEngine.from_config("xpikeformer-vit-smoke", backend=backend)
        eparams = eng.init(key) if eparams is None else eparams
        eng.params = eparams
        labels = eng.classify(images, jax.random.fold_in(key, 8))
        print(f"  backend={backend:9s} predictions: {list(map(int, labels))}")
    print("done.")


if __name__ == "__main__":
    main()
