"""Batched serving example: continuous-batching decode over any --arch.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma3-27b --requests 6
"""

import argparse

from repro.configs.registry import list_archs
from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()
    serve(args.arch, n_requests=args.requests, slots=args.slots,
          max_new=args.max_new)


if __name__ == "__main__":
    main()
