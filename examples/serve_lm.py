"""Batched serving example: continuous-batching decode over any --arch.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma3-27b --requests 6
    PYTHONPATH=src python examples/serve_lm.py --arch xpikeformer-gpt-4-256 \
        --backend pallas

Spiking SSA archs (xpikeformer-gpt-*) decode through the engine backend
over spike-train KV caches; pick --backend reference|integer|pallas.  Also
demonstrates the engine-level batch API::

    eng = XpikeformerEngine.from_config(arch, task="lm", backend=backend)
    eng.init(key)
    outs = eng.generate(prompts, max_new=8)
"""

import argparse

import jax

from repro.configs.registry import list_archs
from repro.engine import XpikeformerEngine
from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--backend", default="reference",
                    choices=["reference", "integer", "pallas"])
    args = ap.parse_args()
    serve(args.arch, n_requests=args.requests, slots=args.slots,
          max_new=args.max_new, backend=args.backend)

    # the same serving system through the engine facade (batch generate)
    eng = XpikeformerEngine.from_config(args.arch, task="lm",
                                        backend=args.backend, reduced=True)
    eng.init(jax.random.PRNGKey(0))
    outs = eng.generate([[5, 7, 9], [11, 13]], max_new=4, slots=2)
    print(f"[generate] {outs}")


if __name__ == "__main__":
    main()
