"""Spiking-ViT image classification (§VI Task 1, reduced scale).

    PYTHONPATH=src python examples/image_classify.py [--mode ann|lif|ssa] [--T 8]
    PYTHONPATH=src python examples/image_classify.py --backend pallas

Trains a ViT on the procedural image dataset in the chosen attention mode
and reports accuracy — run all three modes to reproduce Table III's
relative ordering (ANN >= LIF ~ SSA, SSA needing longer T).  Training uses
the differentiable reference backend; evaluation runs through the unified
``XpikeformerEngine`` on the backend of your choice (``integer`` /
``pallas`` = the bit-faithful hardware datapath).
"""

import argparse

import jax
import jax.numpy as jnp

from repro.core.spiking_transformer import AIMCSim, SpikingConfig, init_vit, vit_forward
from repro.data.synthetic_images import ImageConfig, sample_batch
from repro.engine import XpikeformerEngine
from repro.train.hwat import two_stage_train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="ssa", choices=["ann", "lif", "ssa"])
    ap.add_argument("--backend", default="reference",
                    choices=["reference", "integer", "pallas"],
                    help="compute backend for the final evaluation")
    ap.add_argument("--T", type=int, default=8)
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--depth", type=int, default=2)
    ap.add_argument("--dim", type=int, default=64)
    args = ap.parse_args()

    icfg = ImageConfig(size=16)
    vcfg = SpikingConfig(depth=args.depth, dim=args.dim, num_heads=2, T=args.T,
                         mode=args.mode, image_size=icfg.size, patch_size=4)
    print(f"ViT {args.depth}-{args.dim} mode={args.mode} T={args.T}")
    params = init_vit(jax.random.PRNGKey(0), vcfg)
    fwd = lambda p, b, sim, rng: vit_forward(p, b["images"], vcfg, sim, rng)
    data = lambda k: sample_batch(k, icfg, 64)
    params, _ = two_stage_train(params, fwd, data, ct_steps=args.steps,
                                hwat_steps=args.steps // 8, lr=3e-3,
                                log_every=max(args.steps // 10, 1))
    b = sample_batch(jax.random.PRNGKey(99), icfg, 512)
    backend = args.backend
    if args.mode == "ann" and backend != "reference":
        print(f"note: --mode ann has no spiking ops; --backend {backend} "
              "is ignored (float reference path)")
        backend = "reference"
    eng = XpikeformerEngine.from_config(vcfg, task="vit", backend=backend,
                                        wmode="hwat")
    eng.params = params
    preds = eng.classify(b["images"], jax.random.PRNGKey(3))
    acc = float(jnp.mean(preds == b["labels"]))
    print(f"accuracy[{backend}] = {acc:.3f}")


if __name__ == "__main__":
    main()
