"""Table III reproduction (reduced scale): image classification accuracy.

Trains the same-sized ViT in the paper's three rows — ANN-ViT,
SNN-ViT (LIF attention, Spikformer [13]), Xpikeformer-ViT (SSA) — on the
procedural image dataset (no ImageNet offline; DESIGN.md §1) and reports
accuracy + the spike-encoding length used.  The paper's claim validated
here is *relative*: ANN >= SNN-LIF ~ SSA, with SSA needing longer T.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.spiking_transformer import AIMCSim, SpikingConfig, init_vit, vit_forward
from repro.data.synthetic_images import ImageConfig, sample_batch
from repro.train.hwat import two_stage_train


def _train_eval(mode: str, T: int, steps: int, icfg: ImageConfig, seed: int = 0):
    vcfg = SpikingConfig(depth=2, dim=64, num_heads=2, T=T, mode=mode,
                         image_size=icfg.size, patch_size=4, num_classes=icfg.num_classes)
    params = init_vit(jax.random.PRNGKey(seed), vcfg)
    fwd = lambda p, b, sim, rng: vit_forward(p, b["images"], vcfg, sim, rng)
    data = lambda k: sample_batch(k, icfg, 64)
    params, _ = two_stage_train(params, fwd, data, ct_steps=steps,
                                hwat_steps=max(steps // 8, 1), lr=3e-3, seed=seed)
    b = sample_batch(jax.random.PRNGKey(1234), icfg, 256)
    logits = vit_forward(params, b["images"], vcfg, AIMCSim(wmode="hwat"),
                         jax.random.PRNGKey(5))
    return float(jnp.mean(jnp.argmax(logits, -1) == b["labels"]))


def run(fast: bool = True):
    steps = 90 if fast else 1200
    icfg = ImageConfig(size=16)
    rows = []
    for label, mode, T in (("ANN-ViT", "ann", 1), ("SNN-ViT(LIF)", "lif", 4),
                           ("Xpikeformer-ViT", "ssa", 10)):
        t0 = time.perf_counter()
        acc = _train_eval(mode, T, steps, icfg)
        dt = (time.perf_counter() - t0) * 1e6
        rows.append((f"table3/{label}(T={T})", dt, f"acc={acc:.3f}"))
    return rows
