"""Serving load generator: latency/goodput/energy under open-loop load.

    PYTHONPATH=src python benchmarks/serving_load.py --smoke
    PYTHONPATH=src python benchmarks/serving_load.py --smoke --http --json out.json

Drives the async serving front door (:mod:`repro.server`) with an
**open-loop Poisson arrival process** over a **prefix-share prompt
mixture** (a fraction of requests share a long common prefix — the
"system prompt" regime the paged KV cache is built for) and measures what
an operator actually sees:

* **TTFT** p50/p99 — submit-to-first-streamed-token, queueing included;
* **per-token latency** (inter-token gap) p50/p99;
* **goodput** — completed decoded tokens per wall-second of the run;
* **J/token** — metered energy per decoded token, from the scheduler's
  per-request spike-event meter.

``--http`` runs the same workload through real sockets (HTTP POST
/generate + SSE streaming) instead of the in-process front door — the
transport tax becomes visible in the latency columns.

Every run also serves the identical workload **offline** (all requests
submitted up front to a bare ``BatchScheduler``) as the denominator for
machine-robust gated ratios (CI gates the ``ratios`` block via
``check_regression.py``; absolute latencies swing with runner hardware):

* ``load_goodput_rel_offline_<arch>`` — open-loop goodput over offline
  throughput.  < 1 by construction (arrival gaps + admission overhead);
  a collapse means the front door is starving the scheduler.
* ``load_j_per_token_parity_<arch>`` — offline J/token over load
  J/token.  Energy metering is deterministic per request (spike events
  are a pure function of the token stream), so this sits at ~1.0
  regardless of batching order; drift means double- or under-booking.
* ``load_p99_ttft_steps_inv_<arch>`` / ``load_p99_tpot_steps_inv_<arch>``
  — mean batched-decode-step time over p99 TTFT / p99 per-token gap.
  Both sides scale with the runner, so the ratio tracks *scheduling*
  inflation (queue depth, pump latency), not CPU speed.
* ``obs_overhead_rel_<arch>`` — goodput with the :mod:`repro.obs`
  telemetry stack attached (metrics registry + tracer + flight recorder,
  the serve default) over goodput with ``enable_telemetry=False``.  The
  identical workload runs both ways on the one warm scheduler as
  alternating OFF/ON legs and the ratio compares medians, so machine
  drift and one-off spikes cancel and what remains is telemetry's
  pump-loop cost; it gets its own tight per-key tolerance (5%) in
  ``baseline.json`` — observability must stay effectively free.

Baselines for the latency ratios are set conservatively in
``benchmarks/baseline.json``: tail latencies on shared CI runners are
noisy, so the floor catches collapses (janky pump, stalled stream), not
few-percent wiggles.
"""

from __future__ import annotations

import argparse
import asyncio
import dataclasses
import json
import time

import jax
import numpy as np

from repro.configs.registry import get_config, reduced_config
from repro.engine import get_backend
from repro.models import transformer as T
from repro.server import FrontDoor, HttpFrontDoor, QueueFull, read_sse
from repro.serving import BatchScheduler

SPIKING_ARCH = "xpikeformer-gpt-4-256"


def percentile(xs, q: float) -> float:
    """Nearest-rank percentile; 0.0 for an empty sample."""
    if not xs:
        return 0.0
    xs = sorted(xs)
    k = min(len(xs) - 1, max(0, int(round(q / 100.0 * (len(xs) - 1)))))
    return float(xs[k])


def build_workload(cfg, *, n_requests: int, rate: float, max_new: int,
                   prefix_len: int, share_frac: float, seed: int):
    """(prompt, max_new, seed, arrival_s) per request, fully seeded.

    ``share_frac`` of the requests open with a common ``prefix_len``-token
    prefix plus a unique 3-token tail; the rest are unique short prompts.
    Arrivals are Poisson: exponential inter-arrival gaps at ``rate`` req/s.
    """
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, cfg.vocab_size, size=prefix_len).tolist()
    t = 0.0
    work = []
    for i in range(n_requests):
        t += float(rng.exponential(1.0 / rate))
        if rng.random() < share_frac:
            prompt = shared + rng.integers(0, cfg.vocab_size, size=3).tolist()
        else:
            prompt = rng.integers(
                0, cfg.vocab_size, size=int(rng.integers(4, 10))).tolist()
        work.append((prompt, max_new, seed + 1000 + i, t))
    return work


async def _drive_inproc(front: FrontDoor, workload):
    """Submit per the arrival schedule; returns (result dicts, makespan_s)."""
    t0 = time.perf_counter()  # durations on the monotonic clock

    async def one(item):
        prompt, max_new, seed, at = item
        delay = at - (time.perf_counter() - t0)
        if delay > 0:
            await asyncio.sleep(delay)
        while True:  # open loop: retry through load-shed, arrival time stands
            try:
                ts = await front.submit(prompt, max_new, seed=seed)
                break
            except QueueFull:
                await asyncio.sleep(0.02)
        await ts.tokens()
        return dataclasses.asdict(ts.result)

    res = await asyncio.gather(*(one(w) for w in workload))
    return list(res), time.perf_counter() - t0


async def _drive_http(srv: HttpFrontDoor, workload):
    """The same schedule through real sockets: POST /generate + SSE."""
    t0 = time.perf_counter()  # durations on the monotonic clock

    async def one(item):
        prompt, max_new, seed, at = item
        delay = at - (time.perf_counter() - t0)
        if delay > 0:
            await asyncio.sleep(delay)
        body = json.dumps({"prompt": prompt, "max_new": max_new,
                           "seed": seed}).encode()
        reader, writer = await asyncio.open_connection(srv.host, srv.port)
        try:
            writer.write(
                (f"POST /generate HTTP/1.1\r\nHost: {srv.host}\r\n"
                 f"Content-Type: application/json\r\n"
                 f"Content-Length: {len(body)}\r\n\r\n").encode() + body)
            await writer.drain()
            done = None
            async for ev, payload in read_sse(reader):
                if ev == "done":
                    done = payload
            return done
        finally:
            writer.close()
            try:
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    res = await asyncio.gather(*(one(w) for w in workload))
    return [r for r in res if r is not None], time.perf_counter() - t0


def bench_load(smoke: bool = True, *, n_requests: int = 12, rate: float = 8.0,
               max_new: int = 6, backend: str = "integer", slots: int = 4,
               cache_len: int = 64, prefix_len: int = 12,
               share_frac: float = 0.5, seed: int = 0, http: bool = False,
               paged: bool = False, page_len: int = 8):
    """Returns the {meta, results, ratios} dict written to ``--json``."""
    cfg = reduced_config(SPIKING_ARCH) if smoke else get_config(SPIKING_ARCH)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    be = get_backend(backend)
    work = build_workload(cfg, n_requests=n_requests, rate=rate,
                          max_new=max_new, prefix_len=prefix_len,
                          share_frac=share_frac, seed=seed)
    paged_kw = (dict(paged=True, page_len=page_len) if paged else {})

    # ONE scheduler for warmup, offline denominator and the load run —
    # compiled steps are per-instance, so measuring on a fresh instance
    # would charge compile time to whichever run goes first
    sch = BatchScheduler(params, cfg, be, slots=slots, cache_len=cache_len,
                         **paged_kw)

    def offline():
        for prompt, mn, s, _at in work:
            sch.submit(prompt, mn, seed=s)
        sch.run()
        return sch.stats

    offline()  # warmup: compiles prefill + batched decode
    sch.reset()
    off_st = offline()  # warm offline denominator
    off_snapshot = {"tokens_per_sec": off_st.tokens_per_sec,
                    "j_per_token": off_st.j_per_token}
    async def go(front):
        if http:
            async with HttpFrontDoor(front, port=0) as srv:
                return await _drive_http(srv, work)
        await front.start()
        try:
            return await _drive_inproc(front, work)
        finally:
            await front.stop()

    def goodput_of(results, makespan):
        return sum(len(r["tokens"]) for r in results) / max(makespan, 1e-9)

    # telemetry overhead: alternating OFF/ON legs, ratio of medians.
    # The smoke legs are sub-second, so a single-shot goodput carries a
    # few percent of machine noise — fatal under the tight 5% CI floor
    # on ``obs_overhead_rel``.  Alternation cancels slow machine drift
    # (an all-OFF-then-all-ON order would fold it into the ratio) and
    # the median kills one-off GC/scheduler spikes.  ``detach_obs``
    # between legs undoes the front door's sticky attach; each leg runs
    # the identical workload on the same warm scheduler, so the ratio
    # is the telemetry cost and nothing else.  The last ON leg doubles
    # as the measured load run for the latency/energy columns.
    obs_reps = 3
    good_off, good_on = [], []
    for _rep in range(obs_reps):
        sch.reset()
        sch.detach_obs()
        res_off, mk_off = asyncio.run(go(
            FrontDoor(sch, max_queue=max(n_requests, 16),
                      enable_telemetry=False)))
        good_off.append(goodput_of(res_off, mk_off))

        sch.reset()
        front = FrontDoor(sch, max_queue=max(n_requests, 16))
        results, makespan = asyncio.run(go(front))
        good_on.append(goodput_of(results, makespan))
    goodput_off = percentile(good_off, 50)
    st = sch.stats

    ttfts = [r["ttft_s"] for r in results]
    gaps = []
    for r in results:
        tt = r["token_times"]
        gaps += [b - a for a, b in zip(tt, tt[1:])]
    goodput = percentile(good_on, 50)
    load_jtok = st.j_per_token
    step_s = st.decode_s / max(st.decode_steps, 1)
    p99_ttft = percentile(ttfts, 99)
    p99_tpot = percentile(gaps, 99)

    mode = ("http" if http else "inproc") + (",paged" if paged else "")
    results_rows = [{
        "name": f"serve/{SPIKING_ARCH}[load,{backend},{mode}]",
        "arch": SPIKING_ARCH, "backend": backend, "slots": slots,
        "completed": len(results), "tokens_per_sec": goodput,
        "p50_ttft_s": percentile(ttfts, 50), "p99_ttft_s": p99_ttft,
        "p50_tpot_s": percentile(gaps, 50), "p99_tpot_s": p99_tpot,
        "j_per_token": load_jtok,
        "offline_tokens_per_sec": off_snapshot["tokens_per_sec"],
        "offline_j_per_token": off_snapshot["j_per_token"],
        "mean_step_s": step_s, "makespan_s": makespan,
        "goodput_telemetry_off": goodput_off,
    }]
    ratios = {
        f"load_goodput_rel_offline_{SPIKING_ARCH}":
            goodput / max(off_snapshot["tokens_per_sec"], 1e-9),
        f"load_j_per_token_parity_{SPIKING_ARCH}":
            off_snapshot["j_per_token"] / max(load_jtok, 1e-12),
        f"load_p99_ttft_steps_inv_{SPIKING_ARCH}":
            step_s / max(p99_ttft, 1e-9),
        f"load_p99_tpot_steps_inv_{SPIKING_ARCH}":
            step_s / max(p99_tpot, 1e-9),
        f"obs_overhead_rel_{SPIKING_ARCH}":
            goodput / max(goodput_off, 1e-9),
    }
    return {
        "meta": {"smoke": smoke, "n_requests": n_requests, "rate": rate,
                 "max_new": max_new, "backend": backend, "slots": slots,
                 "prefix_len": prefix_len, "share_frac": share_frac,
                 "seed": seed, "http": http, "paged": paged,
                 "device": jax.devices()[0].platform},
        "results": results_rows,
        "ratios": ratios,
    }


def run(fast: bool = True):
    """benchmarks/run.py entry: (name, us_per_call, derived) rows."""
    out = bench_load(smoke=fast, rate=200.0)  # saturating: measures capacity
    rows = []
    for r in out["results"]:
        rows.append((r["name"], 1e6 / max(r["tokens_per_sec"], 1e-9),
                     f"{r['tokens_per_sec']:.1f} tok/s goodput "
                     f"p99_ttft={r['p99_ttft_s']*1e3:.0f}ms"))
    for k, v in out["ratios"].items():
        rows.append((f"serve/ratio/{k}", 0.0, f"{v:.2f}x"))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", default=False,
                    help="reduced config (CPU CI)")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--rate", type=float, default=8.0,
                    help="Poisson arrival rate, req/s")
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--backend", default="integer",
                    choices=["reference", "integer", "pallas"])
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--share-frac", type=float, default=0.5,
                    help="fraction of requests opening with the shared prefix")
    ap.add_argument("--http", action="store_true", default=False,
                    help="drive through real sockets (HTTP POST + SSE)")
    ap.add_argument("--paged", action="store_true", default=False,
                    help="paged spike-train KV cache under the front door")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, help="write results JSON here")
    a = ap.parse_args(argv)
    out = bench_load(smoke=a.smoke, n_requests=a.requests, rate=a.rate,
                     max_new=a.max_new, backend=a.backend, slots=a.slots,
                     share_frac=a.share_frac, seed=a.seed, http=a.http,
                     paged=a.paged)
    for r in out["results"]:
        print(f"{r['name']:44s} {r['tokens_per_sec']:8.1f} tok/s goodput  "
              f"ttft p50/p99 {r['p50_ttft_s']*1e3:.0f}/{r['p99_ttft_s']*1e3:.0f} ms  "
              f"tpot p50/p99 {r['p50_tpot_s']*1e3:.0f}/{r['p99_tpot_s']*1e3:.0f} ms  "
              f"{r['j_per_token']*1e9:.1f} nJ/tok")
    for k, v in out["ratios"].items():
        print(f"{'ratio/' + k:44s} {v:8.2f} x")
    if a.json:
        with open(a.json, "w") as f:
            json.dump(out, f, indent=2)
        print(f"[serving_load] wrote {a.json}")


if __name__ == "__main__":
    main()
