"""Serving throughput: tokens/sec vs batch size vs backend.

    PYTHONPATH=src python benchmarks/serving_throughput.py --smoke
    PYTHONPATH=src python benchmarks/serving_throughput.py --smoke --json out.json

Drives the continuous-batching ``BatchScheduler`` (one jitted batched
``decode_step``) end to end and measures decoded tokens per wall-second:

* **batch sweep** — the same request load served with 1 vs N slots; the
  slots=1 run is the old sequential serve loop (one request at a time), so
  ``speedup@N`` is exactly what continuous batching buys.
* **backend sweep** — spiking SSA archs decode through every engine
  backend (reference / integer / pallas-interpret on CPU).
* **mesh sweep** (``--mesh DATAxMODEL``, needs data*model devices — run
  under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``) — the same
  load served through ``repro.distributed.Executor`` on a (data, model)
  host mesh: a tensor+data-parallel leg and a data-parallel-only leg, each
  gated as a ratio vs the single-device scheduler (baseline_mesh.json;
  host-mesh "devices" share one CPU, so the ratios track collective /
  partitioning overhead, not real-silicon speedup).

JSON output carries both absolute tok/s and machine-robust *ratios*
(batched-vs-sequential speedup, backend-vs-reference relative throughput);
CI gates regressions on the ratios (see ``benchmarks/check_regression.py``)
because absolute CPU throughput varies across runners.

``run(fast)`` rows integrate with ``benchmarks/run.py`` CSV output.
"""

from __future__ import annotations

import argparse
import json
import time

import jax

from repro.configs.registry import get_config, reduced_config
from repro.engine import get_backend
from repro.models import transformer as T
from repro.serving import BatchScheduler

SPIKING_ARCH = "xpikeformer-gpt-4-256"
ANN_ARCH = "yi-9b"


def _serve_once(sch, cfg, *, n_requests, max_new, seed=0):
    rng = jax.random.PRNGKey(seed)
    for i in range(n_requests):
        p = jax.random.randint(jax.random.fold_in(rng, i), (4 + (i % 3),), 0,
                               cfg.vocab_size, jax.numpy.int32)
        sch.submit(p, max_new, seed=seed + i)
    sch.run()
    return sch.stats


def _measure(params, cfg, backend, *, slots, cache_len, **kw):
    sch = BatchScheduler(params, cfg, backend, slots=slots, cache_len=cache_len)
    _serve_once(sch, cfg, **kw)  # warmup: compiles prefill + decode
    sch.reset()
    return _serve_once(sch, cfg, **kw)


def bench_mesh(smoke: bool = True, *, mesh_spec: str = "2x4", batch: int = 8,
               max_new: int = 8, backend: str = "integer"):
    """Mesh serving sweep -> the same {results, ratios} JSON shape.

    Ratios (gated against benchmarks/baseline_mesh.json in the
    multi-device CI job): sharded decode throughput relative to the
    single-device scheduler, for (data, model) and (data*model, 1)."""
    from repro.distributed import Executor
    from repro.launch.mesh import make_serving_mesh, parse_mesh_spec

    d, m = parse_mesh_spec(mesh_spec)
    cfg = reduced_config(SPIKING_ARCH) if smoke else get_config(SPIKING_ARCH)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    be = get_backend(backend)
    kw = dict(n_requests=batch, max_new=max_new)
    results, ratios = [], {}

    single = _measure(params, cfg, be, slots=batch, cache_len=64, **kw)
    results.append({
        "name": f"serve/{SPIKING_ARCH}[{backend},single]", "arch": SPIKING_ARCH,
        "backend": backend, "slots": batch,
        "tokens_per_sec": single.tokens_per_sec,
        "decode_tokens_per_sec": single.decode_tokens_per_sec,
    })
    for shape in ((d, m), (d * m, 1)):
        ex = Executor(params, cfg, be, make_serving_mesh(shape))
        sch = ex.scheduler(slots=batch, cache_len=64)
        _serve_once(sch, cfg, **kw)  # warmup (compiles sharded decode)
        sch.reset()
        st = _serve_once(sch, cfg, **kw)
        tag = f"dp{shape[0]}_tp{shape[1]}"
        results.append({
            "name": f"serve/{SPIKING_ARCH}[{backend},mesh_{tag}]",
            "arch": SPIKING_ARCH, "backend": backend, "slots": batch,
            "tokens_per_sec": st.tokens_per_sec,
            "decode_tokens_per_sec": st.decode_tokens_per_sec,
        })
        ratios[f"mesh_rel_{tag}_{SPIKING_ARCH}"] = (
            st.decode_tokens_per_sec / max(single.decode_tokens_per_sec, 1e-9))

    return {
        "meta": {"smoke": smoke, "batch": batch, "max_new": max_new,
                 "mesh": [d, m], "backend": backend,
                 "device": jax.devices()[0].platform,
                 "n_devices": len(jax.devices())},
        "results": results,
        "ratios": ratios,
    }


def bench_paged(smoke: bool = True, *, batch: int = 8, max_new: int = 8,
                backend: str = "integer", prefix_len: int = 24,
                page_len: int = 8, cache_len: int = 64):
    """Paged vs dense serving on a *prefix-share* workload.

    ``batch`` requests share a ``prefix_len``-token prompt prefix (think: a
    common system prompt) with unique 3-token tails.  The dense scheduler
    gets ``batch/2`` slots of ``cache_len`` KV; the paged scheduler gets
    the **same cache memory** as a page pool (``batch/2 * cache_len /
    page_len`` pages) but ``batch`` slots — exact prefix sharing is what
    lets twice the concurrency fit the identical budget.  The prefix cache
    is warmed by one extra request (the steady-state serving condition).

    Gated ratios:

    * ``paged_concurrency_*`` — peak concurrently-active paged slots over
      dense slots at the same memory (the >= 2x acceptance claim;
      deterministic page/slot accounting, not wall time);
    * ``paged_prefix_hit_frac_*`` — fraction of prompt-context tokens
      served from shared pages instead of prefill compute (deterministic);
    * ``paged_prefix_share_e2e_rel_*`` — end-to-end decoded-token
      throughput, paged over dense.  e2e is the honest cross-mode wall
      clock: the paged server's prompt work rides its batched step (and is
      mostly *skipped* via the prefix cache), while the dense server's
      prompt work runs in batch-1 admission scans outside its decode
      phase.  On this workload the skipped prefill puts paged well ahead.

    (Decode-phase tok/s is reported per row but deliberately not compared
    across modes: the two schedulers account prefill time differently.)
    """
    cfg = reduced_config(SPIKING_ARCH) if smoke else get_config(SPIKING_ARCH)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    be = get_backend(backend)
    dense_slots = max(batch // 2, 1)
    n_pages = dense_slots * cache_len // page_len + 2  # same bytes + null/trash

    rng = jax.random.PRNGKey(11)
    shared = jax.random.randint(rng, (prefix_len,), 0, cfg.vocab_size,
                                jax.numpy.int32).tolist()
    prompts = [shared + [1 + i, 2 + i, 3 + i] for i in range(batch)]

    def serve(sch, warm_prefix):
        if warm_prefix:  # steady state: the shared prefix is already cached
            sch.submit(shared + [0], 1, seed=999)
            sch.run()
        for i, p in enumerate(prompts):
            sch.submit(p, max_new, seed=i)
        sch.run()  # run() accumulates wall_s (warm request included: the
        return sch.stats  # cache-warming cost is charged to the paged side

    def measure(paged):
        kw = (dict(paged=True, page_len=page_len, n_pages=n_pages,
                   slots=batch) if paged else dict(slots=dense_slots))
        sch = BatchScheduler(params, cfg, be, cache_len=cache_len, **kw)
        serve(sch, paged)  # warmup: compiles the step (and warms the cache)
        sch.reset()
        return serve(sch, paged)

    dense = measure(False)
    paged = measure(True)
    results = [{
        "name": f"serve/{SPIKING_ARCH}[{backend},prefix-share,dense{dense_slots}]",
        "arch": SPIKING_ARCH, "backend": backend, "slots": dense_slots,
        "tokens_per_sec": dense.tokens_per_sec,
        "decode_tokens_per_sec": dense.decode_tokens_per_sec,
    }, {
        "name": f"serve/{SPIKING_ARCH}[{backend},prefix-share,paged{batch}]",
        "arch": SPIKING_ARCH, "backend": backend, "slots": batch,
        "tokens_per_sec": paged.tokens_per_sec,
        "decode_tokens_per_sec": paged.decode_tokens_per_sec,
        "prefix_hit_tokens": paged.prefix_hit_tokens,
        "pages_in_use_peak": paged.pages_in_use_peak,
        "peak_active_slots": paged.peak_active_slots,
        "cow_copies": paged.cow_copies,
    }]
    ctx_tokens = batch * (len(prompts[0]) - 1)
    ratios = {
        f"paged_concurrency_{SPIKING_ARCH}":
            paged.peak_active_slots / max(dense.peak_active_slots, 1),
        f"paged_prefix_hit_frac_{SPIKING_ARCH}":
            paged.prefix_hit_tokens / max(ctx_tokens, 1),
        f"paged_prefix_share_e2e_rel_{SPIKING_ARCH}":
            paged.tokens_per_sec / max(dense.tokens_per_sec, 1e-9),
    }
    return {
        "meta": {"smoke": smoke, "batch": batch, "max_new": max_new,
                 "backend": backend, "prefix_len": prefix_len,
                 "page_len": page_len, "cache_len": cache_len,
                 "dense_slots": dense_slots, "n_pages": n_pages,
                 "device": jax.devices()[0].platform},
        "results": results,
        "ratios": ratios,
    }


def bench(smoke: bool = True, *, batch: int = 8, max_new: int = 8,
          backends=("reference", "integer", "pallas")):
    """Returns the result dict written to --json."""
    results = []
    ratios = {}

    def load(arch):
        cfg = reduced_config(arch) if smoke else get_config(arch)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        return cfg, params

    def row(name, arch, bk, slots, st):
        return {
            "name": name, "arch": arch, "backend": bk, "slots": slots,
            "tokens_per_sec": st.tokens_per_sec,
            "decode_tokens_per_sec": st.decode_tokens_per_sec,
        }

    # -- ANN arch: batched vs sequential ------------------------------
    cfg, params = load(ANN_ARCH)
    kw = dict(n_requests=batch, max_new=max_new, cache_len=64)
    seq = _measure(params, cfg, None, slots=1, **kw)
    bat = _measure(params, cfg, None, slots=batch, **kw)
    results += [
        row(f"serve/{ANN_ARCH}[seq]", ANN_ARCH, "float", 1, seq),
        row(f"serve/{ANN_ARCH}[batch{batch}]", ANN_ARCH, "float", batch, bat),
    ]
    # speedup is gated on decode-phase throughput: prefill is the same
    # batch-1 scan in both configurations, the batched decode_step is the win
    ratios[f"speedup_batch{batch}_{ANN_ARCH}"] = (
        bat.decode_tokens_per_sec / max(seq.decode_tokens_per_sec, 1e-9))

    # -- spiking arch: backend sweep + batched vs sequential ----------
    cfg, params = load(SPIKING_ARCH)
    ref_bat = None
    for bk in backends:
        be = get_backend(bk)
        bat = _measure(params, cfg, be, slots=batch, **kw)
        results.append(
            row(f"serve/{SPIKING_ARCH}[{bk},batch{batch}]", SPIKING_ARCH, bk,
                batch, bat))
        if bk == "reference":
            ref_bat = bat
            seq = _measure(params, cfg, be, slots=1, **kw)
            results.append(
                row(f"serve/{SPIKING_ARCH}[{bk},seq]", SPIKING_ARCH, bk, 1, seq))
            ratios[f"speedup_batch{batch}_{SPIKING_ARCH}"] = (
                bat.decode_tokens_per_sec / max(seq.decode_tokens_per_sec, 1e-9))
        elif ref_bat is not None:
            ratios[f"rel_{bk}_vs_reference_{SPIKING_ARCH}"] = (
                bat.decode_tokens_per_sec / max(ref_bat.decode_tokens_per_sec, 1e-9))

    return {
        "meta": {"smoke": smoke, "batch": batch, "max_new": max_new,
                 "device": jax.devices()[0].platform},
        "results": results,
        "ratios": ratios,
    }


def run(fast: bool = True):
    """benchmarks/run.py entry: (name, us_per_call, derived) rows.

    us_per_call is us per decoded token (1e6 / tok/s) so lower is better,
    like every other row in the suite."""
    out = bench(smoke=fast)
    paged = bench_paged(smoke=fast)
    rows = []
    for r in out["results"] + paged["results"]:
        rows.append((r["name"], 1e6 / max(r["tokens_per_sec"], 1e-9),
                     f"{r['tokens_per_sec']:.1f} tok/s slots={r['slots']}"))
    for k, v in {**out["ratios"], **paged["ratios"]}.items():
        rows.append((f"serve/ratio/{k}", 0.0, f"{v:.2f}x"))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", default=False,
                    help="reduced configs (CPU CI)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--json", default=None, help="write results JSON here")
    ap.add_argument("--mesh", default=None,
                    help="mesh sweep instead of the backend sweep, e.g. 2x4 "
                         "(gate vs benchmarks/baseline_mesh.json)")
    ap.add_argument("--paged", action="store_true", default=False,
                    help="paged-vs-dense sweep on a prefix-share workload "
                         "(same KV memory, 2x the slots; gated in "
                         "benchmarks/baseline.json)")
    ap.add_argument("--page-len", type=int, default=8)
    a = ap.parse_args(argv)
    if a.mesh:
        out = bench_mesh(smoke=a.smoke, mesh_spec=a.mesh, batch=a.batch,
                         max_new=a.max_new)
    elif a.paged:
        out = bench_paged(smoke=a.smoke, batch=a.batch, max_new=a.max_new,
                          page_len=a.page_len)
    else:
        out = bench(smoke=a.smoke, batch=a.batch, max_new=a.max_new)
    for r in out["results"]:
        print(f"{r['name']:48s} {r['tokens_per_sec']:10.1f} tok/s e2e  "
              f"{r['decode_tokens_per_sec']:10.1f} tok/s decode  slots={r['slots']}")
    for k, v in out["ratios"].items():
        print(f"{'ratio/' + k:48s} {v:10.2f} x")
    if a.json:
        with open(a.json, "w") as f:
            json.dump(out, f, indent=2)
        print(f"[serving_throughput] wrote {a.json}")


if __name__ == "__main__":
    main()
