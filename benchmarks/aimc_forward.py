"""Programmed-state forward throughput: tokens/sec per backend.

    PYTHONPATH=src python benchmarks/aimc_forward.py --smoke
    PYTHONPATH=src python benchmarks/aimc_forward.py --smoke --json out.json

The cost of executing *programmed PCM state* (the AIMC device lifecycle,
``repro/aimc_device.py``) vs plain float weights, on every engine backend:

* ``float``      — on-the-fly 5-bit quantisation (integer/pallas) or ideal
                   float matmuls (reference);
* ``programmed`` — the device-state path: int8 drifted image x per-column
                   folded scales on integer/pallas (the hot loop the
                   ``drift_to``/``recalibrate`` fold keeps warm), the full
                   analog crossbar simulation on reference.

JSON output carries absolute tok/s and machine-robust *ratios*
(programmed-vs-float relative throughput per backend); CI gates
regressions on the ratios together with ``serving_throughput.py`` (see
``benchmarks/check_regression.py``) — a change that makes programmed-state
execution fall off the int8 hot path shows up as a collapsed ratio.

``run(fast)`` rows integrate with ``benchmarks/run.py`` CSV output.
"""

from __future__ import annotations

import argparse
import json
import time

import jax

from repro.configs.xpikeformer import SPIKING_ARCHS
from repro.data.icl_mimo import MIMOConfig, sample_batch as mimo_batch
from repro.engine import XpikeformerEngine


def _time_forward(eng, x, *, iters: int) -> float:
    """Decoded-feature tokens per second through a jitted forward."""
    jf = eng.jit_forward()
    rng = jax.random.PRNGKey(1)
    jf(eng.params, x, rng).block_until_ready()  # compile
    t0 = time.perf_counter()
    for i in range(iters):
        jf(eng.params, x, jax.random.fold_in(rng, i)).block_until_ready()
    dt = time.perf_counter() - t0
    tokens = x.shape[0] * x.shape[1] * iters
    return tokens / max(dt, 1e-9)


def bench(smoke: bool = True, *, batch: int = 8, iters: int = 5,
          backends=("reference", "integer", "pallas")):
    """Returns the result dict written to --json."""
    arch = "xpikeformer-gpt-smoke" if smoke else "xpikeformer-gpt-4-256"
    task, cfg = SPIKING_ARCHS[arch]
    x = mimo_batch(jax.random.PRNGKey(0), MIMOConfig(), batch)["features"]

    results = []
    ratios = {}
    for bk in backends:
        eng = XpikeformerEngine.from_config(arch, backend=bk)
        params = eng.init(jax.random.PRNGKey(0))
        tps_float = _time_forward(eng, x, iters=iters)

        eng_hw = XpikeformerEngine.from_config(arch, backend=bk)
        eng_hw.params = params
        eng_hw.program(jax.random.PRNGKey(42))
        tps_prog = _time_forward(eng_hw, x, iters=iters)

        results += [
            {"name": f"aimc/{arch}[{bk},float]", "arch": arch, "backend": bk,
             "state": "float", "tokens_per_sec": tps_float},
            {"name": f"aimc/{arch}[{bk},programmed]", "arch": arch,
             "backend": bk, "state": "programmed", "tokens_per_sec": tps_prog},
        ]
        ratios[f"programmed_vs_float_{bk}_{arch}"] = tps_prog / max(tps_float, 1e-9)

    return {
        "meta": {"smoke": smoke, "batch": batch, "iters": iters,
                 "device": jax.devices()[0].platform},
        "results": results,
        "ratios": ratios,
    }


def run(fast: bool = True):
    """benchmarks/run.py entry: (name, us_per_call, derived) rows."""
    out = bench(smoke=fast)
    rows = []
    for r in out["results"]:
        rows.append((r["name"], 1e6 / max(r["tokens_per_sec"], 1e-9),
                     f"{r['tokens_per_sec']:.1f} tok/s {r['state']}"))
    for k, v in out["ratios"].items():
        rows.append((f"aimc/ratio/{k}", 0.0, f"{v:.2f}x"))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", default=False,
                    help="reduced arch (CPU CI)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--iters", type=int, default=5)
    ap.add_argument("--json", default=None, help="write results JSON here")
    a = ap.parse_args(argv)
    out = bench(smoke=a.smoke, batch=a.batch, iters=a.iters)
    for r in out["results"]:
        print(f"{r['name']:52s} {r['tokens_per_sec']:10.1f} tok/s")
    for k, v in out["ratios"].items():
        print(f"{'ratio/' + k:52s} {v:10.2f} x")
    if a.json:
        with open(a.json, "w") as f:
            json.dump(out, f, indent=2)
        print(f"[aimc_forward] wrote {a.json}")


if __name__ == "__main__":
    main()
