"""Benchmark orchestrator: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run          # fast mode
    PYTHONPATH=src python -m benchmarks.run --full   # paper-scale training

Each module's ``run(fast)`` returns rows of (name, us_per_call, derived);
printed as ``name,us_per_call,derived`` CSV.
"""

from __future__ import annotations

import sys
import traceback


MODULES = [
    "benchmarks.ssa_convergence",
    "benchmarks.fig8_energy",
    "benchmarks.fig9_breakdown",
    "benchmarks.fig10_latency",
    "benchmarks.table6_sota",
    "benchmarks.kernels_micro",
    "benchmarks.backend_forward",
    "benchmarks.aimc_forward",
    "benchmarks.serving_throughput",
    "benchmarks.serving_load",
    "benchmarks.roofline",
    "benchmarks.table4_icl_ber",
    "benchmarks.table3_image_cls",
    "benchmarks.table5_drift",
]


def main() -> None:
    fast = "--full" not in sys.argv
    only = [a for a in sys.argv[1:] if not a.startswith("--")]
    print("name,us_per_call,derived")
    failures = 0
    for modname in MODULES:
        if only and not any(o in modname for o in only):
            continue
        try:
            mod = __import__(modname, fromlist=["run"])
            for name, us, derived in mod.run(fast=fast):
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception as e:  # report but keep the suite going
            failures += 1
            print(f"{modname},0,ERROR:{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
