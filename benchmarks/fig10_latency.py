"""Fig. 10 reproduction: latency breakdown + GPU comparison.

(a) breakdown: periphery >92%, AIMC ~0.3%, SSA ~2.0%;
(b) speedups vs RTX A2000 GPU reference points: 2.18x over ANN transformer,
    6.85x over the GPU spiking transformer.
"""

from __future__ import annotations

import time

from repro.energy import constants as C
from repro.energy.model import Workload, latency_xpikeformer_ms


def _time_us(fn, reps: int) -> float:
    """Mean microseconds per call over ``reps`` timed repetitions."""
    fn()  # warm any lazy setup out of the measurement
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) * 1e6 / reps


def run(fast: bool = True):
    w = Workload(depth=8, dim=768, tokens=196, T_xpike=7)
    reps = 3 if fast else 50

    lat = latency_xpikeformer_ms(w)
    dt_breakdown = _time_us(lambda: latency_xpikeformer_ms(w), reps)

    ann_gpu = C.GPU_ANN_VIT_8_768_MS
    snn_gpu = ann_gpu * C.GPU_SNN_SLOWDOWN

    def speedups():
        m = latency_xpikeformer_ms(w)["total_ms"]
        return ann_gpu / m, snn_gpu / m

    vs_ann, vs_snn = speedups()
    dt_speedups = _time_us(speedups, reps)

    return [
        ("fig10a/breakdown", dt_breakdown,
         f"total={lat['total_ms']:.2f}ms periphery={lat['periphery_frac']:.3f} "
         f"aimc={lat['aimc_frac']:.3f} ssa={lat['ssa_frac']:.3f} "
         "(paper: 2.18ms, >0.92, 0.003, 0.020)"),
        ("fig10b/speedups", dt_speedups,
         f"vs_ANN_GPU={vs_ann:.2f}x (paper 2.18x) "
         f"vs_SNN_GPU={vs_snn:.2f}x (paper 6.85x)"),
    ]
