"""Fig. 10 reproduction: latency breakdown + GPU comparison.

(a) breakdown: periphery >92%, AIMC ~0.3%, SSA ~2.0%;
(b) speedups vs RTX A2000 GPU reference points: 2.18x over ANN transformer,
    6.85x over the GPU spiking transformer.
"""

from __future__ import annotations

import time

from repro.energy import constants as C
from repro.energy.model import Workload, latency_xpikeformer_ms


def run(fast: bool = True):
    w = Workload(depth=8, dim=768, tokens=196, T_xpike=7)
    t0 = time.perf_counter()
    lat = latency_xpikeformer_ms(w)
    dt = (time.perf_counter() - t0) * 1e6
    ann_gpu = C.GPU_ANN_VIT_8_768_MS
    snn_gpu = ann_gpu * C.GPU_SNN_SLOWDOWN
    rows = [
        ("fig10a/breakdown", dt,
         f"total={lat['total_ms']:.2f}ms periphery={lat['periphery_frac']:.3f} "
         f"aimc={lat['aimc_frac']:.3f} ssa={lat['ssa_frac']:.3f} "
         "(paper: 2.18ms, >0.92, 0.003, 0.020)"),
        ("fig10b/speedups", dt,
         f"vs_ANN_GPU={ann_gpu/lat['total_ms']:.2f}x (paper 2.18x) "
         f"vs_SNN_GPU={snn_gpu/lat['total_ms']:.2f}x (paper 6.85x)"),
    ]
    return rows
