"""Table IV reproduction (reduced scale): ICL MIMO symbol-detection BER.

Trains ANN-GPT / SNN-GPT / Xpikeformer-GPT on the 2x2-antenna in-context
learning task (4x4 in full mode) and reports BER — lower is better; the
paper's claim is Xpikeformer BER within ~0.01 of the GPU baselines.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.spiking_transformer import AIMCSim, SpikingConfig, init_gpt, gpt_forward
from repro.data.icl_mimo import MIMOConfig, ber, sample_batch
from repro.train.hwat import two_stage_train


def _train_eval(mode: str, T: int, steps: int, mcfg: MIMOConfig, seed: int = 0):
    gcfg = SpikingConfig(depth=2, dim=96, num_heads=2, T=T, mode=mode,
                         input_dim=mcfg.feat_dim, vocab=mcfg.n_classes)
    params = init_gpt(jax.random.PRNGKey(seed), gcfg)
    fwd = lambda p, b, sim, rng: gpt_forward(p, b["features"], gcfg, sim, rng)
    data = lambda k: sample_batch(k, mcfg, 64)
    params, _ = two_stage_train(params, fwd, data, ct_steps=steps,
                                hwat_steps=max(steps // 8, 1), lr=2e-3, seed=seed)
    b = sample_batch(jax.random.PRNGKey(999), mcfg, 256)
    logits = gpt_forward(params, b["features"], gcfg, AIMCSim(wmode="hwat"),
                         jax.random.PRNGKey(5))
    return float(ber(logits, b["labels"], b["mask"], mcfg))


def run(fast: bool = True):
    steps = 120 if fast else 2000
    antennas = [(2, 2)] if fast else [(2, 2), (4, 4)]
    rows = []
    for n_tx, n_rx in antennas:
        mcfg = MIMOConfig(n_tx=n_tx, n_rx=n_rx)
        for label, mode, T in (("ANN-GPT", "ann", 1), ("SNN-GPT(LIF)", "lif", 4),
                               ("Xpikeformer-GPT", "ssa", 6)):
            t0 = time.perf_counter()
            b = _train_eval(mode, T, steps, mcfg)
            dt = (time.perf_counter() - t0) * 1e6
            rows.append((f"table4/{n_tx}x{n_rx}/{label}(T={T})", dt, f"ber={b:.3f}"))
    return rows
