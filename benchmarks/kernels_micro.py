"""Kernel microbenchmarks (interpret mode on CPU = correctness-path timing;
real TPU timing is out of scope for this container — see §Roofline)."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def _time(fn, *args, reps=3, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run(fast: bool = True):
    key = jax.random.PRNGKey(0)
    rows = []
    t, b, h, n, d = 2, 1, 2, 64, 32
    q = jax.random.bernoulli(key, 0.3, (t, b, h, n, d)).astype(jnp.uint8)
    us = _time(ops.ssa_attention_packed, q, q, q, key, causal=False, interpret=True)
    rows.append(("kernels/ssa_attention_packed", us, f"shape=T{t}B{b}H{h}N{n}D{d}"))

    cur = jax.random.normal(key, (8, 4096))
    us = _time(ops.lif_fused, cur, interpret=True)
    rows.append(("kernels/lif_fused", us, "shape=8x4096"))

    sp = jax.random.bernoulli(key, 0.3, (4, 32, 256)).astype(jnp.float32)
    w = jax.random.randint(key, (256, 256), -15, 16, jnp.int8)
    sc = jnp.full((256,), 0.05, jnp.float32)
    us = _time(ops.aimc_spiking_linear, sp, w, sc, interpret=True)
    rows.append(("kernels/aimc_spiking_linear", us, "shape=4x32x256->256"))
    return rows
