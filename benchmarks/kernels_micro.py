"""Kernel microbenchmarks (interpret mode on CPU = correctness-path timing;
real TPU timing is out of scope for this container — see §Roofline).

    PYTHONPATH=src python benchmarks/kernels_micro.py --smoke
    PYTHONPATH=src python benchmarks/kernels_micro.py --smoke --json out.json

Two kinds of rows:

* **primitive kernels** — packed SSA attention, fused LIF, AIMC spiking
  linear: one ``pallas_call`` each, timed standalone.
* **decode layer step, fused vs unfused** — the same jitted serving
  ``decode_step`` (reduced spiking arch, pallas backend) run through both
  :class:`repro.kernels.plan.DecodePlan` strategies.  The fused plan
  launches ONE megakernel per decoder layer (bit-plane packing, Q/K/V,
  SSA decode, attention-out and FFN tail all inside the kernel, spike
  trains staying packed in VMEM); the unfused plan is the per-primitive
  path with an HBM round-trip between every stage.  Their ratio
  ``fused_vs_unfused_step`` (unfused us / fused us, higher = fused wins)
  is machine-robust — both legs run in the same process on the same
  runner — and is gated in ``benchmarks/baseline.json`` by
  ``check_regression.py``.

Timings are median-of-3 trials.  ``run(fast)`` rows integrate with
``benchmarks/run.py`` CSV output.
"""

from __future__ import annotations

import argparse
import json
import statistics
import time

import jax
import jax.numpy as jnp

from repro.kernels import ops

SPIKING_ARCH = "xpikeformer-gpt-4-256"


def _time(fn, *args, reps=3, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def _median3(fn, *args, **kw):
    return statistics.median(_time(fn, *args, reps=1, **kw) for _ in range(3))


def _primitive_rows():
    key = jax.random.PRNGKey(0)
    rows = []
    t, b, h, n, d = 2, 1, 2, 64, 32
    q = jax.random.bernoulli(key, 0.3, (t, b, h, n, d)).astype(jnp.uint8)
    us = _median3(ops.ssa_attention_packed, q, q, q, key, causal=False,
                  interpret=True)
    rows.append(("kernels/ssa_attention_packed", us,
                 f"shape=T{t}B{b}H{h}N{n}D{d}"))

    cur = jax.random.normal(key, (8, 4096))
    us = _median3(ops.lif_fused, cur, interpret=True)
    rows.append(("kernels/lif_fused", us, "shape=8x4096"))

    sp = jax.random.bernoulli(key, 0.3, (4, 32, 256)).astype(jnp.float32)
    w = jax.random.randint(key, (256, 256), -15, 16, jnp.int8)
    sc = jnp.full((256,), 0.05, jnp.float32)
    us = _median3(ops.aimc_spiking_linear, sp, w, sc, interpret=True)
    rows.append(("kernels/aimc_spiking_linear", us, "shape=4x32x256->256"))
    return rows


def _decode_step_rows(smoke: bool = True, *, batch: int = 4,
                      cache_len: int = 32, steps: int = 4):
    """Fused vs unfused jitted serving decode step on the pallas backend.

    Per-step wall time over ``steps`` chained steps (identical shapes, one
    compile per plan), median of 3 trials."""
    from repro.configs.registry import get_config, reduced_config
    from repro.engine import PallasBackend
    from repro.kernels.plan import build_decode_plan
    from repro.models import transformer as T

    cfg = reduced_config(SPIKING_ARCH) if smoke else get_config(SPIKING_ARCH)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    backend = PallasBackend()
    seeds = jnp.arange(batch, dtype=jnp.uint32)
    tok = jnp.full((batch, 1), 5, jnp.int32)

    times = {}
    for kernel in ("unfused", "fused"):
        plan = build_decode_plan(cfg, backend, kernel=kernel)

        @jax.jit
        def step(cache, tok, plan=plan):
            return T.decode_step(params, cache, tok, cfg, backend=backend,
                                 seeds=seeds, plan=plan)

        _, cache = step(T.init_cache(cfg, batch, cache_len), tok)  # compile

        def chain(cache=cache, step=step):
            lo = None
            for _ in range(steps):
                lo, cache = step(cache, tok)
            return lo

        times[kernel] = _median3(chain) / steps
    rows = [(f"kernels/decode_step[{k}]", us,
             f"arch={SPIKING_ARCH} B={batch} L={cache_len} pallas")
            for k, us in times.items()]
    return rows, times["unfused"] / max(times["fused"], 1e-9)


def bench(smoke: bool = True):
    """Returns the {results, ratios} dict written to --json."""
    rows = _primitive_rows()
    step_rows, rel = _decode_step_rows(smoke)
    results = [{"name": name, "us_per_call": us, "detail": detail}
               for name, us, detail in rows + step_rows]
    return {
        "meta": {"smoke": smoke, "device": jax.devices()[0].platform},
        "results": results,
        "ratios": {"fused_vs_unfused_step": rel},
    }


def run(fast: bool = True):
    """benchmarks/run.py entry: (name, us_per_call, derived) rows."""
    rows = _primitive_rows()
    step_rows, rel = _decode_step_rows(fast)
    rows += step_rows
    rows.append(("kernels/ratio/fused_vs_unfused_step", 0.0, f"{rel:.2f}x"))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", default=False,
                    help="reduced config for the decode-step rows (CPU CI)")
    ap.add_argument("--json", default=None, help="write results JSON here")
    a = ap.parse_args(argv)
    out = bench(smoke=a.smoke)
    for r in out["results"]:
        print(f"{r['name']:40s} {r['us_per_call']:12.1f} us  {r['detail']}")
    for k, v in out["ratios"].items():
        print(f"{'ratio/' + k:40s} {v:12.2f} x")
    if a.json:
        with open(a.json, "w") as f:
            json.dump(out, f, indent=2)
        print(f"[kernels_micro] wrote {a.json}")


if __name__ == "__main__":
    main()
