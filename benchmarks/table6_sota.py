"""Table VI reproduction: comparison with SOTA accelerators.

SwiftTron [34] and X-Former [24] rows use the paper's reported numbers
(they are external chips); the Xpikeformer row is produced by OUR model —
the reproduction claim is that our analytical pipeline lands on the
paper's reported 0.30 mJ / 2.18 ms / 784 mm^2.
"""

from __future__ import annotations

import time

from repro.energy.model import Workload, area_xpikeformer_mm2, energy_xpikeformer, \
    latency_xpikeformer_ms, total


def run(fast: bool = True):
    w = Workload(depth=8, dim=768, tokens=196, T_xpike=7)
    t0 = time.perf_counter()
    e = total(energy_xpikeformer(w)) / 1e9
    lat = latency_xpikeformer_ms(w)["total_ms"]
    params = 8 * (4 * 768 * 768 + 8 * 768 * 768) + 768 * 1000
    area = area_xpikeformer_mm2(w, params)["total_mm2"]
    dt = (time.perf_counter() - t0) * 1e6
    rows = [
        ("table6/swifttron[34]", dt, "energy=3.97mJ latency=2.26ms area=273mm2 (reported)"),
        ("table6/x-former[24]", dt, "energy=2.04mJ latency=4.13ms area=n/a (reported)"),
        ("table6/xpikeformer(ours)", dt,
         f"energy={e:.2f}mJ latency={lat:.2f}ms area={area:.0f}mm2 "
         "(paper: 0.30mJ 2.18ms 784mm2)"),
    ]
    return rows
