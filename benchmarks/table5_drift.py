"""Fig. 7 / Table V reproduction: long-term accuracy under PCM drift.

Trains a reduced Xpikeformer-ViT with CT or CT+HWAT, programs it onto
simulated PCM, and evaluates at t = {0, 1 hour, 1 day, 1 month, 1 year}
with and without global drift compensation.  The paper's claims validated:
HWAT+GDC is the most stable; without GDC accuracy collapses within hours.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import aimc_device as AD
from repro.core.aimc import AIMCConfig
from repro.core.spiking_transformer import (AIMCSim, SpikingConfig, init_vit,
                                            program_model, vit_forward)
from repro.data.synthetic_images import ImageConfig, sample_batch
from repro.train.hwat import two_stage_train

HOUR = 3600.0
TIMES = {"t0": 0.0, "1h": HOUR, "1d": 24 * HOUR, "1mo": 30 * 24 * HOUR,
         "1y": 365 * 24 * HOUR}


def run(fast: bool = True):
    steps = 90 if fast else 1200
    icfg = ImageConfig(size=16)
    acfg = AIMCConfig()
    vcfg = SpikingConfig(depth=2, dim=64, num_heads=2, T=8, mode="ssa",
                         image_size=icfg.size, patch_size=4)
    fwd = lambda p, b, sim, rng: vit_forward(p, b["images"], vcfg, sim, rng)
    data = lambda k: sample_batch(k, icfg, 64)
    test = sample_batch(jax.random.PRNGKey(77), icfg, 256)

    rows = []
    for strat, hwat_steps in (("CT", 0), ("HWAT", max(steps // 2, 1))):
        params = init_vit(jax.random.PRNGKey(0), vcfg)
        t0 = time.perf_counter()
        params, _ = two_stage_train(params, fwd, data, ct_steps=steps,
                                    hwat_steps=hwat_steps, lr=3e-3, aimc_cfg=acfg)
        hw = program_model(jax.random.PRNGKey(42), params, acfg)
        sim = AIMCSim(wmode="hw", cfg=acfg)
        for gdc in (False, True):
            accs = {}
            for name, t in TIMES.items():
                # device lifecycle: drift the programmed state to t;
                # GDC rows recalibrate at t (ideal periodic compensation)
                drifted = AD.drift_tree(hw, t, acfg)
                if gdc:
                    drifted = AD.recalibrate_tree(drifted, acfg)
                logits = vit_forward(drifted, test["images"], vcfg, sim,
                                     jax.random.PRNGKey(5))
                accs[name] = float(jnp.mean(jnp.argmax(logits, -1) == test["labels"]))
            dt = (time.perf_counter() - t0) * 1e6
            label = f"table5/{strat}+{'GDC' if gdc else 'NC'}"
            detail = " ".join(f"{k}={v:.3f}" for k, v in accs.items())
            rows.append((label, dt, detail))
    return rows
