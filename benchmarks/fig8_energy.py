"""Fig. 8 reproduction: per-inference energy, 4 designs x model sizes.

Asserts the paper's headline ratios on the ViT-8-768 ImageNet benchmark:
ANN-Quant 9.6-13x, ANN-Quant+AIMC ~5.4-5.9x, SNN-Digi-Opt 1.8-1.9x the
Xpikeformer energy (Table VI normalised task).
"""

from __future__ import annotations

import time

from repro.energy.model import Workload, all_designs, total

# (label, workload) — Table III / IV model sizes and converged T values
CASES = [
    ("vit-6-512", Workload(depth=6, dim=512, tokens=196, T_xpike=8, T_snn=6)),
    ("vit-8-768", Workload(depth=8, dim=768, tokens=196, T_xpike=7, T_snn=4)),
    ("gpt-4-256", Workload(depth=4, dim=256, tokens=37, T_xpike=11, T_snn=7, classes=256)),
    ("gpt-8-512", Workload(depth=8, dim=512, tokens=37, T_xpike=5, T_snn=4, classes=256)),
]


def run(fast: bool = True):
    rows = []
    for label, w in CASES:
        t0 = time.perf_counter()
        d = all_designs(w)
        tx = total(d["Xpikeformer"])
        dt = (time.perf_counter() - t0) * 1e6
        detail = " ".join(
            f"{k.replace(' ', '')}={total(v)/1e9:.3f}mJ({total(v)/tx:.1f}x)"
            for k, v in d.items()
        )
        rows.append((f"fig8/{label}", dt, detail))
    return rows
