"""Fig. 9 reproduction: Xpikeformer computational-energy breakdown."""

from __future__ import annotations

import time

from repro.energy.model import Workload, energy_xpikeformer


def run(fast: bool = True):
    w = Workload(depth=8, dim=768, tokens=196, T_xpike=7)
    t0 = time.perf_counter()
    e = energy_xpikeformer(w)
    dt = (time.perf_counter() - t0) * 1e6
    tc = e["compute"]
    aimc = sum(e["aimc_breakdown"].values())
    ab = e["aimc_breakdown"]
    rows = [
        ("fig9/compute_split", dt,
         f"aimc={aimc/tc:.3f} ssa={e['ssa']/tc:.3f} other={e['other']/tc:.3f} "
         "(paper: 0.784/0.189/0.027)"),
        ("fig9/aimc_split", dt,
         f"periphery={ab['periphery']/aimc:.3f} accum={ab['accum']/aimc:.3f} "
         f"adc={ab['adc']/aimc:.3f} (paper: 0.859/0.121/0.020)"),
    ]
    return rows
