"""SSA rate convergence (paper §IV-B, Eq. 6).

As the spike-encoding length T grows, the firing rate of
``BNL(BNL(Q^t K^t^T) V^t)`` converges to the deterministic rate product
``clip((Q K^T / d) V / N)``.  Reports mean |rate - expected| vs T — the
empirical error should fall ~ 1/sqrt(T).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import spikes as SP
from repro.core import ssa as SSA


def run(fast: bool = True):
    key = jax.random.PRNGKey(0)
    b, h, n, d = (2, 2, 32, 32) if fast else (4, 4, 64, 64)
    kq, kk, kv, ke = jax.random.split(key, 4)
    q_rate = jax.random.uniform(kq, (b, h, n, d))
    k_rate = jax.random.uniform(kk, (b, h, n, d))
    v_rate = jax.random.uniform(kv, (b, h, n, d))
    expected = SSA.ssa_attention_rate(q_rate, k_rate, v_rate)

    rows = []
    ts = (2, 4, 8, 16, 32) if fast else (2, 4, 8, 16, 32, 64, 128)
    for T in ts:
        kt = jax.random.fold_in(ke, T)
        ks = jax.random.split(kt, 4)
        q = SP.rate_encode(ks[0], q_rate, T, straight_through=False)
        k = SP.rate_encode(ks[1], k_rate, T, straight_through=False)
        v = SP.rate_encode(ks[2], v_rate, T, straight_through=False)
        t0 = time.perf_counter()
        out = SSA.ssa_attention_integer(ks[3], q.astype(jnp.int32), k.astype(jnp.int32),
                                        v.astype(jnp.int32))
        out = jax.block_until_ready(out)
        dt = (time.perf_counter() - t0) * 1e6
        err = float(jnp.mean(jnp.abs(jnp.mean(out.astype(jnp.float32), 0) - expected)))
        rows.append((f"ssa_convergence/T={T}", dt, f"mae={err:.4f}"))
    # convergence check: error at largest T must beat error at smallest T
    return rows
