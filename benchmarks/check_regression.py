"""Benchmark regression gate for CI.

    python benchmarks/check_regression.py current.json \
        --baseline benchmarks/baseline.json --tolerance 0.30
    python benchmarks/check_regression.py serving.json aimc.json \
        --baseline benchmarks/baseline.json

Compares fresh ``--json`` runs (``serving_throughput.py`` and
``aimc_forward.py``; multiple files are merged — their ratio keys are
disjoint) against the checked-in baseline and exits non-zero if any gated
metric regressed by more than ``--tolerance`` (default 30%).

Gated by default: the ``ratios`` block only — batched-vs-sequential
speedup and backend-vs-reference relative throughput.  Ratios are
machine-robust (both numerator and denominator ran on the same runner in
the same process), while absolute tokens/sec swings with CI hardware;
pass ``--absolute`` to gate raw tok/s too (useful on pinned hardware).

A baseline may carry a ``"tolerances"`` block mapping individual ratio
keys to a tighter (or looser) tolerance than the global ``--tolerance``
— e.g. ``obs_overhead_rel_*`` is gated at 5% because telemetry must stay
effectively free, while noisy tail-latency ratios keep the default 30%.
"""

from __future__ import annotations

import argparse
import json
import sys


def check(current: dict, baseline: dict, tolerance: float, absolute: bool):
    failures = []
    report = []
    base_ratios = baseline.get("ratios", {})
    cur_ratios = current.get("ratios", {})
    per_key = baseline.get("tolerances", {})
    for k, base in sorted(base_ratios.items()):
        cur = cur_ratios.get(k)
        if cur is None:
            failures.append(f"ratio {k}: missing from current run")
            continue
        tol = per_key.get(k, tolerance)
        floor = base * (1.0 - tol)
        status = "OK" if cur >= floor else "REGRESSED"
        report.append(f"ratio {k}: {cur:.2f}x vs baseline {base:.2f}x "
                      f"(floor {floor:.2f}x, tol {tol:.0%}) {status}")
        if cur < floor:
            failures.append(report[-1])
    if absolute:
        base_by = {r["name"]: r for r in baseline.get("results", [])}
        for r in current.get("results", []):
            b = base_by.get(r["name"])
            if b is None or "tokens_per_sec" not in r or "tokens_per_sec" not in b:
                continue  # kernel-time rows gate via ratios only
            floor = b["tokens_per_sec"] * (1.0 - tolerance)
            status = "OK" if r["tokens_per_sec"] >= floor else "REGRESSED"
            report.append(
                f"abs {r['name']}: {r['tokens_per_sec']:.1f} tok/s vs baseline "
                f"{b['tokens_per_sec']:.1f} (floor {floor:.1f}) {status}")
            if r["tokens_per_sec"] < floor:
                failures.append(report[-1])
    return failures, report


def merge(runs):
    """Merge several benchmark JSONs (disjoint ratio keys, concat results)."""
    out = {"results": [], "ratios": {}}
    for run in runs:
        out["results"].extend(run.get("results", []))
        out["ratios"].update(run.get("ratios", {}))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("current", nargs="+",
                    help="fresh benchmark --json outputs (merged)")
    ap.add_argument("--baseline", default="benchmarks/baseline.json")
    ap.add_argument("--tolerance", type=float, default=0.30)
    ap.add_argument("--absolute", action="store_true",
                    help="also gate absolute tok/s (pinned hardware only)")
    a = ap.parse_args(argv)
    runs = []
    for path in a.current:
        with open(path) as f:
            runs.append(json.load(f))
    current = merge(runs)
    with open(a.baseline) as f:
        baseline = json.load(f)
    failures, report = check(current, baseline, a.tolerance, a.absolute)
    for line in report:
        print(line)
    if failures:
        print(f"\n{len(failures)} benchmark regression(s) beyond "
              f"{a.tolerance:.0%} tolerance", file=sys.stderr)
        sys.exit(1)
    print("\nall benchmark gates passed")


if __name__ == "__main__":
    main()
