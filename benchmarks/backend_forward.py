"""End-to-end backend benchmark: full spiking model forwards per substrate.

    PYTHONPATH=src python benchmarks/backend_forward.py [--arch xpikeformer-vit-smoke]

The point of the unified engine API is that the Pallas kernels sit on the
model hot path — so they can be timed (and later TPU-profiled) through the
exact code the tasks run, not through synthetic per-kernel shapes.  On this
CPU container the pallas backend runs in interpret mode, which times the
correctness path only; compiled-kernel timing needs a TPU.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.xpikeformer import SPIKING_ARCHS
from repro.data.icl_mimo import MIMOConfig, sample_batch as mimo_batch
from repro.engine import BACKENDS, XpikeformerEngine


def _time(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us, like kernels_micro


def _inputs(task: str, batch: int, key):
    if task == "vit":
        return jax.random.uniform(key, (batch, 16, 16, 3))
    return mimo_batch(key, MIMOConfig(), batch)["features"]


def run(arch: str = "xpikeformer-vit-smoke", batch: int = 4, fast: bool = True):
    if not fast:  # --full: paper-scale smallest ViT instead of smoke
        arch = "xpikeformer-vit-4-384" if "vit" in arch else arch
    task, _ = SPIKING_ARCHS[arch]
    key = jax.random.PRNGKey(0)
    x = _inputs(task, batch, jax.random.fold_in(key, 1))
    rng = jax.random.fold_in(key, 2)
    rows = []
    params = None
    for backend in sorted(BACKENDS):
        eng = XpikeformerEngine.from_config(arch, backend=backend)
        if params is None:
            params = eng.init(key)
        eng.params = params
        fwd = eng.jit_forward()
        us = _time(lambda xx: fwd(params, xx, rng), x)
        rows.append((f"engine/{task}-forward[{backend}]", us,
                     f"arch={arch} B={batch}"))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xpikeformer-vit-smoke",
                    choices=sorted(SPIKING_ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    a = ap.parse_args(argv)
    for name, us, note in run(a.arch, a.batch):
        print(f"{name:44s} {us:12.1f} us   {note}")


if __name__ == "__main__":
    main()
