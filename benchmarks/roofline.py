"""Roofline report: reads experiments/dryrun/*.json into the §Roofline table.

Per (arch x shape) single-pod cell: the three roofline terms, the dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs, and per-device memory.  Also emits a
markdown table for EXPERIMENTS.md (``python -m benchmarks.roofline --md``).
"""

from __future__ import annotations

import json
import time
from pathlib import Path

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load_cells(mesh: str = "single", variant: str = "base"):
    out = []
    for p in sorted(DRYRUN_DIR.glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("mesh") != mesh and "skipped" not in rec:
            continue
        if "skipped" in rec:
            if p.stem.endswith(f"__{mesh}"):
                out.append(rec)
            continue
        if rec.get("variant", "base") != variant:
            continue
        out.append(rec)
    return out


def fmt_row(rec) -> str:
    if "skipped" in rec:
        return f"{rec['cell']:45s} SKIP ({rec['skipped'][:60]})"
    t = {k: max(v, 0.0) for k, v in rec["roofline_terms_s"].items()}
    mem = rec["memory_analysis"].get("temp_size_in_bytes", 0) / 2**30
    return (
        f"{rec['cell']:45s} comp={t['compute_s']:.4f}s mem={t['memory_s']:.4f}s "
        f"coll={t['collective_s']:.4f}s dom={rec['bottleneck'][:-2]:10s} "
        f"useful={rec['useful_flops_ratio']:.2f} temp={mem:.1f}GiB"
    )


def markdown_table(mesh: str = "single", variant: str = "base") -> str:
    lines = [
        "| cell | compute (s) | memory (s) | collective (s) | bottleneck | "
        "MODEL/HLO flops | step (s) | temp GiB | mode |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in load_cells(mesh, variant):
        if "skipped" in rec:
            lines.append(f"| {rec['cell']} | — | — | — | SKIP: {rec['skipped'][:70]} | | | | |")
            continue
        t = {k: max(v, 0.0) for k, v in rec["roofline_terms_s"].items()}
        mem = rec["memory_analysis"].get("temp_size_in_bytes", 0) / 2**30
        lines.append(
            f"| {rec['cell']} | {t['compute_s']:.4f} | {t['memory_s']:.4f} | "
            f"{t['collective_s']:.4f} | {rec['bottleneck'][:-2]} | "
            f"{rec['useful_flops_ratio']:.2f} | {rec['roofline_step_time_s']:.4f} | "
            f"{mem:.1f} | {rec['mode']} |"
        )
    return "\n".join(lines)


def run(fast: bool = True):
    t0 = time.perf_counter()
    cells = load_cells()
    dt = (time.perf_counter() - t0) * 1e6
    done = [c for c in cells if "skipped" not in c]
    skipped = [c for c in cells if "skipped" in c]
    by_dom = {}
    for c in done:
        by_dom[c["bottleneck"]] = by_dom.get(c["bottleneck"], 0) + 1
    rows = [("roofline/summary", dt,
             f"cells={len(done)} skipped={len(skipped)} bottlenecks={by_dom}")]
    for c in done:
        t = c["roofline_terms_s"]
        rows.append((f"roofline/{c['cell']}", dt,
                     f"dom={c['bottleneck'][:-2]} step={c['roofline_step_time_s']:.4f}s "
                     f"useful={c['useful_flops_ratio']:.2f}"))
    return rows


if __name__ == "__main__":
    import sys

    if "--md" in sys.argv:
        print(markdown_table())
    else:
        for rec in load_cells():
            print(fmt_row(rec))
